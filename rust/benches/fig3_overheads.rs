//! End-to-end bench: regenerate paper Figure 3 at reduced scale and time it.
//!
//! `cargo bench --bench fig3_*` — the full-scale regeneration is
//! `sparkbench figure 3`; this bench keeps CI latency bounded while
//! exercising the identical code path.

use sparkbench::bench::{render_results, Bencher};
use sparkbench::experiments::{run_figure, ExpOptions};

fn main() {
    let mut opts = ExpOptions::default();
    opts.scale = "512,4096,48".into();
    opts.workers = 4;
    opts.seeds = 1;
    opts.out_dir = std::env::temp_dir().join("sparkbench_bench_results");
    let b = Bencher::quick();
    let stats = b.run("figure 3 (reduced scale)", || {
        run_figure(3, &opts).expect("figure 3")
    });
    // Print the last rendition so the bench output carries the series.
    let out = run_figure(3, &opts).unwrap();
    println!("{}", out);
    println!("{}", render_results("figure 3", &[stats]));
}
