//! Hot-path micro-benchmarks: the kernels the §Perf pass optimizes.
//!
//! Run with `cargo bench --bench hotpath`. Besides the per-kernel table it
//! writes `BENCH_hotpath.json` at the repo root so the perf trajectory of
//! the reduction/allocation work is tracked PR-over-PR. The headline
//! comparisons:
//!
//! * **tree vs serial AllReduce** at K ∈ {4, 8, 16}: the old master loop
//!   (fresh zeroed accumulator + K sequential `add_assign` passes) against
//!   [`linalg::tree_reduce`] (in-place pairwise tree, level-parallel on
//!   multi-core) — the acceptance bar is ≥ 1.5× at K = 8;
//! * **pooled vs fresh-alloc round**: `NativeScd::solve` (owned result
//!   buffers per call) against `solve_into` with persistent buffers, plus
//!   the measured allocation counts per round from the counting allocator;
//! * **sparse Δv frames** (DESIGN.md §7): actual encoded bytes/round of
//!   the nnz-adaptive frames vs dense on a sparse workload (bar: ≥ 5×
//!   fewer at nnz/m ≤ 0.1, 0 steady-state allocations in the
//!   extract→encode→reduce pipeline), and a dense-vs-sparse H sweep
//!   locating the optimal-H shift;
//! * **nested two-level parallelism** (DESIGN.md §10): threads-engine
//!   wall-clock K×T sweep at a fixed K·H work budget — bar:
//!   `nested_speedup_t4 ≥ 2.0` on ≥ 4 cores — plus the 0-alloc assertion
//!   on the nested sub-solve → two-stage-reduce pipeline;
//! * **kernel backends** (DESIGN.md §11): forced-scalar vs dispatched
//!   (AVX2 under `--features simd`) ns/element for `dot` / `axpy` /
//!   `dot_indexed` / `axpy_indexed` at m ∈ {2¹², 2¹⁶, 2²⁰}, plus the
//!   cache-blocked vs flat CSC traversal of a full SCD round — bar:
//!   dispatched ≥ 1.3× scalar on `dot` at m = 2²⁰ when the avx2 backend
//!   is active (identical bits either way; the ratio is pure speed);
//! * **mixed precision** (DESIGN.md §11): f64 vs mixed-f32 ns/step on the
//!   same round, and the final-objective delta of a 120-round single-shard
//!   trajectory (expected ≤ 1e-3 relative — mixed-f32 is NOT bit-stable);
//! * **chaos layer** (DESIGN.md §12): homogeneous vs heterogeneous
//!   round-time distribution on the virtual clock (round time = max over
//!   seeded per-worker speeds + latency jitter), with speculation off and
//!   on — same bits all three ways, only the clock moves;
//! * **serving** (DESIGN.md §13): steady-state batched predict over the
//!   CSR request mirror — bar: 0 allocations/batch once warm — with 1-core
//!   predictions/sec, sharded speedup at T ∈ {2, 4}, and the batching
//!   front end replayed above and below the cutover rate
//!   λ* = max_batch/max_delay (queue-wait and latency p50/p99 per regime).

use sparkbench::bench::{render_results, Bencher};
use sparkbench::config::{Impl, Precision, TrainConfig};
use sparkbench::coordinator;
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::data::{CsrMatrix, Partitioner, Partitioning, WorkerData};
use sparkbench::framework::serialization::{java_encoded_len, java_sparse_cutover, JavaSer, PickleSer};
use sparkbench::framework::{build_any, Engine, EngineOptions};
use sparkbench::linalg;
use sparkbench::linalg::{DeltaReducer, DeltaSlot, NestedTreePlan};
use sparkbench::problem::{GapScratch, Problem};
use sparkbench::serve::{
    overload_replay, replay, ArrivalPattern, BatchPolicy, OverloadConfig, Predictor, ServiceModel,
};
use sparkbench::session::Session;
use sparkbench::solver::{scd::NativeScd, LocalSolver, SolveRequest, SolveResult};
use sparkbench::testkit::alloc::{current_thread_allocations, CountingAllocator};
use sparkbench::testkit::reference::PreRedesignElasticScd;
use sparkbench::util::json::Json;

/// Count every allocation the bench performs so the pooled-vs-fresh cases
/// can report exact allocations/round next to their timings.
#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// AllReduce problem size: large enough that one pairwise add dwarfs a
/// thread spawn, which is the regime the reduction actually runs in at
/// production scale (m = 1M doubles ≈ 8 MB/worker).
const REDUCE_M: usize = 1 << 20;

fn reduce_inputs(k: usize, m: usize) -> Vec<Vec<f64>> {
    (0..k)
        .map(|w| (0..m).map(|i| ((w * 31 + i) % 97) as f64 * 0.125).collect())
        .collect()
}

fn main() {
    let b = Bencher::default();
    let mut results = Vec::new();
    let mut json = Json::obj();
    json.set("bench", "hotpath").set("schema_version", 9usize);

    // ---- sparse dot / axpy — one call per SCD step, THE hot pair --------
    let ds = webspam_like(&SyntheticSpec::webspam_mini());
    let (ri, vs) = ds.a.col(100);
    let dense = vec![1.0; ds.m()];
    results.push(b.run("dot_indexed (1 col)", || {
        linalg::dot_indexed(ri, vs, &dense)
    }));
    let mut dense_mut = vec![1.0; ds.m()];
    results.push(b.run("axpy_indexed (1 col)", || {
        linalg::axpy_indexed(0.5, ri, vs, &mut dense_mut);
    }));
    results.push(b.run("dot_indexed_fused (1 col)", || {
        linalg::dot_indexed_fused(ri, vs, &dense)
    }));

    // ---- kernel backends: forced-scalar vs dispatched (DESIGN.md §11) ---
    // The dispatcher routes to AVX2 only under `--features simd` on an
    // x86-64 with the feature bit set; elsewhere both rows time the same
    // scalar code and the ratio reads ~1.0. Either way the bits are
    // identical (tests/integration_kernels.rs), so this table is the only
    // place the backend choice is visible.
    {
        use sparkbench::linalg::kernels;
        let mut jk = Json::obj();
        jk.set("backend", kernels::backend());
        for &m in &[1usize << 12, 1 << 16, 1 << 20] {
            let lg = m.trailing_zeros();
            let x: Vec<f64> = (0..m).map(|i| ((i * 31) % 97) as f64 * 0.125 - 6.0).collect();
            let y: Vec<f64> = (0..m).map(|i| ((i * 17) % 89) as f64 * 0.25 - 11.0).collect();
            let mut acc = vec![0.0; m];
            // Synthetic column touching every 3rd row — the gather-bound
            // indexed pair at a controlled density.
            let idx: Vec<u32> = (0..(m as u32) / 3).map(|i| i * 3).collect();
            let vals: Vec<f64> = idx.iter().map(|&i| (i % 13) as f64 * 0.5 - 3.0).collect();
            let mut jm = Json::obj();
            let mut dot_ns = [0.0f64; 2];
            for (slot, forced) in [(0usize, true), (1usize, false)] {
                kernels::force_scalar(forced);
                let tag = if forced { "scalar" } else { "dispatch" };
                let d = b.run(&format!("dot m=2^{} ({})", lg, tag), || linalg::dot(&x, &y));
                let a = b.run(&format!("axpy m=2^{} ({})", lg, tag), || {
                    linalg::axpy(0.5, &x, &mut acc)
                });
                let di = b.run(&format!("dot_indexed m=2^{} ({})", lg, tag), || {
                    linalg::dot_indexed(&idx, &vals, &x)
                });
                let ai = b.run(&format!("axpy_indexed m=2^{} ({})", lg, tag), || {
                    linalg::axpy_indexed(0.5, &idx, &vals, &mut acc)
                });
                dot_ns[slot] = d.mean_s * 1e9 / m as f64;
                jm.set(&format!("dot_ns_per_elem_{}", tag), d.mean_s * 1e9 / m as f64)
                    .set(&format!("axpy_ns_per_elem_{}", tag), a.mean_s * 1e9 / m as f64)
                    .set(
                        &format!("dot_indexed_ns_per_elem_{}", tag),
                        di.mean_s * 1e9 / idx.len().max(1) as f64,
                    )
                    .set(
                        &format!("axpy_indexed_ns_per_elem_{}", tag),
                        ai.mean_s * 1e9 / idx.len().max(1) as f64,
                    );
                results.push(d);
                results.push(a);
                results.push(di);
                results.push(ai);
            }
            kernels::force_scalar(false);
            let speedup = dot_ns[0] / dot_ns[1].max(1e-12);
            println!(
                "kernels m=2^{:2} [{}]: dot {:.3} ns/elem scalar vs {:.3} dispatched → {:.2}x",
                lg,
                kernels::backend(),
                dot_ns[0],
                dot_ns[1],
                speedup
            );
            jm.set("dot_speedup", speedup);
            jk.set(&format!("m{}", m), jm);
        }
        json.set("kernels", jk);
    }

    // ---- full local solve: fresh-alloc vs pooled ------------------------
    let cols: Vec<u32> = (0..(ds.n() as u32 / 8)).collect();
    let wd = WorkerData::from_columns(&ds.a, &cols);
    let alpha = vec![0.0; wd.n_local()];
    let v = vec![0.0; ds.m()];
    let mut solver = NativeScd::new();
    let ridge = Problem::ridge(1.0);
    let req = SolveRequest {
        v: &v,
        b: &ds.b,
        h: wd.n_local(),
        problem: &ridge,
        sigma: 8.0,
        seed: 1,
    };
    let fresh = b.run("native_scd round (fresh alloc)", || {
        solver.solve(&wd, &alpha, &req)
    });
    let a0 = current_thread_allocations();
    let _ = solver.solve(&wd, &alpha, &req);
    let fresh_allocs = current_thread_allocations() - a0;

    let mut out = SolveResult::default();
    solver.solve_into(&wd, &alpha, &req, &mut out); // warmup buffers
    let pooled = b.run("native_scd round (pooled, solve_into)", || {
        solver.solve_into(&wd, &alpha, &req, &mut out)
    });
    let a0 = current_thread_allocations();
    solver.solve_into(&wd, &alpha, &req, &mut out);
    let pooled_allocs = current_thread_allocations() - a0;
    println!(
        "allocations/round: fresh = {}, pooled = {} (pooled MUST be 0)",
        fresh_allocs, pooled_allocs
    );
    let round_speedup = fresh.mean_s / pooled.mean_s.max(1e-12);
    results.push(fresh.clone());
    results.push(pooled.clone());
    {
        let mut jr = Json::obj();
        jr.set("fresh_mean_s", fresh.mean_s)
            .set("pooled_mean_s", pooled.mean_s)
            .set("speedup", round_speedup)
            .set("fresh_allocs_per_round", fresh_allocs)
            .set("pooled_allocs_per_round", pooled_allocs);
        json.set("pooled_round", jr);
    }

    // ---- AllReduce: serial fold (old master loop) vs pairwise tree ------
    let mut jallr = Json::obj();
    for k in [4usize, 8, 16] {
        let mut bufs = reduce_inputs(k, REDUCE_M);
        let serial = b.run(&format!("allreduce serial fold (K={})", k), || {
            let mut agg = vec![0.0; REDUCE_M];
            for d in &bufs {
                linalg::add_assign(&mut agg, d);
            }
            agg
        });
        let tree = b.run(&format!("allreduce tree (K={})", k), || {
            let mut refs: Vec<&mut [f64]> =
                bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
            linalg::tree_reduce(&mut refs);
        });
        let speedup = serial.mean_s / tree.mean_s.max(1e-12);
        println!(
            "K={:2}: serial {:.3} ms, tree {:.3} ms → {:.2}x",
            k,
            serial.mean_s * 1e3,
            tree.mean_s * 1e3,
            speedup
        );
        let mut jk = Json::obj();
        jk.set("serial_mean_s", serial.mean_s)
            .set("tree_mean_s", tree.mean_s)
            .set("speedup", speedup)
            .set("m", REDUCE_M);
        jallr.set(&format!("k{}", k), jk);
        results.push(serial);
        results.push(tree);
    }
    json.set("allreduce", jallr);

    // ---- serialization codecs: fresh frames vs pooled encode_into -------
    let payload = vec![1.5f64; ds.m()];
    results.push(b.run("java ser+deser (fresh frame)", || {
        JavaSer::decode(&JavaSer::encode(&payload)).unwrap()
    }));
    let mut jframe = Vec::new();
    JavaSer::encode_into(&payload, &mut jframe);
    results.push(b.run("java encode_into (pooled frame)", || {
        JavaSer::encode_into(&payload, &mut jframe)
    }));
    results.push(b.run("pickle ser+deser (fresh frame)", || {
        PickleSer::decode(&PickleSer::encode(&payload)).unwrap()
    }));
    let mut pframe = Vec::new();
    PickleSer::encode_into(&payload, &mut pframe);
    results.push(b.run("pickle encode_into (pooled frame)", || {
        PickleSer::encode_into(&payload, &mut pframe)
    }));

    // ---- sparse Δv frames: bytes/round, allocs, optimal-H shift ---------
    // Sparse workload (DESIGN.md §7): columns carry ~8 of 4096 rows, so a
    // small-H round's Δv has nnz/m ≤ 0.1 and the nnz-adaptive layer emits
    // sparse frames. Acceptance bars: ≥5× fewer Δv bytes/round than dense
    // and 0 steady-state allocations in the extract→encode→reduce pipeline.
    {
        let spec = SyntheticSpec {
            m: 4096,
            n: 8192,
            avg_col_nnz: 8,
            powerlaw_s: 1.1,
            model_density: 0.2,
            noise: 0.02,
            seed: 5,
        };
        let sds = webspam_like(&spec);
        let m = sds.m();
        let k = 8usize;
        let mut cfg = TrainConfig::default_for(&sds);
        cfg.workers = k;
        let h_sparse = 32usize;

        // K real worker deltas at small H (the sparse regime).
        let parts = Partitioning::build(Partitioner::Range, &sds.a, k, 0);
        let v0 = vec![0.0; m];
        let mut deltas: Vec<Vec<f64>> = Vec::new();
        for w in 0..k {
            let swd = WorkerData::from_columns(&sds.a, &parts.parts[w]);
            let salpha = vec![0.0; swd.n_local()];
            let sreq = SolveRequest {
                v: &v0,
                b: &sds.b,
                h: h_sparse,
                problem: &cfg.problem,
                sigma: cfg.sigma(),
                seed: 1 + w as u64,
            };
            deltas.push(NativeScd::new().solve(&swd, &salpha, &sreq).delta_v);
        }
        let nnz_max = deltas
            .iter()
            .map(|d| d.iter().filter(|&&x| x != 0.0).count())
            .max()
            .unwrap_or(0);
        let nnz_frac = nnz_max as f64 / m as f64;

        // Frame bytes: the counterfactual dense frames vs the ACTUAL
        // sparse encodes (java codec, delta-varint indices).
        let mut red = DeltaReducer::new(m, java_sparse_cutover(m));
        let mut slots: Vec<DeltaSlot> = (0..k).map(|_| DeltaSlot::new()).collect();
        let mut frame = Vec::new();
        let mut sparse_bytes = 0u64;
        for (slot, d) in slots.iter_mut().zip(deltas.iter()) {
            red.load(slot, d);
            JavaSer::encode_delta_into(slot, &mut frame);
            sparse_bytes += frame.len() as u64;
        }
        let dense_bytes = (k * java_encoded_len(m)) as u64;
        let byte_ratio = dense_bytes as f64 / sparse_bytes.max(1) as f64;
        println!(
            "sparse Δv frames (nnz/m ≤ {:.3}): dense {} B/round vs sparse {} B/round → {:.1}x fewer bytes (MUST be ≥ 5x)",
            nnz_frac, dense_bytes, sparse_bytes, byte_ratio
        );

        // Steady-state allocations of the full sparse pipeline.
        red.reduce(&mut slots); // warmup: merge scratch + any promotions
        let a0 = current_thread_allocations();
        const SPARSE_ROUNDS: u64 = 5;
        for _ in 0..SPARSE_ROUNDS {
            for (slot, d) in slots.iter_mut().zip(deltas.iter()) {
                red.load(slot, d);
                JavaSer::encode_delta_into(slot, &mut frame);
            }
            red.reduce(&mut slots);
        }
        let sparse_allocs = (current_thread_allocations() - a0) / SPARSE_ROUNDS;
        println!(
            "sparse pipeline (extract→encode→reduce) allocations/round: {} (MUST be 0)",
            sparse_allocs
        );

        // Reduce timings on the same deltas: sparse-aware vs dense tree.
        let tr_sparse = b.run("sparse delta reduce (K=8, sparse Δv)", || {
            for (slot, d) in slots.iter_mut().zip(deltas.iter()) {
                red.load(slot, d);
            }
            red.reduce(&mut slots);
        });
        let mut dense_bufs = deltas.clone();
        let tr_dense = b.run("dense tree reduce (same Δv)", || {
            for (buf, d) in dense_bufs.iter_mut().zip(deltas.iter()) {
                buf.copy_from_slice(d);
            }
            let mut refs: Vec<&mut [f64]> =
                dense_bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
            linalg::tree_reduce(&mut refs);
        });
        let reduce_speedup = tr_dense.mean_s / tr_sparse.mean_s.max(1e-12);
        results.push(tr_sparse);
        results.push(tr_dense);

        // H sweep: how sparse frames shift the optimal H. Per H, train to
        // target with dense-forced vs adaptive frames; virtual
        // time-to-target reflects the actual bytes charged per round.
        let fstar = coordinator::oracle_objective(&sds, &cfg);
        let hs = [4usize, 16, 64, 256, 1024];
        let mut jsweep = Json::obj();
        let mut best = [(f64::INFINITY, 0usize); 2]; // [dense, sparse]
        for &h in &hs {
            let mut c = cfg.clone();
            c.h_abs = Some(h);
            c.max_rounds = 600;
            let time_for = |dense_frames: bool| -> f64 {
                let opts = EngineOptions {
                    dense_frames,
                    ..Default::default()
                };
                let rep = Session::builder(&sds)
                    .engine(Impl::SparkCOpt)
                    .options(opts)
                    .config(c.clone())
                    .oracle(fstar)
                    .build()
                    .expect("valid bench session")
                    .run();
                // Penalize runs that missed the target inside max_rounds.
                rep.time_to_target.unwrap_or(rep.total_time * 10.0)
            };
            let td = time_for(true);
            let ts = time_for(false);
            if td < best[0].0 {
                best[0] = (td, h);
            }
            if ts < best[1].0 {
                best[1] = (ts, h);
            }
            println!(
                "H={:5}: dense-frames {:.3} s vs sparse-frames {:.3} s (virtual time-to-target)",
                h, td, ts
            );
            let mut jh = Json::obj();
            jh.set("dense_s", td).set("sparse_s", ts);
            jsweep.set(&format!("h{}", h), jh);
        }
        println!(
            "optimal H: dense-frames {} vs sparse-frames {} (sparse comm shifts the trade-off toward more communication)",
            best[0].1, best[1].1
        );

        let mut js = Json::obj();
        js.set("dv_nnz_frac_max", nnz_frac)
            .set("dense_bytes_per_round", dense_bytes)
            .set("sparse_bytes_per_round", sparse_bytes)
            .set("byte_ratio", byte_ratio)
            .set("allocs_per_round", sparse_allocs)
            .set("reduce_speedup_vs_dense", reduce_speedup)
            .set("h_sweep", jsweep)
            .set("optimal_h_dense", best[0].1)
            .set("optimal_h_sparse", best[1].1);
        json.set("sparse_frames", js);
    }

    // ---- nested two-level parallelism: threads-engine K×T sweep ---------
    // Equal K·H work budget per round: T sub-solvers each run H/T local
    // steps over 1/T of the columns, physically parallel on the rank's
    // sub-pool. Acceptance bar: wall-clock speedup of T = 4 over T = 1 is
    // ≥ 2.0× on ≥ 4 cores (reported with the measured core count — a
    // 2-core box tops out near 2×). Trajectory bits are flat-identical by
    // construction (tests/integration_nested.rs).
    {
        let cores = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 1;
        const TOTAL_H: usize = 4096;
        const NESTED_ROUNDS: usize = 6;
        let mut jn = Json::obj();
        let mut walls = Vec::new();
        for t in [1usize, 2, 4] {
            let mut eng = build_any(
                Engine::threads_nested(1, t),
                &ds,
                &cfg,
                &EngineOptions::default(),
            );
            let h = TOTAL_H / t;
            let mut v = vec![0.0; ds.m()];
            let (dv, _) = eng.run_round(&v, h, 0); // warmup round
            linalg::add_assign(&mut v, &dv);
            let mut samples = Vec::new();
            for round in 1..=NESTED_ROUNDS as u64 {
                // real wall time is the measurement (bench allowlist)
                #[allow(clippy::disallowed_methods)]
                let t0 = std::time::Instant::now();
                let (dv, _) = eng.run_round(&v, h, round);
                samples.push(t0.elapsed().as_secs_f64());
                linalg::add_assign(&mut v, &dv);
            }
            let wall = linalg::median(&samples);
            println!(
                "nested threads 1×{}: {:.3} ms/round (H/T = {}, equal K·H work)",
                t,
                wall * 1e3,
                h
            );
            jn.set(&format!("wall_t{}_s", t), wall);
            walls.push(wall);
        }
        let speedup_t2 = walls[0] / walls[1].max(1e-12);
        let speedup_t4 = walls[0] / walls[2].max(1e-12);
        println!(
            "nested_speedup_t4 = {:.2}x on {} cores (MUST be >= 2.0 on >= 4 cores)",
            speedup_t4, cores
        );

        // Nested 0-alloc assertion: the full sub-solve → slot-load →
        // two-stage-reduce pipeline allocates nothing in steady state.
        let (k, t) = (2usize, 2usize);
        let nparts = Partitioning::build_nested(Partitioner::Range, &ds.a, k, t, cfg.seed);
        let nshards: Vec<WorkerData> = nparts
            .parts
            .iter()
            .map(|cols| WorkerData::from_columns(&ds.a, cols))
            .collect();
        let nalphas: Vec<Vec<f64>> = nshards.iter().map(|s| vec![0.0; s.n_local()]).collect();
        let mut nsolvers: Vec<NativeScd> = (0..k * t).map(|_| NativeScd::new()).collect();
        let mut nresults: Vec<SolveResult> = (0..k * t).map(|_| SolveResult::default()).collect();
        let mut nslots: Vec<DeltaSlot> = (0..k * t).map(|_| DeltaSlot::new()).collect();
        let plan = NestedTreePlan::new(k, t);
        let mut nreducer = DeltaReducer::raw(ds.m());
        let nproblem = Problem::ridge(1.0);
        let nsigma = cfg.sigma_t(t);
        let nv = vec![0.0; ds.m()];
        let mut nested_round = |seed: u64, slots: &mut Vec<DeltaSlot>| {
            for g in 0..k * t {
                let req = SolveRequest {
                    v: &nv,
                    b: &ds.b,
                    h: 64,
                    problem: &nproblem,
                    sigma: nsigma,
                    seed: seed ^ (g as u64).wrapping_mul(0x9E3779B97F4A7C15),
                };
                nsolvers[g].solve_into(&nshards[g], &nalphas[g], &req, &mut nresults[g]);
                nreducer.load(&mut slots[g], &nresults[g].delta_v);
            }
            for w in 0..k {
                nreducer.reduce_pairs(&mut slots[w * t..(w + 1) * t], plan.local_pairs(w));
            }
            nreducer.reduce_pairs(slots, plan.cross_pairs());
        };
        nested_round(0, &mut nslots); // warmup
        let a0 = current_thread_allocations();
        const NESTED_ALLOC_ROUNDS: u64 = 5;
        for seed in 1..=NESTED_ALLOC_ROUNDS {
            nested_round(seed, &mut nslots);
        }
        let nested_allocs = (current_thread_allocations() - a0) / NESTED_ALLOC_ROUNDS;
        println!(
            "nested sub-solve pipeline allocations/round: {} (MUST be 0)",
            nested_allocs
        );

        jn.set("nested_speedup_t2", speedup_t2)
            .set("nested_speedup_t4", speedup_t4)
            .set("cores", cores)
            .set("equal_work_total_h", TOTAL_H)
            .set("rounds_per_point", NESTED_ROUNDS)
            .set("allocs_per_round", nested_allocs);
        json.set("nested_parallel", jn);
    }

    // ---- chaos layer: heterogeneous round times + speculation -----------
    // DESIGN.md §12: same trajectory bits in all three runs (asserted by
    // tests/integration_chaos.rs); this case tracks what chaos does to the
    // virtual clock. Round time is max over the seeded per-worker speed
    // factors (drawn from [1, 1+4] here) times jittered collectives;
    // speculation caps every dragged rank at detect + base, which is the
    // Spark mitigation's modeled win.
    {
        use sparkbench::framework::chaos::ChaosSpec;
        let ccfg = TrainConfig::default_for(&ds);
        const CHAOS_ROUNDS: usize = 20;
        let chaos_run = |spec: &str| -> (f64, f64) {
            let mut builder = Session::builder(&ds)
                .engine(Impl::Mpi)
                .config(ccfg.clone())
                .fixed_rounds(CHAOS_ROUNDS);
            if !spec.is_empty() {
                builder =
                    builder.chaos(ChaosSpec::parse(spec).expect("valid bench chaos spec"));
            }
            let rep = builder.build().expect("valid bench session").run();
            let mut prev = 0.0;
            let mut max_round: f64 = 0.0;
            for l in &rep.logs {
                max_round = max_round.max(l.time - prev);
                prev = l.time;
            }
            (rep.total_time / CHAOS_ROUNDS as f64, max_round)
        };
        let (homog_mean, homog_max) = chaos_run("");
        let (het_mean, het_max) = chaos_run("het=4.0,jitter=0.2");
        let (spec_mean, spec_max) = chaos_run("het=4.0,jitter=0.2,spec");
        let het_slowdown = het_mean / homog_mean.max(1e-12);
        let speculation_speedup = het_mean / spec_mean.max(1e-12);
        println!(
            "chaos rounds (virtual, K=8): homogeneous {:.3} ms mean / {:.3} ms max; \
             het=4+jitter {:.3} / {:.3} ms ({:.2}x slower); +speculation {:.3} / {:.3} ms \
             ({:.2}x back)",
            homog_mean * 1e3,
            homog_max * 1e3,
            het_mean * 1e3,
            het_max * 1e3,
            het_slowdown,
            spec_mean * 1e3,
            spec_max * 1e3,
            speculation_speedup
        );
        let mut jc = Json::obj();
        jc.set("rounds", CHAOS_ROUNDS)
            .set("homogeneous_round_mean_s", homog_mean)
            .set("homogeneous_round_max_s", homog_max)
            .set("het_round_mean_s", het_mean)
            .set("het_round_max_s", het_max)
            .set("het_slowdown", het_slowdown)
            .set("spec_round_mean_s", spec_mean)
            .set("spec_round_max_s", spec_max)
            .set("speculation_speedup", speculation_speedup);
        json.set("chaos", jc);
    }

    // ---- problem dispatch: trait-routed SCD vs the pre-redesign path ----
    // The SCD loop now routes its coordinate step through the round's
    // `Problem` (one `match` per solve, monomorphized loops). This case
    // pins the cost of that indirection against a re-creation of the
    // pre-redesign hard-coded elastic loop: the ratio MUST be ~1.0 (within
    // noise) and the dispatched rounds MUST stay 0-alloc — including the
    // hinge dual, whose update is new.
    {
        // The ONE verbatim copy of the pre-problem hard-coded solver
        // (testkit::reference, shared with tests/integration_problems.rs).
        // Its solve_into shape (r₀ snapshot + Δ materialization) matches
        // the dispatched path, so the ratio isolates the dispatch cost.
        let mut isolver = PreRedesignElasticScd::new();
        let mut iout = SolveResult::default();
        // Warmup sizes the scratch.
        isolver.solve_into(&wd, &alpha, &v, &ds.b, wd.n_local(), 1.0, 1.0, 8.0, 1, &mut iout);
        let inlined = b.run("scd round (pre-redesign inlined elastic)", || {
            isolver.solve_into(&wd, &alpha, &v, &ds.b, wd.n_local(), 1.0, 1.0, 8.0, 1, &mut iout)
        });
        let mut psolver = NativeScd::new();
        let mut pout = SolveResult::default();
        psolver.solve_into(&wd, &alpha, &req, &mut pout); // warmup
        let dispatched = b.run("scd round (problem-dispatched, ridge)", || {
            psolver.solve_into(&wd, &alpha, &req, &mut pout)
        });
        let dispatch_ratio = dispatched.mean_s / inlined.mean_s.max(1e-12);
        let a0 = current_thread_allocations();
        psolver.solve_into(&wd, &alpha, &req, &mut pout);
        let ridge_allocs = current_thread_allocations() - a0;

        // Hinge-dual round on the same data shape: 0-alloc bar extends to
        // the new loss family.
        let svm = Problem::svm(1.0);
        let hreq = SolveRequest {
            v: &v,
            b: &ds.b,
            h: wd.n_local(),
            problem: &svm,
            sigma: 8.0,
            seed: 1,
        };
        let mut hsolver = NativeScd::new();
        let mut hout = SolveResult::default();
        hsolver.solve_into(&wd, &alpha, &hreq, &mut hout); // warmup
        let hinge = b.run("scd round (problem-dispatched, hinge)", || {
            hsolver.solve_into(&wd, &alpha, &hreq, &mut hout)
        });
        let a0 = current_thread_allocations();
        hsolver.solve_into(&wd, &alpha, &hreq, &mut hout);
        let hinge_allocs = current_thread_allocations() - a0;
        println!(
            "problem dispatch: inlined {:.3} ms vs dispatched {:.3} ms → {:.3}x (MUST be ~1.0x); \
             allocs/round ridge = {}, hinge = {} (MUST be 0)",
            inlined.mean_s * 1e3,
            dispatched.mean_s * 1e3,
            dispatch_ratio,
            ridge_allocs,
            hinge_allocs
        );
        let mut jd = Json::obj();
        jd.set("inlined_mean_s", inlined.mean_s)
            .set("dispatched_mean_s", dispatched.mean_s)
            .set("dispatch_ratio", dispatch_ratio)
            .set("ridge_allocs_per_round", ridge_allocs)
            .set("hinge_mean_s", hinge.mean_s)
            .set("hinge_allocs_per_round", hinge_allocs);
        json.set("problem_dispatch", jd);
        results.push(inlined);
        results.push(dispatched);
        results.push(hinge);
    }

    // ---- cache-blocked CSC traversal + mixed precision (DESIGN.md §11) --
    // Same round, three numeric paths: flat f64 (the default at this m),
    // cache-blocked f64 (forced by lowering the row-block threshold), and
    // mixed-f32 (f32 storage mirrors, f64 accumulation). Blocked and mixed
    // must both stay 0-alloc in steady state; mixed additionally reports
    // the final-objective drift of a 120-round trajectory vs f64.
    {
        let mut jkp = Json::obj();
        let mut flat_solver = NativeScd::new();
        let mut flat_out = SolveResult::default();
        flat_solver.solve_into(&wd, &alpha, &req, &mut flat_out); // warmup
        let flat = b.run("scd round (flat f64)", || {
            flat_solver.solve_into(&wd, &alpha, &req, &mut flat_out)
        });
        let mut blk_solver = NativeScd::new().with_block_rows(512);
        let mut blk_out = SolveResult::default();
        blk_solver.solve_into(&wd, &alpha, &req, &mut blk_out); // warmup builds the plan
        let blocked = b.run("scd round (blocked f64, 512-row blocks)", || {
            blk_solver.solve_into(&wd, &alpha, &req, &mut blk_out)
        });
        let a0 = current_thread_allocations();
        blk_solver.solve_into(&wd, &alpha, &req, &mut blk_out);
        let blocked_allocs = current_thread_allocations() - a0;

        let mut mx_solver = NativeScd::with_precision(Precision::MixedF32);
        let mut mx_out = SolveResult::default();
        mx_solver.solve_into(&wd, &alpha, &req, &mut mx_out); // warmup builds mirrors
        let mixed = b.run("scd round (mixed-f32)", || {
            mx_solver.solve_into(&wd, &alpha, &req, &mut mx_out)
        });
        let a0 = current_thread_allocations();
        mx_solver.solve_into(&wd, &alpha, &req, &mut mx_out);
        let mixed_allocs = current_thread_allocations() - a0;
        println!(
            "blocked vs flat SCD: {:.3} ms vs {:.3} ms; mixed-f32 {:.3} ms; \
             allocs/round blocked = {}, mixed = {} (MUST be 0)",
            blocked.mean_s * 1e3,
            flat.mean_s * 1e3,
            mixed.mean_s * 1e3,
            blocked_allocs,
            mixed_allocs
        );

        // Final-objective drift: 120 accumulated single-shard rounds per
        // precision (the scd.rs unit test pins this at ≤ 1e-3 relative).
        let drift = {
            let run = |prec: Precision| -> f64 {
                let mut s = NativeScd::with_precision(prec);
                let mut a = vec![0.0; wd.n_local()];
                let mut vv = vec![0.0; ds.m()];
                let mut o = SolveResult::default();
                for round in 0..120u64 {
                    let r = SolveRequest {
                        v: &vv,
                        b: &ds.b,
                        h: wd.n_local(),
                        problem: &ridge,
                        sigma: 1.0,
                        seed: round,
                    };
                    s.solve_into(&wd, &a, &r, &mut o);
                    for (ai, d) in a.iter_mut().zip(o.delta_alpha.iter()) {
                        *ai += d;
                    }
                    linalg::add_assign(&mut vv, &o.delta_v);
                }
                let mut full = vec![0.0; ds.n()];
                for (j, &c) in wd.global_ids.iter().enumerate() {
                    full[c as usize] = a[j];
                }
                ridge.primal(&ds, &full)
            };
            let f64_obj = run(Precision::F64);
            let mx_obj = run(Precision::MixedF32);
            (mx_obj - f64_obj).abs() / f64_obj.abs().max(1e-12)
        };
        println!("mixed-f32 final-objective drift after 120 rounds: {:.2e} relative", drift);

        let mut jb = Json::obj();
        jb.set("flat_mean_s", flat.mean_s)
            .set("blocked_mean_s", blocked.mean_s)
            .set("blocked_speedup", flat.mean_s / blocked.mean_s.max(1e-12))
            .set("block_rows", 512usize)
            .set("allocs_per_round", blocked_allocs);
        jkp.set("blocked_traversal", jb);
        let mut jm = Json::obj();
        jm.set("f64_mean_s", flat.mean_s)
            .set("mixed_mean_s", mixed.mean_s)
            .set("step_speedup", flat.mean_s / mixed.mean_s.max(1e-12))
            .set("allocs_per_round", mixed_allocs)
            .set("final_objective_drift_rel", drift);
        jkp.set("solver", jm);
        json.set("mixed_precision", jkp);
        results.push(flat);
        results.push(blocked);
        results.push(mixed);
    }

    // ---- problem objective (suboptimality tracking cost) ----------------
    let alpha_full = vec![0.01; ds.n()];
    let p_obj = Problem::ridge(1.0);
    results.push(b.run("objective (O(nnz) matvec)", || {
        p_obj.primal(&ds, &alpha_full)
    }));
    let v_full = ds.shared_vector(&alpha_full);
    results.push(b.run("objective_given_v (O(m+n))", || {
        p_obj.primal_given_v(&v_full, &alpha_full, &ds.b)
    }));
    results.push(b.run("duality_gap (O(nnz) certificate)", || {
        p_obj.duality_gap(&ds, &v_full, &alpha_full)
    }));
    // Pooled eval step: the session's reused GapScratch — same bits, zero
    // steady-state allocations (counting allocator).
    let f_full = p_obj.primal_given_v(&v_full, &alpha_full, &ds.b);
    let mut gap_scratch = GapScratch::default();
    let _ = p_obj.duality_gap_scratch(&ds, &v_full, &alpha_full, f_full, &mut gap_scratch);
    results.push(b.run("duality_gap (pooled GapScratch)", || {
        p_obj.duality_gap_scratch(&ds, &v_full, &alpha_full, f_full, &mut gap_scratch)
    }));
    let a0 = current_thread_allocations();
    let _ = p_obj.duality_gap_scratch(&ds, &v_full, &alpha_full, f_full, &mut gap_scratch);
    let gap_allocs = current_thread_allocations() - a0;
    println!("duality-gap eval allocations (pooled scratch): {} (MUST be 0)", gap_allocs);
    json.set("gap_eval_allocs", gap_allocs);

    // ---- serving: zero-alloc batched inference (DESIGN.md §13) ----------
    // Train→serve handoff measured end to end: a short fixed-round ridge
    // session stands in for any converged model (serving cost depends only
    // on the request rows, not on how good the weights are), and the full
    // corpus replayed row-major is the steady-state batch.
    {
        let (_, model) = Session::builder(&ds)
            .engine(Impl::Mpi)
            .fixed_rounds(10)
            .build()
            .expect("serving bench session")
            .run_extract();
        let rows = CsrMatrix::from_csc(&ds.a);
        let predictor = Predictor::new(model);
        let mut out = Vec::new();
        predictor.predict_into(&rows, &mut out); // warm the output buffer

        let seq = b.run("serve batch predict (1 core)", || {
            predictor.predict_into(&rows, &mut out)
        });
        let a0 = current_thread_allocations();
        predictor.predict_into(&rows, &mut out);
        let serve_allocs = current_thread_allocations() - a0;
        let preds_per_sec_1core = rows.m as f64 / seq.mean_s.max(1e-12);
        println!(
            "serving: {} rows/batch, {:.3e} preds/s on 1 core; allocations/batch = {} (MUST be 0)",
            rows.m, preds_per_sec_1core, serve_allocs
        );

        let cores = std::thread::available_parallelism().map_or(1, |c| c.get());
        let mut js = Json::obj();
        js.set("batch_rows", rows.m)
            .set("allocs_per_batch", serve_allocs)
            .set("preds_per_sec_1core", preds_per_sec_1core)
            .set("cores", cores);
        for shards in [2usize, 4] {
            let sh = b.run(&format!("serve batch predict ({} shards)", shards), || {
                predictor.predict_sharded_into(&rows, shards, &mut out)
            });
            js.set(
                &format!("shard_speedup_t{}", shards),
                seq.mean_s / sh.mean_s.max(1e-12),
            );
            results.push(sh);
        }

        // Batching front end in both regimes of the cutover rule
        // λ* = max_batch / max_delay (arrivals on the virtual clock, only
        // batch compute wall-timed): at 4λ* every flush is a size flush;
        // at λ*/4 the deadline timer always wins and the wait tail is
        // pinned near max_delay.
        let policy = BatchPolicy::new(64, 1e-3);
        let cutover = policy.cutover_rate();
        js.set("cutover_rate", cutover);
        for (tag, rate) in [("size_regime", 4.0 * cutover), ("deadline_regime", 0.25 * cutover)] {
            let mut preds = Vec::new();
            let stats = replay(&predictor, &rows, Some(&ds.b), policy, rate, 1, &mut preds);
            println!("serving replay [{}] @ {:.0} req/s:\n{}", tag, rate, stats.render());
            let mut jr = Json::obj();
            jr.set("rate", rate)
                .set("batches", stats.batches)
                .set("mean_batch", stats.mean_batch)
                .set("size_flushes", stats.size_flushes)
                .set("deadline_flushes", stats.deadline_flushes)
                .set("wait_p50_s", stats.wait_p50_s)
                .set("wait_p99_s", stats.wait_p99_s)
                .set("latency_p50_s", stats.latency_p50_s)
                .set("latency_p99_s", stats.latency_p99_s)
                .set("preds_per_sec", stats.preds_per_sec);
            js.set(tag, jr);
        }

        // Overload regime (DESIGN.md §15): a seeded storm at 4× the
        // sustainable service rate through the admission-controlled
        // harness — entirely on the virtual clock, so these numbers are
        // a deterministic property of the seed, not of this host. The
        // service model pins a full batch to one deadline (μ = λ*).
        let service = ServiceModel {
            overhead_s: 0.5 * policy.max_delay,
            per_row_s: 0.5 * policy.max_delay / policy.max_batch as f64,
        };
        let ocfg = OverloadConfig {
            queue_cap: 4 * policy.max_batch,
            service,
            malformed_every: 0,
            swap_at_batch: None,
            seed: 42,
        };
        let storm_rate = 4.0 * service.sustainable_rate(policy.max_batch);
        let pattern = ArrivalPattern::Storm { rate: storm_rate };
        let mut opreds = Vec::new();
        let ostats = overload_replay(
            predictor.model(),
            None,
            &rows,
            &policy,
            &pattern,
            &ocfg,
            &mut opreds,
        );
        println!(
            "serving overload [storm @ {:.0} req/s, cap {}]: shed {}/{} ({:.1}%), \
             degraded occupancy {:.1}%, p99 {:.0}µs",
            storm_rate,
            ocfg.queue_cap,
            ostats.shed,
            ostats.offered,
            100.0 * ostats.shed_rate,
            100.0 * ostats.degraded_occupancy,
            ostats.p99_latency_s * 1e6
        );
        let mut jo = Json::obj();
        jo.set("storm_rate", storm_rate)
            .set("queue_cap", ocfg.queue_cap)
            .set("offered", ostats.offered)
            .set("admitted", ostats.admitted)
            .set("shed", ostats.shed)
            .set("shed_rate", ostats.shed_rate)
            .set("batches", ostats.batches)
            .set("degraded_occupancy", ostats.degraded_occupancy)
            .set("max_depth", ostats.max_depth)
            .set("p50_latency_s", ostats.p50_latency_s)
            .set("p99_latency_s", ostats.p99_latency_s);
        js.set("overload", jo);

        json.set("serving", js);
        results.push(seq);
    }

    // ---- PJRT-executed Pallas kernel round (needs `make artifacts`) -----
    #[cfg(feature = "pjrt")]
    {
        use sparkbench::runtime::{Manifest, PjrtRuntime};
        use sparkbench::solver::pjrt::PjrtScd;
        use std::sync::Arc;
        match Manifest::load(&Manifest::default_dir()) {
            Ok(man) => {
                let rt = PjrtRuntime::cpu().expect("pjrt client");
                let exec = Arc::new(rt.load_local_solve(&man).expect("compile"));
                let mut spec = SyntheticSpec::pjrt_default();
                spec.m = man.m;
                spec.n = man.nk;
                let pds = webspam_like(&spec);
                let pcols: Vec<u32> = (0..man.nk as u32).collect();
                let pwd = WorkerData::from_columns(&pds.a, &pcols);
                let palpha = vec![0.0; pwd.n_local()];
                let pv = vec![0.0; pds.m()];
                let mut psolver = PjrtScd::new(exec);
                let pproblem = Problem::ridge(10.0);
                let preq = SolveRequest {
                    v: &pv,
                    b: &pds.b,
                    h: pwd.n_local().min(man.h_max),
                    problem: &pproblem,
                    sigma: 4.0,
                    seed: 1,
                };
                results.push(b.run("pjrt_scd round (H=n_local, artifact)", || {
                    psolver.solve(&pwd, &palpha, &preq)
                }));
            }
            Err(_) => {
                eprintln!("(artifacts missing — skipping pjrt bench; run `make artifacts`)")
            }
        }
    }

    println!("{}", render_results("hotpath", &results));

    // ---- perf-trajectory record -----------------------------------------
    let mut jcases = Json::obj();
    for s in &results {
        let mut jc = Json::obj();
        jc.set("mean_s", s.mean_s)
            .set("median_s", s.median_s)
            .set("stddev_s", s.stddev_s)
            .set("samples", s.samples);
        jcases.set(&s.name, jc);
    }
    json.set("cases", jcases);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    match std::fs::write(path, json.pretty() + "\n") {
        Ok(()) => println!("wrote {}", path),
        Err(e) => eprintln!("could not write {}: {}", path, e),
    }
}
