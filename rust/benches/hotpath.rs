//! Hot-path micro-benchmarks: the kernels the §Perf pass optimizes.
//!
//! Run with `cargo bench --bench hotpath`.

use sparkbench::bench::{render_results, Bencher};
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::data::WorkerData;
use sparkbench::framework::serialization::{JavaSer, PickleSer};
use sparkbench::linalg;
use sparkbench::solver::{scd::NativeScd, LocalSolver, SolveRequest};

fn main() {
    let b = Bencher::default();
    let mut results = Vec::new();

    // Sparse dot / axpy — one call per SCD step, THE hot pair.
    let ds = webspam_like(&SyntheticSpec::webspam_mini());
    let (ri, vs) = ds.a.col(100);
    let dense = vec![1.0; ds.m()];
    results.push(b.run("dot_indexed (1 col)", || {
        linalg::dot_indexed(ri, vs, &dense)
    }));
    let mut dense_mut = vec![1.0; ds.m()];
    results.push(b.run("axpy_indexed (1 col)", || {
        linalg::axpy_indexed(0.5, ri, vs, &mut dense_mut);
    }));
    results.push(b.run("dot_indexed_fused (1 col)", || {
        linalg::dot_indexed_fused(ri, vs, &dense)
    }));

    // Full local solve, H = n_local (one worker round).
    let cols: Vec<u32> = (0..(ds.n() as u32 / 8)).collect();
    let wd = WorkerData::from_columns(&ds.a, &cols);
    let alpha = vec![0.0; wd.n_local()];
    let v = vec![0.0; ds.m()];
    let mut solver = NativeScd::new();
    let req = SolveRequest {
        v: &v,
        b: &ds.b,
        h: wd.n_local(),
        lam_n: 1.0,
        eta: 1.0,
        sigma: 8.0,
        seed: 1,
    };
    results.push(b.run("native_scd round (H=n_local)", || {
        solver.solve(&wd, &alpha, &req)
    }));

    // AllReduce aggregation (master hot loop).
    let delta: Vec<Vec<f64>> = (0..8).map(|i| vec![i as f64; ds.m()]).collect();
    results.push(b.run("allreduce agg (K=8, m=2048)", || {
        let mut agg = vec![0.0; ds.m()];
        for d in &delta {
            linalg::add_assign(&mut agg, d);
        }
        agg
    }));

    // Serialization codecs (real byte work on the communicated vectors).
    let payload = vec![1.5f64; ds.m()];
    results.push(b.run("java ser+deser (m=2048)", || {
        JavaSer::decode(&JavaSer::encode(&payload)).unwrap()
    }));
    results.push(b.run("pickle ser+deser (m=2048)", || {
        PickleSer::decode(&PickleSer::encode(&payload)).unwrap()
    }));

    // Dataset objective (suboptimality tracking cost) — O(nnz) matvec path
    // vs the O(m+n) tracked-v path the coordinator uses (§Perf).
    let alpha_full = vec![0.01; ds.n()];
    results.push(b.run("objective (O(nnz) matvec)", || {
        ds.objective(&alpha_full, 1.0, 1.0)
    }));
    let v_full = ds.shared_vector(&alpha_full);
    results.push(b.run("objective_given_v (O(m+n))", || {
        ds.objective_given_v(&v_full, &alpha_full, 1.0, 1.0)
    }));

    // PJRT-executed Pallas kernel round (needs `make artifacts`).
    use sparkbench::runtime::{Manifest, PjrtRuntime};
    use sparkbench::solver::pjrt::PjrtScd;
    use std::sync::Arc;
    match Manifest::load(&Manifest::default_dir()) {
        Ok(man) => {
            let rt = PjrtRuntime::cpu().expect("pjrt client");
            let exec = Arc::new(rt.load_local_solve(&man).expect("compile"));
            let mut spec = sparkbench::data::synthetic::SyntheticSpec::pjrt_default();
            spec.m = man.m;
            spec.n = man.nk;
            let pds = webspam_like(&spec);
            let cols: Vec<u32> = (0..man.nk as u32).collect();
            let pwd = WorkerData::from_columns(&pds.a, &cols);
            let palpha = vec![0.0; pwd.n_local()];
            let pv = vec![0.0; pds.m()];
            let mut psolver = PjrtScd::new(exec);
            let preq = SolveRequest {
                v: &pv,
                b: &pds.b,
                h: pwd.n_local().min(man.h_max),
                lam_n: 10.0,
                eta: 1.0,
                sigma: 4.0,
                seed: 1,
            };
            results.push(b.run("pjrt_scd round (H=n_local, artifact)", || {
                psolver.solve(&pwd, &palpha, &preq)
            }));
        }
        Err(_) => eprintln!("(artifacts missing — skipping pjrt bench; run `make artifacts`)"),
    }

    println!("{}", render_results("hotpath", &results));
}
