//! Hot-path micro-benchmarks: the kernels the §Perf pass optimizes.
//!
//! Run with `cargo bench --bench hotpath`. Besides the per-kernel table it
//! writes `BENCH_hotpath.json` at the repo root so the perf trajectory of
//! the reduction/allocation work is tracked PR-over-PR. The headline
//! comparisons:
//!
//! * **tree vs serial AllReduce** at K ∈ {4, 8, 16}: the old master loop
//!   (fresh zeroed accumulator + K sequential `add_assign` passes) against
//!   [`linalg::tree_reduce`] (in-place pairwise tree, level-parallel on
//!   multi-core) — the acceptance bar is ≥ 1.5× at K = 8;
//! * **pooled vs fresh-alloc round**: `NativeScd::solve` (owned result
//!   buffers per call) against `solve_into` with persistent buffers, plus
//!   the measured allocation counts per round from the counting allocator.

use sparkbench::bench::{render_results, Bencher};
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::data::WorkerData;
use sparkbench::framework::serialization::{JavaSer, PickleSer};
use sparkbench::linalg;
use sparkbench::solver::{scd::NativeScd, LocalSolver, SolveRequest, SolveResult};
use sparkbench::testkit::alloc::{current_thread_allocations, CountingAllocator};
use sparkbench::util::json::Json;

/// Count every allocation the bench performs so the pooled-vs-fresh cases
/// can report exact allocations/round next to their timings.
#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// AllReduce problem size: large enough that one pairwise add dwarfs a
/// thread spawn, which is the regime the reduction actually runs in at
/// production scale (m = 1M doubles ≈ 8 MB/worker).
const REDUCE_M: usize = 1 << 20;

fn reduce_inputs(k: usize, m: usize) -> Vec<Vec<f64>> {
    (0..k)
        .map(|w| (0..m).map(|i| ((w * 31 + i) % 97) as f64 * 0.125).collect())
        .collect()
}

fn main() {
    let b = Bencher::default();
    let mut results = Vec::new();
    let mut json = Json::obj();
    json.set("bench", "hotpath").set("schema_version", 2usize);

    // ---- sparse dot / axpy — one call per SCD step, THE hot pair --------
    let ds = webspam_like(&SyntheticSpec::webspam_mini());
    let (ri, vs) = ds.a.col(100);
    let dense = vec![1.0; ds.m()];
    results.push(b.run("dot_indexed (1 col)", || {
        linalg::dot_indexed(ri, vs, &dense)
    }));
    let mut dense_mut = vec![1.0; ds.m()];
    results.push(b.run("axpy_indexed (1 col)", || {
        linalg::axpy_indexed(0.5, ri, vs, &mut dense_mut);
    }));
    results.push(b.run("dot_indexed_fused (1 col)", || {
        linalg::dot_indexed_fused(ri, vs, &dense)
    }));

    // ---- full local solve: fresh-alloc vs pooled ------------------------
    let cols: Vec<u32> = (0..(ds.n() as u32 / 8)).collect();
    let wd = WorkerData::from_columns(&ds.a, &cols);
    let alpha = vec![0.0; wd.n_local()];
    let v = vec![0.0; ds.m()];
    let mut solver = NativeScd::new();
    let req = SolveRequest {
        v: &v,
        b: &ds.b,
        h: wd.n_local(),
        lam_n: 1.0,
        eta: 1.0,
        sigma: 8.0,
        seed: 1,
    };
    let fresh = b.run("native_scd round (fresh alloc)", || {
        solver.solve(&wd, &alpha, &req)
    });
    let a0 = current_thread_allocations();
    let _ = solver.solve(&wd, &alpha, &req);
    let fresh_allocs = current_thread_allocations() - a0;

    let mut out = SolveResult::default();
    solver.solve_into(&wd, &alpha, &req, &mut out); // warmup buffers
    let pooled = b.run("native_scd round (pooled, solve_into)", || {
        solver.solve_into(&wd, &alpha, &req, &mut out)
    });
    let a0 = current_thread_allocations();
    solver.solve_into(&wd, &alpha, &req, &mut out);
    let pooled_allocs = current_thread_allocations() - a0;
    println!(
        "allocations/round: fresh = {}, pooled = {} (pooled MUST be 0)",
        fresh_allocs, pooled_allocs
    );
    let round_speedup = fresh.mean_s / pooled.mean_s.max(1e-12);
    results.push(fresh.clone());
    results.push(pooled.clone());
    {
        let mut jr = Json::obj();
        jr.set("fresh_mean_s", fresh.mean_s)
            .set("pooled_mean_s", pooled.mean_s)
            .set("speedup", round_speedup)
            .set("fresh_allocs_per_round", fresh_allocs)
            .set("pooled_allocs_per_round", pooled_allocs);
        json.set("pooled_round", jr);
    }

    // ---- AllReduce: serial fold (old master loop) vs pairwise tree ------
    let mut jallr = Json::obj();
    for k in [4usize, 8, 16] {
        let mut bufs = reduce_inputs(k, REDUCE_M);
        let serial = b.run(&format!("allreduce serial fold (K={})", k), || {
            let mut agg = vec![0.0; REDUCE_M];
            for d in &bufs {
                linalg::add_assign(&mut agg, d);
            }
            agg
        });
        let tree = b.run(&format!("allreduce tree (K={})", k), || {
            let mut refs: Vec<&mut [f64]> =
                bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
            linalg::tree_reduce(&mut refs);
        });
        let speedup = serial.mean_s / tree.mean_s.max(1e-12);
        println!(
            "K={:2}: serial {:.3} ms, tree {:.3} ms → {:.2}x",
            k,
            serial.mean_s * 1e3,
            tree.mean_s * 1e3,
            speedup
        );
        let mut jk = Json::obj();
        jk.set("serial_mean_s", serial.mean_s)
            .set("tree_mean_s", tree.mean_s)
            .set("speedup", speedup)
            .set("m", REDUCE_M);
        jallr.set(&format!("k{}", k), jk);
        results.push(serial);
        results.push(tree);
    }
    json.set("allreduce", jallr);

    // ---- serialization codecs: fresh frames vs pooled encode_into -------
    let payload = vec![1.5f64; ds.m()];
    results.push(b.run("java ser+deser (fresh frame)", || {
        JavaSer::decode(&JavaSer::encode(&payload)).unwrap()
    }));
    let mut jframe = Vec::new();
    JavaSer::encode_into(&payload, &mut jframe);
    results.push(b.run("java encode_into (pooled frame)", || {
        JavaSer::encode_into(&payload, &mut jframe)
    }));
    results.push(b.run("pickle ser+deser (fresh frame)", || {
        PickleSer::decode(&PickleSer::encode(&payload)).unwrap()
    }));
    let mut pframe = Vec::new();
    PickleSer::encode_into(&payload, &mut pframe);
    results.push(b.run("pickle encode_into (pooled frame)", || {
        PickleSer::encode_into(&payload, &mut pframe)
    }));

    // ---- dataset objective (suboptimality tracking cost) ----------------
    let alpha_full = vec![0.01; ds.n()];
    results.push(b.run("objective (O(nnz) matvec)", || {
        ds.objective(&alpha_full, 1.0, 1.0)
    }));
    let v_full = ds.shared_vector(&alpha_full);
    results.push(b.run("objective_given_v (O(m+n))", || {
        ds.objective_given_v(&v_full, &alpha_full, 1.0, 1.0)
    }));

    // ---- PJRT-executed Pallas kernel round (needs `make artifacts`) -----
    #[cfg(feature = "pjrt")]
    {
        use sparkbench::runtime::{Manifest, PjrtRuntime};
        use sparkbench::solver::pjrt::PjrtScd;
        use std::sync::Arc;
        match Manifest::load(&Manifest::default_dir()) {
            Ok(man) => {
                let rt = PjrtRuntime::cpu().expect("pjrt client");
                let exec = Arc::new(rt.load_local_solve(&man).expect("compile"));
                let mut spec = SyntheticSpec::pjrt_default();
                spec.m = man.m;
                spec.n = man.nk;
                let pds = webspam_like(&spec);
                let pcols: Vec<u32> = (0..man.nk as u32).collect();
                let pwd = WorkerData::from_columns(&pds.a, &pcols);
                let palpha = vec![0.0; pwd.n_local()];
                let pv = vec![0.0; pds.m()];
                let mut psolver = PjrtScd::new(exec);
                let preq = SolveRequest {
                    v: &pv,
                    b: &pds.b,
                    h: pwd.n_local().min(man.h_max),
                    lam_n: 10.0,
                    eta: 1.0,
                    sigma: 4.0,
                    seed: 1,
                };
                results.push(b.run("pjrt_scd round (H=n_local, artifact)", || {
                    psolver.solve(&pwd, &palpha, &preq)
                }));
            }
            Err(_) => {
                eprintln!("(artifacts missing — skipping pjrt bench; run `make artifacts`)")
            }
        }
    }

    println!("{}", render_results("hotpath", &results));

    // ---- perf-trajectory record -----------------------------------------
    let mut jcases = Json::obj();
    for s in &results {
        let mut jc = Json::obj();
        jc.set("mean_s", s.mean_s)
            .set("median_s", s.median_s)
            .set("stddev_s", s.stddev_s)
            .set("samples", s.samples);
        jcases.set(&s.name, jc);
    }
    json.set("cases", jcases);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");
    match std::fs::write(path, json.pretty() + "\n") {
        Ok(()) => println!("wrote {}", path),
        Err(e) => eprintln!("could not write {}: {}", path, e),
    }
}
