//! Framework comparison: the paper's headline experiment in miniature.
//!
//! Trains the *same* CoCoA algorithm on all five substrates (A)–(E) plus
//! the §5.3 optimized variants, each at H = n_local, and prints the
//! time-to-target ordering — the Figure 2 story.
//!
//! ```sh
//! cargo run --release --example framework_comparison
//! ```

use sparkbench::config::{Impl, TrainConfig};
use sparkbench::coordinator;
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::framework::build_engine;
use sparkbench::metrics::Table;

fn main() {
    let mut spec = SyntheticSpec::small();
    spec.m = 256;
    spec.n = 2048;
    spec.avg_col_nnz = 24;
    let ds = webspam_like(&spec);
    let mut cfg = TrainConfig::default_for(&ds);
    cfg.workers = 4;
    cfg.max_rounds = 3000;

    println!("dataset: {} | K={} | λn={:.2} | target ε=1e-3\n", ds.name, cfg.workers, cfg.lam_n);
    let fstar = coordinator::oracle_objective(&ds, &cfg);

    let mut table = Table::new(&["impl", "rounds", "time (virt s)", "overhead share", "vs MPI"]);
    let mut mpi_time = None;
    let mut rows = Vec::new();

    for imp in [
        Impl::Mpi,
        Impl::SparkCOpt,
        Impl::PySparkCOpt,
        Impl::SparkC,
        Impl::SparkScala,
        Impl::PySparkC,
        Impl::PySpark,
    ] {
        let mut engine = build_engine(imp, &ds, &cfg);
        let rep = coordinator::train_with_oracle(engine.as_mut(), &ds, &cfg, fstar);
        let t = rep.time_to_target.unwrap_or(rep.total_time);
        if imp == Impl::Mpi {
            mpi_time = Some(t);
        }
        rows.push((imp, rep, t));
    }

    for (imp, rep, t) in &rows {
        table.row(vec![
            imp.name().to_string(),
            rep.rounds.to_string(),
            format!("{:.4}", t),
            format!("{:.0}%", 100.0 * rep.total_overhead / rep.total_time),
            mpi_time
                .map(|m| format!("{:.1}×", t / m))
                .unwrap_or_default(),
        ]);
    }
    println!("{}", table.render());
    println!("All rows ran the IDENTICAL algorithm with the identical seed —");
    println!("the spread is pure framework overhead (the paper's 20× → 2× story).");
}
