//! Framework comparison: the paper's headline experiment in miniature.
//!
//! Trains the *same* CoCoA algorithm on every virtual-clock substrate in
//! the session registry — (A)–(E), the §5.3 optimized variants and the
//! parameter-server engine — each at H = n_local, and prints the
//! time-to-target ordering (the Figure 2 story, extended to the
//! registry). The wall-clock `Engine::Threads` substrate is omitted here
//! because its times are not comparable to the virtual clock; see the
//! quickstart and `session` docs for driving it.
//!
//! ```sh
//! cargo run --release --example framework_comparison
//! ```

use sparkbench::config::{Impl, TrainConfig};
use sparkbench::coordinator;
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::framework::Engine;
use sparkbench::metrics::Table;
use sparkbench::session::Session;

fn main() {
    let mut spec = SyntheticSpec::small();
    spec.m = 256;
    spec.n = 2048;
    spec.avg_col_nnz = 24;
    let ds = webspam_like(&spec);
    let mut cfg = TrainConfig::default_for(&ds);
    cfg.workers = 4;
    cfg.max_rounds = 3000;

    println!("dataset: {} | K={} | λn={:.2} | target ε=1e-3\n", ds.name, cfg.workers, cfg.lam_n());
    let fstar = coordinator::oracle_objective(&ds, &cfg);

    let mut table = Table::new(&["engine", "rounds", "time (virt s)", "overhead share", "vs MPI"]);
    let mut mpi_time = None;
    let mut rows = Vec::new();

    for engine in [
        Engine::Impl(Impl::Mpi),
        Engine::Impl(Impl::SparkCOpt),
        Engine::Impl(Impl::PySparkCOpt),
        Engine::Impl(Impl::SparkC),
        Engine::Impl(Impl::SparkScala),
        Engine::Impl(Impl::PySparkC),
        Engine::Impl(Impl::PySpark),
        Engine::ParamServer { staleness: 0 },
    ] {
        let rep = Session::builder(&ds)
            .engine(engine)
            .config(cfg.clone())
            .oracle(fstar)
            .build()
            .expect("valid session")
            .run();
        let t = rep.time_to_target.unwrap_or(rep.total_time);
        if engine == Engine::Impl(Impl::Mpi) {
            mpi_time = Some(t);
        }
        rows.push((rep, t));
    }

    for (rep, t) in &rows {
        table.row(vec![
            rep.impl_name.clone(),
            rep.rounds.to_string(),
            format!("{:.4}", t),
            format!("{:.0}%", 100.0 * rep.total_overhead / rep.total_time),
            mpi_time
                .map(|m| format!("{:.1}×", t / m))
                .unwrap_or_default(),
        ]);
    }
    println!("{}", table.render());
    println!("All rows ran the IDENTICAL algorithm with the identical seed —");
    println!("the spread is pure framework overhead (the paper's 20× → 2× story).");
}
