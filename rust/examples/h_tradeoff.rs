//! The communication–computation trade-off (§5.5): sweep H for a cheap-
//! communication substrate (MPI) and an expensive one (pySpark+C) and show
//! the optimum moves — plus the adaptive-H session finding a good H in a
//! single run (no grid).
//!
//! ```sh
//! cargo run --release --example h_tradeoff
//! ```

use sparkbench::config::{Impl, TrainConfig};
use sparkbench::coordinator::{self, tuner};
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::framework::build_engine;
use sparkbench::metrics::Table;
use sparkbench::session::Session;

fn main() {
    let mut spec = SyntheticSpec::small();
    spec.n = 1024;
    spec.avg_col_nnz = 24;
    let ds = webspam_like(&spec);
    let mut cfg = TrainConfig::default_for(&ds);
    cfg.workers = 4;
    cfg.max_rounds = 4000;
    let fstar = coordinator::oracle_objective(&ds, &cfg);
    let grid = [0.1, 0.25, 0.5, 1.0, 2.0, 4.0];

    for imp in [Impl::Mpi, Impl::PySparkC] {
        let make = || build_engine(imp, &ds, &cfg);
        let (points, best) = tuner::grid_search_h(&make, &ds, &cfg, fstar, &grid);
        println!("{} — time to ε=1e-3 vs H/n_local:", imp.name());
        let mut table = Table::new(&["H/n_local", "rounds", "time (virt s)", "compute %"]);
        for (i, p) in points.iter().enumerate() {
            table.row(vec![
                format!("{}{:.2}", if i == best { "→" } else { " " }, p.h_frac),
                p.report.rounds.to_string(),
                p.report
                    .time_to_target
                    .map(|t| format!("{:.4}", t))
                    .unwrap_or_else(|| "-".into()),
                format!("{:.0}%", 100.0 * p.report.compute_fraction()),
            ]);
        }
        println!("{}", table.render());
    }

    // The future-work feature: adapt H online instead of grid searching —
    // one session with the Adaptive policy.
    println!("adaptive-H (single run, no grid):");
    for (imp, target) in [(Impl::Mpi, 0.9), (Impl::PySparkC, 0.6)] {
        let rep = Session::builder(&ds)
            .engine(imp)
            .config(cfg.clone())
            .oracle(fstar)
            .adaptive_h(target)
            .build()
            .expect("valid session")
            .run();
        println!(
            "  {:16} reached ε at {} (final H = {})",
            rep.impl_name,
            rep.time_to_target
                .map(|t| format!("{:.4} virt s", t))
                .unwrap_or_else(|| "-".into()),
            rep.logs.last().map(|l| l.h).unwrap_or(0)
        );
    }
}
