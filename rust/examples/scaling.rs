//! Scaling behaviour (§5.6): time-to-target vs worker count for MPI and
//! Spark+C, with H re-tuned at every point — Figure 8 in miniature.
//!
//! ```sh
//! cargo run --release --example scaling
//! ```

use sparkbench::config::{Impl, TrainConfig};
use sparkbench::coordinator::{self, tuner};
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::framework::build_engine;
use sparkbench::metrics::Table;

fn main() {
    let mut spec = SyntheticSpec::small();
    spec.n = 2048;
    spec.avg_col_nnz = 24;
    let ds = webspam_like(&spec);
    let grid = [0.25, 0.5, 1.0, 2.0];

    let mut table = Table::new(&["impl", "N", "H*", "time (virt s)", "ideal (no comm)"]);
    for imp in [Impl::Mpi, Impl::SparkC] {
        for n in [2usize, 4, 8, 16] {
            if imp != Impl::Mpi && n < 4 {
                continue; // paper: Spark needed ≥ 4 workers for memory
            }
            let mut cfg = TrainConfig::default_for(&ds);
            cfg.workers = n;
            cfg.max_rounds = 4000;
            let fstar = coordinator::oracle_objective(&ds, &cfg);
            let make = || build_engine(imp, &ds, &cfg);
            let (points, best) = tuner::grid_search_h(&make, &ds, &cfg, fstar, &grid);
            let rep = &points[best].report;
            let ideal: f64 = rep.logs.iter().map(|l| l.timing.t_worker).sum();
            table.row(vec![
                imp.name().to_string(),
                n.to_string(),
                format!("{:.2}", points[best].h_frac),
                rep.time_to_target
                    .map(|t| format!("{:.4}", t))
                    .unwrap_or_else(|| "-".into()),
                format!("{:.4}", ideal),
            ]);
        }
    }
    println!("{}", table.render());
    println!("MPI tracks the zero-communication ideal; Spark's gap to ideal widens with N.");
}
