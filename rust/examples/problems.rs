//! The first-class `Problem` API end to end: ridge and lasso on a
//! synthetic regression corpus, linear SVM and logistic regression on a
//! synthetic classification corpus — every objective through the SAME
//! `Session` loop, the non-quadratic ones stopping on the oracle-free
//! duality-gap certificate (DESIGN.md §9).
//!
//! ```sh
//! cargo run --release --example problems
//! ```

use sparkbench::config::{Impl, TrainConfig};
use sparkbench::data::synthetic::{separable_classes, webspam_like, SyntheticSpec};
use sparkbench::data::{eval, Dataset};
use sparkbench::framework::{build_engine, DistEngine};
use sparkbench::metrics::Table;
use sparkbench::problem::Problem;
use sparkbench::session::{Session, StopPolicy};

/// Train `problem` on `ds` with an attached engine (so the trained α
/// survives for downstream evaluation); return (report, α, v = Aα).
fn train(
    ds: &Dataset,
    cfg: &TrainConfig,
    stop: StopPolicy,
) -> (sparkbench::metrics::TrainReport, Vec<f64>, Vec<f64>) {
    let mut engine: Box<dyn DistEngine> = build_engine(Impl::Mpi, ds, cfg);
    let report = Session::builder(ds)
        .config(cfg.clone())
        .attach(engine.as_mut())
        .stop(stop)
        .build()
        .expect("valid session")
        .run();
    let alpha = engine.alpha_global();
    let v = ds.shared_vector(&alpha);
    (report, alpha, v)
}

fn main() {
    let mut table = Table::new(&[
        "problem",
        "dataset",
        "rounds",
        "objective",
        "gap",
        "quality",
    ]);

    // ---- Regression pair: ridge + lasso on a webspam-like corpus -------
    let reg_ds = webspam_like(&SyntheticSpec::small());
    for problem in [
        Problem::ridge(1e-2 * reg_ds.n() as f64),
        Problem::lasso(0.05 * reg_ds.n() as f64),
    ] {
        let mut cfg = TrainConfig::default_for(&reg_ds);
        cfg.workers = 4;
        cfg.max_rounds = 5000;
        cfg.problem = problem;
        // Lasso demonstrates certificate stopping on a squared-loss
        // problem; ridge keeps the classic oracle target.
        let stop = match problem.kind_name() {
            "ridge" => StopPolicy::ToTarget { subopt: 1e-3 },
            _ => StopPolicy::ToGap { gap: 1e-3 },
        };
        let (report, alpha, v) = train(&reg_ds, &cfg, stop);
        let gap = problem.duality_gap(&reg_ds, &v, &alpha);
        let rmse = eval::rmse(&v, &reg_ds.b);
        let nnz = alpha.iter().filter(|a| a.abs() > 1e-10).count();
        table.row(vec![
            problem.label(),
            reg_ds.name.clone(),
            report.rounds.to_string(),
            format!("{:.6e}", report.final_objective.unwrap()),
            format!("{:.3e}", gap),
            format!("rmse {:.3} ({} nz)", rmse, nnz),
        ]);
    }

    // ---- Classification pair: SVM + logistic on separable ±1 data ------
    let (cls_ds, labels) = separable_classes(48, 256, 0.4, 17);
    for problem in [Problem::svm(1.0), Problem::logistic(1.0)] {
        let mut cfg = TrainConfig::default_for(&cls_ds);
        cfg.workers = 4;
        cfg.max_rounds = 3000;
        cfg.problem = problem;
        let (report, alpha, v) = train(&cls_ds, &cfg, StopPolicy::ToGap { gap: 1e-4 });
        let gap = problem.duality_gap(&cls_ds, &v, &alpha);
        // Margins in datapoint space: x_j·w = y_j·(q_j·v) with w = v.
        let qv = cls_ds.a.matvec_t(&v);
        let pred: Vec<f64> = qv.iter().zip(labels.iter()).map(|(&t, &y)| t * y).collect();
        let acc = eval::accuracy(&pred, &labels);
        let hinge = eval::hinge_loss(&pred, &labels);
        table.row(vec![
            problem.label(),
            cls_ds.name.clone(),
            report.rounds.to_string(),
            format!("{:.6e}", report.final_objective.unwrap()),
            format!("{:.3e}", gap),
            format!("acc {:.1}% hinge {:.3}", 100.0 * acc, hinge),
        ]);
        assert!(
            acc >= 0.95,
            "{} should separate the separable corpus (acc {})",
            problem.kind_name(),
            acc
        );
    }

    println!("all problem families through ONE session loop:\n");
    println!("{}", table.render());
    println!(
        "(svm/logistic/lasso stopped on the duality-gap certificate — no CG oracle was run \
         for them; ridge used the classic oracle target)"
    );
}
