//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! Layer 1 (Pallas SCD kernel) + Layer 2 (JAX `local_solve` graph) were
//! AOT-lowered by `make artifacts`; this binary — pure rust, python never
//! runs here — loads the HLO artifact, compiles it on the PJRT CPU client,
//! and uses it as the local solver inside the Layer-3 CoCoA coordinator to
//! train ridge regression on a webspam-like corpus to 1e-3 suboptimality,
//! logging the loss curve and verifying the result against the native
//! solver and the CG oracle.
//!
//! ```sh
//! make artifacts && cargo run --release --example train_e2e
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use std::sync::Arc;
use std::time::Instant;

use sparkbench::config::TrainConfig;
use sparkbench::coordinator;
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::data::{Partitioner, Partitioning, WorkerData};
use sparkbench::linalg;
use sparkbench::metrics::write_file;
use sparkbench::runtime::{Manifest, PjrtRuntime};
use sparkbench::solver::{pjrt::PjrtScd, scd::NativeScd, LocalSolver, SolveRequest};

fn main() {
    // ---- Load the AOT artifact (L1+L2) -------------------------------
    let dir = Manifest::default_dir();
    let man = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{:#}", e);
            std::process::exit(1);
        }
    };
    let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    println!(
        "PJRT platform {} | artifact {} (m={}, nk={}, h_max={}, VMEM≈{})",
        rt.platform(),
        man.local_solve_file,
        man.m,
        man.nk,
        man.h_max,
        man.vmem_bytes_estimate
            .map(sparkbench::util::fmt_bytes)
            .unwrap_or_else(|| "?".into())
    );
    let exec = Arc::new(rt.load_local_solve(&man).expect("compile local_solve"));

    // ---- Workload: webspam-like corpus matching the artifact shape ----
    let mut spec = SyntheticSpec::pjrt_default();
    spec.m = man.m;
    spec.n = 4 * man.nk; // K=4 workers at full artifact width
    let ds = webspam_like(&spec);
    let k = 4usize;
    let mut cfg = TrainConfig::default_for(&ds);
    cfg.workers = k;
    cfg.problem = sparkbench::problem::Problem::ridge(5e-2 * ds.n() as f64);
    println!("dataset {} ({}x{}, {} nnz), K={}", ds.name, ds.m(), ds.n(), ds.nnz(), k);

    // Range partitioning gives exactly nk columns per worker (the
    // artifact is compiled for [m, nk]); balanced-nnz may exceed it.
    let parts = Partitioning::build(Partitioner::Range, &ds.a, k, cfg.seed);
    let workers: Vec<WorkerData> = parts
        .parts
        .iter()
        .map(|cols| WorkerData::from_columns(&ds.a, cols))
        .collect();
    let mut solvers: Vec<PjrtScd> = (0..k).map(|_| PjrtScd::new(Arc::clone(&exec))).collect();
    for (s, w) in solvers.iter_mut().zip(workers.iter()) {
        assert!(s.fits(w), "partition exceeds compiled artifact");
    }

    // ---- Oracle for suboptimality --------------------------------------
    let (_, fstar) = sparkbench::solver::cg::ridge_optimum(&ds, cfg.lam_n(), 1e-12, 20_000);

    // ---- L3 training loop: CoCoA rounds over the PJRT local solver -----
    let h = workers[0].n_local(); // H = n_local
    let mut alphas: Vec<Vec<f64>> = workers.iter().map(|w| vec![0.0; w.n_local()]).collect();
    let mut v = vec![0.0; ds.m()];
    let mut csv = String::from("round,wall_s,objective,suboptimality\n");
    // real wall time is the measurement itself
    #[allow(clippy::disallowed_methods)]
    let t0 = Instant::now();
    let mut reached = None;
    let max_rounds = 1500usize;

    for round in 0..max_rounds {
        for (w, solver) in solvers.iter_mut().enumerate() {
            let req = SolveRequest {
                v: &v,
                b: &ds.b,
                h,
                problem: &cfg.problem,
                sigma: cfg.sigma(),
                seed: cfg.seed ^ (round as u64 * 1315423911) ^ w as u64,
            };
            let res = solver.solve(&workers[w], &alphas[w], &req);
            linalg::add_assign(&mut alphas[w], &res.delta_alpha);
            linalg::add_assign(&mut v, &res.delta_v);
        }
        // Recompute v from α every few rounds to cancel f32 drift from the
        // kernel (the coordinator owns f64 state; the artifact is f32).
        if round % 10 == 9 {
            let mut alpha = vec![0.0; ds.n()];
            for (wd, al) in workers.iter().zip(alphas.iter()) {
                for (&g, &a) in wd.global_ids.iter().zip(al.iter()) {
                    alpha[g as usize] = a;
                }
            }
            v = ds.shared_vector(&alpha);
        }

        let mut alpha = vec![0.0; ds.n()];
        for (wd, al) in workers.iter().zip(alphas.iter()) {
            for (&g, &a) in wd.global_ids.iter().zip(al.iter()) {
                alpha[g as usize] = a;
            }
        }
        let f = cfg.problem.primal(&ds, &alpha);
        let sub = coordinator::suboptimality(f, fstar);
        let wall = t0.elapsed().as_secs_f64();
        csv.push_str(&format!("{},{:.6},{:.9e},{:.6e}\n", round, wall, f, sub));
        if round % 50 == 0 || sub <= 1e-3 {
            println!("round {:4}  wall {:7.3}s  f {:.6e}  ε {:.3e}", round, wall, f, sub);
        }
        if sub <= 1e-3 {
            reached = Some((round, wall));
            break;
        }
    }

    write_file(std::path::Path::new("results/train_e2e.csv"), &csv).ok();
    println!("loss curve written to results/train_e2e.csv");

    // ---- Verify against the native solver (one round, same seed) -------
    let req = SolveRequest {
        v: &v,
        b: &ds.b,
        h: 128,
        problem: &cfg.problem,
        sigma: cfg.sigma(),
        seed: 424242,
    };
    let res_pjrt = solvers[0].solve(&workers[0], &alphas[0], &req);
    let res_native = NativeScd::new().solve(&workers[0], &alphas[0], &req);
    let max_err = res_pjrt
        .delta_alpha
        .iter()
        .zip(res_native.delta_alpha.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("pjrt-vs-native one-round max |Δα| diff: {:.3e} (f32 kernel)", max_err);

    match reached {
        Some((round, wall)) => {
            println!("E2E OK: reached ε=1e-3 in {} rounds, {:.2}s wall (three-layer stack)", round + 1, wall);
        }
        None => {
            eprintln!("E2E: target not reached in {} rounds", max_rounds);
            std::process::exit(1);
        }
    }
}
