//! Quickstart: train a ridge-regression model with CoCoA through the
//! `Session` builder and print the convergence report.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use sparkbench::config::{Impl, TrainConfig};
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::session::Session;

fn main() {
    // 1. A webspam-like sparse dataset (use `data::libsvm::read_libsvm`
    //    for real corpora).
    let ds = webspam_like(&SyntheticSpec::small());
    println!("dataset: {} ({} x {}, {} nnz)", ds.name, ds.m(), ds.n(), ds.nnz());

    // 2. Training configuration: K workers, ridge (η=1), H = n_local.
    let mut cfg = TrainConfig::default_for(&ds);
    cfg.workers = 4;
    cfg.max_rounds = 2000;

    // 3. Compose the session. The engine selector reaches the whole
    //    registry — every paper impl, `Engine::Threads { .. }` and
    //    `Engine::ParamServer { .. }` — and the whole point of the paper
    //    is that this choice (plus tuning H to it) decides performance.
    let report = Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg)
        .build()
        .expect("valid session")
        .run();

    // 4. One report, same shape for every engine.
    println!(
        "{}: {} rounds, {:.4} virtual s (worker {:.4} / master {:.4} / overhead {:.4})",
        report.impl_name,
        report.rounds,
        report.total_time,
        report.total_worker,
        report.total_master,
        report.total_overhead
    );
    match (report.time_to_target, report.final_suboptimality) {
        (Some(t), _) => println!("reached ε = 1e-3 at {:.4} virtual s", t),
        (None, Some(s)) => println!("did not reach target; final ε = {:.3e}", s),
        (None, None) => println!("timing run: objective not evaluated"),
    }

    // 5. The last few points of the convergence curve.
    for log in report.logs.iter().rev().take(3).collect::<Vec<_>>().into_iter().rev() {
        if let (Some(f), Some(s)) = (log.objective, log.suboptimality) {
            println!("  round {:4}  t={:.4}s  f={:.6e}  ε={:.3e}", log.round, log.time, f, s);
        }
    }
}
