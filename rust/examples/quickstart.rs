//! Quickstart: train a ridge-regression model with CoCoA on the MPI-like
//! substrate and print the convergence report.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use sparkbench::config::{Impl, TrainConfig};
use sparkbench::coordinator;
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::framework::build_engine;

fn main() {
    // 1. A webspam-like sparse dataset (use `data::libsvm::read_libsvm`
    //    for real corpora).
    let ds = webspam_like(&SyntheticSpec::small());
    println!("dataset: {} ({} x {}, {} nnz)", ds.name, ds.m(), ds.n(), ds.nnz());

    // 2. Training configuration: K workers, ridge (η=1), H = n_local.
    let mut cfg = TrainConfig::default_for(&ds);
    cfg.workers = 4;
    cfg.max_rounds = 2000;

    // 3. Pick a framework substrate — the whole point of the paper is that
    //    this choice (and tuning H to it) decides performance.
    let mut engine = build_engine(Impl::Mpi, &ds, &cfg);

    // 4. Train to 1e-3 suboptimality.
    let report = coordinator::train(engine.as_mut(), &ds, &cfg);
    println!(
        "{}: {} rounds, {:.4} virtual s (worker {:.4} / master {:.4} / overhead {:.4})",
        report.impl_name,
        report.rounds,
        report.total_time,
        report.total_worker,
        report.total_master,
        report.total_overhead
    );
    match report.time_to_target {
        Some(t) => println!("reached ε = 1e-3 at {:.4} virtual s", t),
        None => println!("did not reach target; final ε = {:.3e}", report.final_suboptimality),
    }

    // 5. The last few points of the convergence curve.
    for log in report.logs.iter().rev().take(3).collect::<Vec<_>>().into_iter().rev() {
        if let (Some(f), Some(s)) = (log.objective, log.suboptimality) {
            println!("  round {:4}  t={:.4}s  f={:.6e}  ε={:.3e}", log.round, log.time, f, s);
        }
    }
}
