//! Integration: the durability layer (DESIGN.md §15) — checksummed
//! atomic checkpoint stores, coordinator crashes (`crash@R`), and
//! crash-safe resume, end to end through the Session.
//!
//! The proof obligation everywhere: a session killed after the store
//! write race and restarted via `CheckpointStore::latest_valid()` —
//! including past a deliberately corrupted newest envelope — finishes
//! with objective bits EQUAL to the uninterrupted run, on the virtual
//! engine and the physical threads engine alike. Durability failures
//! degrade loudly (observer events), never silently and never by panic.

#![cfg(not(miri))] // interpreted execution is ~100x too slow for these end-to-end suites

use std::path::PathBuf;

use sparkbench::config::{Impl, TrainConfig};
use sparkbench::coordinator::checkpoint::{CheckpointStore, DurabilityEvent};
use sparkbench::coordinator::oracle_objective;
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::data::Dataset;
use sparkbench::framework::chaos::ChaosSpec;
use sparkbench::framework::Engine;
use sparkbench::metrics::TrainReport;
use sparkbench::session::{CheckpointEvery, Recording, Session};

fn setup() -> (Dataset, TrainConfig) {
    let ds = webspam_like(&SyntheticSpec::small());
    let mut cfg = TrainConfig::default_for(&ds);
    cfg.workers = 4;
    cfg.eval_every = 1;
    cfg.max_rounds = 1200;
    (ds, cfg)
}

fn objective_bits(rep: &TrainReport) -> Vec<u64> {
    rep.logs
        .iter()
        .filter_map(|l| l.objective)
        .map(f64::to_bits)
        .collect()
}

fn fresh_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// The uninterrupted baseline: `rounds` rounds, objectives every round.
fn clean_run(ds: &Dataset, cfg: &TrainConfig, fstar: f64, rounds: usize) -> TrainReport {
    Session::builder(ds)
        .engine(Impl::Mpi)
        .config(cfg.clone())
        .fixed_rounds(rounds)
        .oracle(fstar)
        .build()
        .unwrap()
        .run()
}

#[test]
fn crash_chaos_resumes_from_store_onto_uninterrupted_bits() {
    // crash@5 kills the session after round 5 — after the forced store
    // write — and a restart via resume_from_store continues rounds 6..12
    // on the exact trajectory of a run that never crashed.
    let (ds, cfg) = setup();
    let fstar = oracle_objective(&ds, &cfg);
    let dir = fresh_dir("sparkbench_crash_resume_mpi");

    let clean = clean_run(&ds, &cfg, fstar, 12);
    let full = objective_bits(&clean);
    assert_eq!(full.len(), 12);

    let rec = Recording::new();
    let crashed = Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg.clone())
        .chaos(ChaosSpec::parse("crash@5").unwrap())
        .checkpoint_store(&dir, 4, 3)
        .fixed_rounds(12)
        .oracle(fstar)
        .observe(rec.clone())
        .build()
        .unwrap()
        .run();
    // The "process" died after round 5: 6 completed rounds, on-trajectory.
    assert_eq!(crashed.rounds, 6);
    assert_eq!(objective_bits(&crashed), &full[..6]);
    // The store holds the cadence write (round 4) and the crash-forced
    // write (round 6), every save fanned to observers as a Saved event.
    let store = CheckpointStore::new(&dir, 3);
    assert_eq!(store.rounds(), vec![4, 6]);
    let saves: Vec<usize> = rec
        .durability()
        .iter()
        .filter_map(|e| match e {
            DurabilityEvent::Saved { round, .. } => Some(*round),
            _ => None,
        })
        .collect();
    assert_eq!(saves, vec![4, 6]);

    // Restart: latest_valid picks round 6; rounds 6..12 replay the tail.
    let resumed = Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg.clone())
        .resume_from_store(&dir)
        .unwrap()
        .fixed_rounds(6)
        .oracle(fstar)
        .build()
        .unwrap()
        .run();
    assert_eq!(objective_bits(&resumed), &full[6..]);
    assert_eq!(
        resumed.final_objective.unwrap().to_bits(),
        clean.final_objective.unwrap().to_bits()
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn crash_resume_skips_a_corrupted_newest_envelope() {
    // The acceptance scenario with a damaged tail: corrupt the newest
    // envelope after the crash; latest_valid() walks back to the cadence
    // write at round 4, and the restart re-runs rounds 4..12 — still
    // bit-equal to the chaos-free run (round seeds make re-runs exact).
    let (ds, cfg) = setup();
    let fstar = oracle_objective(&ds, &cfg);
    let dir = fresh_dir("sparkbench_crash_resume_corrupt");

    let clean = clean_run(&ds, &cfg, fstar, 12);
    let full = objective_bits(&clean);

    Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg.clone())
        .chaos(ChaosSpec::parse("crash@5").unwrap())
        .checkpoint_store(&dir, 4, 3)
        .fixed_rounds(12)
        .oracle(fstar)
        .build()
        .unwrap()
        .run();

    // Flip one payload bit in the newest envelope (round 6).
    let store = CheckpointStore::new(&dir, 3);
    let newest = store.path_for(6);
    let text = std::fs::read_to_string(&newest).unwrap();
    let pos = text.find("alpha_hex").unwrap() + 14;
    let mut bytes = text.into_bytes();
    bytes[pos] ^= 1;
    std::fs::write(&newest, &bytes).unwrap();
    let (_, env) = store.latest_valid().unwrap();
    assert_eq!(env.ckpt.round, 4);

    let resumed = Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg.clone())
        .resume_from_store(&dir)
        .unwrap()
        .fixed_rounds(8)
        .oracle(fstar)
        .build()
        .unwrap()
        .run();
    assert_eq!(objective_bits(&resumed), &full[4..]);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn crash_resume_is_bit_exact_on_the_physical_threads_engine() {
    // Same crash/recover story where rounds run on real OS threads: the
    // recovered trajectory still lands on the virtual engine's clean-run
    // bits (the registry invariant survives a coordinator crash).
    let (ds, cfg) = setup();
    let fstar = oracle_objective(&ds, &cfg);
    let dir = fresh_dir("sparkbench_crash_resume_threads");

    let clean = clean_run(&ds, &cfg, fstar, 10);
    let full = objective_bits(&clean);

    let crashed = Session::builder(&ds)
        .engine(Engine::threads(0))
        .config(cfg.clone())
        .chaos(ChaosSpec::parse("crash@5").unwrap())
        .checkpoint_store(&dir, 3, 3)
        .fixed_rounds(10)
        .oracle(fstar)
        .build()
        .unwrap()
        .run();
    assert_eq!(crashed.rounds, 6);
    assert_eq!(objective_bits(&crashed), &full[..6]);

    let resumed = Session::builder(&ds)
        .engine(Engine::threads(0))
        .config(cfg.clone())
        .resume_from_store(&dir)
        .unwrap()
        .fixed_rounds(4)
        .oracle(fstar)
        .build()
        .unwrap()
        .run();
    assert_eq!(objective_bits(&resumed), &full[6..]);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_from_store_refuses_an_empty_or_all_corrupt_store() {
    let (ds, cfg) = setup();
    let dir = fresh_dir("sparkbench_store_empty_resume");
    // Empty (nonexistent) store: a typed error, not a panic.
    // (SessionBuilder is not Debug, so destructure instead of unwrap_err.)
    let err = match Session::builder(&ds).config(cfg.clone()).resume_from_store(&dir) {
        Ok(_) => panic!("resume from an empty store must fail"),
        Err(e) => e,
    };
    assert!(err.contains("no valid checkpoint"), "{}", err);
    // A store holding only garbage behaves the same.
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("ckpt.000004.pallas"), "{ not json").unwrap();
    let err = match Session::builder(&ds).config(cfg).resume_from_store(&dir) {
        Ok(_) => panic!("resume from an all-corrupt store must fail"),
        Err(e) => e,
    };
    assert!(err.contains("no valid checkpoint"), "{}", err);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn unwritable_checkpoint_target_degrades_gracefully_not_silently() {
    // PR 3's silent-failure fix: CheckpointEvery pointed at an unwritable
    // target (here: an existing directory, which fails for root and
    // non-root alike — chmod-based read-only dirs don't stop root) must
    // keep training, surface Retry + GaveUp through on_durability, and
    // never panic. The session's own store path degrades the same way.
    let (ds, cfg) = setup();
    let fstar = oracle_objective(&ds, &cfg);
    let bad_target = std::env::temp_dir().join("sparkbench_unwritable_ckpt_target");
    std::fs::create_dir_all(&bad_target).unwrap();

    let rec = Recording::new();
    let report = Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg.clone())
        .fixed_rounds(6)
        .oracle(fstar)
        .observe(rec.clone())
        .observe(CheckpointEvery::new(3, &bad_target))
        .build()
        .unwrap()
        .run();
    // Training completed despite every save failing.
    assert_eq!(report.rounds, 6);
    // The clean baseline proves the failed saves never touched the math.
    let clean = clean_run(&ds, &cfg, fstar, 6);
    assert_eq!(objective_bits(&report), objective_bits(&clean));

    // The session-level store route surfaces the same failure to EVERY
    // observer (CheckpointEvery keeps its events to itself — assert via
    // the store path, where the session fans out). A store dir routed
    // through a regular file fails create_dir_all for any uid.
    let blocker = bad_target.join("blocker");
    std::fs::write(&blocker, "not a directory").unwrap();
    let rec2 = Recording::new();
    let report2 = Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg)
        .fixed_rounds(6)
        .oracle(fstar)
        .observe(rec2.clone())
        .checkpoint_store(blocker.join("store"), 3, 2)
        .build()
        .unwrap()
        .run();
    assert_eq!(report2.rounds, 6);
    let events = rec2.durability();
    assert!(!events.is_empty(), "durability failures must surface");
    let gave_up = events
        .iter()
        .any(|e| matches!(e, DurabilityEvent::GaveUp { .. }));
    let retried = events
        .iter()
        .any(|e| matches!(e, DurabilityEvent::Retry { .. }));
    assert!(gave_up && retried, "{:?}", events);
    std::fs::remove_dir_all(&bad_target).ok();
    // write_atomic's temp file for the directory-target case lives next
    // to the target; sweep it too.
    std::fs::remove_file(std::env::temp_dir().join("sparkbench_unwritable_ckpt_target.tmp")).ok();
}

#[test]
fn store_retention_keeps_only_the_newest_envelopes_during_training() {
    let (ds, cfg) = setup();
    let fstar = oracle_objective(&ds, &cfg);
    let dir = fresh_dir("sparkbench_store_retention_run");
    Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg)
        .checkpoint_store(&dir, 2, 2)
        .fixed_rounds(10)
        .oracle(fstar)
        .build()
        .unwrap()
        .run();
    // Cadence 2 over 10 rounds writes 2,4,6,8,10; retention keeps 8, 10.
    let store = CheckpointStore::new(&dir, 2);
    assert_eq!(store.rounds(), vec![8, 10]);
    let (_, env) = store.latest_valid().unwrap();
    assert_eq!(env.ckpt.round, 10);
    assert_eq!(env.version, 6);
    std::fs::remove_dir_all(&dir).ok();
}
