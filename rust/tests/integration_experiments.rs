//! Integration: the experiment harness — every figure and ablation runs at
//! reduced scale and emits the expected series/markers.

#![cfg(not(miri))] // interpreted execution is ~100x too slow for these end-to-end suites

use sparkbench::experiments::{run_ablation, run_figure, ExpOptions};

fn fast_opts() -> ExpOptions {
    ExpOptions {
        workers: 4,
        scale: "256,2048,32".into(),
        out_dir: std::env::temp_dir().join("sparkbench_it_results"),
        seeds: 1,
        real_managed: false,
        lam_n: None,
    }
}

#[test]
fn figure2_contains_all_impls_and_orders_them() {
    let out = run_figure(2, &fast_opts()).unwrap();
    for name in ["A:spark", "B:spark+c", "C:pyspark", "D:pyspark+c", "E:mpi"] {
        assert!(out.contains(name), "missing {} in:\n{}", name, out);
    }
    assert!(out.contains("tuned H"));
}

#[test]
fn figure3_checkpoints_hold_at_reduced_scale() {
    let out = run_figure(3, &fast_opts()).unwrap();
    assert!(out.contains("T_worker"));
    assert!(out.contains("paper checkpoints"));
    // Parse the MPI overhead percentage and require it small.
    let line = out.lines().find(|l| l.contains("E:mpi")).unwrap();
    let pct: f64 = line
        .split('|')
        .nth(6)
        .and_then(|c| c.trim().trim_end_matches('%').parse().ok())
        .unwrap();
    assert!(pct < 30.0, "MPI overhead {}% too high:\n{}", pct, out);
}

#[test]
fn figure4_shows_optimized_reduction() {
    let out = run_figure(4, &fast_opts()).unwrap();
    assert!(out.contains("B→B* overhead reduction"));
    assert!(out.contains("D→D* overhead reduction"));
    // Extract the D→D* factor, must be > 1.
    let line = out.lines().find(|l| l.contains("D→D*")).unwrap();
    let factor: f64 = line
        .split_whitespace()
        .find(|t| t.ends_with('×'))
        .and_then(|t| t.trim_end_matches('×').parse().ok())
        .unwrap();
    assert!(factor > 1.5, "D→D* reduction only {}×:\n{}", factor, out);
}

#[test]
fn figure5_ranks_mllib_last() {
    let mut opts = fast_opts();
    opts.lam_n = Some(0.05 * 2048.0);
    let out = run_figure(5, &opts).unwrap();
    assert!(out.contains("mllib-sgd"));
    assert!(out.contains("speedup vs MLlib"));
}

#[test]
fn figure6_emits_h_sweep_with_cross_eval() {
    let out = run_figure(6, &fast_opts()).unwrap();
    assert!(out.contains("H*/n_local"));
    assert!(out.contains("H* ordering"));
}

#[test]
fn figure7_reports_compute_fractions() {
    let out = run_figure(7, &fast_opts()).unwrap();
    assert!(out.contains("compute fraction at H*"));
    assert!(out.contains('%'));
}

#[test]
fn figure8_scales_workers() {
    let out = run_figure(8, &fast_opts()).unwrap();
    assert!(out.contains("ideal (zero-comm MPI)"));
    assert!(out.contains("E:mpi"));
}

#[test]
fn unknown_figure_is_an_error() {
    assert!(run_figure(1, &fast_opts()).is_err());
    assert!(run_figure(9, &fast_opts()).is_err());
}

#[test]
fn ablations_run() {
    let opts = fast_opts();
    for name in ["layout", "partitioner", "minibatch-cd", "adaptive-h", "gamma", "async-ps", "broadcast"] {
        let out = run_ablation(name, &opts).unwrap_or_else(|e| panic!("{}: {}", name, e));
        assert!(out.contains("Ablation"), "{} output:\n{}", name, out);
    }
    assert!(run_ablation("bogus", &opts).is_err());
}

#[test]
fn csv_outputs_written() {
    let opts = fast_opts();
    let _ = run_figure(3, &opts).unwrap();
    let csv = std::fs::read_to_string(opts.out_dir.join("fig3_overheads.csv")).unwrap();
    assert!(csv.starts_with("impl,t_tot"));
    assert_eq!(csv.lines().count(), 6); // header + 5 impls
}
