//! Integration: nested two-level parallelism (`threads_per_worker`) —
//! DESIGN.md §10.
//!
//! The load-bearing acceptance: a K-rank engine running T local
//! sub-solvers per rank produces **bit-identical** Δv, α and objective
//! trajectories to the flat K·T ring — for every engine family, for
//! power-of-two and non-power-of-two (K, T), through the Session API, and
//! with strictly fewer cross-rank frames on the wire.

#![cfg(not(miri))] // interpreted execution is ~100x too slow for these end-to-end suites

use sparkbench::config::{Impl, TrainConfig};
use sparkbench::testkit::alloc::CountingAllocator;

/// Install the counting allocator for THIS test binary so the 0-alloc
/// assertion below measures reality (the counter never moves otherwise).
#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::data::{Dataset, Partitioner, Partitioning, WorkerData};
use sparkbench::framework::{build_any, DistEngine, Engine, EngineOptions};
use sparkbench::linalg::{self, DeltaReducer, DeltaSlot, NestedTreePlan};
use sparkbench::problem::Problem;
use sparkbench::session::Session;
use sparkbench::solver::{scd::NativeScd, LocalSolver, SolveRequest, SolveResult};

fn dataset() -> Dataset {
    webspam_like(&SyntheticSpec::small())
}

fn cfg_for(ds: &Dataset, workers: usize) -> TrainConfig {
    let mut cfg = TrainConfig::default_for(ds);
    cfg.workers = workers;
    cfg
}

/// Drive an engine manually and collect the bit patterns of every round's
/// Δv plus the final α.
fn trajectory(
    eng: &mut Box<dyn DistEngine>,
    m: usize,
    rounds: usize,
    h: usize,
) -> (Vec<Vec<u64>>, Vec<u64>) {
    let mut v = vec![0.0; m];
    let mut dvs = Vec::new();
    for round in 0..rounds {
        let (dv, _) = eng.run_round(&v, h, round as u64);
        dvs.push(dv.iter().map(|x| x.to_bits()).collect());
        linalg::add_assign(&mut v, &dv);
    }
    let alpha = eng.alpha_global().iter().map(|x| x.to_bits()).collect();
    (dvs, alpha)
}

#[test]
fn nested_threads_engine_is_bitwise_identical_to_flat_ring() {
    // THE acceptance test: nested (K, T) ≡ flat K·T on the physically
    // parallel engine, for every required shape including
    // non-power-of-two.
    let ds = dataset();
    for (k, t) in [(2usize, 2usize), (3, 2), (2, 3), (4, 4)] {
        let cfg = cfg_for(&ds, k);
        let mut nested = build_any(
            Engine::threads_nested(k, t),
            &ds,
            &cfg,
            &EngineOptions::default(),
        );
        assert_eq!(nested.num_workers(), k, "k={} t={}", k, t);
        assert_eq!(nested.threads_per_worker(), t);
        assert_eq!(nested.engine().label(), format!("threads:{}:{}", k, t));

        let mut flat = build_any(
            Engine::threads(k * t),
            &ds,
            &cfg,
            &EngineOptions::default(),
        );
        assert_eq!(flat.num_workers(), k * t);

        let (ndvs, nalpha) = trajectory(&mut nested, ds.m(), 4, 12);
        let (fdvs, falpha) = trajectory(&mut flat, ds.m(), 4, 12);
        assert_eq!(ndvs, fdvs, "Δv diverged for k={} t={}", k, t);
        assert_eq!(nalpha, falpha, "α diverged for k={} t={}", k, t);
    }
}

#[test]
fn nested_is_bitwise_identical_to_flat_for_every_family() {
    // The same invariant across all five engine families, with a
    // non-power-of-two T so the forest (multi-root) path is exercised on
    // every substrate.
    let ds = dataset();
    let (k, t) = (2usize, 3usize);
    let nested_opts = EngineOptions {
        threads_per_worker: t,
        ..Default::default()
    };
    for family in Engine::FAMILIES {
        let cfg_nested = cfg_for(&ds, k);
        let mut nested = build_any(family, &ds, &cfg_nested, &nested_opts);
        assert_eq!(nested.threads_per_worker(), t, "{}", family.label());
        let cfg_flat = cfg_for(&ds, k * t);
        let mut flat = build_any(family, &ds, &cfg_flat, &EngineOptions::default());

        let (ndvs, nalpha) = trajectory(&mut nested, ds.m(), 3, 8);
        let (fdvs, falpha) = trajectory(&mut flat, ds.m(), 3, 8);
        assert_eq!(ndvs, fdvs, "Δv diverged for {}", family.label());
        assert_eq!(nalpha, falpha, "α diverged for {}", family.label());
    }
}

#[test]
fn nested_session_matches_flat_session_end_to_end() {
    // Session-level equivalence: same H resolution (n_locals reports
    // sub-shard sizes), same round count, same objective bits — the
    // builder's threads_per_worker is the only difference.
    let ds = dataset();
    let mut cfg = cfg_for(&ds, 2);
    cfg.max_rounds = 1500;
    cfg.eval_every = 1;
    let fstar = sparkbench::coordinator::oracle_objective(&ds, &cfg);

    let nested = Session::builder(&ds)
        .engine(Impl::Mpi)
        .threads_per_worker(2)
        .config(cfg.clone())
        .oracle(fstar)
        .build()
        .unwrap()
        .run();
    let mut cfg_flat = cfg.clone();
    cfg_flat.workers = 4;
    let flat = Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg_flat)
        .oracle(fstar)
        .build()
        .unwrap()
        .run();
    assert!(nested.time_to_target.is_some(), "nested session missed target");
    assert_eq!(nested.rounds, flat.rounds);
    let bits = |r: &sparkbench::metrics::TrainReport| -> Vec<u64> {
        r.logs
            .iter()
            .filter_map(|l| l.objective)
            .map(f64::to_bits)
            .collect()
    };
    assert_eq!(bits(&nested), bits(&flat));
}

#[test]
fn nested_cuts_cross_rank_bytes() {
    // The point of reducing locally first: only K forest-root frames
    // cross rank boundaries instead of K·T. Forced-dense frames make the
    // byte counts deterministic (T = 4 is a power of two → one root).
    let ds = dataset();
    let dense = EngineOptions {
        dense_frames: true,
        ..Default::default()
    };
    let nested_dense = EngineOptions {
        dense_frames: true,
        threads_per_worker: 4,
        ..Default::default()
    };
    let cfg = cfg_for(&ds, 2);
    let mut nested = build_any(Engine::Impl(Impl::Mpi), &ds, &cfg, &nested_dense);
    let cfg_flat = cfg_for(&ds, 8);
    let mut flat = build_any(Engine::Impl(Impl::Mpi), &ds, &cfg_flat, &dense);
    let v = vec![0.0; ds.m()];
    let (dv1, tn) = nested.run_round(&v, 8, 1);
    let (dv2, tf) = flat.run_round(&v, 8, 1);
    for (a, b) in dv1.iter().zip(dv2.iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // 2 dense root frames vs 8 dense rank frames.
    assert_eq!(tn.bytes_up * 4, tf.bytes_up);
    assert!(tn.worker_compute.len() == 2 && tf.worker_compute.len() == 8);
}

#[test]
fn nested_sub_solve_pipeline_is_allocation_free() {
    // The tentpole's 0-alloc bar: T sub-solves into persistent results +
    // slot loads + the two-stage reduce — after one warmup round, nothing
    // touches the allocator (aside from the caller-owned aggregate, which
    // this harness keeps out of the loop).
    let ds = dataset();
    let (k, t) = (2usize, 2usize);
    let cfg = cfg_for(&ds, k);
    let parts = Partitioning::build_nested(Partitioner::Range, &ds.a, k, t, cfg.seed);
    let shards: Vec<WorkerData> = parts
        .parts
        .iter()
        .map(|cols| WorkerData::from_columns(&ds.a, cols))
        .collect();
    let alphas: Vec<Vec<f64>> = shards.iter().map(|s| vec![0.0; s.n_local()]).collect();
    let mut solvers: Vec<NativeScd> = (0..k * t).map(|_| NativeScd::new()).collect();
    let mut results: Vec<SolveResult> = (0..k * t).map(|_| SolveResult::default()).collect();
    let mut slots: Vec<DeltaSlot> = (0..k * t).map(|_| DeltaSlot::new()).collect();
    let plan = NestedTreePlan::new(k, t);
    let mut reducer = DeltaReducer::raw(ds.m());
    let problem = Problem::ridge(1.0);
    let sigma = cfg.sigma_t(t);
    let v = vec![0.0; ds.m()];

    let mut round = |seed: u64, slots: &mut Vec<DeltaSlot>| {
        for g in 0..k * t {
            let req = SolveRequest {
                v: &v,
                b: &ds.b,
                h: 16,
                problem: &problem,
                sigma,
                seed: seed ^ (g as u64).wrapping_mul(0x9E3779B97F4A7C15),
            };
            solvers[g].solve_into(&shards[g], &alphas[g], &req, &mut results[g]);
            reducer.load(&mut slots[g], &results[g].delta_v);
        }
        for w in 0..k {
            reducer.reduce_pairs(&mut slots[w * t..(w + 1) * t], plan.local_pairs(w));
        }
        reducer.reduce_pairs(slots, plan.cross_pairs());
    };
    round(0, &mut slots); // warmup sizes every persistent buffer
    let before = sparkbench::testkit::alloc::current_thread_allocations();
    for seed in 1..6u64 {
        round(seed, &mut slots);
    }
    let after = sparkbench::testkit::alloc::current_thread_allocations();
    assert_eq!(after - before, 0, "nested round pipeline allocated");
}

#[test]
fn builder_rejects_bad_threads_per_worker() {
    let ds = dataset();
    let cfg = cfg_for(&ds, 2);
    let err = Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg.clone())
        .threads_per_worker(0)
        .fixed_rounds(1)
        .build()
        .err()
        .expect("T = 0 must be rejected");
    assert!(err.contains("threads_per_worker"), "{}", err);

    let mut eng = sparkbench::framework::build_engine(Impl::Mpi, &ds, &cfg);
    let err = Session::builder(&ds)
        .config(cfg)
        .attach(eng.as_mut())
        .threads_per_worker(2)
        .fixed_rounds(1)
        .build()
        .err()
        .expect("threads_per_worker on an attached engine must be rejected");
    assert!(err.contains("attached"), "{}", err);
}
