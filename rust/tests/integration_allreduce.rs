//! Integration: AllReduce equivalence across execution substrates.
//!
//! The tree reduction must make physical parallelism numerically
//! invisible: the physically-threaded engine (replies arrive in arbitrary
//! interleavings, deltas land in rank-ordered slots) and the virtual-clock
//! MPI engine (sequential execution) combine worker deltas through the
//! identical pairwise tree, so their Δv trajectories are **bit-identical**
//! — not merely close. K covers powers of two and the non-power-of-two
//! binomial-tree edge cases.

#![cfg(not(miri))] // interpreted execution is ~100x too slow for these end-to-end suites

use sparkbench::config::TrainConfig;
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::data::{Dataset, Partitioner, Partitioning};
use sparkbench::framework::mpi::MpiEngine;
use sparkbench::framework::threads::ThreadedMpiEngine;
use sparkbench::framework::DistEngine;
use sparkbench::linalg;

fn setup(k: usize) -> (Dataset, TrainConfig, Partitioning) {
    let ds = webspam_like(&SyntheticSpec::small());
    let mut cfg = TrainConfig::default_for(&ds);
    cfg.workers = k;
    let parts = Partitioning::build(Partitioner::Range, &ds.a, k, 0);
    (ds, cfg, parts)
}

/// Run `rounds` rounds on both engines, asserting bitwise-equal Δv and
/// identical α state afterwards.
fn assert_bit_identical_trajectories(k: usize, rounds: u64, h: usize) {
    let (ds, cfg, parts) = setup(k);
    let mut threaded = ThreadedMpiEngine::new(&ds, &parts, &cfg);
    let mut virtual_eng = MpiEngine::build(&ds, &parts, &cfg);
    let mut v1 = vec![0.0; ds.m()];
    let mut v2 = vec![0.0; ds.m()];
    for round in 0..rounds {
        let (dv1, _) = threaded.run_round(&v1, h, round);
        let (dv2, _) = virtual_eng.run_round(&v2, h, round);
        for (i, (a, b)) in dv1.iter().zip(dv2.iter()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "K={} round {} dv[{}]: {} vs {} (must be BIT-identical)",
                k,
                round,
                i,
                a,
                b
            );
        }
        linalg::add_assign(&mut v1, &dv1);
        linalg::add_assign(&mut v2, &dv2);
    }
    let a1 = threaded.alpha_global();
    let a2 = virtual_eng.alpha_global();
    for (x, y) in a1.iter().zip(a2.iter()) {
        assert_eq!(x.to_bits(), y.to_bits(), "K={}: alpha diverged", k);
    }
}

#[test]
fn threaded_equals_virtual_k2() {
    assert_bit_identical_trajectories(2, 6, 40);
}

#[test]
fn threaded_equals_virtual_k8() {
    assert_bit_identical_trajectories(8, 6, 40);
}

#[test]
fn threaded_equals_virtual_non_power_of_two() {
    // K=5 exercises the orphan-rank path of the binomial tree:
    // (0+1), (2+3) → (0+2) → (0+4).
    assert_bit_identical_trajectories(5, 5, 30);
    assert_bit_identical_trajectories(3, 5, 30);
}

#[test]
fn tree_order_is_rank_order_not_arrival_order() {
    // Run the threaded engine many times on the same round; thread
    // scheduling permutes arrival order between runs, but slotting +
    // fixed-tree reduction must make every run emit identical bits.
    let (ds, cfg, parts) = setup(8);
    let v = vec![0.0; ds.m()];
    let mut reference: Option<Vec<u64>> = None;
    for _ in 0..5 {
        let mut eng = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        let (dv, _) = eng.run_round(&v, 50, 7);
        let bits: Vec<u64> = dv.iter().map(|x| x.to_bits()).collect();
        match &reference {
            None => reference = Some(bits),
            Some(r) => assert_eq!(&bits, r, "arrival interleaving leaked into the reduction"),
        }
    }
}

#[test]
fn every_worker_count_reduces_consistently() {
    // Δv == A·Δα must hold for every K, including K > sensible (idle
    // workers contribute zero-vectors to the tree).
    for k in [1usize, 2, 4, 6, 7, 16] {
        let (ds, cfg, parts) = setup(k);
        let mut eng = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        let v = vec![0.0; ds.m()];
        let (dv, _) = eng.run_round(&v, 25, 3);
        let alpha = eng.alpha_global();
        let want = ds.shared_vector(&alpha);
        for (a, b) in dv.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-9, "K={}: {} vs {}", k, a, b);
        }
    }
}
