//! Integration: the session-driven CoCoA loop over every framework
//! substrate in the registry.

#![cfg(not(miri))] // interpreted execution is ~100x too slow for these end-to-end suites

use sparkbench::config::{Impl, TrainConfig};
use sparkbench::coordinator::{self, tuner};
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::data::Dataset;
use sparkbench::framework::{build_engine, Engine};
use sparkbench::session::Session;

fn setup() -> (Dataset, TrainConfig) {
    let ds = webspam_like(&SyntheticSpec::small());
    let mut cfg = TrainConfig::default_for(&ds);
    cfg.workers = 4;
    cfg.max_rounds = 2500;
    (ds, cfg)
}

fn run_to_target(
    engine: impl Into<Engine>,
    ds: &Dataset,
    cfg: &TrainConfig,
    fstar: f64,
) -> sparkbench::metrics::TrainReport {
    Session::builder(ds)
        .engine(engine)
        .config(cfg.clone())
        .oracle(fstar)
        .build()
        .expect("valid session")
        .run()
}

#[test]
fn every_engine_reaches_target() {
    let (ds, cfg) = setup();
    let fstar = coordinator::oracle_objective(&ds, &cfg);
    // The FULL registry, not just the virtual-clock impls: the thread and
    // parameter-server engines train through the same session loop.
    let mut engines: Vec<Engine> = Impl::ALL
        .iter()
        .filter(|&&imp| imp != Impl::MllibSgd) // needs far more rounds; covered below
        .map(|&imp| Engine::Impl(imp))
        .collect();
    engines.push(Engine::threads(0));
    engines.push(Engine::ParamServer { staleness: 0 });
    for engine in engines {
        let rep = run_to_target(engine, &ds, &cfg, fstar);
        assert!(
            rep.time_to_target.is_some(),
            "{} failed to reach 1e-3 (final {:?} after {} rounds)",
            engine.label(),
            rep.final_suboptimality,
            rep.rounds
        );
    }
}

#[test]
fn mllib_sgd_converges_but_slower_in_rounds() {
    let (ds, mut cfg) = setup();
    cfg.max_rounds = 150;
    cfg.target_subopt = 0.0;
    let fstar = coordinator::oracle_objective(&ds, &cfg);
    let r_mllib = run_to_target(Impl::MllibSgd, &ds, &cfg, fstar);
    let r_cocoa = run_to_target(Impl::SparkScala, &ds, &cfg, fstar);
    let (sub_mllib, sub_cocoa) = (
        r_mllib.final_suboptimality.unwrap(),
        r_cocoa.final_suboptimality.unwrap(),
    );
    assert!(
        sub_cocoa < 0.5 * sub_mllib,
        "CoCoA {:.3e} should be far ahead of SGD {:.3e} at equal rounds",
        sub_cocoa,
        sub_mllib
    );
    // But SGD must still make real progress (it is a correct solver).
    assert!(sub_mllib < 0.5, "{}", sub_mllib);
}

#[test]
fn virtual_time_ordering_matches_figure2() {
    // E < B* < B < A < D < C in time-to-target (paper Figures 2/5).
    let (ds, cfg) = setup();
    let fstar = coordinator::oracle_objective(&ds, &cfg);
    let time_of = |imp: Impl| -> f64 {
        run_to_target(imp, &ds, &cfg, fstar)
            .time_to_target
            .unwrap_or_else(|| panic!("{} missed target", imp.name()))
    };
    let e = time_of(Impl::Mpi);
    let bstar = time_of(Impl::SparkCOpt);
    let b = time_of(Impl::SparkC);
    let a = time_of(Impl::SparkScala);
    let d = time_of(Impl::PySparkC);
    let c = time_of(Impl::PySpark);
    assert!(e < b, "E {} !< B {}", e, b);
    assert!(bstar <= b, "B* {} !<= B {}", bstar, b);
    assert!(b < a, "B {} !< A {}", b, a);
    assert!(a < c, "A {} !< C {}", a, c);
    assert!(d < c, "D {} !< C {}", d, c);
}

#[test]
fn optimized_variants_close_most_of_the_gap() {
    // §5.3/§5.4: B*, D* within a small factor of MPI (paper: < 2×), while
    // the unoptimized python path is an order of magnitude away. Needs the
    // byte-dominated regime, hence the larger dataset.
    let mut spec = SyntheticSpec::small();
    spec.m = 512;
    spec.n = 4096;
    spec.avg_col_nnz = 48;
    let ds = webspam_like(&spec);
    let mut cfg = TrainConfig::default_for(&ds);
    cfg.workers = 4;
    cfg.max_rounds = 2500;
    let fstar = coordinator::oracle_objective(&ds, &cfg);
    let tuned_time = |imp: Impl| -> f64 {
        let make = || build_engine(imp, &ds, &cfg);
        let (points, best) =
            tuner::grid_search_h(&make, &ds, &cfg, fstar, &[0.2, 0.5, 1.0, 2.0, 4.0]);
        points[best].report.time_to_target.expect("tuned run must reach target")
    };
    let e = tuned_time(Impl::Mpi);
    let bstar = tuned_time(Impl::SparkCOpt);
    let dstar = tuned_time(Impl::PySparkCOpt);
    let c = tuned_time(Impl::PySpark);
    assert!(bstar / e < 4.0, "B*/E = {:.2}", bstar / e);
    assert!(dstar / e < 4.0, "D*/E = {:.2}", dstar / e);
    assert!(c / e > 5.0, "C/E = {:.2} should be large", c / e);
}

#[test]
fn eval_every_skips_objective_computation() {
    let (ds, mut cfg) = setup();
    cfg.eval_every = 5;
    cfg.max_rounds = 17;
    cfg.target_subopt = 0.0;
    let fstar = coordinator::oracle_objective(&ds, &cfg);
    let rep = run_to_target(Impl::Mpi, &ds, &cfg, fstar);
    let evals = rep.logs.iter().filter(|l| l.objective.is_some()).count();
    assert_eq!(evals, 5); // rounds 0,5,10,15 + final round 16
}

#[test]
fn elastic_net_trains_too() {
    let (ds, mut cfg) = setup();
    cfg.problem = sparkbench::problem::Problem::elastic(cfg.lam_n() * 4.0, 0.5);
    cfg.max_rounds = 600;
    cfg.target_subopt = 1e-2;
    let mut engine = build_engine(Impl::Mpi, &ds, &cfg);
    let rep = Session::builder(&ds)
        .config(cfg)
        .attach(engine.as_mut())
        .build()
        .expect("valid session")
        .run();
    assert!(
        rep.time_to_target.is_some(),
        "elastic net missed 1e-2: {:?}",
        rep.final_suboptimality
    );
    // The l1 component must produce some sparsity in the model.
    let alpha = engine.alpha_global();
    let zeros = alpha.iter().filter(|a| a.abs() < 1e-12).count();
    assert!(zeros > 0, "no sparsity under elastic net");
}

#[test]
fn adaptive_h_competitive_with_tuned() {
    let (ds, cfg) = setup();
    let fstar = coordinator::oracle_objective(&ds, &cfg);
    let make = || build_engine(Impl::SparkC, &ds, &cfg);
    let (points, best) = tuner::grid_search_h(&make, &ds, &cfg, fstar, &[0.2, 0.5, 1.0, 2.0]);
    let tuned = points[best].report.time_to_target.unwrap();
    let adaptive = Session::builder(&ds)
        .engine(Impl::SparkC)
        .config(cfg.clone())
        .oracle(fstar)
        .adaptive_h(0.75)
        .build()
        .expect("valid session")
        .run();
    let t_adaptive = adaptive.time_to_target.expect("adaptive missed target");
    assert!(
        t_adaptive < 5.0 * tuned,
        "adaptive {} too far from tuned {}",
        t_adaptive,
        tuned
    );
}
