//! Integration: the chaos layer (DESIGN.md §12) — injected worker deaths,
//! stragglers and skew, end to end through the Session recovery loop.
//!
//! The invariant under test everywhere: chaos changes the *clock*, never
//! the *bits*. A session that loses a worker mid-run recovers onto the
//! exact α/objective trajectory of an uninterrupted run; speculative
//! re-execution wins the race without perturbing a single bit; and every
//! scenario is driven by a fixed seed and replayed twice to prove the
//! whole stack (fault schedule, jitter, speculation, recovery) is
//! deterministic.

#![cfg(not(miri))] // interpreted execution is ~100x too slow for these end-to-end suites

use sparkbench::config::{Impl, TrainConfig};
use sparkbench::coordinator::{checkpoint::Checkpoint, oracle_objective};
use sparkbench::data::synthetic::{webspam_like, zipf_columns, SyntheticSpec};
use sparkbench::data::{Dataset, Partitioner};
use sparkbench::framework::chaos::ChaosSpec;
use sparkbench::framework::Engine;
use sparkbench::metrics::TrainReport;
use sparkbench::session::{CheckpointEvery, Recording, Session};

fn setup() -> (Dataset, TrainConfig) {
    let ds = webspam_like(&SyntheticSpec::small());
    let mut cfg = TrainConfig::default_for(&ds);
    cfg.workers = 4;
    cfg.eval_every = 1;
    cfg.max_rounds = 1200;
    (ds, cfg)
}

fn objective_bits(rep: &TrainReport) -> Vec<u64> {
    rep.logs
        .iter()
        .filter_map(|l| l.objective)
        .map(f64::to_bits)
        .collect()
}

/// One chaos run: fixed rounds, recording observer, objectives every round.
fn chaos_run(
    ds: &Dataset,
    cfg: &TrainConfig,
    engine: impl Into<Engine>,
    fstar: f64,
    spec: &str,
    rounds: usize,
) -> (TrainReport, Recording) {
    let rec = Recording::new();
    let mut builder = Session::builder(ds)
        .engine(engine)
        .config(cfg.clone())
        .fixed_rounds(rounds)
        .oracle(fstar)
        .observe(rec.clone());
    if !spec.is_empty() {
        builder = builder.chaos(ChaosSpec::parse(spec).unwrap());
    }
    (builder.build().unwrap().run(), rec)
}

#[test]
fn chaos_session_survives_death_and_straggler_bit_identically() {
    // The ISSUE's headline scenario: K = 4, one injected death at round 5,
    // one 10x slowdown at round 3. The session must survive both and land
    // on the chaos-free trajectory to the bit — only the clock pays.
    let (ds, cfg) = setup();
    let fstar = oracle_objective(&ds, &cfg);
    let spec = "death@5:2,slow@3:1:10";

    let (clean, _) = chaos_run(&ds, &cfg, Impl::Mpi, fstar, "", 12);
    let (chaos, rec) = chaos_run(&ds, &cfg, Impl::Mpi, fstar, spec, 12);

    assert_eq!(chaos.rounds, 12);
    assert_eq!(rec.faults(), vec![(5, 2)]);
    assert_eq!(objective_bits(&chaos), objective_bits(&clean));
    // The aborted attempt + detection + respawn and the dragged round all
    // cost modeled time the clean run never pays.
    assert!(chaos.total_time > clean.total_time);

    // Fixed seed, replayed: the full scenario — fault schedule, recovery,
    // modeled clock — is deterministic down to the time bits.
    let (replay, rec2) = chaos_run(&ds, &cfg, Impl::Mpi, fstar, spec, 12);
    assert_eq!(rec2.faults(), rec.faults());
    assert_eq!(objective_bits(&replay), objective_bits(&chaos));
    assert_eq!(replay.total_time.to_bits(), chaos.total_time.to_bits());
}

#[test]
fn chaos_on_physical_threads_engine_recovers_through_the_session() {
    // Same scenario on the thread-backed engine, where the death is a real
    // OS-thread kill + respawn and the slowdown a real sleep. Bits must
    // still match the virtual engine's chaos-free run (registry invariant
    // survives chaos), and a replay must reproduce them.
    let (ds, cfg) = setup();
    let fstar = oracle_objective(&ds, &cfg);
    let spec = "death@5:1,slow@3:2:5";

    let (clean, _) = chaos_run(&ds, &cfg, Impl::Mpi, fstar, "", 10);
    let (chaos, rec) = chaos_run(&ds, &cfg, Engine::threads(0), fstar, spec, 10);
    assert_eq!(chaos.rounds, 10);
    assert_eq!(rec.faults(), vec![(5, 1)]);
    assert_eq!(objective_bits(&chaos), objective_bits(&clean));

    let (replay, rec2) = chaos_run(&ds, &cfg, Engine::threads(0), fstar, spec, 10);
    assert_eq!(rec2.faults(), rec.faults());
    assert_eq!(objective_bits(&replay), objective_bits(&chaos));
}

#[test]
fn speculative_reexecution_is_bit_identical_and_faster() {
    // A catastrophic straggler (factor 1e8) at every early round. Without
    // speculation the modeled clock eats the full dragged solve; with it
    // the backup copy wins the race at detect + base cost. Both runs, and
    // the clean run, produce identical bits — speculation is a pure
    // scheduling optimization.
    let (ds, cfg) = setup();
    let fstar = oracle_objective(&ds, &cfg);

    let (clean, _) = chaos_run(&ds, &cfg, Impl::Mpi, fstar, "", 8);
    let (slow, _) = chaos_run(&ds, &cfg, Impl::Mpi, fstar, "slow@1:2:1e8", 8);
    let (spec, _) = chaos_run(&ds, &cfg, Impl::Mpi, fstar, "spec,slow@1:2:1e8", 8);

    assert_eq!(objective_bits(&slow), objective_bits(&clean));
    assert_eq!(objective_bits(&spec), objective_bits(&clean));
    // First-result-wins: the speculative run never waits out the drag.
    assert!(spec.total_time < slow.total_time / 1e3);
    assert!(spec.total_time > clean.total_time);

    // Determinism replay, time bits included.
    let (replay, _) = chaos_run(&ds, &cfg, Impl::Mpi, fstar, "spec,slow@1:2:1e8", 8);
    assert_eq!(objective_bits(&replay), objective_bits(&spec));
    assert_eq!(replay.total_time.to_bits(), spec.total_time.to_bits());
}

#[test]
fn heterogeneity_and_jitter_move_the_clock_but_never_the_bits() {
    // Seeded per-worker speeds + per-round latency jitter: the round time
    // becomes max_k over heterogeneous ranks, so the clock grows, but the
    // update bits cannot notice.
    let (ds, cfg) = setup();
    let fstar = oracle_objective(&ds, &cfg);

    let (clean, _) = chaos_run(&ds, &cfg, Impl::Mpi, fstar, "", 8);
    let (het, _) = chaos_run(&ds, &cfg, Impl::Mpi, fstar, "het=2.0,jitter=0.3", 8);
    assert_eq!(objective_bits(&het), objective_bits(&clean));
    assert!(het.total_time > clean.total_time);

    let (replay, _) = chaos_run(&ds, &cfg, Impl::Mpi, fstar, "het=2.0,jitter=0.3", 8);
    assert_eq!(replay.total_time.to_bits(), het.total_time.to_bits());
}

#[test]
fn checkpoint_resume_mid_chaos_does_not_refire_consumed_deaths() {
    // Two scheduled deaths. The run is interrupted between them; the v5
    // checkpoint envelope carries the fault-plan cursor, so the resumed
    // session replays ONLY the second death — and still lands on the
    // uninterrupted trajectory bit-for-bit.
    let (ds, cfg) = setup();
    let fstar = oracle_objective(&ds, &cfg);
    let spec = "death@2:0,death@6:3";
    let path = std::env::temp_dir().join("sparkbench_chaos_ckpt_test.json");

    let (clean, _) = chaos_run(&ds, &cfg, Impl::Mpi, fstar, "", 8);
    let full = objective_bits(&clean);
    assert_eq!(full.len(), 8);

    // First half: rounds 0..4, the round-2 death fires, checkpoint lands
    // after round 3 with fault_cursor = 1.
    let rec1 = Recording::new();
    let first = Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg.clone())
        .chaos(ChaosSpec::parse(spec).unwrap())
        .fixed_rounds(4)
        .oracle(fstar)
        .observe(rec1.clone())
        .observe(CheckpointEvery::new(4, &path))
        .build()
        .unwrap()
        .run();
    assert_eq!(rec1.faults(), vec![(2, 0)]);
    assert_eq!(objective_bits(&first), &full[..4]);

    let ckpt = Checkpoint::load(&path).unwrap();
    assert_eq!(ckpt.round, 4);
    assert_eq!(ckpt.fault_cursor, 1);

    // Resume with the SAME chaos spec: rounds 4..8, only death@6 fires.
    let rec2 = Recording::new();
    let second = Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg.clone())
        .chaos(ChaosSpec::parse(spec).unwrap())
        .fixed_rounds(4)
        .oracle(fstar)
        .resume_from(ckpt)
        .observe(rec2.clone())
        .build()
        .unwrap()
        .run();
    assert_eq!(rec2.faults(), vec![(6, 3)]);
    assert_eq!(objective_bits(&second), &full[4..]);

    std::fs::remove_file(&path).ok();
}

#[test]
fn skewed_partitioning_shifts_the_h_optimum_down() {
    // The acceptance sweep: on Zipfian column-mass data, the deliberately
    // imbalanced Skewed partitioner makes the slowest shard dominate every
    // round, so per-round compute cost grows while fixed overhead stays
    // put. The paper's H trade-off then tilts: large H buys relatively
    // less, and the time-to-target optimum moves to a smaller H than the
    // balanced-nnz baseline sees on the same data.
    let ds = zipf_columns(&SyntheticSpec {
        m: 256,
        n: 512,
        avg_col_nnz: 16,
        powerlaw_s: 1.5,
        model_density: 0.3,
        noise: 0.01,
        seed: 11,
    });
    let mut cfg = TrainConfig::default_for(&ds);
    cfg.workers = 4;
    cfg.eval_every = 1;
    cfg.max_rounds = 20_000;
    let fstar = oracle_objective(&ds, &cfg);

    let grid = [0.1, 0.3, 1.0, 4.0];
    let sweep = |partitioner: Partitioner| -> Vec<f64> {
        grid.iter()
            .map(|&hf| {
                let mut c = cfg.clone();
                c.partitioner = partitioner;
                c.h_frac = hf;
                Session::builder(&ds)
                    .engine(Impl::Mpi)
                    .config(c)
                    .oracle(fstar)
                    .build()
                    .unwrap()
                    .run()
                    .time_to_target
                    .unwrap_or_else(|| panic!("h_frac={} did not reach target", hf))
            })
            .collect()
    };
    let argmin = |tt: &[f64]| {
        tt.iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    };

    let balanced = sweep(Partitioner::BalancedNnz);
    let skewed = sweep(Partitioner::Skewed);

    // Robust form: the penalty for the largest H (relative to the
    // smallest) is measurably worse once one shard holds most of the
    // mass — the compute coefficient in T(H) = R(H)·(F + c·H) grew.
    let ratio_balanced = balanced[grid.len() - 1] / balanced[0];
    let ratio_skewed = skewed[grid.len() - 1] / skewed[0];
    assert!(
        ratio_skewed > ratio_balanced,
        "skew did not shift the H trade-off: skewed {:?} vs balanced {:?}",
        skewed,
        balanced
    );
    // And the optimum itself never moves UP under skew.
    assert!(
        argmin(&skewed) <= argmin(&balanced),
        "best H grew under skew: skewed {:?} vs balanced {:?}",
        skewed,
        balanced
    );
}
