//! End-to-end serving invariants (DESIGN.md §13): model extraction from
//! live sessions and checkpoint envelopes, bit-identity of the batched /
//! sharded / replayed prediction paths, consistency of served predictions
//! with training-side quantities for all four problem families, and the
//! zero-allocation discipline of the steady-state hot path.

#![cfg(not(miri))] // interpreted execution is ~100x too slow for these end-to-end suites

use sparkbench::config::Impl;
use sparkbench::coordinator::checkpoint::Envelope;
use sparkbench::data::synthetic::{separable_classes, webspam_like, SyntheticSpec};
use sparkbench::data::{train_test_split, CsrMatrix, Dataset};
use sparkbench::problem::Problem;
use sparkbench::serve::{
    overload_replay, replay, ArrivalPattern, BatchPolicy, OnlineEval, Output, OverloadConfig,
    Predictor, PrimalModel, ServiceModel,
};
use sparkbench::session::{CheckpointEvery, Session, StopPolicy};
use sparkbench::testkit::alloc::{current_thread_allocations, CountingAllocator};

// Counting allocator for this binary, so the zero-alloc assertions below
// measure the real serving path (uninstalled, the counter never moves).
#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn small() -> Dataset {
    webspam_like(&SyntheticSpec::small())
}

/// Train a squared-loss model for `rounds` and extract it from the live
/// session.
fn squared_model(ds: &Dataset, problem: Problem, rounds: usize) -> PrimalModel {
    let (report, model) = Session::builder(ds)
        .engine(Impl::Mpi)
        .problem(problem)
        .fixed_rounds(rounds)
        .build()
        .unwrap()
        .run_extract();
    assert_eq!(report.rounds, rounds);
    model
}

/// Train a dual-loss (SVM / logistic) model to the duality-gap
/// certificate and extract it from the live session.
fn dual_model(ds: &Dataset, problem: Problem) -> PrimalModel {
    let mut cfg = sparkbench::config::TrainConfig::default_for(ds);
    cfg.workers = 3;
    cfg.max_rounds = 4000;
    let (report, model) = Session::builder(ds)
        .engine(Impl::Mpi)
        .config(cfg)
        .problem(problem)
        .stop(StopPolicy::ToGap { gap: 1e-3 })
        .build()
        .unwrap()
        .run_extract();
    assert!(
        report.time_to_target.is_some(),
        "{} missed the gap target",
        problem.kind_name()
    );
    model
}

#[test]
fn extracted_squared_model_is_consistent_with_training_v() {
    // For squared loss the served weights are α, and predicting the
    // training rows computes Aα — the very quantity training maintains as
    // v. Row-major summation order differs from the CSC column sweep, so
    // the match is to fp tolerance (the bit-exact claims live in the
    // dual-family and path-identity tests).
    let ds = small();
    let model = squared_model(&ds, Problem::ridge(1.0), 25);
    assert_eq!(model.output(), Output::Value);
    assert_eq!(model.dim(), ds.n());
    assert_eq!(model.rounds(), 25);
    let v_ref = ds.shared_vector(model.weights());
    let rows = CsrMatrix::from_csc(&ds.a);
    let preds = Predictor::new(model).predict(&rows);
    for (i, (p, v)) in preds.iter().zip(v_ref.iter()).enumerate() {
        let tol = 1e-10 * (1.0 + v.abs());
        assert!((p - v).abs() <= tol, "row {}: {} vs v {}", i, p, v);
    }
}

#[test]
fn dual_models_serve_bit_identically_to_training_side_matvec_t() {
    // For the dual families the served weights are v = Aα and a request
    // row of Aᵀ aliases a column of A, so per-row serving dots issue the
    // SAME dot_indexed calls as training's matvec_t — bit-identical raw
    // scores, and (for logistic) bit-identical probabilities through the
    // same sigmoid.
    let (ds, _labels) = separable_classes(32, 128, 0.5, 23);
    for problem in [Problem::svm(1.0), Problem::logistic(1.0)] {
        let model = dual_model(&ds, problem);
        assert_eq!(model.dim(), ds.m());
        let want_raw = ds.a.matvec_t(model.weights());
        let output = model.output();
        let rows = CsrMatrix::transpose_of(&ds.a);
        let predictor = Predictor::new(model);
        let preds = predictor.predict(&rows);
        assert_eq!(preds.len(), want_raw.len());
        for (i, (p, raw)) in preds.iter().zip(want_raw.iter()).enumerate() {
            let want = match output {
                Output::Score => *raw,
                Output::Probability => sparkbench::serve::model::sigmoid(*raw),
                Output::Value => unreachable!("dual family produced a Value output"),
            };
            assert_eq!(
                p.to_bits(),
                want.to_bits(),
                "{} row {}: {} vs {}",
                problem.kind_name(),
                i,
                p,
                want
            );
        }
        // Converged separable models classify their q-space datapoints
        // (+1 labels: a positive score means correct) nearly perfectly.
        let ones = vec![1.0; preds.len()];
        let mut ev = OnlineEval::new(output);
        ev.update(&preds, &ones);
        assert!(
            ev.accuracy().unwrap() >= 0.8,
            "{} accuracy {}",
            problem.kind_name(),
            ev.accuracy().unwrap()
        );
    }
}

#[test]
fn checkpoint_extracted_model_matches_the_live_session_bitwise() {
    // A session that checkpoints at its final round and the model
    // extracted from that session must be indistinguishable: the envelope
    // hex-packs every f64 bit-exactly, and Envelope::peek needs no
    // engine, dataset or session to get them back.
    let ds = small();
    let path = std::env::temp_dir().join("sparkbench_serve_ckpt_roundtrip.json");
    let (report, live) = Session::builder(&ds)
        .engine(Impl::Mpi)
        .fixed_rounds(20)
        .observe(CheckpointEvery::new(5, &path))
        .build()
        .unwrap()
        .run_extract();
    assert_eq!(report.rounds, 20);
    let env = Envelope::peek(&path).unwrap();
    assert_eq!(env.version, 5);
    assert_eq!(env.ckpt.round, 20);
    let from_disk = PrimalModel::from_checkpoint(&env.ckpt).unwrap();
    assert_eq!(from_disk.dim(), live.dim());
    assert_eq!(from_disk.rounds(), live.rounds());
    for (a, b) in from_disk.weights().iter().zip(live.weights().iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // Identical weights ⇒ identical predictions, to the bit.
    let (_, test) = train_test_split(&ds, 0.25, 42);
    let rows = CsrMatrix::from_csc(&test.a);
    let p_live = Predictor::new(live).predict(&rows);
    let p_disk = Predictor::new(from_disk).predict(&rows);
    for (a, b) in p_live.iter().zip(p_disk.iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn batched_sharded_and_replayed_paths_agree_bitwise_for_all_families() {
    // Every serving path — one sequential sweep, the sharded multi-core
    // sweep, and the batching front end at any arrival rate — slices the
    // same per-row kernel calls, so all of them produce the same bits.
    let reg_ds = small();
    let (dual_ds, _) = separable_classes(32, 128, 0.5, 23);
    let cases: Vec<(CsrMatrix, PrimalModel)> = vec![
        (
            CsrMatrix::from_csc(&reg_ds.a),
            squared_model(&reg_ds, Problem::ridge(1.0), 10),
        ),
        (
            CsrMatrix::from_csc(&reg_ds.a),
            squared_model(&reg_ds, Problem::lasso(1.0), 10),
        ),
        (
            CsrMatrix::transpose_of(&dual_ds.a),
            dual_model(&dual_ds, Problem::svm(1.0)),
        ),
        (
            CsrMatrix::transpose_of(&dual_ds.a),
            dual_model(&dual_ds, Problem::logistic(1.0)),
        ),
    ];
    for (rows, model) in cases {
        let name = model.problem().kind_name();
        let predictor = Predictor::new(model);
        let seq = predictor.predict(&rows);
        let mut out = Vec::new();
        for shards in [2, 3, rows.m] {
            predictor.predict_sharded_into(&rows, shards, &mut out);
            for (i, (a, b)) in out.iter().zip(seq.iter()).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{} row {} ({} shards)", name, i, shards);
            }
        }
        // Size-bound and deadline-bound replay regimes alike.
        for (rate, shards) in [(1e6, 1), (50.0, 2)] {
            let mut preds = Vec::new();
            let stats = replay(
                &predictor,
                &rows,
                None,
                BatchPolicy::new(16, 0.01),
                rate,
                shards,
                &mut preds,
            );
            assert_eq!(stats.requests, rows.m);
            for (i, (a, b)) in preds.iter().zip(seq.iter()).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{} row {} (rate {})", name, i, rate);
            }
        }
    }
}

#[test]
fn steady_state_batched_predict_never_allocates() {
    // THE acceptance bar: once the output buffer has warmed, batched
    // predict performs zero heap allocations per batch — measured by the
    // counting allocator installed for this binary.
    let ds = small();
    let rows = CsrMatrix::from_csc(&ds.a);
    let alpha: Vec<f64> = (0..ds.n()).map(|j| (j as f64 * 0.29).sin()).collect();
    let model = PrimalModel::from_parts(
        Problem::ridge(1.0),
        &alpha,
        &[],
        sparkbench::config::Precision::F64,
        1,
    );
    let predictor = Predictor::new(model);
    let mut out = Vec::new();
    predictor.predict_into(&rows, &mut out); // warm the buffer
    let before = current_thread_allocations();
    for _ in 0..50 {
        predictor.predict_into(&rows, &mut out);
    }
    let after = current_thread_allocations();
    assert_eq!(after - before, 0, "steady-state batched predict allocated");
}

#[test]
fn held_out_replay_reports_the_offline_rmse_bitwise() {
    // Train on the train split, replay the held-out split through the
    // batching front end: the online RMSE folds in stream order, so it
    // equals the offline data::eval::rmse over the same predictions to
    // the bit — and a trained model beats the zero predictor.
    let ds = small();
    let (train, test) = train_test_split(&ds, 0.3, 1);
    let (report, model) = Session::builder(&train)
        .engine(Impl::Mpi)
        .build()
        .unwrap()
        .run_extract();
    assert!(report.time_to_target.is_some());
    let rows = CsrMatrix::from_csc(&test.a);
    let predictor = Predictor::new(model);
    let mut preds = Vec::new();
    let stats = replay(
        &predictor,
        &rows,
        Some(&test.b),
        BatchPolicy::new(32, 0.001),
        1e5,
        1,
        &mut preds,
    );
    assert_eq!(stats.eval.count(), test.m());
    let offline = sparkbench::data::rmse(&preds, &test.b);
    assert_eq!(stats.eval.rmse().unwrap().to_bits(), offline.to_bits());
    let zero = vec![0.0; test.m()];
    assert!(
        offline < sparkbench::data::rmse(&zero, &test.b),
        "held-out rmse {} not better than the zero model",
        offline
    );
}

// ---------------------------------------------------------------------
// Overload invariants (DESIGN.md §15): bounded-queue shedding, graceful
// deadline degradation, hot-swap bit-identity, and seeded replayability
// of the serve-side fault harness.
// ---------------------------------------------------------------------

/// A synthetic servable model over an `n`-dimensional request space;
/// `phase` shifts the weights so two models disagree on every row.
fn overload_model(n: usize, phase: f64) -> PrimalModel {
    let alpha: Vec<f64> = (0..n).map(|j| (j as f64 * 0.37 + phase).sin()).collect();
    PrimalModel::from_parts(
        Problem::ridge(1.0),
        &alpha,
        &[],
        sparkbench::config::Precision::F64,
        1,
    )
}

fn overload_setup() -> (CsrMatrix, PrimalModel, BatchPolicy, ServiceModel) {
    let ds = small();
    let rows = CsrMatrix::from_csc(&ds.a);
    let model = overload_model(ds.n(), 0.0);
    // μ(16) = 16 / (0.002 + 0.0005·16) = 1600 req/s.
    let policy = BatchPolicy::new(16, 0.005);
    let svc = ServiceModel { overhead_s: 0.002, per_row_s: 0.0005 };
    (rows, model, policy, svc)
}

#[test]
fn overload_storm_sheds_without_corrupting_the_queue() {
    // A storm at 4× the sustainable rate must shed — and shedding must
    // not disturb the admitted requests: depth never exceeds the cap,
    // service order stays FIFO, and every served prediction bit-equals
    // the direct per-row kernel call on the same model.
    let (rows, model, policy, svc) = overload_setup();
    let cfg = OverloadConfig {
        queue_cap: 32,
        service: svc,
        malformed_every: 0,
        swap_at_batch: None,
        seed: 7,
    };
    let pattern = ArrivalPattern::Storm { rate: 4.0 * svc.sustainable_rate(policy.max_batch) };
    let mut preds = Vec::new();
    let st = overload_replay(&model, None, &rows, &policy, &pattern, &cfg, &mut preds);
    assert_eq!(st.offered, rows.m);
    assert_eq!(st.admitted + st.shed + st.malformed, st.offered);
    assert!(st.shed > 0, "a 4x-rate storm must shed ({:?})", st);
    assert!(st.shed_rate > 0.0 && st.shed_rate < 1.0, "shed_rate {}", st.shed_rate);
    assert!(st.max_depth <= cfg.queue_cap, "depth {} broke the cap", st.max_depth);
    assert_eq!(preds.len(), st.admitted);
    assert!(st.p99_latency_s >= st.p50_latency_s && st.p50_latency_s > 0.0);
    let mut last_rid = None;
    for (rid, p) in &preds {
        // FIFO service: row ids come out in admission order.
        if let Some(prev) = last_rid {
            assert!(*rid > prev, "service order corrupted: {} after {}", rid, prev);
        }
        last_rid = Some(*rid);
        let (idx, vals) = rows.row(*rid);
        assert_eq!(p.to_bits(), model.predict_one(idx, vals).to_bits(), "row {}", rid);
    }
}

#[test]
fn degraded_deadline_engages_under_pressure_and_recovers_after_it() {
    // Thundering-herd bursts push the queue past the low-water mark
    // (deadline shrinks, degraded batches form); the long inter-burst
    // gaps drain it back below (full-deadline batches form again). One
    // run showing 0 < degraded_occupancy < 1 proves both directions.
    let (rows, model, policy, svc) = overload_setup();
    let cfg = OverloadConfig {
        queue_cap: 32,
        service: svc,
        malformed_every: 0,
        swap_at_batch: None,
        seed: 11,
    };
    let pattern = ArrivalPattern::Burst { burst: 40, within: 1e-5, gap: 0.5 };
    let mut preds = Vec::new();
    let st = overload_replay(&model, None, &rows, &policy, &pattern, &cfg, &mut preds);
    assert!(st.degraded_batches > 0, "bursts past low-water must degrade ({:?})", st);
    assert!(
        st.degraded_batches < st.batches,
        "the deadline must recover between bursts ({:?})",
        st
    );
    assert!(st.degraded_occupancy > 0.0 && st.degraded_occupancy < 1.0);
    // Degradation trades wait for depth; it never breaks the cap either.
    assert!(st.max_depth <= cfg.queue_cap);
}

#[test]
fn hot_swap_mid_replay_matches_a_drained_then_swapped_baseline_bitwise() {
    // One run hot-swaps at a batch boundary without draining; the
    // baseline is two no-swap runs (all-primary and all-standby) over
    // identical arrivals — admission and batching are model-independent,
    // so the hot-swap run must equal primary-bits up to the boundary and
    // standby-bits after it, with nothing lost or reordered in between.
    let (rows, primary, policy, svc) = overload_setup();
    let standby = overload_model(rows.n, 1.7);
    let pattern = ArrivalPattern::Uniform { rate: 0.5 * svc.sustainable_rate(policy.max_batch) };
    let run = |swap: Option<usize>, sb: Option<&PrimalModel>| {
        let cfg = OverloadConfig {
            queue_cap: 64,
            service: svc,
            malformed_every: 0,
            swap_at_batch: swap,
            seed: 3,
        };
        let mut preds = Vec::new();
        let st = overload_replay(&primary, sb, &rows, &policy, &pattern, &cfg, &mut preds);
        (st, preds)
    };
    let (st_swap, hot) = run(Some(3), Some(&standby));
    let (_, all_primary) = run(None, None);
    let (st_all, all_standby) = run(Some(0), Some(&standby));
    assert!(st_swap.swapped_batches > 0 && st_swap.swapped_batches < st_swap.batches);
    assert_eq!(st_all.swapped_batches, st_all.batches);
    assert_eq!(hot.len(), all_primary.len());
    assert_eq!(hot.len(), all_standby.len());
    // The boundary: the first prediction that left the primary's bits.
    let split = hot
        .iter()
        .zip(all_primary.iter())
        .position(|(a, b)| a.1.to_bits() != b.1.to_bits())
        .expect("the swapped run never diverged from all-primary");
    assert!(split > 0, "swap happened before any primary batch");
    for i in 0..split {
        assert_eq!(hot[i].0, all_primary[i].0, "row order diverged at {}", i);
        assert_eq!(hot[i].1.to_bits(), all_primary[i].1.to_bits(), "pre-swap row {}", i);
    }
    for i in split..hot.len() {
        assert_eq!(hot[i].0, all_standby[i].0, "row order diverged at {}", i);
        assert_eq!(hot[i].1.to_bits(), all_standby[i].1.to_bits(), "post-swap row {}", i);
    }
}

#[test]
fn overload_replay_is_bit_exact_from_its_seed() {
    // The whole harness — storm arrivals, shedding, degradation,
    // malformed traffic, hot-swap — replays bit-identically from its
    // seed: stats and every (row, prediction) pair.
    let (rows, primary, policy, svc) = overload_setup();
    let standby = overload_model(rows.n, 0.9);
    let run = || {
        let cfg = OverloadConfig {
            queue_cap: 32,
            service: svc,
            malformed_every: 9,
            swap_at_batch: Some(2),
            seed: 0xC0FFEE,
        };
        let pattern = ArrivalPattern::Storm { rate: 3.0 * svc.sustainable_rate(policy.max_batch) };
        let mut preds = Vec::new();
        let st = overload_replay(&primary, Some(&standby), &rows, &policy, &pattern, &cfg, &mut preds);
        (st, preds)
    };
    let (st_a, preds_a) = run();
    let (st_b, preds_b) = run();
    assert_eq!(st_a, st_b);
    assert_eq!(preds_a.len(), preds_b.len());
    for (a, b) in preds_a.iter().zip(preds_b.iter()) {
        assert_eq!(a.0, b.0);
        assert_eq!(a.1.to_bits(), b.1.to_bits());
    }
    // A different seed moves the storm: the run is seed-driven, not fixed.
    let cfg2 = OverloadConfig {
        queue_cap: 32,
        service: svc,
        malformed_every: 9,
        swap_at_batch: Some(2),
        seed: 0xBEEF,
    };
    let pattern = ArrivalPattern::Storm { rate: 3.0 * svc.sustainable_rate(policy.max_batch) };
    let mut preds_c = Vec::new();
    let st_c = overload_replay(&primary, Some(&standby), &rows, &policy, &pattern, &cfg2, &mut preds_c);
    assert_ne!(st_c, st_a, "different seeds must produce different storms");
}

#[test]
fn malformed_requests_are_refused_before_the_batch_arena() {
    // Every 7th arrival is presented with a column index past the model
    // dimension. CsrMatrix::push_row would panic on it — the harness
    // must refuse it as a typed outcome instead, serve everything else,
    // and keep the survivors' bits untouched.
    let (rows, model, policy, svc) = overload_setup();
    let cfg = OverloadConfig {
        queue_cap: 64,
        service: svc,
        malformed_every: 7,
        swap_at_batch: None,
        seed: 5,
    };
    let pattern = ArrivalPattern::Uniform { rate: 0.5 * svc.sustainable_rate(policy.max_batch) };
    let mut preds = Vec::new();
    let st = overload_replay(&model, None, &rows, &policy, &pattern, &cfg, &mut preds);
    let expected: Vec<usize> = (0..rows.m)
        .filter(|i| (i + 1) % 7 == 0 && rows.row_nnz(*i) > 0)
        .collect();
    assert_eq!(st.malformed, expected.len());
    assert_eq!(st.shed, 0, "half the sustainable rate must not shed");
    assert_eq!(st.admitted, rows.m - expected.len());
    assert_eq!(preds.len(), st.admitted);
    for (rid, p) in &preds {
        assert!(!expected.contains(rid), "refused row {} was served", rid);
        let (idx, vals) = rows.row(*rid);
        assert_eq!(p.to_bits(), model.predict_one(idx, vals).to_bits());
    }
}
