//! End-to-end serving invariants (DESIGN.md §13): model extraction from
//! live sessions and checkpoint envelopes, bit-identity of the batched /
//! sharded / replayed prediction paths, consistency of served predictions
//! with training-side quantities for all four problem families, and the
//! zero-allocation discipline of the steady-state hot path.

#![cfg(not(miri))] // interpreted execution is ~100x too slow for these end-to-end suites

use sparkbench::config::Impl;
use sparkbench::coordinator::checkpoint::Envelope;
use sparkbench::data::synthetic::{separable_classes, webspam_like, SyntheticSpec};
use sparkbench::data::{train_test_split, CsrMatrix, Dataset};
use sparkbench::problem::Problem;
use sparkbench::serve::{replay, BatchPolicy, OnlineEval, Output, Predictor, PrimalModel};
use sparkbench::session::{CheckpointEvery, Session, StopPolicy};
use sparkbench::testkit::alloc::{current_thread_allocations, CountingAllocator};

// Counting allocator for this binary, so the zero-alloc assertions below
// measure the real serving path (uninstalled, the counter never moves).
#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn small() -> Dataset {
    webspam_like(&SyntheticSpec::small())
}

/// Train a squared-loss model for `rounds` and extract it from the live
/// session.
fn squared_model(ds: &Dataset, problem: Problem, rounds: usize) -> PrimalModel {
    let (report, model) = Session::builder(ds)
        .engine(Impl::Mpi)
        .problem(problem)
        .fixed_rounds(rounds)
        .build()
        .unwrap()
        .run_extract();
    assert_eq!(report.rounds, rounds);
    model
}

/// Train a dual-loss (SVM / logistic) model to the duality-gap
/// certificate and extract it from the live session.
fn dual_model(ds: &Dataset, problem: Problem) -> PrimalModel {
    let mut cfg = sparkbench::config::TrainConfig::default_for(ds);
    cfg.workers = 3;
    cfg.max_rounds = 4000;
    let (report, model) = Session::builder(ds)
        .engine(Impl::Mpi)
        .config(cfg)
        .problem(problem)
        .stop(StopPolicy::ToGap { gap: 1e-3 })
        .build()
        .unwrap()
        .run_extract();
    assert!(
        report.time_to_target.is_some(),
        "{} missed the gap target",
        problem.kind_name()
    );
    model
}

#[test]
fn extracted_squared_model_is_consistent_with_training_v() {
    // For squared loss the served weights are α, and predicting the
    // training rows computes Aα — the very quantity training maintains as
    // v. Row-major summation order differs from the CSC column sweep, so
    // the match is to fp tolerance (the bit-exact claims live in the
    // dual-family and path-identity tests).
    let ds = small();
    let model = squared_model(&ds, Problem::ridge(1.0), 25);
    assert_eq!(model.output(), Output::Value);
    assert_eq!(model.dim(), ds.n());
    assert_eq!(model.rounds(), 25);
    let v_ref = ds.shared_vector(model.weights());
    let rows = CsrMatrix::from_csc(&ds.a);
    let preds = Predictor::new(model).predict(&rows);
    for (i, (p, v)) in preds.iter().zip(v_ref.iter()).enumerate() {
        let tol = 1e-10 * (1.0 + v.abs());
        assert!((p - v).abs() <= tol, "row {}: {} vs v {}", i, p, v);
    }
}

#[test]
fn dual_models_serve_bit_identically_to_training_side_matvec_t() {
    // For the dual families the served weights are v = Aα and a request
    // row of Aᵀ aliases a column of A, so per-row serving dots issue the
    // SAME dot_indexed calls as training's matvec_t — bit-identical raw
    // scores, and (for logistic) bit-identical probabilities through the
    // same sigmoid.
    let (ds, _labels) = separable_classes(32, 128, 0.5, 23);
    for problem in [Problem::svm(1.0), Problem::logistic(1.0)] {
        let model = dual_model(&ds, problem);
        assert_eq!(model.dim(), ds.m());
        let want_raw = ds.a.matvec_t(model.weights());
        let output = model.output();
        let rows = CsrMatrix::transpose_of(&ds.a);
        let predictor = Predictor::new(model);
        let preds = predictor.predict(&rows);
        assert_eq!(preds.len(), want_raw.len());
        for (i, (p, raw)) in preds.iter().zip(want_raw.iter()).enumerate() {
            let want = match output {
                Output::Score => *raw,
                Output::Probability => sparkbench::serve::model::sigmoid(*raw),
                Output::Value => unreachable!("dual family produced a Value output"),
            };
            assert_eq!(
                p.to_bits(),
                want.to_bits(),
                "{} row {}: {} vs {}",
                problem.kind_name(),
                i,
                p,
                want
            );
        }
        // Converged separable models classify their q-space datapoints
        // (+1 labels: a positive score means correct) nearly perfectly.
        let ones = vec![1.0; preds.len()];
        let mut ev = OnlineEval::new(output);
        ev.update(&preds, &ones);
        assert!(
            ev.accuracy().unwrap() >= 0.8,
            "{} accuracy {}",
            problem.kind_name(),
            ev.accuracy().unwrap()
        );
    }
}

#[test]
fn checkpoint_extracted_model_matches_the_live_session_bitwise() {
    // A session that checkpoints at its final round and the model
    // extracted from that session must be indistinguishable: the envelope
    // hex-packs every f64 bit-exactly, and Envelope::peek needs no
    // engine, dataset or session to get them back.
    let ds = small();
    let path = std::env::temp_dir().join("sparkbench_serve_ckpt_roundtrip.json");
    let (report, live) = Session::builder(&ds)
        .engine(Impl::Mpi)
        .fixed_rounds(20)
        .observe(CheckpointEvery::new(5, &path))
        .build()
        .unwrap()
        .run_extract();
    assert_eq!(report.rounds, 20);
    let env = Envelope::peek(&path).unwrap();
    assert_eq!(env.version, 5);
    assert_eq!(env.ckpt.round, 20);
    let from_disk = PrimalModel::from_checkpoint(&env.ckpt).unwrap();
    assert_eq!(from_disk.dim(), live.dim());
    assert_eq!(from_disk.rounds(), live.rounds());
    for (a, b) in from_disk.weights().iter().zip(live.weights().iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // Identical weights ⇒ identical predictions, to the bit.
    let (_, test) = train_test_split(&ds, 0.25, 42);
    let rows = CsrMatrix::from_csc(&test.a);
    let p_live = Predictor::new(live).predict(&rows);
    let p_disk = Predictor::new(from_disk).predict(&rows);
    for (a, b) in p_live.iter().zip(p_disk.iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn batched_sharded_and_replayed_paths_agree_bitwise_for_all_families() {
    // Every serving path — one sequential sweep, the sharded multi-core
    // sweep, and the batching front end at any arrival rate — slices the
    // same per-row kernel calls, so all of them produce the same bits.
    let reg_ds = small();
    let (dual_ds, _) = separable_classes(32, 128, 0.5, 23);
    let cases: Vec<(CsrMatrix, PrimalModel)> = vec![
        (
            CsrMatrix::from_csc(&reg_ds.a),
            squared_model(&reg_ds, Problem::ridge(1.0), 10),
        ),
        (
            CsrMatrix::from_csc(&reg_ds.a),
            squared_model(&reg_ds, Problem::lasso(1.0), 10),
        ),
        (
            CsrMatrix::transpose_of(&dual_ds.a),
            dual_model(&dual_ds, Problem::svm(1.0)),
        ),
        (
            CsrMatrix::transpose_of(&dual_ds.a),
            dual_model(&dual_ds, Problem::logistic(1.0)),
        ),
    ];
    for (rows, model) in cases {
        let name = model.problem().kind_name();
        let predictor = Predictor::new(model);
        let seq = predictor.predict(&rows);
        let mut out = Vec::new();
        for shards in [2, 3, rows.m] {
            predictor.predict_sharded_into(&rows, shards, &mut out);
            for (i, (a, b)) in out.iter().zip(seq.iter()).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{} row {} ({} shards)", name, i, shards);
            }
        }
        // Size-bound and deadline-bound replay regimes alike.
        for (rate, shards) in [(1e6, 1), (50.0, 2)] {
            let mut preds = Vec::new();
            let stats = replay(
                &predictor,
                &rows,
                None,
                BatchPolicy::new(16, 0.01),
                rate,
                shards,
                &mut preds,
            );
            assert_eq!(stats.requests, rows.m);
            for (i, (a, b)) in preds.iter().zip(seq.iter()).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{} row {} (rate {})", name, i, rate);
            }
        }
    }
}

#[test]
fn steady_state_batched_predict_never_allocates() {
    // THE acceptance bar: once the output buffer has warmed, batched
    // predict performs zero heap allocations per batch — measured by the
    // counting allocator installed for this binary.
    let ds = small();
    let rows = CsrMatrix::from_csc(&ds.a);
    let alpha: Vec<f64> = (0..ds.n()).map(|j| (j as f64 * 0.29).sin()).collect();
    let model = PrimalModel::from_parts(
        Problem::ridge(1.0),
        &alpha,
        &[],
        sparkbench::config::Precision::F64,
        1,
    );
    let predictor = Predictor::new(model);
    let mut out = Vec::new();
    predictor.predict_into(&rows, &mut out); // warm the buffer
    let before = current_thread_allocations();
    for _ in 0..50 {
        predictor.predict_into(&rows, &mut out);
    }
    let after = current_thread_allocations();
    assert_eq!(after - before, 0, "steady-state batched predict allocated");
}

#[test]
fn held_out_replay_reports_the_offline_rmse_bitwise() {
    // Train on the train split, replay the held-out split through the
    // batching front end: the online RMSE folds in stream order, so it
    // equals the offline data::eval::rmse over the same predictions to
    // the bit — and a trained model beats the zero predictor.
    let ds = small();
    let (train, test) = train_test_split(&ds, 0.3, 1);
    let (report, model) = Session::builder(&train)
        .engine(Impl::Mpi)
        .build()
        .unwrap()
        .run_extract();
    assert!(report.time_to_target.is_some());
    let rows = CsrMatrix::from_csc(&test.a);
    let predictor = Predictor::new(model);
    let mut preds = Vec::new();
    let stats = replay(
        &predictor,
        &rows,
        Some(&test.b),
        BatchPolicy::new(32, 0.001),
        1e5,
        1,
        &mut preds,
    );
    assert_eq!(stats.eval.count(), test.m());
    let offline = sparkbench::data::rmse(&preds, &test.b);
    assert_eq!(stats.eval.rmse().unwrap().to_bits(), offline.to_bits());
    let zero = vec![0.0; test.m()];
    assert!(
        offline < sparkbench::data::rmse(&zero, &test.b),
        "held-out rmse {} not better than the zero model",
        offline
    );
}
