//! Integration: the session observer layer — recording, checkpoint
//! round-trips (including across substrates), CSV tracing, and the
//! adaptive H policy's bit-for-bit fidelity to the controller.

#![cfg(not(miri))] // interpreted execution is ~100x too slow for these end-to-end suites

use sparkbench::config::{Impl, TrainConfig};
use sparkbench::coordinator::tuner::AdaptiveH;
use sparkbench::coordinator::{checkpoint::Checkpoint, oracle_objective};
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::data::Dataset;
use sparkbench::framework::{build_engine, Engine};
use sparkbench::metrics::TrainReport;
use sparkbench::session::{CheckpointEvery, CsvTrace, Recording, Session};

fn setup() -> (Dataset, TrainConfig) {
    let ds = webspam_like(&SyntheticSpec::small());
    let mut cfg = TrainConfig::default_for(&ds);
    cfg.workers = 4;
    cfg.max_rounds = 1200;
    (ds, cfg)
}

fn objective_bits(rep: &TrainReport) -> Vec<u64> {
    rep.logs
        .iter()
        .filter_map(|l| l.objective)
        .map(f64::to_bits)
        .collect()
}

#[test]
fn recording_observer_sees_every_round_exactly_once() {
    let (ds, cfg) = setup();
    // Fixed-rounds run: rounds 0..12, each exactly once, one completion.
    let rec = Recording::new();
    let report = Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg.clone())
        .fixed_rounds(12)
        .observe(rec.clone())
        .build()
        .unwrap()
        .run();
    assert_eq!(report.rounds, 12);
    assert_eq!(rec.rounds(), (0..12).collect::<Vec<_>>());
    assert_eq!(rec.completions(), 1);

    // Early-stopping run: the observer count tracks the actual rounds.
    let rec2 = Recording::new();
    let report2 = Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg)
        .observe(rec2.clone())
        .build()
        .unwrap()
        .run();
    assert!(report2.time_to_target.is_some());
    assert_eq!(rec2.rounds(), (0..report2.rounds).collect::<Vec<_>>());
    assert_eq!(rec2.completions(), 1);
}

#[test]
fn checkpoint_via_observer_roundtrips_to_the_same_trajectory() {
    let (ds, mut cfg) = setup();
    cfg.eval_every = 1;
    let fstar = oracle_objective(&ds, &cfg);
    let path = std::env::temp_dir().join("sparkbench_session_ckpt_test.json");

    // Uninterrupted reference: 10 rounds, objectives logged every round.
    let uninterrupted = Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg.clone())
        .fixed_rounds(10)
        .oracle(fstar)
        .build()
        .unwrap()
        .run();
    let full = objective_bits(&uninterrupted);
    assert_eq!(full.len(), 10);

    // Interrupted run: 5 rounds, checkpoint written by the observer.
    let first_half = Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg.clone())
        .fixed_rounds(5)
        .oracle(fstar)
        .observe(CheckpointEvery::new(5, &path))
        .build()
        .unwrap()
        .run();
    assert_eq!(objective_bits(&first_half), &full[..5]);

    // Resume from the checkpoint file: rounds 5..10, seeds line up, the
    // engine's α is restored through DistEngine::load_alpha — the
    // trajectory continues BIT-identically.
    let ckpt = Checkpoint::load(&path).unwrap();
    assert_eq!(ckpt.round, 5);
    let resumed = Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg.clone())
        .fixed_rounds(5)
        .oracle(fstar)
        .resume_from(ckpt)
        .build()
        .unwrap()
        .run();
    assert_eq!(resumed.logs.first().unwrap().round, 5);
    assert_eq!(objective_bits(&resumed), &full[5..]);
    // The resumed clock continues from the checkpointed time.
    assert!(resumed.total_time > 0.0);

    // Cross-substrate resume: the same checkpoint restored into the
    // physically parallel thread engine continues the same trajectory —
    // the registry invariant survives a save/restore boundary.
    let ckpt2 = Checkpoint::load(&path).unwrap();
    let resumed_threads = Session::builder(&ds)
        .engine(Engine::threads(0))
        .config(cfg)
        .fixed_rounds(5)
        .oracle(fstar)
        .resume_from(ckpt2)
        .build()
        .unwrap()
        .run();
    assert_eq!(objective_bits(&resumed_threads), &full[5..]);

    std::fs::remove_file(&path).ok();
}

#[test]
fn nested_checkpoint_resume_is_bit_exact_across_substrates() {
    // Satellite: deterministic re-sharding on resume. A T = 4 nested
    // session checkpoints; resuming on BOTH the nested threads engine and
    // the virtual MPI engine with the same T re-shards deterministically
    // (same partitioner, K·T, seed) and continues BIT-exactly. A
    // mismatched T is refused.
    let (ds, mut cfg) = setup();
    cfg.workers = 2;
    cfg.eval_every = 1;
    let fstar = oracle_objective(&ds, &cfg);
    let path = std::env::temp_dir().join("sparkbench_nested_ckpt_test.json");

    // Uninterrupted reference on threads:2:4.
    let reference = Session::builder(&ds)
        .engine(Engine::threads_nested(2, 4))
        .config(cfg.clone())
        .fixed_rounds(8)
        .oracle(fstar)
        .build()
        .unwrap()
        .run();
    let full = objective_bits(&reference);
    assert_eq!(full.len(), 8);

    // Interrupted: 4 rounds, checkpoint written by the observer.
    let first_half = Session::builder(&ds)
        .engine(Engine::threads_nested(2, 4))
        .config(cfg.clone())
        .fixed_rounds(4)
        .oracle(fstar)
        .observe(CheckpointEvery::new(4, &path))
        .build()
        .unwrap()
        .run();
    assert_eq!(objective_bits(&first_half), &full[..4]);

    let ckpt = Checkpoint::load(&path).unwrap();
    assert_eq!(ckpt.round, 4);
    assert_eq!(ckpt.workers, 2);
    assert_eq!(ckpt.threads_per_worker, 4);

    // Resume on the nested threads engine.
    let resumed_threads = Session::builder(&ds)
        .engine(Engine::threads_nested(2, 4))
        .config(cfg.clone())
        .fixed_rounds(4)
        .oracle(fstar)
        .resume_from(Checkpoint::load(&path).unwrap())
        .build()
        .unwrap()
        .run();
    assert_eq!(objective_bits(&resumed_threads), &full[4..]);

    // Resume the SAME checkpoint on the virtual MPI engine with the same
    // T — cross-substrate, bit-exact.
    let resumed_mpi = Session::builder(&ds)
        .engine(Impl::Mpi)
        .threads_per_worker(4)
        .config(cfg.clone())
        .fixed_rounds(4)
        .oracle(fstar)
        .resume_from(Checkpoint::load(&path).unwrap())
        .build()
        .unwrap()
        .run();
    assert_eq!(objective_bits(&resumed_mpi), &full[4..]);

    // Mismatched T: the sub-shard layout is part of the trajectory.
    let err = Session::builder(&ds)
        .engine(Engine::threads_nested(2, 2))
        .config(cfg)
        .fixed_rounds(1)
        .oracle(fstar)
        .resume_from(Checkpoint::load(&path).unwrap())
        .build()
        .err()
        .expect("resume with a different threads_per_worker must be refused");
    assert!(err.contains("threads-per-worker"), "{}", err);

    std::fs::remove_file(&path).ok();
}

#[test]
fn adaptive_policy_reproduces_controller_sequence_bit_for_bit() {
    // The session's Adaptive H policy must walk the exact H sequence the
    // old `tuner::train_adaptive` loop produced: h0 = cfg.h_for(mean),
    // then one controller observation per completed (non-final) round.
    let (ds, mut cfg) = setup();
    cfg.eval_every = 1;
    let fstar = oracle_objective(&ds, &cfg);
    let target_fraction = 0.8;
    let report = Session::builder(&ds)
        .engine(Impl::Mpi)
        .config(cfg.clone())
        .fixed_rounds(25)
        .oracle(fstar)
        .adaptive_h(target_fraction)
        .build()
        .unwrap()
        .run();
    assert_eq!(report.rounds, 25);
    assert_eq!(report.impl_name, "E:mpi+adaptiveH");

    // Replay the bare controller over the recorded timings.
    let n_locals = build_engine(Impl::Mpi, &ds, &cfg).n_locals();
    let mean_n_local =
        (n_locals.iter().sum::<usize>() as f64 / n_locals.len() as f64).round() as usize;
    let mut ctrl = AdaptiveH::new(cfg.h_for(mean_n_local), mean_n_local, target_fraction);
    let mut h = ctrl.h as usize;
    for log in &report.logs {
        assert_eq!(log.h, h, "round {} diverged from the controller", log.round);
        h = ctrl.observe(log.timing.t_worker, log.timing.t_overhead);
    }
    // The controller actually moved H (otherwise this test is vacuous).
    assert!(report.logs.iter().any(|l| l.h != report.logs[0].h));
}

#[test]
fn csv_trace_observer_matches_report_trace() {
    let (ds, mut cfg) = setup();
    cfg.eval_every = 2;
    let fstar = oracle_objective(&ds, &cfg);
    let path = std::env::temp_dir().join("sparkbench_session_trace_test.csv");
    let report = Session::builder(&ds)
        .engine(Impl::SparkC)
        .config(cfg)
        .fixed_rounds(6)
        .oracle(fstar)
        .observe(CsvTrace::create(&path).unwrap())
        .build()
        .unwrap()
        .run();
    let streamed = std::fs::read_to_string(&path).unwrap();
    // The streaming observer and the post-hoc report emit identical CSV.
    assert_eq!(streamed, report.trace_csv());
    assert_eq!(streamed.lines().count(), 1 + 6);
    std::fs::remove_file(&path).ok();
}
