//! Integration: framework substrates — byte accounting, overhead ordering,
//! layout effects, RDD semantics under engine use.

#![cfg(not(miri))] // interpreted execution is ~100x too slow for these end-to-end suites

use sparkbench::config::{Impl, TrainConfig};
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::data::Dataset;
use sparkbench::framework::{build_engine, build_engine_with, EngineOptions, LayoutOverride};
use sparkbench::session::Session;

fn mid_dataset() -> Dataset {
    // Large enough that per-byte/per-record costs dominate the τ-scaled
    // fixed costs — the regime the paper operates in.
    let mut spec = SyntheticSpec::small();
    spec.m = 512;
    spec.n = 4096;
    spec.avg_col_nnz = 48;
    webspam_like(&spec)
}

fn cfg_for(ds: &Dataset) -> TrainConfig {
    let mut cfg = TrainConfig::default_for(ds);
    cfg.workers = 4;
    cfg
}

fn overheads(ds: &Dataset, cfg: &TrainConfig, imp: Impl, rounds: usize) -> (f64, f64, u64, u64) {
    let rep = Session::builder(ds)
        .engine(imp)
        .config(cfg.clone())
        .fixed_rounds(rounds)
        .build()
        .expect("valid session")
        .run();
    let down: u64 = rep.logs.iter().map(|l| l.timing.bytes_down).sum();
    let up: u64 = rep.logs.iter().map(|l| l.timing.bytes_up).sum();
    (rep.total_overhead, rep.total_worker, down, up)
}

#[test]
fn overhead_ordering_matches_figure3() {
    let ds = mid_dataset();
    let cfg = cfg_for(&ds);
    let (ovh_e, _, _, _) = overheads(&ds, &cfg, Impl::Mpi, 20);
    let (ovh_b, _, _, _) = overheads(&ds, &cfg, Impl::SparkC, 20);
    let (ovh_a, _, _, _) = overheads(&ds, &cfg, Impl::SparkScala, 20);
    let (ovh_d, _, _, _) = overheads(&ds, &cfg, Impl::PySparkC, 20);
    assert!(ovh_e < ovh_b, "E {} !< B {}", ovh_e, ovh_b);
    assert!(ovh_b <= ovh_a, "B {} !<= A {}", ovh_b, ovh_a);
    assert!(
        ovh_d > 3.0 * ovh_b,
        "pySpark {} should far exceed Spark {}",
        ovh_d,
        ovh_b
    );
}

#[test]
fn persistent_memory_eliminates_alpha_traffic() {
    let ds = mid_dataset();
    let cfg = cfg_for(&ds);
    let (_, _, down_b, up_b) = overheads(&ds, &cfg, Impl::SparkC, 10);
    let (_, _, down_bs, up_bs) = overheads(&ds, &cfg, Impl::SparkCOpt, 10);
    // B ships v+α down and Δv+α up; B* only v/Δv. With n_local = 2·m the
    // α share is ~2/3 of traffic.
    assert!(
        (down_bs as f64) < 0.6 * down_b as f64,
        "B* down {} !≪ B down {}",
        down_bs,
        down_b
    );
    assert!((up_bs as f64) < 0.6 * up_b as f64);
}

#[test]
fn layout_ablation_flat_beats_records() {
    let ds = mid_dataset();
    let cfg = cfg_for(&ds);
    let run = |layout: LayoutOverride| -> f64 {
        let opts = EngineOptions {
            force_layout: Some(layout),
            ..Default::default()
        };
        Session::builder(&ds)
            .engine(Impl::SparkC)
            .options(opts)
            .config(cfg.clone())
            .fixed_rounds(10)
            .build()
            .expect("valid session")
            .run()
            .total_overhead
    };
    let flat = run(LayoutOverride::Flat);
    let records = run(LayoutOverride::Records);
    let meta = run(LayoutOverride::Meta);
    assert!(flat < records, "flat {} !< records {}", flat, records);
    assert!(meta <= flat, "meta {} !<= flat {}", meta, flat);
}

#[test]
fn engines_expose_consistent_topology() {
    let ds = mid_dataset();
    let cfg = cfg_for(&ds);
    for imp in Impl::ALL {
        let engine = build_engine(imp, &ds, &cfg);
        assert_eq!(engine.num_workers(), 4, "{}", imp.name());
        let n_locals = engine.n_locals();
        assert_eq!(n_locals.iter().sum::<usize>(), ds.n(), "{}", imp.name());
        assert_eq!(engine.alpha_global().len(), ds.n());
        assert_eq!(engine.clock(), 0.0);
    }
}

#[test]
fn timing_decomposition_is_complete() {
    // T_tot == T_worker + T_master + T_overhead per round, for every engine.
    let ds = mid_dataset();
    let cfg = cfg_for(&ds);
    for imp in [Impl::SparkScala, Impl::SparkC, Impl::PySpark, Impl::PySparkC, Impl::Mpi] {
        let mut engine = build_engine(imp, &ds, &cfg);
        let v = vec![0.0; ds.m()];
        let before = engine.clock();
        let (_, t) = engine.run_round(&v, 64, 1);
        let after = engine.clock();
        assert!(
            ((after - before) - t.wall()).abs() < 1e-12,
            "{}: clock delta {} != wall {}",
            imp.name(),
            after - before,
            t.wall()
        );
        assert!(t.t_worker > 0.0);
        assert!(t.t_overhead >= 0.0);
        assert_eq!(t.worker_compute.len(), 4);
    }
}

#[test]
fn real_managed_compute_matches_multiplier_numerics() {
    // The Figure 3 validation mode: genuinely interpreted solvers produce
    // the same Δv as the native+multiplier mode (math is identical).
    let ds = webspam_like(&SyntheticSpec::small());
    let cfg = cfg_for(&ds);
    let v = vec![0.0; ds.m()];
    let fast_opts = EngineOptions::default();
    let real_opts = EngineOptions {
        real_managed_compute: true,
        ..Default::default()
    };
    let mut fast = build_engine_with(Impl::SparkScala, &ds, &cfg, &fast_opts);
    let mut real = build_engine_with(Impl::SparkScala, &ds, &cfg, &real_opts);
    let (dv_fast, _) = fast.run_round(&v, 50, 7);
    let (dv_real, _) = real.run_round(&v, 50, 7);
    for (a, b) in dv_fast.iter().zip(dv_real.iter()) {
        assert!((a - b).abs() < 1e-10, "{} vs {}", a, b);
    }
}

#[test]
fn scaling_worker_counts() {
    // Engines work at every K the paper sweeps (Figure 8).
    let ds = mid_dataset();
    for k in [1usize, 2, 4, 8, 16] {
        let mut cfg = cfg_for(&ds);
        cfg.workers = k;
        let mut engine = build_engine(Impl::Mpi, &ds, &cfg);
        let v = vec![0.0; ds.m()];
        let (dv, _) = engine.run_round(&v, 32, 1);
        let alpha = engine.alpha_global();
        let want = ds.shared_vector(&alpha);
        for (a, b) in dv.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-9, "K={}", k);
        }
    }
}
