//! Integration: the nnz-adaptive sparse Δv layer must be numerically
//! invisible.
//!
//! Whether a round shipped sparse frames, dense frames or a mix (and
//! whichever engine ran it), the Δv and α trajectories must be
//! **bit-identical** — the representation is a communication decision,
//! never an arithmetic one (DESIGN.md §7). The byte accounting, by
//! contrast, must differ: that is the whole point of the layer.

#![cfg(not(miri))] // interpreted execution is ~100x too slow for these end-to-end suites

use sparkbench::config::{Impl, TrainConfig};
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::data::{Dataset, Partitioner, Partitioning};
use sparkbench::framework::{build_engine_with, threads::ThreadedMpiEngine, DistEngine, EngineOptions};
use sparkbench::linalg;

fn setup(k: usize) -> (Dataset, TrainConfig, Partitioning) {
    // Sparse-ish dataset: columns carry ~16 of 128 rows, so small-H
    // rounds produce Δv with nnz/m well under the cutover while large-H
    // rounds go dense — the trajectory crosses the cutover mid-run.
    let ds = webspam_like(&SyntheticSpec::small());
    let mut cfg = TrainConfig::default_for(&ds);
    cfg.workers = k;
    let parts = Partitioning::build(Partitioner::Range, &ds.a, k, 0);
    (ds, cfg, parts)
}

/// Drive `rounds` rounds of the same implementation with adaptive vs
/// forced-dense frames; assert bit-identical Δv and α trajectories and
/// that at least one round actually charged fewer bytes under the
/// adaptive path (i.e. the paths genuinely diverged in representation).
fn assert_frames_invisible(imp: Impl, h_schedule: &[usize]) {
    let (ds, cfg, _) = setup(4);
    let adaptive_opts = EngineOptions::default();
    let dense_opts = EngineOptions {
        dense_frames: true,
        ..Default::default()
    };
    let mut adaptive = build_engine_with(imp, &ds, &cfg, &adaptive_opts);
    let mut dense = build_engine_with(imp, &ds, &cfg, &dense_opts);
    let mut v1 = vec![0.0; ds.m()];
    let mut v2 = vec![0.0; ds.m()];
    let mut saw_savings = false;
    for (round, &h) in h_schedule.iter().enumerate() {
        let (dv1, t1) = adaptive.run_round(&v1, h, round as u64);
        let (dv2, t2) = dense.run_round(&v2, h, round as u64);
        assert_eq!(dv1.len(), dv2.len());
        for (i, (a, b)) in dv1.iter().zip(dv2.iter()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{:?} round {} dv[{}]: {} vs {} (must be BIT-identical)",
                imp,
                round,
                i,
                a,
                b
            );
        }
        assert!(
            t1.bytes_up <= t2.bytes_up,
            "{:?} round {}: adaptive charged MORE ({} > {})",
            imp,
            round,
            t1.bytes_up,
            t2.bytes_up
        );
        saw_savings |= t1.bytes_up < t2.bytes_up;
        linalg::add_assign(&mut v1, &dv1);
        linalg::add_assign(&mut v2, &dv2);
    }
    let a1 = adaptive.alpha_global();
    let a2 = dense.alpha_global();
    for (x, y) in a1.iter().zip(a2.iter()) {
        assert_eq!(x.to_bits(), y.to_bits(), "{:?}: alpha diverged", imp);
    }
    assert!(
        saw_savings,
        "{:?}: no round emitted a cheaper sparse frame — schedule never crossed the cutover",
        imp
    );
}

// The H schedule crosses the cutover both ways: sparse rounds (H=1..4),
// dense rounds (H=n_local-scale), then sparse again.
const H_MIXED: &[usize] = &[1, 2, 4, 64, 128, 2, 3];

#[test]
fn spark_frames_are_numerically_invisible() {
    assert_frames_invisible(Impl::SparkC, H_MIXED);
}

#[test]
fn spark_opt_frames_are_numerically_invisible() {
    assert_frames_invisible(Impl::SparkCOpt, H_MIXED);
}

#[test]
fn pyspark_frames_are_numerically_invisible() {
    assert_frames_invisible(Impl::PySparkC, H_MIXED);
}

#[test]
fn mpi_frames_are_numerically_invisible() {
    assert_frames_invisible(Impl::Mpi, H_MIXED);
}

#[test]
fn threaded_sparse_frames_match_virtual_dense_engine_bitwise() {
    // Cross-substrate AND cross-representation: the physically threaded
    // engine with sparse frames vs the virtual MPI engine forced dense.
    let (ds, cfg, parts) = setup(5);
    let mut threaded = ThreadedMpiEngine::new(&ds, &parts, &cfg);
    let dense_opts = EngineOptions {
        dense_frames: true,
        ..Default::default()
    };
    let mut virtual_dense = build_engine_with(Impl::Mpi, &ds, &cfg, &dense_opts);
    let mut v1 = vec![0.0; ds.m()];
    let mut v2 = vec![0.0; ds.m()];
    for (round, &h) in H_MIXED.iter().enumerate() {
        let (dv1, _) = threaded.run_round(&v1, h, round as u64);
        let (dv2, _) = virtual_dense.run_round(&v2, h, round as u64);
        for (a, b) in dv1.iter().zip(dv2.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "round {} diverged", round);
        }
        linalg::add_assign(&mut v1, &dv1);
        linalg::add_assign(&mut v2, &dv2);
    }
    let a1 = threaded.alpha_global();
    let a2 = virtual_dense.alpha_global();
    for (x, y) in a1.iter().zip(a2.iter()) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

#[test]
fn sparse_rounds_report_fewer_bytes_at_low_nnz() {
    // At tiny H the adaptive engines must charge a multiple fewer Δv
    // bytes than dense. The ≥5× bar at nnz/m ≤ 0.1 lives in the hotpath
    // bench at bench scale; at this 128-row test scale the per-frame
    // headers weigh more, so assert a conservative 2×.
    let (ds, cfg, _) = setup(4);
    for imp in [Impl::SparkCOpt, Impl::PySparkCOpt, Impl::Mpi] {
        let mut adaptive = build_engine_with(imp, &ds, &cfg, &EngineOptions::default());
        let mut dense = build_engine_with(
            imp,
            &ds,
            &cfg,
            &EngineOptions {
                dense_frames: true,
                ..Default::default()
            },
        );
        let v0 = vec![0.0; ds.m()];
        let (_, t1) = adaptive.run_round(&v0, 1, 1);
        let (_, t2) = dense.run_round(&v0, 1, 1);
        assert!(
            t1.bytes_up * 2 <= t2.bytes_up,
            "{:?}: sparse {} not ≥2× under dense {}",
            imp,
            t1.bytes_up,
            t2.bytes_up
        );
    }
}
