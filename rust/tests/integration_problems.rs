//! Integration: the first-class `Problem` API.
//!
//! * ridge stays BIT-identical to the pre-redesign hard-coded elastic-net
//!   path (the verbatim reference lives in `testkit::reference`);
//! * linear SVM trains end to end on every engine family with identical
//!   Δv/α trajectories and ≥ 95% accuracy, stopping on the duality-gap
//!   certificate with no CG oracle;
//! * `ToGap` stopping is consistent with `ToTarget` stopping on ridge.

#![cfg(not(miri))] // interpreted execution is ~100x too slow for these end-to-end suites

use sparkbench::config::TrainConfig;
use sparkbench::coordinator::oracle_objective;
use sparkbench::data::synthetic::{separable_classes, webspam_like, SyntheticSpec};
use sparkbench::data::{eval, Dataset, Partitioner, Partitioning, WorkerData};
use sparkbench::framework::{build_any, Engine, EngineOptions};
use sparkbench::linalg;
use sparkbench::problem::Problem;
use sparkbench::session::{Session, StopPolicy};
use sparkbench::solver::{scd::NativeScd, LocalSolver, SolveRequest};
// The ONE verbatim copy of the pre-problem hard-coded solver (shared with
// the hotpath bench so the reference can never silently fork).
use sparkbench::testkit::reference::PreRedesignElasticScd;

#[test]
fn squared_loss_is_bitwise_equal_to_the_pre_redesign_path() {
    // Fixture: multi-round, multi-worker solves over ridge, elastic and
    // lasso hyper-parameters — the full squared-loss family.
    let ds = webspam_like(&SyntheticSpec::small());
    let parts = Partitioning::build(Partitioner::BalancedNnz, &ds.a, 3, 0);
    let workers: Vec<WorkerData> = parts
        .parts
        .iter()
        .map(|cols| WorkerData::from_columns(&ds.a, cols))
        .collect();
    for (lam_n, eta) in [(12.8, 1.0), (3.0, 0.5), (60.0, 0.0)] {
        let problem = Problem::elastic(lam_n, eta);
        let mut old = PreRedesignElasticScd::default();
        let mut new = NativeScd::new();
        let mut alphas: Vec<Vec<f64>> = workers.iter().map(|w| vec![0.0; w.n_local()]).collect();
        let mut v = vec![0.0; ds.m()];
        for round in 0..6u64 {
            let mut agg = vec![0.0; ds.m()];
            for (w, wd) in workers.iter().enumerate() {
                let seed = round * 7919 + w as u64;
                let res_old =
                    old.solve(wd, &alphas[w], &v, &ds.b, wd.n_local(), lam_n, eta, 3.0, seed);
                let req = SolveRequest {
                    v: &v,
                    b: &ds.b,
                    h: wd.n_local(),
                    problem: &problem,
                    sigma: 3.0,
                    seed,
                };
                let res_new = new.solve(wd, &alphas[w], &req);
                assert_eq!(res_old.steps, res_new.steps);
                for (a, b) in res_old.delta_alpha.iter().zip(res_new.delta_alpha.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "Δα bits (λ={}, η={})", lam_n, eta);
                }
                for (a, b) in res_old.delta_v.iter().zip(res_new.delta_v.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "Δv bits (λ={}, η={})", lam_n, eta);
                }
                linalg::add_assign(&mut alphas[w], &res_new.delta_alpha);
                linalg::add_assign(&mut agg, &res_new.delta_v);
            }
            linalg::add_assign(&mut v, &agg);
        }
    }
}

// ---------------------------------------------------------------------------
// SVM end to end
// ---------------------------------------------------------------------------

fn svm_setup() -> (Dataset, Vec<f64>, TrainConfig) {
    let (ds, labels) = separable_classes(48, 192, 0.4, 11);
    let mut cfg = TrainConfig::default_for(&ds);
    cfg.workers = 4;
    cfg.problem = Problem::svm(1.0);
    cfg.max_rounds = 4000;
    (ds, labels, cfg)
}

#[test]
fn svm_converges_on_every_engine_family_with_identical_trajectories() {
    // The acceptance bar: `.problem(Problem::svm(lam)).stop(ToGap(1e-4))`
    // converges on a synthetic separable dataset on EVERY engine family,
    // with identical Δv/α trajectories across engines and ≥ 95% accuracy.
    let (ds, labels, cfg) = svm_setup();
    let mut trajectories: Vec<(String, Vec<u64>, Vec<u64>)> = Vec::new();
    for engine in Engine::FAMILIES {
        let mut eng = build_any(engine, &ds, &cfg, &EngineOptions::default());
        let report = Session::builder(&ds)
            .config(cfg.clone())
            .attach(eng.as_mut())
            .stop(StopPolicy::ToGap { gap: 1e-4 })
            .build()
            .unwrap()
            .run();
        assert!(
            report.time_to_target.is_some(),
            "{} never met the gap target (last gap {:?} after {} rounds)",
            engine.label(),
            report.logs.last().and_then(|l| l.gap),
            report.rounds
        );
        // Gap column populated at every evaluated round.
        assert!(report.logs.iter().all(|l| l.gap.is_some()));

        let alpha = eng.alpha_global();
        // Box feasibility of the trained dual.
        let c = cfg.problem.reg.box_c();
        assert!(
            alpha.iter().all(|&a| (0.0..=c + 1e-12).contains(&a)),
            "{}: dual iterate escaped the box",
            engine.label()
        );
        // Downstream accuracy from the (scaled) primal w = v = Aα.
        let v = ds.shared_vector(&alpha);
        let qv = ds.a.matvec_t(&v);
        let pred: Vec<f64> = qv.iter().zip(labels.iter()).map(|(&t, &y)| t * y).collect();
        let acc = eval::accuracy(&pred, &labels);
        assert!(acc >= 0.95, "{}: accuracy {}", engine.label(), acc);

        let objs: Vec<u64> = report
            .logs
            .iter()
            .filter_map(|l| l.objective)
            .map(f64::to_bits)
            .collect();
        let alpha_bits: Vec<u64> = alpha.iter().map(|a| a.to_bits()).collect();
        trajectories.push((engine.label(), objs, alpha_bits));
    }
    let (ref_label, ref_objs, ref_alpha) = &trajectories[0];
    for (label, objs, alpha) in &trajectories[1..] {
        assert_eq!(objs, ref_objs, "{} objective bits diverged from {}", label, ref_label);
        assert_eq!(alpha, ref_alpha, "{} α bits diverged from {}", label, ref_label);
    }
}

#[test]
fn logistic_trains_to_gap_and_classifies() {
    let (ds, labels) = separable_classes(32, 128, 0.5, 23);
    let mut cfg = TrainConfig::default_for(&ds);
    cfg.workers = 4;
    cfg.max_rounds = 3000;
    cfg.problem = Problem::logistic(1.0);
    let mut eng = build_any(
        Engine::Impl(sparkbench::config::Impl::Mpi),
        &ds,
        &cfg,
        &EngineOptions::default(),
    );
    let report = Session::builder(&ds)
        .config(cfg)
        .attach(eng.as_mut())
        .stop(StopPolicy::ToGap { gap: 1e-3 })
        .build()
        .unwrap()
        .run();
    assert!(
        report.time_to_target.is_some(),
        "logistic session missed the gap target: {:?}",
        report.logs.last().and_then(|l| l.gap)
    );
    let alpha = eng.alpha_global();
    let v = ds.shared_vector(&alpha);
    let qv = ds.a.matvec_t(&v);
    let pred: Vec<f64> = qv.iter().zip(labels.iter()).map(|(&t, &y)| t * y).collect();
    assert!(eval::accuracy(&pred, &labels) >= 0.9);
}

// ---------------------------------------------------------------------------
// Gap certificate vs the CG oracle on ridge
// ---------------------------------------------------------------------------

fn ridge_setup() -> (Dataset, TrainConfig) {
    let ds = webspam_like(&SyntheticSpec::small());
    let mut cfg = TrainConfig::default_for(&ds);
    cfg.workers = 4;
    cfg.max_rounds = 6000; // gap 1e-4 is a tighter bar than subopt 1e-3
    (ds, cfg)
}

#[test]
fn ridge_gap_vanishes_at_the_cg_optimum() {
    let (ds, cfg) = ridge_setup();
    let p = cfg.problem;
    let (alpha_star, fstar) =
        sparkbench::solver::cg::ridge_optimum(&ds, p.reg.lam_n, 1e-12, 50_000);
    let v = ds.shared_vector(&alpha_star);
    let gap = p.duality_gap(&ds, &v, &alpha_star);
    let scale = 1.0 + fstar.abs();
    assert!(gap >= -1e-9 * scale, "gap {} below numeric zero", gap);
    assert!(gap <= 1e-6 * scale, "gap {} did not vanish at α*", gap);
}

#[test]
fn to_gap_and_to_target_stop_within_one_round_of_each_other_on_ridge() {
    // Stop a ridge session on the certificate; then ask the oracle-based
    // policy to stop at the suboptimality the certificate-stopped run
    // actually reached. The round counts must agree within ±1 — the
    // certificate is a faithful, tight stand-in for the CG oracle.
    let (ds, mut cfg) = ridge_setup();
    cfg.target_subopt = 0.0; // never trigger the default target
    let fstar = oracle_objective(&ds, &cfg);

    let gap_run = Session::builder(&ds)
        .config(cfg.clone())
        .oracle(fstar) // also track suboptimality for the handoff below
        .stop(StopPolicy::ToGap { gap: 1e-4 })
        .build()
        .unwrap()
        .run();
    assert!(gap_run.time_to_target.is_some(), "gap target never met");
    let rounds_gap = gap_run.rounds;
    let sub_at_stop = gap_run.final_suboptimality.unwrap();
    assert!(sub_at_stop >= 0.0);

    let target_run = Session::builder(&ds)
        .config(cfg)
        .oracle(fstar)
        .stop(StopPolicy::ToTarget {
            subopt: sub_at_stop * (1.0 + 1e-12),
        })
        .build()
        .unwrap()
        .run();
    assert!(target_run.time_to_target.is_some());
    let rounds_target = target_run.rounds;
    let diff = rounds_gap as i64 - rounds_target as i64;
    assert!(
        diff.abs() <= 1,
        "ToGap stopped after {} rounds, ToTarget after {}",
        rounds_gap,
        rounds_target
    );
}

#[test]
fn gap_upper_bounds_suboptimality_along_a_trajectory() {
    let (ds, mut cfg) = ridge_setup();
    cfg.max_rounds = 12;
    cfg.target_subopt = 0.0;
    let fstar = oracle_objective(&ds, &cfg);
    let report = Session::builder(&ds)
        .config(cfg)
        .oracle(fstar)
        .track_gap()
        .build()
        .unwrap()
        .run();
    assert_eq!(report.rounds, 12);
    for l in &report.logs {
        let f = l.objective.unwrap();
        let gap_abs = l.gap.unwrap() * f.abs().max(1.0);
        assert!(
            gap_abs + 1e-9 * (1.0 + f.abs()) >= f - fstar,
            "round {}: gap {} < f − f* = {}",
            l.round,
            gap_abs,
            f - fstar
        );
    }
}

// ---------------------------------------------------------------------------
// Checkpointing carries the problem
// ---------------------------------------------------------------------------

#[test]
fn svm_checkpoint_resumes_bit_exactly_and_refuses_ridge() {
    use sparkbench::coordinator::checkpoint::Checkpoint;
    use sparkbench::session::CheckpointEvery;

    let (ds, _labels, cfg) = svm_setup();
    let path = std::env::temp_dir().join("sparkbench_problems_svm_ckpt.json");

    // Uninterrupted 8-round reference.
    let full = Session::builder(&ds)
        .config(cfg.clone())
        .fixed_rounds(8)
        .track_gap()
        .build()
        .unwrap()
        .run();

    // 4 rounds with a checkpoint, then resume for the remaining 4.
    let _ = Session::builder(&ds)
        .config(cfg.clone())
        .fixed_rounds(4)
        .observe(CheckpointEvery::new(4, &path))
        .build()
        .unwrap()
        .run();
    let ckpt = Checkpoint::load(&path).unwrap();
    assert_eq!(ckpt.problem, Problem::svm(1.0));
    assert_eq!(ckpt.round, 4);

    // A ridge config must refuse the SVM envelope.
    let mut ridge_cfg = cfg.clone();
    ridge_cfg.problem = Problem::ridge(1.0);
    let err = Session::builder(&ds)
        .config(ridge_cfg)
        .resume_from(ckpt.clone())
        .fixed_rounds(4)
        .build()
        .err()
        .expect("problem mismatch must be rejected");
    assert!(err.contains("problem mismatch"), "{}", err);

    // Resuming with the right problem continues the exact trajectory.
    let resumed = Session::builder(&ds)
        .config(cfg)
        .resume_from(ckpt)
        .fixed_rounds(4)
        .track_gap()
        .build()
        .unwrap()
        .run();
    let full_tail: Vec<u64> = full.logs[4..]
        .iter()
        .filter_map(|l| l.objective)
        .map(f64::to_bits)
        .collect();
    let resumed_objs: Vec<u64> = resumed
        .logs
        .iter()
        .filter_map(|l| l.objective)
        .map(f64::to_bits)
        .collect();
    assert_eq!(resumed_objs, full_tail, "resumed SVM trajectory diverged");
    std::fs::remove_file(&path).ok();
}
