//! Integration: the PJRT runtime against the real AOT artifact.
//!
//! Requires `make artifacts` (the Makefile `test` target guarantees it)
//! and a build with the off-by-default `pjrt` feature — without it this
//! test crate compiles to nothing.
//!
//! These tests prove the L1 Pallas kernel ≡ L3 native solver equivalence
//! across the actual serialized HLO boundary — the end-to-end correctness
//! claim of the three-layer architecture.

#![cfg(not(miri))] // interpreted execution is ~100x too slow for these end-to-end suites
#![cfg(feature = "pjrt")]

use std::path::PathBuf;
use std::sync::Arc;

use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::data::WorkerData;
use sparkbench::problem::Problem;
use sparkbench::runtime::{Manifest, PjrtRuntime};
use sparkbench::solver::{pjrt::PjrtScd, scd::NativeScd, LocalSolver, SolveRequest};

fn artifacts_dir() -> PathBuf {
    std::env::var("SPARKBENCH_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            // cargo test runs from the workspace root
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

fn load() -> (Manifest, Arc<sparkbench::runtime::LocalSolveExec>) {
    let man = Manifest::load(&artifacts_dir())
        .expect("artifacts missing — run `make artifacts` before `cargo test`");
    let rt = PjrtRuntime::cpu().expect("pjrt cpu client");
    let exec = rt.load_local_solve(&man).expect("compile artifact");
    (man, Arc::new(exec))
}

fn problem(man: &Manifest, nk: usize, seed: u64) -> (sparkbench::data::Dataset, WorkerData) {
    let mut spec = SyntheticSpec::pjrt_default();
    spec.m = man.m;
    spec.n = nk.max(8);
    spec.seed = seed;
    let ds = webspam_like(&spec);
    let cols: Vec<u32> = (0..nk as u32).collect();
    let wd = WorkerData::from_columns(&ds.a, &cols);
    (ds, wd)
}

#[test]
fn artifact_loads_and_matches_manifest() {
    let (man, exec) = load();
    assert!(man.m > 0 && man.nk > 0 && man.h_max > 0);
    assert_eq!(exec.manifest.m, man.m);
}

#[test]
fn pjrt_matches_native_full_width() {
    let (man, exec) = load();
    let (ds, wd) = problem(&man, man.nk, 3);
    let alpha = vec![0.0; wd.n_local()];
    let v = vec![0.0; ds.m()];
    let problem = Problem::ridge(25.0);
    let req = SolveRequest {
        v: &v,
        b: &ds.b,
        h: 200.min(man.h_max),
        problem: &problem,
        sigma: 4.0,
        seed: 11,
    };
    let rp = PjrtScd::new(exec).solve(&wd, &alpha, &req);
    let rn = NativeScd::new().solve(&wd, &alpha, &req);
    for (a, b) in rp.delta_alpha.iter().zip(rn.delta_alpha.iter()) {
        assert!((a - b).abs() < 1e-3, "{} vs {} (f32 tolerance)", a, b);
    }
    for (a, b) in rp.delta_v.iter().zip(rn.delta_v.iter()) {
        assert!((a - b).abs() < 1e-2, "{} vs {}", a, b);
    }
}

#[test]
fn pjrt_handles_padded_partition() {
    // Partition narrower than the compiled nk → zero-column padding path.
    let (man, exec) = load();
    let (ds, wd) = problem(&man, man.nk / 3, 5);
    let alpha = vec![0.0; wd.n_local()];
    let v = vec![0.0; ds.m()];
    let problem = Problem::elastic(10.0, 0.8); // elastic net through the artifact's runtime scalars
    let req = SolveRequest {
        v: &v,
        b: &ds.b,
        h: 100.min(man.h_max),
        problem: &problem,
        sigma: 2.0,
        seed: 17,
    };
    let mut solver = PjrtScd::new(exec);
    assert!(solver.fits(&wd));
    let rp = solver.solve(&wd, &alpha, &req);
    let rn = NativeScd::new().solve(&wd, &alpha, &req);
    assert_eq!(rp.delta_alpha.len(), wd.n_local());
    assert_eq!(rp.delta_v.len(), ds.m());
    for (a, b) in rp.delta_alpha.iter().zip(rn.delta_alpha.iter()) {
        assert!((a - b).abs() < 1e-3, "{} vs {}", a, b);
    }
}

#[test]
fn pjrt_h_zero_is_noop() {
    let (man, exec) = load();
    let (ds, wd) = problem(&man, man.nk / 4, 7);
    let alpha = vec![0.1; wd.n_local()];
    let v = ds.shared_vector(&{
        let mut full = vec![0.0; ds.n()];
        full[..wd.n_local()].copy_from_slice(&alpha);
        full
    });
    let problem = Problem::ridge(1.0);
    let req = SolveRequest {
        v: &v,
        b: &ds.b,
        h: 0,
        problem: &problem,
        sigma: 1.0,
        seed: 0,
    };
    let rp = PjrtScd::new(exec).solve(&wd, &alpha, &req);
    assert!(rp.delta_alpha.iter().all(|&x| x == 0.0));
    assert!(rp.delta_v.iter().all(|&x| x == 0.0));
}

#[test]
fn pjrt_multi_round_training_descends() {
    // Several CoCoA rounds purely through the artifact: objective must
    // decrease monotonically (within f32 noise).
    let (man, exec) = load();
    let (ds, wd) = problem(&man, man.nk, 9);
    let problem = Problem::ridge(0.05 * ds.n() as f64);
    let mut alpha = vec![0.0; wd.n_local()];
    let mut v = vec![0.0; ds.m()];
    let mut solver = PjrtScd::new(exec);
    let mut alpha_full = vec![0.0; ds.n()];
    let mut prev = problem.primal(&ds, &alpha_full);
    for round in 0..5 {
        let req = SolveRequest {
            v: &v,
            b: &ds.b,
            h: wd.n_local().min(man.h_max),
            problem: &problem,
            sigma: 1.0,
            seed: round,
        };
        let res = solver.solve(&wd, &alpha, &req);
        for (a, d) in alpha.iter_mut().zip(res.delta_alpha.iter()) {
            *a += d;
        }
        for (vi, d) in v.iter_mut().zip(res.delta_v.iter()) {
            *vi += d;
        }
        for (slot, &a) in alpha_full.iter_mut().zip(alpha.iter()) {
            *slot = a;
        }
        let cur = problem.primal(&ds, &alpha_full);
        assert!(cur <= prev * (1.0 + 1e-4), "round {}: {} -> {}", round, prev, cur);
        prev = cur;
    }
}

#[test]
fn rejects_oversized_partition() {
    let (man, exec) = load();
    let mut spec = SyntheticSpec::pjrt_default();
    spec.m = man.m;
    spec.n = man.nk + 8;
    let ds = webspam_like(&spec);
    let cols: Vec<u32> = (0..(man.nk + 8) as u32).collect();
    let wd = WorkerData::from_columns(&ds.a, &cols);
    let solver = PjrtScd::new(exec);
    assert!(!solver.fits(&wd));
}
