//! Property-based tests over the system invariants (testkit driver;
//! proptest is unavailable offline — DESIGN.md).

#![cfg(not(miri))] // interpreted execution is ~100x too slow for these end-to-end suites

use sparkbench::config::{Impl, TrainConfig};
use sparkbench::data::sparse::CscMatrix;
use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
use sparkbench::data::{Partitioner, Partitioning, WorkerData};
use sparkbench::framework::build_engine;
use sparkbench::framework::serialization::{JavaSer, PickleSer};
use sparkbench::linalg;
use sparkbench::problem::Problem;
use sparkbench::solver::{
    check_result, minibatch_cd::MiniBatchCd, scd::NativeScd, sgd::MiniBatchSgd, LocalSolver,
    SolveRequest,
};
use sparkbench::testkit::{check, Gen};

fn random_dataset(g: &mut Gen) -> sparkbench::data::Dataset {
    let spec = SyntheticSpec {
        m: g.usize_in(8, 96),
        n: g.usize_in(8, 192),
        avg_col_nnz: g.usize_in(2, 12),
        powerlaw_s: g.f64_in(1.05, 1.8),
        model_density: g.f64_in(0.1, 0.9),
        noise: g.f64_in(0.0, 0.2),
        seed: g.seed(),
    };
    webspam_like(&spec)
}

#[test]
fn prop_delta_v_always_equals_a_delta_alpha() {
    check("delta_v == A·Δα for every solver", 40, |g| {
        let ds = random_dataset(g);
        let k = g.usize_in(1, 5);
        let parts = Partitioning::build(
            *g.pick(&[Partitioner::Range, Partitioner::RoundRobin, Partitioner::BalancedNnz]),
            &ds.a,
            k,
            g.seed(),
        );
        let w = g.usize_in(0, k);
        let wd = WorkerData::from_columns(&ds.a, &parts.parts[w]);
        let alpha: Vec<f64> = g.gaussian_vec(wd.n_local());
        let alpha_scaled: Vec<f64> = alpha.iter().map(|a| a * 0.1).collect();
        let mut full = vec![0.0; ds.n()];
        for (&gid, &a) in wd.global_ids.iter().zip(alpha_scaled.iter()) {
            full[gid as usize] = a;
        }
        let v = ds.shared_vector(&full);
        // Any problem family: Δv = A·Δα is a structural invariant of the
        // round protocol, independent of which loss took the steps.
        let problem = match g.usize_in(0, 4) {
            0 => Problem::elastic(g.f64_in(0.01, 20.0), g.f64_in(0.0, 1.0)),
            1 => Problem::svm(g.f64_in(0.1, 10.0)),
            _ => Problem::logistic(g.f64_in(0.1, 10.0)),
        };
        let req = SolveRequest {
            v: &v,
            b: &ds.b,
            h: g.usize_in(0, 80),
            problem: &problem,
            sigma: g.f64_in(0.5, 8.0),
            seed: g.seed(),
        };
        let mut solver: Box<dyn LocalSolver> = match g.usize_in(0, 3) {
            0 => Box::new(NativeScd::new()),
            1 => Box::new(MiniBatchCd::new()),
            _ => Box::new(MiniBatchSgd::new(g.f64_in(0.01, 1.0), g.f64_in(0.1, 1.0))),
        };
        let res = solver.solve(&wd, &alpha_scaled, &req);
        check_result(&wd, &res, 1e-7).map_err(|e| format!("{}: {}", solver.name(), e))
    });
}

#[test]
fn prop_partitioning_is_exact_cover() {
    check("partitioning covers all columns exactly once", 60, |g| {
        let n = g.usize_in(1, 500);
        let m = g.usize_in(1, 50);
        let a = CscMatrix::zeros(m, n);
        let k = g.usize_in(1, 17);
        let p = *g.pick(&[
            Partitioner::Range,
            Partitioner::RoundRobin,
            Partitioner::BalancedNnz,
            Partitioner::Random,
        ]);
        Partitioning::build(p, &a, k, g.seed()).validate(n)
    });
}

#[test]
fn prop_codecs_roundtrip() {
    check("serialization codecs round-trip", 60, |g| {
        let len = g.usize_in(0, 3000);
        let v = g.gaussian_vec(len);
        let j = JavaSer::decode(&JavaSer::encode(&v)).map_err(|e| e.to_string())?;
        if j != v {
            return Err("java mismatch".into());
        }
        let p = PickleSer::decode(&PickleSer::encode(&v)).map_err(|e| e.to_string())?;
        if p != v {
            return Err("pickle mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_objective_never_increases_under_cocoa_rounds() {
    check("CoCoA round monotonically decreases objective", 20, |g| {
        let ds = random_dataset(g);
        let k = g.usize_in(1, 5);
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = k;
        cfg.problem = Problem::ridge(g.f64_in(0.1, 5.0) * ds.n() as f64 * 0.01);
        let mut engine = build_engine(Impl::Mpi, &ds, &cfg);
        let mut v = vec![0.0; ds.m()];
        let mut prev = cfg.problem.primal(&ds, &engine.alpha_global());
        for round in 0..6 {
            let h = g.usize_in(1, 64);
            let (dv, _) = engine.run_round(&v, h, round);
            linalg::add_assign(&mut v, &dv);
            let cur = cfg.problem.primal(&ds, &engine.alpha_global());
            if cur > prev + 1e-7 * (1.0 + prev.abs()) {
                return Err(format!("round {}: {} -> {}", round, prev, cur));
            }
            prev = cur;
        }
        Ok(())
    });
}

#[test]
fn prop_duality_gap_is_a_nonnegative_certificate() {
    // DESIGN.md §9: gap(α) = f(α) + g*(u) + Σφ*(−(Aᵀu)_j) ≥ 0 for EVERY α
    // and every problem family — the property that makes it a stopping
    // certificate rather than a heuristic.
    check("duality gap >= 0 for every family and any α", 40, |g| {
        let ds = random_dataset(g);
        let problem = match g.usize_in(0, 4) {
            0 => Problem::elastic(g.f64_in(0.05, 10.0), g.f64_in(0.0, 1.0)),
            1 => Problem::lasso(g.f64_in(0.05, 10.0)),
            2 => Problem::svm(g.f64_in(0.1, 10.0)),
            _ => Problem::logistic(g.f64_in(0.1, 10.0)),
        };
        // Feasible α for the family: anything for squared, box-clamped
        // for the duals ((0, C) strictly for logistic's entropy).
        let c = problem.reg.box_c();
        let alpha: Vec<f64> = (0..ds.n())
            .map(|_| match problem.loss {
                sparkbench::problem::LossKind::Squared => g.f64_in(-1.0, 1.0),
                sparkbench::problem::LossKind::Hinge => g.f64_in(0.0, 1.0) * c,
                sparkbench::problem::LossKind::Logistic => g.f64_in(0.01, 0.99) * c,
            })
            .collect();
        let v = ds.shared_vector(&alpha);
        let gap = problem.duality_gap(&ds, &v, &alpha);
        if !gap.is_finite() {
            return Err(format!("{}: gap not finite: {}", problem.kind_name(), gap));
        }
        if gap < 0.0 {
            return Err(format!("{}: negative gap {}", problem.kind_name(), gap));
        }
        Ok(())
    });
}

#[test]
fn prop_engines_agree_numerically() {
    check("all engines produce identical Δv given a seed", 12, |g| {
        let ds = random_dataset(g);
        let k = g.usize_in(2, 5);
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = k;
        let v = vec![0.0; ds.m()];
        let h = g.usize_in(1, 50);
        let seed = g.seed();
        let mut reference: Option<Vec<f64>> = None;
        for imp in [Impl::Mpi, Impl::SparkC, Impl::SparkCOpt, Impl::PySpark, Impl::PySparkCOpt] {
            let mut engine = build_engine(imp, &ds, &cfg);
            let (dv, _) = engine.run_round(&v, h, seed);
            match &reference {
                None => reference = Some(dv),
                Some(r) => {
                    for (a, b) in dv.iter().zip(r.iter()) {
                        if (a - b).abs() > 1e-10 {
                            return Err(format!("{} diverged: {} vs {}", imp.name(), a, b));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_csc_matvec_matches_dense() {
    check("CSC matvec == dense matvec", 40, |g| {
        let m = g.usize_in(1, 40);
        let n = g.usize_in(1, 40);
        let mut triplets = Vec::new();
        for _ in 0..g.usize_in(0, 200) {
            triplets.push((g.usize_in(0, m), g.usize_in(0, n), g.f64_in(-2.0, 2.0)));
        }
        let a = CscMatrix::from_triplets(m, n, &triplets);
        a.validate()?;
        let x = g.gaussian_vec(n);
        let sparse = a.matvec(&x);
        let dense = sparkbench::data::dense::DenseMatrix::from_csc(&a).matvec(&x);
        for (s, d) in sparse.iter().zip(dense.iter()) {
            if (s - d).abs() > 1e-9 {
                return Err(format!("{} vs {}", s, d));
            }
        }
        // And Aᵀy
        let y = g.gaussian_vec(m);
        let at = a.matvec_t(&y);
        for (j, atj) in at.iter().enumerate() {
            let (ri, vs) = a.col(j);
            let want = linalg::dot_indexed(ri, vs, &y);
            if (atj - want).abs() > 1e-9 {
                return Err(format!("matvec_t col {}", j));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_libsvm_roundtrip() {
    check("libsvm text round-trips datasets", 20, |g| {
        let ds = random_dataset(g);
        let text = sparkbench::data::libsvm::to_libsvm_string(&ds);
        let back = sparkbench::data::libsvm::parse_libsvm(&text, Some(ds.n()))
            .map_err(|e| e.to_string())?;
        if back.m() != ds.m() || back.a.nnz() != ds.nnz() {
            return Err(format!(
                "shape changed: {}x{} nnz {} -> {}x{} nnz {}",
                ds.m(),
                ds.n(),
                ds.nnz(),
                back.m(),
                back.n(),
                back.a.nnz()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    use sparkbench::util::json::Json;
    check("json writer/parser round-trip", 40, |g| {
        // build a random nested value
        fn rand_json(g: &mut Gen, depth: usize) -> Json {
            match if depth > 2 { g.usize_in(0, 4) } else { g.usize_in(0, 6) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
                3 => Json::Str(format!("s{}-µ✓", g.usize_in(0, 1000))),
                4 => Json::Num(g.usize_in(0, 100000) as f64),
                5 => Json::Arr((0..g.usize_in(0, 5)).map(|_| rand_json(g, depth + 1)).collect()),
                _ => {
                    let mut o = Json::obj();
                    for i in 0..g.usize_in(0, 5) {
                        o.set(&format!("k{}", i), rand_json(g, depth + 1));
                    }
                    o
                }
            }
        }
        let j = rand_json(g, 0);
        let s = j.pretty();
        let back = Json::parse(&s).map_err(|e| e.to_string())?;
        if back != j {
            return Err(format!("mismatch:\n{}\nvs\n{}", s, back.pretty()));
        }
        Ok(())
    });
}

#[test]
fn prop_worker_data_preserves_columns() {
    check("WorkerData slices match the global matrix", 30, |g| {
        let ds = random_dataset(g);
        let k = g.usize_in(1, 6);
        let parts = Partitioning::build(Partitioner::Random, &ds.a, k, g.seed());
        for (w, cols) in parts.parts.iter().enumerate() {
            let wd = WorkerData::from_columns(&ds.a, cols);
            wd.flat.validate()?;
            for (j, &gid) in wd.global_ids.iter().enumerate() {
                let (ri_l, vs_l) = wd.flat.col(j);
                let (ri_g, vs_g) = ds.a.col(gid as usize);
                if ri_l != ri_g || vs_l != vs_g {
                    return Err(format!("worker {} col {} mismatch", w, j));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_codec_roundtrip_bit_identical() {
    // Randomized sparse Δv frames must round-trip bit-identically through
    // both codecs (DESIGN.md §7), and the delta-varint index coding must
    // preserve the strictly-increasing duplicate-free invariant.
    check("sparse frames round-trip bit-identically", 60, |g| {
        let dim = g.usize_in(1, 5000);
        let density = g.f64_in(0.0, 1.0);
        let mut sv = linalg::SparseVec::new(dim);
        for i in 0..dim {
            if g.f64_in(0.0, 1.0) < density {
                sv.idx.push(i as u32);
                // Mix magnitudes, signs, subnormals and specials.
                let x = match g.usize_in(0, 5) {
                    0 => g.f64_in(-1e3, 1e3),
                    1 => g.f64_in(-1.0, 1.0) * 1e-300,
                    2 => g.f64_in(-1.0, 1.0) * 1e300,
                    3 => f64::INFINITY,
                    _ => g.f64_in(-1.0, 1.0),
                };
                sv.vals.push(x);
            }
        }
        sv.validate()?;

        let mut jb = Vec::new();
        JavaSer::encode_sparse_into(&sv, &mut jb);
        let jback = JavaSer::decode_sparse_slice(&jb).map_err(|e| format!("java: {}", e))?;
        jback.validate()?;
        let mut pb = Vec::new();
        PickleSer::encode_sparse_into(&sv, &mut pb);
        let pback = PickleSer::decode_sparse_slice(&pb).map_err(|e| format!("pickle: {}", e))?;
        pback.validate()?;
        for back in [&jback, &pback] {
            if back.dim != sv.dim || back.idx != sv.idx {
                return Err("structure mismatch".into());
            }
            for (a, b) in back.vals.iter().zip(sv.vals.iter()) {
                if a.to_bits() != b.to_bits() {
                    return Err(format!("value bits {} vs {}", a, b));
                }
            }
        }
        Ok(())
    });
}

/// Zipfian column-mass dataset (chaos layer, DESIGN.md §12): almost all
/// of the nnz sits in the first few columns. Ranges are sized so the
/// heaviest single column (≤ m nnz) stays small against the per-worker
/// mean (≈ n·16/k nnz) — that is what makes the greedy-LPT balance bound
/// below provable rather than probabilistic.
fn zipf_dataset(g: &mut Gen) -> sparkbench::data::Dataset {
    let spec = SyntheticSpec {
        m: g.usize_in(32, 65),
        n: g.usize_in(256, 513),
        avg_col_nnz: 16,
        powerlaw_s: g.f64_in(1.3, 1.7),
        model_density: g.f64_in(0.1, 0.9),
        noise: g.f64_in(0.0, 0.2),
        seed: g.seed(),
    };
    sparkbench::data::synthetic::zipf_columns(&spec)
}

#[test]
fn prop_skewed_zipf_partitioning_is_still_an_exact_cover() {
    // Chaos satellite: however adversarial the column-mass distribution
    // and however deliberately imbalanced the partitioner, every column
    // is assigned to exactly one shard — skew breaks balance, never
    // correctness.
    check("zipf data + every partitioner = exact cover", 20, |g| {
        let ds = zipf_dataset(g);
        let k = g.usize_in(1, 9);
        for p in [
            Partitioner::Range,
            Partitioner::RoundRobin,
            Partitioner::BalancedNnz,
            Partitioner::Random,
            Partitioner::Skewed,
        ] {
            Partitioning::build(p, &ds.a, k, g.seed())
                .validate(ds.n())
                .map_err(|e| format!("{:?}: {}", p, e))?;
        }
        Ok(())
    });
}

#[test]
fn prop_balanced_nnz_bounds_the_shard_ratio_where_range_blows_up() {
    // On Zipfian mass the contiguous Range split hands the heavy head
    // columns to worker 0 and near-empty tails to the last worker, so its
    // max/min shard-nnz ratio explodes. Greedy LPT (`BalancedNnz`) keeps
    // max−min within one column's nnz (≤ m), which the generator sizes
    // well under the per-worker mean — the mitigation the chaos skew
    // experiments measure against.
    check("balanced-nnz bounds shard ratio; range does not", 20, |g| {
        let ds = zipf_dataset(g);
        let k = g.usize_in(2, 6);
        let ratio = |p: Partitioner| -> Result<f64, String> {
            let loads = Partitioning::build(p, &ds.a, k, 7).loads(&ds.a);
            let max = *loads.iter().max().unwrap() as f64;
            let min = *loads.iter().min().unwrap() as f64;
            if min == 0.0 {
                return Err(format!("{:?}: empty shard", p));
            }
            Ok(max / min)
        };
        let balanced = ratio(Partitioner::BalancedNnz)?;
        let range = ratio(Partitioner::Range)?;
        if balanced > 1.5 {
            return Err(format!("balanced-nnz ratio {} > 1.5", balanced));
        }
        if range <= 2.0 * balanced {
            return Err(format!(
                "range ratio {} did not blow up vs balanced {}",
                range, balanced
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_nested_ring_is_bit_identical_to_flat_on_skewed_shards() {
    // DESIGN.md §10's nested ≡ flat identity must survive the chaos
    // layer's worst-case layout: Zipfian data under the deliberately
    // imbalanced Skewed partitioner. K workers × T sub-solvers and a flat
    // K·T ring share the partitioning, σ′ and per-shard seeds, so the
    // round's Δv agrees to the bit.
    check("nested K×T == flat K·T on skewed zipf shards", 8, |g| {
        let ds = zipf_dataset(g);
        let k = g.usize_in(2, 5);
        let t = g.usize_in(2, 5);
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.partitioner = Partitioner::Skewed;
        cfg.seed = g.seed();

        cfg.workers = k;
        let mut opts = sparkbench::framework::EngineOptions::default();
        opts.threads_per_worker = t;
        let mut nested = sparkbench::framework::build_engine_with(Impl::Mpi, &ds, &cfg, &opts);

        cfg.workers = k * t;
        let mut flat = build_engine(Impl::Mpi, &ds, &cfg);

        let v = vec![0.0; ds.m()];
        let h = g.usize_in(1, 40);
        let seed = g.seed();
        let (dv_n, _) = nested.run_round(&v, h, seed);
        let (dv_f, _) = flat.run_round(&v, h, seed);
        for (i, (a, b)) in dv_n.iter().zip(dv_f.iter()).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!("dv[{}]: {} vs {} (k={}, t={})", i, a, b, k, t));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_csr_csc_roundtrip_is_bit_exact() {
    use sparkbench::data::CsrMatrix;
    // The serving mirror (DESIGN.md §13): CSC→CSR→CSC must reproduce the
    // exact storage — same pointers, same indices, same value BITS — for
    // random triplet matrices and the degenerate shapes the request arena
    // meets (all-zero, single-nnz, fully dense). Both conversions are
    // counting sorts that only move values, never combine them.
    check("CSR<->CSC round-trips bit-exactly", 40, |g| {
        let m = g.usize_in(1, 40);
        let n = g.usize_in(1, 40);
        let a = match g.usize_in(0, 10) {
            // Empty: every row and column has zero nnz.
            0 => CscMatrix::zeros(m, n),
            // Single nnz in a random cell.
            1 => CscMatrix::from_triplets(
                m,
                n,
                &[(g.usize_in(0, m), g.usize_in(0, n), g.f64_in(-3.0, 3.0))],
            ),
            // Dense block: every cell occupied.
            2 => {
                let mut t = Vec::with_capacity(m * n);
                for r in 0..m {
                    for c in 0..n {
                        t.push((r, c, g.f64_in(-2.0, 2.0)));
                    }
                }
                CscMatrix::from_triplets(m, n, &t)
            }
            // Random sparsity, including subnormal/huge magnitudes so a
            // value-mangling conversion cannot hide behind tolerance.
            _ => {
                let mut t = Vec::new();
                for _ in 0..g.usize_in(0, 250) {
                    let v = match g.usize_in(0, 4) {
                        0 => g.f64_in(-1.0, 1.0) * 1e-300,
                        1 => g.f64_in(-1.0, 1.0) * 1e300,
                        _ => g.f64_in(-5.0, 5.0),
                    };
                    t.push((g.usize_in(0, m), g.usize_in(0, n), v));
                }
                CscMatrix::from_triplets(m, n, &t)
            }
        };
        a.validate()?;
        let csr = CsrMatrix::from_csc(&a);
        csr.validate()?;
        if csr.nnz() != a.nnz() {
            return Err(format!("nnz changed: {} -> {}", a.nnz(), csr.nnz()));
        }
        let back = csr.to_csc();
        back.validate()?;
        if back.m != a.m || back.n != a.n || back.col_ptr != a.col_ptr || back.row_idx != a.row_idx
        {
            return Err("round-trip changed the structure".into());
        }
        for (x, y) in back.vals.iter().zip(a.vals.iter()) {
            if x.to_bits() != y.to_bits() {
                return Err(format!("round-trip changed value bits: {} vs {}", x, y));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_csr_transpose_rows_are_csc_columns() {
    use sparkbench::data::CsrMatrix;
    // transpose_of is a pure relabel of the CSC buffers, so row i of Aᵀ
    // must alias column i of A exactly, and a per-row dot against y must
    // reproduce `a.matvec_t(&y)` to the bit — the identity that makes
    // dual-family serving bit-consistent with training-side quantities.
    check("CSR transpose rows == CSC columns (bitwise)", 40, |g| {
        let ds = random_dataset(g);
        let t = CsrMatrix::transpose_of(&ds.a);
        if t.m != ds.n() || t.n != ds.m() {
            return Err(format!("transpose shape {}x{}", t.m, t.n));
        }
        t.validate()?;
        for j in 0..ds.n() {
            let (ri, vs) = ds.a.col(j);
            let (ci, ws) = t.row(j);
            if ri != ci {
                return Err(format!("index mismatch in col {}", j));
            }
            for (x, y) in vs.iter().zip(ws.iter()) {
                if x.to_bits() != y.to_bits() {
                    return Err(format!("value bits differ in col {}", j));
                }
            }
        }
        let y = g.gaussian_vec(ds.m());
        let want = ds.a.matvec_t(&y);
        let got = t.matvec(&y);
        for (i, (a, b)) in got.iter().zip(want.iter()).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!("row {} dot differs: {} vs {}", i, a, b));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_delta_reducer_matches_dense_tree_bitwise() {
    // Random worker deltas at random densities and a random cutover must
    // reduce to the exact bits of the all-dense pairwise tree, through
    // sparse merges, mixed pairs and dense promotions alike.
    check("sparse-aware reduce == dense tree (bitwise)", 40, |g| {
        let m = g.usize_in(1, 300);
        let k = g.usize_in(1, 9);
        let cutover = g.usize_in(0, m + 1);
        let deltas: Vec<Vec<f64>> = (0..k)
            .map(|_| {
                let density = g.f64_in(0.0, 0.6);
                (0..m)
                    .map(|_| {
                        if g.f64_in(0.0, 1.0) < density {
                            g.f64_in(-5.0, 5.0)
                        } else {
                            0.0
                        }
                    })
                    .collect()
            })
            .collect();
        let mut dense_bufs = deltas.clone();
        let want = linalg::tree_reduce_collect(dense_bufs.iter_mut());

        let mut red = linalg::DeltaReducer::new(m, cutover);
        let mut slots: Vec<linalg::DeltaSlot> =
            (0..k).map(|_| linalg::DeltaSlot::new()).collect();
        for (slot, d) in slots.iter_mut().zip(deltas.iter()) {
            red.load(slot, d);
        }
        let got = red.reduce_collect(&mut slots);
        if got.len() != want.len() {
            return Err("length mismatch".into());
        }
        for (i, (a, b)) in got.iter().zip(want.iter()).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!("[{}] {} vs {} (cutover {})", i, a, b, cutover));
            }
        }
        Ok(())
    });
}
