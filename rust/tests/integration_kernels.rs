//! Integration: kernel-backend bit-identity (DESIGN.md §11).
//!
//! The tentpole invariant of the SIMD work: the dispatched backend (AVX2
//! under `--features simd`, scalar otherwise) is a pure speed change —
//! full training trajectories are **bit-identical** with the vector units
//! on or off. This binary holds the ONE test that toggles the global
//! `force_scalar` switch, so the toggle is never raced by a parallel test
//! thread. Under a default (non-simd) build the switch is a no-op and the
//! test degenerates to a determinism pin — it must pass in every cell of
//! the CI feature matrix.

#![cfg(not(miri))] // interpreted execution is ~100x too slow for these end-to-end suites

use sparkbench::config::{Impl, TrainConfig};
use sparkbench::data::synthetic::{separable_classes, webspam_like, SyntheticSpec};
use sparkbench::framework::{build_any, DistEngine, Engine, EngineOptions};
use sparkbench::linalg::{self, kernels};
use sparkbench::problem::Problem;
use sparkbench::session::{Session, StopPolicy};

/// Drive an engine manually and collect the bit patterns of every round's
/// Δv plus the final α and shared vector.
fn trajectory(
    eng: &mut Box<dyn DistEngine>,
    m: usize,
    rounds: usize,
    h: usize,
) -> (Vec<Vec<u64>>, Vec<u64>, Vec<u64>) {
    let mut v = vec![0.0; m];
    let mut dvs = Vec::new();
    for round in 0..rounds {
        let (dv, _) = eng.run_round(&v, h, round as u64);
        dvs.push(dv.iter().map(|x| x.to_bits()).collect());
        linalg::add_assign(&mut v, &dv);
    }
    let alpha = eng.alpha_global().iter().map(|x| x.to_bits()).collect();
    let vbits = v.iter().map(|x| x.to_bits()).collect();
    (dvs, alpha, vbits)
}

#[test]
fn backend_switch_never_changes_a_single_bit() {
    // --- engine level: ridge, 20 rounds, two engine families ------------
    // Δv every round + final α + final v, all compared by bits.
    let ds = webspam_like(&SyntheticSpec::small());
    for engine in [Engine::Impl(Impl::Mpi), Engine::threads(3)] {
        let mut run = |forced: bool| {
            kernels::force_scalar(forced);
            let mut cfg = TrainConfig::default_for(&ds);
            cfg.workers = 3;
            let mut eng = build_any(engine, &ds, &cfg, &EngineOptions::default());
            let out = trajectory(&mut eng, ds.m(), 20, 16);
            kernels::force_scalar(false);
            out
        };
        let scalar = run(true);
        let dispatched = run(false);
        assert_eq!(
            scalar,
            dispatched,
            "ridge trajectory diverged between backends on {} [{}]",
            engine.label(),
            kernels::backend()
        );
    }

    // --- session level: hinge dual to the gap certificate ----------------
    // The certificate path exercises the matvec gap evaluation on top of
    // the SCD hot pair; identical backends ⇒ identical round count, gap
    // column and final objective, bit for bit.
    let (cds, _) = separable_classes(24, 96, 0.4, 5);
    let mut run_svm = |forced: bool| {
        kernels::force_scalar(forced);
        let mut cfg = TrainConfig::default_for(&cds);
        cfg.workers = 3;
        cfg.max_rounds = 4000;
        let report = Session::builder(&cds)
            .engine(Impl::Mpi)
            .config(cfg)
            .problem(Problem::svm(1.0))
            .stop(StopPolicy::ToGap { gap: 1e-3 })
            .build()
            .unwrap()
            .run();
        kernels::force_scalar(false);
        let gaps: Vec<u64> = report
            .logs
            .iter()
            .filter_map(|l| l.gap)
            .map(f64::to_bits)
            .collect();
        (report.rounds, gaps, report.final_objective.map(f64::to_bits))
    };
    let scalar = run_svm(true);
    let dispatched = run_svm(false);
    assert!(scalar.0 > 0 && !scalar.1.is_empty(), "svm session did no work");
    assert_eq!(
        scalar,
        dispatched,
        "hinge session diverged between backends [{}]",
        kernels::backend()
    );
}
