//! First-class optimization problems: loss family × regularizer, with
//! duality-gap certificates (DESIGN.md §9).
//!
//! The paper's closing result applies the Spark/MPI optimizations to three
//! distributed linear ML workloads — ridge regression, lasso and linear
//! SVM. All of them are instances of the box-constrained composite
//! objective this module makes explicit:
//!
//! ```text
//! min over α ∈ R^n     f(α) = g(Aα) + Σ_j φ_j(α_j),    g(v) = ½‖v − b‖²
//! ```
//!
//! * **Squared loss** ([`SquaredLoss`]) — φ is the elastic-net regularizer
//!   `λn(η/2·α² + (1−η)|α|)`: ridge at η = 1, lasso at η = 0. This is the
//!   objective the whole pre-problem codebase hard-wired; the math here is
//!   the *identical* expression sequence, so ridge/lasso trajectories are
//!   bit-for-bit unchanged (asserted by `tests/integration_problems.rs`).
//! * **Hinge dual** ([`HingeDual`]) — linear SVM via its box-constrained
//!   dual: columns are label-scaled datapoints `q_j = y_j·x_j`, φ_j(a) =
//!   −a on the box `[0, C]`, `C = 1/λn`, and `v = Aα` is the (scaled)
//!   primal weight vector.
//! * **Logistic dual** ([`LogisticDual`]) — logistic regression via the
//!   entropic dual, φ_j(a) = a·ln a + (C−a)·ln(C−a) on `(0, C)`; the
//!   per-coordinate update is a guarded 1-D Newton iteration
//!   (allocation-free, deterministic).
//!
//! Every loss supplies three pieces through the [`Loss`] trait: the
//! per-coordinate closed-form/prox **step** the SCD hot loop dispatches
//! (monomorphized — the solvers `match` on [`LossKind`] once per solve, so
//! the inner loop pays no dynamic dispatch and performs no allocation),
//! the **primal value** terms, and the Fenchel **conjugate** that powers
//! the duality-gap certificate:
//!
//! ```text
//! gap(α) = f(α) + g*(u) + Σ_j φ_j*(−(Aᵀu)_j) ≥ 0,   u = v − b
//! ```
//!
//! which vanishes at the optimum and upper-bounds `f(α) − f*` for any α —
//! so training can stop on a certificate ([`StopPolicy::ToGap`]) without a
//! conjugate-gradient oracle, which non-quadratic problems do not have.
//!
//! [`StopPolicy::ToGap`]: crate::session::StopPolicy::ToGap

use crate::data::Dataset;
use crate::linalg;
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Regularizer
// ---------------------------------------------------------------------------

/// Elastic-net regularizer parameters: effective strength λ·n and mix η
/// (1 = pure L2/ridge, 0 = pure L1/lasso). For the dual losses the same
/// `lam_n` knob sets the box `C = 1/λn` and η is inert.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Regularizer {
    /// Effective regularizer λ·n (DESIGN.md §5).
    pub lam_n: f64,
    /// Elastic-net mix η ∈ [0, 1].
    pub eta: f64,
}

impl Regularizer {
    /// Pure L2 (ridge).
    pub fn l2(lam_n: f64) -> Regularizer {
        Regularizer { lam_n, eta: 1.0 }
    }

    /// Pure L1 (lasso).
    pub fn l1(lam_n: f64) -> Regularizer {
        Regularizer { lam_n, eta: 0.0 }
    }

    /// Elastic-net mix.
    pub fn elastic(lam_n: f64, eta: f64) -> Regularizer {
        Regularizer { lam_n, eta }
    }

    /// `r(α) = λn(η/2‖α‖² + (1−η)‖α‖₁)` — textually the exact expression
    /// the pre-problem `Dataset::objective` evaluated, so squared-loss
    /// objectives stay bit-identical.
    pub fn value(&self, alpha: &[f64]) -> f64 {
        self.lam_n
            * (0.5 * self.eta * linalg::nrm2_sq(alpha) + (1.0 - self.eta) * linalg::nrm1(alpha))
    }

    /// Box constraint `C = 1/λn` used by the dual losses.
    pub fn box_c(&self) -> f64 {
        1.0 / self.lam_n
    }
}

// ---------------------------------------------------------------------------
// Loss trait + the three shipped losses
// ---------------------------------------------------------------------------

/// One loss family: the per-coordinate SCD update, the per-coordinate
/// objective term Σφ_j, and the Fenchel conjugate for the gap certificate.
///
/// Hot paths do **not** call through `dyn Loss`: the solvers match on
/// [`LossKind`] once per solve and call the concrete `step` inside a
/// monomorphized loop. The trait exists so cold paths (objective, gap)
/// stay uniform and so new losses implement one surface.
pub trait Loss {
    fn name(&self) -> &'static str;

    /// New value of coordinate j minimizing the CoCoA local subproblem
    /// `½σ′‖c_j‖²(a−α_j)² + (a−α_j)·c_jᵀr + φ_j(a)` where `r = v − b` is
    /// the solver-maintained residual. `None` skips degenerate coordinates
    /// (the draw still consumes one of the round's H iterations, exactly
    /// like the pre-problem `denom ≤ 0` skip).
    fn step(&self, reg: &Regularizer, sigma: f64, aj: f64, csq: f64, cj_r: f64) -> Option<f64>;

    /// `Σ_j φ_j(α_j)` — everything in f(α) beyond the smooth `½‖v − b‖²`.
    fn phi_sum(&self, reg: &Regularizer, alpha: &[f64]) -> f64;

    /// `φ*(−t)` for one coordinate — the gap certificate term at
    /// `t = (Aᵀu)_j` (DESIGN.md §9 derivations).
    fn phi_conj_neg(&self, reg: &Regularizer, t: f64) -> f64;
}

/// Squared loss + elastic net — the paper's original workload family.
#[derive(Debug, Clone, Copy, Default)]
pub struct SquaredLoss;

impl Loss for SquaredLoss {
    fn name(&self) -> &'static str {
        "squared"
    }

    #[inline]
    fn step(&self, reg: &Regularizer, sigma: f64, aj: f64, csq: f64, cj_r: f64) -> Option<f64> {
        // Bit-identical to the pre-problem hard-coded SCD update:
        //   α̃⁺ = (σ‖c_j‖²·α_j − c_jᵀr) / (σ‖c_j‖² + λnη)
        //   α⁺  = soft_threshold(α̃⁺, λn(1−η) / (σ‖c_j‖² + λnη))
        let lam_eta = reg.lam_n * reg.eta;
        let denom = sigma * csq + lam_eta;
        if denom <= 0.0 {
            return None;
        }
        let tau_num = reg.lam_n * (1.0 - reg.eta);
        let atilde = (sigma * csq * aj - cj_r) / denom;
        Some(linalg::soft_threshold(atilde, tau_num / denom))
    }

    #[inline]
    fn phi_sum(&self, reg: &Regularizer, alpha: &[f64]) -> f64 {
        reg.value(alpha)
    }

    #[inline]
    fn phi_conj_neg(&self, reg: &Regularizer, t: f64) -> f64 {
        // φ(a) = λnη/2·a² + λn(1−η)|a|  ⇒  φ*(s) = ((|s| − λn(1−η))₊)²/(2λnη).
        // φ is symmetric, so φ*(−t) = φ*(t). At η = 0 the conjugate is the
        // indicator of |s| ≤ λn; `duality_gap` scales u into that ball
        // first, so the term is 0 there.
        let excess = (t.abs() - reg.lam_n * (1.0 - reg.eta)).max(0.0);
        if reg.eta > 0.0 {
            excess * excess / (2.0 * reg.lam_n * reg.eta)
        } else {
            0.0
        }
    }
}

/// Linear-SVM dual: box-constrained coordinate ascent (SDCA). Columns must
/// be label-scaled datapoints `q_j = y_j·x_j`, labels ±1, `b = 0`.
#[derive(Debug, Clone, Copy, Default)]
pub struct HingeDual;

impl Loss for HingeDual {
    fn name(&self) -> &'static str {
        "hinge-dual"
    }

    #[inline]
    fn step(&self, reg: &Regularizer, sigma: f64, aj: f64, csq: f64, cj_r: f64) -> Option<f64> {
        // ∂/∂a [½σcsq(a−α_j)² + (a−α_j)c_jᵀr − a] = 0
        //   ⇒ a = α_j + (1 − c_jᵀr)/(σcsq), clipped to the box — exact for
        // a 1-D quadratic, so no step size is needed (SDCA's hinge update).
        let denom = sigma * csq;
        if denom <= 0.0 {
            return None;
        }
        let a = aj + (1.0 - cj_r) / denom;
        Some(a.clamp(0.0, reg.box_c()))
    }

    #[inline]
    fn phi_sum(&self, _reg: &Regularizer, alpha: &[f64]) -> f64 {
        // φ_j(a) = −a on [0, C]; engines maintain the box invariant.
        // Sequential accumulation — certificate sums are replayed bit-for-bit.
        let mut acc = 0.0;
        for &a in alpha {
            acc += a;
        }
        -acc
    }

    #[inline]
    fn phi_conj_neg(&self, reg: &Regularizer, t: f64) -> f64 {
        // φ*(−t) = C·max(0, 1 − t): the hinge loss of margin t, weighted by
        // the box — the primal partner P(w) = ½‖w‖² + C·Σ hinge(1 − q_jᵀw).
        reg.box_c() * (1.0 - t).max(0.0)
    }
}

/// Logistic-regression dual: entropic per-coordinate term, guarded 1-D
/// Newton update (no closed form). Same data layout as [`HingeDual`].
#[derive(Debug, Clone, Copy, Default)]
pub struct LogisticDual;

/// `x·ln x` with the continuous extension 0 at x = 0.
#[inline]
fn xlnx(x: f64) -> f64 {
    if x <= 0.0 {
        0.0
    } else {
        x * x.ln()
    }
}

/// Numerically stable `ln(1 + eˣ)`.
#[inline]
fn softplus(x: f64) -> f64 {
    if x > 30.0 {
        x
    } else {
        x.exp().ln_1p()
    }
}

impl Loss for LogisticDual {
    fn name(&self) -> &'static str {
        "logistic-dual"
    }

    #[inline]
    fn step(&self, reg: &Regularizer, sigma: f64, aj: f64, csq: f64, cj_r: f64) -> Option<f64> {
        // Minimize q(a) = ½σcsq(a−α_j)² + (a−α_j)c_jᵀr + a·ln a + (C−a)·ln(C−a)
        // on (0, C): q′ is strictly increasing, so a projected Newton
        // iteration converges; all state is scalar (allocation-free) and
        // the float sequence is deterministic, so every engine produces
        // the identical update.
        let denom = sigma * csq;
        if denom <= 0.0 {
            return None;
        }
        let c = reg.box_c();
        let lo = c * 1e-12;
        let hi = c - lo;
        let mut a = aj.clamp(lo, hi);
        for _ in 0..20 {
            let g = denom * (a - aj) + cj_r + (a / (c - a)).ln();
            let h = denom + c / (a * (c - a));
            let next = (a - g / h).clamp(lo, hi);
            let moved = (next - a).abs();
            a = next;
            if moved <= 1e-15 * c {
                break;
            }
        }
        Some(a)
    }

    #[inline]
    fn phi_sum(&self, reg: &Regularizer, alpha: &[f64]) -> f64 {
        let c = reg.box_c();
        // Sequential accumulation — certificate sums are replayed bit-for-bit.
        let mut acc = 0.0;
        for &a in alpha {
            acc += xlnx(a) + xlnx(c - a);
        }
        acc
    }

    #[inline]
    fn phi_conj_neg(&self, reg: &Regularizer, t: f64) -> f64 {
        // φ*(s) = C·ln(1+eˢ) − C·ln C  ⇒  φ*(−t) = C·softplus(−t) − C·ln C
        // (the constant keeps the certificate exact: gap → 0 at optimum).
        let c = reg.box_c();
        c * softplus(-t) - c * c.ln()
    }
}

// ---------------------------------------------------------------------------
// Problem
// ---------------------------------------------------------------------------

/// Reusable evaluation buffers for repeated duality-gap certificates:
/// `u = v − b` and `Aᵀu`. A tracking session owns one and threads it
/// through [`Problem::duality_gap_scratch`], so steady-state evaluations
/// perform zero heap allocations (the buffers reach capacity on the first
/// eval and are reused).
#[derive(Debug, Default)]
pub struct GapScratch {
    u: Vec<f64>,
    at_u: Vec<f64>,
}

/// Which loss family a [`Problem`] trains — the solvers' one-per-solve
/// dispatch key (and the checkpoint-envelope tag).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LossKind {
    /// [`SquaredLoss`]: ridge / lasso / elastic net.
    Squared,
    /// [`HingeDual`]: linear SVM.
    Hinge,
    /// [`LogisticDual`]: logistic regression.
    Logistic,
}

/// A trainable problem: a [`LossKind`] composed with a [`Regularizer`].
/// Small and `Copy` — it travels by value into engine constructors and
/// worker threads, and by reference inside [`SolveRequest`]s.
///
/// [`SolveRequest`]: crate::solver::SolveRequest
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Problem {
    pub loss: LossKind,
    pub reg: Regularizer,
}

impl Problem {
    /// Ridge regression (squared loss, pure L2).
    pub fn ridge(lam_n: f64) -> Problem {
        Problem {
            loss: LossKind::Squared,
            reg: Regularizer::l2(lam_n),
        }
    }

    /// Lasso (squared loss, pure L1).
    pub fn lasso(lam_n: f64) -> Problem {
        Problem {
            loss: LossKind::Squared,
            reg: Regularizer::l1(lam_n),
        }
    }

    /// Elastic net (squared loss, mixed penalty).
    pub fn elastic(lam_n: f64, eta: f64) -> Problem {
        Problem {
            loss: LossKind::Squared,
            reg: Regularizer::elastic(lam_n, eta),
        }
    }

    /// Linear SVM via the hinge dual; box `C = 1/λn`. Data columns must be
    /// label-scaled datapoints (see `data::synthetic::separable_classes`).
    pub fn svm(lam_n: f64) -> Problem {
        Problem {
            loss: LossKind::Hinge,
            reg: Regularizer::l2(lam_n),
        }
    }

    /// Logistic regression via the entropic dual; box `C = 1/λn`.
    pub fn logistic(lam_n: f64) -> Problem {
        Problem {
            loss: LossKind::Logistic,
            reg: Regularizer::l2(lam_n),
        }
    }

    /// Same problem at a different regularization strength.
    pub fn with_lam_n(mut self, lam_n: f64) -> Problem {
        self.reg.lam_n = lam_n;
        self
    }

    /// The loss implementation, for uniform cold-path dispatch.
    pub fn loss_impl(&self) -> &'static dyn Loss {
        match self.loss {
            LossKind::Squared => &SquaredLoss,
            LossKind::Hinge => &HingeDual,
            LossKind::Logistic => &LogisticDual,
        }
    }

    /// Short family name ("ridge" / "lasso" / "elastic" / "svm" / "logistic").
    pub fn kind_name(&self) -> &'static str {
        match self.loss {
            LossKind::Squared => {
                if self.reg.eta == 1.0 {
                    "ridge"
                } else if self.reg.eta == 0.0 {
                    "lasso"
                } else {
                    "elastic"
                }
            }
            LossKind::Hinge => "svm",
            LossKind::Logistic => "logistic",
        }
    }

    /// Human-readable label for logs and CLI banners.
    pub fn label(&self) -> String {
        match self.loss {
            LossKind::Squared if self.reg.eta > 0.0 && self.reg.eta < 1.0 => {
                format!("elastic(η={},λn={:.3})", self.reg.eta, self.reg.lam_n)
            }
            _ => format!("{}(λn={:.3})", self.kind_name(), self.reg.lam_n),
        }
    }

    /// Parse a CLI problem spec: `ridge | lasso | elastic:<eta> | svm |
    /// logistic` (λ·n supplied separately — it is the `--lambda-n` knob).
    pub fn parse(spec: &str, lam_n: f64) -> Result<Problem, String> {
        let lower = spec.to_ascii_lowercase();
        let (head, arg) = match lower.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (lower.as_str(), None),
        };
        match (head, arg) {
            ("ridge" | "l2", None) => Ok(Problem::ridge(lam_n)),
            ("lasso" | "l1", None) => Ok(Problem::lasso(lam_n)),
            ("elastic" | "elastic-net" | "en", Some(eta)) => eta
                .parse()
                .map(|e| Problem::elastic(lam_n, e))
                .map_err(|_| format!("bad elastic mix '{}' (want elastic:<eta>)", eta)),
            ("elastic" | "elastic-net" | "en", None) => {
                Err("elastic needs a mix: elastic:<eta>".into())
            }
            ("svm" | "hinge", None) => Ok(Problem::svm(lam_n)),
            ("logistic" | "logreg", None) => Ok(Problem::logistic(lam_n)),
            _ => Err(format!(
                "unknown problem '{}' (try: ridge, lasso, elastic:<eta>, svm, logistic)",
                spec
            )),
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.reg.lam_n <= 0.0 {
            return Err("lam_n must be > 0".into());
        }
        if self.loss == LossKind::Squared && !(0.0..=1.0).contains(&self.reg.eta) {
            return Err(format!("eta {} outside [0,1]", self.reg.eta));
        }
        Ok(())
    }

    /// Check that a dataset is in the layout this problem trains. The dual
    /// losses (SVM, logistic) require the dual layout — columns are
    /// label-scaled datapoints `q_j = y_j·x_j` and `b = 0` — otherwise the
    /// run would quietly optimize a well-defined but meaningless objective
    /// against regression targets (see
    /// `data::synthetic::separable_classes` and DESIGN.md §9). O(m).
    pub fn check_dataset(&self, ds: &Dataset) -> Result<(), String> {
        match self.loss {
            LossKind::Squared => Ok(()),
            LossKind::Hinge | LossKind::Logistic => {
                if ds.b.iter().any(|&x| x != 0.0) {
                    Err(format!(
                        "{} trains the dual layout: columns must be label-scaled datapoints \
                         (q_j = y_j·x_j) and b must be all-zero, but '{}' has nonzero b — \
                         load/generate the classification layout (e.g. \
                         data::synthetic::separable_classes, or libsvm + normalize_labels_pm1 \
                         folded into the columns)",
                        self.kind_name(),
                        ds.name
                    ))
                } else {
                    Ok(())
                }
            }
        }
    }

    /// Objective `f(α) = ½‖v − b‖² + Σ_j φ_j(α_j)` from an already-
    /// maintained shared vector `v = Aα` — O(m + n), the per-round
    /// trajectory number. For [`LossKind::Squared`] this is bit-identical
    /// to the pre-problem `Dataset::objective_given_v`.
    pub fn primal_given_v(&self, v: &[f64], alpha: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(v.len(), b.len());
        let mut loss = 0.0;
        for (vi, bi) in v.iter().zip(b.iter()) {
            let r = vi - bi;
            loss += r * r;
        }
        0.5 * loss + self.loss_impl().phi_sum(&self.reg, alpha)
    }

    /// Objective via the O(nnz) matvec (no maintained v at hand).
    pub fn primal(&self, ds: &Dataset, alpha: &[f64]) -> f64 {
        let v = ds.a.matvec(alpha);
        self.primal_given_v(&v, alpha, &ds.b)
    }

    /// Duality-gap certificate (module docs; DESIGN.md §9):
    /// `gap(α) = f(α) + g*(u) + Σ_j φ_j*(−(Aᵀu)_j)` with `u = v − b`, and
    /// for pure lasso (η = 0) u additionally scaled into the dual-feasible
    /// ball `‖Aᵀu‖∞ ≤ λn`. Nonnegative for every α, zero exactly at the
    /// optimum, and an upper bound on `f(α) − f*` — the oracle-free
    /// stopping certificate. O(nnz + m + n) per evaluation.
    pub fn duality_gap(&self, ds: &Dataset, v: &[f64], alpha: &[f64]) -> f64 {
        let f = self.primal_given_v(v, alpha, &ds.b);
        self.duality_gap_given_primal(ds, v, alpha, f)
    }

    /// [`duality_gap`](Problem::duality_gap) with the primal value `f(α)`
    /// already in hand — the session loop evaluates the objective every
    /// round anyway, so the certificate should not recompute it. One-shot
    /// form; repeated evaluators (the session loop) go through
    /// [`duality_gap_scratch`](Problem::duality_gap_scratch).
    pub fn duality_gap_given_primal(&self, ds: &Dataset, v: &[f64], alpha: &[f64], f: f64) -> f64 {
        let mut scratch = GapScratch::default();
        self.duality_gap_scratch(ds, v, alpha, f, &mut scratch)
    }

    /// The certificate through caller-owned scratch: `u` and `Aᵀu` land in
    /// the [`GapScratch`] buffers (via [`CscMatrix::matvec_t_into`]), so a
    /// tracking session's per-eval `Vec` allocations disappear — after the
    /// first evaluation the certificate is allocation-free (asserted by
    /// the counting-allocator test below and the hotpath bench). Values
    /// are bit-identical to the one-shot form.
    ///
    /// [`CscMatrix::matvec_t_into`]: crate::data::CscMatrix::matvec_t_into
    pub fn duality_gap_scratch(
        &self,
        ds: &Dataset,
        v: &[f64],
        alpha: &[f64],
        f: f64,
        scratch: &mut GapScratch,
    ) -> f64 {
        debug_assert_eq!(alpha.len(), ds.n());
        let b = &ds.b;
        debug_assert_eq!(v.len(), b.len());
        scratch.u.clear();
        scratch
            .u
            .extend(v.iter().zip(b.iter()).map(|(&vi, &bi)| vi - bi));
        ds.a.matvec_t_into(&scratch.u, &mut scratch.at_u);
        let (u, at_u) = (&mut scratch.u, &mut scratch.at_u);
        if self.loss == LossKind::Squared && self.reg.eta == 0.0 {
            // Lasso: φ* is the indicator of |s| ≤ λn; the standard residual
            // rescaling keeps the certificate finite and tight.
            let inf = at_u.iter().fold(0.0f64, |m, &x| m.max(x.abs()));
            if inf > self.reg.lam_n {
                let s = self.reg.lam_n / inf;
                for x in u.iter_mut() {
                    *x *= s;
                }
                for x in at_u.iter_mut() {
                    *x *= s;
                }
            }
        }
        let gstar = 0.5 * linalg::nrm2_sq(u) + linalg::dot(b, u);
        let l = self.loss_impl();
        let mut conj = 0.0;
        for &t in at_u.iter() {
            conj += l.phi_conj_neg(&self.reg, t);
        }
        f + gstar + conj
    }

    /// Checkpoint-envelope encoding (versioned by the checkpoint format).
    pub fn to_json(&self) -> Json {
        let kind = match self.loss {
            LossKind::Squared => "squared",
            LossKind::Hinge => "hinge",
            LossKind::Logistic => "logistic",
        };
        let mut j = Json::obj();
        j.set("loss", kind)
            .set("lam_n", self.reg.lam_n)
            .set("eta", self.reg.eta);
        j
    }

    pub fn from_json(j: &Json) -> Result<Problem, String> {
        let loss = match j.get("loss").and_then(|v| v.as_str()) {
            Some("squared") => LossKind::Squared,
            Some("hinge") => LossKind::Hinge,
            Some("logistic") => LossKind::Logistic,
            Some(other) => return Err(format!("unknown problem loss '{}'", other)),
            None => return Err("missing problem loss".into()),
        };
        let num = |k: &str| -> Result<f64, String> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .ok_or(format!("missing problem {}", k))
        };
        Ok(Problem {
            loss,
            reg: Regularizer {
                lam_n: num("lam_n")?,
                eta: num("eta")?,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{dense_gaussian, separable_classes, webspam_like, SyntheticSpec};

    #[test]
    fn constructors_and_names() {
        assert_eq!(Problem::ridge(2.0).kind_name(), "ridge");
        assert_eq!(Problem::lasso(2.0).kind_name(), "lasso");
        assert_eq!(Problem::elastic(2.0, 0.5).kind_name(), "elastic");
        assert_eq!(Problem::svm(2.0).kind_name(), "svm");
        assert_eq!(Problem::logistic(2.0).kind_name(), "logistic");
        assert_eq!(Problem::svm(2.0).reg.box_c(), 0.5);
        assert!(Problem::ridge(1.0).label().contains("ridge"));
    }

    #[test]
    fn parse_covers_cli_specs() {
        assert_eq!(Problem::parse("ridge", 2.0).unwrap(), Problem::ridge(2.0));
        assert_eq!(Problem::parse("lasso", 2.0).unwrap(), Problem::lasso(2.0));
        assert_eq!(
            Problem::parse("elastic:0.3", 2.0).unwrap(),
            Problem::elastic(2.0, 0.3)
        );
        assert_eq!(Problem::parse("SVM", 2.0).unwrap(), Problem::svm(2.0));
        assert_eq!(
            Problem::parse("logistic", 2.0).unwrap(),
            Problem::logistic(2.0)
        );
        assert!(Problem::parse("elastic", 2.0).is_err());
        assert!(Problem::parse("elastic:x", 2.0).is_err());
        assert!(Problem::parse("flink", 2.0).is_err());
    }

    #[test]
    fn validation() {
        assert!(Problem::ridge(1.0).validate().is_ok());
        assert!(Problem::ridge(0.0).validate().is_err());
        assert!(Problem::elastic(1.0, 1.5).validate().is_err());
        // η is inert for the dual losses.
        let mut p = Problem::svm(1.0);
        p.reg.eta = 7.0;
        assert!(p.validate().is_ok());
    }

    #[test]
    fn squared_primal_matches_hand_computation() {
        // Same fixture as the (deprecated) Dataset::objective test.
        let ds = crate::data::Dataset {
            a: crate::data::CscMatrix::from_triplets(
                3,
                3,
                &[(0, 0, 1.0), (2, 0, 4.0), (1, 1, 3.0), (0, 2, 2.0), (2, 2, 5.0)],
            ),
            b: vec![1.0, 2.0, 3.0],
            name: "tiny".into(),
        };
        let alpha = vec![1.0, 1.0, 1.0];
        assert!((Problem::elastic(2.0, 1.0).primal(&ds, &alpha) - 23.5).abs() < 1e-12);
        assert!((Problem::elastic(2.0, 0.0).primal(&ds, &alpha) - 26.5).abs() < 1e-12);
    }

    #[test]
    fn hinge_step_is_the_clipped_sdca_update() {
        let p = Problem::svm(2.0); // C = 0.5
        let h = HingeDual;
        // Interior: a = aj + (1 − cj_r)/(σ·csq)
        let a = h.step(&p.reg, 1.0, 0.1, 2.0, 0.4).unwrap();
        assert!((a - (0.1 + 0.6 / 2.0)).abs() < 1e-15);
        // Clipped at both ends of [0, C].
        assert_eq!(h.step(&p.reg, 1.0, 0.0, 1.0, 10.0).unwrap(), 0.0);
        assert_eq!(h.step(&p.reg, 1.0, 0.0, 1.0, -10.0).unwrap(), 0.5);
        // Degenerate column is skipped.
        assert!(h.step(&p.reg, 1.0, 0.0, 0.0, 0.0).is_none());
    }

    #[test]
    fn logistic_step_solves_the_scalar_stationarity_condition() {
        let p = Problem::logistic(1.0); // C = 1
        let l = LogisticDual;
        let (sigma, aj, csq, cj_r) = (2.0, 0.3, 1.5, -0.7);
        let a = l.step(&p.reg, sigma, aj, csq, cj_r).unwrap();
        let c = p.reg.box_c();
        assert!(a > 0.0 && a < c);
        let g = sigma * csq * (a - aj) + cj_r + (a / (c - a)).ln();
        assert!(g.abs() < 1e-9, "stationarity residual {}", g);
        // Deterministic.
        assert_eq!(
            a.to_bits(),
            l.step(&p.reg, sigma, aj, csq, cj_r).unwrap().to_bits()
        );
    }

    #[test]
    fn gap_is_positive_away_from_optimum_for_every_family() {
        let ds = webspam_like(&SyntheticSpec::small());
        let alpha = vec![0.05; ds.n()];
        let v = ds.shared_vector(&alpha);
        for p in [
            Problem::ridge(3.0),
            Problem::lasso(3.0),
            Problem::elastic(3.0, 0.4),
            Problem::svm(1.0),
        ] {
            let gap = p.duality_gap(&ds, &v, &alpha);
            assert!(gap > 0.0, "{}: gap {}", p.kind_name(), gap);
        }
        // Logistic needs α strictly inside (0, C).
        let (cds, _) = separable_classes(16, 48, 0.3, 3);
        let p = Problem::logistic(1.0);
        let a = vec![0.25 * p.reg.box_c(); cds.n()];
        let v = cds.shared_vector(&a);
        assert!(p.duality_gap(&cds, &v, &a) > 0.0);
    }

    #[test]
    fn gap_scratch_matches_one_shot_and_is_allocation_free() {
        // The satellite bar: an eval step through the session's reused
        // scratch is bit-identical to the one-shot form and, once warm,
        // performs zero heap allocations (counting allocator).
        let ds = webspam_like(&SyntheticSpec::small());
        let alpha = vec![0.03; ds.n()];
        let v = ds.shared_vector(&alpha);
        for p in [
            Problem::ridge(2.0),
            Problem::lasso(5.0),
            Problem::elastic(2.0, 0.4),
        ] {
            let f = p.primal_given_v(&v, &alpha, &ds.b);
            let mut scratch = GapScratch::default();
            let warm = p.duality_gap_scratch(&ds, &v, &alpha, f, &mut scratch);
            assert_eq!(
                warm.to_bits(),
                p.duality_gap_given_primal(&ds, &v, &alpha, f).to_bits(),
                "{}",
                p.kind_name()
            );
            let before = crate::testkit::alloc::current_thread_allocations();
            let mut acc = 0.0;
            for _ in 0..10 {
                acc += p.duality_gap_scratch(&ds, &v, &alpha, f, &mut scratch);
            }
            let after = crate::testkit::alloc::current_thread_allocations();
            assert_eq!(after - before, 0, "{} eval step allocated", p.kind_name());
            assert!(acc.is_finite() && acc >= 0.0);
        }
    }

    #[test]
    fn ridge_gap_upper_bounds_suboptimality() {
        let ds = dense_gaussian(24, 10, 5);
        let lam = 0.8;
        let p = Problem::ridge(lam);
        let (_, fstar) = crate::solver::cg::ridge_optimum(&ds, lam, 1e-12, 10_000);
        for seed in 0..5u64 {
            let mut rng = crate::linalg::Xorshift128::new(seed + 1);
            let alpha: Vec<f64> = (0..ds.n()).map(|_| 0.3 * rng.next_gaussian()).collect();
            let v = ds.shared_vector(&alpha);
            let f = p.primal_given_v(&v, &alpha, &ds.b);
            let gap = p.duality_gap(&ds, &v, &alpha);
            assert!(
                gap >= f - fstar - 1e-9 * (1.0 + fstar.abs()),
                "gap {} < subopt {}",
                gap,
                f - fstar
            );
        }
    }

    #[test]
    fn json_roundtrip() {
        for p in [
            Problem::ridge(2.5),
            Problem::elastic(1.0, 0.25),
            Problem::svm(0.5),
            Problem::logistic(4.0),
        ] {
            assert_eq!(Problem::from_json(&p.to_json()).unwrap(), p);
        }
        assert!(Problem::from_json(&Json::obj()).is_err());
    }
}
