//! Discrete-event cluster simulator: virtual clock + network model.
//!
//! The paper's testbed (4 Xeon nodes, 10 Gbit switched LAN, up to 16
//! workers) is not available here, so experiments run on a *virtual
//! cluster*: worker **compute is real, measured execution** folded onto a
//! virtual clock, while communication and framework costs come from the
//! models below (DESIGN.md §2 substitution table). Virtual time makes
//! 16-worker scaling experiments exactly reproducible on a single core —
//! the quantity the paper reports (relative performance, optimal H,
//! compute fractions) is scale-free.
//!
//! Only transfer *times* are modeled here. The payloads those times are
//! charged for are real: the engines hand this model the actual encoded
//! frame sizes (nnz-adaptive sparse Δv frames where cheaper — DESIGN.md
//! §7), and the aggregation the [`ClusterModel::tree_allreduce`] cost
//! stands in for is genuinely executed by `linalg`'s pairwise tree in
//! pooled buffers (no serial fold, no fresh accumulator — see
//! `linalg::tree_reduce` and `linalg::DeltaReducer`).

/// Virtual clock measuring simulated seconds.
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock { now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance by `dt` seconds (panics on negative or NaN — a negative
    /// advance is always a bug in a cost model).
    pub fn advance(&mut self, dt: f64) {
        assert!(dt.is_finite() && dt >= 0.0, "bad clock advance {}", dt);
        self.now += dt;
    }

    /// Advance by the parallel composition of per-worker durations: the
    /// synchronous round completes when the slowest worker finishes.
    pub fn advance_parallel(&mut self, durations: &[f64]) -> f64 {
        let max = durations.iter().cloned().fold(0.0f64, f64::max);
        self.advance(max);
        max
    }
}

/// Point-to-point link model: latency + bandwidth.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// One-way message latency in seconds.
    pub latency_s: f64,
    /// Bandwidth in bytes/second.
    pub bandwidth_bps: f64,
}

impl LinkModel {
    /// The paper's interconnect: 10 Gbit/s switched Ethernet, ~40 µs
    /// one-way latency (typical for the era's switched LAN + kernel stack).
    pub fn ten_gbit_lan() -> LinkModel {
        LinkModel {
            latency_s: 40e-6,
            bandwidth_bps: 1.25e9,
        }
    }

    /// Time to move `bytes` across the link.
    pub fn xfer(&self, bytes: u64) -> f64 {
        self.xfer_scaled(bytes, 1.0)
    }

    /// Transfer time with the latency component scaled by τ (fixed cost)
    /// while the bandwidth component stays physical (data-proportional).
    pub fn xfer_scaled(&self, bytes: u64, tau: f64) -> f64 {
        self.latency_s * tau + bytes as f64 / self.bandwidth_bps
    }
}

/// Cluster topology: K workers on `nodes` physical nodes behind one switch.
#[derive(Debug, Clone)]
pub struct ClusterModel {
    pub link: LinkModel,
    /// Physical nodes (paper: 4).
    pub nodes: usize,
    /// Fixed-cost time-scale factor τ (see `framework::overhead`): applied
    /// to latency-like constants only; bandwidth terms shrink naturally
    /// with the down-scaled dataset (DESIGN.md §6).
    pub time_scale: f64,
}

impl ClusterModel {
    pub fn paper_testbed(time_scale: f64) -> ClusterModel {
        ClusterModel {
            link: LinkModel::ten_gbit_lan(),
            nodes: 4,
            time_scale,
        }
    }

    /// Workers co-located on a node communicate through shared memory —
    /// model as 10× the LAN bandwidth, 1/10 the latency.
    fn local_link(&self) -> LinkModel {
        LinkModel {
            latency_s: self.link.latency_s / 10.0,
            bandwidth_bps: self.link.bandwidth_bps * 10.0,
        }
    }

    /// Whether worker `w` of `k` is co-located with the master (worker 0's
    /// node hosts the driver/rank-0).
    fn colocated(&self, w: usize, k: usize) -> bool {
        let per_node = k.div_ceil(self.nodes);
        per_node > 0 && w / per_node == 0
    }

    /// Star broadcast (Spark driver → each executor in turn over the
    /// driver's NIC): the driver's link serializes the K transfers.
    pub fn star_broadcast(&self, bytes: u64, k: usize) -> f64 {
        let mut t = 0.0;
        for w in 0..k {
            let link = if self.colocated(w, k) {
                self.local_link()
            } else {
                self.link
            };
            t += link.xfer_scaled(bytes, self.time_scale);
        }
        t
    }

    /// Star gather (each executor → driver), also serialized at the driver.
    pub fn star_gather(&self, bytes_per_worker: u64, k: usize) -> f64 {
        self.star_broadcast(bytes_per_worker, k)
    }

    /// Star transfer with per-worker byte counts (unequal partitions).
    pub fn star_varied(&self, bytes_per_worker: &[u64]) -> f64 {
        let k = bytes_per_worker.len();
        let mut t = 0.0;
        for (w, &bytes) in bytes_per_worker.iter().enumerate() {
            let link = if self.colocated(w, k) {
                self.local_link()
            } else {
                self.link
            };
            t += link.xfer_scaled(bytes, self.time_scale);
        }
        t
    }

    /// Spark TorrentBroadcast (the 1.5-era default): the value is split
    /// into blocks that executors re-serve to each other BitTorrent-style,
    /// so the driver NIC stops being the bottleneck — total time ≈ two
    /// block transfers × log2(k) fetch waves instead of k serialized sends.
    pub fn torrent_broadcast(&self, bytes: u64, k: usize) -> f64 {
        if k == 0 {
            return 0.0;
        }
        let waves = (k as f64).log2().ceil().max(1.0);
        self.link.xfer_scaled(2 * bytes, self.time_scale) + waves * self.link.latency_s * self.time_scale
    }

    /// MPI tree AllReduce of a `bytes`-sized vector over k ranks:
    /// reduce + broadcast, ⌈log2 k⌉ rounds each.
    pub fn tree_allreduce(&self, bytes: u64, k: usize) -> f64 {
        if k <= 1 {
            return 0.0;
        }
        let rounds = (k as f64).log2().ceil();
        2.0 * rounds * self.link.xfer_scaled(bytes, self.time_scale)
    }

    /// A scaled scalar cost (barrier, task launch, ...).
    pub fn scaled(&self, seconds: f64) -> f64 {
        seconds * self.time_scale
    }

    /// Latency-jittered copy of the model (chaos layer, DESIGN.md §12):
    /// one-way link latency multiplied by `mult` — the per-round draw of
    /// `framework::chaos::jitter_mult` — while bandwidth stays physical
    /// (congestion jitter hits the latency floor, not the wire rate).
    pub fn jittered(&self, mult: f64) -> ClusterModel {
        let mut c = self.clone();
        c.link.latency_s *= mult;
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances_and_rejects_negative() {
        let mut c = VirtualClock::new();
        c.advance(1.5);
        c.advance(0.5);
        assert_eq!(c.now(), 2.0);
        let r = std::panic::catch_unwind(move || {
            let mut c = VirtualClock::new();
            c.advance(-1.0)
        });
        assert!(r.is_err());
    }

    #[test]
    fn parallel_composition_takes_max() {
        let mut c = VirtualClock::new();
        let max = c.advance_parallel(&[0.1, 0.7, 0.3]);
        assert_eq!(max, 0.7);
        assert_eq!(c.now(), 0.7);
        c.advance_parallel(&[]);
        assert_eq!(c.now(), 0.7);
    }

    #[test]
    fn link_xfer_scales_with_bytes() {
        let l = LinkModel::ten_gbit_lan();
        let t1 = l.xfer(1_000_000);
        let t2 = l.xfer(2_000_000);
        assert!(t2 > t1);
        assert!((t2 - t1 - 1_000_000.0 / 1.25e9).abs() < 1e-12);
        // Latency floor for tiny messages.
        assert!(l.xfer(1) >= 40e-6);
    }

    #[test]
    fn broadcast_grows_linearly_in_k() {
        let c = ClusterModel::paper_testbed(1.0);
        let t4 = c.star_broadcast(1_000_000, 4);
        let t8 = c.star_broadcast(1_000_000, 8);
        assert!(t8 > 1.5 * t4, "star should serialize at the driver");
    }

    #[test]
    fn allreduce_grows_logarithmically() {
        let c = ClusterModel::paper_testbed(1.0);
        let t2 = c.tree_allreduce(1_000_000, 2);
        let t16 = c.tree_allreduce(1_000_000, 16);
        assert!(t16 < 5.0 * t2, "tree allreduce must scale ~log k");
        assert_eq!(c.tree_allreduce(1_000_000, 1), 0.0);
    }

    #[test]
    fn allreduce_cheaper_than_star_roundtrip() {
        // The structural reason MPI communication beats Spark's driver star.
        let c = ClusterModel::paper_testbed(1.0);
        let star = c.star_broadcast(2_800_000, 8) + c.star_gather(2_800_000, 8);
        let tree = c.tree_allreduce(2_800_000, 8);
        assert!(tree < star, "tree {} !< star {}", tree, star);
    }

    #[test]
    fn torrent_beats_star_at_scale() {
        let c = ClusterModel::paper_testbed(1.0);
        let bytes = 2_800_000u64;
        assert!(c.torrent_broadcast(bytes, 16) < c.star_broadcast(bytes, 16) / 3.0);
        // At k=1 star wins (driver→colocated worker is a local copy), but
        // torrent stays within a constant factor (two block transfers).
        assert!(c.torrent_broadcast(bytes, 1) < 25.0 * c.star_broadcast(bytes, 1));
    }

    #[test]
    fn jitter_scales_latency_not_bandwidth() {
        let c = ClusterModel::paper_testbed(1.0);
        let j = c.jittered(2.0);
        // Tiny message: latency-dominated → doubles.
        let r_small = j.star_broadcast(1, 4) / c.star_broadcast(1, 4);
        assert!((r_small - 2.0).abs() < 1e-9, "ratio {}", r_small);
        // Huge message: bandwidth-dominated → barely moves.
        let big = 1_000_000_000u64;
        let r_big = j.star_broadcast(big, 4) / c.star_broadcast(big, 4);
        assert!(r_big < 1.01, "bandwidth must not jitter: ratio {}", r_big);
        // mult = 1 is exactly the identity.
        assert_eq!(c.jittered(1.0).tree_allreduce(1000, 4), c.tree_allreduce(1000, 4));
    }

    #[test]
    fn time_scale_applies_to_latency_only() {
        let c1 = ClusterModel::paper_testbed(1.0);
        let c2 = ClusterModel::paper_testbed(0.01);
        // Tiny message: latency-dominated → scales with τ.
        assert!(c2.star_broadcast(1, 4) < 0.05 * c1.star_broadcast(1, 4));
        // Huge message: bandwidth-dominated → τ barely matters.
        let big = 1_000_000_000u64;
        let r = c2.star_broadcast(big, 4) / c1.star_broadcast(big, 4);
        assert!(r > 0.95, "bandwidth term must not scale: ratio {}", r);
        assert_eq!(c2.scaled(1.0), 0.01);
    }
}
