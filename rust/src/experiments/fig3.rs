//! Figure 3: execution-time decomposition (T_worker / T_master /
//! T_overhead) for 100 rounds at H = n_local, implementations (A)–(E).
//!
//! Expected shape (paper §5.2): master < 2 s everywhere; (A)/(C) dominated
//! by managed-solver compute; +C variants cut worker time 10×/100×+;
//! pySpark overhead ≈ 15× Spark overhead; MPI overhead ≈ 3% of total.

use super::common::{run_timing, ExpOptions};
use crate::config::Impl;
use crate::metrics::Table;

pub const ROUNDS: usize = 100;

pub fn run(opts: &ExpOptions) -> String {
    let ds = opts.dataset();
    let mut cfg = opts.config(&ds);
    cfg.h_frac = 1.0; // H = n_local, the paper's setting
    cfg.h_abs = None;

    let mut out = String::new();
    out.push_str(&format!(
        "Figure 3 — {} rounds at H=n_local, {} (K={}){}\n\n",
        ROUNDS,
        ds.name,
        cfg.workers,
        if opts.real_managed {
            " [real interpreted managed solvers]"
        } else {
            " [native numerics × measured multiplier]"
        }
    ));

    let mut table = Table::new(&[
        "impl",
        "T_tot (s)",
        "T_worker (s)",
        "T_master (s)",
        "T_overhead (s)",
        "ovh %",
    ]);
    let mut csv = String::from("impl,t_tot,t_worker,t_master,t_overhead\n");
    let mut rows = Vec::new();

    for imp in Impl::ALL_PAPER {
        let rep = run_timing(imp, &ds, &cfg, ROUNDS, opts);
        csv.push_str(&format!(
            "{},{:.6},{:.6},{:.6},{:.6}\n",
            imp.name(),
            rep.total_time,
            rep.total_worker,
            rep.total_master,
            rep.total_overhead
        ));
        table.row(vec![
            imp.name().to_string(),
            format!("{:.4}", rep.total_time),
            format!("{:.4}", rep.total_worker),
            format!("{:.4}", rep.total_master),
            format!("{:.4}", rep.total_overhead),
            format!("{:.1}%", 100.0 * rep.total_overhead / rep.total_time),
        ]);
        rows.push((imp, rep));
    }

    out.push_str(&table.render());

    // The paper's §5.2 checkpoints, computed from this run:
    let find = |imp: Impl| rows.iter().find(|(i, _)| *i == imp).map(|(_, r)| r).unwrap();
    let (a, b, c, d, e) = (
        find(Impl::SparkScala),
        find(Impl::SparkC),
        find(Impl::PySpark),
        find(Impl::PySparkC),
        find(Impl::Mpi),
    );
    out.push_str("\npaper checkpoints:\n");
    out.push_str(&format!(
        "  MPI overhead fraction:        {:.1}% (paper ≈ 3%)\n",
        100.0 * e.total_overhead / e.total_time
    ));
    out.push_str(&format!(
        "  pySpark / Spark overhead:     {:.1}× (paper ≈ 15×)\n",
        d.total_overhead / b.total_overhead
    ));
    out.push_str(&format!(
        "  (A)→(B) worker-time speedup:  {:.1}× (paper ≈ 10×)\n",
        a.total_worker / b.total_worker
    ));
    out.push_str(&format!(
        "  (C)→(D) worker-time speedup:  {:.0}× (paper ≈ 100×+)\n",
        c.total_worker / d.total_worker
    ));
    out.push_str(&format!(
        "  master time max:              {:.4} s (paper < 2 s)\n",
        [a, b, c, d, e]
            .iter()
            .map(|r| r.total_master)
            .fold(0.0f64, f64::max)
    ));

    opts.save("fig3_overheads.csv", &csv);
    out
}
