//! Figure 4: overhead + compute for (E), (B), (D) and the optimized (B)\*,
//! (D)\* — the §5.3 persistent-local-memory + meta-RDD variants.
//!
//! Expected shape (paper): B→B\* overhead ↓ ≈3× (mostly from not shipping
//! α), D→D\* overhead ↓ ≈10× (meta-RDD dominates — no python record
//! traffic), leaving B\* ≈ D\* within 2× of MPI.

use super::common::{run_timing, ExpOptions};
use crate::config::Impl;
use crate::metrics::Table;

pub const ROUNDS: usize = 100;

pub fn run(opts: &ExpOptions) -> String {
    let ds = opts.dataset();
    let mut cfg = opts.config(&ds);
    cfg.h_frac = 1.0;
    cfg.h_abs = None;

    let impls = [
        Impl::Mpi,
        Impl::SparkC,
        Impl::SparkCOpt,
        Impl::PySparkC,
        Impl::PySparkCOpt,
    ];

    let mut out = String::new();
    out.push_str(&format!(
        "Figure 4 — optimized implementations, {} rounds at H=n_local (K={})\n\n",
        ROUNDS, cfg.workers
    ));
    let mut table = Table::new(&["impl", "compute (s)", "overhead (s)", "bytes/round ↓", "bytes/round ↑"]);
    let mut csv = String::from("impl,t_worker,t_overhead,bytes_down,bytes_up\n");
    let mut rows = Vec::new();

    for imp in impls {
        let rep = run_timing(imp, &ds, &cfg, ROUNDS, opts);
        let bytes_down: u64 = rep.logs.iter().map(|l| l.timing.bytes_down).sum::<u64>() / ROUNDS as u64;
        let bytes_up: u64 = rep.logs.iter().map(|l| l.timing.bytes_up).sum::<u64>() / ROUNDS as u64;
        csv.push_str(&format!(
            "{},{:.6},{:.6},{},{}\n",
            imp.name(),
            rep.total_worker,
            rep.total_overhead,
            bytes_down,
            bytes_up
        ));
        table.row(vec![
            imp.name().to_string(),
            format!("{:.4}", rep.total_worker),
            format!("{:.4}", rep.total_overhead),
            crate::util::fmt_bytes(bytes_down),
            crate::util::fmt_bytes(bytes_up),
        ]);
        rows.push((imp, rep));
    }

    out.push_str(&table.render());

    let find = |imp: Impl| rows.iter().find(|(i, _)| *i == imp).map(|(_, r)| r).unwrap();
    let (e, b, bs, d, ds_) = (
        find(Impl::Mpi),
        find(Impl::SparkC),
        find(Impl::SparkCOpt),
        find(Impl::PySparkC),
        find(Impl::PySparkCOpt),
    );
    out.push_str("\npaper checkpoints:\n");
    out.push_str(&format!(
        "  B→B* overhead reduction:  {:.1}× (paper ≈ 3×)\n",
        b.total_overhead / bs.total_overhead
    ));
    out.push_str(&format!(
        "  D→D* overhead reduction:  {:.1}× (paper ≈ 10×)\n",
        d.total_overhead / ds_.total_overhead
    ));
    out.push_str(&format!(
        "  B* vs MPI total:          {:.1}× (paper < 2×)\n",
        bs.total_time / e.total_time
    ));
    out.push_str(&format!(
        "  D* vs MPI total:          {:.1}× (paper < 2×)\n",
        ds_.total_time / e.total_time
    ));
    out.push_str(&format!(
        "  B* ≈ D*:                  {:.2}× apart (paper: 'more or less equivalent')\n",
        (bs.total_time / ds_.total_time).max(ds_.total_time / bs.total_time)
    ));

    opts.save("fig4_optimized.csv", &csv);
    out
}
