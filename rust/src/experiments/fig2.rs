//! Figure 2: suboptimality over time for implementations (A)–(E), each at
//! its individually tuned H (ridge regression on the webspam-like corpus).
//!
//! Expected shape (paper): E ≪ B < A ≪ D < C in time-to-ε, with the
//! SPARK+C variants reducing the Spark↔MPI gap from ~10-20× to ~4×.

use super::common::{train_averaged, ExpOptions, HTuneCache};
use crate::config::Impl;
use crate::coordinator;
use crate::metrics::{AsciiPlot, Table};

pub fn run(opts: &ExpOptions) -> String {
    let ds = opts.dataset();
    let cfg = opts.config(&ds);
    let fstar = coordinator::oracle_objective(&ds, &cfg);
    let mut cache = HTuneCache::new();

    let mut out = String::new();
    out.push_str(&format!(
        "Figure 2 — suboptimality vs time, {} (K={}, λn={:.3})\n\n",
        ds.name, cfg.workers, cfg.lam_n()
    ));

    let markers = ['A', 'B', 'C', 'D', 'E'];
    let mut plot = AsciiPlot::new(72, 20).log_y();
    let mut table = Table::new(&["impl", "tuned H/n_local", "rounds", "time-to-1e-3 (virt s)"]);
    let mut csv = String::from("impl,h_frac,round,time_s,suboptimality\n");

    for (imp, marker) in Impl::ALL_PAPER.iter().zip(markers.iter()) {
        let h = cache.tuned_h_frac(*imp, &ds, &cfg, fstar, opts);
        let (mean_time, reports) = train_averaged(*imp, &ds, &cfg, fstar, h, opts);
        let rep = &reports[0];
        let pts: Vec<(f64, f64)> = rep
            .logs
            .iter()
            .filter_map(|l| l.suboptimality.map(|s| (l.time, s.max(1e-12))))
            .collect();
        for (t, s) in &pts {
            csv.push_str(&format!("{},{},,{:.9},{:.6e}\n", imp.name(), h, t, s));
        }
        plot = plot.series(imp.name(), *marker, pts);
        table.row(vec![
            imp.name().to_string(),
            format!("{:.2}", h),
            rep.rounds.to_string(),
            mean_time
                .map(|t| format!("{:.4}", t))
                .unwrap_or_else(|| "not reached".into()),
        ]);
    }

    out.push_str(&table.render());
    out.push('\n');
    out.push_str(&plot.render());
    opts.save("fig2_convergence.csv", &csv);
    out
}
