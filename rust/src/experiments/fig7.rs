//! Figure 7: fraction of time spent computing vs H for (B), (D), (E).
//!
//! Expected shape (paper): monotone-increasing in H for every framework;
//! the *optimal* operating point (from Figure 6) sits at ~90% compute for
//! MPI but only ~60% for pySpark+C — higher effective overheads push the
//! optimum toward more communication-starved operation.

use super::common::{make_engine, ExpOptions};
use crate::config::Impl;
use crate::coordinator::{self, tuner};
use crate::metrics::{AsciiPlot, Table};

pub fn run(opts: &ExpOptions) -> String {
    let ds = opts.dataset();
    let cfg = opts.config(&ds);
    let fstar = coordinator::oracle_objective(&ds, &cfg);
    let grid = tuner::DEFAULT_H_GRID;
    let impls = [Impl::SparkC, Impl::PySparkC, Impl::Mpi];
    let markers = ['B', 'D', 'E'];

    let mut out = String::new();
    out.push_str(&format!(
        "Figure 7 — compute fraction vs H/n_local (K={})\n\n",
        cfg.workers
    ));
    let mut plot = AsciiPlot::new(72, 16).log_x();
    let mut table = Table::new(&["impl", "H*/n_local", "compute fraction at H*"]);
    let mut csv = String::from("impl,h_frac,compute_fraction,time_to_target\n");

    for (imp, marker) in impls.iter().zip(markers.iter()) {
        let make = || make_engine(*imp, &ds, &cfg, opts);
        let (points, best) = tuner::grid_search_h(&make, &ds, &cfg, fstar, &grid);
        let series: Vec<(f64, f64)> = points
            .iter()
            .map(|p| (p.h_frac, p.report.compute_fraction()))
            .collect();
        for p in &points {
            csv.push_str(&format!(
                "{},{},{:.6},{}\n",
                imp.name(),
                p.h_frac,
                p.report.compute_fraction(),
                p.report
                    .time_to_target
                    .map(|t| format!("{:.6}", t))
                    .unwrap_or_default()
            ));
        }
        table.row(vec![
            imp.name().to_string(),
            format!("{:.2}", points[best].h_frac),
            format!("{:.1}%", 100.0 * points[best].report.compute_fraction()),
        ]);
        plot = plot.series(imp.name(), *marker, series);
    }

    out.push_str(&table.render());
    out.push('\n');
    out.push_str(&plot.render());
    out.push_str("\npaper checkpoints: fraction ↑ monotone in H; at the optimum E≈90%, D≈60% — the optimal compute share *falls* as framework overhead rises.\n");
    opts.save("fig7_compute_fraction.csv", &csv);
    out
}
