//! Shared experiment machinery: dataset/config construction, tuned-H cache,
//! output plumbing.

use std::collections::HashMap;
use std::path::PathBuf;

use crate::config::{Impl, TrainConfig};
use crate::coordinator::tuner;
use crate::data::synthetic::{webspam_like, SyntheticSpec};
use crate::data::Dataset;
use crate::framework::{build_engine_with, DistEngine, Engine, EngineOptions};
use crate::metrics::{write_file, TrainReport};
use crate::session::{Session, StopPolicy};

/// Options common to all experiments.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    /// Workers K (paper default: 8).
    pub workers: usize,
    /// Dataset scale: "mini" (default), "small" (CI), or "m,n,nnz" custom.
    pub scale: String,
    /// Output directory for CSVs.
    pub out_dir: PathBuf,
    /// Runs to average over (paper: 10; default 3 for time).
    pub seeds: usize,
    /// Execute the genuinely interpreted managed solvers (slow; Figure 3
    /// validation) instead of native + measured multiplier.
    pub real_managed: bool,
    /// λ·n override (default: 1e-2 · n).
    pub lam_n: Option<f64>,
}

impl Default for ExpOptions {
    fn default() -> Self {
        ExpOptions {
            workers: 8,
            scale: "mini".into(),
            out_dir: PathBuf::from("results"),
            seeds: 3,
            real_managed: false,
            lam_n: None,
        }
    }
}

impl ExpOptions {
    pub fn dataset(&self) -> Dataset {
        let spec = match self.scale.as_str() {
            "mini" => SyntheticSpec::webspam_mini(),
            "small" => SyntheticSpec::small(),
            custom => {
                let parts: Vec<usize> = custom
                    .split(',')
                    .filter_map(|p| p.trim().parse().ok())
                    .collect();
                if parts.len() == 3 {
                    SyntheticSpec {
                        m: parts[0],
                        n: parts[1],
                        avg_col_nnz: parts[2],
                        ..SyntheticSpec::webspam_mini()
                    }
                } else {
                    SyntheticSpec::webspam_mini()
                }
            }
        };
        webspam_like(&spec)
    }

    pub fn config(&self, ds: &Dataset) -> TrainConfig {
        let mut cfg = TrainConfig::default_for(ds);
        cfg.workers = self.workers;
        if let Some(l) = self.lam_n {
            cfg.problem = cfg.problem.with_lam_n(l);
        }
        cfg
    }

    pub fn engine_options(&self) -> EngineOptions {
        EngineOptions {
            real_managed_compute: self.real_managed,
            ..Default::default()
        }
    }

    pub fn save(&self, filename: &str, contents: &str) {
        let path = self.out_dir.join(filename);
        if let Err(e) = write_file(&path, contents) {
            eprintln!("warn: could not write {}: {}", path.display(), e);
        } else {
            eprintln!("wrote {}", path.display());
        }
    }
}

/// Build an engine for an implementation under these options.
pub fn make_engine(
    imp: Impl,
    ds: &Dataset,
    cfg: &TrainConfig,
    opts: &ExpOptions,
) -> Box<dyn DistEngine> {
    build_engine_with(imp, ds, cfg, &opts.engine_options())
}

/// One session to the configured target with a known oracle — the common
/// experiment step.
pub fn run_to_target(
    engine: impl Into<Engine>,
    ds: &Dataset,
    cfg: &TrainConfig,
    fstar: f64,
    opts: &ExpOptions,
) -> TrainReport {
    Session::builder(ds)
        .engine(engine)
        .options(opts.engine_options())
        .config(cfg.clone())
        .oracle(fstar)
        .build()
        .expect("invalid experiment config")
        .run()
}

/// Pure timing run: exactly `rounds` rounds, objective never evaluated
/// (the Figure 3/4 methodology).
pub fn run_timing(
    engine: impl Into<Engine>,
    ds: &Dataset,
    cfg: &TrainConfig,
    rounds: usize,
    opts: &ExpOptions,
) -> TrainReport {
    Session::builder(ds)
        .engine(engine)
        .options(opts.engine_options())
        .config(cfg.clone())
        .stop(StopPolicy::FixedRounds { n: rounds })
        .build()
        .expect("invalid experiment config")
        .run()
}

/// Tune H for an implementation by grid search; memoized per (impl,K).
pub struct HTuneCache {
    cache: HashMap<(Impl, usize), f64>,
}

impl HTuneCache {
    pub fn new() -> HTuneCache {
        HTuneCache {
            cache: HashMap::new(),
        }
    }

    /// Best h_frac for `imp` (grid search over the default grid).
    pub fn tuned_h_frac(
        &mut self,
        imp: Impl,
        ds: &Dataset,
        cfg: &TrainConfig,
        fstar: f64,
        opts: &ExpOptions,
    ) -> f64 {
        if let Some(&h) = self.cache.get(&(imp, cfg.workers)) {
            return h;
        }
        let make = || make_engine(imp, ds, cfg, opts);
        let (points, best) =
            tuner::grid_search_h(&make, ds, cfg, fstar, &tuner::DEFAULT_H_GRID);
        let h = points[best].h_frac;
        self.cache.insert((imp, cfg.workers), h);
        h
    }
}

impl Default for HTuneCache {
    fn default() -> Self {
        Self::new()
    }
}

/// Train `imp` at a given h_frac, averaged over `seeds` runs.
/// Returns (mean time-to-target across seeds that reached it, reports).
pub fn train_averaged(
    imp: Impl,
    ds: &Dataset,
    cfg: &TrainConfig,
    fstar: f64,
    h_frac: f64,
    opts: &ExpOptions,
) -> (Option<f64>, Vec<TrainReport>) {
    let mut reports = Vec::new();
    let mut times = Vec::new();
    for s in 0..opts.seeds.max(1) {
        let mut c = cfg.clone();
        c.h_frac = h_frac;
        c.h_abs = None;
        c.seed = cfg.seed + s as u64;
        let report = run_to_target(imp, ds, &c, fstar, opts);
        if let Some(t) = report.time_to_target {
            times.push(t);
        }
        reports.push(report);
    }
    let mean = if times.is_empty() {
        None
    } else {
        Some(crate::linalg::mean(&times))
    };
    (mean, reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_parsing() {
        let mut o = ExpOptions::default();
        o.scale = "small".into();
        let ds = o.dataset();
        assert_eq!(ds.m(), 128);
        o.scale = "64,128,8".into();
        let ds = o.dataset();
        assert_eq!(ds.m(), 64);
        assert_eq!(ds.n(), 128);
    }

    #[test]
    fn config_uses_workers() {
        let mut o = ExpOptions::default();
        o.scale = "small".into();
        o.workers = 5;
        let ds = o.dataset();
        let cfg = o.config(&ds);
        assert_eq!(cfg.workers, 5);
    }

    #[test]
    fn tune_cache_memoizes() {
        let mut o = ExpOptions::default();
        o.scale = "small".into();
        o.workers = 2;
        o.seeds = 1;
        let ds = o.dataset();
        let mut cfg = o.config(&ds);
        cfg.max_rounds = 60;
        let fstar = crate::coordinator::oracle_objective(&ds, &cfg);
        let mut cache = HTuneCache::new();
        let h1 = cache.tuned_h_frac(Impl::Mpi, &ds, &cfg, fstar, &o);
        let h2 = cache.tuned_h_frac(Impl::Mpi, &ds, &cfg, fstar, &o);
        assert_eq!(h1, h2);
        assert!(h1 > 0.0);
    }
}
