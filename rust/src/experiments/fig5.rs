//! Figure 5: performance gain of the optimized Spark implementation over
//! the reference CoCoA (A) and the MLlib SGD solver.
//!
//! Expected shape (paper §5.4): reference CoCoA beats MLlib by up to ~50×;
//! the optimized implementation gains another order of magnitude; B\*/D\*
//! land within 2× of MPI.

use super::common::{train_averaged, ExpOptions, HTuneCache};
use crate::config::Impl;
use crate::coordinator;
use crate::metrics::Table;
use crate::session::Session;

pub fn run(opts: &ExpOptions) -> String {
    let ds = opts.dataset();
    let mut cfg = opts.config(&ds);
    // MLlib needs far more rounds than CoCoA to reach the target.
    cfg.max_rounds = cfg.max_rounds.max(2000);
    let fstar = coordinator::oracle_objective(&ds, &cfg);
    let mut cache = HTuneCache::new();

    let impls = [
        Impl::MllibSgd,
        Impl::SparkScala,
        Impl::SparkCOpt,
        Impl::PySparkCOpt,
        Impl::Mpi,
    ];

    let mut out = String::new();
    out.push_str(&format!(
        "Figure 5 — time to suboptimality 1e-3 vs MLlib baseline (K={})\n\n",
        cfg.workers
    ));
    let mut table = Table::new(&[
        "impl",
        "time-to-1e-3 (virt s)",
        "time-to-1e-1",
        "rounds",
        "speedup vs MLlib @1e-1",
    ]);
    let mut csv = String::from("impl,time_to_target,time_to_1e1,rounds\n");
    let mut results = Vec::new();

    // First virtual time at which a run crossed the weaker ε = 0.1 (the
    // paper's Figure 5 compares full curves; this anchors a finite ratio
    // even when SGD cannot reach 1e-3 inside the round budget).
    fn time_to(rep: &crate::metrics::TrainReport, eps: f64) -> Option<f64> {
        rep.logs
            .iter()
            .find(|l| l.suboptimality.map(|s| s <= eps).unwrap_or(false))
            .map(|l| l.time)
    }

    for imp in impls {
        if imp == Impl::MllibSgd {
            // The paper "tuned its batch size to get the best performance";
            // we tune (step, batch fraction) over a grid and keep the best.
            // Rank configurations by (reached target? time) then final ε;
            // divergent runs (non-finite ε) rank last.
            let mut best: Option<(Option<f64>, usize, f64, Option<f64>)> = None;
            let score = |time: Option<f64>, fin: f64| -> (f64, f64) {
                (time.unwrap_or(f64::INFINITY), if fin.is_finite() { fin } else { f64::INFINITY })
            };
            for step in [5e-4, 2e-3, 1e-2, 0.05] {
                for frac in [1.0] {
                    let mut eopts = opts.engine_options();
                    eopts.sgd_step = step;
                    eopts.sgd_batch_fraction = frac;
                    let rep = Session::builder(&ds)
                        .engine(imp)
                        .options(eopts)
                        .config(cfg.clone())
                        .oracle(fstar)
                        .build()
                        .expect("invalid fig5 config")
                        .run();
                    let cand = (
                        rep.time_to_target,
                        rep.rounds,
                        rep.final_suboptimality.unwrap_or(f64::INFINITY),
                        time_to(&rep, 0.1),
                    );
                    let replace = match &best {
                        None => true,
                        Some((bt, _, bf, _)) => score(cand.0, cand.2) < score(*bt, *bf),
                    };
                    if replace {
                        best = Some(cand);
                    }
                }
            }
            let (best_time, rounds, fin, t01) = best.unwrap();
            csv.push_str(&format!(
                "{},{},{},{}\n",
                imp.name(),
                best_time.map(|t| format!("{:.6}", t)).unwrap_or_default(),
                t01.map(|t| format!("{:.6}", t)).unwrap_or_default(),
                rounds
            ));
            results.push((imp, best_time, rounds, fin, t01));
            continue;
        }
        let h = cache.tuned_h_frac(imp, &ds, &cfg, fstar, opts);
        let (mean_time, reports) = train_averaged(imp, &ds, &cfg, fstar, h, opts);
        let t01 = time_to(&reports[0], 0.1);
        csv.push_str(&format!(
            "{},{},{},{}\n",
            imp.name(),
            mean_time.map(|t| format!("{:.6}", t)).unwrap_or_default(),
            t01.map(|t| format!("{:.6}", t)).unwrap_or_default(),
            reports[0].rounds
        ));
        results.push((
            imp,
            mean_time,
            reports[0].rounds,
            reports[0].final_suboptimality.unwrap_or(f64::INFINITY),
            t01,
        ));
    }

    let mllib_t01 = results
        .iter()
        .find(|(i, _, _, _, _)| *i == Impl::MllibSgd)
        .and_then(|(_, _, _, _, t)| *t);

    for (imp, time, rounds, final_sub, t01) in &results {
        let time_str = time
            .map(|t| format!("{:.4}", t))
            .unwrap_or_else(|| format!("not reached (ε={:.1e})", final_sub));
        let speedup = match (mllib_t01, t01) {
            (Some(mt), Some(t)) => format!("{:.0}×", mt / t),
            _ => "-".into(),
        };
        table.row(vec![
            imp.name().to_string(),
            time_str,
            t01.map(|t| format!("{:.4}", t)).unwrap_or_else(|| "-".into()),
            rounds.to_string(),
            speedup,
        ]);
    }

    out.push_str(&table.render());
    out.push_str("\npaper checkpoints: CoCoA(A) ≥ ~10× MLlib; optimized ≥ ~10× (A); optimized < 2× from MPI.\n");
    opts.save("fig5_mllib.csv", &csv);
    out
}
