//! Ablations of the design choices DESIGN.md calls out.
//!
//! * `layout` — flat vs record partitions for Spark+C (the paper's §4.1-B
//!   flattening trick: "this flat data format ... reduces overheads by a
//!   factor of 3" for Scala).
//! * `partitioner` — the paper's balanced-nnz MPI load balancer vs Spark
//!   range partitioning ("was found to perform comparable").
//! * `minibatch-cd` — CoCoA's immediate local updates vs classical
//!   mini-batch CD (§2.1).
//! * `adaptive-h` — the conclusion's future-work feature: auto-adapting H
//!   vs grid-tuned H.
//! * `gamma` — adding (γ=1) vs averaging (γ=1/K) aggregation (CoCoA⁺).

use super::common::{make_engine, run_to_target, ExpOptions};
use crate::config::{Impl, TrainConfig};
use crate::coordinator::{self, tuner};
use crate::data::{Partitioner, Partitioning};
use crate::framework::LayoutOverride;
use crate::metrics::Table;
use crate::session::{Session, StopPolicy};

pub fn layout(opts: &ExpOptions) -> String {
    let ds = opts.dataset();
    let mut cfg = opts.config(&ds);
    cfg.h_frac = 1.0;
    let mut out = String::from("Ablation: flat vs record partition layout for (B) spark+c\n\n");
    let mut table = Table::new(&["layout", "T_overhead (s)", "T_tot (s)"]);
    let mut csv = String::from("layout,t_overhead,t_tot\n");
    for (name, layout) in [
        ("flat (paper B)", LayoutOverride::Flat),
        ("records (un-flattened)", LayoutOverride::Records),
    ] {
        let mut eopts = opts.engine_options();
        eopts.force_layout = Some(layout);
        let rep = Session::builder(&ds)
            .engine(Impl::SparkC)
            .options(eopts)
            .config(cfg.clone())
            .stop(StopPolicy::FixedRounds { n: 50 })
            .build()
            .expect("invalid layout ablation config")
            .run();
        table.row(vec![
            name.to_string(),
            format!("{:.4}", rep.total_overhead),
            format!("{:.4}", rep.total_time),
        ]);
        csv.push_str(&format!("{},{:.6},{:.6}\n", name, rep.total_overhead, rep.total_time));
    }
    out.push_str(&table.render());
    out.push_str("\npaper: flattening buys ~3× overhead for Scala (it removes per-record iteration + per-record JNI crossings).\n");
    opts.save("ablation_layout.csv", &csv);
    out
}

pub fn partitioner(opts: &ExpOptions) -> String {
    let ds = opts.dataset();
    let cfg = opts.config(&ds);
    let mut out = String::from("Ablation: partitioner load balance + training impact (E)\n\n");
    let mut table = Table::new(&["partitioner", "nnz imbalance", "time-to-1e-3 (virt s)"]);
    let mut csv = String::from("partitioner,imbalance,time_to_target\n");
    let fstar = coordinator::oracle_objective(&ds, &cfg);
    for p in [
        Partitioner::BalancedNnz,
        Partitioner::Range,
        Partitioner::RoundRobin,
        Partitioner::Random,
    ] {
        let parts = Partitioning::build(p, &ds.a, cfg.workers, cfg.seed);
        let imb = parts.imbalance(&ds.a);
        let mut c = cfg.clone();
        c.partitioner = p;
        let rep = run_to_target(Impl::Mpi, &ds, &c, fstar, opts);
        let t = rep
            .time_to_target
            .map(|t| format!("{:.4}", t))
            .unwrap_or_else(|| "not reached".into());
        table.row(vec![p.name().to_string(), format!("{:.3}", imb), t.clone()]);
        csv.push_str(&format!("{},{:.6},{}\n", p.name(), imb, t));
    }
    out.push_str(&table.render());
    out.push_str("\npaper: the custom balanced-nnz partitioning 'performs comparable to the SPARK partitioning' — load balance matters at higher skew.\n");
    opts.save("ablation_partitioner.csv", &csv);
    out
}

pub fn minibatch_cd(opts: &ExpOptions) -> String {
    use crate::data::WorkerData;
    use crate::solver::{minibatch_cd::MiniBatchCd, scd::NativeScd, LocalSolver, SolveRequest};

    let ds = opts.dataset();
    let cfg = opts.config(&ds);
    let fstar = coordinator::oracle_objective(&ds, &cfg);
    let parts = Partitioning::build(cfg.partitioner, &ds.a, cfg.workers, cfg.seed);
    let workers: Vec<WorkerData> = parts
        .parts
        .iter()
        .map(|c| WorkerData::from_columns(&ds.a, c))
        .collect();

    let run = |use_cocoa: bool, rounds: usize| -> Vec<f64> {
        let mut alphas: Vec<Vec<f64>> = workers.iter().map(|w| vec![0.0; w.n_local()]).collect();
        let mut v = vec![0.0; ds.m()];
        let mut solvers: Vec<Box<dyn LocalSolver>> = workers
            .iter()
            .map(|_| -> Box<dyn LocalSolver> {
                if use_cocoa {
                    Box::new(NativeScd::new())
                } else {
                    Box::new(MiniBatchCd::new())
                }
            })
            .collect();
        let mut subopts = Vec::new();
        for round in 0..rounds {
            let mut agg = vec![0.0; ds.m()];
            for (w, solver) in solvers.iter_mut().enumerate() {
                let req = SolveRequest {
                    v: &v,
                    b: &ds.b,
                    h: workers[w].n_local(),
                    problem: &cfg.problem,
                    sigma: cfg.sigma(),
                    seed: round as u64 * 31 + w as u64,
                };
                let res = solver.solve(&workers[w], &alphas[w], &req);
                crate::linalg::add_assign(&mut alphas[w], &res.delta_alpha);
                crate::linalg::add_assign(&mut agg, &res.delta_v);
            }
            crate::linalg::add_assign(&mut v, &agg);
            let mut alpha = vec![0.0; ds.n()];
            for (wd, al) in workers.iter().zip(alphas.iter()) {
                for (&g, &a) in wd.global_ids.iter().zip(al.iter()) {
                    alpha[g as usize] = a;
                }
            }
            subopts.push(coordinator::suboptimality(
                cfg.problem.primal(&ds, &alpha),
                fstar,
            ));
        }
        subopts
    };

    let rounds = 40;
    let cocoa = run(true, rounds);
    let mb = run(false, rounds);
    let mut out = String::from("Ablation: CoCoA (immediate local updates) vs classical mini-batch CD\n\n");
    let mut table = Table::new(&["round", "CoCoA subopt", "mini-batch CD subopt"]);
    let mut csv = String::from("round,cocoa,minibatch_cd\n");
    for r in [0, 4, 9, 19, rounds - 1] {
        table.row(vec![
            (r + 1).to_string(),
            format!("{:.3e}", cocoa[r]),
            format!("{:.3e}", mb[r]),
        ]);
        csv.push_str(&format!("{},{:.9e},{:.9e}\n", r + 1, cocoa[r], mb[r]));
    }
    out.push_str(&table.render());
    out.push_str("\npaper §2.1: immediate local updates are why CoCoA needs far fewer rounds at equal H.\n");
    opts.save("ablation_minibatch_cd.csv", &csv);
    out
}

pub fn adaptive_h(opts: &ExpOptions) -> String {
    let ds = opts.dataset();
    let cfg = opts.config(&ds);
    let fstar = coordinator::oracle_objective(&ds, &cfg);
    let mut out = String::from("Ablation: adaptive-H controller vs grid-tuned H (§6 future work)\n\n");
    let mut table = Table::new(&["impl", "grid-tuned (virt s)", "adaptive (virt s)", "grid cost (runs)"]);
    let mut csv = String::from("impl,tuned_time,adaptive_time\n");
    for (imp, target_frac) in [(Impl::Mpi, 0.9), (Impl::SparkC, 0.75), (Impl::PySparkC, 0.6)] {
        let make = || make_engine(imp, &ds, &cfg, opts);
        let (points, best) = tuner::grid_search_h(&make, &ds, &cfg, fstar, &tuner::DEFAULT_H_GRID);
        let tuned = points[best].report.time_to_target;
        let adaptive = Session::builder(&ds)
            .engine(imp)
            .options(opts.engine_options())
            .config(cfg.clone())
            .oracle(fstar)
            .adaptive_h(target_frac)
            .build()
            .expect("invalid adaptive-h ablation config")
            .run();
        table.row(vec![
            imp.name().to_string(),
            tuned.map(|t| format!("{:.4}", t)).unwrap_or_else(|| "-".into()),
            adaptive
                .time_to_target
                .map(|t| format!("{:.4}", t))
                .unwrap_or_else(|| "-".into()),
            format!("{}", points.len()),
        ]);
        csv.push_str(&format!(
            "{},{},{}\n",
            imp.name(),
            tuned.map(|t| t.to_string()).unwrap_or_default(),
            adaptive.time_to_target.map(|t| t.to_string()).unwrap_or_default()
        ));
    }
    out.push_str(&table.render());
    out.push_str("\nadaptive-H reaches the target in ONE run (no grid), at a modest premium over the oracle-tuned H.\n");
    opts.save("ablation_adaptive_h.csv", &csv);
    out
}

pub fn gamma(opts: &ExpOptions) -> String {
    let ds = opts.dataset();
    let base = opts.config(&ds);
    let fstar = coordinator::oracle_objective(&ds, &base);
    let mut out = String::from("Ablation: CoCoA⁺ aggregation γ (adding=1 vs averaging=1/K)\n\n");
    let mut table = Table::new(&["gamma", "sigma'", "rounds to 1e-3", "reached"]);
    let mut csv = String::from("gamma,sigma,rounds,reached\n");
    for gamma in [1.0, 0.5, 1.0 / base.workers as f64] {
        let mut cfg = base.clone();
        cfg.gamma = gamma;
        let rep = run_to_target(Impl::Mpi, &ds, &cfg, fstar, opts);
        table.row(vec![
            format!("{:.3}", gamma),
            format!("{:.2}", cfg.sigma()),
            rep.rounds.to_string(),
            rep.time_to_target.is_some().to_string(),
        ]);
        csv.push_str(&format!(
            "{},{},{},{}\n",
            gamma,
            cfg.sigma(),
            rep.rounds,
            rep.time_to_target.is_some()
        ));
    }
    out.push_str(&table.render());
    out.push_str("\nCoCoA⁺ (Ma et al. 2015): 'adding' (γ=1, σ'=K) dominates 'averaging' — fewer rounds at equal safety.\n");
    opts.save("ablation_gamma.csv", &csv);
    out
}

pub fn async_ps(opts: &ExpOptions) -> String {
    use crate::framework::param_server::ParamServerSim;
    let ds = opts.dataset();
    let cfg = opts.config(&ds);
    let fstar = coordinator::oracle_objective(&ds, &cfg);
    let parts = Partitioning::build(cfg.partitioner, &ds.a, cfg.workers, cfg.seed);
    let h = ds.n() / cfg.workers; // H = n_local

    let mut out = String::from(
        "Ablation: synchronous CoCoA vs asynchronous parameter server (staleness sweep)\n\n",
    );
    let mut table = Table::new(&["staleness", "epochs to 1e-3", "relative epochs"]);
    let mut csv = String::from("staleness,epochs\n");
    let mut base = None;
    for s_val in [0usize, 1, 2, 4, 8] {
        let mut ps = ParamServerSim::new(&ds, &parts, &cfg, s_val);
        let epochs = ps.epochs_to_target(&ds, fstar, cfg.target_subopt, h, 20_000);
        let e = epochs.map(|e| e as f64);
        if s_val == 0 {
            base = e;
        }
        table.row(vec![
            s_val.to_string(),
            epochs.map(|e| e.to_string()).unwrap_or_else(|| "> 20000".into()),
            match (base, e) {
                (Some(b), Some(e)) => format!("{:.2}×", e / b),
                _ => "-".into(),
            },
        ]);
        csv.push_str(&format!(
            "{},{}\n",
            s_val,
            epochs.map(|e| e.to_string()).unwrap_or_default()
        ));
    }
    out.push_str(&table.render());
    out.push_str("\nstaleness removes barriers (cheaper epochs) but costs convergence — the trade the paper's §1 cites for avoiding parameter servers in a controlled study.\n");
    opts.save("ablation_async_ps.csv", &csv);
    out
}

pub fn broadcast(opts: &ExpOptions) -> String {
    let ds = opts.dataset();
    let mut cfg = opts.config(&ds);
    cfg.h_frac = 1.0;
    let mut out = String::from("Ablation: driver-star vs TorrentBroadcast for (B), scaling in K\n\n");
    let mut table = Table::new(&["K", "star overhead (s)", "torrent overhead (s)"]);
    let mut csv = String::from("workers,star,torrent\n");
    for k in [4usize, 8, 16] {
        let mut c = cfg.clone();
        c.workers = k;
        let run = |torrent: bool| -> f64 {
            let mut eopts = opts.engine_options();
            eopts.torrent_broadcast = torrent;
            Session::builder(&ds)
                .engine(Impl::SparkC)
                .options(eopts)
                .config(c.clone())
                .stop(StopPolicy::FixedRounds { n: 30 })
                .build()
                .expect("invalid broadcast ablation config")
                .run()
                .total_overhead
        };
        let star = run(false);
        let torrent = run(true);
        table.row(vec![
            k.to_string(),
            format!("{:.4}", star),
            format!("{:.4}", torrent),
        ]);
        csv.push_str(&format!("{},{:.6},{:.6}\n", k, star, torrent));
    }
    out.push_str(&table.render());
    out.push_str("\nTorrentBroadcast removes the driver-NIC bottleneck; the gap widens with K (why Spark 1.5 made it the default).\n");
    opts.save("ablation_broadcast.csv", &csv);
    out
}

#[allow(unused)]
fn unused_train_config_guard(_c: &TrainConfig) {}
