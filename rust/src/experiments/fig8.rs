//! Figure 8: time to 1e-3 vs number of workers N, parameters re-optimized
//! per point, plus the zero-communication ideal MPI line.
//!
//! Expected shape (paper §5.6): MPI tracks the ideal closely (flat-ish
//! scaling); Spark variants flatten early and can *degrade* with N as
//! per-worker overheads grow; Spark needs ≥ 4 workers (memory) — we keep
//! that constraint for authenticity.

use super::common::{make_engine, ExpOptions};
use crate::config::Impl;
use crate::coordinator::{self, tuner};
use crate::metrics::{AsciiPlot, Table};

/// Worker counts swept (paper: 1..16 for MPI, 4..16 for Spark).
pub const WORKER_GRID: [usize; 5] = [2, 4, 8, 12, 16];

/// A reduced H grid per point keeps the re-optimization tractable.
const H_GRID: [f64; 5] = [0.2, 0.5, 1.0, 2.0, 4.0];

pub fn run(opts: &ExpOptions) -> String {
    let ds = opts.dataset();
    let impls = [Impl::SparkC, Impl::PySparkC, Impl::Mpi];
    let markers = ['B', 'D', 'E'];

    let mut out = String::new();
    out.push_str("Figure 8 — time-to-1e-3 vs workers N (H re-tuned per point)\n\n");
    let mut plot = AsciiPlot::new(72, 16).log_y();
    let mut table = Table::new(&["impl", "N", "H*/n_local", "time (virt s)"]);
    let mut csv = String::from("impl,workers,h_frac,time_to_target\n");

    for (imp, marker) in impls.iter().zip(markers.iter()) {
        let mut series = Vec::new();
        for &n in WORKER_GRID.iter() {
            // Spark could not run below 4 workers on the paper's cluster.
            if *imp != Impl::Mpi && n < 4 {
                continue;
            }
            let mut cfg = opts.config(&ds);
            cfg.workers = n;
            let fstar = coordinator::oracle_objective(&ds, &cfg);
            let make = || make_engine(*imp, &ds, &cfg, opts);
            let (points, best) = tuner::grid_search_h(&make, &ds, &cfg, fstar, &H_GRID);
            if let Some(t) = points[best].report.time_to_target {
                series.push((n as f64, t));
                csv.push_str(&format!(
                    "{},{},{},{:.6}\n",
                    imp.name(),
                    n,
                    points[best].h_frac,
                    t
                ));
                table.row(vec![
                    imp.name().to_string(),
                    n.to_string(),
                    format!("{:.2}", points[best].h_frac),
                    format!("{:.4}", t),
                ]);
            }
        }
        plot = plot.series(imp.name(), *marker, series);
    }

    // Zero-communication ideal: MPI worker-compute only (dashed line in the
    // paper). Computed by re-running MPI and charging only t_worker.
    let mut ideal = Vec::new();
    for &n in WORKER_GRID.iter() {
        let mut cfg = opts.config(&ds);
        cfg.workers = n;
        let fstar = coordinator::oracle_objective(&ds, &cfg);
        let make = || make_engine(Impl::Mpi, &ds, &cfg, opts);
        let (points, best) = tuner::grid_search_h(&make, &ds, &cfg, fstar, &H_GRID);
        let rep = &points[best].report;
        if rep.time_to_target.is_some() {
            // worker-compute time accumulated until the target round
            let t_ideal: f64 = rep.logs.iter().map(|l| l.timing.t_worker).sum();
            ideal.push((n as f64, t_ideal));
            csv.push_str(&format!("ideal-mpi,{},{},{:.6}\n", n, points[best].h_frac, t_ideal));
        }
    }
    plot = plot.series("ideal (zero-comm MPI)", '·', ideal);

    out.push_str(&table.render());
    out.push('\n');
    out.push_str(&plot.render());
    out.push_str("\npaper checkpoints: MPI ≈ flat and near the ideal line; Spark impls flatten/degrade as N grows (overheads scale with N).\n");
    opts.save("fig8_scaling.csv", &csv);
    out
}
