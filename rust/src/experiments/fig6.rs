//! Figure 6: time to suboptimality 1e-3 as a function of H for
//! implementations (A)–(E) — the communication-computation trade-off.
//!
//! Expected shape (paper §5.5): U-shaped curves; optimal H differs per
//! implementation — higher-overhead frameworks need larger H; H*(D) ≈ 25×
//! H*(C); running (D) at H*(E) "would more than double its training time".

use super::common::{make_engine, ExpOptions};
use crate::config::Impl;
use crate::coordinator::{self, tuner};
use crate::metrics::{AsciiPlot, Table};

pub fn run(opts: &ExpOptions) -> String {
    let ds = opts.dataset();
    let cfg = opts.config(&ds);
    let fstar = coordinator::oracle_objective(&ds, &cfg);
    let grid = tuner::DEFAULT_H_GRID;

    let mut out = String::new();
    out.push_str(&format!(
        "Figure 6 — time-to-1e-3 vs H/n_local (K={}, grid {:?})\n\n",
        cfg.workers, grid
    ));

    let markers = ['A', 'B', 'C', 'D', 'E'];
    let mut plot = AsciiPlot::new(72, 18).log_x().log_y();
    let mut table = Table::new(&["impl", "H*/n_local", "best time (virt s)"]);
    let mut csv = String::from("impl,h_frac,time_to_target,reached\n");
    let mut best_h: Vec<(Impl, f64, f64)> = Vec::new();
    let mut all_points: Vec<(Impl, Vec<tuner::HPoint>)> = Vec::new();

    for (imp, marker) in Impl::ALL_PAPER.iter().zip(markers.iter()) {
        let make = || make_engine(*imp, &ds, &cfg, opts);
        let (points, best) = tuner::grid_search_h(&make, &ds, &cfg, fstar, &grid);
        let series: Vec<(f64, f64)> = points
            .iter()
            .filter_map(|p| p.report.time_to_target.map(|t| (p.h_frac, t)))
            .collect();
        for p in &points {
            csv.push_str(&format!(
                "{},{},{},{}\n",
                imp.name(),
                p.h_frac,
                p.report
                    .time_to_target
                    .map(|t| format!("{:.6}", t))
                    .unwrap_or_default(),
                p.report.time_to_target.is_some()
            ));
        }
        let best_time = points[best].report.time_to_target.unwrap_or(f64::NAN);
        table.row(vec![
            imp.name().to_string(),
            format!("{:.2}", points[best].h_frac),
            format!("{:.4}", best_time),
        ]);
        best_h.push((*imp, points[best].h_frac, best_time));
        plot = plot.series(imp.name(), *marker, series);
        all_points.push((*imp, points));
    }

    out.push_str(&table.render());
    out.push('\n');
    out.push_str(&plot.render());

    // §5.5 cross-evaluation: run (D) at H*(E).
    let h_e = best_h.iter().find(|(i, _, _)| *i == Impl::Mpi).unwrap().1;
    let (d_imp, d_points) = all_points
        .iter()
        .find(|(i, _)| *i == Impl::PySparkC)
        .unwrap();
    let d_best = best_h.iter().find(|(i, _, _)| *i == *d_imp).unwrap();
    let d_at_he = d_points
        .iter()
        .min_by(|a, b| {
            (a.h_frac - h_e)
                .abs()
                .partial_cmp(&(b.h_frac - h_e).abs())
                .unwrap()
        })
        .unwrap();
    if let (Some(t_cross), t_best) = (d_at_he.report.time_to_target, d_best.2) {
        out.push_str(&format!(
            "\ncross-evaluation (§5.5): running (D) at H*(E)={:.2} takes {:.4} s vs {:.4} s tuned → {:.2}× slower (paper: 'more than double')\n",
            d_at_he.h_frac,
            t_cross,
            t_best,
            t_cross / t_best
        ));
    }

    // Ordering check: H* should grow with framework overhead.
    let h_of = |imp: Impl| best_h.iter().find(|(i, _, _)| *i == imp).unwrap().1;
    out.push_str(&format!(
        "H* ordering: E={:.2} ≤ B={:.2}, C={:.2} ≤ D={:.2} (paper: optimal H grows with overhead; H*(D) ≫ H*(C))\n",
        h_of(Impl::Mpi),
        h_of(Impl::SparkC),
        h_of(Impl::PySpark),
        h_of(Impl::PySparkC),
    ));

    opts.save("fig6_h_sweep.csv", &csv);
    out
}
