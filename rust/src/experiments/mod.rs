//! Experiment harness: one module per paper figure, regenerating the same
//! rows/series the paper reports (DESIGN.md §4, experiment index).
//!
//! Every experiment writes CSV to `--out-dir` (default `results/`) and
//! returns an ASCII rendition for stdout. Absolute virtual seconds are not
//! comparable to the paper's testbed; *ratios, orderings, optimal-H
//! positions and curve shapes* are the reproduction targets.

pub mod ablations;
pub mod common;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;

pub use common::ExpOptions;

/// Dispatch a figure by number.
pub fn run_figure(n: usize, opts: &ExpOptions) -> Result<String, String> {
    match n {
        2 => Ok(fig2::run(opts)),
        3 => Ok(fig3::run(opts)),
        4 => Ok(fig4::run(opts)),
        5 => Ok(fig5::run(opts)),
        6 => Ok(fig6::run(opts)),
        7 => Ok(fig7::run(opts)),
        8 => Ok(fig8::run(opts)),
        _ => Err(format!("no figure {} in the paper (2-8 exist)", n)),
    }
}

/// Dispatch an ablation by name.
pub fn run_ablation(name: &str, opts: &ExpOptions) -> Result<String, String> {
    match name {
        "layout" => Ok(ablations::layout(opts)),
        "partitioner" => Ok(ablations::partitioner(opts)),
        "minibatch-cd" => Ok(ablations::minibatch_cd(opts)),
        "adaptive-h" => Ok(ablations::adaptive_h(opts)),
        "gamma" => Ok(ablations::gamma(opts)),
        "async-ps" => Ok(ablations::async_ps(opts)),
        "broadcast" => Ok(ablations::broadcast(opts)),
        _ => Err(format!(
            "unknown ablation '{}' (layout, partitioner, minibatch-cd, adaptive-h, gamma, async-ps, broadcast)",
            name
        )),
    }
}
