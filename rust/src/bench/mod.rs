//! Mini-criterion: the benchmark harness used by `cargo bench` targets
//! (criterion is unavailable in the offline build image — DESIGN.md
//! §Offline-toolchain substitution).
//!
//! Provides warmup, adaptive iteration counts, and mean/median/stddev
//! reporting, plus a suite runner that renders a results table and writes
//! CSV next to the paper-figure outputs.

use std::time::Instant;

use crate::linalg;

/// Statistics of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStats {
    pub fn throughput_per_s(&self) -> f64 {
        if self.mean_s > 0.0 {
            1.0 / self.mean_s
        } else {
            f64::INFINITY
        }
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Minimum measurement time per benchmark.
    pub min_time_s: f64,
    /// Max samples to collect.
    pub max_samples: usize,
    /// Warmup iterations.
    pub warmup: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            min_time_s: 0.5,
            max_samples: 50,
            warmup: 2,
        }
    }
}

impl Bencher {
    /// Quick profile for slow end-to-end benches (figure regenerations).
    pub fn quick() -> Bencher {
        Bencher {
            min_time_s: 0.0,
            max_samples: 3,
            warmup: 0,
        }
    }

    /// Run `f` repeatedly, returning timing statistics. The closure's
    /// return value is black-boxed so the optimizer cannot elide work.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchStats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        // real wall time is the measurement (bench allowlist)
        #[allow(clippy::disallowed_methods)]
        let start = Instant::now();
        loop {
            // real wall time is the measurement (bench allowlist)
            #[allow(clippy::disallowed_methods)]
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
            if samples.len() >= self.max_samples
                || (samples.len() >= 3 && start.elapsed().as_secs_f64() > self.min_time_s)
            {
                break;
            }
        }
        BenchStats {
            name: name.to_string(),
            samples: samples.len(),
            mean_s: linalg::mean(&samples),
            median_s: linalg::median(&samples),
            stddev_s: linalg::stddev(&samples),
            min_s: samples.iter().cloned().fold(f64::MAX, f64::min),
            max_s: samples.iter().cloned().fold(f64::MIN, f64::max),
        }
    }
}

/// Render a set of results as a table (used by every bench binary).
pub fn render_results(title: &str, stats: &[BenchStats]) -> String {
    let mut t = crate::metrics::Table::new(&["benchmark", "samples", "mean", "median", "stddev"]);
    for s in stats {
        t.row(vec![
            s.name.clone(),
            s.samples.to_string(),
            crate::util::fmt_duration(s.mean_s),
            crate::util::fmt_duration(s.median_s),
            crate::util::fmt_duration(s.stddev_s),
        ]);
    }
    format!("== {} ==\n{}", title, t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_positive_time() {
        let b = Bencher {
            min_time_s: 10.0, // never trips → runs to max_samples
            max_samples: 5,
            warmup: 1,
        };
        let s = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(s.samples, 5);
        assert!(s.mean_s > 0.0);
        assert!(s.min_s <= s.median_s && s.median_s <= s.max_s);
    }

    #[test]
    fn stats_are_consistent() {
        let b = Bencher {
            min_time_s: 0.0,
            max_samples: 8,
            warmup: 0,
        };
        let s = b.run("noop", || 1 + 1);
        assert!(s.mean_s >= 0.0);
        assert!(s.stddev_s >= 0.0);
        assert!(s.throughput_per_s() > 0.0);
    }

    #[test]
    fn render_contains_rows() {
        let b = Bencher::quick();
        let s = b.run("x", || 0);
        let out = render_results("suite", &[s]);
        assert!(out.contains("suite"));
        assert!(out.contains("| x"));
    }
}
