//! `sparkbench` — leader entrypoint and CLI.
//!
//! ```text
//! sparkbench train     --impl mpi --workers 8 [--h-frac 1.0] [--lambda-n X]
//! sparkbench figure N  [--workers 8] [--scale mini] [--out-dir results]
//! sparkbench figures   # regenerate 2..8
//! sparkbench ablation <layout|partitioner|minibatch-cd|adaptive-h|gamma>
//! sparkbench sweep-h   --impl d [--grid 0.1,0.5,1,4]
//! sparkbench calibrate
//! sparkbench partition-stats [--workers 8]
//! sparkbench list-artifacts
//! sparkbench pjrt-smoke   # load + run the AOT artifact end to end
//! sparkbench predict --ckpt FILE [--scale S] [--shards N]
//! sparkbench serve   --ckpt FILE [--rate R] [--max-batch B] [--deadline-us D]
//!                    [--queue-cap N --shed]
//! ```
//!
//! `train --ckpt-dir DIR` keeps a durable checkpoint store and resumes
//! from it automatically on rerun; `serve --shed` replays through the
//! admission-controlled overload harness (DESIGN.md §15).

use std::path::PathBuf;

use sparkbench::config::Impl;
use sparkbench::coordinator::{self, tuner};
use sparkbench::data::{Partitioner, Partitioning};
use sparkbench::experiments::{run_ablation, run_figure, ExpOptions};
use sparkbench::framework::Engine;
use sparkbench::metrics::Table;
use sparkbench::problem::Problem;
use sparkbench::session::{CheckpointEvery, CsvTrace, Session, StopPolicy};
use sparkbench::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let code = match args.subcommand.as_deref() {
        Some("train") => cmd_train(&args),
        Some("figure") => cmd_figure(&args),
        Some("figures") => cmd_figures(&args),
        Some("ablation") => cmd_ablation(&args),
        Some("sweep-h") => cmd_sweep_h(&args),
        Some("calibrate") => cmd_calibrate(),
        Some("partition-stats") => cmd_partition_stats(&args),
        Some("list-artifacts") => cmd_list_artifacts(),
        Some("pjrt-smoke") => cmd_pjrt_smoke(&args),
        Some("predict") => cmd_predict(&args),
        Some("serve") => cmd_serve(&args),
        Some(other) => {
            eprintln!("unknown subcommand '{}'\n", other);
            usage();
            2
        }
        None => {
            usage();
            2
        }
    };
    std::process::exit(code);
}

fn usage() {
    eprintln!("{}", include_str!("usage.txt"));
}

fn exp_options(args: &Args) -> ExpOptions {
    ExpOptions {
        workers: args.get_usize("workers", 8),
        scale: args.get_str("scale", "mini").to_string(),
        out_dir: PathBuf::from(args.get_str("out-dir", "results")),
        seeds: args.get_usize("seeds", 3),
        real_managed: args.flag("real-managed"),
        lam_n: args.get("lambda-n").and_then(|s| s.parse().ok()),
    }
}

fn parse_impl(args: &Args) -> Option<Impl> {
    Impl::parse(args.get_str("impl", "mpi"))
}

fn cmd_train(args: &Args) -> i32 {
    let opts = exp_options(args);
    // --impl reaches the FULL registry: the eight paper impls plus
    // `threads[:K]` and `ps[:STALENESS]` / `param-server`.
    let Some(engine) = Engine::parse(args.get_str("impl", "mpi")) else {
        eprintln!("bad --impl (try: a, b, b*, c, d, d*, mpi, mllib, threads[:K], ps[:S])");
        return 2;
    };
    let ds = opts.dataset();
    let mut cfg = opts.config(&ds);
    cfg.h_frac = args.get_f64("h-frac", 1.0);
    if let Some(h) = args.get("h") {
        cfg.h_abs = h.parse().ok();
    }
    cfg.max_rounds = args.get_usize("max-rounds", cfg.max_rounds);
    cfg.target_subopt = args.get_f64("target", cfg.target_subopt);
    if let Some(p) = args.get("partitioner").and_then(Partitioner::parse) {
        cfg.partitioner = p;
    }
    // --problem opens the full workload family (λ·n still comes from
    // --lambda-n, already folded into the config's problem).
    if let Some(spec) = args.get("problem") {
        match Problem::parse(spec, cfg.lam_n()) {
            Ok(p) => cfg.problem = p,
            Err(e) => {
                eprintln!("{}", e);
                return 2;
            }
        }
    }
    // --precision f64|mixed-f32: numeric mode of the native solver's
    // inner loop (mixed-f32 = f32 storage mirrors, f64 accumulation; the
    // session rejects it for impls without the native solver).
    if let Some(s) = args.get("precision") {
        match sparkbench::config::Precision::parse(s) {
            Some(p) => cfg.precision = p,
            None => {
                eprintln!("bad --precision '{}' (want f64 or mixed-f32)", s);
                return 2;
            }
        }
    }
    // --threads-per-worker T: nested two-level parallelism — T local
    // sub-solvers per worker, bit-identical to a flat K·T ring (an
    // explicit `--impl threads:K:T` wins over the flag).
    let tpw_flag = match args.get("threads-per-worker") {
        Some(s) => match s.parse::<usize>() {
            Ok(t) if t >= 1 => Some(t),
            _ => {
                eprintln!("bad --threads-per-worker '{}' (want an integer >= 1)", s);
                return 2;
            }
        },
        None => None,
    };
    // `threads:K` overrides the configured worker count inside the builder;
    // report the counts the session will actually run with.
    let eff_workers = match engine {
        Engine::Threads { k, .. } if k > 0 => k,
        _ => cfg.workers,
    };
    let eff_t = match engine {
        Engine::Threads { t, .. } if t > 0 => t,
        Engine::Impl(Impl::MllibSgd) => 1,
        _ => tpw_flag.unwrap_or(1),
    };
    println!(
        "training {} [{}] on {} (K={}, T={}, H={})",
        engine.label(),
        cfg.problem.label(),
        ds.name,
        eff_workers,
        eff_t,
        cfg.h_for(ds.n() / (eff_workers * eff_t))
    );

    let mut builder = Session::builder(&ds).engine(engine).config(cfg.clone());
    if let Some(t) = tpw_flag {
        builder = builder.threads_per_worker(t);
    }
    // --chaos SPEC: seeded stragglers, skew and failure injection with
    // speculative recovery (DESIGN.md §12). Grammar: comma-separated
    // seed=N, het=F, jitter=F, spec, death@R[:W], slow@R[:W]:F, crash@R
    // (crash kills the coordinator after the round-R store write).
    if let Some(s) = args.get("chaos") {
        match sparkbench::framework::chaos::ChaosSpec::parse(s) {
            Ok(spec) => builder = builder.chaos(spec),
            Err(e) => {
                eprintln!("{}", e);
                return 2;
            }
        }
    }
    // Fixed-rounds timing runs (Figure 3/4 methodology) skip the oracle.
    if let Some(s) = args.get("fixed-rounds") {
        let Ok(n) = s.parse() else {
            eprintln!("bad --fixed-rounds '{}' (want a round count)", s);
            return 2;
        };
        builder = builder.stop(StopPolicy::FixedRounds { n });
    }
    // Certificate-based stopping: no CG oracle, works for every problem.
    if let Some(s) = args.get("to-gap") {
        if args.get("fixed-rounds").is_some() {
            eprintln!("--to-gap and --fixed-rounds are conflicting stop policies; pick one");
            return 2;
        }
        let Ok(gap) = s.parse() else {
            eprintln!("bad --to-gap '{}' (want a relative gap, e.g. 1e-4)", s);
            return 2;
        };
        builder = builder.stop(StopPolicy::ToGap { gap });
    }
    // §5.5 controller instead of a fixed H.
    if let Some(s) = args.get("adaptive-h") {
        let Ok(frac) = s.parse() else {
            eprintln!("bad --adaptive-h '{}' (want a compute fraction, e.g. 0.9)", s);
            return 2;
        };
        builder = builder.adaptive_h(frac);
    }
    // Streaming observers: incremental CSV trace and periodic checkpoints.
    if let Some(path) = args.get("trace") {
        match CsvTrace::create(path) {
            Ok(obs) => builder = builder.observe(obs),
            Err(e) => {
                eprintln!("cannot open --trace {}: {}", path, e);
                return 2;
            }
        }
    }
    if let Some(path) = args.get("ckpt") {
        let every = args.get_usize("ckpt-every", 50);
        builder = builder.observe(CheckpointEvery::new(every, path));
    }
    // --ckpt-dir DIR: the durable checkpoint store (DESIGN.md §15) — v6
    // CRC-footed envelopes written atomically every --ckpt-every rounds,
    // newest --ckpt-keep retained. Rerunning the SAME command after a
    // crash (or `--chaos crash@R`) resumes from the newest envelope that
    // decodes clean; corrupt or truncated tail files are skipped.
    if let Some(dir) = args.get("ckpt-dir") {
        let every = args.get_usize("ckpt-every", 50);
        let keep = args.get_usize("ckpt-keep", 3);
        let store = sparkbench::coordinator::checkpoint::CheckpointStore::new(dir, keep);
        if let Some((path, env)) = store.latest_valid() {
            println!(
                "resuming from {} (round {}, envelope v{})",
                path.display(),
                env.ckpt.round,
                env.version
            );
            builder = builder.resume_from(env.ckpt);
        }
        builder = builder.checkpoint_store(dir, every, keep);
    }
    let session = match builder.build() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{}", e);
            return 2;
        }
    };
    let report = session.run();

    println!(
        "rounds={} time={:.4}s (virt) worker={:.4} master={:.4} overhead={:.4}",
        report.rounds,
        report.total_time,
        report.total_worker,
        report.total_master,
        report.total_overhead
    );
    match (report.time_to_target, report.final_suboptimality) {
        (Some(t), _) => println!("reached ε={:.1e} at {:.4}s (virt)", cfg.target_subopt, t),
        (None, Some(sub)) => println!(
            "did NOT reach ε={:.1e}; final suboptimality {:.3e}",
            cfg.target_subopt, sub
        ),
        (None, None) => println!("timing run: objective not evaluated"),
    }
    opts.save(
        &format!("train_{}.csv", report.impl_name.replace([':', '*'], "_")),
        &report.trace_csv(),
    );
    0
}

fn cmd_figure(args: &Args) -> i32 {
    let opts = exp_options(args);
    let Some(n) = args.positional.first().and_then(|s| s.parse::<usize>().ok()) else {
        eprintln!("usage: sparkbench figure <2-8>");
        return 2;
    };
    match run_figure(n, &opts) {
        Ok(out) => {
            println!("{}", out);
            0
        }
        Err(e) => {
            eprintln!("{}", e);
            2
        }
    }
}

fn cmd_figures(args: &Args) -> i32 {
    let opts = exp_options(args);
    for n in 2..=8 {
        match run_figure(n, &opts) {
            Ok(out) => println!("{}\n", out),
            Err(e) => {
                eprintln!("figure {}: {}", n, e);
                return 1;
            }
        }
    }
    0
}

fn cmd_ablation(args: &Args) -> i32 {
    let opts = exp_options(args);
    let Some(name) = args.positional.first() else {
        eprintln!("usage: sparkbench ablation <layout|partitioner|minibatch-cd|adaptive-h|gamma>");
        return 2;
    };
    match run_ablation(name, &opts) {
        Ok(out) => {
            println!("{}", out);
            0
        }
        Err(e) => {
            eprintln!("{}", e);
            2
        }
    }
}

fn cmd_sweep_h(args: &Args) -> i32 {
    let opts = exp_options(args);
    let Some(imp) = parse_impl(args) else {
        eprintln!("bad --impl");
        return 2;
    };
    let grid: Vec<f64> = args
        .get_list("grid")
        .map(|l| l.iter().filter_map(|s| s.parse().ok()).collect())
        .unwrap_or_else(|| tuner::DEFAULT_H_GRID.to_vec());
    let ds = opts.dataset();
    let cfg = opts.config(&ds);
    let fstar = coordinator::oracle_objective(&ds, &cfg);
    let make = || sparkbench::experiments::common::make_engine(imp, &ds, &cfg, &opts);
    let (points, best) = tuner::grid_search_h(&make, &ds, &cfg, fstar, &grid);
    let mut table = Table::new(&["H/n_local", "rounds", "time-to-target (virt s)", "compute frac"]);
    for (i, p) in points.iter().enumerate() {
        table.row(vec![
            format!("{}{:.2}", if i == best { "*" } else { " " }, p.h_frac),
            p.report.rounds.to_string(),
            p.report
                .time_to_target
                .map(|t| format!("{:.4}", t))
                .unwrap_or_else(|| "-".into()),
            format!("{:.1}%", 100.0 * p.report.compute_fraction()),
        ]);
    }
    println!("H sweep for {} on {} (K={})", imp.name(), ds.name, cfg.workers);
    println!("{}", table.render());
    0
}

fn cmd_calibrate() -> i32 {
    println!("calibrating managed-runtime solvers against native SCD ...");
    let cal = sparkbench::solver::managed::calibrate(1);
    println!("  scala-like multiplier:  {:.2}×", cal.scala_multiplier);
    println!("  python-like multiplier: {:.2}×", cal.python_multiplier);
    println!("(paper Fig 3: Scala ≈ 10×, Python ≈ 100×+ vs the C++ module)");
    0
}

fn cmd_partition_stats(args: &Args) -> i32 {
    let opts = exp_options(args);
    let ds = opts.dataset();
    let k = opts.workers;
    let mut table = Table::new(&["partitioner", "min nnz", "max nnz", "imbalance"]);
    for p in [
        Partitioner::Range,
        Partitioner::RoundRobin,
        Partitioner::BalancedNnz,
        Partitioner::Random,
    ] {
        let parts = Partitioning::build(p, &ds.a, k, 42);
        let loads = parts.loads(&ds.a);
        table.row(vec![
            p.name().to_string(),
            loads.iter().min().unwrap().to_string(),
            loads.iter().max().unwrap().to_string(),
            format!("{:.4}", parts.imbalance(&ds.a)),
        ]);
    }
    println!("{} (m={}, n={}, nnz={}) across K={} workers", ds.name, ds.m(), ds.n(), ds.nnz(), k);
    println!("{}", table.render());
    0
}

fn cmd_list_artifacts() -> i32 {
    let dir = sparkbench::runtime::Manifest::default_dir();
    match sparkbench::runtime::Manifest::load(&dir) {
        Ok(man) => {
            println!("artifacts dir: {}", man.dir.display());
            println!(
                "  local_solve: {} (m={}, nk={}, h_max={}, vmem≈{})",
                man.local_solve_file,
                man.m,
                man.nk,
                man.h_max,
                man.vmem_bytes_estimate
                    .map(crate::fmt_b)
                    .unwrap_or_else(|| "?".into())
            );
            if let Some(obj) = man.objective_file {
                println!("  objective:  {}", obj);
            }
            0
        }
        Err(e) => {
            eprintln!("{:#}", e);
            1
        }
    }
}

/// Load a servable model from a checkpoint envelope — engine-free: no
/// dataset, no session, just the envelope bytes (DESIGN.md §13).
fn load_model(path: &str) -> Result<(u32, sparkbench::serve::PrimalModel), String> {
    let env = sparkbench::coordinator::checkpoint::Envelope::peek(std::path::Path::new(path))?;
    let model = sparkbench::serve::PrimalModel::from_checkpoint(&env.ckpt)?;
    Ok((env.version, model))
}

/// Rebuild a request set matching the model's dimension. Squared-loss
/// models predict the TEST split of the regenerated `--scale` corpus
/// (seeded `train_test_split`, labels = targets); dual-loss models score
/// a fresh separable corpus of matching dimension, whose label-scaled
/// columns carry `+1` q-space labels (a positive score = correct — see
/// `serve::OnlineEval::update`).
fn build_requests(
    args: &Args,
    model: &sparkbench::serve::PrimalModel,
) -> Result<(sparkbench::data::CsrMatrix, Vec<f64>), String> {
    use sparkbench::data::CsrMatrix;
    use sparkbench::problem::LossKind;
    match model.problem().loss {
        LossKind::Squared => {
            let opts = exp_options(args);
            let ds = opts.dataset();
            if ds.n() != model.dim() {
                return Err(format!(
                    "--scale {} regenerates a {}-feature corpus but the checkpoint trained \
                     {} features; pass the scale the model was trained on",
                    opts.scale,
                    ds.n(),
                    model.dim()
                ));
            }
            let (_, test) = sparkbench::data::train_test_split(&ds, 0.25, 42);
            Ok((CsrMatrix::from_csc(&test.a), test.b))
        }
        LossKind::Hinge | LossKind::Logistic => {
            let requests = args.get_usize("requests", 1024);
            let (ds, _) =
                sparkbench::data::synthetic::separable_classes(model.dim(), requests, 0.4, 42);
            // Columns are the datapoints; the transpose's rows are the
            // requests (a pure relabel of the CSC storage — zero copies
            // of matrix structure beyond the buffers).
            Ok((CsrMatrix::transpose_of(&ds.a), vec![1.0; requests]))
        }
    }
}

fn cmd_predict(args: &Args) -> i32 {
    let Some(path) = args.get("ckpt") else {
        eprintln!("usage: sparkbench predict --ckpt FILE [--scale S] [--shards N] [--requests N]");
        return 2;
    };
    let (version, model) = match load_model(path) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{}", e);
            return 1;
        }
    };
    println!(
        "loaded [{}] from {} (envelope v{}, dim {}, {} rounds, output: {})",
        model.problem().label(),
        path,
        version,
        model.dim(),
        model.rounds(),
        model.output().name()
    );
    let (rows, labels) = match build_requests(args, &model) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{}", e);
            return 1;
        }
    };
    let output = model.output();
    let predictor = sparkbench::serve::Predictor::new(model);
    let shards = args.get_usize("shards", 1);
    let mut preds = Vec::with_capacity(rows.m);
    #[allow(clippy::disallowed_methods)]
    // lint: allow(clock) -- CLI reports end-to-end serving wall time
    let t0 = std::time::Instant::now();
    predictor.predict_sharded_into(&rows, shards, &mut preds);
    let dt = t0.elapsed().as_secs_f64();
    use sparkbench::serve::Output;
    match output {
        Output::Value => println!(
            "rmse={:.6} r2={:.4} over {} held-out rows",
            sparkbench::data::rmse(&preds, &labels),
            sparkbench::data::eval::r2(&preds, &labels),
            preds.len()
        ),
        Output::Score | Output::Probability => {
            let mut ev = sparkbench::serve::OnlineEval::new(output);
            ev.update(&preds, &labels);
            println!("{} over {} fresh datapoints", ev.summary(), preds.len());
        }
    }
    println!(
        "{} predictions in {:.6}s ({:.0} preds/s, {} shard(s))",
        preds.len(),
        dt,
        preds.len() as f64 / dt.max(1e-12),
        shards.max(1)
    );
    0
}

fn cmd_serve(args: &Args) -> i32 {
    let Some(path) = args.get("ckpt") else {
        eprintln!(
            "usage: sparkbench serve --ckpt FILE [--rate R] [--max-batch B] \
             [--deadline-us D] [--shards N] [--requests N] [--queue-cap N --shed]"
        );
        return 2;
    };
    let (version, model) = match load_model(path) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{}", e);
            return 1;
        }
    };
    println!(
        "serving [{}] from {} (envelope v{}, dim {}, {} rounds, output: {})",
        model.problem().label(),
        path,
        version,
        model.dim(),
        model.rounds(),
        model.output().name()
    );
    let (rows, labels) = match build_requests(args, &model) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{}", e);
            return 1;
        }
    };
    let max_batch = args.get_usize("max-batch", 64);
    let deadline_us = args.get_f64("deadline-us", 1000.0);
    if max_batch < 1 || !deadline_us.is_finite() || deadline_us <= 0.0 {
        eprintln!("--max-batch must be >= 1 and --deadline-us > 0");
        return 2;
    }
    let policy = sparkbench::serve::BatchPolicy::new(max_batch, deadline_us * 1e-6);
    // Default arrival rate: 4× the cutover — the size-bound regime.
    let rate = args.get_f64("rate", 4.0 * policy.cutover_rate());
    if !rate.is_finite() || rate <= 0.0 {
        eprintln!("--rate must be > 0 requests/sec");
        return 2;
    }
    let shards = args.get_usize("shards", 1);
    println!(
        "policy: max_batch={} deadline={:.0}µs (cutover λ*={:.0}/s); \
         replaying {} requests at {:.0}/s, {} shard(s)",
        max_batch,
        deadline_us,
        policy.cutover_rate(),
        rows.m,
        rate,
        shards.max(1)
    );
    // --shed: route the replay through admission control instead
    // (DESIGN.md §15) — bounded --queue-cap queue, typed load shedding,
    // degraded deadlines — under a seeded storm at --rate. The virtual
    // service model is pinned to the policy (a full batch costs exactly
    // one deadline), so the sustainable rate equals λ* and the default
    // 4λ* arrival rate is overload by construction.
    if args.flag("shed") {
        let queue_cap = args.get_usize("queue-cap", 4 * max_batch);
        if queue_cap < max_batch {
            eprintln!("--queue-cap must be >= --max-batch");
            return 2;
        }
        let deadline_s = deadline_us * 1e-6;
        let ocfg = sparkbench::serve::OverloadConfig {
            queue_cap,
            service: sparkbench::serve::ServiceModel {
                overhead_s: 0.5 * deadline_s,
                per_row_s: 0.5 * deadline_s / max_batch as f64,
            },
            malformed_every: args.get_usize("malformed-every", 0),
            swap_at_batch: None,
            seed: args.get_usize("seed", 42) as u64,
        };
        let pattern = sparkbench::serve::ArrivalPattern::Storm { rate };
        let mut preds = Vec::new();
        let st = sparkbench::serve::overload_replay(
            &model,
            None,
            &rows,
            &policy,
            &pattern,
            &ocfg,
            &mut preds,
        );
        println!(
            "overload: offered={} admitted={} shed={} ({:.1}% shed) malformed={}",
            st.offered,
            st.admitted,
            st.shed,
            100.0 * st.shed_rate,
            st.malformed
        );
        println!(
            "  batches={} degraded={} ({:.1}% occupancy) max_depth={}/{} \
             p50={:.0}µs p99={:.0}µs",
            st.batches,
            st.degraded_batches,
            100.0 * st.degraded_occupancy,
            st.max_depth,
            queue_cap,
            st.p50_latency_s * 1e6,
            st.p99_latency_s * 1e6
        );
        return 0;
    }
    let predictor = sparkbench::serve::Predictor::new(model);
    let mut preds = Vec::new();
    let stats = sparkbench::serve::replay(
        &predictor,
        &rows,
        Some(&labels),
        policy,
        rate,
        shards,
        &mut preds,
    );
    println!("{}", stats.render());
    0
}

pub(crate) fn fmt_b(b: u64) -> String {
    sparkbench::util::fmt_bytes(b)
}

#[cfg(not(feature = "pjrt"))]
fn cmd_pjrt_smoke(_args: &Args) -> i32 {
    eprintln!(
        "pjrt support is not compiled into this binary; rebuild with \
         `cargo build --features pjrt` (requires the xla crate — see rust/README.md)"
    );
    2
}

#[cfg(feature = "pjrt")]
fn cmd_pjrt_smoke(args: &Args) -> i32 {
    use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
    use sparkbench::data::WorkerData;
    use sparkbench::runtime::{Manifest, PjrtRuntime};
    use sparkbench::solver::{pjrt::PjrtScd, scd::NativeScd, LocalSolver, SolveRequest};
    use std::sync::Arc;

    let dir = PathBuf::from(args.get_str("artifacts", "artifacts"));
    let man = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{:#}", e);
            return 1;
        }
    };
    let rt = match PjrtRuntime::cpu() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{:#}", e);
            return 1;
        }
    };
    println!("PJRT platform: {}", rt.platform());
    let exec = match rt.load_local_solve(&man) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("{:#}", e);
            return 1;
        }
    };
    println!("compiled {} (m={}, nk={}, h_max={})", man.local_solve_file, man.m, man.nk, man.h_max);

    // Run one local solve on a fitting synthetic partition, compare to the
    // native solver at f32 tolerance.
    let mut spec = SyntheticSpec::pjrt_default();
    spec.m = man.m.min(spec.m);
    let ds = webspam_like(&spec);
    let cols: Vec<u32> = (0..(man.nk as u32 / 2)).collect();
    let wd = WorkerData::from_columns(&ds.a, &cols);
    let alpha = vec![0.0; wd.n_local()];
    let v = vec![0.0; ds.m()];
    let problem = Problem::ridge(10.0);
    let req = SolveRequest {
        v: &v,
        b: &ds.b,
        h: 64.min(man.h_max),
        problem: &problem,
        sigma: 2.0,
        seed: 7,
    };
    let mut pjrt_solver = PjrtScd::new(Arc::new(exec));
    let res_pjrt = pjrt_solver.solve(&wd, &alpha, &req);
    let res_native = NativeScd::new().solve(&wd, &alpha, &req);
    let max_err = res_pjrt
        .delta_alpha
        .iter()
        .zip(res_native.delta_alpha.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("pjrt vs native max |Δα| error: {:.3e} (f32 tolerance)", max_err);
    if max_err < 1e-3 {
        println!("pjrt-smoke OK");
        0
    } else {
        eprintln!("pjrt-smoke FAILED: divergence {}", max_err);
        1
    }
}
