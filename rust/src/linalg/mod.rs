//! Dense vector kernels and deterministic RNG used throughout the stack.
//!
//! These are the L3 hot-path primitives: the native SCD solver spends its
//! time in [`dot_indexed`]/[`axpy_indexed`] (sparse column · dense residual),
//! the MPI/Spark engines in [`add_assign`] (AllReduce aggregation). They are
//! written as straight loops the compiler auto-vectorizes; the `hotpath`
//! bench tracks their throughput. The [`delta`] module holds the
//! nnz-adaptive Δv representation and its sparse-aware reduction tree
//! (DESIGN.md §7).

pub mod delta;
pub mod rng;
pub mod tree_reduce;

pub use delta::{
    raw_dense_bytes, raw_sparse_bytes, raw_sparse_cutover, sparse_cutover, DeltaReducer,
    DeltaShape, DeltaSlot, SparseVec,
};
pub use rng::Xorshift128;
pub use tree_reduce::{
    tree_reduce, tree_reduce_collect, tree_reduce_seq, tree_reduce_vecs, NestedTreePlan,
};

/// `y += x`, the AllReduce aggregation kernel.
///
/// Processed in fixed-width chunks of 8 through `chunks_exact`, which hands
/// the compiler bounds-check-free lanes it reliably turns into packed adds
/// (`y += x` carries no cross-lane dependency, so the chunking exists purely
/// to guarantee vectorization survives across rustc versions; §Perf log).
#[inline]
pub fn add_assign(y: &mut [f64], x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    let mut yc = y.chunks_exact_mut(8);
    let mut xc = x.chunks_exact(8);
    for (a, b) in yc.by_ref().zip(xc.by_ref()) {
        a[0] += b[0];
        a[1] += b[1];
        a[2] += b[2];
        a[3] += b[3];
        a[4] += b[4];
        a[5] += b[5];
        a[6] += b[6];
        a[7] += b[7];
    }
    for (yi, xi) in yc.into_remainder().iter_mut().zip(xc.remainder().iter()) {
        *yi += *xi;
    }
}

/// `y -= x`.
#[inline]
pub fn sub_assign(y: &mut [f64], x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi -= *xi;
    }
}

/// `y += a * x` over dense slices.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * *xi;
    }
}

/// Dense dot product.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let mut acc = 0.0;
    for (xi, yi) in x.iter().zip(y.iter()) {
        acc += xi * yi;
    }
    acc
}

/// Sparse-column dot: `sum_i vals[i] * dense[idx[i]]`.
///
/// The single hottest operation of the whole system (one call per SCD
/// step). Unrolled ×4 with independent accumulators to break the serial
/// floating-point add dependency chain (≈1.5× on this core; §Perf log).
#[inline]
pub fn dot_indexed(idx: &[u32], vals: &[f64], dense: &[f64]) -> f64 {
    debug_assert_eq!(idx.len(), vals.len());
    let n = idx.len();
    let chunks = n / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
    unsafe {
        for c in 0..chunks {
            let base = c * 4;
            a0 += *vals.get_unchecked(base)
                * *dense.get_unchecked(*idx.get_unchecked(base) as usize);
            a1 += *vals.get_unchecked(base + 1)
                * *dense.get_unchecked(*idx.get_unchecked(base + 1) as usize);
            a2 += *vals.get_unchecked(base + 2)
                * *dense.get_unchecked(*idx.get_unchecked(base + 2) as usize);
            a3 += *vals.get_unchecked(base + 3)
                * *dense.get_unchecked(*idx.get_unchecked(base + 3) as usize);
        }
        for i in chunks * 4..n {
            a0 += *vals.get_unchecked(i) * *dense.get_unchecked(*idx.get_unchecked(i) as usize);
        }
    }
    (a0 + a1) + (a2 + a3)
}

/// Sparse-column axpy: `dense[idx[i]] += a * vals[i]` (the rank-1 residual
/// update of the SCD step). Unrolled ×4 — safe because CSC columns carry
/// strictly increasing (hence unique) row indices, so the scattered writes
/// never alias within a chunk.
#[inline]
pub fn axpy_indexed(a: f64, idx: &[u32], vals: &[f64], dense: &mut [f64]) {
    debug_assert_eq!(idx.len(), vals.len());
    let n = idx.len();
    let chunks = n / 4;
    unsafe {
        for c in 0..chunks {
            let base = c * 4;
            *dense.get_unchecked_mut(*idx.get_unchecked(base) as usize) +=
                a * *vals.get_unchecked(base);
            *dense.get_unchecked_mut(*idx.get_unchecked(base + 1) as usize) +=
                a * *vals.get_unchecked(base + 1);
            *dense.get_unchecked_mut(*idx.get_unchecked(base + 2) as usize) +=
                a * *vals.get_unchecked(base + 2);
            *dense.get_unchecked_mut(*idx.get_unchecked(base + 3) as usize) +=
                a * *vals.get_unchecked(base + 3);
        }
        for i in chunks * 4..n {
            *dense.get_unchecked_mut(*idx.get_unchecked(i) as usize) += a * *vals.get_unchecked(i);
        }
    }
}

/// Fused sparse dot + squared-norm accumulation used by the optimized SCD
/// inner loop (single pass over the column instead of two).
///
/// Unrolled ×4 with independent accumulators, exactly like [`dot_indexed`]
/// — the dot component follows the identical chunking and final
/// `(a0+a1)+(a2+a3)` pairing, so `dot_indexed_fused(..).0` is bit-equal to
/// `dot_indexed(..)` at every length (asserted below). The previous naive
/// serial loop paired differently; its only caller (the hotpath bench)
/// compares timings, not bits.
#[inline]
pub fn dot_indexed_fused(idx: &[u32], vals: &[f64], dense: &[f64]) -> (f64, f64) {
    debug_assert_eq!(idx.len(), vals.len());
    // min() preserves the pre-unroll zip truncation on mismatched inputs
    // (the unchecked reads below must never run past either slice).
    let n = idx.len().min(vals.len());
    let chunks = n / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
    let (mut n0, mut n1, mut n2, mut n3) = (0.0f64, 0.0, 0.0, 0.0);
    unsafe {
        for c in 0..chunks {
            let base = c * 4;
            let (v0, v1, v2, v3) = (
                *vals.get_unchecked(base),
                *vals.get_unchecked(base + 1),
                *vals.get_unchecked(base + 2),
                *vals.get_unchecked(base + 3),
            );
            a0 += v0 * *dense.get_unchecked(*idx.get_unchecked(base) as usize);
            a1 += v1 * *dense.get_unchecked(*idx.get_unchecked(base + 1) as usize);
            a2 += v2 * *dense.get_unchecked(*idx.get_unchecked(base + 2) as usize);
            a3 += v3 * *dense.get_unchecked(*idx.get_unchecked(base + 3) as usize);
            n0 += v0 * v0;
            n1 += v1 * v1;
            n2 += v2 * v2;
            n3 += v3 * v3;
        }
        for i in chunks * 4..n {
            let v = *vals.get_unchecked(i);
            a0 += v * *dense.get_unchecked(*idx.get_unchecked(i) as usize);
            n0 += v * v;
        }
    }
    ((a0 + a1) + (a2 + a3), (n0 + n1) + (n2 + n3))
}

/// Euclidean norm squared.
#[inline]
pub fn nrm2_sq(x: &[f64]) -> f64 {
    dot(x, x)
}

/// L1 norm.
#[inline]
pub fn nrm1(x: &[f64]) -> f64 {
    x.iter().map(|v| v.abs()).sum()
}

/// Soft-threshold operator `sign(v) * max(|v| - tau, 0)` (elastic-net prox).
#[inline]
pub fn soft_threshold(v: f64, tau: f64) -> f64 {
    if v > tau {
        v - tau
    } else if v < -tau {
        v + tau
    } else {
        0.0
    }
}

/// Mean of a slice (0.0 for empty).
pub fn mean(x: &[f64]) -> f64 {
    if x.is_empty() {
        0.0
    } else {
        x.iter().sum::<f64>() / x.len() as f64
    }
}

/// Sample standard deviation (0.0 for < 2 samples).
pub fn stddev(x: &[f64]) -> f64 {
    if x.len() < 2 {
        return 0.0;
    }
    let m = mean(x);
    (x.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (x.len() - 1) as f64).sqrt()
}

/// Median of the *finite-comparable* samples (of a copy; input untouched).
/// NaN samples are excluded rather than panicking (`partial_cmp().unwrap()`
/// used to abort here) or skewing the statistic toward the tail — bench
/// samples can contain NaN when a clock misbehaves, and a stats helper
/// must neither take the process down nor bias the report over it.
/// All-NaN input yields NaN.
pub fn median(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = x.iter().copied().filter(|f| !f.is_nan()).collect();
    if v.is_empty() {
        return f64::NAN;
    }
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_axpy() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![4.0, 5.0, 6.0];
        assert_eq!(dot(&x, &y), 32.0);
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![6.0, 9.0, 12.0]);
        add_assign(&mut y, &x);
        assert_eq!(y, vec![7.0, 11.0, 15.0]);
        sub_assign(&mut y, &x);
        assert_eq!(y, vec![6.0, 9.0, 12.0]);
    }

    #[test]
    fn indexed_ops_match_dense() {
        let dense = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let idx = vec![0u32, 2, 4];
        let vals = vec![10.0, 20.0, 30.0];
        assert_eq!(dot_indexed(&idx, &vals, &dense), 10.0 + 60.0 + 150.0);
        let (d, n) = dot_indexed_fused(&idx, &vals, &dense);
        assert_eq!(d, 220.0);
        assert_eq!(n, 100.0 + 400.0 + 900.0);
        let mut dense2 = dense.clone();
        axpy_indexed(0.5, &idx, &vals, &mut dense2);
        assert_eq!(dense2, vec![6.0, 2.0, 13.0, 4.0, 20.0]);
    }

    #[test]
    fn fused_dot_matches_dot_indexed_bitwise_at_every_length() {
        // The unrolled fused kernel shares dot_indexed's chunking and final
        // pairing, so the dot component must be BIT-equal at every length
        // around the unroll width, and the norm component must equal the
        // same 4-accumulator pairing over v·v.
        let mut rng = Xorshift128::new(11);
        for n in 0..21usize {
            let dense: Vec<f64> = (0..64).map(|_| rng.next_gaussian()).collect();
            let idx: Vec<u32> = (0..n).map(|_| rng.next_usize(64) as u32).collect();
            let vals: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
            let (d, nrm) = dot_indexed_fused(&idx, &vals, &dense);
            assert_eq!(
                d.to_bits(),
                dot_indexed(&idx, &vals, &dense).to_bits(),
                "n={}",
                n
            );
            let ones = vec![1.0; 64];
            let sq: Vec<f64> = vals.iter().map(|v| v * v).collect();
            let self_idx: Vec<u32> = (0..n as u32).collect();
            // v·v through the same 4-acc pairing = dot_indexed(sq, ones).
            assert_eq!(
                nrm.to_bits(),
                dot_indexed(&self_idx, &sq, &ones).to_bits(),
                "n={}",
                n
            );
        }
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(2.0, 0.0), 2.0);
    }

    #[test]
    fn stats() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&x), 2.5);
        assert_eq!(median(&x), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((stddev(&x) - 1.2909944487).abs() < 1e-9);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn norms() {
        assert_eq!(nrm2_sq(&[3.0, 4.0]), 25.0);
        assert_eq!(nrm1(&[-3.0, 4.0]), 7.0);
    }

    #[test]
    fn median_survives_nan_input() {
        // Regression: partial_cmp().unwrap() used to panic here. NaN
        // samples are dropped, so the result is the median of the valid
        // samples, not a tail-biased slot.
        assert_eq!(median(&[f64::NAN, 1.0, 3.0]), 2.0);
        assert_eq!(median(&[1.0, f64::NAN, 2.0, 3.0, f64::NAN]), 2.0);
        assert!(median(&[f64::NAN]).is_nan());
        assert!(median(&[f64::NAN, f64::NAN]).is_nan());
        // Negative NaN is NaN too.
        assert_eq!(median(&[-f64::NAN, 5.0, 7.0]), 6.0);
    }

    #[test]
    fn add_assign_handles_all_remainder_lengths() {
        // The chunked kernel must agree with the naive loop at every
        // length around the unroll width.
        for n in 0..33usize {
            let x: Vec<f64> = (0..n).map(|i| i as f64 * 0.25 - 2.0).collect();
            let mut y: Vec<f64> = (0..n).map(|i| i as f64 * -0.5 + 1.0).collect();
            let mut want = y.clone();
            for (w, xi) in want.iter_mut().zip(x.iter()) {
                *w += *xi;
            }
            add_assign(&mut y, &x);
            assert_eq!(y, want, "n={}", n);
        }
    }
}
