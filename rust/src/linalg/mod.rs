//! Dense vector kernels and deterministic RNG used throughout the stack.
//!
//! These are the L3 hot-path primitives: the native SCD solver spends its
//! time in [`dot_indexed`]/[`axpy_indexed`] (sparse column · dense residual),
//! the MPI/Spark engines in [`add_assign`] (AllReduce aggregation). The
//! kernels themselves live in [`kernels`]: a scalar reference in the
//! unrolled-×4 accumulator convention ([`kernels::scalar`], always the
//! oracle and the default), an optional bit-equal AVX2 backend behind the
//! `simd` feature, and the cache-blocked CSC traversal plan
//! ([`kernels::BlockPlan`]). The free functions re-exported here are the
//! runtime dispatchers — call sites never name a backend. The `hotpath`
//! bench tracks their throughput. The [`delta`] module holds the
//! nnz-adaptive Δv representation and its sparse-aware reduction tree
//! (DESIGN.md §7).

pub mod delta;
pub mod kernels;
pub mod rng;
pub mod tree_reduce;

pub use delta::{
    raw_dense_bytes, raw_sparse_bytes, raw_sparse_cutover, sparse_cutover, DeltaReducer,
    DeltaShape, DeltaSlot, SparseVec,
};
pub use kernels::{
    add_assign, axpy, axpy_indexed, dot, dot_indexed, dot_indexed_fused, sub_assign, BlockPlan,
    DEFAULT_BLOCK_ROWS,
};
pub use rng::Xorshift128;
pub use tree_reduce::{
    tree_reduce, tree_reduce_collect, tree_reduce_seq, tree_reduce_vecs, NestedTreePlan,
};

/// Euclidean norm squared — `dot(x, x)` through the scalar ×4 convention,
/// which makes it bit-equal to the norm half of [`dot_indexed_fused`]
/// (that identity is what lets the SCD loop drop the `col_sq` table
/// lookup; see `solver::scd`). Always the scalar reference: callers build
/// tables that bit-pinned trajectories compare against, so the value must
/// not depend on the selected backend.
#[inline]
pub fn nrm2_sq(x: &[f64]) -> f64 {
    kernels::scalar::dot(x, x)
}

/// L1 norm. Explicit sequential accumulation: association order is part
/// of the reduce contract (DESIGN.md §11), so no iterator `.sum()` here.
#[inline]
pub fn nrm1(x: &[f64]) -> f64 {
    let mut acc = 0.0;
    for v in x {
        acc += v.abs();
    }
    acc
}

/// Soft-threshold operator `sign(v) * max(|v| - tau, 0)` (elastic-net prox).
#[inline]
pub fn soft_threshold(v: f64, tau: f64) -> f64 {
    if v > tau {
        v - tau
    } else if v < -tau {
        v + tau
    } else {
        0.0
    }
}

/// Mean of a slice (0.0 for empty).
pub fn mean(x: &[f64]) -> f64 {
    if x.is_empty() {
        0.0
    } else {
        let mut acc = 0.0;
        for v in x {
            acc += v;
        }
        acc / x.len() as f64
    }
}

/// Sample standard deviation (0.0 for < 2 samples).
pub fn stddev(x: &[f64]) -> f64 {
    if x.len() < 2 {
        return 0.0;
    }
    let m = mean(x);
    let mut acc = 0.0;
    for v in x {
        acc += (v - m) * (v - m);
    }
    (acc / (x.len() - 1) as f64).sqrt()
}

/// Median of the *finite-comparable* samples (of a copy; input untouched).
/// NaN samples are excluded rather than panicking (`partial_cmp().unwrap()`
/// used to abort here) or skewing the statistic toward the tail — bench
/// samples can contain NaN when a clock misbehaves, and a stats helper
/// must neither take the process down nor bias the report over it.
/// All-NaN input yields NaN.
pub fn median(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = x.iter().copied().filter(|f| !f.is_nan()).collect();
    if v.is_empty() {
        return f64::NAN;
    }
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_and_axpy() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![4.0, 5.0, 6.0];
        assert_eq!(dot(&x, &y), 32.0);
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![6.0, 9.0, 12.0]);
        add_assign(&mut y, &x);
        assert_eq!(y, vec![7.0, 11.0, 15.0]);
        sub_assign(&mut y, &x);
        assert_eq!(y, vec![6.0, 9.0, 12.0]);
    }

    #[test]
    fn indexed_ops_match_dense() {
        let dense = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let idx = vec![0u32, 2, 4];
        let vals = vec![10.0, 20.0, 30.0];
        assert_eq!(dot_indexed(&idx, &vals, &dense), 10.0 + 60.0 + 150.0);
        let (d, n) = dot_indexed_fused(&idx, &vals, &dense);
        assert_eq!(d, 220.0);
        assert_eq!(n, 100.0 + 400.0 + 900.0);
        let mut dense2 = dense.clone();
        axpy_indexed(0.5, &idx, &vals, &mut dense2);
        assert_eq!(dense2, vec![6.0, 2.0, 13.0, 4.0, 20.0]);
    }

    #[test]
    fn fused_dot_matches_dot_indexed_bitwise_at_every_length() {
        // The unrolled fused kernel shares dot_indexed's chunking and final
        // pairing, so the dot component must be BIT-equal at every length
        // around the unroll width, and the norm component must equal the
        // same 4-accumulator pairing over v·v — which since the ×4 rewrite
        // of `dot` is exactly nrm2_sq.
        let mut rng = Xorshift128::new(11);
        for n in 0..21usize {
            let dense: Vec<f64> = (0..64).map(|_| rng.next_gaussian()).collect();
            let idx: Vec<u32> = (0..n).map(|_| rng.next_usize(64) as u32).collect();
            let vals: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
            let (d, nrm) = dot_indexed_fused(&idx, &vals, &dense);
            assert_eq!(
                d.to_bits(),
                dot_indexed(&idx, &vals, &dense).to_bits(),
                "n={}",
                n
            );
            assert_eq!(nrm.to_bits(), nrm2_sq(&vals).to_bits(), "n={}", n);
        }
    }

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(-0.5, 1.0), 0.0);
        assert_eq!(soft_threshold(2.0, 0.0), 2.0);
    }

    #[test]
    fn stats() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&x), 2.5);
        assert_eq!(median(&x), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert!((stddev(&x) - 1.2909944487).abs() < 1e-9);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn norms() {
        assert_eq!(nrm2_sq(&[3.0, 4.0]), 25.0);
        assert_eq!(nrm1(&[-3.0, 4.0]), 7.0);
    }

    #[test]
    fn median_survives_nan_input() {
        // Regression: partial_cmp().unwrap() used to panic here. NaN
        // samples are dropped, so the result is the median of the valid
        // samples, not a tail-biased slot.
        assert_eq!(median(&[f64::NAN, 1.0, 3.0]), 2.0);
        assert_eq!(median(&[1.0, f64::NAN, 2.0, 3.0, f64::NAN]), 2.0);
        assert!(median(&[f64::NAN]).is_nan());
        assert!(median(&[f64::NAN, f64::NAN]).is_nan());
        // Negative NaN is NaN too.
        assert_eq!(median(&[-f64::NAN, 5.0, 7.0]), 6.0);
    }

    #[test]
    fn add_assign_handles_all_remainder_lengths() {
        // The chunked kernel must agree with the naive loop at every
        // length around the unroll width.
        for n in 0..33usize {
            let x: Vec<f64> = (0..n).map(|i| i as f64 * 0.25 - 2.0).collect();
            let mut y: Vec<f64> = (0..n).map(|i| i as f64 * -0.5 + 1.0).collect();
            let mut want = y.clone();
            for (w, xi) in want.iter_mut().zip(x.iter()) {
                *w += *xi;
            }
            add_assign(&mut y, &x);
            assert_eq!(y, want, "n={}", n);
        }
    }

    #[test]
    fn dot_matches_serial_sum_numerically() {
        // The ×4 rewrite of `dot` changes the summation tree vs the old
        // serial loop — exact small-value tests above stay exact, and
        // random data must agree to float tolerance with the naive sum.
        let mut rng = Xorshift128::new(23);
        for n in [0usize, 1, 3, 4, 5, 8, 17, 100, 1001] {
            let x: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
            let y: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
            // lint: allow(bitexact) -- naive float-tolerance oracle, not a trajectory input
            let naive: f64 = x.iter().zip(y.iter()).map(|(a, b)| a * b).sum();
            assert!(
                (dot(&x, &y) - naive).abs() <= 1e-12 * (1.0 + naive.abs()),
                "n={}",
                n
            );
        }
    }
}
