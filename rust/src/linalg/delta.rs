//! nnz-adaptive Δv representation: sparse delta vectors, the byte-cost
//! cutover rule and the sparse-aware pairwise reduction tree
//! (DESIGN.md §7).
//!
//! A CoCoA worker that ran H local steps over sparse columns touches only
//! the rows those columns carry, so its `Δv = A_k·Δα_[k]` is itself sparse —
//! yet the engines used to broadcast and reduce **dense m-dim frames every
//! round**, charging the overhead model for bytes the algorithm never
//! needed to move (MLlib ships sparse Breeze vectors for exactly this
//! reason). This module supplies the pieces every engine shares:
//!
//! * [`SparseVec`] — sorted-u32-index + f64-value delta representation,
//!   extracted from a dense Δv and reconstructed bit-exactly;
//! * the **cutover rule** — a worker emits the sparse frame iff
//!   `cost_sparse(nnz) < cost_dense(m)` under its codec's byte costs
//!   ([`sparse_cutover`] solves the rule for the threshold nnz once per
//!   engine construction);
//! * [`DeltaSlot`] / [`DeltaReducer`] — the pairwise binomial reduction
//!   tree of [`super::tree_reduce()`], made representation-aware:
//!   sparse+sparse pairs merge by sorted two-pointer walk, and a merge
//!   whose nnz grows past the cutover **promotes to dense** (mixed pairs
//!   scatter-add or promote). The tree shape and the per-index additions
//!   are identical to the dense path, so the aggregate Δv is bit-identical
//!   whether a round ran sparse, dense or mixed (asserted by
//!   `tests/integration_sparse_frames.rs`).
//!
//! All buffers (slot storage, merge scratch) are persistent and reach
//! steady capacity after warmup, preserving the zero-allocation hot path
//! of `util::pool` (counting-allocator tests below).
//!
//! ## Exact-zero canonicalization
//!
//! Extraction keeps entries with `value != 0.0`, so a `-0.0` in a dense
//! Δv is canonicalized to `+0.0` on reconstruction. This matches the dense
//! reduce path (`-0.0 + 0.0 == +0.0` under IEEE addition) for every
//! reachable input: untouched coordinates of a worker delta are exactly
//! `+0.0` (`(r − r₀)·σ′⁻¹` with `r == r₀`), and a solver cannot produce a
//! `-0.0` delta without an underflow ~10³⁰⁰× below the residual scale.

use super::add_assign;

// ---------------------------------------------------------------------------
// Raw wire costs (MPI ranks / threaded engine: no codec framing)
// ---------------------------------------------------------------------------

/// Raw sparse frame header: dim u64 + nnz u64.
pub const RAW_SPARSE_HEADER_BYTES: usize = 16;

/// Bytes of a raw dense m-vector frame (doubles on the wire).
pub fn raw_dense_bytes(m: usize) -> usize {
    m * 8
}

/// Bytes of a raw sparse frame: header + u32 index + f64 value per entry.
pub fn raw_sparse_bytes(nnz: usize) -> usize {
    RAW_SPARSE_HEADER_BYTES + nnz * 12
}

/// Solve the cutover rule for a codec: the largest nnz in `[0, m]` with
/// `cost_sparse(nnz) < cost_dense` (a worker emits sparse iff its Δv nnz
/// is ≤ the returned threshold). Returns 0 when sparse never pays.
/// `cost_sparse` must be non-decreasing in nnz (all our codecs are affine).
pub fn sparse_cutover(m: usize, cost_dense: usize, cost_sparse: impl Fn(usize) -> usize) -> usize {
    if cost_sparse(0) >= cost_dense {
        return 0;
    }
    // Binary search the monotone predicate; invariant: pred(lo) holds.
    let (mut lo, mut hi) = (0usize, m);
    while lo < hi {
        let mid = lo + (hi - lo + 1) / 2;
        if cost_sparse(mid) < cost_dense {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

/// Cutover threshold under the raw wire costs (used by the MPI-flavoured
/// engines): sparse iff `16 + 12·nnz < 8·m`.
pub fn raw_sparse_cutover(m: usize) -> usize {
    sparse_cutover(m, raw_dense_bytes(m), raw_sparse_bytes)
}

// ---------------------------------------------------------------------------
// SparseVec
// ---------------------------------------------------------------------------

/// Sparse delta vector: strictly increasing u32 indices + f64 values.
///
/// The delta representation of the sparse communication layer: extracted
/// from a worker's dense Δv ([`SparseVec::fill_from_dense`]), shipped as a
/// codec frame, merged in the reduction tree and reconstructed bit-exactly
/// ([`SparseVec::densify_into`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SparseVec {
    /// Logical dimension m of the dense vector this represents.
    pub dim: usize,
    /// Strictly increasing indices of the stored entries.
    pub idx: Vec<u32>,
    /// Entry values, parallel to `idx`.
    pub vals: Vec<f64>,
}

impl SparseVec {
    pub fn new(dim: usize) -> SparseVec {
        SparseVec {
            dim,
            idx: Vec::new(),
            vals: Vec::new(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    /// Reset to an empty vector of dimension `dim`, keeping capacity.
    pub fn clear(&mut self, dim: usize) {
        self.dim = dim;
        self.idx.clear();
        self.vals.clear();
    }

    /// Extract the entries of `dense` with `value != 0.0` (reusing this
    /// vector's capacity; zero steady-state allocations).
    pub fn fill_from_dense(&mut self, dense: &[f64]) {
        self.clear(dense.len());
        for (i, &v) in dense.iter().enumerate() {
            if v != 0.0 {
                self.idx.push(i as u32);
                self.vals.push(v);
            }
        }
    }

    /// Reconstruct the dense vector into `out` (cleared and zero-filled
    /// first). Entry values are written verbatim — bit-exact round trip.
    pub fn densify_into(&self, out: &mut Vec<f64>) {
        out.clear();
        out.resize(self.dim, 0.0);
        for (&i, &v) in self.idx.iter().zip(self.vals.iter()) {
            out[i as usize] = v;
        }
    }

    /// Scatter-add into a dense accumulator: `y[idx[i]] += vals[i]`.
    /// Exactly the additions the dense path performs at these indices (it
    /// additionally adds `+0.0` everywhere else, a bitwise no-op).
    pub fn add_into_dense(&self, y: &mut [f64]) {
        debug_assert_eq!(y.len(), self.dim);
        for (&i, &v) in self.idx.iter().zip(self.vals.iter()) {
            y[i as usize] += v;
        }
    }

    /// Structural invariants: parallel arrays, strictly increasing
    /// (duplicate-free) in-bounds indices.
    pub fn validate(&self) -> Result<(), String> {
        if self.idx.len() != self.vals.len() {
            return Err("idx/vals length mismatch".into());
        }
        for w in self.idx.windows(2) {
            if w[0] >= w[1] {
                return Err(format!("indices not strictly increasing at {}", w[0]));
            }
        }
        if let Some(&last) = self.idx.last() {
            if last as usize >= self.dim {
                return Err(format!("index {} out of dim {}", last, self.dim));
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// DeltaSlot — one worker's Δv in whichever representation is cheaper
// ---------------------------------------------------------------------------

/// Which representation a Δv frame uses this round.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DeltaShape {
    Dense,
    #[default]
    Sparse,
}

/// One worker's Δv landing slot: holds either a dense copy or the sparse
/// extraction, with both storage arenas persistent across rounds so the
/// representation can flip round-over-round without touching the
/// allocator.
#[derive(Debug, Clone, Default)]
pub struct DeltaSlot {
    shape: DeltaShape,
    dense: Vec<f64>,
    sparse: SparseVec,
}

impl DeltaSlot {
    pub fn new() -> DeltaSlot {
        DeltaSlot::default()
    }

    pub fn shape(&self) -> DeltaShape {
        self.shape
    }

    /// Stored entries: nnz for sparse, the full dimension for dense.
    pub fn stored_len(&self) -> usize {
        match self.shape {
            DeltaShape::Dense => self.dense.len(),
            DeltaShape::Sparse => self.sparse.nnz(),
        }
    }

    /// The sparse payload (None when dense).
    pub fn sparse(&self) -> Option<&SparseVec> {
        match self.shape {
            DeltaShape::Sparse => Some(&self.sparse),
            DeltaShape::Dense => None,
        }
    }

    /// The dense payload (None when sparse).
    pub fn dense(&self) -> Option<&[f64]> {
        match self.shape {
            DeltaShape::Dense => Some(&self.dense),
            DeltaShape::Sparse => None,
        }
    }

    /// Fill from a worker's dense Δv, choosing the representation by the
    /// cutover rule: sparse iff `nnz <= cutover_nnz` (and the cutover is
    /// nonzero — 0 means frames are forced dense). Returns the chosen
    /// shape and the counted nnz.
    ///
    /// Single pass over the m-vector: entries stream into the sparse
    /// arena, and the moment the count exceeds the cutover we fall back to
    /// the dense copy — so the common sparse case never re-scans.
    pub fn fill_from_dense(&mut self, delta: &[f64], cutover_nnz: usize) -> (DeltaShape, usize) {
        let dense_fallback = |slot: &mut DeltaSlot, seen: usize, rest: &[f64]| {
            let nnz = seen + rest.iter().filter(|&&v| v != 0.0).count();
            slot.dense.clear();
            slot.dense.extend_from_slice(delta);
            slot.shape = DeltaShape::Dense;
            (DeltaShape::Dense, nnz)
        };
        if cutover_nnz == 0 {
            return dense_fallback(self, 0, delta);
        }
        self.sparse.clear(delta.len());
        for (i, &v) in delta.iter().enumerate() {
            if v != 0.0 {
                if self.sparse.nnz() == cutover_nnz {
                    // This entry pushes nnz past the cutover: dense wins.
                    return dense_fallback(self, cutover_nnz + 1, &delta[i + 1..]);
                }
                self.sparse.idx.push(i as u32);
                self.sparse.vals.push(v);
            }
        }
        self.shape = DeltaShape::Sparse;
        (DeltaShape::Sparse, self.sparse.nnz())
    }

    /// Bytes this slot would occupy as a raw wire frame.
    pub fn raw_bytes(&self, m: usize) -> usize {
        match self.shape {
            DeltaShape::Dense => raw_dense_bytes(m),
            DeltaShape::Sparse => raw_sparse_bytes(self.sparse.nnz()),
        }
    }

    /// Densify into an owned vector of dimension `m` (the per-round
    /// aggregate the `run_round` API hands the caller).
    pub fn densify_collect(&self, m: usize) -> Vec<f64> {
        match self.shape {
            DeltaShape::Dense => {
                debug_assert_eq!(self.dense.len(), m);
                self.dense.clone()
            }
            DeltaShape::Sparse => {
                debug_assert_eq!(self.sparse.dim, m);
                let mut out = Vec::new();
                self.sparse.densify_into(&mut out);
                out
            }
        }
    }
}

// ---------------------------------------------------------------------------
// DeltaReducer — the sparse-aware pairwise reduction tree
// ---------------------------------------------------------------------------

/// Reduces K [`DeltaSlot`]s pairwise with the same binomial tree shape as
/// [`super::tree_reduce()`] (result in `slots[0]`, the rest are scratch),
/// merging sparse pairs and promoting to dense past the cutover.
///
/// Owns the merge scratch so steady-state rounds are allocation-free; each
/// engine owns one reducer (single-threaded, like `util::pool`).
#[derive(Debug)]
pub struct DeltaReducer {
    m: usize,
    cutover_nnz: usize,
    merge: SparseVec,
}

impl DeltaReducer {
    /// Reducer with an explicit cutover threshold (0 forces dense frames —
    /// the `EngineOptions::dense_frames` escape hatch and A/B baseline).
    pub fn new(m: usize, cutover_nnz: usize) -> DeltaReducer {
        DeltaReducer {
            m,
            cutover_nnz,
            merge: SparseVec::new(m),
        }
    }

    /// Reducer under the raw wire-cost cutover (MPI-flavoured engines).
    pub fn raw(m: usize) -> DeltaReducer {
        DeltaReducer::new(m, raw_sparse_cutover(m))
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn cutover_nnz(&self) -> usize {
        self.cutover_nnz
    }

    /// Load a worker's dense Δv into its slot under this reducer's cutover.
    pub fn load(&self, slot: &mut DeltaSlot, delta: &[f64]) -> (DeltaShape, usize) {
        debug_assert_eq!(delta.len(), self.m);
        slot.fill_from_dense(delta, self.cutover_nnz)
    }

    /// Reduce `slots[1..]` into `slots[0]` pairwise. The pairs come from
    /// the shared [`super::tree_reduce::for_each_tree_pair`] enumeration —
    /// the very loop [`super::tree_reduce_seq`] drives — and per-index
    /// addition order matches the dense path, so the result is
    /// bit-identical to the all-dense reduction by construction.
    // lint: alloc-free (reduce runs once per round on every engine)
    pub fn reduce(&mut self, slots: &mut [DeltaSlot]) {
        super::tree_reduce::for_each_tree_pair(slots.len(), |dst, src| {
            let (left, right) = slots.split_at_mut(src);
            self.combine(&mut left[dst], &right[0]);
        });
    }

    /// Apply an explicit `(dst, src)` combine list in order
    /// (`slots[dst] += slots[src]`, `dst < src`). This is how the nested
    /// two-level engines drive the [`NestedTreePlan`] split of the flat
    /// tree: each rank runs its `local_pairs` over its own slot block,
    /// the master runs `cross_pairs` over the forest roots — the same
    /// combines as [`reduce`](DeltaReducer::reduce) over the flat slot
    /// array, hence a bit-identical aggregate.
    ///
    /// [`NestedTreePlan`]: super::tree_reduce::NestedTreePlan
    // lint: alloc-free (nested-tree variant of reduce)
    pub fn reduce_pairs(&mut self, slots: &mut [DeltaSlot], pairs: &[(usize, usize)]) {
        for &(dst, src) in pairs {
            debug_assert!(dst < src && src < slots.len());
            let (left, right) = slots.split_at_mut(src);
            self.combine(&mut left[dst], &right[0]);
        }
    }

    /// Reduce and densify the aggregate (the one per-round allocation the
    /// `run_round` API imposes — the caller owns the result).
    pub fn reduce_collect(&mut self, slots: &mut [DeltaSlot]) -> Vec<f64> {
        if slots.is_empty() {
            return Vec::new();
        }
        self.reduce(slots);
        slots[0].densify_collect(self.m)
    }

    /// `left += right` in whichever representations the pair holds.
    // lint: alloc-free (per-pair combine inside the reduce tree)
    fn combine(&mut self, left: &mut DeltaSlot, right: &DeltaSlot) {
        match (left.shape, right.shape) {
            (DeltaShape::Dense, DeltaShape::Dense) => {
                add_assign(&mut left.dense, &right.dense);
            }
            (DeltaShape::Dense, DeltaShape::Sparse) => {
                right.sparse.add_into_dense(&mut left.dense);
            }
            (DeltaShape::Sparse, DeltaShape::Dense) => {
                promote(self.m, left);
                add_assign(&mut left.dense, &right.dense);
            }
            (DeltaShape::Sparse, DeltaShape::Sparse) => {
                merge_sparse(&left.sparse, &right.sparse, &mut self.merge);
                std::mem::swap(&mut left.sparse, &mut self.merge);
                if left.sparse.nnz() > self.cutover_nnz {
                    promote(self.m, left);
                }
            }
        }
    }
}

/// Promote a sparse slot to dense in place (reusing its dense arena — the
/// scatter is [`SparseVec::densify_into`], the same reconstruction the
/// frame decoders use).
fn promote(m: usize, slot: &mut DeltaSlot) {
    debug_assert_eq!(slot.shape, DeltaShape::Sparse);
    debug_assert_eq!(slot.sparse.dim, m);
    slot.sparse.densify_into(&mut slot.dense);
    slot.shape = DeltaShape::Dense;
}

/// Sorted two-pointer merge: `out = a + b`. Indices present in both sides
/// add (`a + b`, the dense path's operation); single-sided entries copy
/// (bitwise equal to `x + 0.0` for the nonzero values stored here).
/// Exact cancellations (`a + b == 0.0`) are kept as explicit `+0.0`
/// entries — dropping them would also densify to `+0.0`, but keeping them
/// avoids a re-filter pass (the promotion rule bounds growth anyway).
// lint: alloc-free (two-pointer merge into a reused output)
fn merge_sparse(a: &SparseVec, b: &SparseVec, out: &mut SparseVec) {
    debug_assert_eq!(a.dim, b.dim);
    out.clear(a.dim);
    let (mut i, mut j) = (0usize, 0usize);
    while i < a.idx.len() && j < b.idx.len() {
        match a.idx[i].cmp(&b.idx[j]) {
            std::cmp::Ordering::Less => {
                out.idx.push(a.idx[i]);
                out.vals.push(a.vals[i]);
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                out.idx.push(b.idx[j]);
                out.vals.push(b.vals[j]);
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                out.idx.push(a.idx[i]);
                out.vals.push(a.vals[i] + b.vals[j]);
                i += 1;
                j += 1;
            }
        }
    }
    while i < a.idx.len() {
        out.idx.push(a.idx[i]);
        out.vals.push(a.vals[i]);
        i += 1;
    }
    while j < b.idx.len() {
        out.idx.push(b.idx[j]);
        out.vals.push(b.vals[j]);
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::tree_reduce_collect;

    fn sparse_dense(m: usize, entries: &[(u32, f64)]) -> Vec<f64> {
        let mut v = vec![0.0; m];
        for &(i, x) in entries {
            v[i as usize] = x;
        }
        v
    }

    #[test]
    fn extraction_roundtrip_is_bit_exact() {
        let d = sparse_dense(64, &[(0, 1.5), (7, -2.25), (63, 1e-300)]);
        let mut sv = SparseVec::new(0);
        sv.fill_from_dense(&d);
        assert_eq!(sv.nnz(), 3);
        sv.validate().unwrap();
        let mut back = Vec::new();
        sv.densify_into(&mut back);
        for (a, b) in d.iter().zip(back.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn extraction_is_allocation_free_after_warmup() {
        let d = sparse_dense(256, &[(3, 1.0), (100, 2.0), (200, -3.0)]);
        let mut sv = SparseVec::new(0);
        sv.fill_from_dense(&d); // warmup sizes the arenas
        let before = crate::testkit::alloc::current_thread_allocations();
        for _ in 0..20 {
            sv.fill_from_dense(&d);
        }
        let after = crate::testkit::alloc::current_thread_allocations();
        assert_eq!(after - before, 0, "steady-state extraction allocated");
    }

    #[test]
    fn cutover_rule_solves_the_inequality() {
        let m = 1000;
        let c = raw_sparse_cutover(m);
        assert!(raw_sparse_bytes(c) < raw_dense_bytes(m));
        assert!(raw_sparse_bytes(c + 1) >= raw_dense_bytes(m));
        // 16 + 12·nnz < 8000  →  nnz ≤ 665
        assert_eq!(c, 665);
        // Degenerate: dense never beaten → 0.
        assert_eq!(sparse_cutover(10, 0, raw_sparse_bytes), 0);
        // Sparse always cheaper → full range.
        assert_eq!(sparse_cutover(10, usize::MAX, raw_sparse_bytes), 10);
    }

    #[test]
    fn slot_picks_representation_by_cutover() {
        let d = sparse_dense(100, &[(1, 1.0), (2, 2.0), (3, 3.0)]);
        let mut slot = DeltaSlot::new();
        let (shape, nnz) = slot.fill_from_dense(&d, 3);
        assert_eq!((shape, nnz), (DeltaShape::Sparse, 3));
        assert_eq!(slot.raw_bytes(100), raw_sparse_bytes(3));
        let (shape, nnz) = slot.fill_from_dense(&d, 2);
        assert_eq!((shape, nnz), (DeltaShape::Dense, 3));
        assert_eq!(slot.raw_bytes(100), raw_dense_bytes(100));
        // Either way the content round-trips bit-exactly.
        let back = slot.densify_collect(100);
        assert_eq!(back, d);
    }

    #[test]
    fn merge_matches_dense_add() {
        let m = 40;
        let da = sparse_dense(m, &[(1, 1.0), (5, 2.0), (9, -3.0)]);
        let db = sparse_dense(m, &[(5, 0.5), (9, 3.0), (30, 7.0)]);
        let (mut a, mut b) = (SparseVec::new(0), SparseVec::new(0));
        a.fill_from_dense(&da);
        b.fill_from_dense(&db);
        let mut out = SparseVec::new(0);
        merge_sparse(&a, &b, &mut out);
        out.validate().unwrap();
        // Exact cancellation at 9 is kept as an explicit +0.0 entry.
        assert_eq!(out.nnz(), 4);
        let mut got = Vec::new();
        out.densify_into(&mut got);
        let want: Vec<f64> = da.iter().zip(db.iter()).map(|(x, y)| x + y).collect();
        for (g, w) in got.iter().zip(want.iter()) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
    }

    /// The core guarantee: any mix of sparse/dense slots reduces to the
    /// exact bits the all-dense pairwise tree produces.
    #[test]
    fn reducer_is_bit_identical_to_dense_tree() {
        for k in [1usize, 2, 3, 5, 8, 13] {
            for cutover_frac in [0.0, 0.05, 0.5, 1.0] {
                let m = 97;
                let mut rng = crate::linalg::Xorshift128::new(42 + k as u64);
                let deltas: Vec<Vec<f64>> = (0..k)
                    .map(|_| {
                        (0..m)
                            .map(|_| {
                                if rng.next_f64() < 0.15 {
                                    rng.next_gaussian()
                                } else {
                                    0.0
                                }
                            })
                            .collect()
                    })
                    .collect();
                let mut dense_bufs = deltas.clone();
                let want = tree_reduce_collect(dense_bufs.iter_mut());

                let cutover = (m as f64 * cutover_frac) as usize;
                let mut red = DeltaReducer::new(m, cutover);
                let mut slots: Vec<DeltaSlot> = (0..k).map(|_| DeltaSlot::new()).collect();
                for (slot, d) in slots.iter_mut().zip(deltas.iter()) {
                    red.load(slot, d);
                }
                let got = red.reduce_collect(&mut slots);
                assert_eq!(got.len(), want.len());
                for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "K={} cutover={} [{}]: {} vs {}",
                        k,
                        cutover,
                        i,
                        g,
                        w
                    );
                }
            }
        }
    }

    /// The nested split (rank-local pairs, then cross-rank pairs) must
    /// produce the exact bits of the flat pairwise tree for every (k, t),
    /// across sparse/dense/mixed slot representations.
    #[test]
    fn nested_reduce_pairs_match_flat_reduce_bitwise() {
        use crate::linalg::tree_reduce::NestedTreePlan;
        for (k, t) in [(2usize, 2usize), (3, 2), (2, 3), (4, 4), (3, 5)] {
            for cutover_frac in [0.0, 0.1, 1.0] {
                let m = 61;
                let n = k * t;
                let mut rng = crate::linalg::Xorshift128::new(7 + (k * 17 + t) as u64);
                let deltas: Vec<Vec<f64>> = (0..n)
                    .map(|_| {
                        (0..m)
                            .map(|_| {
                                if rng.next_f64() < 0.2 {
                                    rng.next_gaussian()
                                } else {
                                    0.0
                                }
                            })
                            .collect()
                    })
                    .collect();
                let cutover = (m as f64 * cutover_frac) as usize;

                let mut flat_red = DeltaReducer::new(m, cutover);
                let mut flat_slots: Vec<DeltaSlot> = (0..n).map(|_| DeltaSlot::new()).collect();
                for (slot, d) in flat_slots.iter_mut().zip(deltas.iter()) {
                    flat_red.load(slot, d);
                }
                let want = flat_red.reduce_collect(&mut flat_slots);

                let plan = NestedTreePlan::new(k, t);
                let mut red = DeltaReducer::new(m, cutover);
                let mut slots: Vec<DeltaSlot> = (0..n).map(|_| DeltaSlot::new()).collect();
                for (slot, d) in slots.iter_mut().zip(deltas.iter()) {
                    red.load(slot, d);
                }
                for w in 0..k {
                    red.reduce_pairs(&mut slots[w * t..(w + 1) * t], plan.local_pairs(w));
                }
                red.reduce_pairs(&mut slots, plan.cross_pairs());
                let got = slots[0].densify_collect(m);
                for (i, (g, wv)) in got.iter().zip(want.iter()).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        wv.to_bits(),
                        "k={} t={} cutover={} [{}]",
                        k,
                        t,
                        cutover,
                        i
                    );
                }
            }
        }
    }

    #[test]
    fn merge_growth_promotes_to_dense() {
        let m = 30;
        // Cutover 10: two 8-nnz disjoint deltas merge to 16 > 10 → dense.
        let da = sparse_dense(m, &(0..8).map(|i| (i as u32, 1.0)).collect::<Vec<_>>());
        let db = sparse_dense(m, &(10..18).map(|i| (i as u32, 2.0)).collect::<Vec<_>>());
        let mut red = DeltaReducer::new(m, 10);
        let mut slots = vec![DeltaSlot::new(), DeltaSlot::new()];
        assert_eq!(red.load(&mut slots[0], &da).0, DeltaShape::Sparse);
        assert_eq!(red.load(&mut slots[1], &db).0, DeltaShape::Sparse);
        red.reduce(&mut slots);
        assert_eq!(slots[0].shape(), DeltaShape::Dense);
        let got = slots[0].densify_collect(m);
        let want: Vec<f64> = da.iter().zip(db.iter()).map(|(x, y)| x + y).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn reduce_handles_empty_and_single() {
        let mut red = DeltaReducer::raw(8);
        let mut none: Vec<DeltaSlot> = Vec::new();
        assert!(red.reduce_collect(&mut none).is_empty());
        let d = sparse_dense(8, &[(2, 5.0)]);
        let mut one = vec![DeltaSlot::new()];
        red.load(&mut one[0], &d);
        assert_eq!(red.reduce_collect(&mut one), d);
    }

    #[test]
    fn steady_state_reduce_is_allocation_free() {
        let m = 64;
        let k = 6;
        let mut rng = crate::linalg::Xorshift128::new(9);
        let deltas: Vec<Vec<f64>> = (0..k)
            .map(|_| {
                (0..m)
                    .map(|_| {
                        if rng.next_f64() < 0.2 {
                            rng.next_gaussian()
                        } else {
                            0.0
                        }
                    })
                    .collect()
            })
            .collect();
        let mut red = DeltaReducer::raw(m);
        let mut slots: Vec<DeltaSlot> = (0..k).map(|_| DeltaSlot::new()).collect();
        // Warmup: sizes slot arenas and the merge scratch.
        for (slot, d) in slots.iter_mut().zip(deltas.iter()) {
            red.load(slot, d);
        }
        red.reduce(&mut slots);
        let before = crate::testkit::alloc::current_thread_allocations();
        for _ in 0..10 {
            for (slot, d) in slots.iter_mut().zip(deltas.iter()) {
                red.load(slot, d);
            }
            red.reduce(&mut slots);
        }
        let after = crate::testkit::alloc::current_thread_allocations();
        assert_eq!(after - before, 0, "steady-state sparse reduce allocated");
    }
}
