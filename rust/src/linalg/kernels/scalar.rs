//! The scalar reference kernels — the bit-exactness ORACLE.
//!
//! Every kernel here is written in the unrolled-×4 independent-accumulator
//! convention (DESIGN.md §11):
//!
//! * reductions run 4 stride-4 accumulators `a0..a3` over `n/4` chunks
//!   (`a_i` owns elements `4c + i`), the remainder folds into `a0`, and the
//!   final reduce is the fixed pairing `(a0 + a1) + (a2 + a3)`;
//! * every per-element operation is a bare multiply followed by a bare add
//!   (two roundings — never a fused multiply-add, which rounds once and
//!   would change bits);
//! * element-wise kernels (`axpy`, `add_assign`, the scatters) carry no
//!   cross-lane dependency at all, so any chunking is bit-neutral.
//!
//! This layout is exactly a 4-lane AVX2 register: lane *i* of the SIMD
//! accumulator performs the same adds in the same order as scalar `a_i`,
//! so the `simd` backend ([`super::simd`]) is bit-equal BY CONSTRUCTION,
//! not by tolerance — asserted exhaustively by the property tests in
//! [`super`] and end-to-end by `tests/integration_kernels.rs`.
//!
//! ## Length contracts (the audited rule)
//!
//! All kernels take equal-length primary slices and document that contract
//! with `debug_assert!`; release builds clamp to the common prefix
//! (`min()`) ONLY where unchecked reads need the clamp for memory safety —
//! the clamp is a safety net, not semantics. Indexed kernels additionally
//! require every `idx[i] < dense.len()`; that is enforced once per solve
//! at the solver boundary (release-mode `assert!` in `solve_into` — the
//! CSC validator guarantees `row_idx < m` and the solver checks
//! `v.len() == m`), so the per-element reads stay unchecked.

/// `y += x`, the AllReduce aggregation kernel. Element-wise (no reduction
/// order to preserve); chunked ×8 purely so packed adds survive across
/// rustc versions. Contract: `y.len() == x.len()` (debug-asserted;
/// release operates on the common prefix via the zip).
#[inline]
pub fn add_assign(y: &mut [f64], x: &[f64]) {
    debug_assert_eq!(y.len(), x.len(), "add_assign: length mismatch");
    let mut yc = y.chunks_exact_mut(8);
    let mut xc = x.chunks_exact(8);
    for (a, b) in yc.by_ref().zip(xc.by_ref()) {
        a[0] += b[0];
        a[1] += b[1];
        a[2] += b[2];
        a[3] += b[3];
        a[4] += b[4];
        a[5] += b[5];
        a[6] += b[6];
        a[7] += b[7];
    }
    for (yi, xi) in yc.into_remainder().iter_mut().zip(xc.remainder().iter()) {
        *yi += *xi;
    }
}

/// `y -= x`. Contract: `y.len() == x.len()` (debug-asserted).
#[inline]
pub fn sub_assign(y: &mut [f64], x: &[f64]) {
    debug_assert_eq!(y.len(), x.len(), "sub_assign: length mismatch");
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi -= *xi;
    }
}

/// `y += a * x` over dense slices. Element-wise: each element is one
/// multiply + one add, so chunking cannot change bits. Contract:
/// `y.len() == x.len()` (debug-asserted).
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(y.len(), x.len(), "axpy: length mismatch");
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * *xi;
    }
}

/// Dense dot product in the ×4 accumulator convention (module docs).
///
/// The pairing is what makes `nrm2_sq(x) = dot(x, x)` bit-equal to the
/// norm half of [`dot_indexed_fused`] — which is what lets the SCD loop
/// take its column norm from the fused kernel instead of the precomputed
/// `col_sq` table without moving a single bit (see `solver::scd`).
/// Contract: `x.len() == y.len()` (debug-asserted; release clamps to the
/// common prefix for the unchecked reads).
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len(), "dot: length mismatch");
    let n = x.len().min(y.len());
    let chunks = n / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
    // SAFETY: every index is < n = min(x.len(), y.len()) — `base + 3 < 4 * chunks <= n`
    // for the unrolled body, `i < n` for the tail.
    unsafe {
        for c in 0..chunks {
            let base = c * 4;
            a0 += *x.get_unchecked(base) * *y.get_unchecked(base);
            a1 += *x.get_unchecked(base + 1) * *y.get_unchecked(base + 1);
            a2 += *x.get_unchecked(base + 2) * *y.get_unchecked(base + 2);
            a3 += *x.get_unchecked(base + 3) * *y.get_unchecked(base + 3);
        }
        for i in chunks * 4..n {
            a0 += *x.get_unchecked(i) * *y.get_unchecked(i);
        }
    }
    (a0 + a1) + (a2 + a3)
}

/// Sparse-column dot: `sum_i vals[i] * dense[idx[i]]`.
///
/// The single hottest operation of the whole system (one call per SCD
/// step). Unrolled ×4 with independent accumulators to break the serial
/// floating-point add dependency chain (≈1.5× on this core; §Perf log).
/// Contract: `idx.len() == vals.len()` (debug-asserted; release clamps)
/// and every `idx[i] < dense.len()` (checked at the solver boundary).
#[inline]
pub fn dot_indexed(idx: &[u32], vals: &[f64], dense: &[f64]) -> f64 {
    debug_assert_eq!(idx.len(), vals.len(), "dot_indexed: length mismatch");
    let n = idx.len().min(vals.len());
    let chunks = n / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
    // SAFETY: `idx`/`vals` reads are < n = min(len, len); `dense` reads rely on
    // the documented contract `idx[i] < dense.len()`, asserted at the solver
    // boundary when columns are ingested.
    unsafe {
        for c in 0..chunks {
            let base = c * 4;
            a0 += *vals.get_unchecked(base)
                * *dense.get_unchecked(*idx.get_unchecked(base) as usize);
            a1 += *vals.get_unchecked(base + 1)
                * *dense.get_unchecked(*idx.get_unchecked(base + 1) as usize);
            a2 += *vals.get_unchecked(base + 2)
                * *dense.get_unchecked(*idx.get_unchecked(base + 2) as usize);
            a3 += *vals.get_unchecked(base + 3)
                * *dense.get_unchecked(*idx.get_unchecked(base + 3) as usize);
        }
        for i in chunks * 4..n {
            a0 += *vals.get_unchecked(i) * *dense.get_unchecked(*idx.get_unchecked(i) as usize);
        }
    }
    (a0 + a1) + (a2 + a3)
}

/// Sparse-column axpy: `dense[idx[i]] += a * vals[i]` (the rank-1 residual
/// update of the SCD step). Unrolled ×4 — safe because CSC columns carry
/// strictly increasing (hence unique) row indices, so the scattered writes
/// never alias within a chunk. Element-wise per target slot (one multiply
/// + one add), so traversal order cannot change bits. Contract as
/// [`dot_indexed`].
#[inline]
pub fn axpy_indexed(a: f64, idx: &[u32], vals: &[f64], dense: &mut [f64]) {
    debug_assert_eq!(idx.len(), vals.len(), "axpy_indexed: length mismatch");
    let n = idx.len().min(vals.len());
    let chunks = n / 4;
    // SAFETY: `idx`/`vals` reads are < n = min(len, len); the scatter writes
    // `dense[idx[i]]` under the contract `idx[i] < dense.len()` (asserted at
    // the solver boundary).
    unsafe {
        for c in 0..chunks {
            let base = c * 4;
            *dense.get_unchecked_mut(*idx.get_unchecked(base) as usize) +=
                a * *vals.get_unchecked(base);
            *dense.get_unchecked_mut(*idx.get_unchecked(base + 1) as usize) +=
                a * *vals.get_unchecked(base + 1);
            *dense.get_unchecked_mut(*idx.get_unchecked(base + 2) as usize) +=
                a * *vals.get_unchecked(base + 2);
            *dense.get_unchecked_mut(*idx.get_unchecked(base + 3) as usize) +=
                a * *vals.get_unchecked(base + 3);
        }
        for i in chunks * 4..n {
            *dense.get_unchecked_mut(*idx.get_unchecked(i) as usize) += a * *vals.get_unchecked(i);
        }
    }
}

/// Fused sparse dot + squared-norm accumulation used by the SCD inner
/// loop (single pass over the column instead of two).
///
/// Unrolled ×4 with independent accumulators, exactly like [`dot_indexed`]
/// — the dot component follows the identical chunking and final
/// `(a0+a1)+(a2+a3)` pairing, so `dot_indexed_fused(..).0` is bit-equal to
/// `dot_indexed(..)` at every length, and the norm component is bit-equal
/// to `dot(vals, vals)` (both asserted in [`super`]'s tests). Contract as
/// [`dot_indexed`].
#[inline]
pub fn dot_indexed_fused(idx: &[u32], vals: &[f64], dense: &[f64]) -> (f64, f64) {
    debug_assert_eq!(idx.len(), vals.len(), "dot_indexed_fused: length mismatch");
    // min() preserves the pre-unroll zip truncation on mismatched inputs
    // (the unchecked reads below must never run past either slice).
    let n = idx.len().min(vals.len());
    let chunks = n / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
    let (mut n0, mut n1, mut n2, mut n3) = (0.0f64, 0.0, 0.0, 0.0);
    // SAFETY: identical access pattern to `dot_indexed` — reads clamped by n,
    // `dense` indexed under the solver-boundary contract `idx[i] < dense.len()`.
    unsafe {
        for c in 0..chunks {
            let base = c * 4;
            let (v0, v1, v2, v3) = (
                *vals.get_unchecked(base),
                *vals.get_unchecked(base + 1),
                *vals.get_unchecked(base + 2),
                *vals.get_unchecked(base + 3),
            );
            a0 += v0 * *dense.get_unchecked(*idx.get_unchecked(base) as usize);
            a1 += v1 * *dense.get_unchecked(*idx.get_unchecked(base + 1) as usize);
            a2 += v2 * *dense.get_unchecked(*idx.get_unchecked(base + 2) as usize);
            a3 += v3 * *dense.get_unchecked(*idx.get_unchecked(base + 3) as usize);
            n0 += v0 * v0;
            n1 += v1 * v1;
            n2 += v2 * v2;
            n3 += v3 * v3;
        }
        for i in chunks * 4..n {
            let v = *vals.get_unchecked(i);
            a0 += v * *dense.get_unchecked(*idx.get_unchecked(i) as usize);
            n0 += v * v;
        }
    }
    ((a0 + a1) + (a2 + a3), (n0 + n1) + (n2 + n3))
}

// ---------------------------------------------------------------------------
// Mixed-precision (f32-storage) helpers — solver::scd's MixedF32 path.
// f32 column/residual mirrors halve the hot loop's memory traffic; each
// product rounds once in f32 and ACCUMULATES in f64 (the ×4 convention),
// so the coordinate step, α update and Δv stay f64. Deliberately NOT
// bit-stable against the f64 path (DESIGN.md §11).
// ---------------------------------------------------------------------------

/// Mixed-precision sparse dot: f32 storage reads, f64 ×4 accumulation.
/// Contract as [`dot_indexed`].
#[inline]
pub fn dot_indexed_f32(idx: &[u32], vals: &[f32], dense: &[f32]) -> f64 {
    debug_assert_eq!(idx.len(), vals.len(), "dot_indexed_f32: length mismatch");
    let n = idx.len().min(vals.len());
    let chunks = n / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
    // SAFETY: as `dot_indexed` (f32 storage, same clamped indices and the same
    // solver-boundary contract on `idx`).
    unsafe {
        for c in 0..chunks {
            let base = c * 4;
            a0 += (*vals.get_unchecked(base)
                * *dense.get_unchecked(*idx.get_unchecked(base) as usize))
                as f64;
            a1 += (*vals.get_unchecked(base + 1)
                * *dense.get_unchecked(*idx.get_unchecked(base + 1) as usize))
                as f64;
            a2 += (*vals.get_unchecked(base + 2)
                * *dense.get_unchecked(*idx.get_unchecked(base + 2) as usize))
                as f64;
            a3 += (*vals.get_unchecked(base + 3)
                * *dense.get_unchecked(*idx.get_unchecked(base + 3) as usize))
                as f64;
        }
        for i in chunks * 4..n {
            a0 += (*vals.get_unchecked(i) * *dense.get_unchecked(*idx.get_unchecked(i) as usize))
                as f64;
        }
    }
    (a0 + a1) + (a2 + a3)
}

/// Mixed-precision scatter update: `dense[idx[i]] += a * vals[i]` in f32
/// (the residual mirror update). Contract as [`axpy_indexed`].
#[inline]
pub fn axpy_indexed_f32(a: f32, idx: &[u32], vals: &[f32], dense: &mut [f32]) {
    debug_assert_eq!(idx.len(), vals.len(), "axpy_indexed_f32: length mismatch");
    let n = idx.len().min(vals.len());
    // SAFETY: as `axpy_indexed` (f32 storage, same clamped indices and the same
    // solver-boundary contract on `idx`).
    unsafe {
        for i in 0..n {
            *dense.get_unchecked_mut(*idx.get_unchecked(i) as usize) += a * *vals.get_unchecked(i);
        }
    }
}
