//! The hot-path kernel layer: one scalar reference, one optional SIMD
//! backend, one dispatch point (DESIGN.md §11).
//!
//! * [`scalar`] — the bit-exactness ORACLE. Every kernel in the ×4
//!   independent-accumulator convention; always compiled, always the
//!   default.
//! * [`simd`] (feature `simd`, x86-64 only) — explicit 4-lane AVX2 with
//!   runtime feature detection. The lane layout mirrors the scalar
//!   convention exactly, so results are bit-equal at every length; on
//!   non-x86 targets the `simd` feature falls back to the scalar kernels,
//!   whose ×4 chunking IS the portable-chunk form.
//! * [`block`] — the cache-blocked CSC traversal plan for the SCD inner
//!   loop (orthogonal to the backend choice: blocking decisions depend
//!   only on data shape, never on the `simd` feature).
//!
//! The free functions below are the dispatchers `linalg` re-exports; all
//! call sites (solvers, matvecs, reducers) route through them. A runtime
//! switch ([`force_scalar`]) pins the scalar reference even when AVX2 is
//! compiled in and detected, so ONE binary can compare both backends —
//! the trajectory bit-equality tests and the `kernels` bench section use
//! it. Dispatch costs one relaxed atomic load + a cached CPUID flag per
//! call; the `simd`-less build compiles to direct scalar calls.

pub mod block;
pub mod scalar;
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub mod simd;

pub use block::{BlockPlan, DEFAULT_BLOCK_ROWS};
pub use scalar::{axpy_indexed_f32, dot_indexed_f32};

#[cfg(feature = "simd")]
mod switch {
    use std::sync::atomic::{AtomicBool, Ordering};

    /// Runtime backend pin: `true` forces the scalar reference.
    static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

    pub(super) fn set(on: bool) {
        FORCE_SCALAR.store(on, Ordering::SeqCst);
    }

    #[inline]
    pub(super) fn forced() -> bool {
        FORCE_SCALAR.load(Ordering::Relaxed)
    }
}

/// Pin the scalar reference at runtime even when the `simd` feature is
/// compiled in and AVX2 is detected (no-op otherwise). Process-global:
/// tests and benches that toggle it run their comparisons sequentially.
#[cfg(feature = "simd")]
pub fn force_scalar(on: bool) {
    switch::set(on);
}

/// No-op without the `simd` feature — the scalar reference is all there is.
#[cfg(not(feature = "simd"))]
pub fn force_scalar(_on: bool) {}

/// Name of the backend the dispatchers select right now:
/// `"avx2"`, `"scalar"` (default build, undetected, or forced via
/// [`force_scalar`]), or `"portable"` (`simd` feature on a non-x86
/// target — the scalar ×4 chunked form).
pub fn backend() -> &'static str {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if switch::forced() {
            return "scalar";
        }
        if std::is_x86_feature_detected!("avx2") {
            return "avx2";
        }
        return "scalar";
    }
    #[cfg(all(feature = "simd", not(target_arch = "x86_64")))]
    {
        return "portable";
    }
    #[allow(unreachable_code)]
    "scalar"
}

/// Whether the AVX2 backend will execute the next kernel call.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[inline]
fn simd_active() -> bool {
    !switch::forced() && std::is_x86_feature_detected!("avx2")
}

/// Gathers sign-extend i32 indices: the AVX2 indexed kernels only engage
/// when the dense operand is addressable by non-negative i32.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
const I32_INDEXABLE: usize = i32::MAX as usize;

/// `y += x` (AllReduce aggregation). See [`scalar::add_assign`] for the
/// contract.
#[inline]
pub fn add_assign(y: &mut [f64], x: &[f64]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: AVX2 presence just checked.
        return unsafe { simd::add_assign(y, x) };
    }
    scalar::add_assign(y, x)
}

/// `y -= x` (cold path; scalar on every backend).
#[inline]
pub fn sub_assign(y: &mut [f64], x: &[f64]) {
    scalar::sub_assign(y, x)
}

/// Dense `y += a * x`. See [`scalar::axpy`] for the contract.
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: AVX2 presence just checked.
        return unsafe { simd::axpy(a, x, y) };
    }
    scalar::axpy(a, x, y)
}

/// Dense dot product. See [`scalar::dot`] for the contract.
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: AVX2 presence just checked.
        return unsafe { simd::dot(x, y) };
    }
    scalar::dot(x, y)
}

/// Sparse-column dot (the hottest kernel). See [`scalar::dot_indexed`]
/// for the contract.
#[inline]
pub fn dot_indexed(idx: &[u32], vals: &[f64], dense: &[f64]) -> f64 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() && dense.len() <= I32_INDEXABLE {
        // SAFETY: AVX2 presence checked; index bounds are the shared
        // solver-boundary contract; dense is i32-indexable for the gather.
        return unsafe { simd::dot_indexed(idx, vals, dense) };
    }
    scalar::dot_indexed(idx, vals, dense)
}

/// Sparse scatter `dense[idx[i]] += a * vals[i]`. See
/// [`scalar::axpy_indexed`] for the contract.
#[inline]
pub fn axpy_indexed(a: f64, idx: &[u32], vals: &[f64], dense: &mut [f64]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: AVX2 presence checked; index bounds are the shared
        // solver-boundary contract (no gather — no i32 bound).
        return unsafe { simd::axpy_indexed(a, idx, vals, dense) };
    }
    scalar::axpy_indexed(a, idx, vals, dense)
}

/// Fused sparse dot + squared norm. See [`scalar::dot_indexed_fused`]
/// for the contract.
#[inline]
pub fn dot_indexed_fused(idx: &[u32], vals: &[f64], dense: &[f64]) -> (f64, f64) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() && dense.len() <= I32_INDEXABLE {
        // SAFETY: as `dot_indexed`.
        return unsafe { simd::dot_indexed_fused(idx, vals, dense) };
    }
    scalar::dot_indexed_fused(idx, vals, dense)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Xorshift128;

    /// Lengths the property sweeps cover: everything around the unroll
    /// width plus large sizes that stress many full chunks.
    fn sweep_lengths() -> Vec<usize> {
        if cfg!(miri) {
            // Miri interprets every FP op; the bit-equality argument is
            // inductive in length, so a dense band around the unroll width
            // plus two ragged tails keeps full UB coverage at ~1% the cost.
            return (0..=16).chain([31, 45]).collect();
        }
        let mut v: Vec<usize> = (0..=64).collect();
        v.extend([127, 1000, 4093]);
        v
    }

    /// Random payload with NaN and ±0.0 planted — the bit-equality
    /// assertions must hold for non-finite payloads too (x86 NaN
    /// propagation picks the same operand for scalar and packed ops).
    fn payload(rng: &mut Xorshift128, n: usize) -> Vec<f64> {
        (0..n)
            .map(|i| match i % 17 {
                7 => f64::NAN,
                11 => -0.0,
                13 => 0.0,
                _ => rng.next_gaussian(),
            })
            .collect()
    }

    #[test]
    fn dispatched_kernels_bit_equal_scalar_reference() {
        // The dispatcher (whatever backend it picks on this machine) must
        // agree with the scalar oracle to the bit, at every length, with
        // unaligned slice starts, and with NaN/±0.0 payloads. In the
        // default build this pins dispatch == scalar; with `--features
        // simd` on an AVX2 core it is the tentpole bit-equality proof.
        let mut rng = Xorshift128::new(42);
        let dense_len = if cfg!(miri) { 96usize } else { 4096usize };
        for n in sweep_lengths() {
            for offset in [0usize, 1, 3] {
                let dense = payload(&mut rng, dense_len + offset);
                let dense = &dense[offset..];
                let idx: Vec<u32> = (0..n).map(|_| rng.next_usize(dense_len) as u32).collect();
                let vals = payload(&mut rng, n + offset);
                let vals = &vals[offset..];
                let x = payload(&mut rng, n + offset);
                let x = &x[offset..];

                assert_eq!(
                    dot(x, vals).to_bits(),
                    scalar::dot(x, vals).to_bits(),
                    "dot n={} off={}",
                    n,
                    offset
                );
                assert_eq!(
                    dot_indexed(&idx, vals, dense).to_bits(),
                    scalar::dot_indexed(&idx, vals, dense).to_bits(),
                    "dot_indexed n={} off={}",
                    n,
                    offset
                );
                let (fd, fn_) = dot_indexed_fused(&idx, vals, dense);
                let (sd, sn) = scalar::dot_indexed_fused(&idx, vals, dense);
                assert_eq!(fd.to_bits(), sd.to_bits(), "fused dot n={}", n);
                assert_eq!(fn_.to_bits(), sn.to_bits(), "fused norm n={}", n);

                let mut y1: Vec<f64> = vals.to_vec();
                let mut y2 = y1.clone();
                axpy(0.75, x, &mut y1);
                scalar::axpy(0.75, x, &mut y2);
                assert_eq!(
                    y1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    y2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "axpy n={}",
                    n
                );

                add_assign(&mut y1, x);
                scalar::add_assign(&mut y2, x);
                assert_eq!(
                    y1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    y2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "add_assign n={}",
                    n
                );

                // Scatter: unique targets (CSC contract) — sample without
                // replacement by striding.
                let uniq: Vec<u32> = (0..n.min(dense_len))
                    .map(|i| ((i * 37) % dense_len) as u32)
                    .collect();
                let uvals = &vals[..uniq.len()];
                let mut d1 = dense.to_vec();
                let mut d2 = d1.clone();
                axpy_indexed(-1.25, &uniq, uvals, &mut d1);
                scalar::axpy_indexed(-1.25, &uniq, uvals, &mut d2);
                assert_eq!(
                    d1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    d2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "axpy_indexed n={}",
                    n
                );
            }
        }
    }

    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    #[test]
    fn avx2_backend_bit_equal_scalar_directly() {
        // Bypass the dispatcher and pin the AVX2 functions themselves
        // (the dispatcher test above could silently route scalar-scalar
        // if detection failed). Skips on cores without AVX2.
        if !std::is_x86_feature_detected!("avx2") {
            return;
        }
        let mut rng = Xorshift128::new(7);
        let dense = payload(&mut rng, 2048);
        for n in sweep_lengths() {
            let idx: Vec<u32> = (0..n).map(|_| rng.next_usize(2048) as u32).collect();
            let vals = payload(&mut rng, n);
            // SAFETY: AVX2 presence is feature-detected at the top of the
            // test; `idx` entries are drawn below `dense.len()`.
            unsafe {
                assert_eq!(
                    simd::dot(&vals, &vals).to_bits(),
                    scalar::dot(&vals, &vals).to_bits(),
                    "n={}",
                    n
                );
                assert_eq!(
                    simd::dot_indexed(&idx, &vals, &dense).to_bits(),
                    scalar::dot_indexed(&idx, &vals, &dense).to_bits(),
                    "n={}",
                    n
                );
                let (ad, an) = simd::dot_indexed_fused(&idx, &vals, &dense);
                let (sd, sn) = scalar::dot_indexed_fused(&idx, &vals, &dense);
                assert_eq!(ad.to_bits(), sd.to_bits(), "n={}", n);
                assert_eq!(an.to_bits(), sn.to_bits(), "n={}", n);
                let mut y1 = dense[..n].to_vec();
                let mut y2 = y1.clone();
                simd::axpy(1.5, &vals, &mut y1);
                scalar::axpy(1.5, &vals, &mut y2);
                for (a, b) in y1.iter().zip(y2.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "n={}", n);
                }
            }
        }
    }

    #[test]
    fn fused_norm_bit_equal_dense_self_dot() {
        // THE invariant satellite 1 rests on: the fused kernel's norm half
        // equals dot(vals, vals) — hence nrm2_sq, hence the col_sq table —
        // to the bit at every length. This is what makes switching the SCD
        // loop from the table to the fused kernel a pure refactor.
        let mut rng = Xorshift128::new(99);
        let dense = payload(&mut rng, 512);
        for n in sweep_lengths() {
            let idx: Vec<u32> = (0..n).map(|_| rng.next_usize(512) as u32).collect();
            let vals: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
            let (_, nrm) = dot_indexed_fused(&idx, &vals, &dense);
            assert_eq!(nrm.to_bits(), dot(&vals, &vals).to_bits(), "n={}", n);
            assert_eq!(
                nrm.to_bits(),
                crate::linalg::nrm2_sq(&vals).to_bits(),
                "n={}",
                n
            );
        }
    }

    #[test]
    fn empty_and_singleton_columns() {
        let dense = vec![2.0, 3.0, 5.0];
        assert_eq!(dot_indexed(&[], &[], &dense), 0.0);
        assert_eq!(dot_indexed_fused(&[], &[], &dense), (0.0, 0.0));
        assert_eq!(dot_indexed(&[2], &[4.0], &dense), 20.0);
        assert_eq!(dot_indexed_fused(&[1], &[4.0], &dense), (12.0, 16.0));
        let mut d = dense.clone();
        axpy_indexed(2.0, &[0], &[0.5], &mut d);
        assert_eq!(d, vec![3.0, 3.0, 5.0]);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn backend_reports_a_known_name() {
        let b = backend();
        assert!(
            b == "avx2" || b == "scalar" || b == "portable",
            "unexpected backend {}",
            b
        );
        // force_scalar is callable in every build (no-op without `simd`).
        force_scalar(true);
        #[cfg(feature = "simd")]
        assert_ne!(backend(), "avx2");
        force_scalar(false);
    }

    #[cfg(debug_assertions)]
    mod contract {
        use super::super::scalar;

        #[test]
        #[should_panic(expected = "dot: length mismatch")]
        fn dot_rejects_mismatched_lengths_in_debug() {
            scalar::dot(&[1.0, 2.0], &[1.0]);
        }

        #[test]
        #[should_panic(expected = "axpy: length mismatch")]
        fn axpy_rejects_mismatched_lengths_in_debug() {
            let mut y = [0.0];
            scalar::axpy(1.0, &[1.0, 2.0], &mut y);
        }

        #[test]
        #[should_panic(expected = "add_assign: length mismatch")]
        fn add_assign_rejects_mismatched_lengths_in_debug() {
            let mut y = [0.0];
            scalar::add_assign(&mut y, &[1.0, 2.0]);
        }

        #[test]
        #[should_panic(expected = "dot_indexed: length mismatch")]
        fn dot_indexed_rejects_mismatched_lengths_in_debug() {
            scalar::dot_indexed(&[0, 1], &[1.0], &[1.0, 2.0]);
        }
    }
}
