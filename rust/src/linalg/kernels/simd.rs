//! AVX2 backend: 4-lane f64 vectorization of the hot kernels, bit-equal
//! to the scalar reference BY CONSTRUCTION.
//!
//! The scalar kernels ([`super::scalar`]) already run four independent
//! stride-4 accumulators — that IS a 4-lane AVX2 register laid on its
//! side. Lane *i* of the vector accumulator performs exactly the adds of
//! scalar accumulator `a_i`, in the same chunk order:
//!
//! * products use `_mm256_mul_pd` followed by `_mm256_add_pd` — two
//!   roundings per element, never `_mm256_fmadd_pd` (FMA rounds once and
//!   would change bits vs the scalar multiply-then-add);
//! * the remainder (`n % 4` tail elements) folds into extracted lane 0
//!   with scalar ops, exactly like the scalar kernels fold into `a0`;
//! * the final reduce extracts the four lanes and applies the same fixed
//!   `(a0 + a1) + (a2 + a3)` pairing in scalar arithmetic (no `hadd`,
//!   whose lane order differs).
//!
//! Gathers (`_mm256_i32gather_pd`) sign-extend 32-bit indices, so the
//! dispatcher ([`super`]) only routes here when `dense.len() <=
//! i32::MAX` — row counts beyond 2³¹ fall back to scalar (and every
//! `idx[i] < dense.len()` is the same solver-boundary contract the scalar
//! kernels rely on for their unchecked reads).
//!
//! Every function is `#[target_feature(enable = "avx2")]` and only
//! reachable through the runtime-detected dispatcher; calling them on a
//! non-AVX2 core is undefined behavior, hence `unsafe`.
#![cfg(all(feature = "simd", target_arch = "x86_64"))]

use core::arch::x86_64::{
    __m128i, _mm256_add_pd, _mm256_i32gather_pd, _mm256_loadu_pd, _mm256_mul_pd,
    _mm256_set1_pd, _mm256_setzero_pd, _mm256_storeu_pd, _mm_loadu_si128,
};

/// Dense dot, AVX2 lanes ≡ scalar `a0..a3`.
///
/// # Safety
/// Requires AVX2 (dispatcher-checked).
// SAFETY: all loads go through `_mm256_loadu_pd` on offsets bounded by
// `n = min(len, len)` chunk math; the tail uses checked indexing. The only
// caller obligation is AVX2 presence, verified by the dispatcher.
#[target_feature(enable = "avx2")]
pub unsafe fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len(), "dot: length mismatch");
    let n = x.len().min(y.len());
    let chunks = n / 4;
    let mut acc = _mm256_setzero_pd();
    for c in 0..chunks {
        let base = c * 4;
        let xv = _mm256_loadu_pd(x.as_ptr().add(base));
        let yv = _mm256_loadu_pd(y.as_ptr().add(base));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(xv, yv));
    }
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
    let (mut a0, a1, a2, a3) = (lanes[0], lanes[1], lanes[2], lanes[3]);
    for i in chunks * 4..n {
        a0 += *x.get_unchecked(i) * *y.get_unchecked(i);
    }
    (a0 + a1) + (a2 + a3)
}

/// Dense `y += a * x`. Element-wise (one mul + one add per element), so
/// packed execution is bit-neutral.
///
/// # Safety
/// Requires AVX2 (dispatcher-checked).
// SAFETY: packed loads/stores and the `get_unchecked` tail are bounded by
// `n = min(x.len(), y.len())`; AVX2 presence is the dispatcher's check.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(y.len(), x.len(), "axpy: length mismatch");
    let n = x.len().min(y.len());
    let chunks = n / 4;
    let va = _mm256_set1_pd(a);
    for c in 0..chunks {
        let base = c * 4;
        let xv = _mm256_loadu_pd(x.as_ptr().add(base));
        let yv = _mm256_loadu_pd(y.as_ptr().add(base));
        _mm256_storeu_pd(
            y.as_mut_ptr().add(base),
            _mm256_add_pd(yv, _mm256_mul_pd(va, xv)),
        );
    }
    for i in chunks * 4..n {
        *y.get_unchecked_mut(i) += a * *x.get_unchecked(i);
    }
}

/// `y += x`, packed. Element-wise → bit-neutral.
///
/// # Safety
/// Requires AVX2 (dispatcher-checked).
// SAFETY: same bounds argument as `axpy` — every access is clamped by
// `n = min(x.len(), y.len())`; AVX2 presence is the dispatcher's check.
#[target_feature(enable = "avx2")]
pub unsafe fn add_assign(y: &mut [f64], x: &[f64]) {
    debug_assert_eq!(y.len(), x.len(), "add_assign: length mismatch");
    let n = x.len().min(y.len());
    let chunks = n / 4;
    for c in 0..chunks {
        let base = c * 4;
        let xv = _mm256_loadu_pd(x.as_ptr().add(base));
        let yv = _mm256_loadu_pd(y.as_ptr().add(base));
        _mm256_storeu_pd(y.as_mut_ptr().add(base), _mm256_add_pd(yv, xv));
    }
    for i in chunks * 4..n {
        *y.get_unchecked_mut(i) += *x.get_unchecked(i);
    }
}

/// Sparse-column dot via 4-wide index gathers; lanes ≡ scalar `a0..a3`.
///
/// # Safety
/// Requires AVX2, `dense.len() <= i32::MAX` and every `idx[i] <
/// dense.len()` (dispatcher + solver-boundary contract).
// SAFETY: the gather reads `dense[idx[c*4..c*4+4]]` — in-bounds iff the
// caller upholds `idx[i] < dense.len()` (asserted at the solver boundary);
// `idx`/`vals` accesses are clamped by `n = min(len, len)`.
#[target_feature(enable = "avx2")]
pub unsafe fn dot_indexed(idx: &[u32], vals: &[f64], dense: &[f64]) -> f64 {
    debug_assert_eq!(idx.len(), vals.len(), "dot_indexed: length mismatch");
    let n = idx.len().min(vals.len());
    let chunks = n / 4;
    let mut acc = _mm256_setzero_pd();
    for c in 0..chunks {
        let base = c * 4;
        let i4 = _mm_loadu_si128(idx.as_ptr().add(base) as *const __m128i);
        let g = _mm256_i32gather_pd::<8>(dense.as_ptr(), i4);
        let v = _mm256_loadu_pd(vals.as_ptr().add(base));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(v, g));
    }
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
    let (mut a0, a1, a2, a3) = (lanes[0], lanes[1], lanes[2], lanes[3]);
    for i in chunks * 4..n {
        a0 += *vals.get_unchecked(i) * *dense.get_unchecked(*idx.get_unchecked(i) as usize);
    }
    (a0 + a1) + (a2 + a3)
}

/// Sparse scatter `dense[idx[i]] += a * vals[i]`: products computed
/// 4-wide, scattered with scalar adds (AVX2 has gathers but no scatters).
/// Each target slot still sees exactly one mul + one add → bit-neutral.
///
/// # Safety
/// As [`dot_indexed`] (without the i32 bound — no gather here).
// SAFETY: scalar scatters write `dense[idx[i]]` via `get_unchecked_mut` —
// in-bounds iff the caller upholds `idx[i] < dense.len()` (asserted at the
// solver boundary); `idx`/`vals` accesses are clamped by `n`.
#[target_feature(enable = "avx2")]
pub unsafe fn axpy_indexed(a: f64, idx: &[u32], vals: &[f64], dense: &mut [f64]) {
    debug_assert_eq!(idx.len(), vals.len(), "axpy_indexed: length mismatch");
    let n = idx.len().min(vals.len());
    let chunks = n / 4;
    let va = _mm256_set1_pd(a);
    let mut lanes = [0.0f64; 4];
    for c in 0..chunks {
        let base = c * 4;
        let v = _mm256_loadu_pd(vals.as_ptr().add(base));
        _mm256_storeu_pd(lanes.as_mut_ptr(), _mm256_mul_pd(va, v));
        *dense.get_unchecked_mut(*idx.get_unchecked(base) as usize) += lanes[0];
        *dense.get_unchecked_mut(*idx.get_unchecked(base + 1) as usize) += lanes[1];
        *dense.get_unchecked_mut(*idx.get_unchecked(base + 2) as usize) += lanes[2];
        *dense.get_unchecked_mut(*idx.get_unchecked(base + 3) as usize) += lanes[3];
    }
    for i in chunks * 4..n {
        *dense.get_unchecked_mut(*idx.get_unchecked(i) as usize) += a * *vals.get_unchecked(i);
    }
}

/// Fused sparse dot + squared norm, both accumulators 4-wide; lanes ≡
/// the scalar kernel's `a0..a3` / `n0..n3`.
///
/// # Safety
/// As [`dot_indexed`].
// SAFETY: identical access pattern to `dot_indexed` (one extra register
// accumulator, no extra memory traffic) — same bounds argument.
#[target_feature(enable = "avx2")]
pub unsafe fn dot_indexed_fused(idx: &[u32], vals: &[f64], dense: &[f64]) -> (f64, f64) {
    debug_assert_eq!(idx.len(), vals.len(), "dot_indexed_fused: length mismatch");
    let n = idx.len().min(vals.len());
    let chunks = n / 4;
    let mut acc = _mm256_setzero_pd();
    let mut nrm = _mm256_setzero_pd();
    for c in 0..chunks {
        let base = c * 4;
        let i4 = _mm_loadu_si128(idx.as_ptr().add(base) as *const __m128i);
        let g = _mm256_i32gather_pd::<8>(dense.as_ptr(), i4);
        let v = _mm256_loadu_pd(vals.as_ptr().add(base));
        acc = _mm256_add_pd(acc, _mm256_mul_pd(v, g));
        nrm = _mm256_add_pd(nrm, _mm256_mul_pd(v, v));
    }
    let mut alanes = [0.0f64; 4];
    let mut nlanes = [0.0f64; 4];
    _mm256_storeu_pd(alanes.as_mut_ptr(), acc);
    _mm256_storeu_pd(nlanes.as_mut_ptr(), nrm);
    let (mut a0, a1, a2, a3) = (alanes[0], alanes[1], alanes[2], alanes[3]);
    let (mut n0, n1, n2, n3) = (nlanes[0], nlanes[1], nlanes[2], nlanes[3]);
    for i in chunks * 4..n {
        let v = *vals.get_unchecked(i);
        a0 += v * *dense.get_unchecked(*idx.get_unchecked(i) as usize);
        n0 += v * v;
    }
    ((a0 + a1) + (a2 + a3), (n0 + n1) + (n2 + n3))
}
