//! Cache-blocked CSC column traversal for the SCD inner loop.
//!
//! On tall datasets the residual `r` (m doubles) outgrows L2, and each
//! column dot/axpy walks it end to end — every SCD step streams the
//! residual through the cache hierarchy. [`BlockPlan`] precomputes, per
//! column, where its row-index run crosses L2-sized row-block boundaries
//! (`block_rows` rows ≙ `block_rows × 8` bytes of residual), so the
//! blocked kernels traverse one residual block's worth of a column at a
//! time — keeping the dot's gathers and the following axpy's scatters
//! inside the same cache footprint.
//!
//! **Bit-exactness boundary (DESIGN.md §11).** The blocked dot sums one
//! ×4-convention partial dot per segment and adds the partials serially —
//! a DIFFERENT summation tree than the single whole-column ×4 pass, so
//! blocked results are deliberately NOT bit-equal to unblocked ones.
//! Consequently the solver only engages the plan above a row threshold
//! (`m > block_rows`, default 2¹⁵ — far above every bit-pinned test
//! fixture), and the blocking decision depends ONLY on the data shape —
//! never on the `simd` feature — so scalar-blocked remains the bitwise
//! oracle for SIMD-blocked and flat-vs-nested engine equalities are
//! untouched (both sides see the same plan). The blocked *axpy* is
//! element-wise and therefore bit-equal to the unblocked scatter; it is
//! segmented purely for locality symmetry with the dot.
//!
//! The plan lives in solver scratch, keyed by data identity: steady-state
//! solves never rebuild it and never allocate (counting-allocator tests
//! in `solver::scd`).

use crate::data::CscMatrix;

/// Default row-block height: 2¹⁵ rows ≙ 256 KiB of f64 residual — sized
/// to sit inside a typical per-core L2 with room for the column stream.
pub const DEFAULT_BLOCK_ROWS: usize = 1 << 15;

/// Precomputed per-shard blocking plan: for every column, the offsets
/// (within the column's `(row_idx, vals)` slices) where a new
/// `block_rows`-high row block begins.
#[derive(Debug, Clone)]
pub struct BlockPlan {
    /// Identity of the matrix this plan was built for (pointer + shape) —
    /// the same cheap cache key the managed solvers use for their record
    /// layouts. Rebuilt automatically when the solver sees other data.
    key: (usize, usize, usize),
    block_rows: usize,
    /// Per-column range into `seg_end`: column `j`'s segment ends are
    /// `seg_end[seg_ptr[j]..seg_ptr[j + 1]]`. Length `n + 1`.
    seg_ptr: Vec<u32>,
    /// Flat array of segment END offsets, relative to the column start;
    /// each column's final entry equals its nnz.
    seg_end: Vec<u32>,
}

impl BlockPlan {
    fn key_of(mat: &CscMatrix) -> (usize, usize, usize) {
        (mat as *const CscMatrix as usize, mat.m, mat.n)
    }

    /// Build the plan for `mat` with `block_rows`-high row blocks.
    pub fn build(mat: &CscMatrix, block_rows: usize) -> BlockPlan {
        assert!(block_rows > 0, "block_rows must be positive");
        let mut seg_ptr = Vec::with_capacity(mat.n + 1);
        let mut seg_end = Vec::new();
        seg_ptr.push(0u32);
        for j in 0..mat.n {
            let (lo, hi) = (mat.col_ptr[j], mat.col_ptr[j + 1]);
            let rows = &mat.row_idx[lo..hi];
            let mut cur_block = usize::MAX;
            for (off, &ri) in rows.iter().enumerate() {
                let blk = ri as usize / block_rows;
                if blk != cur_block {
                    if off > 0 {
                        seg_end.push(off as u32);
                    }
                    cur_block = blk;
                }
            }
            if !rows.is_empty() {
                seg_end.push(rows.len() as u32);
            }
            seg_ptr.push(seg_end.len() as u32);
        }
        BlockPlan {
            key: BlockPlan::key_of(mat),
            block_rows,
            seg_ptr,
            seg_end,
        }
    }

    /// Whether this plan was built for exactly this matrix and block size
    /// (solver scratch uses this to skip steady-state rebuilds).
    pub fn matches(&self, mat: &CscMatrix, block_rows: usize) -> bool {
        self.key == BlockPlan::key_of(mat) && self.block_rows == block_rows
    }

    /// The row-block height this plan was built with.
    pub fn block_rows(&self) -> usize {
        self.block_rows
    }

    /// Column `j`'s segment end offsets (relative to the column start).
    #[inline]
    fn segments(&self, j: usize) -> &[u32] {
        &self.seg_end[self.seg_ptr[j] as usize..self.seg_ptr[j + 1] as usize]
    }

    /// Blocked sparse dot over column `j`: one ×4-convention partial dot
    /// per residual block, partials summed serially (NOT bit-equal to the
    /// unblocked whole-column dot — module docs).
    #[inline]
    pub fn dot_indexed(&self, j: usize, idx: &[u32], vals: &[f64], dense: &[f64]) -> f64 {
        let mut acc = 0.0f64;
        let mut start = 0usize;
        for &e in self.segments(j) {
            let end = e as usize;
            acc += super::dot_indexed(&idx[start..end], &vals[start..end], dense);
            start = end;
        }
        acc
    }

    /// Blocked scatter update over column `j` — element-wise, hence
    /// bit-equal to the unblocked [`super::axpy_indexed`]; segmented so
    /// the scatters revisit the residual blocks the dot just touched.
    #[inline]
    pub fn axpy_indexed(&self, j: usize, a: f64, idx: &[u32], vals: &[f64], dense: &mut [f64]) {
        let mut start = 0usize;
        for &e in self.segments(j) {
            let end = e as usize;
            super::axpy_indexed(a, &idx[start..end], &vals[start..end], dense);
            start = end;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{self, Xorshift128};

    fn random_csc(m: usize, n: usize, avg_nnz: usize, seed: u64) -> CscMatrix {
        let mut rng = Xorshift128::new(seed);
        let mut t = Vec::new();
        for c in 0..n {
            let nnz = 1 + rng.next_usize(2 * avg_nnz);
            for _ in 0..nnz {
                t.push((rng.next_usize(m), c, rng.next_gaussian()));
            }
        }
        CscMatrix::from_triplets(m, n, &t)
    }

    #[test]
    fn segments_partition_every_column() {
        let mat = random_csc(100, 20, 8, 7);
        let plan = BlockPlan::build(&mat, 16);
        for j in 0..mat.n {
            let (ri, _) = mat.col(j);
            let segs = plan.segments(j);
            // Ends strictly increase and the last one covers the column.
            let mut prev = 0u32;
            for &e in segs {
                assert!(e > prev || (e == 0 && prev == 0), "col {}", j);
                prev = e;
            }
            assert_eq!(segs.last().copied().unwrap_or(0) as usize, ri.len());
            // Within one segment, all rows share a block.
            let mut start = 0usize;
            for &e in segs {
                let blk = ri[start] as usize / 16;
                for &r in &ri[start..e as usize] {
                    assert_eq!(r as usize / 16, blk);
                }
                start = e as usize;
            }
        }
    }

    #[test]
    fn blocked_dot_matches_unblocked_numerically() {
        // Not bit-equal (different summation tree) — but within float
        // tolerance at realistic magnitudes, and exactly equal when a
        // column fits one block.
        let mat = random_csc(256, 30, 12, 3);
        let plan = BlockPlan::build(&mat, 64);
        let mut rng = Xorshift128::new(5);
        let dense: Vec<f64> = (0..256).map(|_| rng.next_gaussian()).collect();
        for j in 0..mat.n {
            let (ri, vs) = mat.col(j);
            let blocked = plan.dot_indexed(j, ri, vs, &dense);
            let flat = linalg::dot_indexed(ri, vs, &dense);
            assert!(
                (blocked - flat).abs() <= 1e-12 * (1.0 + flat.abs()),
                "col {}: {} vs {}",
                j,
                blocked,
                flat
            );
        }
        // One-block plan ⇒ the exact same single ×4 pass ⇒ same bits.
        let one = BlockPlan::build(&mat, 1 << 20);
        for j in 0..mat.n {
            let (ri, vs) = mat.col(j);
            assert_eq!(
                one.dot_indexed(j, ri, vs, &dense).to_bits(),
                linalg::dot_indexed(ri, vs, &dense).to_bits()
            );
        }
    }

    #[test]
    fn blocked_axpy_is_bit_equal_to_unblocked() {
        let mat = random_csc(200, 25, 10, 11);
        let plan = BlockPlan::build(&mat, 32);
        let mut rng = Xorshift128::new(13);
        let base: Vec<f64> = (0..200).map(|_| rng.next_gaussian()).collect();
        for j in 0..mat.n {
            let (ri, vs) = mat.col(j);
            let mut blocked = base.clone();
            let mut flat = base.clone();
            plan.axpy_indexed(j, 0.37, ri, vs, &mut blocked);
            linalg::axpy_indexed(0.37, ri, vs, &mut flat);
            for (a, b) in blocked.iter().zip(flat.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "col {}", j);
            }
        }
    }

    #[test]
    fn plan_cache_key_tracks_identity_and_block_size() {
        let mat = random_csc(64, 8, 4, 1);
        let plan = BlockPlan::build(&mat, 16);
        assert!(plan.matches(&mat, 16));
        assert!(!plan.matches(&mat, 32));
        let other = mat.clone();
        assert!(!plan.matches(&other, 16));
        assert_eq!(plan.block_rows(), 16);
    }

    #[test]
    fn empty_columns_produce_empty_segment_lists() {
        let mat = CscMatrix::zeros(50, 4);
        let plan = BlockPlan::build(&mat, 8);
        let dense = vec![1.0; 50];
        for j in 0..4 {
            assert_eq!(plan.segments(j).len(), 0);
            let (ri, vs) = mat.col(j);
            assert_eq!(plan.dot_indexed(j, ri, vs, &dense), 0.0);
        }
    }
}
