//! Deterministic xorshift128+ RNG.
//!
//! Every stochastic component (data generation, coordinate sampling,
//! partition shuffling) takes an explicit seed so experiments are exactly
//! reproducible run-to-run — the paper averages over 10 runs; we average
//! over seeds 0..R.

/// xorshift128+ (Vigna 2014): fast, passes BigCrush minus matrix rank tests;
/// entirely sufficient for coordinate sampling and synthetic data.
#[derive(Debug, Clone)]
pub struct Xorshift128 {
    s0: u64,
    s1: u64,
}

impl Xorshift128 {
    /// Seed with SplitMix64 expansion so small/consecutive seeds give
    /// well-separated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s0 = next();
        let mut s1 = next();
        if s0 == 0 && s1 == 0 {
            s1 = 1;
        }
        Xorshift128 { s0, s1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Uses rejection-free modulo (bias < 2^-32
    /// for the n values used here); n must be > 0.
    #[inline]
    pub fn next_usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.next_usize(i + 1);
            v.swap(i, j);
        }
    }

    /// A random permutation of 0..n as u32 (feeds the PJRT kernel's idx input).
    pub fn permutation_u32(&mut self, n: usize) -> Vec<u32> {
        let mut v: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut v);
        v
    }

    /// Zipf-like power-law sample in [0, n): P(i) ∝ (i+1)^-s, via inverse
    /// CDF on a precomputed table is overkill here — we use the standard
    /// approximation by inverse transform of the continuous density,
    /// adequate for generating webspam-like column popularity skew.
    pub fn next_powerlaw(&mut self, n: usize, s: f64) -> usize {
        let u = self.next_f64();
        if (s - 1.0).abs() < 1e-9 {
            let x = ((n as f64).ln() * u).exp();
            (x as usize).min(n - 1)
        } else {
            let nf = n as f64;
            let a = 1.0 - s;
            let x = ((nf.powf(a) - 1.0) * u + 1.0).powf(1.0 / a);
            (x as usize - 1).min(n - 1)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Xorshift128::new(42);
        let mut b = Xorshift128::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let mut a = Xorshift128::new(1);
        let mut b = Xorshift128::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Xorshift128::new(7);
        let xs: Vec<f64> = (0..20_000).map(|_| r.next_f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        // lint: allow(bitexact) -- statistical test; tolerance-checked, not a trajectory input
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((m - 0.5).abs() < 0.01, "mean {}", m);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xorshift128::new(9);
        let xs: Vec<f64> = (0..50_000).map(|_| r.next_gaussian()).collect();
        // lint: allow(bitexact) -- statistical test; tolerance-checked, not a trajectory input
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        // lint: allow(bitexact) -- statistical test; tolerance-checked, not a trajectory input
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!(m.abs() < 0.02, "mean {}", m);
        assert!((v - 1.0).abs() < 0.05, "var {}", v);
    }

    #[test]
    fn permutation_valid() {
        let mut r = Xorshift128::new(3);
        let p = r.permutation_u32(100);
        let mut sorted = p.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100u32).collect::<Vec<_>>());
    }

    #[test]
    fn bounded_sampling() {
        let mut r = Xorshift128::new(5);
        for _ in 0..1000 {
            assert!(r.next_usize(17) < 17);
            assert!(r.next_powerlaw(1000, 1.3) < 1000);
        }
    }

    #[test]
    fn powerlaw_is_skewed() {
        let mut r = Xorshift128::new(11);
        let n = 1000;
        let mut lo = 0;
        for _ in 0..10_000 {
            if r.next_powerlaw(n, 1.5) < n / 10 {
                lo += 1;
            }
        }
        // A power law with s=1.5 puts far more than 10% of mass in the first decile.
        assert!(lo > 5_000, "low-decile count {}", lo);
    }
}
