//! Pairwise (binomial-tree) AllReduce of K worker delta vectors.
//!
//! The paper's MPI implementation owes most of its communication advantage
//! to the log₂(K)-depth reduction tree (Figure 1); the engines here used to
//! *model* that cost while actually folding the K vectors serially into a
//! freshly zeroed accumulator. This module performs the real thing: buffers
//! are combined pairwise in place — `(((0+1)+(2+3)) + ((4+5)+(6+7)))` — so
//!
//! * no zeroed accumulator is allocated (the result lands in `bufs[0]`),
//! * the combination order is a fixed function of the worker index, making
//!   results **bit-identical** between the virtual-clock engines, the
//!   physically-threaded engine and the sequential/parallel variants below,
//! * independent pairs at each level can execute on separate cores, giving
//!   the ⌈log₂K⌉ critical path the model charges.
//!
//! Non-power-of-two K is handled by the standard binomial scheme: a partner
//! beyond the end of the array simply doesn't exist at that level, and the
//! orphan waits for a later level (e.g. K=5 pairs (0,1),(2,3) then (0,2),
//! then (0,4)).

use super::add_assign;

/// Elements per buffer below which the parallel variant falls back to the
/// sequential one: a thread spawn (~tens of µs) must be amortized over the
/// adds it takes over (~0.5 µs/KiB).
const PARALLEL_MIN_LEN: usize = 1 << 16;

/// Enumerate the binomial-tree pairs for K buffers in reduction order,
/// calling `f(dst, src)` for each combination (`dst < src`, result
/// accumulates into `dst`; `dst = 0` at the root).
///
/// This is THE tree shape: [`tree_reduce_seq`] and the sparse-aware
/// `DeltaReducer` both drive their combines through it, so the
/// bit-identical-across-engines invariant cannot drift between the dense
/// and sparse reduction paths.
pub fn for_each_tree_pair(k: usize, mut f: impl FnMut(usize, usize)) {
    let mut gap = 1;
    while gap < k {
        let mut i = 0;
        while i + gap < k {
            f(i, i + gap);
            i += 2 * gap;
        }
        gap *= 2;
    }
}

/// The nested two-level split of the flat `k·t`-leaf binomial tree
/// (hierarchical parallelism: `k` worker ranks × `t` local sub-solvers).
///
/// Rank `w` owns the contiguous leaf block `[w·t, (w+1)·t)`. Every pair of
/// [`for_each_tree_pair`]`(k·t)` is classified by where the combined
/// subtree lives:
///
/// * **rank-local** — both operands' leaf ranges lie inside one block, so
///   the combine can run on the rank before anything crosses the network;
/// * **cross-rank** — the combined range spans blocks; these run at the
///   master, in the flat tree's enumeration order.
///
/// A local pair's operands were only ever produced by earlier local pairs
/// of the same block (subtree ranges nest), so executing *all* local pairs
/// per rank and then the cross pairs in order performs exactly the flat
/// tree's combines with every data dependency respected — the aggregate is
/// **bit-identical to the flat `k·t` reduction for any (k, t)**, including
/// non-power-of-two shapes (asserted below and by
/// `tests/integration_nested.rs`).
///
/// After the local stage a rank holds a small *forest*: the maximal
/// subtrees of the flat tree contained in its block ([`roots`]). When `t`
/// is a power of two each block is one complete subtree and the forest is
/// a single root; otherwise a few partials ship (≤ ⌈log₂ t⌉ + 1). Only
/// those roots cross the network — the nested engines charge exactly
/// their bytes.
///
/// [`roots`]: NestedTreePlan::roots
#[derive(Debug, Clone)]
pub struct NestedTreePlan {
    k: usize,
    t: usize,
    /// Per-rank within-block pairs in flat-tree order, as *local*
    /// sub-shard indices `(dst, src)` with `dst < src < t`.
    local_pairs: Vec<Vec<(usize, usize)>>,
    /// Per-rank local indices still holding live partials after the local
    /// stage (increasing order) — what the rank ships.
    roots: Vec<Vec<usize>>,
    /// Remaining pairs in *global* leaf indices, flat-tree order.
    cross_pairs: Vec<(usize, usize)>,
}

impl NestedTreePlan {
    pub fn new(k: usize, t: usize) -> NestedTreePlan {
        assert!(k > 0 && t > 0, "need k >= 1 and t >= 1");
        let n = k * t;
        // end[i] = one past the last leaf of the subtree currently rooted
        // at slot i (leaves start as [i, i+1)).
        let mut end: Vec<usize> = (1..=n).collect();
        let mut local_pairs = vec![Vec::new(); k];
        let mut consumed = vec![false; n];
        let mut cross_pairs = Vec::new();
        for_each_tree_pair(n, |dst, src| {
            let e = end[src];
            let block = dst / t;
            // dst >= block·t by construction; the pair is block-local iff
            // the merged range also ends inside the block.
            if e <= (block + 1) * t {
                local_pairs[block].push((dst - block * t, src - block * t));
                consumed[src] = true;
            } else {
                cross_pairs.push((dst, src));
            }
            end[dst] = e;
        });
        let mut roots: Vec<Vec<usize>> = vec![Vec::new(); k];
        for (g, &gone) in consumed.iter().enumerate() {
            if !gone {
                roots[g / t].push(g % t);
            }
        }
        NestedTreePlan {
            k,
            t,
            local_pairs,
            roots,
            cross_pairs,
        }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn t(&self) -> usize {
        self.t
    }

    /// Total leaves `k·t` (= the flat ring this plan is equivalent to).
    pub fn n(&self) -> usize {
        self.k * self.t
    }

    /// Rank `w`'s within-block combines (local sub-shard indices).
    pub fn local_pairs(&self, w: usize) -> &[(usize, usize)] {
        &self.local_pairs[w]
    }

    /// Rank `w`'s forest roots after the local stage (local indices).
    pub fn roots(&self, w: usize) -> &[usize] {
        &self.roots[w]
    }

    /// The master's remaining combines (global leaf indices, in order).
    pub fn cross_pairs(&self) -> &[(usize, usize)] {
        &self.cross_pairs
    }
}

/// Reduce `bufs[1..]` into `bufs[0]` pairwise, sequentially.
///
/// Every buffer must have the same length; `bufs[1..]` are left holding
/// partial sums (they are scratch). The reduction tree is identical to
/// [`tree_reduce_parallel`], so both produce bit-identical results.
pub fn tree_reduce_seq(bufs: &mut [&mut [f64]]) {
    for_each_tree_pair(bufs.len(), |dst, src| {
        let (left, right) = bufs.split_at_mut(src);
        add_assign(&mut *left[dst], &*right[0]);
    });
}

/// Reduce `bufs[1..]` into `bufs[0]` pairwise, running the independent
/// pairs of each tree level on scoped threads.
///
/// Arithmetic is bit-identical to [`tree_reduce_seq`]: parallelism changes
/// *when* each pairwise `add_assign` runs, never which pairs are combined
/// or in which order within a pair.
pub fn tree_reduce_parallel(bufs: &mut [&mut [f64]]) {
    let k = bufs.len();
    let mut gap = 1;
    while gap < k {
        std::thread::scope(|scope| {
            let mut rest: &mut [&mut [f64]] = &mut *bufs;
            // Walk chunks of 2·gap; each chunk contributes one independent
            // pair (chunk[0] += chunk[gap]). The first pair of each level
            // runs inline on the calling thread — it would otherwise idle
            // in the scope join — so a level with one pair (and K=2 as a
            // whole) spawns no threads at all.
            let mut inline_pair: Option<(&mut [f64], &[f64])> = None;
            while rest.len() > gap {
                let take = (2 * gap).min(rest.len());
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(take);
                rest = tail;
                let (left, right) = chunk.split_at_mut(gap);
                let dst: &mut [f64] = &mut *left[0];
                let src: &[f64] = &*right[0];
                if inline_pair.is_none() {
                    inline_pair = Some((dst, src));
                } else {
                    scope.spawn(move || add_assign(dst, src));
                }
            }
            if let Some((dst, src)) = inline_pair {
                add_assign(dst, src);
            }
        });
        gap *= 2;
    }
}

/// Reduce pairwise, choosing the parallel path when the buffers are large
/// enough to amortize thread spawns and more than one core is available.
/// This is what the engines call: small virtual-cluster rounds stay on the
/// sequential path, the hotpath bench and large workloads go wide. Both
/// paths produce bit-identical results.
pub fn tree_reduce(bufs: &mut [&mut [f64]]) {
    let len = bufs.first().map(|b| b.len()).unwrap_or(0);
    // Cheap guards first: the virtual-cluster rounds are far below the
    // parallel threshold, and must not pay the available_parallelism
    // syscall just to discard its answer.
    if bufs.len() >= 2
        && len >= PARALLEL_MIN_LEN
        && std::thread::available_parallelism()
            .map(|p| p.get() > 1)
            .unwrap_or(false)
    {
        tree_reduce_parallel(bufs);
    } else {
        tree_reduce_seq(bufs);
    }
}

/// Convenience over owned buffers: reduce into `bufs[0]`.
pub fn tree_reduce_vecs(bufs: &mut [Vec<f64>]) {
    let mut refs: Vec<&mut [f64]> = bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
    tree_reduce(&mut refs);
}

/// The engine-master reduction step: tree-reduce the given Δv buffers in
/// place (scratching them) and return an owned copy of the aggregate.
///
/// One shared site for all engine masters, so the reduction protocol —
/// and with it the bit-identical-across-substrates invariant the
/// integration tests assert — cannot drift between engines. The returned
/// `Vec` is the single per-round allocation the `run_round` API imposes
/// (the caller owns the aggregate).
pub fn tree_reduce_collect<'a, I>(bufs: I) -> Vec<f64>
where
    I: IntoIterator<Item = &'a mut Vec<f64>>,
{
    let mut refs: Vec<&mut [f64]> = bufs.into_iter().map(|b| b.as_mut_slice()).collect();
    if refs.is_empty() {
        return Vec::new();
    }
    tree_reduce(&mut refs);
    refs[0].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(k: usize, m: usize) -> Vec<Vec<f64>> {
        (0..k)
            .map(|w| (0..m).map(|i| (w * m + i) as f64 * 0.5 - 3.0).collect())
            .collect()
    }

    fn serial_sum(bufs: &[Vec<f64>]) -> Vec<f64> {
        let m = bufs[0].len();
        let mut out = vec![0.0; m];
        for b in bufs {
            add_assign(&mut out, b);
        }
        out
    }

    #[test]
    fn matches_serial_sum_within_float_tolerance() {
        for k in [1usize, 2, 3, 4, 5, 7, 8, 16] {
            let mut bufs = mk(k, 33);
            let want = serial_sum(&bufs);
            tree_reduce_vecs(&mut bufs);
            for (a, b) in bufs[0].iter().zip(want.iter()) {
                assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()), "K={}: {} vs {}", k, a, b);
            }
        }
    }

    #[test]
    fn sequential_and_parallel_are_bit_identical() {
        for k in [2usize, 3, 5, 8, 13, 16] {
            let mut a = mk(k, 257);
            let mut b = a.clone();
            {
                let mut refs: Vec<&mut [f64]> = a.iter_mut().map(|v| v.as_mut_slice()).collect();
                tree_reduce_seq(&mut refs);
            }
            {
                let mut refs: Vec<&mut [f64]> = b.iter_mut().map(|v| v.as_mut_slice()).collect();
                tree_reduce_parallel(&mut refs);
            }
            assert_eq!(a[0], b[0], "K={} diverged", k);
        }
    }

    #[test]
    fn non_power_of_two_pairs_deterministically() {
        // K=5 must combine as ((0+1)+(2+3)) + 4 — check the exact grouping
        // by using values where float rounding distinguishes orders.
        let mut bufs: Vec<Vec<f64>> = vec![
            vec![1e16],
            vec![1.0],
            vec![-1e16],
            vec![1.0],
            vec![1.0],
        ];
        tree_reduce_vecs(&mut bufs);
        // (1e16 + 1) + (-1e16 + 1) = 1e16 + (-1e16 + 1) = 1 ... then + 1:
        // level1: b0 = 1e16+1 = 1e16 (absorbed), b2 = -1e16+1 = -1e16+1
        // level2: b0 = 1e16 + (-1e16+1) = 1.0 (wait: -1e16+1 rounds to -9999999999999999 ≈ representable)
        // level4: b0 += b4 → deterministic value; just assert it equals the
        // sequential tree on the same inputs.
        let mut again: Vec<Vec<f64>> = vec![
            vec![1e16],
            vec![1.0],
            vec![-1e16],
            vec![1.0],
            vec![1.0],
        ];
        {
            let mut refs: Vec<&mut [f64]> = again.iter_mut().map(|v| v.as_mut_slice()).collect();
            tree_reduce_seq(&mut refs);
        }
        assert_eq!(bufs[0], again[0]);
    }

    #[test]
    fn collect_matches_manual_reduce_and_handles_empty() {
        let mut bufs = mk(6, 17);
        let mut manual = bufs.clone();
        tree_reduce_vecs(&mut manual);
        let agg = tree_reduce_collect(bufs.iter_mut());
        assert_eq!(agg, manual[0]);
        let mut none: Vec<Vec<f64>> = Vec::new();
        assert!(tree_reduce_collect(none.iter_mut()).is_empty());
    }

    #[test]
    fn empty_and_single() {
        let mut none: Vec<Vec<f64>> = Vec::new();
        tree_reduce_vecs(&mut none); // no panic
        let mut one = vec![vec![1.0, 2.0]];
        tree_reduce_vecs(&mut one);
        assert_eq!(one[0], vec![1.0, 2.0]);
    }

    /// Execute a nested plan with plain adds and compare bitwise against
    /// the flat tree — the invariant every nested engine rests on.
    fn run_nested_plan(k: usize, t: usize, leaves: &[Vec<f64>]) -> Vec<f64> {
        let plan = NestedTreePlan::new(k, t);
        let mut slots: Vec<Vec<f64>> = leaves.to_vec();
        for w in 0..k {
            let block = &mut slots[w * t..(w + 1) * t];
            for &(dst, src) in plan.local_pairs(w) {
                let (l, r) = block.split_at_mut(src);
                add_assign(&mut l[dst], &r[0]);
            }
        }
        for &(dst, src) in plan.cross_pairs() {
            let (l, r) = slots.split_at_mut(src);
            add_assign(&mut l[dst], &r[0]);
        }
        slots.swap_remove(0)
    }

    #[test]
    fn nested_plan_is_bit_identical_to_flat_tree() {
        // Values chosen so float rounding distinguishes every grouping —
        // any deviation from the flat tree's combine order changes bits.
        for (k, t) in [(1, 1), (2, 2), (3, 2), (2, 3), (4, 4), (3, 5), (5, 3), (1, 7), (7, 1)] {
            let n = k * t;
            let leaves: Vec<Vec<f64>> = (0..n)
                .map(|g| {
                    vec![
                        if g % 2 == 0 { 1e16 } else { 1.0 } * if g % 3 == 0 { -1.0 } else { 1.0 },
                        g as f64 * 0.1 + 1e-3,
                    ]
                })
                .collect();
            let mut flat = leaves.clone();
            tree_reduce_vecs(&mut flat);
            let nested = run_nested_plan(k, t, &leaves);
            assert_eq!(
                nested
                    .iter()
                    .map(|x| x.to_bits())
                    .collect::<Vec<_>>(),
                flat[0].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "k={} t={} diverged from the flat tree",
                k,
                t
            );
        }
    }

    #[test]
    fn nested_plan_structure_is_sound() {
        for (k, t) in [(2usize, 2usize), (3, 2), (2, 3), (4, 4), (5, 3)] {
            let plan = NestedTreePlan::new(k, t);
            assert_eq!(plan.n(), k * t);
            let mut combines = 0;
            for w in 0..k {
                // Power-of-two t ⇒ each block is one complete subtree.
                if t.is_power_of_two() {
                    assert_eq!(plan.roots(w), &[0], "k={} t={} w={}", k, t, w);
                }
                // Local indices stay inside the block; result lands at a root.
                for &(dst, src) in plan.local_pairs(w) {
                    assert!(dst < src && src < t);
                }
                assert!(!plan.roots(w).is_empty());
                assert!(plan.roots(w)[0] == 0 || w > 0);
                combines += plan.local_pairs(w).len();
            }
            // Every flat pair shows up exactly once across the two stages.
            combines += plan.cross_pairs().len();
            let mut flat_pairs = 0;
            for_each_tree_pair(k * t, |_, _| flat_pairs += 1);
            assert_eq!(combines, flat_pairs, "k={} t={}", k, t);
            // Cross pairs only touch forest-root positions.
            let mut is_root = vec![false; k * t];
            for w in 0..k {
                for &r in plan.roots(w) {
                    is_root[w * t + r] = true;
                }
            }
            for &(dst, src) in plan.cross_pairs() {
                assert!(is_root[dst] && is_root[src], "k={} t={} ({},{})", k, t, dst, src);
            }
            // The final aggregate lives at global slot 0.
            assert!(is_root[0]);
        }
    }

    #[test]
    fn reduction_is_in_place_and_allocation_free() {
        let mut bufs = mk(8, 512);
        // warm nothing — tree_reduce itself must not allocate buffers
        // (the refs Vec in tree_reduce_vecs is the only allocation, so go
        // through the slice API directly).
        let mut refs: Vec<&mut [f64]> = bufs.iter_mut().map(|v| v.as_mut_slice()).collect();
        let before = crate::testkit::alloc::current_thread_allocations();
        tree_reduce_seq(&mut refs);
        let after = crate::testkit::alloc::current_thread_allocations();
        assert_eq!(after - before, 0, "sequential tree reduce allocated");
    }
}
