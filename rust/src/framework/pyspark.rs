//! pySpark engine: implementations (C), (D) and (D)\*.
//!
//! The python API stacks two extra layers on every task boundary (§5.2):
//! the py4j driver↔JVM bridge and pickle (de)serialization feeding the
//! python worker processes, plus python-speed record iteration inside
//! `mapPartitions`. Per the paper:
//!
//! * (C) `pyspark`: NumPy/CPython local solver, record-layout partitions,
//!   α round-trips every stage;
//! * (D) `pyspark+c`: native solver behind a Python-C API call; the RDD
//!   keeps the *iterator* layout (flattening was found slower in python —
//!   §4.1-D), so the per-record python iteration cost remains;
//! * (D)\*: (D) + persistent local memory + meta-RDD — the §5.3
//!   optimizations that cut pySpark overhead 10×.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use super::chaos::{ChaosRuntime, RoundChaos};
use super::overhead::OverheadModel;
use super::rdd::{Rdd, SparkContext};
use super::serialization::{pickle_encoded_len, pickle_sparse_cutover, PickleSer};
use super::{DistEngine, EngineOptions, RoundTiming};
use crate::config::{Impl, TrainConfig};
use crate::data::{Dataset, Partitioning, WorkerData};
use crate::linalg::{self, DeltaReducer, DeltaSlot};
use crate::problem::Problem;
use crate::simnet::VirtualClock;
use crate::solver::{managed, scd, LocalSolver, SolveRequest};
use crate::util::pool::BytePool;

pub struct PySparkEngine {
    imp: Impl,
    /// One entry per sub-shard (rank-major, `K·t`; `t = 1` = flat).
    data: Rc<Vec<WorkerData>>,
    alpha: Rc<RefCell<Vec<Vec<f64>>>>,
    solvers: Rc<RefCell<Vec<Box<dyn LocalSolver>>>>,
    base: Rdd<usize>,
    model: OverheadModel,
    clock: VirtualClock,
    problem: Problem,
    sigma: f64,
    b: Rc<Vec<f64>>,
    n_total: usize,
    m: usize,
    /// Local sub-solvers per task (nested parallelism; DESIGN.md §10).
    t: usize,
    /// Flat K·t tree split into task-local and driver stages.
    plan: linalg::NestedTreePlan,
    /// Modeled intra-worker speedup of t sub-solvers per executor.
    speedup: f64,
    records_per_task: Vec<usize>,
    /// Columns per *rank* (sub-shard sizes summed) — the α-payload model.
    rank_n_locals: Vec<usize>,
    compute_multiplier: f64,
    /// Pooled pickle frames (driver-side encode reuses one buffer/round).
    frame_pool: BytePool,
    /// Per-worker Δv frames under the pickle-codec cutover (DESIGN.md §7)
    /// feeding the sparse-aware reduction tree; arenas persist.
    slots: Vec<DeltaSlot>,
    reducer: DeltaReducer,
    /// Chaos layer (DESIGN.md §12): heterogeneity, jitter, faults.
    chaos: Option<ChaosRuntime>,
}

impl PySparkEngine {
    pub fn new(
        imp: Impl,
        ds: &Dataset,
        parts: &Partitioning,
        cfg: &TrainConfig,
        model: OverheadModel,
        opts: EngineOptions,
    ) -> PySparkEngine {
        assert!(matches!(
            imp,
            Impl::PySpark | Impl::PySparkC | Impl::PySparkCOpt
        ));
        // Nested layout (DESIGN.md §10): t sub-shards per rank over the
        // flat K·t partitioning.
        let t = opts.threads_per_worker.max(1);
        assert_eq!(
            parts.parts.len(),
            cfg.workers * t,
            "nested layout needs the flat K·t partitioning"
        );
        let data: Vec<WorkerData> = parts
            .parts
            .iter()
            .map(|cols| WorkerData::from_columns(&ds.a, cols))
            .collect();
        let n_shards = data.len();
        let k = n_shards / t;
        let alpha: Vec<Vec<f64>> = data.iter().map(|d| vec![0.0; d.n_local()]).collect();
        let rank_n_locals: Vec<usize> = (0..k)
            .map(|w| data[w * t..(w + 1) * t].iter().map(|d| d.n_local()).sum())
            .collect();

        let cal = super::calibration();
        let (solvers, compute_multiplier): (Vec<Box<dyn LocalSolver>>, f64) = match imp {
            Impl::PySpark => {
                if opts.real_managed_compute {
                    (
                        (0..n_shards)
                            .map(|_| {
                                Box::new(managed::PythonLikeScd::new()) as Box<dyn LocalSolver>
                            })
                            .collect(),
                        1.0,
                    )
                } else {
                    (
                        (0..n_shards)
                            .map(|_| Box::new(scd::NativeScd::new()) as Box<dyn LocalSolver>)
                            .collect(),
                        cal.python_multiplier,
                    )
                }
            }
            _ => (
                (0..n_shards)
                    .map(|_| {
                        Box::new(scd::NativeScd::with_precision(cfg.precision))
                            as Box<dyn LocalSolver>
                    })
                    .collect(),
                1.0,
            ),
        };

        // One task per RANK covering its t sub-shards.
        let records_per_task: Vec<usize> = match imp {
            // (C) and (D) both iterate the record layout in python (§4.1-D:
            // flattening made things *worse* in python, so (D) keeps it).
            Impl::PySpark | Impl::PySparkC => rank_n_locals.clone(),
            // (D)*: meta-RDD — data lives in native memory.
            Impl::PySparkCOpt => vec![0; k],
            _ => unreachable!(),
        };

        let sc = SparkContext::new();
        let base = sc.parallelize((0..k).map(|w| vec![w]).collect());
        base.cache();

        PySparkEngine {
            imp,
            data: Rc::new(data),
            alpha: Rc::new(RefCell::new(alpha)),
            solvers: Rc::new(RefCell::new(solvers)),
            base,
            speedup: model.intra_worker_speedup(t),
            model,
            clock: VirtualClock::new(),
            problem: cfg.problem,
            sigma: cfg.sigma_t(t),
            b: Rc::new(ds.b.clone()),
            n_total: ds.n(),
            m: ds.m(),
            t,
            plan: linalg::NestedTreePlan::new(k, t),
            records_per_task,
            rank_n_locals,
            compute_multiplier,
            frame_pool: BytePool::with_buffers(1, pickle_encoded_len(ds.m())),
            slots: (0..n_shards).map(|_| DeltaSlot::new()).collect(),
            reducer: DeltaReducer::new(
                ds.m(),
                if opts.dense_frames {
                    0
                } else {
                    pickle_sparse_cutover(ds.m())
                },
            ),
            chaos: ChaosRuntime::from_opts(&opts, k),
        }
    }

    fn persistent(&self) -> bool {
        self.imp.has_persistent_local_state()
    }
}

impl DistEngine for PySparkEngine {
    fn imp(&self) -> Impl {
        self.imp
    }

    fn num_workers(&self) -> usize {
        self.data.len() / self.t
    }

    fn threads_per_worker(&self) -> usize {
        self.t
    }

    fn n_locals(&self) -> Vec<usize> {
        self.data.iter().map(|d| d.n_local()).collect()
    }

    fn alpha_global(&self) -> Vec<f64> {
        let alpha = self.alpha.borrow();
        let mut out = vec![0.0; self.n_total];
        for (wd, al) in self.data.iter().zip(alpha.iter()) {
            for (&gid, &a) in wd.global_ids.iter().zip(al.iter()) {
                out[gid as usize] = a;
            }
        }
        out
    }

    fn load_alpha(&mut self, alpha_global: &[f64]) {
        super::scatter_alpha(&self.data, &mut self.alpha.borrow_mut(), alpha_global);
    }

    fn clock(&self) -> f64 {
        self.clock.now()
    }

    fn arm_chaos(&mut self, rc: RoundChaos) {
        if let Some(c) = self.chaos.as_mut() {
            c.arm(rc);
        }
    }

    fn run_round(&mut self, v: &[f64], h: usize, round_seed: u64) -> (Vec<f64>, RoundTiming) {
        let k = self.num_workers();
        let rc = match self.chaos.as_mut() {
            Some(c) => c.take(),
            None => RoundChaos::default(),
        };
        // Per-round latency jitter on fixed/network costs; exactly 1.0
        // without chaos.
        let jm = self.chaos.as_ref().map(|c| c.jitter(round_seed)).unwrap_or(1.0);

        // ---- 1. python driver → JVM → workers ---------------------------
        // The shared vector is pickled by the python driver, crosses py4j,
        // is java-serialized for the wire, then unpickled in each python
        // worker: both codecs are charged (the paper's "additional
        // serialization steps").
        let mut v_frame = self.frame_pool.take_cleared();
        PickleSer::encode_into(v, &mut v_frame);
        debug_assert_eq!(PickleSer::decode_slice(&v_frame).unwrap().len(), v.len());
        let alpha_down_bytes: Vec<u64> = if self.persistent() {
            vec![0; k]
        } else {
            // One α payload per task, covering the rank's t sub-shards.
            self.rank_n_locals
                .iter()
                .map(|&nl| pickle_encoded_len(nl) as u64)
                .collect()
        };
        let down_per_worker: Vec<u64> = alpha_down_bytes
            .iter()
            .map(|&ab| ab + v_frame.len() as u64)
            .collect();
        let bytes_down: u64 = down_per_worker.iter().sum();
        // v and α are NumPy arrays → binary-buffer pickling (fast path).
        let t_driver_down = self.model.numpy_pickle(bytes_down)
            + self.model.py4j_roundtrip()
            + self.model.java_ser(bytes_down);
        let t_net_down = self.model.cluster.jittered(jm).star_varied(&down_per_worker);
        self.frame_pool.put(v_frame);

        // ---- 2. the stage -------------------------------------------------
        // One task per rank; a nested task runs its t sub-solvers (flat
        // ranks w·t..(w+1)·t — same seeds/σ′ as the flat K·t ring).
        let data = Rc::clone(&self.data);
        let alpha = Rc::clone(&self.alpha);
        let solvers = Rc::clone(&self.solvers);
        let b = Rc::clone(&self.b);
        let v_shared: Rc<Vec<f64>> = Rc::new(v.to_vec());
        let (problem, sigma) = (self.problem, self.sigma);
        let records_per_task = self.records_per_task.clone();
        let t = self.t;

        let job = self.base.map_partitions_indexed(move |p, ids, ctx| {
            let w = ids[0];
            debug_assert_eq!(p, w);
            ctx.read_records(records_per_task[w]);
            let mut out = Vec::with_capacity(t);
            for s in 0..t {
                let g = w * t + s;
                let req = SolveRequest {
                    v: &v_shared,
                    b: &b,
                    h,
                    problem: &problem,
                    sigma,
                    seed: round_seed ^ (g as u64).wrapping_mul(0x9E3779B97F4A7C15),
                };
                let alpha_g = alpha.borrow()[g].clone();
                #[allow(clippy::disallowed_methods)]
                // lint: allow(clock) -- real solve wall time feeds the cost model
                let t0 = Instant::now();
                let res = solvers.borrow_mut()[g].solve(&data[g], &alpha_g, &req);
                let secs = t0.elapsed().as_secs_f64();
                out.push((g, res, secs));
            }
            out
        });
        let (mut outs, stats) = job.collect_with_stats();
        debug_assert_eq!(stats.tasks, k);
        debug_assert_eq!(outs.len(), k * t);
        // Flat-rank order for the deterministic reduction tree below.
        outs.sort_by_key(|(g, _, _)| *g);

        // ---- 3. per-task virtual times ------------------------------------
        let native_call = match self.imp {
            Impl::PySparkC | Impl::PySparkCOpt => self.model.pyc_call(),
            _ => 0.0,
        };
        let mut task_times = vec![0.0; k];
        let mut computes = vec![0.0; k];
        let mut up_per_worker = vec![0u64; k];
        for (slot, (_, res, _)) in self.slots.iter_mut().zip(outs.iter()) {
            self.reducer.load(slot, &res.delta_v);
        }
        // Task-local stage of the flat K·t tree (DESIGN.md §10).
        for w in 0..k {
            self.reducer
                .reduce_pairs(&mut self.slots[w * t..(w + 1) * t], self.plan.local_pairs(w));
        }
        // Each python worker pickles its forest roots as the cheaper of
        // the index/value-array (sparse) or flat-list (dense) frames — the
        // codec really runs on a pooled buffer and the model is charged
        // the ACTUAL encoded bytes.
        let mut up_frame = self.frame_pool.take_cleared();
        for w in 0..k {
            let solve_s: f64 = outs[w * t..(w + 1) * t]
                .iter()
                .map(|(_, _, secs)| *secs)
                .sum(); // lint: allow(bitexact) -- sums simulated seconds, not solver state
            // t sub-solves share the python worker's cores; t = 1 divides
            // by exactly 1.0.
            let compute = solve_s * self.compute_multiplier / self.speedup;
            computes[w] = compute;
            let mut dv = 0u64;
            for &ri in self.plan.roots(w) {
                let slot = &self.slots[w * t + ri];
                PickleSer::encode_delta_into(slot, &mut up_frame);
                debug_assert_eq!(
                    PickleSer::decode_delta_dense(&up_frame).unwrap(),
                    slot.densify_collect(self.m)
                );
                dv += up_frame.len() as u64;
            }
            let da = if self.persistent() {
                0
            } else {
                pickle_encoded_len(self.rank_n_locals[w]) as u64
            };
            let up = dv + da;
            up_per_worker[w] = up;
            task_times[w] = self.model.spark_task_launch()
                + self.model.python_task()
                + self.model.numpy_pickle(down_per_worker[w])
                + self.model.record_iter_python(self.records_per_task[w])
                + native_call * t as f64
                + compute
                + self.model.numpy_pickle(up);
        }
        self.frame_pool.put(up_frame);

        // Chaos (DESIGN.md §12): heterogeneity / armed slowdowns drag each
        // rank's compute component; speculation races a clean backup
        // against the dragged original and pays the winner.
        if let Some(cr) = &self.chaos {
            let detect = self.model.fault_detect();
            for w in 0..k {
                let sped = cr.speculate(computes[w], cr.factor(&rc, w), detect);
                task_times[w] += sped - computes[w];
                computes[w] = sped;
            }
        }
        // Armed death: the dead rank's task never reports. The stage
        // aborts after the surviving tasks plus failure detection and
        // executor respawn — *nothing* reaches the α commit below, so the
        // session replays this round from its snapshot bit-exactly.
        if let Some(dead) = rc.death {
            computes[dead] = 0.0;
            task_times[dead] = 0.0;
            let t_worker = computes.iter().cloned().fold(0.0f64, f64::max);
            let t_tasks = task_times.iter().cloned().fold(0.0f64, f64::max);
            let t_fault = self.model.fault_detect() + self.model.respawn();
            let wall =
                self.model.spark_stage() * jm + t_driver_down + t_net_down + t_tasks + t_fault;
            self.clock.advance(wall);
            let timing = RoundTiming {
                t_worker,
                t_master: 0.0,
                t_overhead: (wall - t_worker).max(0.0),
                worker_compute: computes,
                bytes_up: 0,
                bytes_down,
            };
            return (vec![0.0; self.m], timing);
        }
        let bytes_up: u64 = up_per_worker.iter().sum();
        let t_tasks_max = task_times.iter().cloned().fold(0.0f64, f64::max);
        let t_worker = computes.iter().cloned().fold(0.0f64, f64::max);

        // ---- 4. gather + python-driver aggregate --------------------------
        let t_net_up = self.model.cluster.jittered(jm).star_varied(&up_per_worker);
        let t_driver_up = self.model.java_deser(bytes_up)
            + self.model.py4j_roundtrip()
            + self.model.numpy_pickle(bytes_up);

        // Driver reduce: the cross-rank pairs of the same flat tree every
        // engine runs, in place (bit-identical Δv across substrates and
        // frame representations, no zeroed accumulator).
        #[allow(clippy::disallowed_methods)]
        // lint: allow(clock) -- real solve wall time feeds the cost model
        let t0 = Instant::now();
        {
            let mut alpha = self.alpha.borrow_mut();
            for (g, res, _) in &outs {
                linalg::add_assign(&mut alpha[*g], &res.delta_alpha);
            }
        }
        self.reducer.reduce_pairs(&mut self.slots, self.plan.cross_pairs());
        let agg = self.slots[0].densify_collect(self.m);
        debug_assert_eq!(agg.len(), self.m);
        let t_master = t0.elapsed().as_secs_f64();

        // ---- 5. compose ----------------------------------------------------
        let wall = self.model.spark_stage() * jm
            + t_driver_down
            + t_net_down
            + t_tasks_max
            + t_net_up
            + t_driver_up
            + t_master;
        self.clock.advance(wall);

        let timing = RoundTiming {
            t_worker,
            t_master,
            t_overhead: (wall - t_worker - t_master).max(0.0),
            worker_compute: computes,
            bytes_up,
            bytes_down,
        };
        (agg, timing)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{webspam_like, SyntheticSpec};
    use crate::data::Partitioner;
    use crate::framework::spark::SparkEngine;

    fn engine(imp: Impl) -> (Dataset, PySparkEngine) {
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 4;
        let parts = Partitioning::build(Partitioner::Range, &ds.a, 4, 0);
        let tau = crate::framework::overhead::auto_time_scale(ds.m(), ds.n());
        let model = OverheadModel::paper_defaults(crate::simnet::ClusterModel::paper_testbed(tau));
        let eng = PySparkEngine::new(imp, &ds, &parts, &cfg, model, EngineOptions::default());
        (ds, eng)
    }

    #[test]
    fn round_is_consistent() {
        let (ds, mut eng) = engine(Impl::PySparkC);
        let v0 = vec![0.0; ds.m()];
        let (dv, timing) = eng.run_round(&v0, 50, 1);
        let alpha = eng.alpha_global();
        let want = ds.shared_vector(&alpha);
        for (a, b) in dv.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-9);
        }
        assert!(timing.t_overhead > 0.0);
    }

    #[test]
    fn chaos_death_discards_round_and_replay_matches_clean() {
        let (ds, mut clean) = engine(Impl::PySparkCOpt);
        let ds2 = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds2);
        cfg.workers = 4;
        let parts = Partitioning::build(Partitioner::Range, &ds2.a, 4, 0);
        let tau = crate::framework::overhead::auto_time_scale(ds2.m(), ds2.n());
        let model = OverheadModel::paper_defaults(crate::simnet::ClusterModel::paper_testbed(tau));
        let opts = EngineOptions {
            chaos: Some(
                crate::framework::chaos::ChaosSpec::parse("het=0.3,jitter=0.2")
                    .unwrap()
                    .bind(4)
                    .unwrap(),
            ),
            ..Default::default()
        };
        let mut chaotic = PySparkEngine::new(Impl::PySparkCOpt, &ds2, &parts, &cfg, model, opts);
        let v0 = vec![0.0; ds.m()];
        // Attempt with a death: zeros back, α untouched, clock charged.
        let alpha_before = chaotic.alpha_global();
        chaotic.arm_chaos(RoundChaos {
            death: Some(3),
            slowdowns: vec![(1, 6.0)],
        });
        let (dv_dead, t_dead) = chaotic.run_round(&v0, 40, 1);
        assert!(dv_dead.iter().all(|&x| x == 0.0));
        assert_eq!(chaotic.alpha_global(), alpha_before);
        assert_eq!(t_dead.bytes_up, 0);
        assert!(t_dead.worker_compute[3] == 0.0);
        assert!(chaotic.clock() > 0.0);
        // Replay (quiet attempt) matches the chaos-free engine bit-exactly.
        let (dv1, _) = clean.run_round(&v0, 40, 1);
        let (dv2, _) = chaotic.run_round(&v0, 40, 1);
        for (a, b) in dv1.iter().zip(dv2.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(clean.alpha_global(), chaotic.alpha_global());
    }

    #[test]
    fn pyspark_overhead_exceeds_spark_overhead() {
        // The paper's 15× observation, qualitatively: same dataset, same H.
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 4;
        let parts = Partitioning::build(Partitioner::Range, &ds.a, 4, 0);
        let tau = crate::framework::overhead::auto_time_scale(ds.m(), ds.n());
        let model = OverheadModel::paper_defaults(crate::simnet::ClusterModel::paper_testbed(tau));
        let mut spark = SparkEngine::new(
            Impl::SparkC,
            &ds,
            &parts,
            &cfg,
            model.clone(),
            EngineOptions::default(),
        );
        let mut pyspark = PySparkEngine::new(
            Impl::PySparkC,
            &ds,
            &parts,
            &cfg,
            model,
            EngineOptions::default(),
        );
        let v0 = vec![0.0; ds.m()];
        let (_, ts) = spark.run_round(&v0, 50, 1);
        let (_, tp) = pyspark.run_round(&v0, 50, 1);
        assert!(
            tp.t_overhead > 2.0 * ts.t_overhead,
            "pyspark {} !≫ spark {}",
            tp.t_overhead,
            ts.t_overhead
        );
    }

    #[test]
    fn dstar_reduces_overhead_and_bytes() {
        let (ds, mut d) = engine(Impl::PySparkC);
        let (_, mut dstar) = engine(Impl::PySparkCOpt);
        let v0 = vec![0.0; ds.m()];
        let (_, td) = d.run_round(&v0, 50, 1);
        let (_, tds) = dstar.run_round(&v0, 50, 1);
        assert!(tds.bytes_down < td.bytes_down);
        assert!(tds.bytes_up < td.bytes_up);
        assert!(
            tds.t_overhead < 0.8 * td.t_overhead,
            "D* {} !< 0.8 × D {}",
            tds.t_overhead,
            td.t_overhead
        );
    }

    #[test]
    fn sparse_frames_cut_up_bytes_and_keep_bits() {
        // (D)* with small H: pure Δv up-traffic, sparse pickle frames must
        // charge fewer bytes with a BIT-identical aggregate.
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 4;
        let parts = Partitioning::build(Partitioner::Range, &ds.a, 4, 0);
        let tau = crate::framework::overhead::auto_time_scale(ds.m(), ds.n());
        let model = OverheadModel::paper_defaults(crate::simnet::ClusterModel::paper_testbed(tau));
        let mut adaptive = PySparkEngine::new(
            Impl::PySparkCOpt,
            &ds,
            &parts,
            &cfg,
            model.clone(),
            EngineOptions::default(),
        );
        let mut dense = PySparkEngine::new(
            Impl::PySparkCOpt,
            &ds,
            &parts,
            &cfg,
            model,
            EngineOptions {
                dense_frames: true,
                ..Default::default()
            },
        );
        let v0 = vec![0.0; ds.m()];
        let (dv1, t1) = adaptive.run_round(&v0, 2, 1);
        let (dv2, t2) = dense.run_round(&v0, 2, 1);
        for (a, b) in dv1.iter().zip(dv2.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(
            t1.bytes_up < t2.bytes_up,
            "sparse {} !< dense {}",
            t1.bytes_up,
            t2.bytes_up
        );
    }

    #[test]
    fn numerics_match_spark_engines() {
        // Same seed ⇒ same trajectory across the full engine zoo.
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 4;
        let parts = Partitioning::build(Partitioner::Range, &ds.a, 4, 0);
        let tau = crate::framework::overhead::auto_time_scale(ds.m(), ds.n());
        let model = OverheadModel::paper_defaults(crate::simnet::ClusterModel::paper_testbed(tau));
        let mut spark = SparkEngine::new(
            Impl::SparkC,
            &ds,
            &parts,
            &cfg,
            model.clone(),
            EngineOptions::default(),
        );
        let mut pys = PySparkEngine::new(
            Impl::PySpark,
            &ds,
            &parts,
            &cfg,
            model,
            EngineOptions::default(),
        );
        let v0 = vec![0.0; ds.m()];
        let (dv1, _) = spark.run_round(&v0, 40, 3);
        let (dv2, _) = pys.run_round(&v0, 40, 3);
        for (a, b) in dv1.iter().zip(dv2.iter()) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
