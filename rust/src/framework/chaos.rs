//! Chaos layer: seeded worker heterogeneity, latency jitter, and fault
//! injection (worker deaths and slowdowns) with the two mitigations of
//! DESIGN.md §12 — speculative re-execution and checkpoint-based
//! mid-round recovery.
//!
//! Everything here is **deterministic**: a [`ChaosSpec`] is a pure
//! function of its seed, so any chaos session can be replayed bit-for-bit.
//! The spec only ever perturbs *timing* (virtual or physical) and *which
//! round attempts commit* — never the numerics of a committed round. A
//! sub-solve is a pure function of `(v, α, h, seed, shard)`, which is why
//! speculative duplicates and post-recovery replays produce bit-identical
//! Δv (the invariant `tests/integration_chaos.rs` pins).
//!
//! The flow per round: the [`Session`](crate::session::Session) asks its
//! [`ChaosSpec`]-derived schedule what fires this attempt, packages it as
//! a [`RoundChaos`] and hands it to the engine via
//! [`DistEngine::arm_chaos`](super::DistEngine::arm_chaos). The threads
//! engine honors it *physically* (dragged ranks really sleep, dead ranks
//! really have their thread shut down and respawned); the virtual-clock
//! engines honor it on the model (multiplied compute, aborted rounds
//! charged detect + respawn).

use super::EngineOptions;
use crate::linalg::Xorshift128;

/// Golden-ratio mixing constant — the same one the per-shard seed
/// derivation uses (`threads::SEED_GOLDEN`).
const GOLDEN: u64 = 0x9E3779B97F4A7C15;

/// What a single fault does to its target rank.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The worker dies mid-round: nothing it computed commits, the round
    /// aborts, and the session recovers from its last-round snapshot.
    Death,
    /// The worker runs `factor >= 1` times slower for that round.
    Slow(f64),
}

/// One scheduled fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Round index (0-based) the fault arms at.
    pub round: usize,
    /// Target rank; `None` = pick one deterministically from the spec
    /// seed at [`ChaosSpec::bind`] time (when K is known).
    pub worker: Option<usize>,
    pub kind: FaultKind,
}

/// A seeded schedule of deaths and slowdowns.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Schedule a death of `worker` at `round`.
    pub fn death_at(mut self, round: usize, worker: usize) -> FaultPlan {
        self.events.push(FaultEvent {
            round,
            worker: Some(worker),
            kind: FaultKind::Death,
        });
        self
    }

    /// Schedule a `factor`× slowdown of `worker` at `round`.
    pub fn slow_at(mut self, round: usize, worker: usize, factor: f64) -> FaultPlan {
        self.events.push(FaultEvent {
            round,
            worker: Some(worker),
            kind: FaultKind::Slow(factor),
        });
        self
    }

    /// Resolve unbound targets and validate against a cluster of `k`
    /// ranks. Rejects out-of-range targets, sub-1 slowdown factors, and —
    /// the build-time guard the chaos tests pin — any round whose deaths
    /// would kill **all** `k` workers at once, leaving no survivor to
    /// recover alongside.
    fn bind(&self, seed: u64, k: usize) -> Result<FaultPlan, String> {
        let mut events = self.events.clone();
        for ev in events.iter_mut() {
            if ev.worker.is_none() {
                // Seeded pick, stable across replays of the same spec.
                let mix = seed ^ (ev.round as u64).wrapping_mul(GOLDEN);
                ev.worker = Some(Xorshift128::new(mix).next_usize(k));
            }
            let w = ev.worker.unwrap();
            if w >= k {
                return Err(format!(
                    "fault at round {} targets worker {} but K = {}",
                    ev.round, w, k
                ));
            }
            if let FaultKind::Slow(f) = ev.kind {
                if !f.is_finite() || f < 1.0 {
                    return Err(format!("slowdown factor {} must be >= 1", f));
                }
            }
        }
        // Deaths fire one per attempt in schedule order; keep that order
        // stable by round.
        events.sort_by_key(|e| e.round);
        for ev in &events {
            if ev.kind != FaultKind::Death {
                continue;
            }
            let mut dead: Vec<usize> = events
                .iter()
                .filter(|e| e.round == ev.round && e.kind == FaultKind::Death)
                .map(|e| e.worker.unwrap())
                .collect();
            dead.sort_unstable();
            dead.dedup();
            if dead.len() >= k {
                return Err(format!(
                    "fault plan kills all {} workers at round {}; no survivor to recover with",
                    k, ev.round
                ));
            }
        }
        Ok(FaultPlan { events })
    }
}

/// Full chaos specification: heterogeneity, jitter, speculation, and the
/// fault schedule. Parsed from the CLI `--chaos` grammar or built
/// programmatically; [`bind`](ChaosSpec::bind) must run (with the worker
/// count) before a session will accept it.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosSpec {
    /// Seed for every chaos draw (worker picks, speed table, jitter).
    pub seed: u64,
    /// Heterogeneity spread: static per-worker speed multipliers drawn
    /// uniformly from `[1, 1 + het]`. 0 = homogeneous cluster.
    pub het: f64,
    /// Latency jitter fraction: fixed/network round costs multiplied by a
    /// per-round factor in `[1, 1 + jitter]`. 0 = no jitter.
    pub jitter: f64,
    /// Speculative re-execution of the straggler rank's sub-solve: a
    /// backup copy races the original, first result wins. Bit-identical
    /// to no-speculation because both run the same deterministic solve.
    pub speculation: bool,
    /// Coordinator crash rounds (`crash@R`): the session is killed after
    /// round R completes — *after* the checkpoint-store write race — and
    /// must be restarted via `resume_from_store` (DESIGN.md §15). Unlike
    /// worker deaths this is not a round-attempt fault: nothing replays
    /// in-process, the proof obligation is that restart + resume lands on
    /// the uninterrupted trajectory bit-for-bit.
    pub crashes: Vec<usize>,
    pub plan: FaultPlan,
}

impl Default for ChaosSpec {
    fn default() -> ChaosSpec {
        ChaosSpec {
            seed: 0xC4A05,
            het: 0.0,
            jitter: 0.0,
            speculation: false,
            crashes: Vec::new(),
            plan: FaultPlan::default(),
        }
    }
}

impl ChaosSpec {
    /// Parse the CLI spec grammar: comma-separated directives
    ///
    /// ```text
    /// seed=N        chaos seed (default 0xC4A05)
    /// het=F         heterogeneity spread (speed multipliers in [1, 1+F])
    /// jitter=F      per-round latency jitter fraction
    /// spec          enable speculative re-execution
    /// death@R       kill a seeded-pick worker at round R
    /// death@R:W     kill worker W at round R
    /// slow@R:F      slow a seeded-pick worker by F× at round R
    /// slow@R:W:F    slow worker W by F× at round R
    /// crash@R       kill the whole session after round R (after the
    ///               checkpoint-store write); restart resumes from the
    ///               store's newest valid envelope
    /// ```
    pub fn parse(s: &str) -> Result<ChaosSpec, String> {
        let mut spec = ChaosSpec::default();
        for raw in s.split(',') {
            let d = raw.trim();
            if d.is_empty() {
                continue;
            }
            let bad = |what: &str| format!("bad chaos directive '{}': {}", d, what);
            if d == "spec" {
                spec.speculation = true;
            } else if let Some(v) = d.strip_prefix("seed=") {
                spec.seed = v.parse().map_err(|_| bad("seed must be an integer"))?;
            } else if let Some(v) = d.strip_prefix("het=") {
                spec.het = v.parse().map_err(|_| bad("het must be a number"))?;
            } else if let Some(v) = d.strip_prefix("jitter=") {
                spec.jitter = v.parse().map_err(|_| bad("jitter must be a number"))?;
            } else if let Some(v) = d.strip_prefix("death@") {
                let parts: Vec<&str> = v.split(':').collect();
                let round = parts[0].parse().map_err(|_| bad("round must be an integer"))?;
                let worker = match parts.len() {
                    1 => None,
                    2 => Some(parts[1].parse().map_err(|_| bad("worker must be an integer"))?),
                    _ => return Err(bad("expected death@R or death@R:W")),
                };
                spec.plan.events.push(FaultEvent {
                    round,
                    worker,
                    kind: FaultKind::Death,
                });
            } else if let Some(v) = d.strip_prefix("crash@") {
                if v.contains(':') {
                    return Err(bad("expected crash@R (a crash kills every rank)"));
                }
                let round = v.parse().map_err(|_| bad("round must be an integer"))?;
                spec.crashes.push(round);
            } else if let Some(v) = d.strip_prefix("slow@") {
                let parts: Vec<&str> = v.split(':').collect();
                let round = parts[0].parse().map_err(|_| bad("round must be an integer"))?;
                let (worker, factor) = match parts.len() {
                    2 => (
                        None,
                        parts[1].parse().map_err(|_| bad("factor must be a number"))?,
                    ),
                    3 => (
                        Some(parts[1].parse().map_err(|_| bad("worker must be an integer"))?),
                        parts[2].parse().map_err(|_| bad("factor must be a number"))?,
                    ),
                    _ => return Err(bad("expected slow@R:F or slow@R:W:F")),
                };
                spec.plan.events.push(FaultEvent {
                    round,
                    worker,
                    kind: FaultKind::Slow(factor),
                });
            } else {
                return Err(bad(
                    "known directives: seed=N, het=F, jitter=F, spec, death@R[:W], slow@R[:W]:F, crash@R",
                ));
            }
        }
        Ok(spec)
    }

    /// Resolve seeded worker picks and validate the spec against `k`
    /// ranks. Sessions call this at build time — a plan that kills every
    /// worker in one round is rejected here, not mid-run.
    pub fn bind(&self, k: usize) -> Result<ChaosSpec, String> {
        if k == 0 {
            return Err("chaos needs at least one worker".into());
        }
        if !self.het.is_finite() || self.het < 0.0 {
            return Err(format!("het {} must be >= 0", self.het));
        }
        if !self.jitter.is_finite() || self.jitter < 0.0 {
            return Err(format!("jitter {} must be >= 0", self.jitter));
        }
        let mut crashes = self.crashes.clone();
        crashes.sort_unstable();
        crashes.dedup();
        Ok(ChaosSpec {
            plan: self.plan.bind(self.seed, k)?,
            crashes,
            ..self.clone()
        })
    }

    /// True when the spec perturbs nothing *inside* the engine — crash
    /// rounds kill the coordinator between rounds, the engine itself
    /// never arms chaos for them.
    pub fn is_quiet(&self) -> bool {
        self.het == 0.0
            && self.jitter == 0.0
            && !self.speculation
            && self.plan.events.is_empty()
    }
}

/// Static per-worker speed multipliers in `[1, 1 + spread]`, seeded.
pub fn speed_table(seed: u64, spread: f64, k: usize) -> Vec<f64> {
    if spread <= 0.0 {
        return vec![1.0; k];
    }
    let mut rng = Xorshift128::new(seed ^ 0x5EED_7AB1E);
    (0..k).map(|_| 1.0 + spread * rng.next_f64()).collect()
}

/// Deterministic per-round latency-jitter multiplier in `[1, 1 + frac]`.
pub fn jitter_mult(seed: u64, round_seed: u64, frac: f64) -> f64 {
    if frac <= 0.0 {
        return 1.0;
    }
    let mut rng = Xorshift128::new(seed ^ round_seed.wrapping_mul(GOLDEN) ^ 0x717_7E4);
    1.0 + frac * rng.next_f64()
}

/// The chaos armed for ONE round attempt: at most one death (the session
/// fires pending deaths one per attempt, so recovery itself can be hit by
/// the next death) plus any number of slowdowns.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RoundChaos {
    /// Rank that dies this attempt, if any.
    pub death: Option<usize>,
    /// `(rank, factor)` slowdowns in effect this attempt.
    pub slowdowns: Vec<(usize, f64)>,
}

impl RoundChaos {
    pub fn is_quiet(&self) -> bool {
        self.death.is_none() && self.slowdowns.is_empty()
    }
}

/// Engine-side chaos state, shared by all five engines: the bound spec,
/// the static speed table, and the [`RoundChaos`] armed for the next
/// `run_round` call.
#[derive(Debug, Clone)]
pub struct ChaosRuntime {
    pub spec: ChaosSpec,
    /// Static heterogeneity multipliers, one per rank.
    pub speed: Vec<f64>,
    pending: RoundChaos,
}

impl ChaosRuntime {
    pub fn new(spec: ChaosSpec, k: usize) -> ChaosRuntime {
        let speed = speed_table(spec.seed, spec.het, k);
        ChaosRuntime {
            spec,
            speed,
            pending: RoundChaos::default(),
        }
    }

    /// Build from engine options when a bound spec is present.
    pub fn from_opts(opts: &EngineOptions, k: usize) -> Option<ChaosRuntime> {
        opts.chaos.as_ref().map(|spec| ChaosRuntime::new(spec.clone(), k))
    }

    /// Store the chaos for the next round attempt.
    pub fn arm(&mut self, rc: RoundChaos) {
        self.pending = rc;
    }

    /// Take (and clear) the armed chaos.
    pub fn take(&mut self) -> RoundChaos {
        std::mem::take(&mut self.pending)
    }

    /// Combined compute multiplier for rank `w` this attempt: static
    /// heterogeneity × any armed slowdown.
    pub fn factor(&self, rc: &RoundChaos, w: usize) -> f64 {
        let mut f = self.speed[w];
        for &(sw, m) in &rc.slowdowns {
            if sw == w {
                f *= m;
            }
        }
        f
    }

    /// Modeled speculation on a straggler: a clean backup copy launches
    /// after `detect` seconds and races the dragged original; the round
    /// pays whichever finishes first. `factor = 1` (no straggle) always
    /// returns `base` — speculation never slows a healthy rank.
    pub fn speculate(&self, base: f64, factor: f64, detect: f64) -> f64 {
        let dragged = base * factor;
        if self.spec.speculation {
            dragged.min(detect + base)
        } else {
            dragged
        }
    }

    /// Per-round latency-jitter multiplier for fixed/network costs.
    pub fn jitter(&self, round_seed: u64) -> f64 {
        jitter_mult(self.spec.seed, round_seed, self.spec.jitter)
    }

    /// The rank whose sub-solve a physical shadow replica covers: the
    /// first scheduled slowdown's target if any, else the statically
    /// slowest rank, else the last rank.
    pub fn speculation_target(&self, k: usize) -> usize {
        for ev in &self.spec.plan.events {
            if let FaultKind::Slow(_) = ev.kind {
                if let Some(w) = ev.worker {
                    return w;
                }
            }
        }
        let mut worst = k - 1;
        let mut worst_speed = 0.0;
        for (w, &s) in self.speed.iter().enumerate() {
            if s > worst_speed {
                worst_speed = s;
                worst = w;
            }
        }
        worst
    }
}

/// Session-side fault schedule: pending deaths fire one per attempt
/// (cursor-ordered, so a replayed round can itself be killed — "death
/// during recovery"); slowdowns re-apply on every attempt of their round.
#[derive(Debug, Clone)]
pub struct FaultSchedule {
    deaths: Vec<(usize, usize)>,
    slows: Vec<(usize, usize, f64)>,
    /// Deaths fired so far; persisted in checkpoint envelope v5 so a
    /// resume does not re-fire already-survived faults.
    pub cursor: usize,
}

impl FaultSchedule {
    /// Build from a **bound** plan (every target resolved).
    pub fn new(plan: &FaultPlan) -> FaultSchedule {
        let mut deaths = Vec::new();
        let mut slows = Vec::new();
        for ev in &plan.events {
            let w = ev.worker.expect("FaultSchedule needs a bound plan");
            match ev.kind {
                FaultKind::Death => deaths.push((ev.round, w)),
                FaultKind::Slow(f) => slows.push((ev.round, w, f)),
            }
        }
        deaths.sort_by_key(|&(r, _)| r);
        FaultSchedule {
            deaths,
            slows,
            cursor: 0,
        }
    }

    /// The chaos for the next attempt of `round`: the first unfired death
    /// due at or before this round (deaths scheduled during an earlier
    /// round's recovery fire on the replay attempt), plus this round's
    /// slowdowns.
    pub fn arm(&self, round: usize) -> RoundChaos {
        let death = self
            .deaths
            .get(self.cursor)
            .filter(|&&(r, _)| r <= round)
            .map(|&(_, w)| w);
        let slowdowns = self
            .slows
            .iter()
            .filter(|&&(r, _, _)| r == round)
            .map(|&(_, w, f)| (w, f))
            .collect();
        RoundChaos { death, slowdowns }
    }

    /// Record that the armed death fired (the attempt was aborted).
    pub fn fired(&mut self) {
        self.cursor += 1;
    }

    /// Number of deaths in the plan — resume clamps its restored cursor
    /// here so a corrupt checkpoint cannot index past the schedule.
    pub fn deaths_total(&self) -> usize {
        self.deaths.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let spec =
            ChaosSpec::parse("seed=7,het=0.5,jitter=0.1,spec,death@5:1,slow@3:0:10").unwrap();
        assert_eq!(spec.seed, 7);
        assert!((spec.het - 0.5).abs() < 1e-15);
        assert!((spec.jitter - 0.1).abs() < 1e-15);
        assert!(spec.speculation);
        assert_eq!(spec.plan.events.len(), 2);
        assert_eq!(
            spec.plan.events[0],
            FaultEvent {
                round: 5,
                worker: Some(1),
                kind: FaultKind::Death
            }
        );
        assert_eq!(
            spec.plan.events[1],
            FaultEvent {
                round: 3,
                worker: Some(0),
                kind: FaultKind::Slow(10.0)
            }
        );
    }

    #[test]
    fn parse_seeded_picks_resolve_at_bind() {
        let spec = ChaosSpec::parse("death@5,slow@2:4").unwrap();
        assert_eq!(spec.plan.events[0].worker, None);
        assert_eq!(spec.plan.events[1].worker, None);
        let bound = spec.bind(4).unwrap();
        for ev in &bound.plan.events {
            assert!(ev.worker.unwrap() < 4);
        }
        // Deterministic: binding twice resolves identically.
        assert_eq!(bound, spec.bind(4).unwrap());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(ChaosSpec::parse("bogus=1").is_err());
        assert!(ChaosSpec::parse("death@x").is_err());
        assert!(ChaosSpec::parse("slow@3").is_err());
        assert!(ChaosSpec::parse("slow@3:1:2:9").is_err());
        assert!(ChaosSpec::parse("het=fast").is_err());
        assert!(ChaosSpec::parse("crash@x").is_err());
        assert!(ChaosSpec::parse("crash@5:1").is_err());
    }

    #[test]
    fn parse_crash_rounds_and_bind_normalizes_them() {
        let spec = ChaosSpec::parse("crash@5,death@2:0,crash@5,crash@3").unwrap();
        assert_eq!(spec.crashes, vec![5, 5, 3]);
        // A crash-only spec is engine-quiet: nothing to arm per round.
        assert!(ChaosSpec::parse("crash@5").unwrap().is_quiet());
        // bind sorts + dedups crash rounds, and still binds the plan.
        let bound = spec.bind(4).unwrap();
        assert_eq!(bound.crashes, vec![3, 5]);
        assert_eq!(bound.plan.events.len(), 1);
    }

    #[test]
    fn bind_rejects_kill_all_plans() {
        // Killing all K workers in one round leaves nobody to recover
        // with — rejected at build time, the chaos-suite edge case.
        let spec = ChaosSpec::parse("death@2:0,death@2:1").unwrap();
        let err = spec.bind(2).unwrap_err();
        assert!(err.contains("kills all"), "{}", err);
        // The same deaths against a bigger cluster are fine.
        assert!(spec.bind(3).is_ok());
        // Duplicate deaths of the SAME rank at one round are not kill-all.
        let dup = ChaosSpec::parse("death@2:0,death@2:0").unwrap();
        assert!(dup.bind(2).is_ok());
    }

    #[test]
    fn bind_rejects_out_of_range_and_bad_factors() {
        assert!(ChaosSpec::parse("death@1:5").unwrap().bind(4).is_err());
        assert!(ChaosSpec::parse("slow@1:0:0.5").unwrap().bind(4).is_err());
        let mut spec = ChaosSpec::default();
        spec.het = -1.0;
        assert!(spec.bind(4).is_err());
    }

    #[test]
    fn speed_table_is_seeded_and_bounded() {
        let a = speed_table(42, 0.5, 8);
        let b = speed_table(42, 0.5, 8);
        assert_eq!(a, b);
        assert!(a.iter().all(|&s| (1.0..=1.5).contains(&s)));
        // Heterogeneous: not all equal.
        assert!(a.iter().any(|&s| (s - a[0]).abs() > 1e-12));
        assert_eq!(speed_table(42, 0.0, 8), vec![1.0; 8]);
        assert_ne!(speed_table(43, 0.5, 8), a);
    }

    #[test]
    fn jitter_is_seeded_and_bounded() {
        let j = jitter_mult(7, 1234, 0.25);
        assert_eq!(j, jitter_mult(7, 1234, 0.25));
        assert!((1.0..=1.25).contains(&j));
        assert_ne!(j, jitter_mult(7, 1235, 0.25));
        assert_eq!(jitter_mult(7, 1234, 0.0), 1.0);
    }

    #[test]
    fn runtime_factors_combine_het_and_slowdowns() {
        let spec = ChaosSpec::parse("het=0.5,slow@3:1:10").unwrap().bind(4).unwrap();
        let mut rt = ChaosRuntime::new(spec, 4);
        let rc = RoundChaos {
            death: None,
            slowdowns: vec![(1, 10.0)],
        };
        let f1 = rt.factor(&rc, 1);
        assert!((f1 / rt.speed[1] - 10.0).abs() < 1e-12);
        assert_eq!(rt.factor(&rc, 0), rt.speed[0]);
        // arm/take round-trips and clears.
        rt.arm(rc.clone());
        assert_eq!(rt.take(), rc);
        assert!(rt.take().is_quiet());
    }

    #[test]
    fn speculation_wins_races_and_never_hurts() {
        let spec = ChaosSpec::parse("spec").unwrap().bind(2).unwrap();
        let rt = ChaosRuntime::new(spec, 2);
        // Straggler: backup (detect + clean) beats the 10x drag.
        assert!((rt.speculate(1.0, 10.0, 0.1) - 1.1).abs() < 1e-12);
        // Mild drag: original wins the race.
        assert!((rt.speculate(1.0, 1.05, 0.5) - 1.05).abs() < 1e-12);
        // Healthy rank: exactly base.
        assert_eq!(rt.speculate(1.0, 1.0, 0.1), 1.0);
        // Speculation off: full drag.
        let off = ChaosRuntime::new(ChaosSpec::default().bind(2).unwrap(), 2);
        assert_eq!(off.speculate(1.0, 10.0, 0.1), 10.0);
    }

    #[test]
    fn schedule_fires_deaths_one_per_attempt() {
        // Two deaths at the same round on different ranks: the first
        // fires on attempt one, the second on the recovery replay —
        // "death during recovery".
        let spec = ChaosSpec::parse("death@2:0,death@2:1,slow@2:2:3")
            .unwrap()
            .bind(4)
            .unwrap();
        let mut sched = FaultSchedule::new(&spec.plan);
        assert!(sched.arm(0).is_quiet());
        assert!(sched.arm(1).is_quiet());
        let a1 = sched.arm(2);
        assert_eq!(a1.death, Some(0));
        assert_eq!(a1.slowdowns, vec![(2, 3.0)]);
        sched.fired();
        let a2 = sched.arm(2);
        assert_eq!(a2.death, Some(1));
        assert_eq!(a2.slowdowns, vec![(2, 3.0)]);
        sched.fired();
        let a3 = sched.arm(2);
        assert_eq!(a3.death, None);
        assert_eq!(a3.slowdowns, vec![(2, 3.0)]);
        assert!(sched.arm(3).is_quiet());
    }

    #[test]
    fn schedule_death_at_round_zero() {
        let spec = ChaosSpec::parse("death@0:1").unwrap().bind(2).unwrap();
        let mut sched = FaultSchedule::new(&spec.plan);
        assert_eq!(sched.arm(0).death, Some(1));
        sched.fired();
        assert!(sched.arm(0).is_quiet());
    }

    #[test]
    fn schedule_cursor_resumes_past_fired_deaths() {
        let spec = ChaosSpec::parse("death@1:0,death@4:1").unwrap().bind(2).unwrap();
        let mut sched = FaultSchedule::new(&spec.plan);
        sched.cursor = 1; // checkpoint recorded the round-1 death as fired
        assert!(sched.arm(1).is_quiet());
        assert_eq!(sched.arm(4).death, Some(1));
    }

    #[test]
    fn simultaneous_death_and_slowdown_on_same_rank() {
        let spec = ChaosSpec::parse("death@3:1,slow@3:1:5").unwrap().bind(4).unwrap();
        let sched = FaultSchedule::new(&spec.plan);
        let rc = sched.arm(3);
        assert_eq!(rc.death, Some(1));
        assert_eq!(rc.slowdowns, vec![(1, 5.0)]);
    }

    #[test]
    fn speculation_target_prefers_scheduled_straggler() {
        let spec = ChaosSpec::parse("spec,slow@4:2:10").unwrap().bind(4).unwrap();
        assert_eq!(ChaosRuntime::new(spec, 4).speculation_target(4), 2);
        // No slow event: the statically slowest rank.
        let het = ChaosSpec::parse("spec,het=0.5").unwrap().bind(4).unwrap();
        let rt = ChaosRuntime::new(het, 4);
        let target = rt.speculation_target(4);
        for &s in &rt.speed {
            assert!(rt.speed[target] >= s);
        }
    }

    #[test]
    fn quiet_spec_detection() {
        assert!(ChaosSpec::default().is_quiet());
        assert!(!ChaosSpec::parse("het=0.1").unwrap().is_quiet());
        assert!(!ChaosSpec::parse("death@1").unwrap().is_quiet());
    }
}
