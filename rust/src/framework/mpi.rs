//! MPI engine: implementation (E) — the paper's no-overhead reference.
//!
//! All-C++ ranks with persistent local state: `α_[k]` lives in rank memory
//! forever, the only communication is the tree AllReduce of the Δv update
//! (Figure 1), there is no serialization (raw buffers on the wire) and no
//! per-stage scheduling. Framework overhead per the paper is ~3% of total
//! runtime — here a barrier plus the AllReduce transfer.
//!
//! Each rank emits its Δv as a raw sparse frame when that is cheaper than
//! the dense m-vector (`linalg::raw_sparse_cutover`; DESIGN.md §7), the
//! reduction runs the sparse-aware pairwise tree (`linalg::DeltaReducer`,
//! bit-identical to the dense tree), and the cost model is charged the
//! actual frame bytes.

use std::time::Instant;

use super::overhead::OverheadModel;
use super::{DistEngine, EngineOptions, RoundTiming, WorkerSet};
use crate::config::{Impl, TrainConfig};
use crate::data::{Dataset, Partitioning};
use crate::linalg;
use crate::problem::Problem;
use crate::simnet::VirtualClock;
use crate::solver::{scd::NativeScd, LocalSolver, SolveRequest, SolveResult};

pub struct MpiEngine {
    ws: WorkerSet,
    solvers: Vec<NativeScd>,
    /// Per-rank round results, alive across rounds: `solve_into` refills
    /// them and the tree reduce consumes `delta_v` in place, so the
    /// steady-state round performs no per-worker allocations.
    results: Vec<SolveResult>,
    /// Per-rank Δv frames (sparse or dense by the raw cutover) feeding the
    /// sparse-aware reduction tree; arenas persist across rounds.
    slots: Vec<linalg::DeltaSlot>,
    reducer: linalg::DeltaReducer,
    model: OverheadModel,
    clock: VirtualClock,
    problem: Problem,
    sigma: f64,
    b: Vec<f64>,
    m: usize,
}

impl MpiEngine {
    pub fn new(
        ds: &Dataset,
        parts: &Partitioning,
        cfg: &TrainConfig,
        model: OverheadModel,
    ) -> MpiEngine {
        let ws = WorkerSet::build(ds, parts);
        let solvers = (0..ws.data.len()).map(|_| NativeScd::new()).collect();
        let results = (0..ws.data.len()).map(|_| SolveResult::default()).collect();
        let slots = (0..ws.data.len()).map(|_| linalg::DeltaSlot::new()).collect();
        MpiEngine {
            ws,
            solvers,
            results,
            slots,
            reducer: linalg::DeltaReducer::raw(ds.m()),
            model,
            clock: VirtualClock::new(),
            problem: cfg.problem,
            sigma: cfg.sigma(),
            b: ds.b.clone(),
            m: ds.m(),
        }
    }

    /// Construct with explicit [`EngineOptions`] — the unified-registry
    /// path ([`crate::framework::build_any`]). `dense_frames` swaps the
    /// raw sparse cutover for the dense-always reducer, exactly like the
    /// Spark engines swap their codec cutover.
    pub fn new_with(
        ds: &Dataset,
        parts: &Partitioning,
        cfg: &TrainConfig,
        model: OverheadModel,
        opts: &EngineOptions,
    ) -> MpiEngine {
        let mut eng = MpiEngine::new(ds, parts, cfg, model);
        if opts.dense_frames {
            eng.force_dense_frames();
        }
        eng
    }

    /// Construct via the generic builder path (used by tests).
    pub fn build(ds: &Dataset, parts: &Partitioning, cfg: &TrainConfig) -> MpiEngine {
        let tau = super::overhead::auto_time_scale(ds.m(), ds.n());
        let model = OverheadModel::paper_defaults(crate::simnet::ClusterModel::paper_testbed(tau));
        MpiEngine::new_with(ds, parts, cfg, model, &EngineOptions::default())
    }

    /// Disable the sparse frame path (cutover 0 → every rank emits dense),
    /// the `EngineOptions::dense_frames` baseline.
    pub fn force_dense_frames(&mut self) {
        self.reducer = linalg::DeltaReducer::new(self.m, 0);
    }
}

impl DistEngine for MpiEngine {
    fn imp(&self) -> Impl {
        Impl::Mpi
    }

    fn num_workers(&self) -> usize {
        self.ws.data.len()
    }

    fn n_locals(&self) -> Vec<usize> {
        self.ws.n_locals()
    }

    fn alpha_global(&self) -> Vec<f64> {
        self.ws.alpha_global()
    }

    fn load_alpha(&mut self, alpha_global: &[f64]) {
        self.ws.load_alpha(alpha_global);
    }

    fn clock(&self) -> f64 {
        self.clock.now()
    }

    fn run_round(&mut self, v: &[f64], h: usize, round_seed: u64) -> (Vec<f64>, RoundTiming) {
        let k = self.num_workers();

        // ---- 1. local solves (ranks run in parallel; real measured) ------
        let mut computes = vec![0.0; k];
        for w in 0..k {
            let req = SolveRequest {
                v,
                b: &self.b,
                h,
                problem: &self.problem,
                sigma: self.sigma,
                seed: round_seed ^ (w as u64).wrapping_mul(0x9E3779B97F4A7C15),
            };
            let t0 = Instant::now();
            self.solvers[w].solve_into(
                &self.ws.data[w],
                &self.ws.alpha[w],
                &req,
                &mut self.results[w],
            );
            computes[w] = t0.elapsed().as_secs_f64();
        }
        let t_worker = computes.iter().cloned().fold(0.0f64, f64::max);

        // ---- 2. AllReduce of Δv (tree) + barrier --------------------------
        // Real aggregation: the log₂(K) pairwise tree the cost model below
        // charges for actually executes — each rank emits its Δv as a raw
        // sparse frame when that is cheaper (DESIGN.md §7 cutover), deltas
        // are combined in place in rank order (sparse pairs merge, growth
        // past the cutover promotes to dense), no zeroed accumulator is
        // allocated, and the identical tree shape across all engines keeps
        // Δv bit-identical between substrates. Counted as master time,
        // matching the paper's < 2 s measurement.
        let t0 = Instant::now();
        for (al, res) in self.ws.alpha.iter_mut().zip(self.results.iter()) {
            linalg::add_assign(al, &res.delta_alpha);
        }
        let mut bytes_up = 0u64;
        let mut rank_payload_max = 0u64;
        for (slot, res) in self.slots.iter_mut().zip(self.results.iter()) {
            self.reducer.load(slot, &res.delta_v);
            let b = slot.raw_bytes(self.m) as u64;
            bytes_up += b;
            rank_payload_max = rank_payload_max.max(b);
        }
        self.reducer.reduce(&mut self.slots);
        // Broadcast leg: every rank receives the merged Δv in whichever
        // representation it ended up in.
        let down_payload = self.slots[0].raw_bytes(self.m) as u64;
        let agg = self.slots[0].densify_collect(self.m);
        let t_master = t0.elapsed().as_secs_f64();

        // Charged bytes are the ACTUAL frame sizes: the reduce waves carry
        // at most max(rank frames, merged frame), the broadcast waves the
        // merged frame — charge the tree with the larger (conservative).
        let payload = rank_payload_max.max(down_payload);
        let t_allreduce = self.model.cluster.tree_allreduce(payload, k);
        let t_barrier = self.model.mpi_barrier();

        let wall = t_worker + t_allreduce + t_barrier + t_master;
        self.clock.advance(wall);

        let timing = RoundTiming {
            t_worker,
            t_master,
            t_overhead: t_allreduce + t_barrier,
            worker_compute: computes,
            bytes_up,
            bytes_down: down_payload * k as u64,
        };
        (agg, timing)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{webspam_like, SyntheticSpec};
    use crate::data::Partitioner;

    fn engine() -> (Dataset, MpiEngine) {
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 4;
        let parts = Partitioning::build(Partitioner::BalancedNnz, &ds.a, 4, 0);
        let eng = MpiEngine::build(&ds, &parts, &cfg);
        (ds, eng)
    }

    #[test]
    fn round_consistency() {
        let (ds, mut eng) = engine();
        let v0 = vec![0.0; ds.m()];
        let (dv, timing) = eng.run_round(&v0, 50, 1);
        let alpha = eng.alpha_global();
        let want = ds.shared_vector(&alpha);
        for (a, b) in dv.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-9);
        }
        assert!(timing.t_worker > 0.0);
    }

    #[test]
    fn mpi_overhead_is_small_fraction() {
        // §5.2: MPI overheads ≈ 3% of total. At full H the solve dominates.
        let (ds, mut eng) = engine();
        let v0 = vec![0.0; ds.m()];
        let n_local = eng.n_locals()[0];
        let (_, t) = eng.run_round(&v0, 4 * n_local, 1);
        let frac = t.t_overhead / t.wall();
        assert!(frac < 0.25, "overhead fraction {} too high", frac);
    }

    #[test]
    fn persistent_alpha_state_accumulates() {
        let (ds, mut eng) = engine();
        let mut v = vec![0.0; ds.m()];
        let p = eng.problem;
        let mut prev = p.primal(&ds, &eng.alpha_global());
        for round in 0..5 {
            let (dv, _) = eng.run_round(&v, 100, round);
            linalg::add_assign(&mut v, &dv);
            let cur = p.primal(&ds, &eng.alpha_global());
            assert!(cur <= prev + 1e-9, "round {}: {} -> {}", round, prev, cur);
            prev = cur;
        }
    }

    #[test]
    fn sparse_frames_cut_bytes_and_keep_bits() {
        // Small H on a sparse dataset → sparse Δv frames; the adaptive
        // engine must move fewer bytes than the dense-forced one while
        // producing BIT-identical aggregates.
        let (ds, mut adaptive) = engine();
        let (_, mut dense) = engine();
        dense.force_dense_frames();
        let mut v1 = vec![0.0; ds.m()];
        let mut v2 = vec![0.0; ds.m()];
        let mut saw_sparse_savings = false;
        for round in 0..4 {
            let (dv1, t1) = adaptive.run_round(&v1, 2, round);
            let (dv2, t2) = dense.run_round(&v2, 2, round);
            for (a, b) in dv1.iter().zip(dv2.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert!(t1.bytes_up <= t2.bytes_up);
            if t1.bytes_up < t2.bytes_up {
                saw_sparse_savings = true;
            }
            linalg::add_assign(&mut v1, &dv1);
            linalg::add_assign(&mut v2, &dv2);
        }
        assert!(saw_sparse_savings, "no round used a cheaper sparse frame");
    }

    #[test]
    fn convergence_insensitive_to_worker_count() {
        // CoCoA converges for any K (σ′ = γK keeps aggregation safe).
        for k in [1usize, 2, 8] {
            let ds = webspam_like(&SyntheticSpec::small());
            let mut cfg = TrainConfig::default_for(&ds);
            cfg.workers = k;
            let parts = Partitioning::build(Partitioner::Range, &ds.a, k, 0);
            let model =
                OverheadModel::paper_defaults(crate::simnet::ClusterModel::paper_testbed(1.0));
            let mut eng = MpiEngine::new(&ds, &parts, &cfg, model);
            let mut v = vec![0.0; ds.m()];
            let f0 = cfg.problem.primal(&ds, &eng.alpha_global());
            for round in 0..20 {
                let h = eng.n_locals()[0];
                let (dv, _) = eng.run_round(&v, h, round);
                linalg::add_assign(&mut v, &dv);
            }
            let f = cfg.problem.primal(&ds, &eng.alpha_global());
            assert!(f < 0.6 * f0, "K={}: {} -> {}", k, f0, f);
        }
    }
}
