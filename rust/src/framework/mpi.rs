//! MPI engine: implementation (E) — the paper's no-overhead reference.
//!
//! All-C++ ranks with persistent local state: `α_[k]` lives in rank memory
//! forever, the only communication is the tree AllReduce of the Δv update
//! (Figure 1), there is no serialization (raw buffers on the wire) and no
//! per-stage scheduling. Framework overhead per the paper is ~3% of total
//! runtime — here a barrier plus the AllReduce transfer.
//!
//! Each rank emits its Δv as a raw sparse frame when that is cheaper than
//! the dense m-vector (`linalg::raw_sparse_cutover`; DESIGN.md §7), the
//! reduction runs the sparse-aware pairwise tree (`linalg::DeltaReducer`,
//! bit-identical to the dense tree), and the cost model is charged the
//! actual frame bytes.

use std::time::Instant;

use super::chaos::{ChaosRuntime, RoundChaos};
use super::overhead::OverheadModel;
use super::{DistEngine, EngineOptions, RoundTiming, WorkerSet};
use crate::config::{Impl, TrainConfig};
use crate::data::{Dataset, Partitioning};
use crate::linalg;
use crate::problem::Problem;
use crate::simnet::VirtualClock;
use crate::solver::{scd::NativeScd, LocalSolver, SolveRequest, SolveResult};

pub struct MpiEngine {
    /// One entry per *sub-shard* (rank-major, `K·t` of them; `t = 1` for
    /// the classic flat ring).
    ws: WorkerSet,
    solvers: Vec<NativeScd>,
    /// Per-sub-shard round results, alive across rounds: `solve_into`
    /// refills them and the tree reduce consumes `delta_v` in place, so
    /// the steady-state round performs no per-worker allocations.
    results: Vec<SolveResult>,
    /// Per-sub-shard Δv frames (sparse or dense by the raw cutover)
    /// feeding the sparse-aware reduction tree; arenas persist.
    slots: Vec<linalg::DeltaSlot>,
    reducer: linalg::DeltaReducer,
    /// Local sub-solvers per rank (nested parallelism; DESIGN.md §10).
    t: usize,
    /// The flat K·t tree split into rank-local and cross-rank stages.
    plan: linalg::NestedTreePlan,
    /// Modeled intra-worker speedup of t sub-solvers on one rank's cores.
    speedup: f64,
    model: OverheadModel,
    clock: VirtualClock,
    problem: Problem,
    sigma: f64,
    b: Vec<f64>,
    m: usize,
    /// Chaos layer (DESIGN.md §12): heterogeneity/jitter/faults on the
    /// modeled costs. `None` = inert.
    chaos: Option<ChaosRuntime>,
}

impl MpiEngine {
    pub fn new(
        ds: &Dataset,
        parts: &Partitioning,
        cfg: &TrainConfig,
        model: OverheadModel,
    ) -> MpiEngine {
        MpiEngine::new_nested(ds, parts, cfg, model, 1)
    }

    /// Nested construction: `parts` is the flat `K·t` partitioning
    /// ([`Partitioning::build_nested`]); rank `w` owns sub-shards
    /// `[w·t, (w+1)·t)`. σ′ = γ·K·t and per-shard seeds use the flat rank
    /// ids, so trajectories are bit-identical to a flat `K·t` ring.
    pub fn new_nested(
        ds: &Dataset,
        parts: &Partitioning,
        cfg: &TrainConfig,
        model: OverheadModel,
        t: usize,
    ) -> MpiEngine {
        assert!(t >= 1, "need at least one sub-solver per worker");
        assert_eq!(
            parts.parts.len(),
            cfg.workers * t,
            "nested layout needs the flat K·t partitioning"
        );
        let ws = WorkerSet::build(ds, parts);
        let solvers = (0..ws.data.len())
            .map(|_| NativeScd::with_precision(cfg.precision))
            .collect();
        let results = (0..ws.data.len()).map(|_| SolveResult::default()).collect();
        let slots = (0..ws.data.len()).map(|_| linalg::DeltaSlot::new()).collect();
        let speedup = model.intra_worker_speedup(t);
        MpiEngine {
            ws,
            solvers,
            results,
            slots,
            reducer: linalg::DeltaReducer::raw(ds.m()),
            t,
            plan: linalg::NestedTreePlan::new(cfg.workers, t),
            speedup,
            model,
            clock: VirtualClock::new(),
            problem: cfg.problem,
            sigma: cfg.sigma_t(t),
            b: ds.b.clone(),
            m: ds.m(),
            chaos: None,
        }
    }

    /// Construct with explicit [`EngineOptions`] — the unified-registry
    /// path ([`crate::framework::build_any`]). `dense_frames` swaps the
    /// raw sparse cutover for the dense-always reducer, exactly like the
    /// Spark engines swap their codec cutover; `threads_per_worker`
    /// selects the nested layout.
    pub fn new_with(
        ds: &Dataset,
        parts: &Partitioning,
        cfg: &TrainConfig,
        model: OverheadModel,
        opts: &EngineOptions,
    ) -> MpiEngine {
        let mut eng =
            MpiEngine::new_nested(ds, parts, cfg, model, opts.threads_per_worker.max(1));
        if opts.dense_frames {
            eng.force_dense_frames();
        }
        eng.chaos = ChaosRuntime::from_opts(opts, cfg.workers);
        eng
    }

    /// Construct via the generic builder path (used by tests).
    pub fn build(ds: &Dataset, parts: &Partitioning, cfg: &TrainConfig) -> MpiEngine {
        let tau = super::overhead::auto_time_scale(ds.m(), ds.n());
        let model = OverheadModel::paper_defaults(crate::simnet::ClusterModel::paper_testbed(tau));
        MpiEngine::new_with(ds, parts, cfg, model, &EngineOptions::default())
    }

    /// Disable the sparse frame path (cutover 0 → every rank emits dense),
    /// the `EngineOptions::dense_frames` baseline.
    pub fn force_dense_frames(&mut self) {
        self.reducer = linalg::DeltaReducer::new(self.m, 0);
    }
}

impl DistEngine for MpiEngine {
    fn imp(&self) -> Impl {
        Impl::Mpi
    }

    fn num_workers(&self) -> usize {
        self.ws.data.len() / self.t
    }

    fn threads_per_worker(&self) -> usize {
        self.t
    }

    fn n_locals(&self) -> Vec<usize> {
        self.ws.n_locals()
    }

    fn alpha_global(&self) -> Vec<f64> {
        self.ws.alpha_global()
    }

    fn load_alpha(&mut self, alpha_global: &[f64]) {
        self.ws.load_alpha(alpha_global);
    }

    fn clock(&self) -> f64 {
        self.clock.now()
    }

    fn arm_chaos(&mut self, rc: RoundChaos) {
        if let Some(c) = self.chaos.as_mut() {
            c.arm(rc);
        }
    }

    fn run_round(&mut self, v: &[f64], h: usize, round_seed: u64) -> (Vec<f64>, RoundTiming) {
        let t = self.t;
        let k = self.num_workers();
        let n_shards = self.ws.data.len();
        let rc = match self.chaos.as_mut() {
            Some(c) => c.take(),
            None => RoundChaos::default(),
        };

        // ---- 1. local solves (each rank runs t sub-solvers; measured) ----
        // Sub-shard g of the nested layout is rank g of the flat K·t ring:
        // same seed, same σ′ (= γ·K·t), same columns ⇒ same bits.
        let mut sub_computes = vec![0.0; n_shards];
        for g in 0..n_shards {
            // An armed death: the doomed rank's sub-solves never complete
            // and nothing of this attempt commits — skip them entirely.
            if rc.death == Some(g / t) {
                continue;
            }
            let req = SolveRequest {
                v,
                b: &self.b,
                h,
                problem: &self.problem,
                sigma: self.sigma,
                seed: round_seed ^ (g as u64).wrapping_mul(0x9E3779B97F4A7C15),
            };
            #[allow(clippy::disallowed_methods)]
            // lint: allow(clock) -- real solve wall time feeds the cost model
            let t0 = Instant::now();
            self.solvers[g].solve_into(
                &self.ws.data[g],
                &self.ws.alpha[g],
                &req,
                &mut self.results[g],
            );
            sub_computes[g] = t0.elapsed().as_secs_f64();
        }
        // A rank's t sub-solvers share its cores: charge the serialized
        // sum divided by the intra-worker speedup curve (DESIGN.md §10).
        // At t = 1 this is the measured solve time divided by exactly 1.0.
        let mut computes = vec![0.0; k];
        for w in 0..k {
            // lint: allow(bitexact) -- sums simulated seconds for the cost model, not solver state
            computes[w] = sub_computes[w * t..(w + 1) * t].iter().sum::<f64>() / self.speedup;
        }
        // Chaos (DESIGN.md §12): static heterogeneity × armed slowdowns on
        // each rank's compute; with speculation a clean backup copy races
        // the straggler (min rule). Timing only — the bits are untouched.
        if let Some(cr) = &self.chaos {
            let detect = self.model.fault_detect();
            for (w, c) in computes.iter_mut().enumerate() {
                *c = cr.speculate(*c, cr.factor(&rc, w), detect);
            }
        }
        let t_worker = computes.iter().cloned().fold(0.0f64, f64::max);

        // Armed death: the round aborts with nothing committed — no α
        // update, no reduce. Survivors' compute is spent, the coordinator
        // pays detection + respawn, and the session replays the round from
        // its recovery snapshot.
        if rc.death.is_some() {
            let t_fault = self.model.fault_detect() + self.model.respawn();
            let wall = t_worker + t_fault;
            self.clock.advance(wall);
            let timing = RoundTiming {
                t_worker,
                t_master: 0.0,
                t_overhead: t_fault,
                worker_compute: computes,
                bytes_up: 0,
                bytes_down: 0,
            };
            return (vec![0.0; self.m], timing);
        }

        // ---- 2. AllReduce of Δv (tree) + barrier --------------------------
        // Real aggregation: the log₂(K) pairwise tree the cost model below
        // charges for actually executes — each sub-solver emits its Δv as
        // a raw sparse frame when that is cheaper (DESIGN.md §7 cutover).
        // The flat K·t tree is split per DESIGN.md §10: within-block pairs
        // combine rank-locally (shared memory, no wire bytes), only the
        // forest roots cross the network, and the master completes the
        // remaining pairs in flat-tree order — the aggregate is
        // bit-identical to the flat ring whatever the frame mix. Counted
        // as master time, matching the paper's < 2 s measurement.
        #[allow(clippy::disallowed_methods)]
        // lint: allow(clock) -- real solve wall time feeds the cost model
        let t0 = Instant::now();
        for (al, res) in self.ws.alpha.iter_mut().zip(self.results.iter()) {
            linalg::add_assign(al, &res.delta_alpha);
        }
        for (slot, res) in self.slots.iter_mut().zip(self.results.iter()) {
            self.reducer.load(slot, &res.delta_v);
        }
        for w in 0..k {
            self.reducer
                .reduce_pairs(&mut self.slots[w * t..(w + 1) * t], self.plan.local_pairs(w));
        }
        let mut bytes_up = 0u64;
        let mut rank_payload_max = 0u64;
        for w in 0..k {
            let mut rank_bytes = 0u64;
            for &ri in self.plan.roots(w) {
                rank_bytes += self.slots[w * t + ri].raw_bytes(self.m) as u64;
            }
            bytes_up += rank_bytes;
            rank_payload_max = rank_payload_max.max(rank_bytes);
        }
        self.reducer.reduce_pairs(&mut self.slots, self.plan.cross_pairs());
        // Broadcast leg: every rank receives the merged Δv in whichever
        // representation it ended up in.
        let down_payload = self.slots[0].raw_bytes(self.m) as u64;
        let agg = self.slots[0].densify_collect(self.m);
        let t_master = t0.elapsed().as_secs_f64();

        // Charged bytes are the ACTUAL frame sizes: the reduce waves carry
        // at most max(rank frames, merged frame), the broadcast waves the
        // merged frame — charge the tree with the larger (conservative).
        let payload = rank_payload_max.max(down_payload);
        // Per-round latency jitter (chaos layer) on the collective's
        // latency terms; exactly 1.0 without chaos.
        let jm = self.chaos.as_ref().map(|c| c.jitter(round_seed)).unwrap_or(1.0);
        let t_allreduce = self.model.cluster.jittered(jm).tree_allreduce(payload, k);
        let t_barrier = self.model.mpi_barrier() * jm;

        let wall = t_worker + t_allreduce + t_barrier + t_master;
        self.clock.advance(wall);

        let timing = RoundTiming {
            t_worker,
            t_master,
            t_overhead: t_allreduce + t_barrier,
            worker_compute: computes,
            bytes_up,
            bytes_down: down_payload * k as u64,
        };
        (agg, timing)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{webspam_like, SyntheticSpec};
    use crate::data::Partitioner;

    fn engine() -> (Dataset, MpiEngine) {
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 4;
        let parts = Partitioning::build(Partitioner::BalancedNnz, &ds.a, 4, 0);
        let eng = MpiEngine::build(&ds, &parts, &cfg);
        (ds, eng)
    }

    #[test]
    fn round_consistency() {
        let (ds, mut eng) = engine();
        let v0 = vec![0.0; ds.m()];
        let (dv, timing) = eng.run_round(&v0, 50, 1);
        let alpha = eng.alpha_global();
        let want = ds.shared_vector(&alpha);
        for (a, b) in dv.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-9);
        }
        assert!(timing.t_worker > 0.0);
    }

    #[test]
    fn mpi_overhead_is_small_fraction() {
        // §5.2: MPI overheads ≈ 3% of total. At full H the solve dominates.
        let (ds, mut eng) = engine();
        let v0 = vec![0.0; ds.m()];
        let n_local = eng.n_locals()[0];
        let (_, t) = eng.run_round(&v0, 4 * n_local, 1);
        let frac = t.t_overhead / t.wall();
        assert!(frac < 0.25, "overhead fraction {} too high", frac);
    }

    #[test]
    fn persistent_alpha_state_accumulates() {
        let (ds, mut eng) = engine();
        let mut v = vec![0.0; ds.m()];
        let p = eng.problem;
        let mut prev = p.primal(&ds, &eng.alpha_global());
        for round in 0..5 {
            let (dv, _) = eng.run_round(&v, 100, round);
            linalg::add_assign(&mut v, &dv);
            let cur = p.primal(&ds, &eng.alpha_global());
            assert!(cur <= prev + 1e-9, "round {}: {} -> {}", round, prev, cur);
            prev = cur;
        }
    }

    #[test]
    fn sparse_frames_cut_bytes_and_keep_bits() {
        // Small H on a sparse dataset → sparse Δv frames; the adaptive
        // engine must move fewer bytes than the dense-forced one while
        // producing BIT-identical aggregates.
        let (ds, mut adaptive) = engine();
        let (_, mut dense) = engine();
        dense.force_dense_frames();
        let mut v1 = vec![0.0; ds.m()];
        let mut v2 = vec![0.0; ds.m()];
        let mut saw_sparse_savings = false;
        for round in 0..4 {
            let (dv1, t1) = adaptive.run_round(&v1, 2, round);
            let (dv2, t2) = dense.run_round(&v2, 2, round);
            for (a, b) in dv1.iter().zip(dv2.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert!(t1.bytes_up <= t2.bytes_up);
            if t1.bytes_up < t2.bytes_up {
                saw_sparse_savings = true;
            }
            linalg::add_assign(&mut v1, &dv1);
            linalg::add_assign(&mut v2, &dv2);
        }
        assert!(saw_sparse_savings, "no round used a cheaper sparse frame");
    }

    #[test]
    fn nested_engine_matches_flat_ring_bitwise() {
        // The tentpole invariant at the engine level: K ranks × t
        // sub-solvers produce the exact bits of a flat K·t ring —
        // including a non-power-of-two t.
        let ds = webspam_like(&SyntheticSpec::small());
        let model =
            || OverheadModel::paper_defaults(crate::simnet::ClusterModel::paper_testbed(1.0));
        for (k, t) in [(2usize, 2usize), (2, 3)] {
            let mut cfg_nested = TrainConfig::default_for(&ds);
            cfg_nested.workers = k;
            let nparts = Partitioning::build_nested(
                cfg_nested.partitioner,
                &ds.a,
                k,
                t,
                cfg_nested.seed,
            );
            let mut nested = MpiEngine::new_nested(&ds, &nparts, &cfg_nested, model(), t);
            assert_eq!(nested.num_workers(), k);
            assert_eq!(nested.threads_per_worker(), t);
            assert_eq!(nested.n_locals().len(), k * t);

            let mut cfg_flat = cfg_nested.clone();
            cfg_flat.workers = k * t;
            let fparts =
                Partitioning::build(cfg_flat.partitioner, &ds.a, k * t, cfg_flat.seed);
            let mut flat = MpiEngine::new(&ds, &fparts, &cfg_flat, model());

            let mut v1 = vec![0.0; ds.m()];
            let mut v2 = vec![0.0; ds.m()];
            for round in 0..4 {
                let (dv1, t1) = nested.run_round(&v1, 16, round);
                let (dv2, _) = flat.run_round(&v2, 16, round);
                for (a, b) in dv1.iter().zip(dv2.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "k={} t={} round {}", k, t, round);
                }
                assert_eq!(t1.worker_compute.len(), k);
                assert!(t1.bytes_up > 0);
                linalg::add_assign(&mut v1, &dv1);
                linalg::add_assign(&mut v2, &dv2);
            }
            let a1 = nested.alpha_global();
            let a2 = flat.alpha_global();
            for (x, y) in a1.iter().zip(a2.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "k={} t={}", k, t);
            }
        }
    }

    fn chaos_engine(spec: &str) -> MpiEngine {
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 4;
        let parts = Partitioning::build(Partitioner::BalancedNnz, &ds.a, 4, 0);
        let tau = super::super::overhead::auto_time_scale(ds.m(), ds.n());
        let model = OverheadModel::paper_defaults(crate::simnet::ClusterModel::paper_testbed(tau));
        let opts = EngineOptions {
            chaos: Some(
                crate::framework::chaos::ChaosSpec::parse(spec)
                    .unwrap()
                    .bind(4)
                    .unwrap(),
            ),
            ..Default::default()
        };
        MpiEngine::new_with(&ds, &parts, &cfg, model, &opts)
    }

    #[test]
    fn chaos_perturbs_time_never_bits() {
        // Heterogeneity, jitter, and slowdowns only touch the virtual
        // clock: Δv stays bit-identical to the chaos-free engine.
        let (ds, mut clean) = engine();
        let mut chaotic = chaos_engine("het=0.5,jitter=0.3");
        let mut v1 = vec![0.0; ds.m()];
        let mut v2 = vec![0.0; ds.m()];
        for round in 0..3 {
            chaotic.arm_chaos(RoundChaos {
                death: None,
                slowdowns: vec![(1, 8.0)],
            });
            let (dv1, _) = clean.run_round(&v1, 16, round);
            let (dv2, t2) = chaotic.run_round(&v2, 16, round);
            for (a, b) in dv1.iter().zip(dv2.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "round {}", round);
            }
            assert!(t2.wall() > 0.0);
            linalg::add_assign(&mut v1, &dv1);
            linalg::add_assign(&mut v2, &dv2);
        }
        assert_eq!(clean.alpha_global(), chaotic.alpha_global());
    }

    #[test]
    fn chaos_slowdown_drags_the_armed_rank() {
        // A 1000x slowdown on rank 1 must dominate its quiet-round compute
        // (same engine, so measured base times are comparable; the wide
        // margin absorbs measurement noise).
        let mut eng = chaos_engine("");
        let v0 = vec![0.0; eng.m];
        let (_, quiet) = eng.run_round(&v0, 16, 0);
        eng.arm_chaos(RoundChaos {
            death: None,
            slowdowns: vec![(1, 1000.0)],
        });
        let (_, dragged) = eng.run_round(&v0, 16, 1);
        assert!(
            dragged.worker_compute[1] > 30.0 * quiet.worker_compute[1],
            "dragged {} !>> quiet {}",
            dragged.worker_compute[1],
            quiet.worker_compute[1]
        );
    }

    #[test]
    fn chaos_death_aborts_commit_and_replay_matches_clean() {
        let (ds, mut clean) = engine();
        let mut chaotic = chaos_engine("het=0.2");
        let mut v1 = vec![0.0; ds.m()];
        let mut v2 = vec![0.0; ds.m()];
        // Round 0 completes on both.
        let (dv1, _) = clean.run_round(&v1, 16, 0);
        let (dv2, _) = chaotic.run_round(&v2, 16, 0);
        linalg::add_assign(&mut v1, &dv1);
        linalg::add_assign(&mut v2, &dv2);
        let alpha_before = chaotic.alpha_global();
        // Round 1 attempt: rank 2 dies — zeros back, nothing committed,
        // the coordinator is charged detect + respawn.
        chaotic.arm_chaos(RoundChaos {
            death: Some(2),
            slowdowns: vec![],
        });
        let clock_before = chaotic.clock();
        let (dv_dead, t_dead) = chaotic.run_round(&v2, 16, 1);
        assert!(dv_dead.iter().all(|&x| x == 0.0));
        assert_eq!(chaotic.alpha_global(), alpha_before);
        assert!(t_dead.t_overhead > 0.0);
        assert!(chaotic.clock() > clock_before);
        // Replay of round 1 (same seed, restored state) matches the
        // uninterrupted engine bit-for-bit.
        let (dv1b, _) = clean.run_round(&v1, 16, 1);
        let (dv2b, _) = chaotic.run_round(&v2, 16, 1);
        for (a, b) in dv1b.iter().zip(dv2b.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(clean.alpha_global(), chaotic.alpha_global());
    }

    #[test]
    fn convergence_insensitive_to_worker_count() {
        // CoCoA converges for any K (σ′ = γK keeps aggregation safe).
        for k in [1usize, 2, 8] {
            let ds = webspam_like(&SyntheticSpec::small());
            let mut cfg = TrainConfig::default_for(&ds);
            cfg.workers = k;
            let parts = Partitioning::build(Partitioner::Range, &ds.a, k, 0);
            let model =
                OverheadModel::paper_defaults(crate::simnet::ClusterModel::paper_testbed(1.0));
            let mut eng = MpiEngine::new(&ds, &parts, &cfg, model);
            let mut v = vec![0.0; ds.m()];
            let f0 = cfg.problem.primal(&ds, &eng.alpha_global());
            for round in 0..20 {
                let h = eng.n_locals()[0];
                let (dv, _) = eng.run_round(&v, h, round);
                linalg::add_assign(&mut v, &dv);
            }
            let f = cfg.problem.primal(&ds, &eng.alpha_global());
            assert!(f < 0.6 * f0, "K={}: {} -> {}", k, f0, f);
        }
    }
}
