//! Calibrated framework-overhead model (DESIGN.md §6).
//!
//! Constants are *physical* per-operation costs of the paper's software
//! stack (Spark 1.5 on JVM, pySpark over py4j, MPI over 10 GbE), taken
//! from the era's measurement literature (Ousterhout NSDI'15 for task
//! launch, Karau PyData'16 for pySpark serialization) and the paper's own
//! Figure 3 decomposition.
//!
//! **Scaling rule.** Costs split into two classes:
//! * *data-proportional* (per-byte serialization, per-record iteration,
//!   bandwidth) — charged at physical rates, unscaled: they shrink
//!   naturally with the down-scaled dataset;
//! * *fixed per-operation* (stage scheduling, task launch, py4j round
//!   trips, process costs, JNI/Python-C crossings, barriers, link latency)
//!   — multiplied by the cluster `time_scale` τ so their share of a round
//!   matches the paper's testbed at the smaller scale (a 20 ms Spark stage
//!   against a 1/300-size dataset would otherwise swamp every other term).
//!
//! With τ = geometric mean of the dimension ratios ([`auto_time_scale`])
//! this model reproduces the paper's Figure 3 decomposition at webspam
//! scale within ~30% per component (checked in the unit tests below and
//! validated end-to-end by `sparkbench figure 3`).
//!
//! What is modeled vs real (DESIGN.md §6):
//! * **real** — solver execution (measured), the aggregation arithmetic
//!   (the pairwise tree AllReduce of `linalg::tree_reduce` /
//!   `linalg::DeltaReducer` actually executes, in pooled buffers), the Δv
//!   frame encodes (each worker's frame — sparse or dense per the
//!   DESIGN.md §7 cutover — is really produced, and the byte counts
//!   charged below are the actual encoded lengths), algorithm
//!   trajectories;
//! * **modeled** — network transfer times, JVM/python process costs,
//!   scheduler latencies (cannot be physically produced on this machine),
//!   and the α-payload byte counts (computed by the `*_encoded_len` size
//!   functions rather than encoded — their layout is the fixed dense one,
//!   so length needs no encode).

use crate::simnet::ClusterModel;

/// Webspam's dimensions — the reference workload the constants assume.
pub const WEBSPAM_M: f64 = 350_000.0;
pub const WEBSPAM_N: f64 = 16_600_000.0;

/// Default fixed-cost time scale: τ = √((m/350k)·(n/16.6M)), the geometric
/// mean of the communication-dimension ratios (v traffic scales with m,
/// α traffic with n/K).
pub fn auto_time_scale(m: usize, n: usize) -> f64 {
    ((m as f64 / WEBSPAM_M) * (n as f64 / WEBSPAM_N))
        .sqrt()
        .clamp(1e-9, 1.0)
}

/// Per-operation cost constants (unscaled seconds / bytes-per-second).
#[derive(Debug, Clone)]
pub struct OverheadModel {
    pub cluster: ClusterModel,

    // --- Spark core (JVM) ---
    /// Per-stage driver cost: DAG scheduling, lazy-eval planning, closure
    /// serialization, result handling (Spark 1.5: tens of ms).
    pub spark_stage_fixed_s: f64,
    /// Per-task launch cost (scheduler dispatch + executor pickup).
    pub spark_task_launch_s: f64,
    /// JavaSerializer throughput.
    pub java_ser_bps: f64,
    pub java_deser_bps: f64,
    /// One JNI native call (GetPrimitiveArrayCritical etc.).
    pub jni_call_s: f64,
    /// Per-record cost of iterating a Scala RDD iterator (mapPartitions).
    pub record_iter_scala_s: f64,

    // --- pySpark additions ---
    /// cPickle throughput for generic python object graphs (records).
    pub pickle_bps: f64,
    pub unpickle_bps: f64,
    /// cPickle throughput for NumPy arrays (protocol-2 binary buffers are
    /// near-memcpy; this is what the v/α vector payloads use).
    pub numpy_pickle_bps: f64,
    /// One py4j driver↔JVM round trip.
    pub py4j_roundtrip_s: f64,
    /// Waking/feeding a python worker process per task (reused daemons).
    pub python_task_s: f64,
    /// Per-record cost of iterating records in the python worker.
    pub record_iter_python_s: f64,
    /// One Python-C API boundary crossing (NumPy pointer extraction).
    pub pyc_call_s: f64,

    // --- MPI ---
    /// Synchronization barrier per collective.
    pub mpi_barrier_s: f64,

    // --- fault handling (chaos layer, DESIGN.md §12) ---
    /// Time for the coordinator to notice a dead worker (missed heartbeat
    /// / broken connection). Also the launch delay of a speculative
    /// backup copy.
    pub fault_detect_s: f64,
    /// Time to respawn a worker process and reload its shards.
    pub respawn_s: f64,

    // --- multi-core workers (nested parallelism, DESIGN.md §10) ---
    /// Serial/contention fraction of one worker's compute when `t` local
    /// sub-solvers share its cores (memory-bandwidth pressure on the
    /// shared residual reads plus the rank-local combine). Feeds
    /// [`intra_worker_speedup`](OverheadModel::intra_worker_speedup).
    pub intra_worker_serial_frac: f64,
}

impl OverheadModel {
    /// Paper-calibrated constants on the given virtual cluster.
    pub fn paper_defaults(cluster: ClusterModel) -> OverheadModel {
        OverheadModel {
            cluster,
            spark_stage_fixed_s: 20e-3,
            spark_task_launch_s: 5e-3,
            java_ser_bps: 250e6,
            java_deser_bps: 400e6,
            jni_call_s: 20e-6,
            record_iter_scala_s: 0.3e-6,
            pickle_bps: 50e6,
            unpickle_bps: 80e6,
            numpy_pickle_bps: 400e6,
            py4j_roundtrip_s: 2e-3,
            python_task_s: 10e-3,
            record_iter_python_s: 5e-6,
            pyc_call_s: 100e-6,
            mpi_barrier_s: 30e-6,
            fault_detect_s: 100e-3,
            respawn_s: 1.0,
            intra_worker_serial_frac: 0.05,
        }
    }

    fn tau(&self) -> f64 {
        self.cluster.time_scale
    }

    // -- Spark --

    pub fn spark_stage(&self) -> f64 {
        self.spark_stage_fixed_s * self.tau()
    }

    pub fn spark_task_launch(&self) -> f64 {
        self.spark_task_launch_s * self.tau()
    }

    pub fn java_ser(&self, bytes: u64) -> f64 {
        bytes as f64 / self.java_ser_bps
    }

    pub fn java_deser(&self, bytes: u64) -> f64 {
        bytes as f64 / self.java_deser_bps
    }

    pub fn jni_call(&self) -> f64 {
        self.jni_call_s * self.tau()
    }

    pub fn record_iter_scala(&self, records: usize) -> f64 {
        self.record_iter_scala_s * records as f64
    }

    // -- pySpark --

    pub fn pickle(&self, bytes: u64) -> f64 {
        bytes as f64 / self.pickle_bps
    }

    pub fn unpickle(&self, bytes: u64) -> f64 {
        bytes as f64 / self.unpickle_bps
    }

    /// Pickling a NumPy vector payload (one direction).
    pub fn numpy_pickle(&self, bytes: u64) -> f64 {
        bytes as f64 / self.numpy_pickle_bps
    }

    pub fn py4j_roundtrip(&self) -> f64 {
        self.py4j_roundtrip_s * self.tau()
    }

    pub fn python_task(&self) -> f64 {
        self.python_task_s * self.tau()
    }

    pub fn record_iter_python(&self, records: usize) -> f64 {
        self.record_iter_python_s * records as f64
    }

    pub fn pyc_call(&self) -> f64 {
        self.pyc_call_s * self.tau()
    }

    // -- MPI --

    pub fn mpi_barrier(&self) -> f64 {
        self.mpi_barrier_s * self.tau()
    }

    // -- fault handling (chaos layer, DESIGN.md §12) --

    /// Detection delay for a dead or straggling worker (fixed cost, τ-scaled).
    pub fn fault_detect(&self) -> f64 {
        self.fault_detect_s * self.tau()
    }

    /// Worker respawn + shard reload (fixed cost, τ-scaled).
    pub fn respawn(&self) -> f64 {
        self.respawn_s * self.tau()
    }

    // -- multi-core workers --

    /// Modeled speedup of one worker's local compute when `t` sub-solvers
    /// run on its cores (nested parallelism, DESIGN.md §10). Amdahl-style
    /// linear scaling degraded by a serial/contention fraction `c`:
    ///
    /// ```text
    /// speedup(t) = t / (1 + c·(t − 1)),   c = intra_worker_serial_frac
    /// ```
    ///
    /// `speedup(1) = 1` exactly (a t = 1 round divides by 1.0, keeping the
    /// single-solver virtual clock bit-identical), and `speedup(t) < t`
    /// for every c > 0 — the paper's one-rank-per-*core* MPI baseline is
    /// the ceiling this curve approaches. The threads engine does not use
    /// it: its timing is measured wall clock.
    pub fn intra_worker_speedup(&self, t: usize) -> f64 {
        if t <= 1 {
            return 1.0;
        }
        let tf = t as f64;
        tf / (1.0 + self.intra_worker_serial_frac * (tf - 1.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::ClusterModel;

    fn model(tau: f64) -> OverheadModel {
        OverheadModel::paper_defaults(ClusterModel::paper_testbed(tau))
    }

    #[test]
    fn auto_scale_tracks_dimensions() {
        assert!((auto_time_scale(350_000, 16_600_000) - 1.0).abs() < 1e-9);
        let tau = auto_time_scale(2048, 32768);
        assert!(tau > 1e-4 && tau < 1e-2, "tau {}", tau);
        assert!(auto_time_scale(0, 0) > 0.0); // clamped
    }

    #[test]
    fn scaling_applies_to_fixed_costs_only() {
        let m1 = model(1.0);
        let m2 = model(0.5);
        assert!((m2.spark_stage() - 0.5 * m1.spark_stage()).abs() < 1e-12);
        assert!((m2.mpi_barrier() - 0.5 * m1.mpi_barrier()).abs() < 1e-15);
        assert!((m2.py4j_roundtrip() - 0.5 * m1.py4j_roundtrip()).abs() < 1e-15);
        // data-proportional costs are NOT scaled
        assert_eq!(m2.pickle(1000), m1.pickle(1000));
        assert_eq!(m2.java_ser(1000), m1.java_ser(1000));
        assert_eq!(m2.record_iter_python(10), m1.record_iter_python(10));
    }

    #[test]
    fn cost_hierarchy_matches_paper() {
        // The qualitative ordering the paper measures (§5.2):
        let m = model(1.0);
        // generic pickle is several times slower than java serialization,
        // but numpy-buffer pickling is fast (binary memcpy path)
        assert!(m.pickle(1_000_000) > 3.0 * m.java_ser(1_000_000));
        assert!(m.numpy_pickle(1_000_000) < m.pickle(1_000_000) / 4.0);
        // python record iteration is much more expensive than scala
        assert!(m.record_iter_python(1000) > 10.0 * m.record_iter_scala(1000));
        // MPI per-round cost is orders below a Spark stage
        assert!(m.mpi_barrier() < m.spark_stage() / 100.0);
        // Python-C crossing costs more than JNI
        assert!(m.pyc_call() > m.jni_call());
    }

    #[test]
    fn fault_costs_scale_with_tau_and_dominate_a_round() {
        let m1 = model(1.0);
        let m2 = model(0.5);
        assert!((m2.fault_detect() - 0.5 * m1.fault_detect()).abs() < 1e-12);
        assert!((m2.respawn() - 0.5 * m1.respawn()).abs() < 1e-12);
        // Losing a worker costs far more than a round's fixed overhead —
        // the reason mid-round recovery is worth modeling at all.
        assert!(m1.fault_detect() + m1.respawn() > 10.0 * m1.spark_stage());
    }

    #[test]
    fn intra_worker_speedup_curve_is_sane() {
        let m = model(1.0);
        assert_eq!(m.intra_worker_speedup(1), 1.0); // exact: t=1 is a no-op
        let s2 = m.intra_worker_speedup(2);
        let s4 = m.intra_worker_speedup(4);
        let s8 = m.intra_worker_speedup(8);
        // Monotone in t, sublinear, and close to linear at small t with
        // the default 5% serial fraction.
        assert!(1.0 < s2 && s2 < 2.0);
        assert!(s2 < s4 && s4 < 4.0);
        assert!(s4 < s8 && s8 < 8.0);
        assert!(s4 > 3.0, "speedup(4) {} unexpectedly poor", s4);
        // A fully serial worker never speeds up.
        let mut serial = model(1.0);
        serial.intra_worker_serial_frac = 1.0;
        assert_eq!(serial.intra_worker_speedup(4), 1.0);
    }

    #[test]
    fn per_round_spark_overhead_magnitude_at_paper_scale() {
        // Sanity: at webspam scale (m=350k → v ≈ 2.8 MB, K=8, n_local = 2M
        // → α ≈ 16 MB/worker) one round of (B)-style overhead lands within
        // 2× of the paper's ≈0.7 s/round (Figure 3: 70 s / 100 rounds).
        let m = model(1.0);
        let k = 8u64;
        let v_bytes = 2_800_000u64;
        let alpha_bytes = 16_000_000u64;
        let ser = m.java_ser((v_bytes + alpha_bytes) * k) * 2.0;
        let net = m.cluster.star_broadcast(v_bytes + alpha_bytes, 8)
            + m.cluster.star_gather(v_bytes + alpha_bytes, 8);
        let fixed = m.spark_stage() + 8.0 * m.spark_task_launch();
        let total = ser + net + fixed;
        assert!(
            total > 0.3 && total < 2.0,
            "per-round B overhead {} outside [0.3, 2.0] s (paper ≈ 0.7)",
            total
        );
    }

    #[test]
    fn figure3_decomposition_at_paper_scale() {
        // Recompute the paper's Figure 3 per-round overheads from the model
        // at webspam scale and check each lands near the measured bar.
        let md = model(1.0);
        let k = 8usize;
        let v_b = 2_800_000u64; // m=350k doubles, java
        let a_b = 16_600_000u64; // n_local = 2.07M doubles
        let recs = 2_075_000usize;

        // (A) spark: records + java ser of v+α both ways
        let a_ovh = md.record_iter_scala(recs)
            + md.java_ser((v_b + a_b) * k as u64) * 2.0
            + md.cluster.star_broadcast(v_b + a_b, k) * 2.0;
        assert!(a_ovh > 1.0 && a_ovh < 4.0, "A {} (paper ≈ 2.1 s/round)", a_ovh);

        // (D) pyspark+c: python record iteration dominates
        let d_ovh = md.record_iter_python(recs) + md.pickle((v_b + a_b) * k as u64);
        assert!(d_ovh > 5.0 && d_ovh < 20.0, "D {} (paper ≈ 10.5 s/round)", d_ovh);

        // (E) mpi: tree allreduce only
        let e_ovh = md.cluster.tree_allreduce(v_b, k) + md.mpi_barrier();
        assert!(e_ovh < 0.05, "E {} (paper ≈ 0.02 s/round)", e_ovh);
    }
}
