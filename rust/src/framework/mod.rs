//! Distributed-execution substrates: the five framework stacks the paper
//! compares, behind one [`DistEngine`] interface.
//!
//! * [`spark`] — implementations (A), (B) and (B)\* on the mini-RDD engine;
//! * [`pyspark`] — implementations (C), (D) and (D)\* (adds the
//!   pickle / py4j / python-worker layers), plus the MLlib-SGD baseline;
//! * [`mpi`] — implementation (E): tree AllReduce, persistent ranks;
//! * [`rdd`] — the Spark programming model itself;
//! * [`overhead`] / [`serialization`] — the calibrated cost model and the
//!   real byte codecs.
//!
//! Engines execute the *real* algorithm (numerics are bit-identical across
//! engines given the same seed — enforced by integration tests) and fold
//! measured compute plus modeled framework costs onto the virtual clock
//! (DESIGN.md §2). Every engine's workers emit their Δv as whichever frame
//! is cheaper — sparse (sorted index + value) or dense — under the
//! byte-cost cutover rule of DESIGN.md §7, and the overhead model is
//! charged the actual encoded bytes.

pub mod mpi;
pub mod param_server;
pub mod overhead;
pub mod pyspark;
pub mod rdd;
pub mod serialization;
pub mod spark;
pub mod threads;

use std::sync::OnceLock;

use crate::config::{Impl, TrainConfig};
use crate::data::{Dataset, Partitioning, WorkerData};
use crate::framework::overhead::{auto_time_scale, OverheadModel};
use crate::simnet::ClusterModel;
use crate::solver::managed::Calibration;

/// Timing breakdown of one synchronous CoCoA round, in virtual seconds —
/// the decomposition of §5.2 (`T_tot = T_worker + T_master + T_overhead`).
#[derive(Debug, Clone, Default)]
pub struct RoundTiming {
    /// Critical-path local-solver compute (max over workers).
    pub t_worker: f64,
    /// Master aggregation compute (measured).
    pub t_master: f64,
    /// Framework overhead: serialization, network, scheduling, language
    /// boundaries — everything that is neither worker nor master compute.
    pub t_overhead: f64,
    /// Per-worker solver compute (virtual seconds, after multiplier).
    pub worker_compute: Vec<f64>,
    /// Bytes moved worker→master this round (all workers).
    pub bytes_up: u64,
    /// Bytes moved master→worker this round (all workers).
    pub bytes_down: u64,
}

impl RoundTiming {
    /// Total round wall time.
    pub fn wall(&self) -> f64 {
        self.t_worker + self.t_master + self.t_overhead
    }
}

/// One framework substrate executing CoCoA rounds.
pub trait DistEngine {
    fn imp(&self) -> Impl;

    fn num_workers(&self) -> usize;

    /// Columns per worker.
    fn n_locals(&self) -> Vec<usize>;

    /// Execute one round: broadcast shared state, run H local steps per
    /// worker, aggregate. Returns the aggregated Δv and the timing split.
    /// `round_seed` drives coordinate sampling (deterministic runs).
    fn run_round(&mut self, v: &[f64], h: usize, round_seed: u64) -> (Vec<f64>, RoundTiming);

    /// Assemble the global α from worker state — metrics only, free of
    /// charge on the virtual clock.
    fn alpha_global(&self) -> Vec<f64>;

    /// Virtual time consumed so far.
    fn clock(&self) -> f64;
}

/// Shared engine internals: partitioned data + per-worker α state.
pub(crate) struct WorkerSet {
    pub data: Vec<WorkerData>,
    pub alpha: Vec<Vec<f64>>,
    pub n_total: usize,
}

impl WorkerSet {
    pub fn build(ds: &Dataset, parts: &Partitioning) -> WorkerSet {
        let data: Vec<WorkerData> = parts
            .parts
            .iter()
            .map(|cols| WorkerData::from_columns(&ds.a, cols))
            .collect();
        let alpha = data.iter().map(|d| vec![0.0; d.n_local()]).collect();
        WorkerSet {
            data,
            alpha,
            n_total: ds.n(),
        }
    }

    pub fn alpha_global(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.n_total];
        for (wd, al) in self.data.iter().zip(self.alpha.iter()) {
            for (&gid, &a) in wd.global_ids.iter().zip(al.iter()) {
                out[gid as usize] = a;
            }
        }
        out
    }

    pub fn n_locals(&self) -> Vec<usize> {
        self.data.iter().map(|d| d.n_local()).collect()
    }
}

/// Partition layout override for the flat-vs-records ablation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayoutOverride {
    /// One contiguous record per partition (paper impl. B).
    Flat,
    /// One record per feature (paper impls. A/C/D).
    Records,
    /// No records in the RDD at all (§5.3 meta-RDD).
    Meta,
}

/// Options controlling engine construction.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Execute the genuinely interpreted managed solvers for (A)/(C)
    /// instead of native-numerics + measured multiplier. Slower; used by
    /// the Figure 3 validation run.
    pub real_managed_compute: bool,
    /// Override the virtual-cluster time scale (default: auto from nnz).
    pub time_scale: Option<f64>,
    /// MLlib SGD step size / batch fraction (Figure 5 baseline).
    pub sgd_step: f64,
    pub sgd_batch_fraction: f64,
    /// Force a partition layout (ablation: flat vs records).
    pub force_layout: Option<LayoutOverride>,
    /// Use TorrentBroadcast for the master→worker path (Spark 1.5 default)
    /// instead of the driver-star model (ablation: `broadcast`).
    pub torrent_broadcast: bool,
    /// Force dense Δv frames, disabling the nnz-adaptive sparse
    /// communication layer (DESIGN.md §7). The numerics are bit-identical
    /// either way (asserted by `tests/integration_sparse_frames.rs`);
    /// this is the A/B baseline for byte accounting and the H-sweep bench.
    pub dense_frames: bool,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            real_managed_compute: false,
            time_scale: None,
            sgd_step: 1.0,
            sgd_batch_fraction: 1.0,
            force_layout: None,
            torrent_broadcast: false,
            dense_frames: false,
        }
    }
}

/// Measured managed-runtime slowdowns, calibrated once per process.
pub fn calibration() -> &'static Calibration {
    static CAL: OnceLock<Calibration> = OnceLock::new();
    CAL.get_or_init(|| crate::solver::managed::calibrate(1))
}

/// Build the engine for an implementation on a dataset.
pub fn build_engine(imp: Impl, ds: &Dataset, cfg: &TrainConfig) -> Box<dyn DistEngine> {
    build_engine_with(imp, ds, cfg, &EngineOptions::default())
}

/// Build with explicit options.
pub fn build_engine_with(
    imp: Impl,
    ds: &Dataset,
    cfg: &TrainConfig,
    opts: &EngineOptions,
) -> Box<dyn DistEngine> {
    cfg.validate().expect("invalid TrainConfig");
    let parts = Partitioning::build(cfg.partitioner, &ds.a, cfg.workers, cfg.seed);
    let tau = opts.time_scale.unwrap_or_else(|| auto_time_scale(ds.m(), ds.n()));
    let cluster = ClusterModel::paper_testbed(tau);
    let model = OverheadModel::paper_defaults(cluster);
    match imp {
        Impl::SparkScala | Impl::SparkC | Impl::SparkCOpt | Impl::MllibSgd => Box::new(
            spark::SparkEngine::new(imp, ds, &parts, cfg, model, opts.clone()),
        ),
        Impl::PySpark | Impl::PySparkC | Impl::PySparkCOpt => Box::new(
            pyspark::PySparkEngine::new(imp, ds, &parts, cfg, model, opts.clone()),
        ),
        Impl::Mpi => {
            let mut eng = mpi::MpiEngine::new(ds, &parts, cfg, model);
            if opts.dense_frames {
                eng.force_dense_frames();
            }
            Box::new(eng)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{webspam_like, SyntheticSpec};
    use crate::data::Partitioner;

    #[test]
    fn worker_set_assembles_alpha() {
        let ds = webspam_like(&SyntheticSpec::small());
        let parts = Partitioning::build(Partitioner::RoundRobin, &ds.a, 3, 0);
        let mut ws = WorkerSet::build(&ds, &parts);
        // Tag each worker's coordinates with its id.
        for (w, al) in ws.alpha.iter_mut().enumerate() {
            for a in al.iter_mut() {
                *a = (w + 1) as f64;
            }
        }
        let global = ws.alpha_global();
        assert_eq!(global.len(), ds.n());
        for (c, &g) in global.iter().enumerate() {
            assert_eq!(g, (c % 3 + 1) as f64, "column {}", c);
        }
    }

    #[test]
    fn round_timing_wall_is_sum() {
        let t = RoundTiming {
            t_worker: 1.0,
            t_master: 0.25,
            t_overhead: 0.5,
            ..Default::default()
        };
        assert!((t.wall() - 1.75).abs() < 1e-15);
    }
}
