//! Distributed-execution substrates: the five framework stacks the paper
//! compares, behind one [`DistEngine`] interface.
//!
//! * [`spark`] — implementations (A), (B) and (B)\* on the mini-RDD engine;
//! * [`pyspark`] — implementations (C), (D) and (D)\* (adds the
//!   pickle / py4j / python-worker layers), plus the MLlib-SGD baseline;
//! * [`mpi`] — implementation (E): tree AllReduce, persistent ranks;
//! * [`rdd`] — the Spark programming model itself;
//! * [`overhead`] / [`serialization`] — the calibrated cost model and the
//!   real byte codecs.
//!
//! Engines execute the *real* algorithm (numerics are bit-identical across
//! engines given the same seed — enforced by integration tests) and fold
//! measured compute plus modeled framework costs onto the virtual clock
//! (DESIGN.md §2). Every engine's workers emit their Δv as whichever frame
//! is cheaper — sparse (sorted index + value) or dense — under the
//! byte-cost cutover rule of DESIGN.md §7, and the overhead model is
//! charged the actual encoded bytes.

pub mod chaos;
pub mod mpi;
pub mod param_server;
pub mod overhead;
pub mod pyspark;
pub mod rdd;
pub mod serialization;
pub mod spark;
pub mod threads;

use std::sync::OnceLock;

use crate::config::{Impl, TrainConfig};
use crate::data::{Dataset, Partitioning, WorkerData};
use crate::framework::overhead::{auto_time_scale, OverheadModel};
use crate::simnet::ClusterModel;
use crate::solver::managed::Calibration;

/// Timing breakdown of one synchronous CoCoA round, in virtual seconds —
/// the decomposition of §5.2 (`T_tot = T_worker + T_master + T_overhead`).
#[derive(Debug, Clone, Default)]
pub struct RoundTiming {
    /// Critical-path local-solver compute (max over workers).
    pub t_worker: f64,
    /// Master aggregation compute (measured).
    pub t_master: f64,
    /// Framework overhead: serialization, network, scheduling, language
    /// boundaries — everything that is neither worker nor master compute.
    pub t_overhead: f64,
    /// Per-worker solver compute (virtual seconds, after multiplier).
    pub worker_compute: Vec<f64>,
    /// Bytes moved worker→master this round (all workers).
    pub bytes_up: u64,
    /// Bytes moved master→worker this round (all workers).
    pub bytes_down: u64,
}

impl RoundTiming {
    /// Total round wall time.
    pub fn wall(&self) -> f64 {
        self.t_worker + self.t_master + self.t_overhead
    }
}

/// One framework substrate executing CoCoA rounds.
pub trait DistEngine {
    /// Paper-implementation classification (solver kind, persistence).
    fn imp(&self) -> Impl;

    /// Registry identity — distinguishes the thread and parameter-server
    /// substrates from the virtual-clock `Impl` they emulate.
    fn engine(&self) -> Engine {
        Engine::Impl(self.imp())
    }

    fn num_workers(&self) -> usize;

    /// Local sub-solvers per worker (nested two-level parallelism;
    /// DESIGN.md §10). 1 for a classic flat engine.
    fn threads_per_worker(&self) -> usize {
        1
    }

    /// Columns per local solver — one entry per worker for flat engines,
    /// one per *sub-shard* (`num_workers · threads_per_worker` entries,
    /// rank-major) for nested engines, so H resolution against the mean
    /// sub-problem size matches the equivalent flat `K·T` ring.
    fn n_locals(&self) -> Vec<usize>;

    /// Execute one round: broadcast shared state, run H local steps per
    /// worker, aggregate. Returns the aggregated Δv and the timing split.
    /// `round_seed` drives coordinate sampling (deterministic runs).
    fn run_round(&mut self, v: &[f64], h: usize, round_seed: u64) -> (Vec<f64>, RoundTiming);

    /// Assemble the global α from worker state — metrics only, free of
    /// charge on the virtual clock.
    fn alpha_global(&self) -> Vec<f64>;

    /// Scatter a global α into the per-worker state (checkpoint resume).
    /// Free of charge on the virtual clock, like [`alpha_global`].
    ///
    /// [`alpha_global`]: DistEngine::alpha_global
    fn load_alpha(&mut self, alpha_global: &[f64]);

    /// Virtual time consumed so far.
    fn clock(&self) -> f64;

    /// Arm the chaos for the next `run_round` attempt (DESIGN.md §12):
    /// the session-side fault schedule decides *what* fires each attempt;
    /// the engine decides *how* — physically (threads) or on the cost
    /// model (virtual engines). Default: ignore chaos entirely, so
    /// engines without a chaos path stay untouched.
    fn arm_chaos(&mut self, _rc: chaos::RoundChaos) {}
}

/// Scatter a global α into per-worker vectors by their global column ids
/// — the one inverse of the `alpha_global` gather, shared by every
/// engine's `load_alpha`.
pub(crate) fn scatter_alpha(data: &[WorkerData], alpha: &mut [Vec<f64>], alpha_global: &[f64]) {
    for (wd, al) in data.iter().zip(alpha.iter_mut()) {
        for (&gid, a) in wd.global_ids.iter().zip(al.iter_mut()) {
            *a = alpha_global[gid as usize];
        }
    }
}

/// Shared engine internals: partitioned data + per-solver α state (one
/// entry per worker, or per sub-shard in a nested K·T layout — the
/// gather/scatter by global column ids is layout-agnostic).
pub(crate) struct WorkerSet {
    pub data: Vec<WorkerData>,
    pub alpha: Vec<Vec<f64>>,
    pub n_total: usize,
}

impl WorkerSet {
    pub fn build(ds: &Dataset, parts: &Partitioning) -> WorkerSet {
        let data: Vec<WorkerData> = parts
            .parts
            .iter()
            .map(|cols| WorkerData::from_columns(&ds.a, cols))
            .collect();
        let alpha = data.iter().map(|d| vec![0.0; d.n_local()]).collect();
        WorkerSet {
            data,
            alpha,
            n_total: ds.n(),
        }
    }

    pub fn alpha_global(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.n_total];
        for (wd, al) in self.data.iter().zip(self.alpha.iter()) {
            for (&gid, &a) in wd.global_ids.iter().zip(al.iter()) {
                out[gid as usize] = a;
            }
        }
        out
    }

    /// Inverse of [`alpha_global`](WorkerSet::alpha_global): scatter a global
    /// α back into the per-worker vectors (checkpoint resume).
    pub fn load_alpha(&mut self, alpha_global: &[f64]) {
        scatter_alpha(&self.data, &mut self.alpha, alpha_global);
    }

    pub fn n_locals(&self) -> Vec<usize> {
        self.data.iter().map(|d| d.n_local()).collect()
    }
}

/// Partition layout override for the flat-vs-records ablation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayoutOverride {
    /// One contiguous record per partition (paper impl. B).
    Flat,
    /// One record per feature (paper impls. A/C/D).
    Records,
    /// No records in the RDD at all (§5.3 meta-RDD).
    Meta,
}

/// Options controlling engine construction.
#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Execute the genuinely interpreted managed solvers for (A)/(C)
    /// instead of native-numerics + measured multiplier. Slower; used by
    /// the Figure 3 validation run.
    pub real_managed_compute: bool,
    /// Override the virtual-cluster time scale (default: auto from nnz).
    pub time_scale: Option<f64>,
    /// MLlib SGD step size / batch fraction (Figure 5 baseline).
    pub sgd_step: f64,
    pub sgd_batch_fraction: f64,
    /// Force a partition layout (ablation: flat vs records).
    pub force_layout: Option<LayoutOverride>,
    /// Use TorrentBroadcast for the master→worker path (Spark 1.5 default)
    /// instead of the driver-star model (ablation: `broadcast`).
    pub torrent_broadcast: bool,
    /// Force dense Δv frames, disabling the nnz-adaptive sparse
    /// communication layer (DESIGN.md §7). The numerics are bit-identical
    /// either way (asserted by `tests/integration_sparse_frames.rs`);
    /// this is the A/B baseline for byte accounting and the H-sweep bench.
    pub dense_frames: bool,
    /// Local sub-solvers per worker (nested two-level parallelism,
    /// DESIGN.md §10). Every worker rank sub-partitions its columns into
    /// this many sub-shards — the sub-shards ARE the parts of the flat
    /// `K·T` partitioning, σ′ becomes γ·K·T and per-shard seeds use the
    /// flat rank ids, so trajectories are **bit-identical** to a flat
    /// `K·T` ring (`tests/integration_nested.rs`). Physically parallel in
    /// the threads engine (persistent sub-pool per rank); modeled in the
    /// virtual-clock engines via
    /// [`OverheadModel::intra_worker_speedup`]. Inert for `mllib-sgd`
    /// (its solver is one gradient step, not a partitionable CoCoA
    /// subproblem). An explicit `Engine::Threads { t, .. } > 0` wins over
    /// this field.
    pub threads_per_worker: usize,
    /// Bound chaos spec (DESIGN.md §12): per-worker heterogeneity,
    /// latency jitter, speculation, and the fault schedule. Set by the
    /// session builder (which binds and validates the spec against the
    /// worker count); engines build their [`chaos::ChaosRuntime`] from
    /// it. `None` = the chaos layer is entirely inert.
    pub chaos: Option<chaos::ChaosSpec>,
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            real_managed_compute: false,
            time_scale: None,
            sgd_step: 1.0,
            sgd_batch_fraction: 1.0,
            force_layout: None,
            torrent_broadcast: false,
            dense_frames: false,
            threads_per_worker: 1,
            chaos: None,
        }
    }
}

/// Measured managed-runtime slowdowns, calibrated once per process.
pub fn calibration() -> &'static Calibration {
    static CAL: OnceLock<Calibration> = OnceLock::new();
    CAL.get_or_init(|| crate::solver::managed::calibrate(1))
}

/// Selector for the full engine registry: every substrate the testbed can
/// run. The eight virtual-clock [`Impl`] variants plus the two engines the
/// old registry could not reach — the physically parallel thread engine
/// and the parameter-server engine. One constructor path ([`build_any`])
/// serves all of them and applies every applicable [`EngineOptions`]
/// field uniformly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// A virtual-clock paper implementation (A..E, B*, D*, mllib-sgd).
    Impl(Impl),
    /// Physically parallel rank-per-thread engine (wall-clock timing, MPI
    /// semantics). `k = 0` means "use `cfg.workers`"; any other value
    /// overrides the worker count. `t` is the number of local sub-solvers
    /// per rank (nested two-level parallelism, DESIGN.md §10): `t = 0`
    /// defers to [`EngineOptions::threads_per_worker`], `t >= 1` overrides
    /// it. Nested trajectories are bit-identical to the flat
    /// `Threads { k: k·t, t: 1 }` ring.
    Threads { k: usize, t: usize },
    /// Parameter-server engine. `staleness = 0` is the synchronous mode
    /// (bit-identical trajectories to MPI); larger values let workers
    /// compute against views that many rounds old, damped by 1/(1+s).
    ParamServer { staleness: usize },
}

impl From<Impl> for Engine {
    fn from(imp: Impl) -> Engine {
        Engine::Impl(imp)
    }
}

impl Engine {
    /// The thread engine with `k` ranks (0 = `cfg.workers`), one local
    /// solver each.
    pub fn threads(k: usize) -> Engine {
        Engine::Threads { k, t: 0 }
    }

    /// The thread engine with `k` ranks × `t` local sub-solvers per rank
    /// (nested two-level parallelism; bit-identical to `threads(k·t)`).
    pub fn threads_nested(k: usize, t: usize) -> Engine {
        Engine::Threads { k, t }
    }

    /// Human-readable registry label (CLI tables, reports).
    pub fn label(&self) -> String {
        match self {
            Engine::Impl(imp) => imp.name().to_string(),
            Engine::Threads { k: 0, t: 0 | 1 } => "threads".to_string(),
            Engine::Threads { k, t: 0 | 1 } => format!("threads:{}", k),
            Engine::Threads { k, t } => format!("threads:{}:{}", k, t),
            Engine::ParamServer { staleness: 0 } => "param-server".to_string(),
            Engine::ParamServer { staleness } => format!("param-server:{}", staleness),
        }
    }

    /// Parse CLI aliases: every [`Impl::parse`] alias, plus `threads`
    /// (optionally `threads:K` or `threads:K:T` for K ranks × T local
    /// sub-solvers) and `ps` / `param-server` (optionally `ps:STALENESS`).
    pub fn parse(s: &str) -> Option<Engine> {
        if let Some(imp) = Impl::parse(s) {
            return Some(Engine::Impl(imp));
        }
        let lower = s.to_ascii_lowercase();
        let mut segs = lower.split(':');
        let head = segs.next()?;
        let args: Vec<&str> = segs.collect();
        let num = |i: usize, default: usize| -> Option<usize> {
            match args.get(i) {
                None => Some(default),
                Some(a) => a.parse().ok(),
            }
        };
        match (head, args.len()) {
            ("threads", 0 | 1) => Some(Engine::Threads { k: num(0, 0)?, t: 0 }),
            ("threads", 2) => {
                let (k, t) = (num(0, 0)?, num(1, 0)?);
                // threads:K:T needs an explicit sub-solver count >= 1.
                if t == 0 {
                    return None;
                }
                Some(Engine::Threads { k, t })
            }
            ("ps" | "param-server" | "param_server", 0 | 1) => {
                Some(Engine::ParamServer { staleness: num(0, 0)? })
            }
            _ => None,
        }
    }

    /// Every engine family once — the registry sweep used by tests.
    pub const FAMILIES: [Engine; 5] = [
        Engine::Impl(Impl::SparkCOpt),
        Engine::Impl(Impl::PySparkCOpt),
        Engine::Impl(Impl::Mpi),
        Engine::Threads { k: 0, t: 0 },
        Engine::ParamServer { staleness: 0 },
    ];
}

/// Build the engine for an implementation on a dataset.
pub fn build_engine(imp: Impl, ds: &Dataset, cfg: &TrainConfig) -> Box<dyn DistEngine> {
    build_any(Engine::Impl(imp), ds, cfg, &EngineOptions::default())
}

/// Build an [`Impl`] with explicit options (shim over [`build_any`]).
pub fn build_engine_with(
    imp: Impl,
    ds: &Dataset,
    cfg: &TrainConfig,
    opts: &EngineOptions,
) -> Box<dyn DistEngine> {
    build_any(Engine::Impl(imp), ds, cfg, opts)
}

/// The unified constructor: build any registry [`Engine`] on a dataset.
///
/// Every substrate goes through the same path: one [`Partitioning`] from
/// the config, one overhead model from the options, and every applicable
/// [`EngineOptions`] field applied identically — in particular
/// `dense_frames` disables the sparse Δv layer for **all** five engine
/// families (spark, pyspark, mpi, threads, param-server), not just the
/// virtual Spark engines. Substrate-specific fields (`sgd_step`,
/// `force_layout`, `torrent_broadcast`, `real_managed_compute`) apply
/// where the substrate has the corresponding layer and are inert
/// elsewhere, exactly as they always were for the virtual engines.
/// `time_scale` governs the virtual clock and is inert for the
/// wall-clock thread engine.
///
/// `threads_per_worker` (or an explicit `Engine::Threads { t, .. }`)
/// switches every family except `mllib-sgd` into the nested two-level
/// layout: ONE flat `K·T` [`Partitioning`] whose parts become the
/// sub-shards, grouped `T` per rank — the construction DESIGN.md §10
/// proves bit-identical to the flat ring.
pub fn build_any(
    engine: Engine,
    ds: &Dataset,
    cfg: &TrainConfig,
    opts: &EngineOptions,
) -> Box<dyn DistEngine> {
    cfg.validate().expect("invalid TrainConfig");
    let cfg_owned;
    let cfg = match engine {
        Engine::Threads { k, .. } if k > 0 => {
            let mut c = cfg.clone();
            c.workers = k;
            cfg_owned = c;
            &cfg_owned
        }
        _ => cfg,
    };
    // Resolve the sub-solver count once; engines read it back from the
    // normalized options. An explicit `threads:K:T` wins over the option;
    // MLlib's gradient step is not a partitionable CoCoA subproblem.
    let tpw = match engine {
        Engine::Threads { t, .. } if t > 0 => t,
        Engine::Impl(Impl::MllibSgd) => 1,
        _ => opts.threads_per_worker.max(1),
    };
    let mut opts_resolved = opts.clone();
    opts_resolved.threads_per_worker = tpw;
    let opts = &opts_resolved;
    let parts = Partitioning::build_nested(cfg.partitioner, &ds.a, cfg.workers, tpw, cfg.seed);
    let tau = opts.time_scale.unwrap_or_else(|| auto_time_scale(ds.m(), ds.n()));
    let cluster = ClusterModel::paper_testbed(tau);
    let model = OverheadModel::paper_defaults(cluster);
    match engine {
        Engine::Impl(imp) => match imp {
            Impl::SparkScala | Impl::SparkC | Impl::SparkCOpt | Impl::MllibSgd => Box::new(
                spark::SparkEngine::new(imp, ds, &parts, cfg, model, opts.clone()),
            ),
            Impl::PySpark | Impl::PySparkC | Impl::PySparkCOpt => Box::new(
                pyspark::PySparkEngine::new(imp, ds, &parts, cfg, model, opts.clone()),
            ),
            Impl::Mpi => Box::new(mpi::MpiEngine::new_with(ds, &parts, cfg, model, opts)),
        },
        Engine::Threads { .. } => Box::new(threads::ThreadedMpiEngine::with_options(
            ds, &parts, cfg, opts,
        )),
        Engine::ParamServer { staleness } => Box::new(param_server::ParamServerEngine::new(
            ds, &parts, cfg, model, staleness, opts,
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{webspam_like, SyntheticSpec};
    use crate::data::Partitioner;

    #[test]
    fn worker_set_assembles_alpha() {
        let ds = webspam_like(&SyntheticSpec::small());
        let parts = Partitioning::build(Partitioner::RoundRobin, &ds.a, 3, 0);
        let mut ws = WorkerSet::build(&ds, &parts);
        // Tag each worker's coordinates with its id.
        for (w, al) in ws.alpha.iter_mut().enumerate() {
            for a in al.iter_mut() {
                *a = (w + 1) as f64;
            }
        }
        let global = ws.alpha_global();
        assert_eq!(global.len(), ds.n());
        for (c, &g) in global.iter().enumerate() {
            assert_eq!(g, (c % 3 + 1) as f64, "column {}", c);
        }
    }

    #[test]
    fn round_timing_wall_is_sum() {
        let t = RoundTiming {
            t_worker: 1.0,
            t_master: 0.25,
            t_overhead: 0.5,
            ..Default::default()
        };
        assert!((t.wall() - 1.75).abs() < 1e-15);
    }

    #[test]
    fn worker_set_load_alpha_roundtrips() {
        let ds = webspam_like(&SyntheticSpec::small());
        let parts = Partitioning::build(Partitioner::RoundRobin, &ds.a, 3, 0);
        let mut ws = WorkerSet::build(&ds, &parts);
        let snapshot: Vec<f64> = (0..ds.n()).map(|i| i as f64 * 0.25 - 3.0).collect();
        ws.load_alpha(&snapshot);
        assert_eq!(ws.alpha_global(), snapshot);
    }

    #[test]
    fn engine_parse_covers_full_registry() {
        use crate::config::Impl;
        assert_eq!(Engine::parse("mpi"), Some(Engine::Impl(Impl::Mpi)));
        assert_eq!(Engine::parse("b*"), Some(Engine::Impl(Impl::SparkCOpt)));
        assert_eq!(Engine::parse("threads"), Some(Engine::threads(0)));
        assert_eq!(Engine::parse("threads:4"), Some(Engine::threads(4)));
        assert_eq!(
            Engine::parse("threads:4:2"),
            Some(Engine::threads_nested(4, 2))
        );
        assert_eq!(
            Engine::parse("threads:0:8"),
            Some(Engine::Threads { k: 0, t: 8 })
        );
        assert_eq!(Engine::parse("ps"), Some(Engine::ParamServer { staleness: 0 }));
        assert_eq!(
            Engine::parse("param-server:2"),
            Some(Engine::ParamServer { staleness: 2 })
        );
        assert!(Engine::parse("threads:x").is_none());
        assert!(Engine::parse("threads:2:x").is_none());
        assert!(Engine::parse("threads:2:0").is_none()); // explicit T must be >= 1
        assert!(Engine::parse("threads:2:2:2").is_none());
        assert!(Engine::parse("flink").is_none());
        assert_eq!(Engine::parse("THREADS"), Some(Engine::threads(0)));
        assert_eq!(Engine::threads(4).label(), "threads:4");
        assert_eq!(Engine::Threads { k: 4, t: 1 }.label(), "threads:4");
        assert_eq!(Engine::threads_nested(4, 2).label(), "threads:4:2");
        assert_eq!(Engine::threads(0).label(), "threads");
        assert_eq!(Engine::ParamServer { staleness: 0 }.label(), "param-server");
    }

    #[test]
    fn builder_reaches_threads_and_param_server() {
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 3;
        for engine in [
            Engine::threads(0),
            Engine::threads(2),
            Engine::threads_nested(2, 2),
            Engine::ParamServer { staleness: 0 },
            Engine::ParamServer { staleness: 2 },
        ] {
            let mut eng = build_any(engine, &ds, &cfg, &EngineOptions::default());
            let expect_k = match engine {
                Engine::Threads { k, .. } if k > 0 => k,
                _ => 3,
            };
            assert_eq!(eng.num_workers(), expect_k, "{}", engine.label());
            let v = vec![0.0; ds.m()];
            let (dv, timing) = eng.run_round(&v, 8, 1);
            assert_eq!(dv.len(), ds.m());
            assert!(dv.iter().any(|&x| x != 0.0), "{}", engine.label());
            assert!(timing.bytes_up > 0, "{}", engine.label());
        }
    }

    #[test]
    fn nested_options_apply_to_every_family_and_are_inert_for_mllib() {
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 2;
        let opts = EngineOptions {
            threads_per_worker: 2,
            ..Default::default()
        };
        for engine in Engine::FAMILIES {
            let eng = build_any(engine, &ds, &cfg, &opts);
            assert_eq!(eng.num_workers(), 2, "{}", engine.label());
            assert_eq!(eng.threads_per_worker(), 2, "{}", engine.label());
            // n_locals reports per-sub-shard sizes: K·T rank-major entries
            // covering every column once.
            let n_locals = eng.n_locals();
            assert_eq!(n_locals.len(), 4, "{}", engine.label());
            assert_eq!(n_locals.iter().sum::<usize>(), ds.n(), "{}", engine.label());
        }
        // MLlib's gradient step has no sub-shards: the option is inert.
        let mllib = build_any(Engine::Impl(Impl::MllibSgd), &ds, &cfg, &opts);
        assert_eq!(mllib.threads_per_worker(), 1);
        assert_eq!(mllib.n_locals().len(), 2);
    }

    #[test]
    fn dense_frames_applies_identically_to_every_family() {
        // Satellite regression: `EngineOptions::dense_frames` must take
        // effect through the ONE constructor path for all five engine
        // families — bit-identical Δv both ways, strictly more bytes_up
        // when forced dense (tiny H → sparse frames win), and for the
        // family where no effect is expected (MLlib ships fixed n-dim
        // payloads) byte-identical accounting.
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 4;
        let adaptive_opts = EngineOptions::default();
        let dense_opts = EngineOptions {
            dense_frames: true,
            ..Default::default()
        };
        for engine in Engine::FAMILIES {
            let mut adaptive = build_any(engine, &ds, &cfg, &adaptive_opts);
            let mut dense = build_any(engine, &ds, &cfg, &dense_opts);
            let (mut v1, mut v2) = (vec![0.0; ds.m()], vec![0.0; ds.m()]);
            let (mut up1, mut up2) = (0u64, 0u64);
            for round in 0..4 {
                let (dv1, t1) = adaptive.run_round(&v1, 2, round);
                let (dv2, t2) = dense.run_round(&v2, 2, round);
                for (a, b) in dv1.iter().zip(dv2.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{}", engine.label());
                }
                up1 += t1.bytes_up;
                up2 += t2.bytes_up;
                crate::linalg::add_assign(&mut v1, &dv1);
                crate::linalg::add_assign(&mut v2, &dv2);
            }
            assert!(
                up1 < up2,
                "{}: adaptive {} !< dense {}",
                engine.label(),
                up1,
                up2
            );
        }
        // Expected-no-difference case: MLlib's traffic is the n-dim weight
        // vector either way.
        let mllib = Engine::Impl(crate::config::Impl::MllibSgd);
        let mut a = build_any(mllib, &ds, &cfg, &adaptive_opts);
        let mut d = build_any(mllib, &ds, &cfg, &dense_opts);
        let v = vec![0.0; ds.m()];
        let (_, ta) = a.run_round(&v, 2, 1);
        let (_, td) = d.run_round(&v, 2, 1);
        assert_eq!(ta.bytes_up, td.bytes_up);
        assert_eq!(ta.bytes_down, td.bytes_down);
    }
}
