//! Spark (JVM) engine: implementations (A), (B), (B)\* and the MLlib-SGD
//! baseline, on the mini-RDD engine.
//!
//! Round = one Spark stage: `broadcast(shared) → mapPartitions(local solve)
//! → collect → driver reduce`. Costs charged per DESIGN.md §6:
//!
//! * (A) `spark`: managed Scala solver, record-layout partitions, α
//!   round-trips driver↔worker every stage (no persistent worker state);
//! * (B) `spark+c`: native solver behind a JNI call, **flat** partitions
//!   (one record per partition → per-record iteration cost collapses);
//! * (B)\*: (B) + persistent local memory (no α traffic) + meta-RDD
//!   (no partition records at all);
//! * `mllib-sgd`: one gradient step per round; communicates the full
//!   n-dimensional weight/gradient vectors (MLlib's pattern) instead of
//!   CoCoA's m-dimensional shared vector.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use super::chaos::{ChaosRuntime, RoundChaos};
use super::overhead::OverheadModel;
use super::rdd::{Rdd, SparkContext};
use super::serialization::{java_encoded_len, java_sparse_cutover, JavaSer};
use super::{DistEngine, EngineOptions, RoundTiming};
use crate::config::{Impl, TrainConfig};
use crate::data::{Dataset, Partitioning, WorkerData};
use crate::linalg::{self, DeltaReducer, DeltaSlot};
use crate::problem::Problem;
use crate::simnet::VirtualClock;
use crate::solver::{managed, scd, sgd, LocalSolver, SolveRequest};
use crate::util::pool::BytePool;

pub struct SparkEngine {
    imp: Impl,
    data: Rc<Vec<WorkerData>>,
    alpha: Rc<RefCell<Vec<Vec<f64>>>>,
    solvers: Rc<RefCell<Vec<Box<dyn LocalSolver>>>>,
    base: Rdd<usize>,
    #[allow(dead_code)]
    sc: SparkContext,
    model: OverheadModel,
    clock: VirtualClock,
    problem: Problem,
    sigma: f64,
    b: Rc<Vec<f64>>,
    n_total: usize,
    m: usize,
    /// Local sub-solvers per task (nested parallelism; DESIGN.md §10).
    /// Always 1 for MLlib — a gradient step has no sub-shards. `data`,
    /// `alpha`, `solvers` and `slots` then hold one entry per sub-shard
    /// (rank-major, `K·t`).
    t: usize,
    /// Flat K·t tree split into task-local and driver stages.
    plan: linalg::NestedTreePlan,
    /// Modeled intra-worker speedup of t sub-solvers per executor.
    speedup: f64,
    /// Records iterated per task (layout-dependent; see module docs).
    records_per_task: Vec<usize>,
    /// Columns per *rank* (sub-shard sizes summed) — the α-payload model.
    rank_n_locals: Vec<usize>,
    /// Virtual-clock multiplier applied to measured solver seconds.
    compute_multiplier: f64,
    /// Extra driver-side cost per round (py4j for the pySpark-driven MLlib).
    extra_round_fixed: f64,
    /// TorrentBroadcast (vs driver star) for the broadcast path.
    torrent: bool,
    /// Pooled serialization frames — the driver-side encode reuses one
    /// checked-out buffer per round instead of allocating a codec frame.
    frame_pool: BytePool,
    /// Per-worker Δv frames under the java-codec cutover (DESIGN.md §7)
    /// feeding the sparse-aware reduction tree; arenas persist.
    slots: Vec<DeltaSlot>,
    reducer: DeltaReducer,
    /// Chaos layer (DESIGN.md §12): heterogeneity, jitter, faults.
    chaos: Option<ChaosRuntime>,
}

impl SparkEngine {
    pub fn new(
        imp: Impl,
        ds: &Dataset,
        parts: &Partitioning,
        cfg: &TrainConfig,
        model: OverheadModel,
        opts: EngineOptions,
    ) -> SparkEngine {
        assert!(matches!(
            imp,
            Impl::SparkScala | Impl::SparkC | Impl::SparkCOpt | Impl::MllibSgd
        ));
        // Nested layout: t sub-shards per rank, the parts being the flat
        // K·t partitioning (DESIGN.md §10). MLlib's gradient step is not a
        // partitionable CoCoA subproblem — t is forced to 1 there.
        let t = if imp == Impl::MllibSgd {
            1
        } else {
            opts.threads_per_worker.max(1)
        };
        assert_eq!(
            parts.parts.len(),
            cfg.workers * t,
            "nested layout needs the flat K·t partitioning"
        );
        let data: Vec<WorkerData> = parts
            .parts
            .iter()
            .map(|cols| WorkerData::from_columns(&ds.a, cols))
            .collect();
        let n_shards = data.len();
        let k = n_shards / t;
        let alpha: Vec<Vec<f64>> = data.iter().map(|d| vec![0.0; d.n_local()]).collect();
        let rank_n_locals: Vec<usize> = (0..k)
            .map(|w| data[w * t..(w + 1) * t].iter().map(|d| d.n_local()).sum())
            .collect();

        let cal = super::calibration();
        let (solvers, compute_multiplier): (Vec<Box<dyn LocalSolver>>, f64) = match imp {
            Impl::SparkScala => {
                if opts.real_managed_compute {
                    (
                        (0..n_shards)
                            .map(|_| Box::new(managed::ScalaLikeScd::new()) as Box<dyn LocalSolver>)
                            .collect(),
                        1.0,
                    )
                } else {
                    (
                        (0..n_shards)
                            .map(|_| Box::new(scd::NativeScd::new()) as Box<dyn LocalSolver>)
                            .collect(),
                        cal.scala_multiplier,
                    )
                }
            }
            Impl::MllibSgd => (
                (0..n_shards)
                    .map(|_| {
                        Box::new(sgd::MiniBatchSgd::new(opts.sgd_step, opts.sgd_batch_fraction))
                            as Box<dyn LocalSolver>
                    })
                    .collect(),
                cal.scala_multiplier,
            ),
            _ => (
                (0..n_shards)
                    .map(|_| {
                        Box::new(scd::NativeScd::with_precision(cfg.precision))
                            as Box<dyn LocalSolver>
                    })
                    .collect(),
                1.0,
            ),
        };

        let layout = opts.force_layout.unwrap_or(match imp {
            // (A): one record per feature flows through the task iterator.
            Impl::SparkScala => super::LayoutOverride::Records,
            // (B): flattened partition = a single record.
            Impl::SparkC | Impl::MllibSgd => super::LayoutOverride::Flat,
            // (B)*: meta-RDD — the RDD carries only partition ids.
            Impl::SparkCOpt => super::LayoutOverride::Meta,
            _ => unreachable!(),
        });
        // One task per RANK: its iterator covers the rank's t sub-shards.
        let records_per_task: Vec<usize> = match layout {
            super::LayoutOverride::Records => rank_n_locals.clone(),
            super::LayoutOverride::Flat => vec![1; k],
            super::LayoutOverride::Meta => vec![0; k],
        };

        let sc = SparkContext::new();
        let base = sc.parallelize((0..k).map(|w| vec![w]).collect());
        base.cache();

        // MLlib is driven from pySpark in the paper's §5.4 comparison: one
        // py4j round trip per job submission.
        let extra_round_fixed = if imp == Impl::MllibSgd {
            model.py4j_roundtrip()
        } else {
            0.0
        };

        SparkEngine {
            imp,
            data: Rc::new(data),
            alpha: Rc::new(RefCell::new(alpha)),
            solvers: Rc::new(RefCell::new(solvers)),
            base,
            sc,
            speedup: model.intra_worker_speedup(t),
            model,
            clock: VirtualClock::new(),
            problem: cfg.problem,
            sigma: cfg.sigma_t(t),
            b: Rc::new(ds.b.clone()),
            n_total: ds.n(),
            m: ds.m(),
            t,
            plan: linalg::NestedTreePlan::new(k, t),
            records_per_task,
            rank_n_locals,
            compute_multiplier,
            extra_round_fixed,
            torrent: opts.torrent_broadcast,
            frame_pool: BytePool::with_buffers(1, java_encoded_len(ds.m())),
            slots: (0..n_shards).map(|_| DeltaSlot::new()).collect(),
            reducer: DeltaReducer::new(
                ds.m(),
                if opts.dense_frames {
                    0
                } else {
                    java_sparse_cutover(ds.m())
                },
            ),
            chaos: ChaosRuntime::from_opts(&opts, k),
        }
    }

    fn persistent(&self) -> bool {
        self.imp.has_persistent_local_state()
    }
}

impl DistEngine for SparkEngine {
    fn imp(&self) -> Impl {
        self.imp
    }

    fn num_workers(&self) -> usize {
        self.data.len() / self.t
    }

    fn threads_per_worker(&self) -> usize {
        self.t
    }

    fn n_locals(&self) -> Vec<usize> {
        self.data.iter().map(|d| d.n_local()).collect()
    }

    fn alpha_global(&self) -> Vec<f64> {
        let alpha = self.alpha.borrow();
        let mut out = vec![0.0; self.n_total];
        for (wd, al) in self.data.iter().zip(alpha.iter()) {
            for (&gid, &a) in wd.global_ids.iter().zip(al.iter()) {
                out[gid as usize] = a;
            }
        }
        out
    }

    fn load_alpha(&mut self, alpha_global: &[f64]) {
        super::scatter_alpha(&self.data, &mut self.alpha.borrow_mut(), alpha_global);
    }

    fn clock(&self) -> f64 {
        self.clock.now()
    }

    fn arm_chaos(&mut self, rc: RoundChaos) {
        if let Some(c) = self.chaos.as_mut() {
            c.arm(rc);
        }
    }

    fn run_round(&mut self, v: &[f64], h: usize, round_seed: u64) -> (Vec<f64>, RoundTiming) {
        let k = self.num_workers();
        let mllib = self.imp == Impl::MllibSgd;
        let rc = match self.chaos.as_mut() {
            Some(c) => c.take(),
            None => RoundChaos::default(),
        };
        // Per-round latency jitter on fixed/network costs; exactly 1.0
        // without chaos.
        let jm = self.chaos.as_ref().map(|c| c.jitter(round_seed)).unwrap_or(1.0);

        // ---- 1. Driver: serialize + broadcast shared state --------------
        // Real encode (byte counts + integrity), modeled time. The frame
        // buffer is checked out of the engine's pool: zero steady-state
        // allocations on the codec path (§Perf; util::pool).
        let mut v_frame = self.frame_pool.take_cleared();
        JavaSer::encode_into(v, &mut v_frame);
        debug_assert_eq!(JavaSer::decode_slice(&v_frame).unwrap().len(), v.len());
        let alpha_down_bytes: Vec<u64> = if self.persistent() {
            vec![0; k]
        } else if mllib {
            // MLlib broadcasts the full n-dim weight vector to every worker.
            vec![java_encoded_len(self.n_total) as u64; k]
        } else {
            // One α payload per task, covering the rank's t sub-shards.
            self.rank_n_locals
                .iter()
                .map(|&nl| java_encoded_len(nl) as u64)
                .collect()
        };
        let down_per_worker: Vec<u64> = alpha_down_bytes
            .iter()
            .map(|&ab| ab + if mllib { 0 } else { v_frame.len() as u64 })
            .collect();
        let bytes_down: u64 = down_per_worker.iter().sum();
        let t_ser_driver = self.model.java_ser(bytes_down);
        let t_net_down = if self.torrent {
            // Torrent: one (max-size) payload spreads peer-to-peer.
            let max_bytes = down_per_worker.iter().copied().max().unwrap_or(0);
            self.model.cluster.jittered(jm).torrent_broadcast(max_bytes, k)
        } else {
            self.model.cluster.jittered(jm).star_varied(&down_per_worker)
        };
        self.frame_pool.put(v_frame);

        // ---- 2. The stage: mapPartitions(local solve) over the RDD ------
        // One task per rank; a nested task runs its t sub-solvers (flat
        // ranks w·t..(w+1)·t — same seeds/σ′ as the flat K·t ring).
        let data = Rc::clone(&self.data);
        let alpha = Rc::clone(&self.alpha);
        let solvers = Rc::clone(&self.solvers);
        let b = Rc::clone(&self.b);
        let v_shared: Rc<Vec<f64>> = Rc::new(v.to_vec());
        let (problem, sigma) = (self.problem, self.sigma);
        let records_per_task = self.records_per_task.clone();
        let t = self.t;

        let job = self.base.map_partitions_indexed(move |p, ids, ctx| {
            let w = ids[0];
            debug_assert_eq!(p, w);
            ctx.read_records(records_per_task[w]);
            let mut out = Vec::with_capacity(t);
            for s in 0..t {
                let g = w * t + s;
                let req = SolveRequest {
                    v: &v_shared,
                    b: &b,
                    h,
                    problem: &problem,
                    sigma,
                    seed: round_seed ^ (g as u64).wrapping_mul(0x9E3779B97F4A7C15),
                };
                // The per-task α clone and owned result are deliberate:
                // vanilla Spark has no persistent worker buffers — every
                // task ships its state (that cost is the paper's point;
                // the zero-alloc path lives in the MPI/threaded engines).
                let alpha_g = alpha.borrow()[g].clone();
                #[allow(clippy::disallowed_methods)]
                // lint: allow(clock) -- real solve wall time feeds the cost model
                let t0 = Instant::now();
                let res = solvers.borrow_mut()[g].solve(&data[g], &alpha_g, &req);
                let secs = t0.elapsed().as_secs_f64();
                out.push((g, res, secs));
            }
            out
        });
        let (mut outs, stats) = job.collect_with_stats();
        debug_assert_eq!(stats.tasks, k);
        debug_assert_eq!(outs.len(), k * t);
        // Flat-rank order for the deterministic reduction tree below.
        outs.sort_by_key(|(g, _, _)| *g);

        // ---- 3. Per-task virtual times -----------------------------------
        let native_call = match self.imp {
            Impl::SparkC | Impl::SparkCOpt => self.model.jni_call(),
            _ => 0.0,
        };
        let mut task_times = vec![0.0; k];
        let mut computes = vec![0.0; k];
        let mut up_per_worker = vec![0u64; k];
        for (slot, (_, res, _)) in self.slots.iter_mut().zip(outs.iter()) {
            self.reducer.load(slot, &res.delta_v);
        }
        // Task-local stage: the within-block combines of the flat K·t tree
        // run inside the executor before anything is serialized
        // (DESIGN.md §10) — a flat round (t = 1) has no such pairs.
        for w in 0..k {
            self.reducer
                .reduce_pairs(&mut self.slots[w * t..(w + 1) * t], self.plan.local_pairs(w));
        }
        // Each task emits its forest roots as the cheaper of the
        // sparse/dense java frames (the codec really runs — the pooled
        // buffer below — and the model is charged the ACTUAL encoded
        // bytes).
        let mut up_frame = self.frame_pool.take_cleared();
        for w in 0..k {
            // t sub-solves share the executor's cores (DESIGN.md §10);
            // t = 1 divides by exactly 1.0.
            let solve_s: f64 = outs[w * t..(w + 1) * t]
                .iter()
                .map(|(_, _, secs)| *secs)
                .sum(); // lint: allow(bitexact) -- sums simulated seconds, not solver state
            let compute = solve_s * self.compute_multiplier / self.speedup;
            computes[w] = compute;
            let up = if mllib {
                java_encoded_len(self.n_total) as u64
            } else {
                let mut dv = 0u64;
                for &ri in self.plan.roots(w) {
                    let slot = &self.slots[w * t + ri];
                    JavaSer::encode_delta_into(slot, &mut up_frame);
                    debug_assert_eq!(
                        JavaSer::decode_delta_dense(&up_frame).unwrap(),
                        slot.densify_collect(self.m)
                    );
                    dv += up_frame.len() as u64;
                }
                let da = if self.persistent() {
                    0
                } else {
                    java_encoded_len(self.rank_n_locals[w]) as u64
                };
                dv + da
            };
            up_per_worker[w] = up;
            task_times[w] = self.model.spark_task_launch()
                + self.model.java_deser(down_per_worker[w])
                + self.model.record_iter_scala(self.records_per_task[w])
                + native_call * t as f64
                + compute
                + self.model.java_ser(up);
        }
        self.frame_pool.put(up_frame);

        // Chaos (DESIGN.md §12): heterogeneity / armed slowdowns drag each
        // rank's compute component; speculation races a clean backup
        // against the dragged original and pays the winner.
        if let Some(cr) = &self.chaos {
            let detect = self.model.fault_detect();
            for w in 0..k {
                let sped = cr.speculate(computes[w], cr.factor(&rc, w), detect);
                task_times[w] += sped - computes[w];
                computes[w] = sped;
            }
        }
        // Armed death: the dead rank's task never reports. The stage
        // aborts after the surviving tasks plus failure detection and
        // executor respawn — *nothing* reaches the α commit below, so the
        // session replays this round from its snapshot bit-exactly.
        if let Some(dead) = rc.death {
            computes[dead] = 0.0;
            task_times[dead] = 0.0;
            let t_worker = computes.iter().cloned().fold(0.0f64, f64::max);
            let t_tasks = task_times.iter().cloned().fold(0.0f64, f64::max);
            let t_fault = self.model.fault_detect() + self.model.respawn();
            let wall = self.model.spark_stage() * jm
                + self.extra_round_fixed
                + t_ser_driver
                + t_net_down
                + t_tasks
                + t_fault;
            self.clock.advance(wall);
            let timing = RoundTiming {
                t_worker,
                t_master: 0.0,
                t_overhead: (wall - t_worker).max(0.0),
                worker_compute: computes,
                bytes_up: 0,
                bytes_down,
            };
            return (vec![0.0; self.m], timing);
        }
        let bytes_up: u64 = up_per_worker.iter().sum();
        let t_tasks_max = task_times.iter().cloned().fold(0.0f64, f64::max);
        let t_worker = computes.iter().cloned().fold(0.0f64, f64::max);

        // ---- 4. Gather + driver aggregate --------------------------------
        let t_net_up = self.model.cluster.jittered(jm).star_varied(&up_per_worker);
        let t_deser_driver = self.model.java_deser(bytes_up);

        // Driver reduce: the cross-rank pairs of the same flat tree every
        // engine runs (Δv stays bit-identical across substrates whatever
        // mix of frame representations the tasks emitted), in place — no
        // zeroed m-vector accumulator; sparse pairs merge, growth past the
        // cutover promotes to dense.
        #[allow(clippy::disallowed_methods)]
        // lint: allow(clock) -- real solve wall time feeds the cost model
        let t0 = Instant::now();
        {
            let mut alpha = self.alpha.borrow_mut();
            for (g, res, _) in &outs {
                linalg::add_assign(&mut alpha[*g], &res.delta_alpha);
            }
        }
        self.reducer.reduce_pairs(&mut self.slots, self.plan.cross_pairs());
        let agg = self.slots[0].densify_collect(self.m);
        debug_assert_eq!(agg.len(), self.m);
        let t_master = t0.elapsed().as_secs_f64();

        // ---- 5. Compose the round on the virtual clock -------------------
        let wall = self.model.spark_stage() * jm
            + self.extra_round_fixed
            + t_ser_driver
            + t_net_down
            + t_tasks_max
            + t_net_up
            + t_deser_driver
            + t_master;
        self.clock.advance(wall);

        let timing = RoundTiming {
            t_worker,
            t_master,
            t_overhead: (wall - t_worker - t_master).max(0.0),
            worker_compute: computes,
            bytes_up,
            bytes_down,
        };
        (agg, timing)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{webspam_like, SyntheticSpec};
    use crate::data::Partitioner;

    fn engine(imp: Impl) -> (Dataset, SparkEngine) {
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 4;
        let parts = Partitioning::build(Partitioner::Range, &ds.a, 4, 0);
        let model = OverheadModel::paper_defaults(crate::simnet::ClusterModel::paper_testbed(1.0));
        let eng = SparkEngine::new(imp, &ds, &parts, &cfg, model, EngineOptions::default());
        (ds, eng)
    }

    #[test]
    fn round_aggregates_delta_v() {
        let (ds, mut eng) = engine(Impl::SparkC);
        let v0 = vec![0.0; ds.m()];
        let (dv, timing) = eng.run_round(&v0, 50, 1);
        assert_eq!(dv.len(), ds.m());
        assert!(dv.iter().any(|&x| x != 0.0));
        assert!(timing.wall() > 0.0);
        assert!(timing.bytes_up > 0 && timing.bytes_down > 0);
        // Aggregate must equal A·Δα over the assembled global update.
        let alpha = eng.alpha_global();
        let v_from_alpha = ds.shared_vector(&alpha);
        for (a, b) in dv.iter().zip(v_from_alpha.iter()) {
            assert!((a - b).abs() < 1e-9, "{} vs {}", a, b);
        }
    }

    #[test]
    fn persistent_variant_moves_fewer_bytes() {
        let (ds, mut eng_b) = engine(Impl::SparkC);
        let (_, mut eng_bstar) = engine(Impl::SparkCOpt);
        let v0 = vec![0.0; ds.m()];
        let (_, tb) = eng_b.run_round(&v0, 50, 1);
        let (_, tbs) = eng_bstar.run_round(&v0, 50, 1);
        assert!(
            tbs.bytes_down < tb.bytes_down,
            "B* down {} !< B down {}",
            tbs.bytes_down,
            tb.bytes_down
        );
        assert!(tbs.bytes_up < tb.bytes_up);
        assert!(tbs.t_overhead < tb.t_overhead);
    }

    #[test]
    fn identical_numerics_across_variants() {
        // (A), (B), (B)* run identical math — same seed, same Δv.
        let (ds, mut ea) = engine(Impl::SparkScala);
        let (_, mut eb) = engine(Impl::SparkC);
        let (_, mut ebs) = engine(Impl::SparkCOpt);
        let v0 = vec![0.0; ds.m()];
        let (dva, _) = ea.run_round(&v0, 30, 9);
        let (dvb, _) = eb.run_round(&v0, 30, 9);
        let (dvbs, _) = ebs.run_round(&v0, 30, 9);
        for ((a, b), c) in dva.iter().zip(dvb.iter()).zip(dvbs.iter()) {
            assert!((a - b).abs() < 1e-12);
            assert!((b - c).abs() < 1e-12);
        }
    }

    #[test]
    fn scala_variant_charges_multiplier() {
        let (ds, mut ea) = engine(Impl::SparkScala);
        let (_, mut eb) = engine(Impl::SparkC);
        let v0 = vec![0.0; ds.m()];
        let (_, ta) = ea.run_round(&v0, 200, 1);
        let (_, tb) = eb.run_round(&v0, 200, 1);
        assert!(
            ta.t_worker > tb.t_worker,
            "managed compute {} !> native {}",
            ta.t_worker,
            tb.t_worker
        );
    }

    #[test]
    fn mllib_moves_n_dimensional_payloads() {
        let (ds, mut em) = engine(Impl::MllibSgd);
        let (_, mut eb) = engine(Impl::SparkC);
        let v0 = vec![0.0; ds.m()];
        let (_, tm) = em.run_round(&v0, 0, 1);
        let (_, tb) = eb.run_round(&v0, 50, 1);
        // n = 256 vs m = 128 at this scale → heavier traffic for MLlib.
        assert!(tm.bytes_down > tb.bytes_down);
    }

    #[test]
    fn sparse_frames_cut_up_bytes_and_keep_bits() {
        // Small H → sparse Δv; (B)* has no α traffic, so bytes_up is the
        // pure Δv frame — the adaptive engine must charge strictly fewer
        // bytes while the aggregate stays BIT-identical.
        let (ds, mut adaptive) = engine(Impl::SparkCOpt);
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 4;
        let parts = Partitioning::build(Partitioner::Range, &ds.a, 4, 0);
        let model = OverheadModel::paper_defaults(crate::simnet::ClusterModel::paper_testbed(1.0));
        let mut dense = SparkEngine::new(
            Impl::SparkCOpt,
            &ds,
            &parts,
            &cfg,
            model,
            EngineOptions {
                dense_frames: true,
                ..Default::default()
            },
        );
        let v0 = vec![0.0; ds.m()];
        let (dv1, t1) = adaptive.run_round(&v0, 2, 1);
        let (dv2, t2) = dense.run_round(&v0, 2, 1);
        for (a, b) in dv1.iter().zip(dv2.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(
            t1.bytes_up < t2.bytes_up,
            "sparse {} !< dense {}",
            t1.bytes_up,
            t2.bytes_up
        );
    }

    fn chaos_engine(spec: &str) -> SparkEngine {
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 4;
        let parts = Partitioning::build(Partitioner::Range, &ds.a, 4, 0);
        let model = OverheadModel::paper_defaults(crate::simnet::ClusterModel::paper_testbed(1.0));
        let opts = EngineOptions {
            chaos: Some(
                crate::framework::chaos::ChaosSpec::parse(spec)
                    .unwrap()
                    .bind(4)
                    .unwrap(),
            ),
            ..Default::default()
        };
        SparkEngine::new(Impl::SparkC, &ds, &parts, &cfg, model, opts)
    }

    #[test]
    fn chaos_speculation_caps_straggler_and_keeps_bits() {
        let (ds, mut clean) = engine(Impl::SparkC);
        let mut dragged = chaos_engine("");
        let mut backed = chaos_engine("spec");
        let v0 = vec![0.0; ds.m()];
        // The factor must dwarf detect/base so the backup copy certainly
        // wins the race whatever the measured sub-ms solve time is.
        let slow = RoundChaos {
            death: None,
            slowdowns: vec![(2, 1e8)],
        };
        dragged.arm_chaos(slow.clone());
        backed.arm_chaos(slow);
        let (dv0, _) = clean.run_round(&v0, 50, 1);
        let (dv1, t1) = dragged.run_round(&v0, 50, 1);
        let (dv2, t2) = backed.run_round(&v0, 50, 1);
        // Speculation never changes the math — only who finishes first.
        for ((a, b), c) in dv0.iter().zip(dv1.iter()).zip(dv2.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
            assert_eq!(b.to_bits(), c.to_bits());
        }
        // The backup copy beats a 50× straggler by a wide margin.
        assert!(
            t2.worker_compute[2] < 0.5 * t1.worker_compute[2],
            "speculated {} !< dragged {}",
            t2.worker_compute[2],
            t1.worker_compute[2]
        );
        // A death on the same engines aborts with nothing committed.
        let alpha_before = backed.alpha_global();
        backed.arm_chaos(RoundChaos {
            death: Some(0),
            slowdowns: vec![],
        });
        let (dvd, td) = backed.run_round(&v0, 50, 2);
        assert!(dvd.iter().all(|&x| x == 0.0));
        assert_eq!(backed.alpha_global(), alpha_before);
        assert_eq!(td.bytes_up, 0);
    }

    #[test]
    fn clock_accumulates() {
        let (ds, mut eng) = engine(Impl::SparkC);
        let v0 = vec![0.0; ds.m()];
        assert_eq!(eng.clock(), 0.0);
        let (_, t1) = eng.run_round(&v0, 10, 1);
        let c1 = eng.clock();
        assert!((c1 - t1.wall()).abs() < 1e-12);
        let (_, t2) = eng.run_round(&v0, 10, 2);
        assert!((eng.clock() - t1.wall() - t2.wall()).abs() < 1e-12);
    }
}
