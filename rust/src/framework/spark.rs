//! Spark (JVM) engine: implementations (A), (B), (B)\* and the MLlib-SGD
//! baseline, on the mini-RDD engine.
//!
//! Round = one Spark stage: `broadcast(shared) → mapPartitions(local solve)
//! → collect → driver reduce`. Costs charged per DESIGN.md §6:
//!
//! * (A) `spark`: managed Scala solver, record-layout partitions, α
//!   round-trips driver↔worker every stage (no persistent worker state);
//! * (B) `spark+c`: native solver behind a JNI call, **flat** partitions
//!   (one record per partition → per-record iteration cost collapses);
//! * (B)\*: (B) + persistent local memory (no α traffic) + meta-RDD
//!   (no partition records at all);
//! * `mllib-sgd`: one gradient step per round; communicates the full
//!   n-dimensional weight/gradient vectors (MLlib's pattern) instead of
//!   CoCoA's m-dimensional shared vector.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

use super::overhead::OverheadModel;
use super::rdd::{Rdd, SparkContext};
use super::serialization::{java_encoded_len, java_sparse_cutover, JavaSer};
use super::{DistEngine, EngineOptions, RoundTiming};
use crate::config::{Impl, TrainConfig};
use crate::data::{Dataset, Partitioning, WorkerData};
use crate::linalg::{self, DeltaReducer, DeltaSlot};
use crate::problem::Problem;
use crate::simnet::VirtualClock;
use crate::solver::{managed, scd, sgd, LocalSolver, SolveRequest};
use crate::util::pool::BytePool;

pub struct SparkEngine {
    imp: Impl,
    data: Rc<Vec<WorkerData>>,
    alpha: Rc<RefCell<Vec<Vec<f64>>>>,
    solvers: Rc<RefCell<Vec<Box<dyn LocalSolver>>>>,
    base: Rdd<usize>,
    #[allow(dead_code)]
    sc: SparkContext,
    model: OverheadModel,
    clock: VirtualClock,
    problem: Problem,
    sigma: f64,
    b: Rc<Vec<f64>>,
    n_total: usize,
    m: usize,
    /// Records iterated per task (layout-dependent; see module docs).
    records_per_task: Vec<usize>,
    /// Virtual-clock multiplier applied to measured solver seconds.
    compute_multiplier: f64,
    /// Extra driver-side cost per round (py4j for the pySpark-driven MLlib).
    extra_round_fixed: f64,
    /// TorrentBroadcast (vs driver star) for the broadcast path.
    torrent: bool,
    /// Pooled serialization frames — the driver-side encode reuses one
    /// checked-out buffer per round instead of allocating a codec frame.
    frame_pool: BytePool,
    /// Per-worker Δv frames under the java-codec cutover (DESIGN.md §7)
    /// feeding the sparse-aware reduction tree; arenas persist.
    slots: Vec<DeltaSlot>,
    reducer: DeltaReducer,
}

impl SparkEngine {
    pub fn new(
        imp: Impl,
        ds: &Dataset,
        parts: &Partitioning,
        cfg: &TrainConfig,
        model: OverheadModel,
        opts: EngineOptions,
    ) -> SparkEngine {
        assert!(matches!(
            imp,
            Impl::SparkScala | Impl::SparkC | Impl::SparkCOpt | Impl::MllibSgd
        ));
        let data: Vec<WorkerData> = parts
            .parts
            .iter()
            .map(|cols| WorkerData::from_columns(&ds.a, cols))
            .collect();
        let k = data.len();
        let alpha: Vec<Vec<f64>> = data.iter().map(|d| vec![0.0; d.n_local()]).collect();

        let cal = super::calibration();
        let (solvers, compute_multiplier): (Vec<Box<dyn LocalSolver>>, f64) = match imp {
            Impl::SparkScala => {
                if opts.real_managed_compute {
                    (
                        (0..k)
                            .map(|_| Box::new(managed::ScalaLikeScd::new()) as Box<dyn LocalSolver>)
                            .collect(),
                        1.0,
                    )
                } else {
                    (
                        (0..k)
                            .map(|_| Box::new(scd::NativeScd::new()) as Box<dyn LocalSolver>)
                            .collect(),
                        cal.scala_multiplier,
                    )
                }
            }
            Impl::MllibSgd => (
                (0..k)
                    .map(|_| {
                        Box::new(sgd::MiniBatchSgd::new(opts.sgd_step, opts.sgd_batch_fraction))
                            as Box<dyn LocalSolver>
                    })
                    .collect(),
                cal.scala_multiplier,
            ),
            _ => (
                (0..k)
                    .map(|_| Box::new(scd::NativeScd::new()) as Box<dyn LocalSolver>)
                    .collect(),
                1.0,
            ),
        };

        let layout = opts.force_layout.unwrap_or(match imp {
            // (A): one record per feature flows through the task iterator.
            Impl::SparkScala => super::LayoutOverride::Records,
            // (B): flattened partition = a single record.
            Impl::SparkC | Impl::MllibSgd => super::LayoutOverride::Flat,
            // (B)*: meta-RDD — the RDD carries only partition ids.
            Impl::SparkCOpt => super::LayoutOverride::Meta,
            _ => unreachable!(),
        });
        let records_per_task: Vec<usize> = match layout {
            super::LayoutOverride::Records => data.iter().map(|d| d.n_local()).collect(),
            super::LayoutOverride::Flat => vec![1; k],
            super::LayoutOverride::Meta => vec![0; k],
        };

        let sc = SparkContext::new();
        let base = sc.parallelize((0..k).map(|w| vec![w]).collect());
        base.cache();

        // MLlib is driven from pySpark in the paper's §5.4 comparison: one
        // py4j round trip per job submission.
        let extra_round_fixed = if imp == Impl::MllibSgd {
            model.py4j_roundtrip()
        } else {
            0.0
        };

        SparkEngine {
            imp,
            data: Rc::new(data),
            alpha: Rc::new(RefCell::new(alpha)),
            solvers: Rc::new(RefCell::new(solvers)),
            base,
            sc,
            model,
            clock: VirtualClock::new(),
            problem: cfg.problem,
            sigma: cfg.sigma(),
            b: Rc::new(ds.b.clone()),
            n_total: ds.n(),
            m: ds.m(),
            records_per_task,
            compute_multiplier,
            extra_round_fixed,
            torrent: opts.torrent_broadcast,
            frame_pool: BytePool::with_buffers(1, java_encoded_len(ds.m())),
            slots: (0..k).map(|_| DeltaSlot::new()).collect(),
            reducer: DeltaReducer::new(
                ds.m(),
                if opts.dense_frames {
                    0
                } else {
                    java_sparse_cutover(ds.m())
                },
            ),
        }
    }

    fn persistent(&self) -> bool {
        self.imp.has_persistent_local_state()
    }
}

impl DistEngine for SparkEngine {
    fn imp(&self) -> Impl {
        self.imp
    }

    fn num_workers(&self) -> usize {
        self.data.len()
    }

    fn n_locals(&self) -> Vec<usize> {
        self.data.iter().map(|d| d.n_local()).collect()
    }

    fn alpha_global(&self) -> Vec<f64> {
        let alpha = self.alpha.borrow();
        let mut out = vec![0.0; self.n_total];
        for (wd, al) in self.data.iter().zip(alpha.iter()) {
            for (&gid, &a) in wd.global_ids.iter().zip(al.iter()) {
                out[gid as usize] = a;
            }
        }
        out
    }

    fn load_alpha(&mut self, alpha_global: &[f64]) {
        super::scatter_alpha(&self.data, &mut self.alpha.borrow_mut(), alpha_global);
    }

    fn clock(&self) -> f64 {
        self.clock.now()
    }

    fn run_round(&mut self, v: &[f64], h: usize, round_seed: u64) -> (Vec<f64>, RoundTiming) {
        let k = self.num_workers();
        let mllib = self.imp == Impl::MllibSgd;

        // ---- 1. Driver: serialize + broadcast shared state --------------
        // Real encode (byte counts + integrity), modeled time. The frame
        // buffer is checked out of the engine's pool: zero steady-state
        // allocations on the codec path (§Perf; util::pool).
        let mut v_frame = self.frame_pool.take_cleared();
        JavaSer::encode_into(v, &mut v_frame);
        debug_assert_eq!(JavaSer::decode_slice(&v_frame).unwrap().len(), v.len());
        let alpha_down_bytes: Vec<u64> = if self.persistent() {
            vec![0; k]
        } else if mllib {
            // MLlib broadcasts the full n-dim weight vector to every worker.
            vec![java_encoded_len(self.n_total) as u64; k]
        } else {
            self.data
                .iter()
                .map(|d| java_encoded_len(d.n_local()) as u64)
                .collect()
        };
        let down_per_worker: Vec<u64> = alpha_down_bytes
            .iter()
            .map(|&ab| ab + if mllib { 0 } else { v_frame.len() as u64 })
            .collect();
        let bytes_down: u64 = down_per_worker.iter().sum();
        let t_ser_driver = self.model.java_ser(bytes_down);
        let t_net_down = if self.torrent {
            // Torrent: one (max-size) payload spreads peer-to-peer.
            let max_bytes = down_per_worker.iter().copied().max().unwrap_or(0);
            self.model.cluster.torrent_broadcast(max_bytes, k)
        } else {
            self.model.cluster.star_varied(&down_per_worker)
        };
        self.frame_pool.put(v_frame);

        // ---- 2. The stage: mapPartitions(local solve) over the RDD ------
        let data = Rc::clone(&self.data);
        let alpha = Rc::clone(&self.alpha);
        let solvers = Rc::clone(&self.solvers);
        let b = Rc::clone(&self.b);
        let v_shared: Rc<Vec<f64>> = Rc::new(v.to_vec());
        let (problem, sigma) = (self.problem, self.sigma);
        let records_per_task = self.records_per_task.clone();

        let job = self.base.map_partitions_indexed(move |p, ids, ctx| {
            let w = ids[0];
            debug_assert_eq!(p, w);
            ctx.read_records(records_per_task[w]);
            let req = SolveRequest {
                v: &v_shared,
                b: &b,
                h,
                problem: &problem,
                sigma,
                seed: round_seed ^ (w as u64).wrapping_mul(0x9E3779B97F4A7C15),
            };
            // The per-task α clone and owned result are deliberate: vanilla
            // Spark has no persistent worker buffers — every task ships its
            // state (that cost is the paper's point; the zero-alloc path
            // lives in the MPI/threaded engines).
            let alpha_w = alpha.borrow()[w].clone();
            let t0 = Instant::now();
            let res = solvers.borrow_mut()[w].solve(&data[w], &alpha_w, &req);
            let secs = t0.elapsed().as_secs_f64();
            vec![(w, res, secs)]
        });
        let (mut outs, stats) = job.collect_with_stats();
        debug_assert_eq!(stats.tasks, k);
        // Rank order for the deterministic reduction tree below.
        outs.sort_by_key(|(w, _, _)| *w);

        // ---- 3. Per-task virtual times -----------------------------------
        let native_call = match self.imp {
            Impl::SparkC | Impl::SparkCOpt => self.model.jni_call(),
            _ => 0.0,
        };
        let mut task_times = vec![0.0; k];
        let mut computes = vec![0.0; k];
        let mut up_per_worker = vec![0u64; k];
        // Each task emits its Δv as the cheaper of the sparse/dense java
        // frames (the codec really runs — the pooled buffer below — and
        // the model is charged the ACTUAL encoded bytes), and the frame
        // lands in the worker's reduction slot.
        let mut up_frame = self.frame_pool.take_cleared();
        for (w, res, secs) in &outs {
            let compute = secs * self.compute_multiplier;
            computes[*w] = compute;
            self.reducer.load(&mut self.slots[*w], &res.delta_v);
            let up = if mllib {
                java_encoded_len(self.n_total) as u64
            } else {
                JavaSer::encode_delta_into(&self.slots[*w], &mut up_frame);
                debug_assert_eq!(
                    JavaSer::decode_delta_dense(&up_frame).unwrap(),
                    res.delta_v
                );
                let dv = up_frame.len() as u64;
                let da = if self.persistent() {
                    0
                } else {
                    java_encoded_len(res.delta_alpha.len()) as u64
                };
                dv + da
            };
            up_per_worker[*w] = up;
            task_times[*w] = self.model.spark_task_launch()
                + self.model.java_deser(down_per_worker[*w])
                + self.model.record_iter_scala(self.records_per_task[*w])
                + native_call
                + compute
                + self.model.java_ser(up);
        }
        self.frame_pool.put(up_frame);
        let bytes_up: u64 = up_per_worker.iter().sum();
        let t_tasks_max = task_times.iter().cloned().fold(0.0f64, f64::max);
        let t_worker = computes.iter().cloned().fold(0.0f64, f64::max);

        // ---- 4. Gather + driver aggregate --------------------------------
        let t_net_up = self.model.cluster.star_varied(&up_per_worker);
        let t_deser_driver = self.model.java_deser(bytes_up);

        // Driver reduce: the same pairwise tree as the MPI engines (Δv
        // stays bit-identical across substrates whatever mix of frame
        // representations the tasks emitted), in place — no zeroed
        // m-vector accumulator; sparse pairs merge, growth past the
        // cutover promotes to dense.
        let t0 = Instant::now();
        {
            let mut alpha = self.alpha.borrow_mut();
            for (w, res, _) in &outs {
                linalg::add_assign(&mut alpha[*w], &res.delta_alpha);
            }
        }
        let agg = self.reducer.reduce_collect(&mut self.slots);
        debug_assert_eq!(agg.len(), self.m);
        let t_master = t0.elapsed().as_secs_f64();

        // ---- 5. Compose the round on the virtual clock -------------------
        let wall = self.model.spark_stage()
            + self.extra_round_fixed
            + t_ser_driver
            + t_net_down
            + t_tasks_max
            + t_net_up
            + t_deser_driver
            + t_master;
        self.clock.advance(wall);

        let timing = RoundTiming {
            t_worker,
            t_master,
            t_overhead: (wall - t_worker - t_master).max(0.0),
            worker_compute: computes,
            bytes_up,
            bytes_down,
        };
        (agg, timing)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{webspam_like, SyntheticSpec};
    use crate::data::Partitioner;

    fn engine(imp: Impl) -> (Dataset, SparkEngine) {
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 4;
        let parts = Partitioning::build(Partitioner::Range, &ds.a, 4, 0);
        let model = OverheadModel::paper_defaults(crate::simnet::ClusterModel::paper_testbed(1.0));
        let eng = SparkEngine::new(imp, &ds, &parts, &cfg, model, EngineOptions::default());
        (ds, eng)
    }

    #[test]
    fn round_aggregates_delta_v() {
        let (ds, mut eng) = engine(Impl::SparkC);
        let v0 = vec![0.0; ds.m()];
        let (dv, timing) = eng.run_round(&v0, 50, 1);
        assert_eq!(dv.len(), ds.m());
        assert!(dv.iter().any(|&x| x != 0.0));
        assert!(timing.wall() > 0.0);
        assert!(timing.bytes_up > 0 && timing.bytes_down > 0);
        // Aggregate must equal A·Δα over the assembled global update.
        let alpha = eng.alpha_global();
        let v_from_alpha = ds.shared_vector(&alpha);
        for (a, b) in dv.iter().zip(v_from_alpha.iter()) {
            assert!((a - b).abs() < 1e-9, "{} vs {}", a, b);
        }
    }

    #[test]
    fn persistent_variant_moves_fewer_bytes() {
        let (ds, mut eng_b) = engine(Impl::SparkC);
        let (_, mut eng_bstar) = engine(Impl::SparkCOpt);
        let v0 = vec![0.0; ds.m()];
        let (_, tb) = eng_b.run_round(&v0, 50, 1);
        let (_, tbs) = eng_bstar.run_round(&v0, 50, 1);
        assert!(
            tbs.bytes_down < tb.bytes_down,
            "B* down {} !< B down {}",
            tbs.bytes_down,
            tb.bytes_down
        );
        assert!(tbs.bytes_up < tb.bytes_up);
        assert!(tbs.t_overhead < tb.t_overhead);
    }

    #[test]
    fn identical_numerics_across_variants() {
        // (A), (B), (B)* run identical math — same seed, same Δv.
        let (ds, mut ea) = engine(Impl::SparkScala);
        let (_, mut eb) = engine(Impl::SparkC);
        let (_, mut ebs) = engine(Impl::SparkCOpt);
        let v0 = vec![0.0; ds.m()];
        let (dva, _) = ea.run_round(&v0, 30, 9);
        let (dvb, _) = eb.run_round(&v0, 30, 9);
        let (dvbs, _) = ebs.run_round(&v0, 30, 9);
        for ((a, b), c) in dva.iter().zip(dvb.iter()).zip(dvbs.iter()) {
            assert!((a - b).abs() < 1e-12);
            assert!((b - c).abs() < 1e-12);
        }
    }

    #[test]
    fn scala_variant_charges_multiplier() {
        let (ds, mut ea) = engine(Impl::SparkScala);
        let (_, mut eb) = engine(Impl::SparkC);
        let v0 = vec![0.0; ds.m()];
        let (_, ta) = ea.run_round(&v0, 200, 1);
        let (_, tb) = eb.run_round(&v0, 200, 1);
        assert!(
            ta.t_worker > tb.t_worker,
            "managed compute {} !> native {}",
            ta.t_worker,
            tb.t_worker
        );
    }

    #[test]
    fn mllib_moves_n_dimensional_payloads() {
        let (ds, mut em) = engine(Impl::MllibSgd);
        let (_, mut eb) = engine(Impl::SparkC);
        let v0 = vec![0.0; ds.m()];
        let (_, tm) = em.run_round(&v0, 0, 1);
        let (_, tb) = eb.run_round(&v0, 50, 1);
        // n = 256 vs m = 128 at this scale → heavier traffic for MLlib.
        assert!(tm.bytes_down > tb.bytes_down);
    }

    #[test]
    fn sparse_frames_cut_up_bytes_and_keep_bits() {
        // Small H → sparse Δv; (B)* has no α traffic, so bytes_up is the
        // pure Δv frame — the adaptive engine must charge strictly fewer
        // bytes while the aggregate stays BIT-identical.
        let (ds, mut adaptive) = engine(Impl::SparkCOpt);
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 4;
        let parts = Partitioning::build(Partitioner::Range, &ds.a, 4, 0);
        let model = OverheadModel::paper_defaults(crate::simnet::ClusterModel::paper_testbed(1.0));
        let mut dense = SparkEngine::new(
            Impl::SparkCOpt,
            &ds,
            &parts,
            &cfg,
            model,
            EngineOptions {
                dense_frames: true,
                ..Default::default()
            },
        );
        let v0 = vec![0.0; ds.m()];
        let (dv1, t1) = adaptive.run_round(&v0, 2, 1);
        let (dv2, t2) = dense.run_round(&v0, 2, 1);
        for (a, b) in dv1.iter().zip(dv2.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(
            t1.bytes_up < t2.bytes_up,
            "sparse {} !< dense {}",
            t1.bytes_up,
            t2.bytes_up
        );
    }

    #[test]
    fn clock_accumulates() {
        let (ds, mut eng) = engine(Impl::SparkC);
        let v0 = vec![0.0; ds.m()];
        assert_eq!(eng.clock(), 0.0);
        let (_, t1) = eng.run_round(&v0, 10, 1);
        let c1 = eng.clock();
        assert!((c1 - t1.wall()).abs() < 1e-12);
        let (_, t2) = eng.run_round(&v0, 10, 2);
        assert!((eng.clock() - t1.wall() - t2.wall()).abs() < 1e-12);
    }
}
