//! Mini-RDD engine: the Spark programming model the paper's code runs on.
//!
//! A faithful, small re-implementation of the RDD abstraction (Zaharia et
//! al., NSDI'12) sufficient for the paper's workloads:
//!
//! * **lazy transformations** (`map`, `filter`, `map_partitions_indexed`) —
//!   nothing executes until an action; each transformation only records a
//!   closure and a parent pointer (the lineage);
//! * **actions** (`collect`, `reduce`, `count`) — run one *job* of one task
//!   per partition and report per-task statistics the engines convert into
//!   virtual-clock time;
//! * **lineage & fault tolerance** — an uncached RDD recomputes its chain
//!   from the source on every action (and after simulated partition loss),
//!   exactly like Spark; `cache()` memoizes per-partition results;
//! * **broadcast variables** — read-only values shipped to every task.
//!
//! The CoCoA-on-Spark engines (`spark.rs`, `pyspark.rs`) express each round
//! as `broadcast → map_partitions → collect`, so the structural costs the
//! paper attributes to Spark (stage per round, task per partition, records
//! iterated at task boundaries) are *counted by the engine that actually
//! runs the computation* rather than assumed.

use std::cell::{Cell, RefCell};
use std::rc::Rc;

/// Per-task runtime context handed to partition closures.
pub struct TaskContext {
    pub partition: usize,
    /// Records the closure pulled through the iterator boundary.
    records_read: Cell<usize>,
}

impl TaskContext {
    pub fn read_records(&self, n: usize) {
        self.records_read.set(self.records_read.get() + n);
    }
}

/// Statistics of one job (one action).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JobStats {
    pub tasks: usize,
    pub records_read: usize,
    /// Measured wall-clock seconds per task (real execution).
    pub task_seconds: Vec<f64>,
}

/// A broadcast variable (driver → every task, read-only).
#[derive(Clone)]
pub struct Broadcast<T> {
    value: Rc<T>,
}

impl<T> Broadcast<T> {
    pub fn value(&self) -> &T {
        &self.value
    }
}

type ComputeFn<T> = Rc<dyn Fn(usize, &TaskContext) -> Vec<T>>;

/// A resilient distributed dataset.
pub struct Rdd<T> {
    num_partitions: usize,
    compute: ComputeFn<T>,
    cache: Rc<RefCell<Vec<Option<Vec<T>>>>>,
    cached: Cell<bool>,
    /// Human-readable lineage for debugging/tests, e.g.
    /// `parallelize → map → mapPartitions`.
    lineage: String,
}

impl<T> Clone for Rdd<T> {
    fn clone(&self) -> Self {
        Rdd {
            num_partitions: self.num_partitions,
            compute: Rc::clone(&self.compute),
            cache: Rc::clone(&self.cache),
            cached: self.cached.clone(),
            lineage: self.lineage.clone(),
        }
    }
}

/// Driver-side context (creates RDDs and broadcasts).
#[derive(Default)]
pub struct SparkContext;

impl SparkContext {
    pub fn new() -> SparkContext {
        SparkContext
    }

    /// Create a source RDD from pre-partitioned data.
    pub fn parallelize<T: Clone + 'static>(&self, parts: Vec<Vec<T>>) -> Rdd<T> {
        let n = parts.len();
        let src = Rc::new(parts);
        Rdd {
            num_partitions: n,
            compute: Rc::new(move |p, _ctx| src[p].clone()),
            cache: Rc::new(RefCell::new((0..n).map(|_| None).collect())),
            cached: Cell::new(false),
            lineage: "parallelize".to_string(),
        }
    }

    pub fn broadcast<T>(&self, value: T) -> Broadcast<T> {
        Broadcast {
            value: Rc::new(value),
        }
    }
}

impl<T: Clone + 'static> Rdd<T> {
    pub fn num_partitions(&self) -> usize {
        self.num_partitions
    }

    pub fn lineage(&self) -> &str {
        &self.lineage
    }

    /// Partition data, honoring the cache, recomputing from lineage
    /// otherwise.
    fn partition_data(&self, p: usize, ctx: &TaskContext) -> Vec<T> {
        if self.cached.get() {
            if let Some(data) = &self.cache.borrow()[p] {
                return data.clone();
            }
        }
        let data = (self.compute)(p, ctx);
        if self.cached.get() {
            self.cache.borrow_mut()[p] = Some(data.clone());
        }
        data
    }

    /// Lazy element-wise transformation.
    pub fn map<U: Clone + 'static>(&self, f: impl Fn(&T) -> U + 'static) -> Rdd<U> {
        let parent = self.clone();
        let n = self.num_partitions;
        Rdd {
            num_partitions: n,
            compute: Rc::new(move |p, ctx| {
                let input = parent.partition_data(p, ctx);
                ctx.read_records(input.len());
                input.iter().map(&f).collect()
            }),
            cache: Rc::new(RefCell::new((0..n).map(|_| None).collect())),
            cached: Cell::new(false),
            lineage: format!("{} → map", self.lineage),
        }
    }

    /// Lazy filter.
    pub fn filter(&self, f: impl Fn(&T) -> bool + 'static) -> Rdd<T> {
        let parent = self.clone();
        let n = self.num_partitions;
        Rdd {
            num_partitions: n,
            compute: Rc::new(move |p, ctx| {
                let input = parent.partition_data(p, ctx);
                ctx.read_records(input.len());
                input.into_iter().filter(|x| f(x)).collect()
            }),
            cache: Rc::new(RefCell::new((0..n).map(|_| None).collect())),
            cached: Cell::new(false),
            lineage: format!("{} → filter", self.lineage),
        }
    }

    /// Lazy whole-partition transformation with partition index — the
    /// operation the paper's implementations build their local solve on
    /// (`mapPartitions` for (A)/(C)/(D), `map` over flat records for (B)).
    pub fn map_partitions_indexed<U: Clone + 'static>(
        &self,
        f: impl Fn(usize, Vec<T>, &TaskContext) -> Vec<U> + 'static,
    ) -> Rdd<U> {
        let parent = self.clone();
        let n = self.num_partitions;
        Rdd {
            num_partitions: n,
            compute: Rc::new(move |p, ctx| {
                let input = parent.partition_data(p, ctx);
                f(p, input, ctx)
            }),
            cache: Rc::new(RefCell::new((0..n).map(|_| None).collect())),
            cached: Cell::new(false),
            lineage: format!("{} → mapPartitions", self.lineage),
        }
    }

    /// Mark for caching (memoized on next action, like `persist()`).
    pub fn cache(&self) -> &Self {
        self.cached.set(true);
        self
    }

    /// Drop cached partitions (simulates executor loss → lineage recompute).
    pub fn unpersist(&self) {
        for slot in self.cache.borrow_mut().iter_mut() {
            *slot = None;
        }
    }

    /// ACTION: materialize all partitions, returning data + job stats.
    pub fn collect_with_stats(&self) -> (Vec<T>, JobStats) {
        let mut out = Vec::new();
        let mut stats = JobStats {
            tasks: self.num_partitions,
            ..Default::default()
        };
        for p in 0..self.num_partitions {
            let ctx = TaskContext {
                partition: p,
                records_read: Cell::new(0),
            };
            #[allow(clippy::disallowed_methods)]
            // lint: allow(clock) -- real solve wall time feeds the cost model
            let t0 = std::time::Instant::now();
            let data = self.partition_data(p, &ctx);
            stats.task_seconds.push(t0.elapsed().as_secs_f64());
            stats.records_read += ctx.records_read.get();
            out.extend(data);
        }
        (out, stats)
    }

    /// ACTION: collect without stats.
    pub fn collect(&self) -> Vec<T> {
        self.collect_with_stats().0
    }

    /// ACTION: element count.
    pub fn count(&self) -> usize {
        self.collect().len()
    }

    /// ACTION: fold all elements with `f` (requires at least one element).
    pub fn reduce(&self, f: impl Fn(T, T) -> T) -> Option<T> {
        self.collect().into_iter().reduce(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sc() -> SparkContext {
        SparkContext::new()
    }

    #[test]
    fn transformations_are_lazy() {
        let calls = Rc::new(Cell::new(0usize));
        let c2 = Rc::clone(&calls);
        let rdd = sc().parallelize(vec![vec![1, 2], vec![3]]);
        let mapped = rdd.map(move |x| {
            c2.set(c2.get() + 1);
            x * 10
        });
        assert_eq!(calls.get(), 0, "map must not execute before an action");
        let out = mapped.collect();
        assert_eq!(out, vec![10, 20, 30]);
        assert_eq!(calls.get(), 3);
    }

    #[test]
    fn lineage_recomputes_without_cache() {
        let calls = Rc::new(Cell::new(0usize));
        let c2 = Rc::clone(&calls);
        let rdd = sc().parallelize(vec![vec![1, 2, 3]]).map(move |x| {
            c2.set(c2.get() + 1);
            x + 1
        });
        rdd.collect();
        rdd.collect();
        assert_eq!(calls.get(), 6, "uncached RDD recomputes per action");
    }

    #[test]
    fn cache_memoizes_and_unpersist_recomputes() {
        let calls = Rc::new(Cell::new(0usize));
        let c2 = Rc::clone(&calls);
        let rdd = sc().parallelize(vec![vec![1, 2, 3]]).map(move |x| {
            c2.set(c2.get() + 1);
            x + 1
        });
        rdd.cache();
        assert_eq!(rdd.collect(), vec![2, 3, 4]);
        assert_eq!(rdd.collect(), vec![2, 3, 4]);
        assert_eq!(calls.get(), 3, "cached RDD computes once");
        // Simulated partition loss: recompute from lineage, same result.
        rdd.unpersist();
        assert_eq!(rdd.collect(), vec![2, 3, 4]);
        assert_eq!(calls.get(), 6);
    }

    #[test]
    fn map_partitions_sees_partition_index() {
        let rdd = sc().parallelize(vec![vec![1], vec![2], vec![3]]);
        let out = rdd
            .map_partitions_indexed(|p, data, _| vec![(p, data[0])])
            .collect();
        assert_eq!(out, vec![(0, 1), (1, 2), (2, 3)]);
    }

    #[test]
    fn actions_and_stats() {
        let rdd = sc().parallelize(vec![vec![1, 2], vec![3, 4, 5]]);
        assert_eq!(rdd.count(), 5);
        assert_eq!(rdd.reduce(|a, b| a + b), Some(15));
        let doubled = rdd.map(|x| x * 2);
        let (_, stats) = doubled.collect_with_stats();
        assert_eq!(stats.tasks, 2);
        assert_eq!(stats.records_read, 5);
        assert_eq!(stats.task_seconds.len(), 2);
    }

    #[test]
    fn filter_chain_and_lineage_string() {
        let rdd = sc()
            .parallelize(vec![(1..=10).collect::<Vec<i32>>()])
            .filter(|x| x % 2 == 0)
            .map(|x| x * x);
        assert_eq!(rdd.collect(), vec![4, 16, 36, 64, 100]);
        assert_eq!(rdd.lineage(), "parallelize → filter → map");
    }

    #[test]
    fn broadcast_shared_across_tasks() {
        let ctx = sc();
        let bc = ctx.broadcast(vec![10, 20, 30]);
        let rdd = ctx.parallelize(vec![vec![0usize, 1], vec![2]]);
        let bc2 = bc.clone();
        let out = rdd.map(move |&i| bc2.value()[i]).collect();
        assert_eq!(out, vec![10, 20, 30]);
    }

    #[test]
    fn empty_rdd() {
        let rdd = sc().parallelize(Vec::<Vec<i32>>::new());
        assert_eq!(rdd.count(), 0);
        assert_eq!(rdd.reduce(|a, b| a + b), None);
    }

    #[test]
    fn reduce_matches_cocoa_aggregation_shape() {
        // Vector-sum reduce — exactly the Δv aggregation of Algorithm 1.
        let parts: Vec<Vec<Vec<f64>>> = vec![
            vec![vec![1.0, 2.0]],
            vec![vec![10.0, 20.0]],
            vec![vec![100.0, 200.0]],
        ];
        let rdd = sc().parallelize(parts);
        let sum = rdd
            .reduce(|mut a, b| {
                crate::linalg::add_assign(&mut a, &b);
                a
            })
            .unwrap();
        assert_eq!(sum, vec![111.0, 222.0]);
    }
}
