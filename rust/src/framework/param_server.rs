//! Parameter-server substrate: the registry engine and the asynchronous
//! simulation baseline.
//!
//! The paper's §1/§2 contrasts synchronous schemes (its subject) with
//! parameter servers (Li et al. OSDI'14; Multiverso): workers push updates
//! against *stale* views of the shared state and never barrier.
//!
//! Two faces of the same math live here:
//!
//! * [`ParamServerEngine`] — the first-class [`DistEngine`] reachable from
//!   the unified registry (`Engine::ParamServer`). At staleness 0 it runs
//!   the synchronous round on the server's star topology and its Δv is
//!   **bit-identical** to the MPI engine (same solvers, same rank-ordered
//!   reduction tree) — the paper's central invariant extends to it. With
//!   staleness s > 0 workers compute against views `s` rounds old, every
//!   push damped by 1/(1+s).
//! * [`ParamServerSim`] — the free-running epoch simulation (pushes
//!   applied in arrival order, no aggregate handed back) used by the
//!   `sparkbench ablation async-ps` staleness sweep.
//!
//! Pushes ride the sparse layer in both: a worker ships its Δv as the raw
//! sparse frame when that is cheaper (DESIGN.md §7 cutover) and the cost
//! model is charged the actual frame bytes.

use std::collections::VecDeque;
use std::time::Instant;

use super::chaos::{ChaosRuntime, RoundChaos};
use super::overhead::OverheadModel;
use super::{DistEngine, Engine, EngineOptions, RoundTiming, WorkerSet};
use crate::config::{Impl, TrainConfig};
use crate::data::{Dataset, Partitioning, WorkerData};
use crate::linalg::{self, DeltaReducer, DeltaShape, DeltaSlot};
use crate::problem::Problem;
use crate::simnet::VirtualClock;
use crate::solver::{scd::NativeScd, LocalSolver, SolveRequest, SolveResult};

/// First-class parameter-server engine (see module docs).
pub struct ParamServerEngine {
    /// One entry per sub-shard (rank-major; `t = 1` = classic flat).
    ws: WorkerSet,
    solvers: Vec<NativeScd>,
    results: Vec<SolveResult>,
    slots: Vec<DeltaSlot>,
    reducer: DeltaReducer,
    /// Local sub-solvers per worker (nested parallelism; DESIGN.md §10).
    t: usize,
    /// Flat K·t tree split into rank-local and cross-rank stages.
    plan: linalg::NestedTreePlan,
    /// Modeled intra-worker speedup of t sub-solvers per rank.
    speedup: f64,
    model: OverheadModel,
    clock: VirtualClock,
    staleness: usize,
    /// 1/(1+staleness): the standard step-size correction that keeps
    /// bounded-staleness updates stable; exactly 1 at staleness 0.
    damping: f64,
    /// Ring of coordinator views (front = newest); workers read the view
    /// `staleness` rounds old. Buffers recycle — no steady-state allocs.
    history: VecDeque<Vec<f64>>,
    problem: Problem,
    sigma: f64,
    b: Vec<f64>,
    m: usize,
    /// Chaos layer (DESIGN.md §12): heterogeneity, jitter, faults.
    chaos: Option<ChaosRuntime>,
}

impl ParamServerEngine {
    pub fn new(
        ds: &Dataset,
        parts: &Partitioning,
        cfg: &TrainConfig,
        model: OverheadModel,
        staleness: usize,
        opts: &EngineOptions,
    ) -> ParamServerEngine {
        let t = opts.threads_per_worker.max(1);
        assert_eq!(
            parts.parts.len(),
            cfg.workers * t,
            "nested layout needs the flat K·t partitioning"
        );
        let ws = WorkerSet::build(ds, parts);
        let n_shards = ws.data.len();
        let cutover = if opts.dense_frames {
            0
        } else {
            linalg::raw_sparse_cutover(ds.m())
        };
        ParamServerEngine {
            solvers: (0..n_shards)
                .map(|_| NativeScd::with_precision(cfg.precision))
                .collect(),
            results: (0..n_shards).map(|_| SolveResult::default()).collect(),
            slots: (0..n_shards).map(|_| DeltaSlot::new()).collect(),
            reducer: DeltaReducer::new(ds.m(), cutover),
            t,
            plan: linalg::NestedTreePlan::new(cfg.workers, t),
            speedup: model.intra_worker_speedup(t),
            model,
            clock: VirtualClock::new(),
            staleness,
            damping: 1.0 / (1.0 + staleness as f64),
            history: VecDeque::with_capacity(staleness + 1),
            problem: cfg.problem,
            sigma: cfg.sigma_t(t),
            b: ds.b.clone(),
            m: ds.m(),
            chaos: ChaosRuntime::from_opts(opts, cfg.workers),
            ws,
        }
    }
}

impl DistEngine for ParamServerEngine {
    fn imp(&self) -> Impl {
        // Native ranks with persistent local state — the MPI column of the
        // paper's classification; `engine()` carries the registry identity.
        Impl::Mpi
    }

    fn engine(&self) -> Engine {
        Engine::ParamServer {
            staleness: self.staleness,
        }
    }

    fn num_workers(&self) -> usize {
        self.ws.data.len() / self.t
    }

    fn threads_per_worker(&self) -> usize {
        self.t
    }

    fn n_locals(&self) -> Vec<usize> {
        self.ws.n_locals()
    }

    fn alpha_global(&self) -> Vec<f64> {
        self.ws.alpha_global()
    }

    fn load_alpha(&mut self, alpha_global: &[f64]) {
        self.ws.load_alpha(alpha_global);
    }

    fn clock(&self) -> f64 {
        self.clock.now()
    }

    fn arm_chaos(&mut self, rc: RoundChaos) {
        if let Some(c) = self.chaos.as_mut() {
            c.arm(rc);
        }
    }

    fn run_round(&mut self, v: &[f64], h: usize, round_seed: u64) -> (Vec<f64>, RoundTiming) {
        let t = self.t;
        let k = self.num_workers();
        let n_shards = self.ws.data.len();
        let rc = match self.chaos.as_mut() {
            Some(c) => c.take(),
            None => RoundChaos::default(),
        };
        let jm = self.chaos.as_ref().map(|c| c.jitter(round_seed)).unwrap_or(1.0);

        // Read the view `staleness` rounds old. The fresh view is recorded
        // into the ring only when the round COMMITS (below), so a chaos-
        // aborted attempt leaves the ring exactly as it found it and the
        // replay sees the same stale views as an uninterrupted run. The
        // indexing is equivalent to pushing v first and reading entry
        // `staleness` of the grown ring.
        let view: &[f64] = if self.staleness == 0 || self.history.is_empty() {
            v
        } else {
            &self.history[(self.staleness - 1).min(self.history.len() - 1)]
        };

        // ---- 1. local solves against the (possibly stale) view ----------
        // Sub-shard g is rank g of the flat K·t ring (seed, σ′, columns).
        // A dead rank's sub-solves never happen.
        let mut sub_computes = vec![0.0; n_shards];
        for g in 0..n_shards {
            if rc.death == Some(g / t) {
                continue;
            }
            let req = SolveRequest {
                v: view,
                b: &self.b,
                h,
                problem: &self.problem,
                sigma: self.sigma,
                seed: round_seed ^ (g as u64).wrapping_mul(0x9E3779B97F4A7C15),
            };
            #[allow(clippy::disallowed_methods)]
            // lint: allow(clock) -- real solve wall time feeds the cost model
            let t0 = Instant::now();
            self.solvers[g].solve_into(
                &self.ws.data[g],
                &self.ws.alpha[g],
                &req,
                &mut self.results[g],
            );
            sub_computes[g] = t0.elapsed().as_secs_f64();
        }
        // t sub-solvers share the worker's cores (DESIGN.md §10).
        let mut computes = vec![0.0; k];
        for w in 0..k {
            // lint: allow(bitexact) -- sums simulated seconds for the cost model, not solver state
            computes[w] = sub_computes[w * t..(w + 1) * t].iter().sum::<f64>() / self.speedup;
        }
        // Chaos (DESIGN.md §12): heterogeneity / armed slowdowns drag each
        // rank's push; speculation races a clean backup against the drag.
        if let Some(cr) = &self.chaos {
            let detect = self.model.fault_detect();
            for (w, c) in computes.iter_mut().enumerate() {
                *c = cr.speculate(*c, cr.factor(&rc, w), detect);
            }
        }
        let t_worker = computes.iter().cloned().fold(0.0f64, f64::max);
        // Armed death: the server times out waiting on the dead worker's
        // push and the round aborts with nothing committed — no damping,
        // no α update, no ring push. The session replays from its
        // snapshot; the replay reads the same stale views as a clean run.
        if rc.death.is_some() {
            let t_fault = self.model.fault_detect() + self.model.respawn();
            let wall = t_worker + t_fault;
            self.clock.advance(wall);
            let timing = RoundTiming {
                t_worker,
                t_master: 0.0,
                t_overhead: t_fault,
                worker_compute: computes,
                bytes_up: 0,
                bytes_down: 0,
            };
            return (vec![0.0; self.m], timing);
        }

        // Commit path: record the fresh coordinator view (ring recycles
        // the evicted buffer).
        let mut snap = if self.history.len() > self.staleness {
            self.history.pop_back().unwrap()
        } else {
            Vec::with_capacity(self.m)
        };
        snap.clear();
        snap.extend_from_slice(v);
        self.history.push_front(snap);

        // ---- 2. damped pushes + server-side tree reduce ------------------
        // Damping is skipped entirely at staleness 0 so the synchronous
        // mode stays bit-identical to the MPI engine's round.
        #[allow(clippy::disallowed_methods)]
        // lint: allow(clock) -- real solve wall time feeds the cost model
        let t0 = Instant::now();
        if self.damping != 1.0 {
            for res in self.results.iter_mut() {
                for x in res.delta_alpha.iter_mut() {
                    *x *= self.damping;
                }
                for x in res.delta_v.iter_mut() {
                    *x *= self.damping;
                }
            }
        }
        for (al, res) in self.ws.alpha.iter_mut().zip(self.results.iter()) {
            linalg::add_assign(al, &res.delta_alpha);
        }
        for (slot, res) in self.slots.iter_mut().zip(self.results.iter()) {
            self.reducer.load(slot, &res.delta_v);
        }
        // Rank-local combines of the flat K·t tree run inside the worker;
        // only the forest roots are pushed to the server (DESIGN.md §10).
        for w in 0..k {
            self.reducer
                .reduce_pairs(&mut self.slots[w * t..(w + 1) * t], self.plan.local_pairs(w));
        }
        let mut up_per_worker = vec![0u64; k];
        for (w, up) in up_per_worker.iter_mut().enumerate() {
            for &ri in self.plan.roots(w) {
                *up += self.slots[w * t + ri].raw_bytes(self.m) as u64;
            }
        }
        self.reducer.reduce_pairs(&mut self.slots, self.plan.cross_pairs());
        let agg = self.slots[0].densify_collect(self.m);
        let t_master = t0.elapsed().as_secs_f64();

        // ---- 3. server star topology on the virtual clock ----------------
        // Pushes gather on the server's NIC; the merged view fans back out.
        // No barrier term: the PS removes the synchronization gap — that is
        // its entire pitch (§1) — so overhead is pure transfer.
        let bytes_up: u64 = up_per_worker.iter().sum();
        let bytes_down = (self.m * 8 * k) as u64;
        let net = self.model.cluster.jittered(jm);
        let t_push = net.star_varied(&up_per_worker);
        let t_pull = net.star_broadcast((self.m * 8) as u64, k);

        let wall = t_worker + t_master + t_push + t_pull;
        self.clock.advance(wall);

        let timing = RoundTiming {
            t_worker,
            t_master,
            t_overhead: t_push + t_pull,
            worker_compute: computes,
            bytes_up,
            bytes_down,
        };
        (agg, timing)
    }
}

/// Simulated asynchronous parameter server running CoCoA-style updates.
pub struct ParamServerSim {
    workers: Vec<WorkerData>,
    alphas: Vec<Vec<f64>>,
    solvers: Vec<NativeScd>,
    /// Authoritative shared vector at the server.
    v: Vec<f64>,
    /// Ring of historical v snapshots (index 0 = newest).
    history: VecDeque<Vec<f64>>,
    /// How many epochs old the view a worker computes against is.
    pub staleness: usize,
    problem: Problem,
    sigma: f64,
    b: Vec<f64>,
    epoch: u64,
    /// Staleness-aware damping 1/(1+s) applied to every push (the standard
    /// step-size correction that keeps bounded-staleness updates stable;
    /// identity at s = 0).
    damping: f64,
    /// Reused stale-view scratch (copy of the historical v the workers
    /// read this epoch; zero-alloc steady state).
    view_buf: Vec<f64>,
    /// Per-worker reused round results (`solve_into` targets).
    results: Vec<SolveResult>,
    /// Per-worker push frames (sparse when cheaper; arenas persist).
    push_slots: Vec<DeltaSlot>,
    /// Raw-frame cutover for pushes (see `linalg::raw_sparse_cutover`).
    cutover_nnz: usize,
    /// Actual Δv bytes pushed to the server so far (raw frame sizes).
    pub bytes_pushed: u64,
}

impl ParamServerSim {
    pub fn new(ds: &Dataset, parts: &Partitioning, cfg: &TrainConfig, staleness: usize) -> Self {
        let workers: Vec<WorkerData> = parts
            .parts
            .iter()
            .map(|cols| WorkerData::from_columns(&ds.a, cols))
            .collect();
        let alphas = workers.iter().map(|w| vec![0.0; w.n_local()]).collect();
        let solvers = (0..workers.len())
            .map(|_| NativeScd::with_precision(cfg.precision))
            .collect();
        let v = vec![0.0; ds.m()];
        let mut history = VecDeque::with_capacity(staleness + 1);
        history.push_front(v.clone());
        let k = workers.len();
        ParamServerSim {
            workers,
            alphas,
            solvers,
            v,
            history,
            staleness,
            problem: cfg.problem,
            sigma: cfg.sigma(),
            b: ds.b.clone(),
            epoch: 0,
            damping: 1.0 / (1.0 + staleness as f64),
            view_buf: Vec::with_capacity(ds.m()),
            results: (0..k).map(|_| SolveResult::default()).collect(),
            push_slots: (0..k).map(|_| DeltaSlot::new()).collect(),
            cutover_nnz: linalg::raw_sparse_cutover(ds.m()),
            bytes_pushed: 0,
        }
    }

    /// One epoch: every worker computes H steps against its stale view;
    /// the server applies the pushes in arrival order (no barrier — the
    /// virtual-time benefit is that the epoch costs max(compute) with no
    /// synchronization gap, which the caller accounts for).
    pub fn run_epoch(&mut self, h: usize, seed: u64) {
        // Copy the stale view into the reused scratch (no per-epoch clone).
        let idx = self.staleness.min(self.history.len() - 1);
        self.view_buf.clear();
        self.view_buf.extend_from_slice(&self.history[idx]);
        for w in 0..self.workers.len() {
            let req = SolveRequest {
                v: &self.view_buf,
                b: &self.b,
                h,
                problem: &self.problem,
                sigma: self.sigma,
                seed: seed ^ (w as u64).wrapping_mul(0x9E3779B97F4A7C15),
            };
            self.solvers[w].solve_into(&self.workers[w], &self.alphas[w], &req, &mut self.results[w]);
            // Push: applied immediately at the server (arrival order),
            // damped by 1/(1+staleness) to keep stale updates stable. The
            // worker ships whichever raw frame is cheaper; the server
            // applies sparse pushes entry-wise (same multiplies and adds
            // the dense axpy performs at those indices).
            linalg::axpy(self.damping, &self.results[w].delta_alpha, &mut self.alphas[w]);
            let slot = &mut self.push_slots[w];
            slot.fill_from_dense(&self.results[w].delta_v, self.cutover_nnz);
            self.bytes_pushed += slot.raw_bytes(self.v.len()) as u64;
            match slot.shape() {
                DeltaShape::Sparse => {
                    let sv = slot.sparse().unwrap();
                    for (&i, &x) in sv.idx.iter().zip(sv.vals.iter()) {
                        self.v[i as usize] += self.damping * x;
                    }
                }
                DeltaShape::Dense => {
                    linalg::axpy(self.damping, slot.dense().unwrap(), &mut self.v);
                }
            }
        }
        // Ring update: recycle the evicted snapshot buffer instead of
        // allocating a fresh clone of v every epoch.
        let mut snap = if self.history.len() > self.staleness {
            self.history.pop_back().unwrap()
        } else {
            Vec::with_capacity(self.v.len())
        };
        snap.clear();
        snap.extend_from_slice(&self.v);
        self.history.push_front(snap);
        self.epoch += 1;
    }

    pub fn alpha_global(&self, n: usize) -> Vec<f64> {
        let mut out = vec![0.0; n];
        for (wd, al) in self.workers.iter().zip(self.alphas.iter()) {
            for (&gid, &a) in wd.global_ids.iter().zip(al.iter()) {
                out[gid as usize] = a;
            }
        }
        out
    }

    /// Epochs to reach `target` suboptimality (None if `max_epochs` hit).
    pub fn epochs_to_target(
        &mut self,
        ds: &Dataset,
        fstar: f64,
        target: f64,
        h: usize,
        max_epochs: usize,
    ) -> Option<usize> {
        for e in 0..max_epochs {
            self.run_epoch(h, e as u64);
            let alpha = self.alpha_global(ds.n());
            let f = self.problem.primal(ds, &alpha);
            if crate::coordinator::suboptimality(f, fstar) <= target {
                return Some(e + 1);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{webspam_like, SyntheticSpec};
    use crate::data::Partitioner;
    use crate::framework::DistEngine;
    
    fn setup() -> (Dataset, TrainConfig, Partitioning) {
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 4;
        let parts = Partitioning::build(Partitioner::Range, &ds.a, 4, 0);
        (ds, cfg, parts)
    }

    #[test]
    fn zero_staleness_equals_synchronous_engine() {
        let (ds, cfg, parts) = setup();
        let mut ps = ParamServerSim::new(&ds, &parts, &cfg, 0);
        // Same partitioning for both sides (build_engine would re-partition
        // with the config default).
        let mut sync = crate::framework::mpi::MpiEngine::build(&ds, &parts, &cfg);
        let mut v_sync = vec![0.0; ds.m()];
        for round in 0..5 {
            ps.run_epoch(40, round);
            let (dv, _) = sync.run_round(&v_sync, 40, round);
            linalg::add_assign(&mut v_sync, &dv);
        }
        let a_ps = ps.alpha_global(ds.n());
        let a_sync = sync.alpha_global();
        for (x, y) in a_ps.iter().zip(a_sync.iter()) {
            assert!((x - y).abs() < 1e-12, "{} vs {}", x, y);
        }
    }

    #[test]
    fn converges_under_bounded_staleness() {
        let (ds, cfg, parts) = setup();
        let fstar = crate::coordinator::oracle_objective(&ds, &cfg);
        let mut ps = ParamServerSim::new(&ds, &parts, &cfg, 2);
        let reached = ps.epochs_to_target(&ds, fstar, 1e-3, 64, 20_000);
        assert!(reached.is_some(), "stale-2 PS failed to converge");
    }

    #[test]
    fn staleness_costs_epochs() {
        let (ds, cfg, parts) = setup();
        let fstar = crate::coordinator::oracle_objective(&ds, &cfg);
        let epochs_at = |s: usize| -> usize {
            let mut ps = ParamServerSim::new(&ds, &parts, &cfg, s);
            ps.epochs_to_target(&ds, fstar, 1e-2, 64, 5000)
                .unwrap_or(usize::MAX)
        };
        let fresh = epochs_at(0);
        let stale = epochs_at(4);
        assert!(
            stale >= fresh,
            "staleness should not accelerate per-epoch progress: {} vs {}",
            stale,
            fresh
        );
    }

    #[test]
    fn sparse_pushes_charge_fewer_bytes_and_match_dense() {
        let (ds, cfg, parts) = setup();
        let mut sparse_ps = ParamServerSim::new(&ds, &parts, &cfg, 1);
        let mut dense_ps = ParamServerSim::new(&ds, &parts, &cfg, 1);
        dense_ps.cutover_nnz = 0; // force dense pushes
        for e in 0..5 {
            sparse_ps.run_epoch(2, e); // tiny H → sparse Δv
            dense_ps.run_epoch(2, e);
        }
        assert!(
            sparse_ps.bytes_pushed < dense_ps.bytes_pushed,
            "sparse {} !< dense {}",
            sparse_ps.bytes_pushed,
            dense_ps.bytes_pushed
        );
        // The applied updates are the same multiplies/adds → identical v.
        for (a, b) in sparse_ps.v.iter().zip(dense_ps.v.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn history_ring_is_bounded() {
        let (ds, cfg, parts) = setup();
        let mut ps = ParamServerSim::new(&ds, &parts, &cfg, 3);
        for e in 0..10 {
            ps.run_epoch(8, e);
        }
        assert!(ps.history.len() <= 4);
    }

    fn default_model() -> OverheadModel {
        OverheadModel::paper_defaults(crate::simnet::ClusterModel::paper_testbed(1.0))
    }

    #[test]
    fn synchronous_engine_matches_mpi_bitwise() {
        // The registry engine at staleness 0 IS the synchronous round:
        // same solvers, same rank-ordered reduction tree ⇒ bit-identical
        // Δv to the MPI engine, round after round.
        let (ds, cfg, parts) = setup();
        let mut ps = ParamServerEngine::new(
            &ds,
            &parts,
            &cfg,
            default_model(),
            0,
            &EngineOptions::default(),
        );
        let mut mpi = crate::framework::mpi::MpiEngine::build(&ds, &parts, &cfg);
        let mut v1 = vec![0.0; ds.m()];
        let mut v2 = vec![0.0; ds.m()];
        for round in 0..5 {
            let (dv1, t1) = ps.run_round(&v1, 40, round);
            let (dv2, _) = mpi.run_round(&v2, 40, round);
            for (a, b) in dv1.iter().zip(dv2.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "round {}", round);
            }
            assert!(t1.bytes_up > 0 && t1.t_overhead > 0.0);
            linalg::add_assign(&mut v1, &dv1);
            linalg::add_assign(&mut v2, &dv2);
        }
        let a1 = ps.alpha_global();
        let a2 = mpi.alpha_global();
        for (x, y) in a1.iter().zip(a2.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn stale_engine_damps_and_diverges_from_sync() {
        let (ds, cfg, parts) = setup();
        let opts = EngineOptions::default();
        let mut stale = ParamServerEngine::new(&ds, &parts, &cfg, default_model(), 2, &opts);
        let mut sync = ParamServerEngine::new(&ds, &parts, &cfg, default_model(), 0, &opts);
        assert_eq!(stale.engine(), Engine::ParamServer { staleness: 2 });
        let mut v1 = vec![0.0; ds.m()];
        let mut v2 = vec![0.0; ds.m()];
        let mut diverged = false;
        for round in 0..6 {
            let (dv1, _) = stale.run_round(&v1, 40, round);
            let (dv2, _) = sync.run_round(&v2, 40, round);
            diverged |= dv1.iter().zip(dv2.iter()).any(|(a, b)| a != b);
            linalg::add_assign(&mut v1, &dv1);
            linalg::add_assign(&mut v2, &dv2);
        }
        assert!(diverged, "staleness-2 engine behaved like the sync engine");
        // Ring is bounded by staleness + 1.
        assert!(stale.history.len() <= 3);
        // Objective still decreases under bounded staleness + damping.
        let zero = vec![0.0; ds.n()];
        let f0 = cfg.problem.primal(&ds, &zero);
        let f = cfg.problem.primal(&ds, &stale.alpha_global());
        assert!(f < f0, "{} !< {}", f, f0);
    }

    #[test]
    fn chaos_death_leaves_stale_ring_consistent() {
        // The hard case: staleness > 0. A death-aborted attempt must leave
        // the view ring untouched, so the replayed trajectory stays
        // bit-identical to an uninterrupted stale run.
        let (ds, cfg, parts) = setup();
        let opts = EngineOptions {
            chaos: Some(
                crate::framework::chaos::ChaosSpec::parse("het=0.4,jitter=0.2")
                    .unwrap()
                    .bind(4)
                    .unwrap(),
            ),
            ..Default::default()
        };
        let mut clean =
            ParamServerEngine::new(&ds, &parts, &cfg, default_model(), 2, &EngineOptions::default());
        let mut chaotic = ParamServerEngine::new(&ds, &parts, &cfg, default_model(), 2, &opts);
        let mut v1 = vec![0.0; ds.m()];
        let mut v2 = vec![0.0; ds.m()];
        for round in 0..4 {
            if round == 2 {
                // Failed attempt first: worker 1 dies, nothing commits.
                let alpha_before = chaotic.alpha_global();
                let ring_before = chaotic.history.clone();
                chaotic.arm_chaos(RoundChaos {
                    death: Some(1),
                    slowdowns: vec![(3, 7.0)],
                });
                let (dvd, td) = chaotic.run_round(&v2, 30, round);
                assert!(dvd.iter().all(|&x| x == 0.0));
                assert_eq!(chaotic.alpha_global(), alpha_before);
                assert_eq!(chaotic.history, ring_before);
                assert_eq!(td.bytes_up, 0);
            }
            let (dv1, _) = clean.run_round(&v1, 30, round);
            let (dv2, _) = chaotic.run_round(&v2, 30, round);
            for (a, b) in dv1.iter().zip(dv2.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "round {}", round);
            }
            linalg::add_assign(&mut v1, &dv1);
            linalg::add_assign(&mut v2, &dv2);
        }
        let a1 = clean.alpha_global();
        let a2 = chaotic.alpha_global();
        for (x, y) in a1.iter().zip(a2.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn engine_load_alpha_roundtrips() {
        let (ds, cfg, parts) = setup();
        let mut ps = ParamServerEngine::new(
            &ds,
            &parts,
            &cfg,
            default_model(),
            0,
            &EngineOptions::default(),
        );
        let snapshot: Vec<f64> = (0..ds.n()).map(|i| (i as f64).sin()).collect();
        ps.load_alpha(&snapshot);
        assert_eq!(ps.alpha_global(), snapshot);
    }
}
