//! Asynchronous parameter-server baseline (simulated).
//!
//! The paper's §1/§2 contrasts synchronous schemes (its subject) with
//! parameter servers (Li et al. OSDI'14; Multiverso): workers push updates
//! against *stale* views of the shared state and never barrier. We build
//! the simulation the comparison implies: a server holding `v`, workers
//! computing CoCoA-style local updates against snapshots that are
//! `staleness` rounds old, updates applied in arrival order. With
//! staleness 0 this reduces exactly to the synchronous engine (tested);
//! growing staleness trades per-round progress for removed barriers —
//! quantified by `sparkbench ablation async-ps`.
//!
//! Pushes ride the sparse layer too: a worker ships its Δv as the raw
//! sparse frame when that is cheaper (DESIGN.md §7 cutover) and the
//! server applies the damped update straight from the sparse entries;
//! `bytes_pushed` accounts the actual frame bytes.

use std::collections::VecDeque;

use crate::config::TrainConfig;
use crate::data::{Dataset, Partitioning, WorkerData};
use crate::linalg::{self, DeltaShape, DeltaSlot};
use crate::solver::{scd::NativeScd, LocalSolver, SolveRequest, SolveResult};

/// Simulated asynchronous parameter server running CoCoA-style updates.
pub struct ParamServerSim {
    workers: Vec<WorkerData>,
    alphas: Vec<Vec<f64>>,
    solvers: Vec<NativeScd>,
    /// Authoritative shared vector at the server.
    v: Vec<f64>,
    /// Ring of historical v snapshots (index 0 = newest).
    history: VecDeque<Vec<f64>>,
    /// How many epochs old the view a worker computes against is.
    pub staleness: usize,
    lam_n: f64,
    eta: f64,
    sigma: f64,
    b: Vec<f64>,
    epoch: u64,
    /// Staleness-aware damping 1/(1+s) applied to every push (the standard
    /// step-size correction that keeps bounded-staleness updates stable;
    /// identity at s = 0).
    damping: f64,
    /// Reused stale-view scratch (copy of the historical v the workers
    /// read this epoch; zero-alloc steady state).
    view_buf: Vec<f64>,
    /// Per-worker reused round results (`solve_into` targets).
    results: Vec<SolveResult>,
    /// Per-worker push frames (sparse when cheaper; arenas persist).
    push_slots: Vec<DeltaSlot>,
    /// Raw-frame cutover for pushes (see `linalg::raw_sparse_cutover`).
    cutover_nnz: usize,
    /// Actual Δv bytes pushed to the server so far (raw frame sizes).
    pub bytes_pushed: u64,
}

impl ParamServerSim {
    pub fn new(ds: &Dataset, parts: &Partitioning, cfg: &TrainConfig, staleness: usize) -> Self {
        let workers: Vec<WorkerData> = parts
            .parts
            .iter()
            .map(|cols| WorkerData::from_columns(&ds.a, cols))
            .collect();
        let alphas = workers.iter().map(|w| vec![0.0; w.n_local()]).collect();
        let solvers = (0..workers.len()).map(|_| NativeScd::new()).collect();
        let v = vec![0.0; ds.m()];
        let mut history = VecDeque::with_capacity(staleness + 1);
        history.push_front(v.clone());
        let k = workers.len();
        ParamServerSim {
            workers,
            alphas,
            solvers,
            v,
            history,
            staleness,
            lam_n: cfg.lam_n,
            eta: cfg.eta,
            sigma: cfg.sigma(),
            b: ds.b.clone(),
            epoch: 0,
            damping: 1.0 / (1.0 + staleness as f64),
            view_buf: Vec::with_capacity(ds.m()),
            results: (0..k).map(|_| SolveResult::default()).collect(),
            push_slots: (0..k).map(|_| DeltaSlot::new()).collect(),
            cutover_nnz: linalg::raw_sparse_cutover(ds.m()),
            bytes_pushed: 0,
        }
    }

    /// One epoch: every worker computes H steps against its stale view;
    /// the server applies the pushes in arrival order (no barrier — the
    /// virtual-time benefit is that the epoch costs max(compute) with no
    /// synchronization gap, which the caller accounts for).
    pub fn run_epoch(&mut self, h: usize, seed: u64) {
        // Copy the stale view into the reused scratch (no per-epoch clone).
        let idx = self.staleness.min(self.history.len() - 1);
        self.view_buf.clear();
        self.view_buf.extend_from_slice(&self.history[idx]);
        for w in 0..self.workers.len() {
            let req = SolveRequest {
                v: &self.view_buf,
                b: &self.b,
                h,
                lam_n: self.lam_n,
                eta: self.eta,
                sigma: self.sigma,
                seed: seed ^ (w as u64).wrapping_mul(0x9E3779B97F4A7C15),
            };
            self.solvers[w].solve_into(&self.workers[w], &self.alphas[w], &req, &mut self.results[w]);
            // Push: applied immediately at the server (arrival order),
            // damped by 1/(1+staleness) to keep stale updates stable. The
            // worker ships whichever raw frame is cheaper; the server
            // applies sparse pushes entry-wise (same multiplies and adds
            // the dense axpy performs at those indices).
            linalg::axpy(self.damping, &self.results[w].delta_alpha, &mut self.alphas[w]);
            let slot = &mut self.push_slots[w];
            slot.fill_from_dense(&self.results[w].delta_v, self.cutover_nnz);
            self.bytes_pushed += slot.raw_bytes(self.v.len()) as u64;
            match slot.shape() {
                DeltaShape::Sparse => {
                    let sv = slot.sparse().unwrap();
                    for (&i, &x) in sv.idx.iter().zip(sv.vals.iter()) {
                        self.v[i as usize] += self.damping * x;
                    }
                }
                DeltaShape::Dense => {
                    linalg::axpy(self.damping, slot.dense().unwrap(), &mut self.v);
                }
            }
        }
        // Ring update: recycle the evicted snapshot buffer instead of
        // allocating a fresh clone of v every epoch.
        let mut snap = if self.history.len() > self.staleness {
            self.history.pop_back().unwrap()
        } else {
            Vec::with_capacity(self.v.len())
        };
        snap.clear();
        snap.extend_from_slice(&self.v);
        self.history.push_front(snap);
        self.epoch += 1;
    }

    pub fn alpha_global(&self, n: usize) -> Vec<f64> {
        let mut out = vec![0.0; n];
        for (wd, al) in self.workers.iter().zip(self.alphas.iter()) {
            for (&gid, &a) in wd.global_ids.iter().zip(al.iter()) {
                out[gid as usize] = a;
            }
        }
        out
    }

    /// Epochs to reach `target` suboptimality (None if `max_epochs` hit).
    pub fn epochs_to_target(
        &mut self,
        ds: &Dataset,
        fstar: f64,
        target: f64,
        h: usize,
        max_epochs: usize,
    ) -> Option<usize> {
        for e in 0..max_epochs {
            self.run_epoch(h, e as u64);
            let alpha = self.alpha_global(ds.n());
            let f = ds.objective(&alpha, self.lam_n, self.eta);
            if crate::coordinator::suboptimality(f, fstar) <= target {
                return Some(e + 1);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{webspam_like, SyntheticSpec};
    use crate::data::Partitioner;
    use crate::framework::DistEngine;
    
    fn setup() -> (Dataset, TrainConfig, Partitioning) {
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 4;
        let parts = Partitioning::build(Partitioner::Range, &ds.a, 4, 0);
        (ds, cfg, parts)
    }

    #[test]
    fn zero_staleness_equals_synchronous_engine() {
        let (ds, cfg, parts) = setup();
        let mut ps = ParamServerSim::new(&ds, &parts, &cfg, 0);
        // Same partitioning for both sides (build_engine would re-partition
        // with the config default).
        let mut sync = crate::framework::mpi::MpiEngine::build(&ds, &parts, &cfg);
        let mut v_sync = vec![0.0; ds.m()];
        for round in 0..5 {
            ps.run_epoch(40, round);
            let (dv, _) = sync.run_round(&v_sync, 40, round);
            linalg::add_assign(&mut v_sync, &dv);
        }
        let a_ps = ps.alpha_global(ds.n());
        let a_sync = sync.alpha_global();
        for (x, y) in a_ps.iter().zip(a_sync.iter()) {
            assert!((x - y).abs() < 1e-12, "{} vs {}", x, y);
        }
    }

    #[test]
    fn converges_under_bounded_staleness() {
        let (ds, cfg, parts) = setup();
        let fstar = crate::coordinator::oracle_objective(&ds, &cfg);
        let mut ps = ParamServerSim::new(&ds, &parts, &cfg, 2);
        let reached = ps.epochs_to_target(&ds, fstar, 1e-3, 64, 20_000);
        assert!(reached.is_some(), "stale-2 PS failed to converge");
    }

    #[test]
    fn staleness_costs_epochs() {
        let (ds, cfg, parts) = setup();
        let fstar = crate::coordinator::oracle_objective(&ds, &cfg);
        let epochs_at = |s: usize| -> usize {
            let mut ps = ParamServerSim::new(&ds, &parts, &cfg, s);
            ps.epochs_to_target(&ds, fstar, 1e-2, 64, 5000)
                .unwrap_or(usize::MAX)
        };
        let fresh = epochs_at(0);
        let stale = epochs_at(4);
        assert!(
            stale >= fresh,
            "staleness should not accelerate per-epoch progress: {} vs {}",
            stale,
            fresh
        );
    }

    #[test]
    fn sparse_pushes_charge_fewer_bytes_and_match_dense() {
        let (ds, cfg, parts) = setup();
        let mut sparse_ps = ParamServerSim::new(&ds, &parts, &cfg, 1);
        let mut dense_ps = ParamServerSim::new(&ds, &parts, &cfg, 1);
        dense_ps.cutover_nnz = 0; // force dense pushes
        for e in 0..5 {
            sparse_ps.run_epoch(2, e); // tiny H → sparse Δv
            dense_ps.run_epoch(2, e);
        }
        assert!(
            sparse_ps.bytes_pushed < dense_ps.bytes_pushed,
            "sparse {} !< dense {}",
            sparse_ps.bytes_pushed,
            dense_ps.bytes_pushed
        );
        // The applied updates are the same multiplies/adds → identical v.
        for (a, b) in sparse_ps.v.iter().zip(dense_ps.v.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn history_ring_is_bounded() {
        let (ds, cfg, parts) = setup();
        let mut ps = ParamServerSim::new(&ds, &parts, &cfg, 3);
        for e in 0..10 {
            ps.run_epoch(8, e);
        }
        assert!(ps.history.len() <= 4);
    }
}
