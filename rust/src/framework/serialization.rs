//! Serialization codecs emulating the byte formats the paper's stacks use.
//!
//! Two real codecs — encode/decode actually run on the communicated vectors
//! so byte counts are exact and corruption is detectable:
//!
//! * [`JavaSer`] — JavaSerializer-flavoured: block headers + big-endian
//!   doubles (Spark's closure/data default in 1.5).
//! * [`PickleSer`] — cPickle-protocol-2-flavoured: opcode byte per element
//!   + little-endian payload (what pySpark pays on every task boundary).
//!
//! Time is *charged* via [`super::overhead::OverheadModel`] throughput
//! constants rather than the codec's own wall time, because the dataset is
//! a down-scaled stand-in (DESIGN.md §6); the bytes, however, are real.

/// Encoded frame plus element count (for validation on decode).
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub bytes: Vec<u8>,
}

impl Frame {
    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }
}

/// Java-serialization-flavoured codec (big-endian, stream + block headers).
pub struct JavaSer;

const JAVA_MAGIC: u16 = 0xACED;
const JAVA_BLOCK: usize = 1024;

impl JavaSer {
    /// Encode an f64 vector into a caller-owned buffer (cleared first).
    /// With a pooled/persistent buffer the codec stops churning the
    /// allocator — one `encode_into` per round instead of one `Vec` per
    /// round (zero-allocation hot path; see `util::pool`).
    pub fn encode_into(v: &[f64], out: &mut Vec<u8>) {
        out.clear();
        out.reserve(java_encoded_len(v.len()));
        out.extend_from_slice(&JAVA_MAGIC.to_be_bytes());
        out.extend_from_slice(&(5u16).to_be_bytes()); // stream version
        out.extend_from_slice(&(v.len() as u64).to_be_bytes());
        for (i, &x) in v.iter().enumerate() {
            if i % JAVA_BLOCK == 0 {
                out.push(0x77); // TC_BLOCKDATA
                out.push(JAVA_BLOCK.min(v.len() - i).min(255) as u8);
            }
            out.extend_from_slice(&x.to_be_bytes());
        }
    }

    /// Encode an f64 vector.
    pub fn encode(v: &[f64]) -> Frame {
        let mut out = Vec::new();
        JavaSer::encode_into(v, &mut out);
        Frame { bytes: out }
    }

    /// Decode; errors on malformed input.
    pub fn decode(f: &Frame) -> Result<Vec<f64>, String> {
        JavaSer::decode_slice(&f.bytes)
    }

    /// Decode raw bytes (the pooled-buffer counterpart of [`Self::decode`]).
    pub fn decode_slice(b: &[u8]) -> Result<Vec<f64>, String> {
        if b.len() < 12 {
            return Err("short frame".into());
        }
        if u16::from_be_bytes([b[0], b[1]]) != JAVA_MAGIC {
            return Err("bad magic".into());
        }
        let n = u64::from_be_bytes(b[4..12].try_into().unwrap()) as usize;
        let mut out = Vec::with_capacity(n);
        let mut pos = 12;
        for i in 0..n {
            if i % JAVA_BLOCK == 0 {
                if pos + 2 > b.len() || b[pos] != 0x77 {
                    return Err(format!("missing block header at {}", pos));
                }
                pos += 2;
            }
            if pos + 8 > b.len() {
                return Err("truncated".into());
            }
            out.push(f64::from_be_bytes(b[pos..pos + 8].try_into().unwrap()));
            pos += 8;
        }
        Ok(out)
    }
}

/// Pickle-protocol-2-flavoured codec (opcode per element, LE payload).
pub struct PickleSer;

const OP_PROTO: u8 = 0x80;
const OP_BINFLOAT: u8 = b'G';
const OP_EMPTY_LIST: u8 = b']';
const OP_APPEND: u8 = b'a';
const OP_STOP: u8 = b'.';

impl PickleSer {
    /// Encode into a caller-owned buffer (cleared first) — the pooled,
    /// allocation-free variant of [`Self::encode`].
    pub fn encode_into(v: &[f64], out: &mut Vec<u8>) {
        // pickle floats are actually big-endian 'G'; we keep that detail.
        out.clear();
        out.reserve(pickle_encoded_len(v.len()));
        out.push(OP_PROTO);
        out.push(2);
        out.push(OP_EMPTY_LIST);
        out.extend_from_slice(&(v.len() as u64).to_le_bytes());
        for &x in v {
            out.push(OP_BINFLOAT);
            out.extend_from_slice(&x.to_be_bytes());
            out.push(OP_APPEND);
        }
        out.push(OP_STOP);
    }

    pub fn encode(v: &[f64]) -> Frame {
        let mut out = Vec::new();
        PickleSer::encode_into(v, &mut out);
        Frame { bytes: out }
    }

    pub fn decode(f: &Frame) -> Result<Vec<f64>, String> {
        PickleSer::decode_slice(&f.bytes)
    }

    /// Decode raw bytes (pooled-buffer counterpart of [`Self::decode`]).
    pub fn decode_slice(b: &[u8]) -> Result<Vec<f64>, String> {
        if b.len() < 12 || b[0] != OP_PROTO || b[2] != OP_EMPTY_LIST {
            return Err("bad pickle header".into());
        }
        let n = u64::from_le_bytes(b[3..11].try_into().unwrap()) as usize;
        let mut out = Vec::with_capacity(n);
        let mut pos = 11;
        for _ in 0..n {
            if pos + 10 > b.len() || b[pos] != OP_BINFLOAT {
                return Err(format!("bad element at {}", pos));
            }
            out.push(f64::from_be_bytes(b[pos + 1..pos + 9].try_into().unwrap()));
            if b[pos + 9] != OP_APPEND {
                return Err("missing APPEND".into());
            }
            pos += 10;
        }
        if pos >= b.len() || b[pos] != OP_STOP {
            return Err("missing STOP".into());
        }
        Ok(out)
    }
}

/// Size in bytes of a payload under each codec without encoding it
/// (used by the cost model for counterfactual byte accounting).
pub fn java_encoded_len(n_elems: usize) -> usize {
    12 + n_elems * 8 + n_elems.div_ceil(JAVA_BLOCK) * 2
}

pub fn pickle_encoded_len(n_elems: usize) -> usize {
    12 + n_elems * 10
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<f64> {
        (0..3000).map(|i| (i as f64) * 0.37 - 55.0).collect()
    }

    #[test]
    fn java_roundtrip() {
        let v = sample();
        let f = JavaSer::encode(&v);
        assert_eq!(f.len(), java_encoded_len(v.len()));
        assert_eq!(JavaSer::decode(&f).unwrap(), v);
    }

    #[test]
    fn pickle_roundtrip() {
        let v = sample();
        let f = PickleSer::encode(&v);
        assert_eq!(f.len(), pickle_encoded_len(v.len()));
        assert_eq!(PickleSer::decode(&f).unwrap(), v);
    }

    #[test]
    fn empty_vectors() {
        assert_eq!(JavaSer::decode(&JavaSer::encode(&[])).unwrap(), Vec::<f64>::new());
        assert_eq!(PickleSer::decode(&PickleSer::encode(&[])).unwrap(), Vec::<f64>::new());
    }

    #[test]
    fn special_values_roundtrip() {
        let v = vec![f64::INFINITY, f64::NEG_INFINITY, 0.0, -0.0, f64::MIN_POSITIVE];
        assert_eq!(JavaSer::decode(&JavaSer::encode(&v)).unwrap(), v);
        assert_eq!(PickleSer::decode(&PickleSer::encode(&v)).unwrap(), v);
    }

    #[test]
    fn corruption_detected() {
        let v = sample();
        let mut f = JavaSer::encode(&v);
        f.bytes[0] ^= 0xFF;
        assert!(JavaSer::decode(&f).is_err());
        let mut p = PickleSer::encode(&v);
        p.bytes[11] = 0; // first opcode
        assert!(PickleSer::decode(&p).is_err());
        let t = Frame {
            bytes: JavaSer::encode(&v).bytes[..40].to_vec(),
        };
        assert!(JavaSer::decode(&t).is_err());
    }

    #[test]
    fn encode_into_matches_encode_and_reuses_capacity() {
        let v = sample();
        let mut buf = Vec::new();
        JavaSer::encode_into(&v, &mut buf);
        assert_eq!(buf, JavaSer::encode(&v).bytes);
        assert_eq!(JavaSer::decode_slice(&buf).unwrap(), v);
        let cap = buf.capacity();
        // Re-encoding a same-size payload must not grow the buffer, and
        // after warmup must not allocate at all.
        let before = crate::testkit::alloc::current_thread_allocations();
        for _ in 0..5 {
            JavaSer::encode_into(&v, &mut buf);
        }
        let after = crate::testkit::alloc::current_thread_allocations();
        assert_eq!(after - before, 0, "pooled java encode allocated");
        assert_eq!(buf.capacity(), cap);

        let mut pbuf = Vec::new();
        PickleSer::encode_into(&v, &mut pbuf);
        assert_eq!(pbuf, PickleSer::encode(&v).bytes);
        assert_eq!(PickleSer::decode_slice(&pbuf).unwrap(), v);
        let before = crate::testkit::alloc::current_thread_allocations();
        for _ in 0..5 {
            PickleSer::encode_into(&v, &mut pbuf);
        }
        let after = crate::testkit::alloc::current_thread_allocations();
        assert_eq!(after - before, 0, "pooled pickle encode allocated");
    }

    #[test]
    fn pickle_is_fatter_than_java() {
        // The 10-vs-8 bytes/element tax is part of why pySpark moves more data.
        assert!(pickle_encoded_len(10_000) > java_encoded_len(10_000));
    }
}
