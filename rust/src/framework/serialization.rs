//! Serialization codecs emulating the byte formats the paper's stacks use.
//!
//! Two real codecs — encode/decode actually run on the communicated vectors
//! so byte counts are exact and corruption is detectable:
//!
//! * [`JavaSer`] — JavaSerializer-flavoured: block headers + big-endian
//!   doubles (Spark's closure/data default in 1.5).
//! * [`PickleSer`] — cPickle-protocol-2-flavoured: opcode byte per element
//!   + little-endian payload (what pySpark pays on every task boundary).
//!
//! Each codec carries two frame layouts for the communicated Δv:
//!
//! * **dense** — the historical m-doubles frame;
//! * **sparse** — nnz (index, value) pairs with delta-coded LEB128 varint
//!   indices (Breeze-SparseVector-flavoured for [`JavaSer`], pickled
//!   index/value arrays for [`PickleSer`]). A worker emits whichever is
//!   cheaper under the cutover rule (DESIGN.md §7): sparse iff the
//!   worst-case sparse length undercuts the dense length
//!   ([`java_sparse_cutover`] / [`pickle_sparse_cutover`]).
//!
//! Every `encode_into` writes into a caller-owned (pooled / persistent)
//! buffer, preserving the zero-allocation steady state of `util::pool`;
//! the engines charge the overhead model the **actual** encoded frame
//! lengths, not a counterfactual dense size.
//!
//! Time is *charged* via [`super::overhead::OverheadModel`] throughput
//! constants rather than the codec's own wall time, because the dataset is
//! a down-scaled stand-in (DESIGN.md §6); the bytes, however, are real.

use crate::linalg::{sparse_cutover, DeltaShape, DeltaSlot, SparseVec};

/// Encoded frame plus element count (for validation on decode).
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub bytes: Vec<u8>,
}

impl Frame {
    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }
}

/// Java-serialization-flavoured codec (big-endian, stream + block headers).
pub struct JavaSer;

const JAVA_MAGIC: u16 = 0xACED;
const JAVA_BLOCK: usize = 1024;

impl JavaSer {
    /// Encode an f64 vector into a caller-owned buffer (cleared first).
    /// With a pooled/persistent buffer the codec stops churning the
    /// allocator — one `encode_into` per round instead of one `Vec` per
    /// round (zero-allocation hot path; see `util::pool`).
    pub fn encode_into(v: &[f64], out: &mut Vec<u8>) {
        out.clear();
        out.reserve(java_encoded_len(v.len()));
        out.extend_from_slice(&JAVA_MAGIC.to_be_bytes());
        out.extend_from_slice(&(5u16).to_be_bytes()); // stream version
        out.extend_from_slice(&(v.len() as u64).to_be_bytes());
        for (i, &x) in v.iter().enumerate() {
            if i % JAVA_BLOCK == 0 {
                out.push(0x77); // TC_BLOCKDATA
                out.push(JAVA_BLOCK.min(v.len() - i).min(255) as u8);
            }
            out.extend_from_slice(&x.to_be_bytes());
        }
    }

    /// Encode an f64 vector.
    pub fn encode(v: &[f64]) -> Frame {
        let mut out = Vec::new();
        JavaSer::encode_into(v, &mut out);
        Frame { bytes: out }
    }

    /// Decode; errors on malformed input.
    pub fn decode(f: &Frame) -> Result<Vec<f64>, String> {
        JavaSer::decode_slice(&f.bytes)
    }

    /// Decode raw bytes (the pooled-buffer counterpart of [`Self::decode`]).
    pub fn decode_slice(b: &[u8]) -> Result<Vec<f64>, String> {
        if b.len() < 12 {
            return Err("short frame".into());
        }
        if u16::from_be_bytes([b[0], b[1]]) != JAVA_MAGIC {
            return Err("bad magic".into());
        }
        let n = u64::from_be_bytes(b[4..12].try_into().unwrap()) as usize;
        // Frame-supplied count: bound it by the frame length (≥ 8 bytes
        // per element) before pre-allocating, so a corrupt frame returns
        // Err instead of aborting on a huge allocation.
        if n > b.len() {
            return Err(format!("element count {} exceeds frame size {}", n, b.len()));
        }
        let mut out = Vec::with_capacity(n);
        let mut pos = 12;
        for i in 0..n {
            if i % JAVA_BLOCK == 0 {
                if pos + 2 > b.len() || b[pos] != 0x77 {
                    return Err(format!("missing block header at {}", pos));
                }
                pos += 2;
            }
            if pos + 8 > b.len() {
                return Err("truncated".into());
            }
            out.push(f64::from_be_bytes(b[pos..pos + 8].try_into().unwrap()));
            pos += 8;
        }
        Ok(out)
    }

    /// Encode a sparse Δv frame (Breeze-SparseVector-flavoured): magic +
    /// stream version, a `0xFF` sparse marker (a dense frame's byte 4 is
    /// the top byte of its u64 length, never `0xFF`), the `'S'` tag, dim
    /// and nnz as u64 BE, delta-varint indices, then nnz f64 BE values.
    pub fn encode_sparse_into(sv: &SparseVec, out: &mut Vec<u8>) {
        out.clear();
        out.reserve(java_sparse_encoded_len_max(sv.nnz()));
        out.extend_from_slice(&JAVA_MAGIC.to_be_bytes());
        out.extend_from_slice(&(5u16).to_be_bytes());
        out.push(SPARSE_MARKER);
        out.push(b'S');
        out.extend_from_slice(&(sv.dim as u64).to_be_bytes());
        out.extend_from_slice(&(sv.nnz() as u64).to_be_bytes());
        write_delta_varints(&sv.idx, out);
        for &x in &sv.vals {
            out.extend_from_slice(&x.to_be_bytes());
        }
    }

    /// Decode a sparse frame; errors on malformed input.
    pub fn decode_sparse_slice(b: &[u8]) -> Result<SparseVec, String> {
        if b.len() < 22 {
            return Err("short sparse frame".into());
        }
        if u16::from_be_bytes([b[0], b[1]]) != JAVA_MAGIC {
            return Err("bad magic".into());
        }
        if b[4] != SPARSE_MARKER || b[5] != b'S' {
            return Err("not a sparse java frame".into());
        }
        let dim = u64::from_be_bytes(b[6..14].try_into().unwrap()) as usize;
        let nnz = u64::from_be_bytes(b[14..22].try_into().unwrap()) as usize;
        // Each entry needs ≥ 1 varint byte + 8 value bytes, so a
        // frame-supplied nnz beyond the frame length is provably corrupt —
        // reject BEFORE pre-allocating instead of panicking on capacity.
        if nnz > b.len() {
            return Err(format!("nnz {} exceeds frame size {}", nnz, b.len()));
        }
        let mut pos = 22;
        let idx = read_delta_varints(b, &mut pos, nnz, dim)?;
        let mut vals = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            if pos + 8 > b.len() {
                return Err("truncated sparse values".into());
            }
            vals.push(f64::from_be_bytes(b[pos..pos + 8].try_into().unwrap()));
            pos += 8;
        }
        Ok(SparseVec { dim, idx, vals })
    }

    /// Encode a delta slot in whichever layout it holds.
    pub fn encode_delta_into(slot: &DeltaSlot, out: &mut Vec<u8>) {
        match slot.shape() {
            DeltaShape::Dense => JavaSer::encode_into(slot.dense().unwrap(), out),
            DeltaShape::Sparse => JavaSer::encode_sparse_into(slot.sparse().unwrap(), out),
        }
    }

    /// Decode either frame layout to its dense form (test/debug surface;
    /// sniffs the sparse marker byte).
    pub fn decode_delta_dense(b: &[u8]) -> Result<Vec<f64>, String> {
        if b.len() > 4 && b[4] == SPARSE_MARKER {
            let sv = JavaSer::decode_sparse_slice(b)?;
            let mut out = Vec::new();
            sv.densify_into(&mut out);
            Ok(out)
        } else {
            JavaSer::decode_slice(b)
        }
    }
}

/// Pickle-protocol-2-flavoured codec (opcode per element, LE payload).
pub struct PickleSer;

const OP_PROTO: u8 = 0x80;
const OP_BINFLOAT: u8 = b'G';
const OP_EMPTY_LIST: u8 = b']';
const OP_APPEND: u8 = b'a';
const OP_STOP: u8 = b'.';

impl PickleSer {
    /// Encode into a caller-owned buffer (cleared first) — the pooled,
    /// allocation-free variant of [`Self::encode`].
    pub fn encode_into(v: &[f64], out: &mut Vec<u8>) {
        // pickle floats are actually big-endian 'G'; we keep that detail.
        out.clear();
        out.reserve(pickle_encoded_len(v.len()));
        out.push(OP_PROTO);
        out.push(2);
        out.push(OP_EMPTY_LIST);
        out.extend_from_slice(&(v.len() as u64).to_le_bytes());
        for &x in v {
            out.push(OP_BINFLOAT);
            out.extend_from_slice(&x.to_be_bytes());
            out.push(OP_APPEND);
        }
        out.push(OP_STOP);
    }

    pub fn encode(v: &[f64]) -> Frame {
        let mut out = Vec::new();
        PickleSer::encode_into(v, &mut out);
        Frame { bytes: out }
    }

    pub fn decode(f: &Frame) -> Result<Vec<f64>, String> {
        PickleSer::decode_slice(&f.bytes)
    }

    /// Decode raw bytes (pooled-buffer counterpart of [`Self::decode`]).
    pub fn decode_slice(b: &[u8]) -> Result<Vec<f64>, String> {
        if b.len() < 12 || b[0] != OP_PROTO || b[2] != OP_EMPTY_LIST {
            return Err("bad pickle header".into());
        }
        let n = u64::from_le_bytes(b[3..11].try_into().unwrap()) as usize;
        if n > b.len() {
            return Err(format!("element count {} exceeds frame size {}", n, b.len()));
        }
        let mut out = Vec::with_capacity(n);
        let mut pos = 11;
        for _ in 0..n {
            if pos + 10 > b.len() || b[pos] != OP_BINFLOAT {
                return Err(format!("bad element at {}", pos));
            }
            out.push(f64::from_be_bytes(b[pos + 1..pos + 9].try_into().unwrap()));
            if b[pos + 9] != OP_APPEND {
                return Err("missing APPEND".into());
            }
            pos += 10;
        }
        if pos >= b.len() || b[pos] != OP_STOP {
            return Err("missing STOP".into());
        }
        Ok(out)
    }

    /// Encode a sparse Δv frame: proto-2 header, `'('` (MARK — a pickled
    /// tuple of index/value arrays, vs the dense frame's `']'` list), dim
    /// and nnz as u64 LE, delta-varint indices, then the value array as a
    /// raw little-endian buffer (NumPy `tobytes`, the fast binary path),
    /// and STOP.
    pub fn encode_sparse_into(sv: &SparseVec, out: &mut Vec<u8>) {
        out.clear();
        out.reserve(pickle_sparse_encoded_len_max(sv.nnz()));
        out.push(OP_PROTO);
        out.push(2);
        out.push(OP_MARK);
        out.extend_from_slice(&(sv.dim as u64).to_le_bytes());
        out.extend_from_slice(&(sv.nnz() as u64).to_le_bytes());
        write_delta_varints(&sv.idx, out);
        for &x in &sv.vals {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out.push(OP_STOP);
    }

    /// Decode a sparse frame; errors on malformed input.
    pub fn decode_sparse_slice(b: &[u8]) -> Result<SparseVec, String> {
        if b.len() < 20 || b[0] != OP_PROTO || b[2] != OP_MARK {
            return Err("bad sparse pickle header".into());
        }
        let dim = u64::from_le_bytes(b[3..11].try_into().unwrap()) as usize;
        let nnz = u64::from_le_bytes(b[11..19].try_into().unwrap()) as usize;
        if nnz > b.len() {
            return Err(format!("nnz {} exceeds frame size {}", nnz, b.len()));
        }
        let mut pos = 19;
        let idx = read_delta_varints(b, &mut pos, nnz, dim)?;
        let mut vals = Vec::with_capacity(nnz);
        for _ in 0..nnz {
            if pos + 8 > b.len() {
                return Err("truncated sparse values".into());
            }
            vals.push(f64::from_le_bytes(b[pos..pos + 8].try_into().unwrap()));
            pos += 8;
        }
        if pos >= b.len() || b[pos] != OP_STOP {
            return Err("missing STOP".into());
        }
        Ok(SparseVec { dim, idx, vals })
    }

    /// Encode a delta slot in whichever layout it holds.
    pub fn encode_delta_into(slot: &DeltaSlot, out: &mut Vec<u8>) {
        match slot.shape() {
            DeltaShape::Dense => PickleSer::encode_into(slot.dense().unwrap(), out),
            DeltaShape::Sparse => PickleSer::encode_sparse_into(slot.sparse().unwrap(), out),
        }
    }

    /// Decode either frame layout to its dense form (sniffs opcode 2).
    pub fn decode_delta_dense(b: &[u8]) -> Result<Vec<f64>, String> {
        if b.len() > 2 && b[2] == OP_MARK {
            let sv = PickleSer::decode_sparse_slice(b)?;
            let mut out = Vec::new();
            sv.densify_into(&mut out);
            Ok(out)
        } else {
            PickleSer::decode_slice(b)
        }
    }
}

/// Byte 4 of a sparse java frame; a dense frame carries the top byte of
/// its u64 BE element count there, which is never `0xFF` for any payload
/// this testbed can hold (< 2^56 elements).
const SPARSE_MARKER: u8 = 0xFF;
/// Pickle MARK opcode — opens the (indices, values) tuple of the sparse
/// frame; the dense frame opens with EMPTY_LIST instead.
const OP_MARK: u8 = b'(';

// ---------------------------------------------------------------------------
// Varint index coding shared by both sparse layouts
// ---------------------------------------------------------------------------

/// Append one LEB128 varint.
fn write_varint_u32(mut v: u32, out: &mut Vec<u8>) {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Read one LEB128 varint, advancing `pos`.
fn read_varint_u32(b: &[u8], pos: &mut usize) -> Result<u32, String> {
    let mut v: u32 = 0;
    let mut shift = 0u32;
    loop {
        if *pos >= b.len() {
            return Err("truncated varint".into());
        }
        let byte = b[*pos];
        *pos += 1;
        if shift >= 32 || (shift == 28 && (byte & 0x7F) > 0x0F) {
            return Err("varint overflows u32".into());
        }
        v |= ((byte & 0x7F) as u32) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

/// Delta-code a strictly increasing index list: first index absolute,
/// then the gaps (all ≥ 1) — small-column deltas compress to one byte.
fn write_delta_varints(idx: &[u32], out: &mut Vec<u8>) {
    let mut prev = 0u32;
    for (i, &x) in idx.iter().enumerate() {
        if i == 0 {
            write_varint_u32(x, out);
        } else {
            write_varint_u32(x - prev, out);
        }
        prev = x;
    }
}

/// Inverse of [`write_delta_varints`]; validates strict monotonicity and
/// the `dim` bound so a corrupt frame cannot materialize out-of-range
/// indices.
fn read_delta_varints(
    b: &[u8],
    pos: &mut usize,
    nnz: usize,
    dim: usize,
) -> Result<Vec<u32>, String> {
    let mut idx = Vec::with_capacity(nnz);
    let mut prev: u64 = 0;
    for i in 0..nnz {
        let raw = read_varint_u32(b, pos)? as u64;
        let cur = if i == 0 {
            raw
        } else {
            if raw == 0 {
                return Err("zero index gap (duplicate index)".into());
            }
            prev + raw
        };
        if cur >= dim as u64 {
            return Err(format!("index {} out of dim {}", cur, dim));
        }
        idx.push(cur as u32);
        prev = cur;
    }
    Ok(idx)
}

// ---------------------------------------------------------------------------
// Frame sizes and the cutover rule
// ---------------------------------------------------------------------------

/// Size in bytes of a payload under each codec without encoding it
/// (used by the cost model for counterfactual byte accounting).
pub fn java_encoded_len(n_elems: usize) -> usize {
    12 + n_elems * 8 + n_elems.div_ceil(JAVA_BLOCK) * 2
}

pub fn pickle_encoded_len(n_elems: usize) -> usize {
    12 + n_elems * 10
}

/// Worst-case sparse java frame length (varints at 5 bytes each; the
/// actual encoded frame is usually much smaller thanks to delta coding).
pub fn java_sparse_encoded_len_max(nnz: usize) -> usize {
    22 + nnz * 13
}

/// Worst-case sparse pickle frame length.
pub fn pickle_sparse_encoded_len_max(nnz: usize) -> usize {
    20 + nnz * 13
}

/// Cutover threshold for Spark's java frames: a worker emits the sparse
/// layout iff its Δv nnz is ≤ this. Conservative: uses the worst-case
/// sparse length, so sparse is chosen only when guaranteed smaller; the
/// engines then charge the (smaller still) actual encoded bytes.
pub fn java_sparse_cutover(m: usize) -> usize {
    sparse_cutover(m, java_encoded_len(m), java_sparse_encoded_len_max)
}

/// Cutover threshold for pySpark's pickle frames.
pub fn pickle_sparse_cutover(m: usize) -> usize {
    sparse_cutover(m, pickle_encoded_len(m), pickle_sparse_encoded_len_max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<f64> {
        (0..3000).map(|i| (i as f64) * 0.37 - 55.0).collect()
    }

    #[test]
    fn java_roundtrip() {
        let v = sample();
        let f = JavaSer::encode(&v);
        assert_eq!(f.len(), java_encoded_len(v.len()));
        assert_eq!(JavaSer::decode(&f).unwrap(), v);
    }

    #[test]
    fn pickle_roundtrip() {
        let v = sample();
        let f = PickleSer::encode(&v);
        assert_eq!(f.len(), pickle_encoded_len(v.len()));
        assert_eq!(PickleSer::decode(&f).unwrap(), v);
    }

    #[test]
    fn empty_vectors() {
        assert_eq!(JavaSer::decode(&JavaSer::encode(&[])).unwrap(), Vec::<f64>::new());
        assert_eq!(PickleSer::decode(&PickleSer::encode(&[])).unwrap(), Vec::<f64>::new());
    }

    #[test]
    fn special_values_roundtrip() {
        let v = vec![f64::INFINITY, f64::NEG_INFINITY, 0.0, -0.0, f64::MIN_POSITIVE];
        assert_eq!(JavaSer::decode(&JavaSer::encode(&v)).unwrap(), v);
        assert_eq!(PickleSer::decode(&PickleSer::encode(&v)).unwrap(), v);
    }

    #[test]
    fn corruption_detected() {
        let v = sample();
        let mut f = JavaSer::encode(&v);
        f.bytes[0] ^= 0xFF;
        assert!(JavaSer::decode(&f).is_err());
        let mut p = PickleSer::encode(&v);
        p.bytes[11] = 0; // first opcode
        assert!(PickleSer::decode(&p).is_err());
        let t = Frame {
            bytes: JavaSer::encode(&v).bytes[..40].to_vec(),
        };
        assert!(JavaSer::decode(&t).is_err());
    }

    #[test]
    fn encode_into_matches_encode_and_reuses_capacity() {
        let v = sample();
        let mut buf = Vec::new();
        JavaSer::encode_into(&v, &mut buf);
        assert_eq!(buf, JavaSer::encode(&v).bytes);
        assert_eq!(JavaSer::decode_slice(&buf).unwrap(), v);
        let cap = buf.capacity();
        // Re-encoding a same-size payload must not grow the buffer, and
        // after warmup must not allocate at all.
        let before = crate::testkit::alloc::current_thread_allocations();
        for _ in 0..5 {
            JavaSer::encode_into(&v, &mut buf);
        }
        let after = crate::testkit::alloc::current_thread_allocations();
        assert_eq!(after - before, 0, "pooled java encode allocated");
        assert_eq!(buf.capacity(), cap);

        let mut pbuf = Vec::new();
        PickleSer::encode_into(&v, &mut pbuf);
        assert_eq!(pbuf, PickleSer::encode(&v).bytes);
        assert_eq!(PickleSer::decode_slice(&pbuf).unwrap(), v);
        let before = crate::testkit::alloc::current_thread_allocations();
        for _ in 0..5 {
            PickleSer::encode_into(&v, &mut pbuf);
        }
        let after = crate::testkit::alloc::current_thread_allocations();
        assert_eq!(after - before, 0, "pooled pickle encode allocated");
    }

    #[test]
    fn pickle_is_fatter_than_java() {
        // The 10-vs-8 bytes/element tax is part of why pySpark moves more data.
        assert!(pickle_encoded_len(10_000) > java_encoded_len(10_000));
    }

    fn sv(dim: usize, entries: &[(u32, f64)]) -> SparseVec {
        SparseVec {
            dim,
            idx: entries.iter().map(|&(i, _)| i).collect(),
            vals: entries.iter().map(|&(_, v)| v).collect(),
        }
    }

    #[test]
    fn varint_roundtrip_all_widths() {
        for v in [0u32, 1, 127, 128, 300, 16_383, 16_384, 1 << 21, u32::MAX] {
            let mut buf = Vec::new();
            write_varint_u32(v, &mut buf);
            assert!(buf.len() <= 5);
            let mut pos = 0;
            assert_eq!(read_varint_u32(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
        // Truncation and overflow are detected.
        let mut pos = 0;
        assert!(read_varint_u32(&[0x80], &mut pos).is_err());
        let mut pos = 0;
        assert!(read_varint_u32(&[0xFF, 0xFF, 0xFF, 0xFF, 0x7F], &mut pos).is_err());
    }

    #[test]
    fn sparse_roundtrip_both_codecs() {
        let cases = [
            sv(1000, &[]),                                  // empty
            sv(1000, &[(999, -3.5)]),                       // single nnz at the edge
            sv(8, &[(0, 1.0), (1, 2.0), (7, f64::INFINITY)]), // specials
            sv(1 << 20, &[(0, 0.5), (1 << 10, -0.25), ((1 << 20) - 1, 1e-300)]),
        ];
        for v in &cases {
            let mut jb = Vec::new();
            JavaSer::encode_sparse_into(v, &mut jb);
            assert!(jb.len() <= java_sparse_encoded_len_max(v.nnz()));
            let back = JavaSer::decode_sparse_slice(&jb).unwrap();
            assert_eq!(&back, v);
            back.validate().unwrap();

            let mut pb = Vec::new();
            PickleSer::encode_sparse_into(v, &mut pb);
            assert!(pb.len() <= pickle_sparse_encoded_len_max(v.nnz()));
            let back = PickleSer::decode_sparse_slice(&pb).unwrap();
            assert_eq!(&back, v);
        }
    }

    #[test]
    fn sparse_frames_are_distinguishable_from_dense() {
        let dense = JavaSer::encode(&[1.0, 2.0, 3.0]);
        assert_ne!(dense.bytes[4], 0xFF, "dense frame must not carry the sparse marker");
        let v = sv(64, &[(3, 1.5), (40, -2.0)]);
        let mut jb = Vec::new();
        JavaSer::encode_sparse_into(&v, &mut jb);
        // decode_delta_dense dispatches on the marker for both layouts.
        assert_eq!(
            JavaSer::decode_delta_dense(&dense.bytes).unwrap(),
            vec![1.0, 2.0, 3.0]
        );
        let mut want = Vec::new();
        v.densify_into(&mut want);
        assert_eq!(JavaSer::decode_delta_dense(&jb).unwrap(), want);

        let pdense = PickleSer::encode(&[4.0, 5.0]);
        let mut pb = Vec::new();
        PickleSer::encode_sparse_into(&v, &mut pb);
        assert_eq!(PickleSer::decode_delta_dense(&pdense.bytes).unwrap(), vec![4.0, 5.0]);
        assert_eq!(PickleSer::decode_delta_dense(&pb).unwrap(), want);
    }

    #[test]
    fn sparse_corruption_detected() {
        let v = sv(128, &[(1, 1.0), (2, 2.0), (100, 3.0)]);
        let mut jb = Vec::new();
        JavaSer::encode_sparse_into(&v, &mut jb);
        let mut bad = jb.clone();
        bad[0] ^= 0xFF; // magic
        assert!(JavaSer::decode_sparse_slice(&bad).is_err());
        assert!(JavaSer::decode_sparse_slice(&jb[..jb.len() - 4]).is_err()); // truncated
        let mut bad = jb.clone();
        bad[22] = 0x80; // first index varint becomes unterminated garbage run
        bad.truncate(23);
        assert!(JavaSer::decode_sparse_slice(&bad).is_err());

        let mut pb = Vec::new();
        PickleSer::encode_sparse_into(&v, &mut pb);
        let mut bad = pb.clone();
        bad[2] = OP_EMPTY_LIST; // wrong layout tag
        assert!(PickleSer::decode_sparse_slice(&bad).is_err());
        let mut bad = pb.clone();
        let last = bad.len() - 1;
        bad[last] = 0; // STOP
        assert!(PickleSer::decode_sparse_slice(&bad).is_err());
    }

    #[test]
    fn huge_frame_counts_error_instead_of_allocating() {
        // A corrupt count field (e.g. 2^61) must return Err from the
        // length guard, not abort inside Vec::with_capacity.
        let v = sv(64, &[(1, 1.0), (30, 2.0)]);
        let huge = (1u64 << 61).to_be_bytes();
        let mut jb = Vec::new();
        JavaSer::encode_sparse_into(&v, &mut jb);
        jb[14..22].copy_from_slice(&huge); // nnz field
        assert!(JavaSer::decode_sparse_slice(&jb).is_err());
        let mut jd = JavaSer::encode(&[1.0, 2.0, 3.0]).bytes;
        jd[4..12].copy_from_slice(&huge); // dense element count
        assert!(JavaSer::decode_slice(&jd).is_err());

        let huge_le = (1u64 << 61).to_le_bytes();
        let mut pb = Vec::new();
        PickleSer::encode_sparse_into(&v, &mut pb);
        pb[11..19].copy_from_slice(&huge_le);
        assert!(PickleSer::decode_sparse_slice(&pb).is_err());
        let mut pd = PickleSer::encode(&[1.0, 2.0]).bytes;
        pd[3..11].copy_from_slice(&huge_le);
        assert!(PickleSer::decode_slice(&pd).is_err());
    }

    #[test]
    fn duplicate_and_out_of_range_indices_rejected() {
        // A zero gap (duplicate index) and an out-of-dim index must both
        // fail the delta-varint validation on decode.
        let mut frame = Vec::new();
        frame.extend_from_slice(&JAVA_MAGIC.to_be_bytes());
        frame.extend_from_slice(&(5u16).to_be_bytes());
        frame.push(SPARSE_MARKER);
        frame.push(b'S');
        frame.extend_from_slice(&(16u64).to_be_bytes()); // dim
        frame.extend_from_slice(&(2u64).to_be_bytes()); // nnz
        frame.push(3); // idx[0] = 3
        frame.push(0); // gap 0 → duplicate
        frame.extend_from_slice(&1.0f64.to_be_bytes());
        frame.extend_from_slice(&2.0f64.to_be_bytes());
        assert!(JavaSer::decode_sparse_slice(&frame).is_err());

        let mut frame2 = frame.clone();
        frame2[22] = 40; // idx[0] = 40 ≥ dim 16
        frame2[23] = 1;
        assert!(JavaSer::decode_sparse_slice(&frame2).is_err());
    }

    #[test]
    fn cutover_thresholds_solve_the_rule() {
        for m in [64usize, 1000, 1 << 17] {
            let cj = java_sparse_cutover(m);
            assert!(java_sparse_encoded_len_max(cj) < java_encoded_len(m));
            assert!(java_sparse_encoded_len_max(cj + 1) >= java_encoded_len(m));
            let cp = pickle_sparse_cutover(m);
            assert!(pickle_sparse_encoded_len_max(cp) < pickle_encoded_len(m));
            assert!(pickle_sparse_encoded_len_max(cp + 1) >= pickle_encoded_len(m));
            // Both sit in the expected ~0.6m..0.8m band.
            assert!(cj > m / 2 && cj < m, "java cutover {} at m={}", cj, m);
            assert!(cp > m / 2 && cp < m, "pickle cutover {} at m={}", cp, m);
        }
    }

    #[test]
    fn sparse_encode_into_is_allocation_free_after_warmup() {
        let v = sv(4096, &(0..200).map(|i| (i * 20, 0.5 + i as f64)).collect::<Vec<_>>());
        let mut jb = Vec::new();
        JavaSer::encode_sparse_into(&v, &mut jb); // warmup
        let before = crate::testkit::alloc::current_thread_allocations();
        for _ in 0..5 {
            JavaSer::encode_sparse_into(&v, &mut jb);
        }
        let after = crate::testkit::alloc::current_thread_allocations();
        assert_eq!(after - before, 0, "pooled sparse java encode allocated");

        let mut pb = Vec::new();
        PickleSer::encode_sparse_into(&v, &mut pb);
        let before = crate::testkit::alloc::current_thread_allocations();
        for _ in 0..5 {
            PickleSer::encode_sparse_into(&v, &mut pb);
        }
        let after = crate::testkit::alloc::current_thread_allocations();
        assert_eq!(after - before, 0, "pooled sparse pickle encode allocated");
    }

    #[test]
    fn sparse_frame_much_smaller_at_low_density() {
        // nnz/m = 0.05 → ≥ 5× fewer bytes under both codecs (the
        // acceptance bar of the hotpath bench, checked here structurally).
        let m = 20_000;
        let nnz = m / 20;
        let v = sv(m, &(0..nnz).map(|i| ((i * 20) as u32, 1.0)).collect::<Vec<_>>());
        let mut jb = Vec::new();
        JavaSer::encode_sparse_into(&v, &mut jb);
        assert!(jb.len() * 5 < java_encoded_len(m), "java {} vs {}", jb.len(), java_encoded_len(m));
        let mut pb = Vec::new();
        PickleSer::encode_sparse_into(&v, &mut pb);
        assert!(pb.len() * 5 < pickle_encoded_len(m));
    }
}
