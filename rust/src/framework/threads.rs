//! Real-thread engine: physically parallel workers over channels.
//!
//! Unlike the virtual-clock engines (which *model* the paper's cluster so
//! figures are reproducible on one core), this engine actually runs K
//! worker threads with message-passing AllReduce — the closest this
//! testbed gets to real distribution. Timing here is wall-clock, not
//! virtual. Used by the e2e examples and as a cross-check that the
//! virtual-clock trajectories equal physically-parallel trajectories
//! (same seeds ⇒ same Δv, regardless of execution interleaving).
//!
//! ## Zero-allocation round protocol
//!
//! The original implementation paid, per round: a full clone of the shared
//! vector `v` into *every* worker (K·m doubles), a clone of the label
//! vector at construction per worker, a fresh Δv allocation per worker per
//! round and a serial K-pass fold at the master — exactly the framework
//! overheads the paper indicts. The broadcast, solve and reduce paths now
//! run allocation-free in steady state (what remains per round is the
//! caller-owned aggregate `Vec` the `run_round` API returns, plus the
//! small timing vectors):
//!
//! * `v` is written once into an `Arc<Vec<f64>>` and *shared* with all
//!   workers (true shared-memory broadcast; `Arc::make_mut` reclaims the
//!   buffer after the barrier, so no allocation either);
//! * labels `b` are a construction-time `Arc` shared by every rank;
//! * each `Round` message carries a recycled [`linalg::DeltaSlot`]; the
//!   worker fills it with its Δv — **sparse when the raw frame is cheaper
//!   than dense** (the DESIGN.md §7 cutover), dense otherwise — and the
//!   slot comes home with the reply, orbiting master ↔ workers forever;
//! * the master combines the K deltas with the sparse-aware pairwise
//!   [`linalg::DeltaReducer`] **in rank order**, making the result
//!   bit-identical to the virtual-clock MPI engine regardless of arrival
//!   interleaving or frame representation (asserted by
//!   `tests/integration_allreduce.rs` and
//!   `tests/integration_sparse_frames.rs`).

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::{DistEngine, EngineOptions, RoundTiming};
use crate::config::{Impl, TrainConfig};
use crate::data::{Dataset, Partitioning, WorkerData};
use crate::linalg::{self, DeltaReducer, DeltaSlot};
use crate::solver::{scd::NativeScd, LocalSolver, SolveRequest, SolveResult};

enum ToWorker {
    Round {
        /// Shared-memory broadcast of v — one copy total, not one per rank.
        v: Arc<Vec<f64>>,
        h: usize,
        seed: u64,
        /// Recycled Δv slot; returns with the reply carrying this round's
        /// delta in whichever representation the cutover picked.
        recycle: DeltaSlot,
    },
    GetAlpha,
    /// Replace the rank's local α with this slice (checkpoint resume).
    /// Channel ordering guarantees it lands before any later `Round`.
    SetAlpha(Vec<f64>),
    Shutdown,
}

enum FromWorker {
    RoundDone {
        worker: usize,
        delta: DeltaSlot,
        compute_s: f64,
    },
    Alpha {
        worker: usize,
        alpha: Vec<f64>,
    },
}

struct WorkerHandle {
    tx: mpsc::Sender<ToWorker>,
    join: Option<JoinHandle<()>>,
}

/// Physically parallel rank-per-thread engine (MPI semantics).
pub struct ThreadedMpiEngine {
    workers: Vec<WorkerHandle>,
    rx: mpsc::Receiver<FromWorker>,
    global_ids: Vec<Vec<u32>>,
    n_locals: Vec<usize>,
    n_total: usize,
    m: usize,
    wall: f64,
    /// Reused broadcast buffer; refcount returns to 1 at the round barrier.
    v_shared: Arc<Vec<f64>>,
    /// Spare Δv slots cycling master → worker → master.
    spare: Vec<DeltaSlot>,
    /// Per-rank landing slots for this round's deltas (worker order, so the
    /// reduction tree is deterministic under any arrival interleaving).
    slots: Vec<DeltaSlot>,
    /// Sparse-aware pairwise reducer (same tree as every other engine).
    reducer: DeltaReducer,
}

impl ThreadedMpiEngine {
    /// Engine with the raw-frame cutover (sparse Δv when cheaper).
    pub fn new(ds: &Dataset, parts: &Partitioning, cfg: &TrainConfig) -> ThreadedMpiEngine {
        ThreadedMpiEngine::with_cutover(ds, parts, cfg, linalg::raw_sparse_cutover(ds.m()))
    }

    /// Engine with every rank forced to dense frames (A/B baseline).
    pub fn new_dense_frames(
        ds: &Dataset,
        parts: &Partitioning,
        cfg: &TrainConfig,
    ) -> ThreadedMpiEngine {
        ThreadedMpiEngine::with_cutover(ds, parts, cfg, 0)
    }

    /// Construct from [`EngineOptions`] — the unified-registry path
    /// ([`crate::framework::build_any`]). `dense_frames` maps to a zero
    /// cutover exactly like the virtual engines; `time_scale` is inert
    /// here (this engine reports wall-clock time).
    pub fn with_options(
        ds: &Dataset,
        parts: &Partitioning,
        cfg: &TrainConfig,
        opts: &EngineOptions,
    ) -> ThreadedMpiEngine {
        let cutover = if opts.dense_frames {
            0
        } else {
            linalg::raw_sparse_cutover(ds.m())
        };
        ThreadedMpiEngine::with_cutover(ds, parts, cfg, cutover)
    }

    /// Engine with an explicit Δv frame cutover (nnz threshold; 0 = dense
    /// always). Workers copy the threshold and make the sparse/dense call
    /// locally — the master never inspects the dense Δv.
    pub fn with_cutover(
        ds: &Dataset,
        parts: &Partitioning,
        cfg: &TrainConfig,
        cutover_nnz: usize,
    ) -> ThreadedMpiEngine {
        let (result_tx, rx) = mpsc::channel::<FromWorker>();
        let mut workers = Vec::new();
        let mut global_ids = Vec::new();
        let mut n_locals = Vec::new();
        // `Problem` is Copy + Send: each rank owns its copy, exactly like
        // real MPI ranks own their hyper-parameters.
        let (problem, sigma) = (cfg.problem, cfg.sigma());
        // One shared label vector for all ranks (the paper's workers each
        // hold b; in shared memory one copy serves everyone).
        let b_shared: Arc<Vec<f64>> = Arc::new(ds.b.clone());

        for (w, cols) in parts.parts.iter().enumerate() {
            let data = WorkerData::from_columns(&ds.a, cols);
            global_ids.push(data.global_ids.clone());
            n_locals.push(data.n_local());
            let (tx, worker_rx) = mpsc::channel::<ToWorker>();
            let result_tx = result_tx.clone();
            let b = Arc::clone(&b_shared);
            let join = std::thread::Builder::new()
                .name(format!("rank-{}", w))
                .spawn(move || {
                    let mut alpha = vec![0.0; data.n_local()];
                    let mut solver = NativeScd::new();
                    let mut res = SolveResult::default();
                    while let Ok(msg) = worker_rx.recv() {
                        match msg {
                            ToWorker::Round {
                                v,
                                h,
                                seed,
                                mut recycle,
                            } => {
                                let req = SolveRequest {
                                    v: v.as_slice(),
                                    b: &b,
                                    h,
                                    problem: &problem,
                                    sigma,
                                    seed: seed ^ (w as u64).wrapping_mul(0x9E3779B97F4A7C15),
                                };
                                let t0 = Instant::now();
                                solver.solve_into(&data, &alpha, &req, &mut res);
                                let compute_s = t0.elapsed().as_secs_f64();
                                linalg::add_assign(&mut alpha, &res.delta_alpha);
                                // Emit whichever frame is cheaper into the
                                // recycled slot (its arenas keep capacity
                                // across orbits — no steady-state allocs).
                                recycle.fill_from_dense(&res.delta_v, cutover_nnz);
                                // Drop our v reference BEFORE the reply so
                                // the master (which proceeds only after all
                                // replies) sees refcount 1 and reuses the
                                // broadcast buffer without cloning.
                                drop(v);
                                let _ = result_tx.send(FromWorker::RoundDone {
                                    worker: w,
                                    delta: recycle,
                                    compute_s,
                                });
                            }
                            ToWorker::GetAlpha => {
                                let _ = result_tx.send(FromWorker::Alpha {
                                    worker: w,
                                    alpha: alpha.clone(),
                                });
                            }
                            ToWorker::SetAlpha(new_alpha) => {
                                debug_assert_eq!(new_alpha.len(), alpha.len());
                                alpha = new_alpha;
                            }
                            ToWorker::Shutdown => break,
                        }
                    }
                })
                .expect("spawn worker thread");
            workers.push(WorkerHandle {
                tx,
                join: Some(join),
            });
        }

        let k = workers.len();
        ThreadedMpiEngine {
            workers,
            rx,
            global_ids,
            n_locals,
            n_total: ds.n(),
            m: ds.m(),
            wall: 0.0,
            v_shared: Arc::new(Vec::with_capacity(ds.m())),
            spare: (0..k).map(|_| DeltaSlot::new()).collect(),
            slots: (0..k).map(|_| DeltaSlot::new()).collect(),
            reducer: DeltaReducer::new(ds.m(), cutover_nnz),
        }
    }
}

impl DistEngine for ThreadedMpiEngine {
    fn imp(&self) -> Impl {
        Impl::Mpi
    }

    fn engine(&self) -> super::Engine {
        super::Engine::Threads {
            k: self.workers.len(),
        }
    }

    fn num_workers(&self) -> usize {
        self.workers.len()
    }

    fn n_locals(&self) -> Vec<usize> {
        self.n_locals.clone()
    }

    fn alpha_global(&self) -> Vec<f64> {
        for w in &self.workers {
            let _ = w.tx.send(ToWorker::GetAlpha);
        }
        let mut out = vec![0.0; self.n_total];
        for _ in 0..self.workers.len() {
            if let Ok(FromWorker::Alpha { worker, alpha }) = self.rx.recv() {
                for (&gid, &a) in self.global_ids[worker].iter().zip(alpha.iter()) {
                    out[gid as usize] = a;
                }
            }
        }
        out
    }

    fn load_alpha(&mut self, alpha_global: &[f64]) {
        for (w, wk) in self.workers.iter().enumerate() {
            let local: Vec<f64> = self.global_ids[w]
                .iter()
                .map(|&gid| alpha_global[gid as usize])
                .collect();
            let _ = wk.tx.send(ToWorker::SetAlpha(local));
        }
    }

    fn clock(&self) -> f64 {
        self.wall
    }

    fn run_round(&mut self, v: &[f64], h: usize, round_seed: u64) -> (Vec<f64>, RoundTiming) {
        let k = self.workers.len();
        let t0 = Instant::now();

        // Broadcast: one copy of v into the shared buffer, then an Arc
        // clone per worker (pointer bump — the shared-memory equivalent of
        // MPI_Bcast over ranks on one node). All worker references were
        // dropped before last round's replies, so make_mut reclaims the
        // existing buffer without cloning or allocating.
        {
            let buf = Arc::make_mut(&mut self.v_shared);
            buf.clear();
            buf.extend_from_slice(v);
        }
        for wk in self.workers.iter() {
            let _ = wk.tx.send(ToWorker::Round {
                v: Arc::clone(&self.v_shared),
                h,
                seed: round_seed,
                recycle: self.spare.pop().unwrap_or_default(),
            });
        }

        // Gather into rank-ordered slots (replies arrive in any order).
        let mut computes = vec![0.0; k];
        let mut bytes_up = 0u64;
        for _ in 0..k {
            match self.rx.recv().expect("worker died") {
                FromWorker::RoundDone {
                    worker,
                    delta,
                    compute_s,
                } => {
                    bytes_up += delta.raw_bytes(self.m) as u64;
                    self.slots[worker] = delta;
                    computes[worker] = compute_s;
                }
                FromWorker::Alpha { .. } => unreachable!("unexpected alpha reply"),
            }
        }

        // Sparse-aware pairwise tree reduce in rank order — same tree as
        // the virtual-clock MPI engine, hence bit-identical Δv whatever
        // mix of representations the workers chose.
        let rt0 = Instant::now();
        let agg = self.reducer.reduce_collect(&mut self.slots);
        let t_master = rt0.elapsed().as_secs_f64();
        // All K slots go back to the spare orbit for the next round.
        for slot in self.slots.iter_mut() {
            self.spare.push(std::mem::take(slot));
        }

        let wall = t0.elapsed().as_secs_f64();
        self.wall += wall;
        let t_worker = computes.iter().cloned().fold(0.0f64, f64::max);
        let timing = RoundTiming {
            t_worker,
            t_master,
            t_overhead: (wall - t_worker - t_master).max(0.0),
            worker_compute: computes,
            // Actual emitted frame bytes (sparse where cheaper).
            bytes_up,
            // Shared-memory broadcast moves one m-vector, not K.
            bytes_down: (self.m * 8) as u64,
        };
        (agg, timing)
    }
}

impl Drop for ThreadedMpiEngine {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(ToWorker::Shutdown);
        }
        for w in self.workers.iter_mut() {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{webspam_like, SyntheticSpec};
    use crate::data::Partitioner;
    use crate::framework::mpi::MpiEngine;

    fn setup(k: usize) -> (Dataset, TrainConfig, Partitioning) {
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = k;
        let parts = Partitioning::build(Partitioner::Range, &ds.a, k, 0);
        (ds, cfg, parts)
    }

    #[test]
    fn threaded_round_is_consistent() {
        let (ds, cfg, parts) = setup(4);
        let mut eng = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        let v = vec![0.0; ds.m()];
        let (dv, timing) = eng.run_round(&v, 50, 1);
        let alpha = eng.alpha_global();
        let want = ds.shared_vector(&alpha);
        for (a, b) in dv.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-9);
        }
        assert!(timing.t_worker > 0.0);
        assert!(eng.clock() > 0.0);
    }

    #[test]
    fn threaded_matches_virtual_engine_numerically() {
        // Physical parallelism must not change the math: same seeds ⇒ the
        // exact same Δv as the discrete-event MPI engine.
        let (ds, cfg, parts) = setup(4);
        let mut threaded = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        let mut virtual_eng = MpiEngine::build(&ds, &parts, &cfg);
        let mut v1 = vec![0.0; ds.m()];
        let mut v2 = vec![0.0; ds.m()];
        for round in 0..5 {
            let (dv1, _) = threaded.run_round(&v1, 40, round);
            let (dv2, _) = virtual_eng.run_round(&v2, 40, round);
            for (a, b) in dv1.iter().zip(dv2.iter()) {
                assert!((a - b).abs() < 1e-12, "round {}: {} vs {}", round, a, b);
            }
            linalg::add_assign(&mut v1, &dv1);
            linalg::add_assign(&mut v2, &dv2);
        }
        let a1 = threaded.alpha_global();
        let a2 = virtual_eng.alpha_global();
        for (x, y) in a1.iter().zip(a2.iter()) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn sparse_and_dense_frame_engines_agree_bitwise() {
        // Small H → sparse frames on the adaptive engine; the dense-forced
        // engine must see the exact same Δv bits and strictly more bytes.
        let (ds, cfg, parts) = setup(4);
        let mut adaptive = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        let mut dense = ThreadedMpiEngine::new_dense_frames(&ds, &parts, &cfg);
        let mut v1 = vec![0.0; ds.m()];
        let mut v2 = vec![0.0; ds.m()];
        let mut saved = false;
        for round in 0..4 {
            let (dv1, t1) = adaptive.run_round(&v1, 2, round);
            let (dv2, t2) = dense.run_round(&v2, 2, round);
            for (a, b) in dv1.iter().zip(dv2.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert!(t1.bytes_up <= t2.bytes_up);
            saved |= t1.bytes_up < t2.bytes_up;
            linalg::add_assign(&mut v1, &dv1);
            linalg::add_assign(&mut v2, &dv2);
        }
        assert!(saved, "adaptive engine never emitted a cheaper sparse frame");
    }

    #[test]
    fn trains_to_target() {
        let (ds, mut cfg, parts) = setup(2);
        cfg.max_rounds = 1500;
        let mut eng = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        let report = crate::session::Session::builder(&ds)
            .config(cfg.clone())
            .attach(&mut eng)
            .build()
            .unwrap()
            .run();
        assert!(
            report.time_to_target.is_some(),
            "threaded engine missed target: {:?}",
            report.final_suboptimality
        );
        assert_eq!(report.impl_name, "threads:2");
    }

    #[test]
    fn clean_shutdown_under_drop() {
        let (ds, cfg, parts) = setup(3);
        {
            let mut eng = ThreadedMpiEngine::new(&ds, &parts, &cfg);
            let v = vec![0.0; ds.m()];
            let _ = eng.run_round(&v, 10, 0);
            // eng dropped here — must join all threads without hanging
        }
    }

    #[test]
    fn single_worker_degenerate_case() {
        let (ds, cfg, parts) = setup(1);
        let mut eng = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        let v = vec![0.0; ds.m()];
        let (dv, _) = eng.run_round(&v, 30, 0);
        assert!(dv.iter().any(|&x| x != 0.0));
    }
}
