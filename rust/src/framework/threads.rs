//! Real-thread engine: physically parallel workers over channels.
//!
//! Unlike the virtual-clock engines (which *model* the paper's cluster so
//! figures are reproducible on one core), this engine actually runs K
//! worker threads with message-passing AllReduce — the closest this
//! testbed gets to real distribution. Timing here is wall-clock, not
//! virtual. Used by the e2e examples and as a cross-check that the
//! virtual-clock trajectories equal physically-parallel trajectories
//! (same seeds ⇒ same Δv, regardless of execution interleaving).
//!
//! ## Nested two-level parallelism (DESIGN.md §10)
//!
//! With `threads_per_worker = t > 1` every rank owns a **persistent
//! sub-pool**: `t − 1` sub-threads plus the rank thread itself, each
//! driving one monomorphized [`NativeScd`] over its own sub-shard — the
//! paper's one-rank-per-*core* MPI layout recovered inside a K-wide
//! communication topology. The sub-shards are the parts of the flat `K·t`
//! partitioning, σ′ = γ·K·t and sub-shard `g = w·t + s` seeds like flat
//! rank `g`, so the α/Δv trajectories are **bit-identical** to
//! `Threads { k: K·t, t: 1 }` (`tests/integration_nested.rs`). The rank
//! combines its `t` sub-deltas with the within-block pairs of the flat
//! tree ([`linalg::NestedTreePlan`]) and ships only the forest roots; the
//! master completes the cross-rank pairs in flat-tree order.
//!
//! ## Zero-allocation round protocol
//!
//! The original implementation paid, per round: a full clone of the shared
//! vector `v` into *every* worker (K·m doubles), a clone of the label
//! vector at construction per worker, a fresh Δv allocation per worker per
//! round and a serial K-pass fold at the master — exactly the framework
//! overheads the paper indicts. The broadcast, solve and reduce paths now
//! run allocation-free in steady state (what remains per round is the
//! caller-owned aggregate `Vec` the `run_round` API returns, plus the
//! small timing vectors):
//!
//! * `v` is written once into an `Arc<Vec<f64>>` and *shared* with all
//!   workers and sub-solvers (true shared-memory broadcast;
//!   `Arc::make_mut` reclaims the buffer after the barrier, so no
//!   allocation either);
//! * labels `b` are a construction-time `Arc` shared by every rank;
//! * each `Round` message carries the rank's recycled root
//!   [`linalg::DeltaSlot`]s (the `Vec` itself orbits too); each sub-solver
//!   keeps its own slot orbiting rank ↔ sub, fills it with its Δv —
//!   **sparse when the raw frame is cheaper than dense** (the DESIGN.md §7
//!   cutover), dense otherwise — and all sub-solver scratch (residuals,
//!   α, results) lives in persistent per-sub-shard buffers;
//! * the master scatters returned roots into their flat-tree positions and
//!   completes the cross-rank pairs with the sparse-aware
//!   [`linalg::DeltaReducer`] **in flat-tree order**, making the result
//!   bit-identical to the virtual-clock MPI engine regardless of arrival
//!   interleaving or frame representation (asserted by
//!   `tests/integration_allreduce.rs`, `tests/integration_sparse_frames.rs`
//!   and `tests/integration_nested.rs`).

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::chaos::{ChaosRuntime, RoundChaos};
use super::{DistEngine, EngineOptions, RoundTiming};
use crate::config::{Impl, Precision, TrainConfig};
use crate::data::{Dataset, Partitioning, WorkerData};
use crate::linalg::{self, DeltaReducer, DeltaSlot, NestedTreePlan};
use crate::problem::Problem;
use crate::solver::{scd::NativeScd, LocalSolver, SolveRequest, SolveResult};

const SEED_GOLDEN: u64 = 0x9E3779B97F4A7C15;

enum ToWorker {
    Round {
        /// Shared-memory broadcast of v — one copy total, not one per rank.
        v: Arc<Vec<f64>>,
        h: usize,
        seed: u64,
        /// Physical straggler injection (chaos layer, DESIGN.md §12): the
        /// rank really sleeps `(drag − 1)×` its busy time before replying.
        /// Exactly 1.0 without chaos — the clean path never sleeps.
        drag: f64,
        /// Recycled root slots (in `plan.roots(w)` order); they return with
        /// the reply carrying this round's forest roots. The `Vec` orbits
        /// master ↔ rank forever — no steady-state allocations.
        recycle: Vec<DeltaSlot>,
    },
    GetAlpha,
    /// Replace the rank's local α (concatenated over its sub-shards) with
    /// this slice (checkpoint resume). Channel ordering guarantees it
    /// lands before any later `Round`.
    SetAlpha(Vec<f64>),
    Shutdown,
}

enum FromWorker {
    RoundDone {
        worker: usize,
        /// The rank's forest roots after its local reduce stage.
        roots: Vec<DeltaSlot>,
        compute_s: f64,
        /// The round seed this reply answers. Under speculation the master
        /// races two replies per target rank; the seed tag lets it accept
        /// the first fresh one and bank the loser's containers even when
        /// the loser drifts in during a later round's gather.
        seed: u64,
    },
    Alpha {
        worker: usize,
        alpha: Vec<f64>,
    },
}

enum ToSub {
    Solve {
        v: Arc<Vec<f64>>,
        h: usize,
        seed: u64,
        /// Recycled Δv slot orbiting rank ↔ sub.
        slot: DeltaSlot,
    },
    GetAlpha,
    SetAlpha(Vec<f64>),
    Shutdown,
}

enum FromSub {
    Solved {
        sub: usize,
        slot: DeltaSlot,
    },
    Alpha {
        sub: usize,
        alpha: Vec<f64>,
    },
}

/// One sub-shard's persistent solver state (rank-inline or sub-thread).
/// The column data sits behind an `Arc` so a chaos respawn (and the
/// speculation shadow) can rebuild a rank's shards without re-slicing the
/// dataset.
struct SubShard {
    data: Arc<WorkerData>,
    alpha: Vec<f64>,
    solver: NativeScd,
    res: SolveResult,
}

impl SubShard {
    /// Run one round's H steps and fill `slot` with the cheaper frame.
    /// All scratch is persistent — steady-state solves never allocate.
    #[allow(clippy::too_many_arguments)]
    fn solve_round(
        &mut self,
        v: &[f64],
        b: &[f64],
        h: usize,
        problem: &Problem,
        sigma: f64,
        seed: u64,
        flat_rank: usize,
        cutover_nnz: usize,
        slot: &mut DeltaSlot,
    ) {
        let req = SolveRequest {
            v,
            b,
            h,
            problem,
            sigma,
            // Sub-shard g seeds exactly like rank g of the flat K·t ring.
            seed: seed ^ (flat_rank as u64).wrapping_mul(SEED_GOLDEN),
        };
        self.solver.solve_into(&self.data, &self.alpha, &req, &mut self.res);
        linalg::add_assign(&mut self.alpha, &self.res.delta_alpha);
        slot.fill_from_dense(&self.res.delta_v, cutover_nnz);
    }
}

struct SubHandle {
    tx: mpsc::Sender<ToSub>,
    join: Option<JoinHandle<()>>,
}

struct WorkerHandle {
    tx: mpsc::Sender<ToWorker>,
    join: Option<JoinHandle<()>>,
}

/// Physically parallel rank-per-thread engine (MPI semantics), with an
/// optional persistent sub-pool of `t` local solvers per rank (nested
/// two-level parallelism — see the module docs).
pub struct ThreadedMpiEngine {
    workers: Vec<WorkerHandle>,
    rx: mpsc::Receiver<FromWorker>,
    /// Per-rank global column ids, concatenated over the rank's sub-shards
    /// in sub order (matches the layout of the rank's α replies).
    global_ids: Vec<Vec<u32>>,
    /// Per-sub-shard column counts (rank-major, `K·t` entries).
    n_locals: Vec<usize>,
    n_total: usize,
    m: usize,
    t: usize,
    /// Flat K·t tree split into rank-local and cross-rank stages.
    plan: NestedTreePlan,
    wall: f64,
    /// Reused broadcast buffer; refcount returns to 1 at the round barrier.
    v_shared: Arc<Vec<f64>>,
    /// Flat-tree slot array (`K·t` positions; only forest-root positions
    /// ever hold data between the gather and the cross-rank reduce).
    slots: Vec<DeltaSlot>,
    /// Per-rank orbiting `Vec`s carrying root slots in Round messages.
    root_vecs: Vec<Vec<DeltaSlot>>,
    /// Sparse-aware pairwise reducer (same tree as every other engine).
    reducer: DeltaReducer,
    /// Chaos runtime (drag factors, armed fault, speculation) — `None` on
    /// the clean path, which then behaves exactly as before the chaos
    /// layer existed.
    chaos: Option<ChaosRuntime>,
    /// Respawn context for physical worker deaths (retained only under
    /// chaos).
    spawn_ctx: Option<SpawnCtx>,
    /// Speculative re-execution replica of the designated straggler rank.
    shadow: Option<ShadowState>,
}

impl ThreadedMpiEngine {
    /// Engine with the raw-frame cutover (sparse Δv when cheaper).
    pub fn new(ds: &Dataset, parts: &Partitioning, cfg: &TrainConfig) -> ThreadedMpiEngine {
        ThreadedMpiEngine::with_cutover(ds, parts, cfg, linalg::raw_sparse_cutover(ds.m()))
    }

    /// Engine with every rank forced to dense frames (A/B baseline).
    pub fn new_dense_frames(
        ds: &Dataset,
        parts: &Partitioning,
        cfg: &TrainConfig,
    ) -> ThreadedMpiEngine {
        ThreadedMpiEngine::with_cutover(ds, parts, cfg, 0)
    }

    /// Construct from [`EngineOptions`] — the unified-registry path
    /// ([`crate::framework::build_any`]). `dense_frames` maps to a zero
    /// cutover exactly like the virtual engines, `threads_per_worker`
    /// selects the nested sub-pool layout; `time_scale` is inert here
    /// (this engine reports wall-clock time).
    pub fn with_options(
        ds: &Dataset,
        parts: &Partitioning,
        cfg: &TrainConfig,
        opts: &EngineOptions,
    ) -> ThreadedMpiEngine {
        let cutover = if opts.dense_frames {
            0
        } else {
            linalg::raw_sparse_cutover(ds.m())
        };
        ThreadedMpiEngine::new_full(
            ds,
            parts,
            cfg,
            cutover,
            opts.threads_per_worker.max(1),
            ChaosRuntime::from_opts(opts, cfg.workers),
        )
    }

    /// Engine with an explicit Δv frame cutover (nnz threshold; 0 = dense
    /// always) and one solver per rank.
    pub fn with_cutover(
        ds: &Dataset,
        parts: &Partitioning,
        cfg: &TrainConfig,
        cutover_nnz: usize,
    ) -> ThreadedMpiEngine {
        ThreadedMpiEngine::with_cutover_nested(ds, parts, cfg, cutover_nnz, 1)
    }

    /// The full constructor: explicit cutover and `t` sub-solvers per rank
    /// over the flat `K·t` partitioning ([`Partitioning::build_nested`]).
    /// Workers copy the cutover threshold and make the sparse/dense call
    /// locally — the master never inspects the dense Δv.
    pub fn with_cutover_nested(
        ds: &Dataset,
        parts: &Partitioning,
        cfg: &TrainConfig,
        cutover_nnz: usize,
        t: usize,
    ) -> ThreadedMpiEngine {
        ThreadedMpiEngine::new_full(ds, parts, cfg, cutover_nnz, t, None)
    }

    /// Innermost constructor: everything above plus the optional chaos
    /// runtime (per-rank drag factors, fault plan, speculation shadow —
    /// DESIGN.md §12).
    fn new_full(
        ds: &Dataset,
        parts: &Partitioning,
        cfg: &TrainConfig,
        cutover_nnz: usize,
        t: usize,
        chaos: Option<ChaosRuntime>,
    ) -> ThreadedMpiEngine {
        assert!(t >= 1, "need at least one sub-solver per rank");
        assert_eq!(
            parts.parts.len(),
            cfg.workers * t,
            "nested layout needs the flat K·t partitioning"
        );
        let k = cfg.workers;
        let plan = NestedTreePlan::new(k, t);
        let (result_tx, rx) = mpsc::channel::<FromWorker>();
        // `Problem` is Copy + Send: each rank owns its copy, exactly like
        // real MPI ranks own their hyper-parameters. σ′ = γ·K·t — the flat
        // ring's value, to the bit.
        let (problem, sigma) = (cfg.problem, cfg.sigma_t(t));
        // One shared label vector for all ranks (the paper's workers each
        // hold b; in shared memory one copy serves everyone).
        let b_shared: Arc<Vec<f64>> = Arc::new(ds.b.clone());

        // Column data per sub-shard behind `Arc`s so a chaos respawn (and
        // the speculation shadow) can rebuild a rank's solver state
        // without re-slicing the dataset.
        let shard_data: Vec<Vec<Arc<WorkerData>>> = (0..k)
            .map(|w| {
                parts
                    .rank_shards(w, t)
                    .iter()
                    .map(|cols| Arc::new(WorkerData::from_columns(&ds.a, cols)))
                    .collect()
            })
            .collect();
        let mut global_ids = Vec::new();
        let mut n_locals = Vec::new();
        for rank in &shard_data {
            let mut rank_ids = Vec::new();
            for d in rank {
                rank_ids.extend_from_slice(&d.global_ids);
                n_locals.push(d.n_local());
            }
            global_ids.push(rank_ids);
        }

        let workers: Vec<WorkerHandle> = (0..k)
            .map(|w| {
                spawn_worker(
                    w,
                    w,
                    build_shards(&shard_data[w], cfg.precision),
                    t,
                    &plan,
                    Arc::clone(&b_shared),
                    problem,
                    sigma,
                    cutover_nnz,
                    ds.m(),
                    result_tx.clone(),
                )
            })
            .collect();

        // Chaos state. The respawn context is retained ONLY under chaos —
        // the clean path keeps its fail-loud recv semantics (all senders
        // dropped ⇒ recv errors instead of hanging). The shadow replica
        // mirrors the designated straggler rank and races it every round
        // with identical seeds; the first fresh reply wins (DESIGN.md §12).
        let (spawn_ctx, shadow) = match &chaos {
            Some(c) => {
                let ctx = SpawnCtx {
                    shard_data,
                    b: Arc::clone(&b_shared),
                    problem,
                    sigma,
                    precision: cfg.precision,
                    cutover_nnz,
                    m: ds.m(),
                    result_tx: result_tx.clone(),
                };
                let shadow = if c.spec.speculation {
                    let r = c.speculation_target(k);
                    let handle = spawn_worker(
                        r,
                        k,
                        build_shards(&ctx.shard_data[r], ctx.precision),
                        t,
                        &plan,
                        Arc::clone(&ctx.b),
                        problem,
                        sigma,
                        cutover_nnz,
                        ctx.m,
                        ctx.result_tx.clone(),
                    );
                    Some(ShadowState {
                        rank: r,
                        handle,
                        slots: (0..plan.roots(r).len()).map(|_| DeltaSlot::new()).collect(),
                        carrier: Vec::with_capacity(plan.roots(r).len()),
                    })
                } else {
                    None
                };
                (Some(ctx), shadow)
            }
            None => (None, None),
        };

        // Empty carrier vecs (capacity only): the root slots themselves
        // live in `slots` between rounds and are moved into the carrier
        // per Round message.
        let root_vecs = (0..k)
            .map(|w| Vec::with_capacity(plan.roots(w).len()))
            .collect();
        ThreadedMpiEngine {
            workers,
            rx,
            global_ids,
            n_locals,
            n_total: ds.n(),
            m: ds.m(),
            t,
            wall: 0.0,
            v_shared: Arc::new(Vec::with_capacity(ds.m())),
            slots: (0..k * t).map(|_| DeltaSlot::new()).collect(),
            root_vecs,
            plan,
            reducer: DeltaReducer::new(ds.m(), cutover_nnz),
            chaos,
            spawn_ctx,
            shadow,
        }
    }
}

/// Everything needed to respawn a dead rank's worker thread mid-run.
/// Held only when chaos is enabled.
struct SpawnCtx {
    shard_data: Vec<Vec<Arc<WorkerData>>>,
    b: Arc<Vec<f64>>,
    problem: Problem,
    sigma: f64,
    precision: Precision,
    cutover_nnz: usize,
    m: usize,
    result_tx: mpsc::Sender<FromWorker>,
}

/// The speculation shadow: a full replica of one rank's worker (same
/// shards, same seeds ⇒ bit-identical solves) racing the original every
/// round. `slots`/`carrier` are its private containers so banked loser
/// replies never alias the accepted winner's slots.
struct ShadowState {
    rank: usize,
    handle: WorkerHandle,
    slots: Vec<DeltaSlot>,
    carrier: Vec<DeltaSlot>,
}

/// Fresh solver state over a rank's (shared, immutable) column data.
fn build_shards(data: &[Arc<WorkerData>], precision: Precision) -> Vec<SubShard> {
    data.iter()
        .map(|d| SubShard {
            alpha: vec![0.0; d.n_local()],
            data: Arc::clone(d),
            solver: NativeScd::with_precision(precision),
            res: SolveResult::default(),
        })
        .collect()
}

/// Spawn one rank's worker thread (plus its `t−1` sub-solver threads).
///
/// `rank` fixes the flat-ring seed block (`g = rank·t + sub`) and the
/// reduction-tree role; `reply_as` stamps outgoing messages. The
/// speculation shadow runs with `reply_as = K` so the master can tell
/// replica replies from real ones while both compute bit-identical
/// results.
#[allow(clippy::too_many_arguments)]
fn spawn_worker(
    rank: usize,
    reply_as: usize,
    mut shards: Vec<SubShard>,
    t: usize,
    plan: &NestedTreePlan,
    b: Arc<Vec<f64>>,
    problem: Problem,
    sigma: f64,
    cutover_nnz: usize,
    m: usize,
    result_tx: mpsc::Sender<FromWorker>,
) -> WorkerHandle {
    let local_pairs: Vec<(usize, usize)> = plan.local_pairs(rank).to_vec();
    let roots: Vec<usize> = plan.roots(rank).to_vec();
    let sub_lens: Vec<usize> = shards.iter().map(|s| s.data.n_local()).collect();
    let (tx, worker_rx) = mpsc::channel::<ToWorker>();
    let join = std::thread::Builder::new()
        .name(format!("rank-{}", reply_as))
        .spawn(move || {
            // ---- persistent sub-pool: shard 0 runs inline on the
            // rank thread, shards 1..t on their own threads -------------
            let mut shard0 = shards.remove(0);
            let (sub_tx, sub_rx) = mpsc::channel::<FromSub>();
            let subs: Vec<SubHandle> = shards
                .into_iter()
                .enumerate()
                .map(|(i, mut shard)| {
                    let sub = i + 1; // sub index within the rank
                    let g = rank * t + sub; // flat rank id
                    let (stx, srx) = mpsc::channel::<ToSub>();
                    let reply = sub_tx.clone();
                    let b = Arc::clone(&b);
                    let join = std::thread::Builder::new()
                        .name(format!("rank-{}-sub-{}", reply_as, sub))
                        .spawn(move || {
                            while let Ok(msg) = srx.recv() {
                                match msg {
                                    ToSub::Solve { v, h, seed, mut slot } => {
                                        shard.solve_round(
                                            &v, &b, h, &problem, sigma, seed, g, cutover_nnz,
                                            &mut slot,
                                        );
                                        // Drop the broadcast ref BEFORE
                                        // replying so the master can
                                        // reclaim the buffer after the
                                        // barrier.
                                        drop(v);
                                        let _ = reply.send(FromSub::Solved { sub, slot });
                                    }
                                    ToSub::GetAlpha => {
                                        let _ = reply.send(FromSub::Alpha {
                                            sub,
                                            alpha: shard.alpha.clone(),
                                        });
                                    }
                                    ToSub::SetAlpha(a) => {
                                        debug_assert_eq!(a.len(), shard.alpha.len());
                                        shard.alpha = a;
                                    }
                                    ToSub::Shutdown => break,
                                }
                            }
                        })
                        .expect("spawn sub-solver thread");
                    SubHandle {
                        tx: stx,
                        join: Some(join),
                    }
                })
                .collect();
            // Drop the rank's own reply-sender: once the sub threads'
            // clones are gone (a sub panicked/died), the recv()s below
            // return Err and the engine fails loudly instead of blocking
            // forever on a reply that cannot come.
            drop(sub_tx);

            // Per-sub Δv slots; root positions are refreshed from each
            // Round's recycled vec.
            let mut slots: Vec<DeltaSlot> = (0..t).map(|_| DeltaSlot::new()).collect();
            let mut reducer = DeltaReducer::new(m, cutover_nnz);

            while let Ok(msg) = worker_rx.recv() {
                match msg {
                    ToWorker::Round {
                        v,
                        h,
                        seed,
                        drag,
                        mut recycle,
                    } => {
                        // Root slots come home from the master in
                        // plan-roots order.
                        debug_assert_eq!(recycle.len(), roots.len());
                        for (&ri, slot) in roots.iter().zip(recycle.drain(..)) {
                            slots[ri] = slot;
                        }
                        #[allow(clippy::disallowed_methods)]
                        // lint: allow(clock) -- worker timers feed the cost model
                        let t0 = Instant::now();
                        // Fan out to the sub-pool, then solve shard 0 on
                        // this thread — physical parallelism across the
                        // rank's cores.
                        for (i, sub) in subs.iter().enumerate() {
                            let _ = sub.tx.send(ToSub::Solve {
                                v: Arc::clone(&v),
                                h,
                                seed,
                                slot: std::mem::take(&mut slots[i + 1]),
                            });
                        }
                        shard0.solve_round(
                            &v, &b, h, &problem, sigma, seed, rank * t, cutover_nnz,
                            &mut slots[0],
                        );
                        for _ in 0..subs.len() {
                            match sub_rx.recv().expect("sub-solver died") {
                                FromSub::Solved { sub, slot } => slots[sub] = slot,
                                FromSub::Alpha { .. } => {
                                    unreachable!("unexpected alpha reply")
                                }
                            }
                        }
                        // Rank-local stage: the within-block pairs of the
                        // flat K·t tree (DESIGN.md §10).
                        reducer.reduce_pairs(&mut slots, &local_pairs);
                        // Chaos straggler: physically sleep off the extra
                        // (drag − 1)× of the measured busy time. Exactly
                        // 1.0 on the clean path — no sleep, no branch
                        // cost worth measuring.
                        if drag > 1.0 {
                            std::thread::sleep(t0.elapsed().mul_f64(drag - 1.0));
                        }
                        let compute_s = t0.elapsed().as_secs_f64();
                        // Drop our v reference BEFORE the reply so the
                        // master (which proceeds only after all replies)
                        // sees refcount 1 and reuses the broadcast buffer
                        // without cloning.
                        drop(v);
                        // Ship the forest roots in the recycled vec.
                        let mut out = recycle;
                        for &ri in &roots {
                            out.push(std::mem::take(&mut slots[ri]));
                        }
                        let _ = result_tx.send(FromWorker::RoundDone {
                            worker: reply_as,
                            roots: out,
                            compute_s,
                            seed,
                        });
                    }
                    ToWorker::GetAlpha => {
                        let mut alpha = shard0.alpha.clone();
                        for sub in &subs {
                            let _ = sub.tx.send(ToSub::GetAlpha);
                        }
                        // Sub replies can interleave: stage them by sub
                        // index, then concatenate in order. A dead sub or
                        // a stray reply must fail loudly (like the Round
                        // path) — a silent hole would shift later shards'
                        // α onto earlier shards' column ids.
                        let mut parts: Vec<Option<Vec<f64>>> = vec![None; subs.len()];
                        for _ in 0..subs.len() {
                            match sub_rx.recv().expect("sub-solver died") {
                                FromSub::Alpha { sub, alpha: a } => parts[sub - 1] = Some(a),
                                FromSub::Solved { .. } => {
                                    unreachable!("unexpected solve reply")
                                }
                            }
                        }
                        for p in parts.into_iter() {
                            alpha.extend_from_slice(&p.expect("missing sub α reply"));
                        }
                        let _ = result_tx.send(FromWorker::Alpha {
                            worker: reply_as,
                            alpha,
                        });
                    }
                    ToWorker::SetAlpha(new_alpha) => {
                        debug_assert_eq!(new_alpha.len(), sub_lens.iter().sum::<usize>());
                        let mut off = sub_lens[0];
                        shard0.alpha.clear();
                        shard0.alpha.extend_from_slice(&new_alpha[..off]);
                        for (i, sub) in subs.iter().enumerate() {
                            let len = sub_lens[i + 1];
                            let _ = sub
                                .tx
                                .send(ToSub::SetAlpha(new_alpha[off..off + len].to_vec()));
                            off += len;
                        }
                    }
                    ToWorker::Shutdown => {
                        for sub in &subs {
                            let _ = sub.tx.send(ToSub::Shutdown);
                        }
                        for mut sub in subs {
                            if let Some(j) = sub.join.take() {
                                let _ = j.join();
                            }
                        }
                        break;
                    }
                }
            }
        })
        .expect("spawn worker thread");
    WorkerHandle {
        tx,
        join: Some(join),
    }
}

impl DistEngine for ThreadedMpiEngine {
    fn imp(&self) -> Impl {
        Impl::Mpi
    }

    fn engine(&self) -> super::Engine {
        super::Engine::Threads {
            k: self.workers.len(),
            t: self.t,
        }
    }

    fn num_workers(&self) -> usize {
        self.workers.len()
    }

    fn threads_per_worker(&self) -> usize {
        self.t
    }

    fn n_locals(&self) -> Vec<usize> {
        self.n_locals.clone()
    }

    fn alpha_global(&self) -> Vec<f64> {
        for w in &self.workers {
            let _ = w.tx.send(ToWorker::GetAlpha);
        }
        let mut out = vec![0.0; self.n_total];
        let mut got = 0;
        while got < self.workers.len() {
            match self.rx.recv().expect("worker died") {
                FromWorker::Alpha { worker, alpha } => {
                    // The shadow is never polled for α: its state is
                    // implied by its target's (same seeds ⇒ same updates).
                    debug_assert!(worker < self.workers.len());
                    for (&gid, &a) in self.global_ids[worker].iter().zip(alpha.iter()) {
                        out[gid as usize] = a;
                    }
                    got += 1;
                }
                // A speculation loser's stale RoundDone can still be in
                // flight; drop it. Its containers are lost, but the next
                // banking replaces them — reachable only under chaos
                // (clean runs never see a stray reply here).
                FromWorker::RoundDone { .. } => {}
            }
        }
        out
    }

    fn load_alpha(&mut self, alpha_global: &[f64]) {
        for (w, wk) in self.workers.iter().enumerate() {
            let local: Vec<f64> = self.global_ids[w]
                .iter()
                .map(|&gid| alpha_global[gid as usize])
                .collect();
            let _ = wk.tx.send(ToWorker::SetAlpha(local));
        }
        // Keep the speculation replica in lockstep with its target — this
        // is also how a replica whose target died is resynchronized (the
        // session reloads the recovery snapshot into every rank).
        if let Some(sh) = &self.shadow {
            let local: Vec<f64> = self.global_ids[sh.rank]
                .iter()
                .map(|&gid| alpha_global[gid as usize])
                .collect();
            let _ = sh.handle.tx.send(ToWorker::SetAlpha(local));
        }
    }

    fn clock(&self) -> f64 {
        self.wall
    }

    fn arm_chaos(&mut self, rc: RoundChaos) {
        if let Some(c) = self.chaos.as_mut() {
            c.arm(rc);
        }
    }

    fn run_round(&mut self, v: &[f64], h: usize, round_seed: u64) -> (Vec<f64>, RoundTiming) {
        let k = self.workers.len();
        let t = self.t;
        let rc = match self.chaos.as_mut() {
            Some(c) => c.take(),
            None => RoundChaos::default(),
        };
        let dead = rc.death;
        #[allow(clippy::disallowed_methods)]
        // lint: allow(clock) -- real solve wall time feeds the cost model
        let t0 = Instant::now();

        // Broadcast: one copy of v into the shared buffer, then an Arc
        // clone per worker (pointer bump — the shared-memory equivalent of
        // MPI_Bcast over ranks on one node). All worker references were
        // dropped before last round's replies, so make_mut reclaims the
        // existing buffer without cloning or allocating. (Under chaos a
        // lagging speculation loser may still hold last round's ref, in
        // which case make_mut clones — an allocation unreachable on the
        // clean path.)
        {
            let buf = Arc::make_mut(&mut self.v_shared);
            buf.clear();
            buf.extend_from_slice(v);
        }
        for (w, wk) in self.workers.iter().enumerate() {
            if dead == Some(w) {
                // The dying rank gets no work; its root containers were
                // consumed by its last completed round and the replay's
                // broadcast hands it fresh `Default` slots instead.
                continue;
            }
            // Hand each rank back its root slots (plan-roots order); the
            // Vec itself orbits master ↔ rank.
            let mut recycle = std::mem::take(&mut self.root_vecs[w]);
            for &ri in self.plan.roots(w) {
                recycle.push(std::mem::take(&mut self.slots[w * t + ri]));
            }
            let drag = self.chaos.as_ref().map_or(1.0, |c| c.factor(&rc, w));
            let _ = wk.tx.send(ToWorker::Round {
                v: Arc::clone(&self.v_shared),
                h,
                seed: round_seed,
                drag,
                recycle,
            });
        }
        // The shadow races its target with the same v/h/seed but no drag:
        // bit-identical math, faster wall-clock when the target is the
        // straggler. It sits out death rounds — nothing commits on those,
        // and the session's recovery SetAlpha resynchronizes everyone.
        if dead.is_none() {
            if let Some(sh) = self.shadow.as_mut() {
                let mut recycle = std::mem::take(&mut sh.carrier);
                recycle.clear();
                recycle.extend(sh.slots.drain(..));
                // If the previous loser reply has not drifted in yet the
                // pool is short — pad with fresh containers.
                let need = self.plan.roots(sh.rank).len();
                while recycle.len() < need {
                    recycle.push(DeltaSlot::new());
                }
                let _ = sh.handle.tx.send(ToWorker::Round {
                    v: Arc::clone(&self.v_shared),
                    h,
                    seed: round_seed,
                    drag: 1.0,
                    recycle,
                });
            }
        }

        // Gather the forest roots into their flat-tree positions (replies
        // arrive in any order; positions are fixed, so the reduction tree
        // is deterministic under any interleaving). Under speculation the
        // first reply carrying this round's seed wins a rank's slot; the
        // loser (and any stale laggard) is banked into the shadow pool.
        let mut computes = vec![0.0; k];
        let mut bytes_up = 0u64;
        let mut need: Vec<bool> = (0..k).map(|w| dead != Some(w)).collect();
        let want = k - usize::from(dead.is_some());
        let mut got = 0;
        let target = self.shadow.as_ref().map(|s| s.rank);
        while got < want {
            match self.rx.recv().expect("worker died") {
                FromWorker::RoundDone {
                    worker,
                    mut roots,
                    compute_s,
                    seed,
                } => {
                    let rank = if worker == k {
                        target.expect("shadow reply without a shadow")
                    } else {
                        worker
                    };
                    if seed == round_seed && need[rank] {
                        need[rank] = false;
                        got += 1;
                        for (&ri, slot) in self.plan.roots(rank).iter().zip(roots.drain(..)) {
                            bytes_up += slot.raw_bytes(self.m) as u64;
                            self.slots[rank * t + ri] = slot;
                        }
                        self.root_vecs[rank] = roots;
                        computes[rank] = compute_s;
                    } else if let Some(sh) = self.shadow.as_mut() {
                        sh.slots.clear();
                        sh.slots.extend(roots.drain(..));
                        sh.carrier = roots;
                    }
                }
                FromWorker::Alpha { .. } => unreachable!("unexpected alpha reply"),
            }
        }

        if let Some(d) = dead {
            // Physical kill + respawn: tear the rank down for real and
            // rebuild it from the retained spawn context. Nothing from
            // this attempt commits — the Δv is zeroed and the caller
            // (session recovery, DESIGN.md §12) reloads the α snapshot
            // into every rank before replaying the round, which also
            // resets the survivors whose local α advanced in the aborted
            // attempt.
            let ctx = self
                .spawn_ctx
                .as_ref()
                .expect("death armed without a chaos runtime");
            let _ = self.workers[d].tx.send(ToWorker::Shutdown);
            if let Some(j) = self.workers[d].join.take() {
                let _ = j.join();
            }
            self.workers[d] = spawn_worker(
                d,
                d,
                build_shards(&ctx.shard_data[d], ctx.precision),
                t,
                &self.plan,
                Arc::clone(&ctx.b),
                ctx.problem,
                ctx.sigma,
                ctx.cutover_nnz,
                ctx.m,
                ctx.result_tx.clone(),
            );
            let wall = t0.elapsed().as_secs_f64();
            self.wall += wall;
            let t_worker = computes.iter().cloned().fold(0.0f64, f64::max);
            let timing = RoundTiming {
                t_worker,
                t_master: 0.0,
                // Detection + join + respawn are physically real here —
                // the whole abort shows up as overhead.
                t_overhead: (wall - t_worker).max(0.0),
                worker_compute: computes,
                bytes_up: 0,
                bytes_down: (self.m * 8) as u64,
            };
            return (vec![0.0; self.m], timing);
        }

        // Cross-rank stage: the remaining pairs of the flat K·t tree in
        // enumeration order — same combines as the virtual-clock engines,
        // hence bit-identical Δv whatever mix of representations and
        // arrival order the workers produced.
        #[allow(clippy::disallowed_methods)]
        // lint: allow(clock) -- real reduce wall time feeds the cost model
        let rt0 = Instant::now();
        self.reducer.reduce_pairs(&mut self.slots, self.plan.cross_pairs());
        let agg = self.slots[0].densify_collect(self.m);
        let t_master = rt0.elapsed().as_secs_f64();

        let wall = t0.elapsed().as_secs_f64();
        self.wall += wall;
        let t_worker = computes.iter().cloned().fold(0.0f64, f64::max);
        let timing = RoundTiming {
            t_worker,
            t_master,
            t_overhead: (wall - t_worker - t_master).max(0.0),
            worker_compute: computes,
            // Actual emitted frame bytes (sparse where cheaper); only the
            // forest roots cross rank boundaries.
            bytes_up,
            // Shared-memory broadcast moves one m-vector, not K.
            bytes_down: (self.m * 8) as u64,
        };
        (agg, timing)
    }
}

impl Drop for ThreadedMpiEngine {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(ToWorker::Shutdown);
        }
        if let Some(sh) = &self.shadow {
            let _ = sh.handle.tx.send(ToWorker::Shutdown);
        }
        for w in self.workers.iter_mut() {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
        if let Some(sh) = self.shadow.as_mut() {
            if let Some(j) = sh.handle.join.take() {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{webspam_like, SyntheticSpec};
    use crate::data::Partitioner;
    use crate::framework::mpi::MpiEngine;

    fn setup(k: usize) -> (Dataset, TrainConfig, Partitioning) {
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = k;
        let parts = Partitioning::build(Partitioner::Range, &ds.a, k, 0);
        (ds, cfg, parts)
    }

    #[test]
    fn threaded_round_is_consistent() {
        let (ds, cfg, parts) = setup(4);
        let mut eng = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        let v = vec![0.0; ds.m()];
        let (dv, timing) = eng.run_round(&v, 50, 1);
        let alpha = eng.alpha_global();
        let want = ds.shared_vector(&alpha);
        for (a, b) in dv.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-9);
        }
        assert!(timing.t_worker > 0.0);
        assert!(eng.clock() > 0.0);
    }

    #[test]
    fn threaded_matches_virtual_engine_numerically() {
        // Physical parallelism must not change the math: same seeds ⇒ the
        // exact same Δv as the discrete-event MPI engine.
        let (ds, cfg, parts) = setup(4);
        let mut threaded = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        let mut virtual_eng = MpiEngine::build(&ds, &parts, &cfg);
        let mut v1 = vec![0.0; ds.m()];
        let mut v2 = vec![0.0; ds.m()];
        for round in 0..5 {
            let (dv1, _) = threaded.run_round(&v1, 40, round);
            let (dv2, _) = virtual_eng.run_round(&v2, 40, round);
            for (a, b) in dv1.iter().zip(dv2.iter()) {
                assert!((a - b).abs() < 1e-12, "round {}: {} vs {}", round, a, b);
            }
            linalg::add_assign(&mut v1, &dv1);
            linalg::add_assign(&mut v2, &dv2);
        }
        let a1 = threaded.alpha_global();
        let a2 = virtual_eng.alpha_global();
        for (x, y) in a1.iter().zip(a2.iter()) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn nested_subpool_matches_flat_ring_bitwise() {
        // The tentpole acceptance on the physical engine: K ranks × t
        // sub-threads ≡ flat K·t ranks, to the bit, for power-of-two AND
        // non-power-of-two shapes.
        let ds = webspam_like(&SyntheticSpec::small());
        for (k, t) in [(2usize, 2usize), (3, 2), (2, 3), (4, 4)] {
            let mut cfg_nested = TrainConfig::default_for(&ds);
            cfg_nested.workers = k;
            let nparts = Partitioning::build_nested(
                Partitioner::Range,
                &ds.a,
                k,
                t,
                cfg_nested.seed,
            );
            let cutover = linalg::raw_sparse_cutover(ds.m());
            let mut nested =
                ThreadedMpiEngine::with_cutover_nested(&ds, &nparts, &cfg_nested, cutover, t);
            assert_eq!(nested.num_workers(), k);
            assert_eq!(nested.threads_per_worker(), t);
            assert_eq!(
                nested.engine(),
                crate::framework::Engine::Threads { k, t }
            );

            let mut cfg_flat = cfg_nested.clone();
            cfg_flat.workers = k * t;
            let fparts = Partitioning::build(Partitioner::Range, &ds.a, k * t, cfg_flat.seed);
            let mut flat = ThreadedMpiEngine::new(&ds, &fparts, &cfg_flat);

            let mut v1 = vec![0.0; ds.m()];
            let mut v2 = vec![0.0; ds.m()];
            for round in 0..3 {
                let (dv1, _) = nested.run_round(&v1, 12, round);
                let (dv2, _) = flat.run_round(&v2, 12, round);
                for (a, b) in dv1.iter().zip(dv2.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "k={} t={} round {}", k, t, round);
                }
                linalg::add_assign(&mut v1, &dv1);
                linalg::add_assign(&mut v2, &dv2);
            }
            let a1 = nested.alpha_global();
            let a2 = flat.alpha_global();
            for (x, y) in a1.iter().zip(a2.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "k={} t={}", k, t);
            }
        }
    }

    #[test]
    fn nested_load_alpha_roundtrips_through_the_subpool() {
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 2;
        let parts = Partitioning::build_nested(Partitioner::Range, &ds.a, 2, 3, cfg.seed);
        let cutover = linalg::raw_sparse_cutover(ds.m());
        let mut eng = ThreadedMpiEngine::with_cutover_nested(&ds, &parts, &cfg, cutover, 3);
        let snapshot: Vec<f64> = (0..ds.n()).map(|i| (i as f64).cos()).collect();
        eng.load_alpha(&snapshot);
        assert_eq!(eng.alpha_global(), snapshot);
    }

    #[test]
    fn sparse_and_dense_frame_engines_agree_bitwise() {
        // Small H → sparse frames on the adaptive engine; the dense-forced
        // engine must see the exact same Δv bits and strictly more bytes.
        let (ds, cfg, parts) = setup(4);
        let mut adaptive = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        let mut dense = ThreadedMpiEngine::new_dense_frames(&ds, &parts, &cfg);
        let mut v1 = vec![0.0; ds.m()];
        let mut v2 = vec![0.0; ds.m()];
        let mut saved = false;
        for round in 0..4 {
            let (dv1, t1) = adaptive.run_round(&v1, 2, round);
            let (dv2, t2) = dense.run_round(&v2, 2, round);
            for (a, b) in dv1.iter().zip(dv2.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert!(t1.bytes_up <= t2.bytes_up);
            saved |= t1.bytes_up < t2.bytes_up;
            linalg::add_assign(&mut v1, &dv1);
            linalg::add_assign(&mut v2, &dv2);
        }
        assert!(saved, "adaptive engine never emitted a cheaper sparse frame");
    }

    #[test]
    fn trains_to_target() {
        let (ds, mut cfg, parts) = setup(2);
        cfg.max_rounds = 1500;
        let mut eng = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        let report = crate::session::Session::builder(&ds)
            .config(cfg.clone())
            .attach(&mut eng)
            .build()
            .unwrap()
            .run();
        assert!(
            report.time_to_target.is_some(),
            "threaded engine missed target: {:?}",
            report.final_suboptimality
        );
        assert_eq!(report.impl_name, "threads:2");
    }

    #[test]
    fn clean_shutdown_under_drop() {
        let (ds, cfg, parts) = setup(3);
        {
            let mut eng = ThreadedMpiEngine::new(&ds, &parts, &cfg);
            let v = vec![0.0; ds.m()];
            let _ = eng.run_round(&v, 10, 0);
            // eng dropped here — must join all threads without hanging
        }
        // Nested engines must also join their sub-pools.
        let ds2 = webspam_like(&SyntheticSpec::small());
        let mut cfg2 = TrainConfig::default_for(&ds2);
        cfg2.workers = 2;
        let nparts = Partitioning::build_nested(Partitioner::Range, &ds2.a, 2, 2, cfg2.seed);
        {
            let cutover = linalg::raw_sparse_cutover(ds2.m());
            let mut eng = ThreadedMpiEngine::with_cutover_nested(&ds2, &nparts, &cfg2, cutover, 2);
            let v = vec![0.0; ds2.m()];
            let _ = eng.run_round(&v, 10, 0);
        }
    }

    #[test]
    fn single_worker_degenerate_case() {
        let (ds, cfg, parts) = setup(1);
        let mut eng = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        let v = vec![0.0; ds.m()];
        let (dv, _) = eng.run_round(&v, 30, 0);
        assert!(dv.iter().any(|&x| x != 0.0));
    }

    // ---- chaos layer (DESIGN.md §12) --------------------------------

    fn chaos_opts(k: usize, spec: &str) -> EngineOptions {
        let mut opts = EngineOptions::default();
        opts.chaos = Some(
            crate::framework::chaos::ChaosSpec::parse(spec)
                .unwrap()
                .bind(k)
                .unwrap(),
        );
        opts
    }

    #[test]
    fn chaos_drag_physically_slows_the_armed_rank() {
        let (ds, cfg, parts) = setup(2);
        let mut eng = ThreadedMpiEngine::with_options(&ds, &parts, &cfg, &chaos_opts(2, ""));
        let v = vec![0.0; ds.m()];
        let (_, quiet) = eng.run_round(&v, 40, 1);
        eng.arm_chaos(RoundChaos {
            death: None,
            slowdowns: vec![(1, 50.0)],
        });
        let (_, dragged) = eng.run_round(&v, 40, 2);
        // A 50× drag really sleeps off 49× the measured busy time — even
        // with µs-scale solves and timer noise, 3× over the quiet round's
        // compute is a conservative floor.
        assert!(
            dragged.worker_compute[1] > 3.0 * quiet.worker_compute[1],
            "drag did not slow rank 1: quiet {} vs dragged {}",
            quiet.worker_compute[1],
            dragged.worker_compute[1]
        );
    }

    #[test]
    fn chaos_death_respawns_and_replay_matches_clean() {
        let (ds, cfg, parts) = setup(3);
        let mut clean = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        let mut chaotic = ThreadedMpiEngine::with_options(&ds, &parts, &cfg, &chaos_opts(3, ""));

        // A clean round on both engines, then snapshot α (the session's
        // recovery state).
        let v0 = vec![0.0; ds.m()];
        let (dc, _) = clean.run_round(&v0, 25, 7);
        let (dx, _) = chaotic.run_round(&v0, 25, 7);
        assert_eq!(dc, dx);
        let snapshot = clean.alpha_global();
        assert_eq!(snapshot, chaotic.alpha_global());
        let mut v1 = v0.clone();
        linalg::add_assign(&mut v1, &dc);

        // Kill rank 1 mid-round: the attempt commits nothing, the clock
        // still advances (the abort is physically real), and the worker
        // is respawned in place.
        let clock_before = chaotic.clock();
        chaotic.arm_chaos(RoundChaos {
            death: Some(1),
            slowdowns: vec![],
        });
        let (dz, tz) = chaotic.run_round(&v1, 25, 8);
        assert!(dz.iter().all(|x| *x == 0.0));
        assert_eq!(tz.bytes_up, 0);
        assert!(chaotic.clock() > clock_before);

        // Recovery (the session's job): reload the snapshot into every
        // rank, replay the same round — bit-identical to the engine that
        // never saw the fault.
        chaotic.load_alpha(&snapshot);
        let (d1c, _) = clean.run_round(&v1, 25, 8);
        let (d1x, _) = chaotic.run_round(&v1, 25, 8);
        assert_eq!(d1c, d1x);
        assert_eq!(clean.alpha_global(), chaotic.alpha_global());
    }

    #[test]
    fn chaos_speculation_shadow_wins_race_and_keeps_bits() {
        let (ds, cfg, parts) = setup(3);
        let mut clean = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        // The slow@ event binds the speculation target to rank 2; the
        // shadow replica races it every round. The scheduled round itself
        // is irrelevant here — drags are armed manually below.
        let mut dragged =
            ThreadedMpiEngine::with_options(&ds, &parts, &cfg, &chaos_opts(3, "slow@0:2:1000"));
        let mut backed =
            ThreadedMpiEngine::with_options(&ds, &parts, &cfg, &chaos_opts(3, "spec,slow@0:2:1000"));

        let mut vc = vec![0.0; ds.m()];
        let mut vd = vec![0.0; ds.m()];
        let mut vb = vec![0.0; ds.m()];
        for round in 0..3u64 {
            dragged.arm_chaos(RoundChaos {
                death: None,
                slowdowns: vec![(2, 1000.0)],
            });
            backed.arm_chaos(RoundChaos {
                death: None,
                slowdowns: vec![(2, 1000.0)],
            });
            let (a, _) = clean.run_round(&vc, 25, round);
            let (b, td) = dragged.run_round(&vd, 25, round);
            let (c, tb) = backed.run_round(&vb, 25, round);
            // Chaos perturbs time, never bits: all three agree exactly.
            assert_eq!(a, b, "round {}", round);
            assert_eq!(a, c, "round {}", round);
            // The undragged shadow beats a 1000× straggler by a wide
            // margin, so speculation caps the rank's effective compute.
            assert!(
                tb.worker_compute[2] < 0.1 * td.worker_compute[2],
                "round {}: speculation did not win ({} vs {})",
                round,
                tb.worker_compute[2],
                td.worker_compute[2]
            );
            linalg::add_assign(&mut vc, &a);
            linalg::add_assign(&mut vd, &b);
            linalg::add_assign(&mut vb, &c);
        }
        assert_eq!(clean.alpha_global(), backed.alpha_global());
    }
}
