//! Real-thread engine: physically parallel workers over channels.
//!
//! Unlike the virtual-clock engines (which *model* the paper's cluster so
//! figures are reproducible on one core), this engine actually runs K
//! worker threads with message-passing AllReduce — the closest this
//! testbed gets to real distribution. Timing here is wall-clock, not
//! virtual. Used by the e2e examples and as a cross-check that the
//! virtual-clock trajectories equal physically-parallel trajectories
//! (same seeds ⇒ same Δv, regardless of execution interleaving).

use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::{DistEngine, RoundTiming};
use crate::config::{Impl, TrainConfig};
use crate::data::{Dataset, Partitioning, WorkerData};
use crate::linalg;
use crate::solver::{scd::NativeScd, LocalSolver, SolveRequest};

enum ToWorker {
    Round {
        v: Vec<f64>,
        h: usize,
        seed: u64,
    },
    GetAlpha,
    Shutdown,
}

enum FromWorker {
    RoundDone {
        worker: usize,
        delta_v: Vec<f64>,
        compute_s: f64,
    },
    Alpha {
        worker: usize,
        alpha: Vec<f64>,
    },
}

struct WorkerHandle {
    tx: mpsc::Sender<ToWorker>,
    join: Option<JoinHandle<()>>,
}

/// Physically parallel rank-per-thread engine (MPI semantics).
pub struct ThreadedMpiEngine {
    workers: Vec<WorkerHandle>,
    rx: mpsc::Receiver<FromWorker>,
    global_ids: Vec<Vec<u32>>,
    n_locals: Vec<usize>,
    n_total: usize,
    m: usize,
    wall: f64,
}

impl ThreadedMpiEngine {
    pub fn new(ds: &Dataset, parts: &Partitioning, cfg: &TrainConfig) -> ThreadedMpiEngine {
        let (result_tx, rx) = mpsc::channel::<FromWorker>();
        let mut workers = Vec::new();
        let mut global_ids = Vec::new();
        let mut n_locals = Vec::new();
        let (lam_n, eta, sigma) = (cfg.lam_n, cfg.eta, cfg.sigma());
        let b_shared = ds.b.clone();

        for (w, cols) in parts.parts.iter().enumerate() {
            let data = WorkerData::from_columns(&ds.a, cols);
            global_ids.push(data.global_ids.clone());
            n_locals.push(data.n_local());
            let (tx, worker_rx) = mpsc::channel::<ToWorker>();
            let result_tx = result_tx.clone();
            let b = b_shared.clone();
            let join = std::thread::Builder::new()
                .name(format!("rank-{}", w))
                .spawn(move || {
                    let mut alpha = vec![0.0; data.n_local()];
                    let mut solver = NativeScd::new();
                    while let Ok(msg) = worker_rx.recv() {
                        match msg {
                            ToWorker::Round { v, h, seed } => {
                                let req = SolveRequest {
                                    v: &v,
                                    b: &b,
                                    h,
                                    lam_n,
                                    eta,
                                    sigma,
                                    seed: seed ^ (w as u64).wrapping_mul(0x9E3779B97F4A7C15),
                                };
                                let t0 = Instant::now();
                                let res = solver.solve(&data, &alpha, &req);
                                let compute_s = t0.elapsed().as_secs_f64();
                                linalg::add_assign(&mut alpha, &res.delta_alpha);
                                let _ = result_tx.send(FromWorker::RoundDone {
                                    worker: w,
                                    delta_v: res.delta_v,
                                    compute_s,
                                });
                            }
                            ToWorker::GetAlpha => {
                                let _ = result_tx.send(FromWorker::Alpha {
                                    worker: w,
                                    alpha: alpha.clone(),
                                });
                            }
                            ToWorker::Shutdown => break,
                        }
                    }
                })
                .expect("spawn worker thread");
            workers.push(WorkerHandle {
                tx,
                join: Some(join),
            });
        }

        ThreadedMpiEngine {
            workers,
            rx,
            global_ids,
            n_locals,
            n_total: ds.n(),
            m: ds.m(),
            wall: 0.0,
        }
    }
}

impl DistEngine for ThreadedMpiEngine {
    fn imp(&self) -> Impl {
        Impl::Mpi
    }

    fn num_workers(&self) -> usize {
        self.workers.len()
    }

    fn n_locals(&self) -> Vec<usize> {
        self.n_locals.clone()
    }

    fn alpha_global(&self) -> Vec<f64> {
        for w in &self.workers {
            let _ = w.tx.send(ToWorker::GetAlpha);
        }
        let mut out = vec![0.0; self.n_total];
        for _ in 0..self.workers.len() {
            if let Ok(FromWorker::Alpha { worker, alpha }) = self.rx.recv() {
                for (&gid, &a) in self.global_ids[worker].iter().zip(alpha.iter()) {
                    out[gid as usize] = a;
                }
            }
        }
        out
    }

    fn clock(&self) -> f64 {
        self.wall
    }

    fn run_round(&mut self, v: &[f64], h: usize, round_seed: u64) -> (Vec<f64>, RoundTiming) {
        let k = self.workers.len();
        let t0 = Instant::now();

        // Broadcast (real copy per worker — exactly MPI_Bcast semantics).
        for w in &self.workers {
            let _ = w.tx.send(ToWorker::Round {
                v: v.to_vec(),
                h,
                seed: round_seed,
            });
        }

        // Gather + reduce (leader-side sum, real).
        let mut agg = vec![0.0; self.m];
        let mut computes = vec![0.0; k];
        for _ in 0..k {
            match self.rx.recv().expect("worker died") {
                FromWorker::RoundDone {
                    worker,
                    delta_v,
                    compute_s,
                } => {
                    linalg::add_assign(&mut agg, &delta_v);
                    computes[worker] = compute_s;
                }
                FromWorker::Alpha { .. } => unreachable!("unexpected alpha reply"),
            }
        }

        let wall = t0.elapsed().as_secs_f64();
        self.wall += wall;
        let t_worker = computes.iter().cloned().fold(0.0f64, f64::max);
        let timing = RoundTiming {
            t_worker,
            t_master: 0.0,
            t_overhead: (wall - t_worker).max(0.0),
            worker_compute: computes,
            bytes_up: (self.m * 8 * k) as u64,
            bytes_down: (self.m * 8 * k) as u64,
        };
        (agg, timing)
    }
}

impl Drop for ThreadedMpiEngine {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(ToWorker::Shutdown);
        }
        for w in self.workers.iter_mut() {
            if let Some(j) = w.join.take() {
                let _ = j.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{webspam_like, SyntheticSpec};
    use crate::data::Partitioner;
    use crate::framework::mpi::MpiEngine;

    fn setup(k: usize) -> (Dataset, TrainConfig, Partitioning) {
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = k;
        let parts = Partitioning::build(Partitioner::Range, &ds.a, k, 0);
        (ds, cfg, parts)
    }

    #[test]
    fn threaded_round_is_consistent() {
        let (ds, cfg, parts) = setup(4);
        let mut eng = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        let v = vec![0.0; ds.m()];
        let (dv, timing) = eng.run_round(&v, 50, 1);
        let alpha = eng.alpha_global();
        let want = ds.shared_vector(&alpha);
        for (a, b) in dv.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-9);
        }
        assert!(timing.t_worker > 0.0);
        assert!(eng.clock() > 0.0);
    }

    #[test]
    fn threaded_matches_virtual_engine_numerically() {
        // Physical parallelism must not change the math: same seeds ⇒ the
        // exact same Δv as the discrete-event MPI engine.
        let (ds, cfg, parts) = setup(4);
        let mut threaded = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        let mut virtual_eng = MpiEngine::build(&ds, &parts, &cfg);
        let mut v1 = vec![0.0; ds.m()];
        let mut v2 = vec![0.0; ds.m()];
        for round in 0..5 {
            let (dv1, _) = threaded.run_round(&v1, 40, round);
            let (dv2, _) = virtual_eng.run_round(&v2, 40, round);
            for (a, b) in dv1.iter().zip(dv2.iter()) {
                assert!((a - b).abs() < 1e-12, "round {}: {} vs {}", round, a, b);
            }
            linalg::add_assign(&mut v1, &dv1);
            linalg::add_assign(&mut v2, &dv2);
        }
        let a1 = threaded.alpha_global();
        let a2 = virtual_eng.alpha_global();
        for (x, y) in a1.iter().zip(a2.iter()) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn trains_to_target() {
        let (ds, mut cfg, parts) = setup(2);
        cfg.max_rounds = 1500;
        let mut eng = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        let report = crate::coordinator::train(&mut eng, &ds, &cfg);
        assert!(
            report.time_to_target.is_some(),
            "threaded engine missed target: {:.3e}",
            report.final_suboptimality
        );
    }

    #[test]
    fn clean_shutdown_under_drop() {
        let (ds, cfg, parts) = setup(3);
        {
            let mut eng = ThreadedMpiEngine::new(&ds, &parts, &cfg);
            let v = vec![0.0; ds.m()];
            let _ = eng.run_round(&v, 10, 0);
            // eng dropped here — must join all threads without hanging
        }
    }

    #[test]
    fn single_worker_degenerate_case() {
        let (ds, cfg, parts) = setup(1);
        let mut eng = ThreadedMpiEngine::new(&ds, &parts, &cfg);
        let v = vec![0.0; ds.m()];
        let (dv, _) = eng.run_round(&v, 30, 0);
        assert!(dv.iter().any(|&x| x != 0.0));
    }
}
