//! Pluggable H policies: how many local steps each round runs.
//!
//! The paper's §5.5 shows H is *the* tuning knob of CoCoA-style training —
//! its optimum moves with framework overhead. A session owns exactly one
//! [`HPolicy`]; the built-ins are [`Fixed`] (the config's `h_frac`/`h_abs`
//! resolution, what every figure run uses) and [`Adaptive`] (the
//! compute-fraction controller the paper's conclusion calls for, absorbed
//! from the old `tuner::train_adaptive` loop).

use crate::config::TrainConfig;
use crate::coordinator::tuner::AdaptiveH;
use crate::framework::RoundTiming;

/// Chooses H for every round of a session.
///
/// The session calls [`initial`](HPolicy::initial) once before round 0 and
/// [`next`](HPolicy::next) after every *non-final* round (a round that
/// triggers the stop policy is never observed — the same cadence the old
/// `train_adaptive` loop had, which keeps H sequences reproducible
/// bit-for-bit).
pub trait HPolicy {
    /// H for the first round, given the mean partition size.
    fn initial(&mut self, cfg: &TrainConfig, mean_n_local: usize) -> usize;

    /// Observe a completed round's timing split; return H for the next.
    fn next(&mut self, timing: &RoundTiming, last_h: usize) -> usize;

    /// Suffix for the report's `impl_name` (None = plain engine label).
    fn label(&self) -> Option<&str> {
        None
    }
}

/// Fixed H resolved from the config (`h_abs`, else `h_frac · n_local`).
#[derive(Debug, Clone, Copy, Default)]
pub struct Fixed;

impl HPolicy for Fixed {
    fn initial(&mut self, cfg: &TrainConfig, mean_n_local: usize) -> usize {
        cfg.h_for(mean_n_local)
    }

    fn next(&mut self, _timing: &RoundTiming, last_h: usize) -> usize {
        last_h
    }
}

/// The compute-fraction controller on the session loop: observes each
/// round's worker/overhead split and multiplicatively scales H toward the
/// target fraction (≈0.9 for MPI, ≈0.6 for pySpark+C — Figure 7).
#[derive(Debug, Clone)]
pub struct Adaptive {
    pub target_compute_fraction: f64,
    ctrl: Option<AdaptiveH>,
}

impl Adaptive {
    pub fn new(target_compute_fraction: f64) -> Adaptive {
        Adaptive {
            target_compute_fraction,
            ctrl: None,
        }
    }
}

impl HPolicy for Adaptive {
    fn initial(&mut self, cfg: &TrainConfig, mean_n_local: usize) -> usize {
        let ctrl = AdaptiveH::new(
            cfg.h_for(mean_n_local),
            mean_n_local,
            self.target_compute_fraction,
        );
        let h0 = ctrl.h as usize;
        self.ctrl = Some(ctrl);
        h0
    }

    fn next(&mut self, timing: &RoundTiming, _last_h: usize) -> usize {
        self.ctrl
            .as_mut()
            .expect("HPolicy::next before initial")
            .observe(timing.t_worker, timing.t_overhead)
    }

    fn label(&self) -> Option<&str> {
        Some("adaptiveH")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{webspam_like, SyntheticSpec};

    #[test]
    fn fixed_policy_is_constant() {
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.h_frac = 0.5;
        let mut p = Fixed;
        let h0 = p.initial(&cfg, 100);
        assert_eq!(h0, 50);
        let t = RoundTiming {
            t_worker: 0.1,
            t_overhead: 0.9,
            ..Default::default()
        };
        assert_eq!(p.next(&t, h0), h0);
        assert!(p.label().is_none());
    }

    #[test]
    fn adaptive_policy_tracks_controller() {
        let ds = webspam_like(&SyntheticSpec::small());
        let cfg = TrainConfig::default_for(&ds);
        let mut p = Adaptive::new(0.8);
        let h0 = p.initial(&cfg, 100);
        assert_eq!(h0, cfg.h_for(100));
        // Overhead-dominated round → H must grow, exactly as the bare
        // controller would say.
        let mut reference = AdaptiveH::new(cfg.h_for(100), 100, 0.8);
        let t = RoundTiming {
            t_worker: 0.1,
            t_overhead: 0.9,
            ..Default::default()
        };
        assert_eq!(p.next(&t, h0), reference.observe(0.1, 0.9));
        assert_eq!(p.label(), Some("adaptiveH"));
    }
}
