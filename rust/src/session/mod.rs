//! The unified `Session` training API: ONE round loop for every substrate.
//!
//! The paper's methodology is running the *same* algorithm over five
//! framework substrates and comparing clocks. This module is the driver
//! layer that finally expresses that uniformly (DESIGN.md §8):
//!
//! * an **engine selector** ([`Engine`]) covering the full registry — the
//!   eight virtual-clock [`Impl`](crate::config::Impl) variants *plus* the
//!   thread and parameter-server engines — through one constructor path
//!   that applies every [`EngineOptions`] field identically;
//! * a **stopping policy** ([`StopPolicy`]): train to a target
//!   suboptimality, to a duality-gap certificate (oracle-free — what
//!   SVM/logistic sessions use), or run a fixed number of rounds as a
//!   pure timing run;
//! * a **[`Problem`]** selector ([`SessionBuilder::problem`]): ridge,
//!   lasso, elastic net, linear SVM or logistic regression through the
//!   same loop on every substrate;
//! * a pluggable **[`HPolicy`]** ([`policy::Fixed`], [`policy::Adaptive`])
//!   deciding the local-steps knob every round;
//! * a streaming **[`RoundObserver`]** fan-out ([`observer::CsvTrace`],
//!   [`observer::CheckpointEvery`], [`observer::Recording`]) — the
//!   features that used to own private copies of the loop.
//!
//! `coordinator::train`, `train_with_oracle`, `run_fixed_rounds` and
//! `tuner::train_adaptive` survive as thin deprecated shims over this
//! loop; there is no other `engine.run_round` driver in the crate.
//!
//! ```no_run
//! use sparkbench::config::Impl;
//! use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
//! use sparkbench::session::Session;
//!
//! let ds = webspam_like(&SyntheticSpec::small());
//! let report = Session::builder(&ds)
//!     .engine(Impl::Mpi)
//!     .build()
//!     .unwrap()
//!     .run();
//! println!("{} rounds, {:?} to target", report.rounds, report.time_to_target);
//! ```

pub mod observer;
pub mod policy;

pub use observer::{CheckpointEvery, CsvTrace, Recording, RoundCtx, RoundObserver};
pub use policy::HPolicy;

use crate::config::{Impl, Precision, SolverKind, TrainConfig};
use crate::coordinator::checkpoint::{Checkpoint, CheckpointStore};
use crate::coordinator::{oracle_objective, suboptimality};
use crate::data::Dataset;
use crate::framework::chaos::{ChaosSpec, FaultSchedule};
use crate::framework::{build_any, DistEngine, Engine, EngineOptions};
use crate::linalg;
use crate::metrics::{RoundLog, TrainReport};
use crate::problem::Problem;

/// When a session stops driving rounds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StopPolicy {
    /// Stop once suboptimality ≤ `subopt` (bounded by `cfg.max_rounds`).
    /// Requires an oracle f* — the builder computes one if none is given.
    ToTarget { subopt: f64 },
    /// Stop once the problem's duality-gap certificate, normalized as
    /// `gap / max(1, |f|)`, falls to `gap` (bounded by `cfg.max_rounds`).
    /// Needs NO oracle: the certificate comes from the problem's Fenchel
    /// conjugate (DESIGN.md §9), so non-quadratic problems (SVM, logistic)
    /// stop without a CG solve. Costs one O(nnz) `Aᵀu` per evaluation
    /// (`cfg.eval_every` cadence).
    ToGap { gap: f64 },
    /// Run exactly `n` rounds — the Figure 3/4 timing methodology. No
    /// early stop; without an explicit oracle the objective is never
    /// evaluated and the report's `final_*` fields are `None`, not fake
    /// values against f* = 0.
    FixedRounds { n: usize },
}

/// How the session obtains f* for suboptimality tracking.
enum OracleMode {
    /// Compute it (`ToTarget`) or go without (`FixedRounds`).
    Auto,
    /// Caller supplies a precomputed optimum (sweeps cache the oracle).
    Known(f64),
    /// Explicitly none — forces a pure timing run.
    Off,
}

/// The engine a session drives: built by the registry, or attached by the
/// caller (the deprecated shims and pre-built-engine tests use the
/// latter).
enum EngineRef<'a> {
    Owned(Box<dyn DistEngine>),
    Attached(&'a mut dyn DistEngine),
}

impl EngineRef<'_> {
    fn get(&self) -> &(dyn DistEngine + '_) {
        match self {
            EngineRef::Owned(b) => &**b,
            EngineRef::Attached(r) => &**r,
        }
    }

    fn get_mut(&mut self) -> &mut (dyn DistEngine + '_) {
        match self {
            EngineRef::Owned(b) => &mut **b,
            EngineRef::Attached(r) => &mut **r,
        }
    }
}

/// Builder for a [`Session`]. Start from [`Session::builder`].
pub struct SessionBuilder<'a> {
    ds: &'a Dataset,
    engine: Engine,
    attached: Option<&'a mut dyn DistEngine>,
    cfg: Option<TrainConfig>,
    problem: Option<Problem>,
    opts: Option<EngineOptions>,
    stop: Option<StopPolicy>,
    h_policy: Box<dyn HPolicy>,
    observers: Vec<Box<dyn RoundObserver>>,
    oracle: OracleMode,
    resume: Option<Checkpoint>,
    track_gap: bool,
    threads_per_worker: Option<usize>,
    chaos: Option<ChaosSpec>,
    store: Option<(CheckpointStore, usize)>,
}

impl<'a> SessionBuilder<'a> {
    /// Select the engine from the registry (any [`Impl`] converts, and
    /// [`Engine::Threads`]/[`Engine::ParamServer`] are first-class).
    ///
    /// [`Impl`]: crate::config::Impl
    pub fn engine(mut self, engine: impl Into<Engine>) -> Self {
        self.engine = engine.into();
        self
    }

    /// Drive a caller-owned engine instead of building one. Overrides
    /// [`engine`](Self::engine); the caller keeps the engine afterwards
    /// (its α/clock state reflects the run).
    pub fn attach(mut self, engine: &'a mut dyn DistEngine) -> Self {
        self.attached = Some(engine);
        self
    }

    /// Training configuration (default: `TrainConfig::default_for(ds)`).
    pub fn config(mut self, cfg: TrainConfig) -> Self {
        self.cfg = Some(cfg);
        self
    }

    /// Train a specific [`Problem`] (ridge/lasso/elastic, SVM, logistic),
    /// overriding whatever the config carries (registry-built engines
    /// only — an attached engine was already built around a problem) —
    /// the one-liner for opening a new workload on any engine:
    ///
    /// ```no_run
    /// # use sparkbench::data::synthetic::separable_classes;
    /// # use sparkbench::problem::Problem;
    /// # use sparkbench::session::{Session, StopPolicy};
    /// # let (ds, _labels) = separable_classes(32, 128, 0.4, 1);
    /// let report = Session::builder(&ds)
    ///     .problem(Problem::svm(1.0))
    ///     .stop(StopPolicy::ToGap { gap: 1e-4 })
    ///     .train();
    /// ```
    pub fn problem(mut self, p: Problem) -> Self {
        self.problem = Some(p);
        self
    }

    /// Evaluate and log the duality-gap certificate every `eval_every`
    /// rounds even when the stop policy does not need it (the trace CSV's
    /// `gap` column). Implied by [`StopPolicy::ToGap`].
    pub fn track_gap(mut self) -> Self {
        self.track_gap = true;
        self
    }

    /// Engine-construction options, applied uniformly to every substrate.
    /// Only meaningful for registry-built engines — combining with
    /// [`attach`](Self::attach) is a build-time error (an already-built
    /// engine cannot take construction options).
    pub fn options(mut self, opts: EngineOptions) -> Self {
        self.opts = Some(opts);
        self
    }

    /// Run `t` local sub-solvers inside every worker — nested two-level
    /// parallelism (DESIGN.md §10). The sub-shards are the parts of the
    /// flat `K·t` partitioning, σ′ becomes γ·K·t and per-shard seeds use
    /// the flat rank ids, so the trajectory is **bit-identical** to a flat
    /// `K·t` ring while the communication topology stays K-wide:
    ///
    /// ```no_run
    /// # use sparkbench::data::synthetic::{webspam_like, SyntheticSpec};
    /// # use sparkbench::session::Session;
    /// # let ds = webspam_like(&SyntheticSpec::small());
    /// // 4 ranks × 2 sub-solvers each ≡ an 8-worker flat ring.
    /// let report = Session::builder(&ds)
    ///     .engine(sparkbench::framework::Engine::threads(4))
    ///     .threads_per_worker(2)
    ///     .train();
    /// # let _ = report;
    /// ```
    ///
    /// Shorthand for setting [`EngineOptions::threads_per_worker`]
    /// (overriding whatever [`options`](Self::options) carried); an
    /// explicit `Engine::Threads { t, .. } > 0` still wins. Registry-built
    /// engines only — combining with [`attach`](Self::attach) is a
    /// build-time error.
    pub fn threads_per_worker(mut self, t: usize) -> Self {
        self.threads_per_worker = Some(t);
        self
    }

    /// Stopping policy (default: `ToTarget` at the config's
    /// `target_subopt`).
    pub fn stop(mut self, stop: StopPolicy) -> Self {
        self.stop = Some(stop);
        self
    }

    /// Sugar for `stop(StopPolicy::FixedRounds { n })`.
    pub fn fixed_rounds(self, n: usize) -> Self {
        self.stop(StopPolicy::FixedRounds { n })
    }

    /// Sugar for `stop(StopPolicy::ToTarget { subopt })`.
    pub fn target(self, subopt: f64) -> Self {
        self.stop(StopPolicy::ToTarget { subopt })
    }

    /// Sugar for `stop(StopPolicy::ToGap { gap })` — certificate-based
    /// stopping, no oracle needed.
    pub fn target_gap(self, gap: f64) -> Self {
        self.stop(StopPolicy::ToGap { gap })
    }

    /// H policy (default: [`policy::Fixed`]).
    pub fn h_policy(mut self, p: impl HPolicy + 'static) -> Self {
        self.h_policy = Box::new(p);
        self
    }

    /// Sugar for `h_policy(policy::Adaptive::new(target_fraction))`.
    pub fn adaptive_h(self, target_fraction: f64) -> Self {
        self.h_policy(policy::Adaptive::new(target_fraction))
    }

    /// Register a round observer (any number; called in registration
    /// order).
    pub fn observe(mut self, o: impl RoundObserver + 'static) -> Self {
        self.observers.push(Box::new(o));
        self
    }

    /// Supply a precomputed optimum f* (sweeps cache the oracle instead
    /// of re-running CG per point).
    pub fn oracle(mut self, fstar: f64) -> Self {
        self.oracle = OracleMode::Known(fstar);
        self
    }

    /// Never evaluate the objective: a pure timing run. Incompatible with
    /// `ToTarget` (build errors).
    pub fn no_oracle(mut self) -> Self {
        self.oracle = OracleMode::Off;
        self
    }

    /// Inject chaos (DESIGN.md §12): per-worker heterogeneity, latency
    /// jitter, a seeded [`FaultPlan`](crate::framework::chaos::FaultPlan)
    /// of worker deaths and slowdowns, and optional speculative
    /// re-execution. The spec binds against the engine's worker count at
    /// build time (a plan that kills every worker in one round is a build
    /// error). A death aborts the round attempt with nothing committed;
    /// the session reloads its α snapshot and replays the same round —
    /// same seed, so the post-recovery trajectory is bit-identical to an
    /// uninterrupted run (`tests/integration_chaos.rs`). Registry-built
    /// engines only.
    pub fn chaos(mut self, spec: ChaosSpec) -> Self {
        self.chaos = Some(spec);
        self
    }

    /// Resume from a checkpoint: restores α into the engine, v, the round
    /// counter (round seeds line up) and the clock offset.
    ///
    /// The checkpoint fingerprint covers λn, η, K, `threads_per_worker`
    /// (v3 envelopes; earlier versions imply T = 1) and the vector sizes.
    /// `seed`, `partitioner`, the H settings (`h_frac`/`h_abs`) and
    /// `gamma` are NOT recorded and are not checked — bit-exact
    /// continuation requires resuming with the same values for all of
    /// them as the original run (re-sharding is then deterministic, even
    /// for nested K×T layouts).
    pub fn resume_from(mut self, ckpt: Checkpoint) -> Self {
        self.resume = Some(ckpt);
        self
    }

    /// Durable checkpointing (DESIGN.md §15): after every `every`-th
    /// completed round the session writes a v6 envelope into a
    /// [`CheckpointStore`] at `dir` — atomic rename, CRC footer, last
    /// `keep` envelopes retained — with bounded retry, fanning every
    /// [`DurabilityEvent`](crate::coordinator::checkpoint::DurabilityEvent)
    /// to all observers via `on_durability`. A `crash@R` chaos round also
    /// forces a write before the kill, so a restart resumes at R+1.
    pub fn checkpoint_store(
        mut self,
        dir: impl AsRef<std::path::Path>,
        every: usize,
        keep: usize,
    ) -> Self {
        self.store = Some((CheckpointStore::new(dir, keep), every.max(1)));
        self
    }

    /// Crash-safe resume: continue from the newest envelope in the store
    /// at `dir` that decodes clean ([`CheckpointStore::latest_valid`] —
    /// corrupt/truncated tail files are skipped). Errors when the store
    /// holds no valid checkpoint at all; otherwise equivalent to
    /// [`resume_from`](Self::resume_from) with the recovered checkpoint.
    pub fn resume_from_store(self, dir: impl AsRef<std::path::Path>) -> Result<Self, String> {
        let store = CheckpointStore::new(dir, CheckpointStore::DEFAULT_KEEP);
        match store.latest_valid() {
            Some((_, env)) => Ok(self.resume_from(env.ckpt)),
            None => Err(format!(
                "no valid checkpoint envelope in {}",
                store.dir().display()
            )),
        }
    }

    /// Validate and assemble the session (computes the oracle when needed).
    pub fn build(self) -> Result<Session<'a>, String> {
        let mut cfg = self
            .cfg
            .unwrap_or_else(|| TrainConfig::default_for(self.ds));
        if let Some(p) = self.problem {
            cfg.problem = p;
        }
        cfg.validate()?;
        let stop = self.stop.unwrap_or(StopPolicy::ToTarget {
            subopt: cfg.target_subopt,
        });
        // Cheap misuse checks BEFORE the (potentially expensive) auto
        // oracle below — an invalid build must not burn a CG solve first.
        // Builder-misuse errors come first so e.g. `.attach(..).problem(..)`
        // reports the real mistake, not a downstream dataset complaint.
        if self.attached.is_some() && self.opts.is_some() {
            return Err(
                ".options(...) cannot apply to an attached engine — it is already \
                 built; configure it at construction or select via .engine(...)"
                    .into(),
            );
        }
        if self.attached.is_some() && self.threads_per_worker.is_some() {
            return Err(
                ".threads_per_worker(...) cannot apply to an attached engine — its \
                 sub-shard layout was fixed at construction; build nested engines \
                 via .engine(...) or framework::build_any"
                    .into(),
            );
        }
        if self.threads_per_worker == Some(0) {
            return Err("threads_per_worker must be >= 1".into());
        }
        if self.attached.is_some() && self.chaos.is_some() {
            return Err(
                ".chaos(...) cannot apply to an attached engine — the chaos runtime \
                 is part of engine construction; select via .engine(...)"
                    .into(),
            );
        }
        if self.attached.is_some() && self.problem.is_some() {
            return Err(
                ".problem(...) cannot apply to an attached engine — its workers were \
                 built around a problem already; set `cfg.problem` before constructing \
                 the engine, or select via .engine(...)"
                    .into(),
            );
        }
        // A dual-loss problem on a regression-layout dataset would quietly
        // optimize something meaningless — refuse before any oracle work.
        cfg.problem.check_dataset(self.ds)?;
        // MixedF32 lives in the native solver's inner loop. Engines whose
        // local solvers are managed stand-ins (A, C) or mini-batch SGD
        // have no mixed path — refuse rather than silently train in a
        // different numeric mode than requested. (Attached engines fixed
        // their solvers at construction; their builder did this check.)
        if cfg.precision == Precision::MixedF32 && self.attached.is_none() {
            if let Engine::Impl(imp) = self.engine {
                if !imp.uses_native_solver() || imp == Impl::MllibSgd {
                    return Err(format!(
                        "precision mixed-f32 requires the native local solver; {} runs {}",
                        imp.name(),
                        SolverKind::for_impl(imp).name()
                    ));
                }
            }
        }
        let fstar = match self.oracle {
            OracleMode::Known(f) => Some(f),
            OracleMode::Off => None,
            OracleMode::Auto => match stop {
                StopPolicy::ToTarget { .. } => Some(oracle_objective(self.ds, &cfg)),
                // The gap certificate IS the stopping signal — no oracle.
                StopPolicy::ToGap { .. } | StopPolicy::FixedRounds { .. } => None,
            },
        };
        if fstar.is_none() && matches!(stop, StopPolicy::ToTarget { .. }) {
            return Err(
                "StopPolicy::ToTarget needs an oracle (drop .no_oracle() or pass .oracle(fstar))"
                    .into(),
            );
        }
        let mut opts = self.opts.unwrap_or_default();
        if let Some(t) = self.threads_per_worker {
            opts.threads_per_worker = t;
        }
        // Bind the chaos spec against the worker count the engine will
        // actually run with (`Engine::Threads { k > 0 }` overrides
        // `cfg.workers`). Binding resolves seeded worker picks and rejects
        // unsatisfiable plans — kill-all rounds fail HERE, not mid-run.
        let bound_chaos = match &self.chaos {
            Some(spec) => {
                let eff_k = match self.engine {
                    Engine::Threads { k, .. } if k > 0 => k,
                    _ => cfg.workers,
                };
                Some(spec.bind(eff_k)?)
            }
            None => None,
        };
        let mut fault_sched = bound_chaos.as_ref().map(|s| FaultSchedule::new(&s.plan));
        // Coordinator crash rounds (crash@R) are session-level, not engine
        // chaos: the engine never sees them. bind() sorted and deduped.
        let crash_rounds = bound_chaos
            .as_ref()
            .map(|s| s.crashes.clone())
            .unwrap_or_default();
        opts.chaos = bound_chaos;
        let resume_fault_cursor = self.resume.as_ref().map(|c| c.fault_cursor);
        let mut engine = match self.attached {
            Some(e) => EngineRef::Attached(e),
            None => EngineRef::Owned(build_any(self.engine, self.ds, &cfg, &opts)),
        };
        let (start_round, v, clock_offset) = match self.resume {
            Some(ckpt) => {
                // λ/η fingerprints come from the config; K from the engine
                // actually driving the rounds (`Engine::Threads { k }` may
                // override `cfg.workers`).
                let mut fingerprint = cfg.clone();
                fingerprint.workers = engine.get().num_workers();
                ckpt.compatible_with(&fingerprint)?;
                // The nested layout is part of the trajectory: a K×T run
                // re-shards deterministically (same partitioner, K·T,
                // seed), so T must match the engine driving the resume.
                let engine_t = engine.get().threads_per_worker();
                if ckpt.threads_per_worker != engine_t {
                    return Err(format!(
                        "threads-per-worker mismatch: checkpoint trained with T={}, \
                         resuming engine has T={}",
                        ckpt.threads_per_worker, engine_t
                    ));
                }
                if ckpt.v.len() != self.ds.m() {
                    return Err(format!(
                        "checkpoint v has {} entries, dataset m = {}",
                        ckpt.v.len(),
                        self.ds.m()
                    ));
                }
                if ckpt.alpha.len() != self.ds.n() {
                    return Err(format!(
                        "checkpoint α has {} entries, dataset n = {}",
                        ckpt.alpha.len(),
                        self.ds.n()
                    ));
                }
                engine.get_mut().load_alpha(&ckpt.alpha);
                // Report times continue from the checkpointed clock. An
                // attached engine may already carry (part of) that time on
                // its own clock — offset only by the remainder, so resumed
                // times are neither double-counted nor rewound.
                let offset = ckpt.time - engine.get().clock();
                (ckpt.round, ckpt.v, offset)
            }
            None => {
                // A fresh run assumes v = Aα = 0. An attached engine that
                // already trained would silently violate that invariant —
                // reject it (resume_from is the sanctioned continuation).
                if matches!(&engine, EngineRef::Attached(_))
                    && engine.get().alpha_global().iter().any(|&a| a != 0.0)
                {
                    return Err(
                        "attached engine has trained state (α ≠ 0); start from a fresh \
                         engine or continue with .resume_from(checkpoint)"
                            .into(),
                    );
                }
                (0, vec![0.0; self.ds.m()], 0.0)
            }
        };
        // A resumed chaos run skips the fault-plan prefix it already
        // survived (checkpoint envelope v5; pre-v5 implies cursor 0).
        if let (Some(sched), Some(cursor)) = (fault_sched.as_mut(), resume_fault_cursor) {
            sched.cursor = cursor.min(sched.deaths_total());
        }
        Ok(Session {
            ds: self.ds,
            engine,
            cfg,
            stop,
            h_policy: self.h_policy,
            observers: self.observers,
            fstar,
            start_round,
            v,
            clock_offset,
            track_gap: self.track_gap,
            fault_sched,
            store: self.store,
            crash_rounds,
        })
    }

    /// `build().unwrap().run()` — the one-liner for the common case.
    pub fn train(self) -> TrainReport {
        self.build().expect("invalid session").run()
    }
}

/// A configured training run over one engine: see the module docs.
pub struct Session<'a> {
    ds: &'a Dataset,
    engine: EngineRef<'a>,
    cfg: TrainConfig,
    stop: StopPolicy,
    h_policy: Box<dyn HPolicy>,
    observers: Vec<Box<dyn RoundObserver>>,
    fstar: Option<f64>,
    start_round: usize,
    v: Vec<f64>,
    clock_offset: f64,
    track_gap: bool,
    /// Fault-plan schedule (chaos sessions only): which deaths/slowdowns
    /// hit which round attempts, and how many deaths already fired.
    fault_sched: Option<FaultSchedule>,
    /// Durable checkpoint store and its round cadence (DESIGN.md §15).
    store: Option<(CheckpointStore, usize)>,
    /// Sorted coordinator crash rounds (`crash@R` chaos): the run halts
    /// after round R — after the store write — and must be resumed.
    crash_rounds: Vec<usize>,
}

impl<'a> Session<'a> {
    /// Start composing a session on a dataset (defaults: MPI engine,
    /// default config, `ToTarget`, fixed H, no observers).
    pub fn builder(ds: &Dataset) -> SessionBuilder<'_> {
        SessionBuilder {
            ds,
            engine: Engine::Impl(crate::config::Impl::Mpi),
            attached: None,
            cfg: None,
            problem: None,
            opts: None,
            stop: None,
            h_policy: Box::new(policy::Fixed),
            observers: Vec::new(),
            oracle: OracleMode::Auto,
            resume: None,
            track_gap: false,
            threads_per_worker: None,
            chaos: None,
            store: None,
        }
    }

    /// Drive rounds until the stop policy fires — THE round loop. Every
    /// other driver in the crate (the deprecated `coordinator` shims, the
    /// tuner's grid search, the experiments, the CLI) delegates here.
    pub fn run(self) -> TrainReport {
        self.run_extract().0
    }

    /// [`run`](Session::run), plus extraction of the servable
    /// [`PrimalModel`](crate::serve::PrimalModel) from the final training
    /// state — the live-session half of the train→serve handoff
    /// (DESIGN.md §13). The weights copy α (squared loss) or `v = Aα`
    /// (dual losses) bit-exactly, so a model extracted here is
    /// bit-identical to one decoded from a checkpoint the same session
    /// wrote at its final round.
    pub fn run_extract(self) -> (TrainReport, crate::serve::PrimalModel) {
        let Session {
            ds,
            mut engine,
            cfg,
            stop,
            mut h_policy,
            mut observers,
            fstar,
            start_round,
            mut v,
            clock_offset,
            track_gap,
            mut fault_sched,
            store,
            crash_rounds,
        } = self;

        let n_locals = engine.get().n_locals();
        let mean_n_local = (n_locals.iter().sum::<usize>() as f64 / n_locals.len().max(1) as f64)
            .round() as usize;
        let mut h = h_policy.initial(&cfg, mean_n_local.max(1));

        let budget = match stop {
            StopPolicy::FixedRounds { n } => n,
            StopPolicy::ToTarget { .. } | StopPolicy::ToGap { .. } => cfg.max_rounds,
        };
        let end_round = start_round + budget;

        // Objective evaluation runs iff an oracle exists (`ToTarget`
        // guarantees one — builder invariant) or the gap certificate is
        // wanted (`ToGap` stopping / `.track_gap()`); `FixedRounds`
        // without either is a pure timing run.
        let want_gap = track_gap || matches!(stop, StopPolicy::ToGap { .. });
        let eval = fstar.is_some() || want_gap;
        // Reused certificate buffers: gap evaluations stop allocating
        // after the first one (Problem::duality_gap_scratch).
        let mut gap_scratch = crate::problem::GapScratch::default();
        let mut final_obj = None;
        let mut final_sub = None;
        if eval {
            let f = cfg
                .problem
                .primal_given_v(&v, &engine.get().alpha_global(), &ds.b);
            final_obj = Some(f);
            final_sub = fstar.map(|fs| suboptimality(f, fs));
        }

        let mut logs: Vec<RoundLog> = Vec::new();
        let mut time_to_target = None;
        let (mut tot_worker, mut tot_master, mut tot_overhead) = (0.0, 0.0, 0.0);

        // Chaos recovery snapshot: the global α after the last COMPLETED
        // round. A death aborts the attempt with nothing committed to v,
        // but worker-local α may have advanced — reloading this snapshot
        // plus replaying with the same round seed makes the recovered
        // trajectory bit-identical to an uninterrupted run. Chaos-free
        // sessions never take it (no per-round alpha_global cost).
        let mut snapshot: Option<Vec<f64>> =
            fault_sched.as_ref().map(|_| engine.get().alpha_global());

        for round in start_round..end_round {
            let seed = cfg.seed ^ (round as u64).wrapping_mul(0xA24BAED4963EE407);
            // Attempt loop: each armed death aborts one attempt (clock
            // still advances — failure costs real time), then the SAME
            // round replays. The schedule fires deaths one per attempt,
            // so a death scheduled during recovery hits the replay too.
            // It terminates: every abort consumes one of finitely many
            // plan deaths.
            let (dv, timing) = loop {
                let rc = match fault_sched.as_ref() {
                    Some(s) => s.arm(round),
                    None => Default::default(),
                };
                let fault = rc.death;
                if !rc.is_quiet() {
                    engine.get_mut().arm_chaos(rc);
                }
                let out = engine.get_mut().run_round(&v, h, seed);
                match fault {
                    Some(w) => {
                        fault_sched
                            .as_mut()
                            .expect("armed death without a schedule")
                            .fired();
                        let snap = snapshot.as_ref().expect("chaos session without snapshot");
                        engine.get_mut().load_alpha(snap);
                        for obs in observers.iter_mut() {
                            obs.on_fault(round, w, engine.get().clock() + clock_offset);
                        }
                    }
                    None => break out,
                }
            };
            linalg::add_assign(&mut v, &dv);
            if let Some(sn) = snapshot.as_mut() {
                *sn = engine.get().alpha_global();
            }
            tot_worker += timing.t_worker;
            tot_master += timing.t_master;
            tot_overhead += timing.t_overhead;

            let is_last = round + 1 == end_round;
            // Absolute round index, so a resumed run evaluates at the same
            // rounds the uninterrupted run would have.
            let (objective, sub, gap) = if eval && (round % cfg.eval_every == 0 || is_last) {
                // O(m+n) evaluation from the tracked shared vector (§Perf);
                // v is exact by construction (pure float additions of Δv).
                let alpha = engine.get().alpha_global();
                let f = cfg.problem.primal_given_v(&v, &alpha, &ds.b);
                final_obj = Some(f);
                let s = fstar.map(|fs| suboptimality(f, fs));
                final_sub = s;
                // The certificate costs an O(nnz) Aᵀu on top — computed
                // only when something consumes it, reusing the f above.
                let g = if want_gap {
                    let gap = cfg
                        .problem
                        .duality_gap_scratch(ds, &v, &alpha, f, &mut gap_scratch);
                    Some(gap / f.abs().max(1.0))
                } else {
                    None
                };
                (Some(f), s, g)
            } else {
                (None, None, None)
            };

            let log = RoundLog {
                round,
                time: engine.get().clock() + clock_offset,
                objective,
                suboptimality: sub,
                gap,
                timing: timing.clone(),
                h,
            };
            for obs in observers.iter_mut() {
                obs.on_round(&RoundCtx {
                    log: &log,
                    v: &v,
                    engine: engine.get(),
                    cfg: &cfg,
                    fault_cursor: fault_sched.as_ref().map_or(0, |s| s.cursor),
                });
            }
            logs.push(log);

            // Durable checkpointing (DESIGN.md §15): atomic store write on
            // the cadence — and forced at a crash round, so the kill below
            // lands *after* the store write race and a restart resumes at
            // R+1. Save failures retry bounded and fan out through
            // on_durability; training continues either way.
            let crash_now = crash_rounds.binary_search(&round).is_ok();
            if let Some((st, every)) = &store {
                if (round + 1) % every == 0 || crash_now {
                    let ckpt = Checkpoint {
                        round: round + 1,
                        time: engine.get().clock() + clock_offset,
                        alpha: engine.get().alpha_global(),
                        v: v.clone(),
                        problem: cfg.problem,
                        workers: engine.get().num_workers(),
                        threads_per_worker: engine.get().threads_per_worker(),
                        precision: cfg.precision,
                        fault_cursor: fault_sched.as_ref().map_or(0, |s| s.cursor),
                    };
                    let mut events = Vec::new();
                    let _ = st.save(&ckpt, &mut |e| events.push(e));
                    for ev in &events {
                        for obs in observers.iter_mut() {
                            obs.on_durability(ev);
                        }
                    }
                }
            }
            // Coordinator crash (crash@R): the session dies here — no
            // stop-policy bookkeeping, no further rounds. Restart via
            // resume_from_store to continue the trajectory bit-exactly.
            if crash_now {
                break;
            }

            match stop {
                StopPolicy::ToTarget { subopt } => {
                    if let Some(s) = sub {
                        if s <= subopt {
                            if time_to_target.is_none() {
                                time_to_target = Some(engine.get().clock() + clock_offset);
                            }
                            break;
                        }
                    }
                }
                StopPolicy::ToGap { gap: threshold } => {
                    if let Some(g) = gap {
                        if g <= threshold {
                            if time_to_target.is_none() {
                                time_to_target = Some(engine.get().clock() + clock_offset);
                            }
                            break;
                        }
                    }
                }
                StopPolicy::FixedRounds { .. } => {}
            }
            h = h_policy.next(&timing, h);
        }

        let impl_name = match h_policy.label() {
            Some(sfx) => format!("{}+{}", engine.get().engine().label(), sfx),
            None => engine.get().engine().label(),
        };
        let report = TrainReport {
            impl_name,
            rounds: logs.len(),
            time_to_target,
            final_suboptimality: final_sub,
            final_objective: final_obj,
            total_time: engine.get().clock() + clock_offset,
            total_worker: tot_worker,
            total_master: tot_master,
            total_overhead: tot_overhead,
            logs,
        };
        for obs in observers.iter_mut() {
            obs.on_complete(&report);
        }
        let model = crate::serve::PrimalModel::from_parts(
            cfg.problem,
            &engine.get().alpha_global(),
            &v,
            cfg.precision,
            start_round + report.rounds,
        );
        (report, model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Impl;
    use crate::data::synthetic::{webspam_like, SyntheticSpec};

    fn setup() -> (Dataset, TrainConfig) {
        let ds = webspam_like(&SyntheticSpec::small());
        let mut cfg = TrainConfig::default_for(&ds);
        cfg.workers = 4;
        cfg.max_rounds = 1200;
        (ds, cfg)
    }

    #[test]
    fn session_trains_to_target() {
        let (ds, cfg) = setup();
        let report = Session::builder(&ds)
            .engine(Impl::Mpi)
            .config(cfg.clone())
            .build()
            .unwrap()
            .run();
        assert!(report.time_to_target.is_some(), "{:?}", report.final_suboptimality);
        assert!(report.final_suboptimality.unwrap() <= cfg.target_subopt);
        assert_eq!(report.impl_name, "E:mpi");
        for w in report.logs.windows(2) {
            assert!(w[1].time >= w[0].time);
        }
    }

    #[test]
    fn fixed_rounds_is_a_pure_timing_run() {
        let (ds, cfg) = setup();
        let report = Session::builder(&ds)
            .engine(Impl::Mpi)
            .config(cfg)
            .fixed_rounds(7)
            .build()
            .unwrap()
            .run();
        assert_eq!(report.rounds, 7);
        assert!(report.total_time > 0.0);
        assert!(report.total_worker > 0.0);
        // Satellite: absent, not faked against f* = 0.
        assert!(report.final_suboptimality.is_none());
        assert!(report.final_objective.is_none());
        assert!(report.time_to_target.is_none());
        assert!(report.logs.iter().all(|l| l.objective.is_none()));
    }

    #[test]
    fn fixed_rounds_with_oracle_still_evaluates() {
        let (ds, mut cfg) = setup();
        cfg.eval_every = 1;
        let fstar = oracle_objective(&ds, &cfg);
        let report = Session::builder(&ds)
            .engine(Impl::Mpi)
            .config(cfg)
            .fixed_rounds(5)
            .oracle(fstar)
            .build()
            .unwrap()
            .run();
        assert_eq!(report.rounds, 5);
        assert!(report.final_objective.is_some());
        assert!(report.final_suboptimality.is_some());
        assert_eq!(report.logs.iter().filter(|l| l.objective.is_some()).count(), 5);
    }

    #[test]
    fn to_gap_stops_without_an_oracle_and_logs_the_gap_column() {
        // Certificate-based stopping must not trigger a CG solve: fstar is
        // absent, suboptimality is absent, yet the session stops early and
        // every evaluated round carries a gap value.
        let (ds, mut cfg) = setup();
        cfg.max_rounds = 6000; // gap 1e-4 is a tighter bar than subopt 1e-3
        let report = Session::builder(&ds)
            .engine(Impl::Mpi)
            .config(cfg)
            .stop(StopPolicy::ToGap { gap: 1e-4 })
            .build()
            .unwrap()
            .run();
        assert!(
            report.time_to_target.is_some(),
            "gap target missed: {:?}",
            report.logs.last().and_then(|l| l.gap)
        );
        assert!(report.final_suboptimality.is_none());
        assert!(report.final_objective.is_some());
        assert!(report.logs.iter().all(|l| l.gap.is_some()));
        let last = report.logs.last().unwrap().gap.unwrap();
        assert!(last <= 1e-4, "stopped at gap {}", last);
        // Monotone-ish certificate: ends far below where it starts.
        let first = report.logs.first().unwrap().gap.unwrap();
        assert!(first > last);
    }

    #[test]
    fn track_gap_adds_the_column_to_oracle_runs() {
        let (ds, mut cfg) = setup();
        cfg.max_rounds = 6;
        cfg.target_subopt = 0.0;
        let fstar = oracle_objective(&ds, &cfg);
        let report = Session::builder(&ds)
            .engine(Impl::Mpi)
            .config(cfg)
            .oracle(fstar)
            .track_gap()
            .build()
            .unwrap()
            .run();
        assert_eq!(report.rounds, 6);
        for l in &report.logs {
            let (g, f) = (l.gap.unwrap(), l.objective.unwrap());
            assert!(g >= 0.0 && g.is_finite());
            // De-normalized, the certificate upper-bounds the true
            // suboptimality f − f* at every round (weak duality).
            let gap_abs = g * f.abs().max(1.0);
            assert!(
                gap_abs + 1e-9 * (1.0 + f.abs()) >= f - fstar,
                "gap {} < f - f* = {}",
                gap_abs,
                f - fstar
            );
        }
    }

    #[test]
    fn builder_problem_overrides_the_config() {
        use crate::data::synthetic::separable_classes;
        use crate::problem::Problem;
        let (ds, _) = separable_classes(24, 96, 0.4, 5);
        let mut cfg = TrainConfig::default_for(&ds); // ridge by default
        cfg.workers = 3;
        cfg.max_rounds = 4000;
        let report = Session::builder(&ds)
            .engine(Impl::Mpi)
            .config(cfg)
            .problem(Problem::svm(1.0))
            .stop(StopPolicy::ToGap { gap: 1e-3 })
            .build()
            .unwrap()
            .run();
        assert!(
            report.time_to_target.is_some(),
            "svm session missed the gap target: {:?}",
            report.logs.last().and_then(|l| l.gap)
        );
    }

    #[test]
    fn dual_loss_on_a_regression_layout_dataset_is_rejected() {
        // SVM/logistic require the dual layout (label-scaled columns,
        // b = 0); a regression corpus must be refused at build time, not
        // silently "trained" against its nonzero targets — and refused
        // BEFORE any oracle work.
        let (ds, cfg) = setup(); // webspam-like: b != 0
        for p in [
            crate::problem::Problem::svm(1.0),
            crate::problem::Problem::logistic(1.0),
        ] {
            let err = Session::builder(&ds)
                .engine(Impl::Mpi)
                .config(cfg.clone())
                .problem(p)
                .build()
                .err()
                .expect("dual loss on regression layout must be rejected");
            assert!(err.contains("dual layout"), "{}", err);
        }
    }

    #[test]
    fn problem_override_on_attached_engine_is_rejected() {
        // The engine's workers were built around a problem; silently
        // evaluating a different one would split solver and session.
        let (ds, cfg) = setup();
        let mut eng = crate::framework::build_engine(Impl::Mpi, &ds, &cfg);
        let err = Session::builder(&ds)
            .config(cfg)
            .attach(eng.as_mut())
            .problem(crate::problem::Problem::lasso(1.0))
            .fixed_rounds(2)
            .build()
            .err()
            .expect("must reject");
        assert!(err.contains(".problem("), "{}", err);
    }

    #[test]
    fn to_target_without_oracle_is_rejected() {
        let (ds, cfg) = setup();
        let err = Session::builder(&ds)
            .engine(Impl::Mpi)
            .config(cfg)
            .no_oracle()
            .build()
            .err()
            .expect("must reject");
        assert!(err.contains("oracle"), "{}", err);
    }

    #[test]
    fn attach_drives_a_caller_owned_engine() {
        let (ds, cfg) = setup();
        let mut eng = crate::framework::build_engine(Impl::Mpi, &ds, &cfg);
        let report = Session::builder(&ds)
            .config(cfg)
            .attach(eng.as_mut())
            .fixed_rounds(3)
            .build()
            .unwrap()
            .run();
        assert_eq!(report.rounds, 3);
        // The engine keeps its advanced state.
        assert!(eng.clock() > 0.0);
        assert!(eng.alpha_global().iter().any(|&a| a != 0.0));
    }

    #[test]
    fn attach_rejects_already_trained_engine() {
        // Reusing a trained engine without resume_from would silently run
        // against v = 0 while α ≠ 0 — the builder must refuse.
        let (ds, cfg) = setup();
        let mut eng = crate::framework::build_engine(Impl::Mpi, &ds, &cfg);
        let _ = Session::builder(&ds)
            .config(cfg.clone())
            .attach(eng.as_mut())
            .fixed_rounds(2)
            .build()
            .unwrap()
            .run();
        let err = Session::builder(&ds)
            .config(cfg)
            .attach(eng.as_mut())
            .fixed_rounds(2)
            .build()
            .err()
            .expect("second attach of a trained engine must be rejected");
        assert!(err.contains("trained state"), "{}", err);
    }

    #[test]
    fn adaptive_session_reaches_target_and_labels_itself() {
        let (ds, mut cfg) = setup();
        cfg.max_rounds = 1500;
        cfg.eval_every = 1;
        let report = Session::builder(&ds)
            .engine(Impl::Mpi)
            .config(cfg)
            .adaptive_h(0.9)
            .build()
            .unwrap()
            .run();
        assert!(
            report.time_to_target.is_some(),
            "adaptive session missed target: {:?}",
            report.final_suboptimality
        );
        assert_eq!(report.impl_name, "E:mpi+adaptiveH");
        // H actually moved at least once under the controller.
        let hs: Vec<usize> = report.logs.iter().map(|l| l.h).collect();
        assert!(
            hs.windows(2).any(|w| w[0] != w[1]),
            "H never adapted: {:?}",
            &hs[..hs.len().min(8)]
        );
    }

    #[test]
    fn every_registry_engine_trains_through_session() {
        let (ds, mut cfg) = setup();
        cfg.max_rounds = 1500;
        let fstar = oracle_objective(&ds, &cfg);
        for engine in [
            Engine::Impl(Impl::Mpi),
            Engine::Impl(Impl::SparkCOpt),
            Engine::threads(0),
            Engine::ParamServer { staleness: 0 },
        ] {
            let report = Session::builder(&ds)
                .engine(engine)
                .config(cfg.clone())
                .oracle(fstar)
                .build()
                .unwrap()
                .run();
            assert!(
                report.time_to_target.is_some(),
                "{} missed target: {:?}",
                engine.label(),
                report.final_suboptimality
            );
        }
    }

    #[test]
    fn chaos_kill_all_plan_is_rejected_at_build() {
        let (ds, cfg) = setup(); // workers = 4
        let spec = ChaosSpec::parse("death@3:0,death@3:1,death@3:2,death@3:3").unwrap();
        let err = Session::builder(&ds)
            .engine(Impl::Mpi)
            .config(cfg)
            .chaos(spec)
            .fixed_rounds(5)
            .build()
            .err()
            .expect("kill-all plan must be rejected at build time");
        assert!(err.contains("kills all"), "{}", err);
    }

    #[test]
    fn chaos_on_attached_engine_is_rejected() {
        let (ds, cfg) = setup();
        let mut eng = crate::framework::build_engine(Impl::Mpi, &ds, &cfg);
        let err = Session::builder(&ds)
            .config(cfg)
            .attach(eng.as_mut())
            .chaos(ChaosSpec::parse("death@2").unwrap())
            .fixed_rounds(3)
            .build()
            .err()
            .expect("chaos on an attached engine must be rejected");
        assert!(err.contains(".chaos("), "{}", err);
    }

    #[test]
    fn chaos_session_survives_death_and_records_the_fault() {
        let (ds, mut cfg) = setup();
        cfg.eval_every = 1;
        let rec = Recording::new();
        let report = Session::builder(&ds)
            .engine(Impl::Mpi)
            .config(cfg.clone())
            .chaos(ChaosSpec::parse("death@2:1").unwrap())
            .fixed_rounds(6)
            .oracle(oracle_objective(&ds, &cfg))
            .observe(rec.clone())
            .build()
            .unwrap()
            .run();
        // All six rounds complete despite the mid-run death...
        assert_eq!(report.rounds, 6);
        assert_eq!(rec.faults(), vec![(2, 1)]);
        // ...and the trajectory is bit-identical to the chaos-free run
        // (only the clock differs: the aborted attempt cost real time).
        let clean = Session::builder(&ds)
            .engine(Impl::Mpi)
            .config(cfg.clone())
            .fixed_rounds(6)
            .oracle(oracle_objective(&ds, &cfg))
            .build()
            .unwrap()
            .run();
        for (a, b) in report.logs.iter().zip(clean.logs.iter()) {
            assert_eq!(a.objective, b.objective, "round {}", a.round);
        }
        assert!(report.total_time > clean.total_time);
    }

    #[test]
    fn mixed_precision_rejects_non_native_solvers() {
        // The f32 mirrors live inside NativeScd; a managed stand-in or the
        // MLlib SGD path would silently ignore the flag, so build() refuses.
        let (ds, mut cfg) = setup();
        cfg.precision = Precision::MixedF32;
        for imp in [Impl::SparkScala, Impl::PySpark, Impl::MllibSgd] {
            let err = Session::builder(&ds)
                .engine(imp)
                .config(cfg.clone())
                .build()
                .err()
                .expect("mixed-f32 on a non-native solver must be rejected");
            assert!(err.contains("mixed-f32"), "{}", err);
            assert!(err.contains(imp.name()), "{}", err);
        }
    }

    #[test]
    fn mixed_precision_trains_on_native_solver_engines() {
        let (ds, mut cfg) = setup();
        cfg.precision = Precision::MixedF32;
        cfg.max_rounds = 1500;
        for engine in [Engine::Impl(Impl::Mpi), Engine::threads(3)] {
            let report = Session::builder(&ds)
                .engine(engine)
                .config(cfg.clone())
                .build()
                .unwrap()
                .run();
            // f32 storage with f64 accumulation still clears the 1e-3
            // suboptimality bar on the small corpus.
            assert!(
                report.time_to_target.is_some(),
                "{} mixed-f32 missed target: {:?}",
                engine.label(),
                report.final_suboptimality
            );
        }
    }
}
