//! Streaming round observers: per-round callbacks a [`Session`] fans each
//! finished round out to.
//!
//! Checkpointing ([`CheckpointEvery`]), CSV tracing ([`CsvTrace`]) and
//! test/experiment instrumentation ([`Recording`]) are all ordinary
//! observers — none of them owns a copy of the round loop.
//!
//! [`Session`]: crate::session::Session

use std::cell::RefCell;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::rc::Rc;

use crate::config::TrainConfig;
use crate::coordinator::checkpoint::{save_with_retry, Checkpoint, DurabilityEvent};
use crate::framework::DistEngine;
use crate::metrics::{RoundLog, TrainReport};

/// Everything an observer may inspect after a round completes.
pub struct RoundCtx<'a> {
    /// The round's log entry (timing split, H, objective when evaluated).
    pub log: &'a RoundLog,
    /// Shared vector v = Aα *after* this round's update.
    pub v: &'a [f64],
    /// The engine, read-only (`alpha_global()` for model snapshots).
    pub engine: &'a dyn DistEngine,
    pub cfg: &'a TrainConfig,
    /// Fault-plan events consumed so far (chaos sessions; 0 otherwise).
    /// Checkpoints record it so a resumed run does not re-fire deaths
    /// that already happened.
    pub fault_cursor: usize,
}

/// Per-round callback stream. `on_round` fires exactly once per completed
/// round, in round order; `on_complete` fires once when the session ends.
pub trait RoundObserver {
    fn on_round(&mut self, ctx: &RoundCtx<'_>);

    /// A chaos fault aborted a round attempt: `worker` died at `round`
    /// (virtual or physical depending on the engine) and the session is
    /// about to recover and replay. Default: ignore.
    fn on_fault(&mut self, _round: usize, _worker: usize, _clock: f64) {}

    /// A checkpoint-durability event: a save reached disk, a failed
    /// attempt is being retried, or the bounded retry budget ran out
    /// (DESIGN.md §15). Fired by the session's checkpoint store and by
    /// [`CheckpointEvery`] — durability failures degrade loudly through
    /// the observer stream instead of a lone eprintln. Default: ignore.
    fn on_durability(&mut self, _event: &DurabilityEvent) {}

    fn on_complete(&mut self, _report: &TrainReport) {}
}

/// Streams the convergence trace to a CSV file as rounds finish (same
/// row format as [`TrainReport::trace_csv`], but incremental — a killed
/// run keeps every completed round on disk).
pub struct CsvTrace {
    out: BufWriter<File>,
}

impl CsvTrace {
    /// Create/truncate `path` (parent dirs included) and write the header.
    pub fn create(path: impl AsRef<Path>) -> std::io::Result<CsvTrace> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut out = BufWriter::new(File::create(path)?);
        writeln!(out, "{}", crate::metrics::TRACE_CSV_HEADER)?;
        Ok(CsvTrace { out })
    }
}

impl RoundObserver for CsvTrace {
    fn on_round(&mut self, ctx: &RoundCtx<'_>) {
        let _ = writeln!(self.out, "{}", ctx.log.csv_row());
    }

    fn on_complete(&mut self, _report: &TrainReport) {
        let _ = self.out.flush();
    }
}

/// Saves a [`Checkpoint`] after every `every`-th completed round, so a
/// restart resumes from the newest finished multiple (rounds past the
/// last multiple are re-run on resume — the round seeds make that
/// bit-exact).
pub struct CheckpointEvery {
    every: usize,
    path: PathBuf,
    /// Successful saves so far.
    pub saves: usize,
    /// Most recent `GaveUp` error — the save exhausted its bounded retry
    /// budget. `None` while every save (eventually) lands.
    pub last_error: Option<String>,
    /// Every durability event this observer routed through
    /// [`RoundObserver::on_durability`], in order: the full audit trail
    /// of saves, retries, and give-ups.
    pub events: Vec<DurabilityEvent>,
}

impl CheckpointEvery {
    pub fn new(every: usize, path: impl AsRef<Path>) -> CheckpointEvery {
        CheckpointEvery {
            every: every.max(1),
            path: path.as_ref().to_path_buf(),
            saves: 0,
            last_error: None,
            events: Vec::new(),
        }
    }

    fn capture(&mut self, ctx: &RoundCtx<'_>) {
        let ckpt = Checkpoint {
            round: ctx.log.round + 1,
            time: ctx.log.time,
            alpha: ctx.engine.alpha_global(),
            v: ctx.v.to_vec(),
            problem: ctx.cfg.problem,
            workers: ctx.engine.num_workers(),
            threads_per_worker: ctx.engine.threads_per_worker(),
            precision: ctx.cfg.precision,
            fault_cursor: ctx.fault_cursor,
        };
        // Bounded-retry save; failures degrade gracefully (training goes
        // on) and surface through the on_durability stream instead of a
        // lone eprintln (DESIGN.md §15).
        let mut pending = Vec::new();
        let _ = save_with_retry(&ckpt, &self.path, &mut |e| pending.push(e));
        for ev in pending {
            self.on_durability(&ev);
        }
    }
}

impl RoundObserver for CheckpointEvery {
    fn on_round(&mut self, ctx: &RoundCtx<'_>) {
        if (ctx.log.round + 1) % self.every == 0 {
            self.capture(ctx);
        }
    }

    fn on_durability(&mut self, event: &DurabilityEvent) {
        match event {
            DurabilityEvent::Saved { .. } => self.saves += 1,
            DurabilityEvent::Retry { .. } => {}
            DurabilityEvent::GaveUp { error, .. } => self.last_error = Some(error.clone()),
        }
        self.events.push(event.clone());
    }
}

/// What a [`Recording`] observer saw; one entry per round.
#[derive(Debug, Default, Clone)]
pub struct RecordingInner {
    pub rounds: Vec<usize>,
    pub hs: Vec<usize>,
    pub times: Vec<f64>,
    /// `(round, worker)` of every fault the session recovered from.
    pub faults: Vec<(usize, usize)>,
    /// Checkpoint durability events, in order (saves/retries/give-ups).
    pub durability: Vec<DurabilityEvent>,
    pub completions: usize,
}

/// Cheap cloneable recording observer: keep one handle, move the clone
/// into the session, inspect afterwards. Used by tests and notebooks.
#[derive(Debug, Default, Clone)]
pub struct Recording {
    inner: Rc<RefCell<RecordingInner>>,
}

impl Recording {
    pub fn new() -> Recording {
        Recording::default()
    }

    pub fn rounds(&self) -> Vec<usize> {
        self.inner.borrow().rounds.clone()
    }

    pub fn hs(&self) -> Vec<usize> {
        self.inner.borrow().hs.clone()
    }

    pub fn times(&self) -> Vec<f64> {
        self.inner.borrow().times.clone()
    }

    pub fn completions(&self) -> usize {
        self.inner.borrow().completions
    }

    pub fn faults(&self) -> Vec<(usize, usize)> {
        self.inner.borrow().faults.clone()
    }

    pub fn durability(&self) -> Vec<DurabilityEvent> {
        self.inner.borrow().durability.clone()
    }
}

impl RoundObserver for Recording {
    fn on_round(&mut self, ctx: &RoundCtx<'_>) {
        let mut inner = self.inner.borrow_mut();
        inner.rounds.push(ctx.log.round);
        inner.hs.push(ctx.log.h);
        inner.times.push(ctx.log.time);
    }

    fn on_fault(&mut self, round: usize, worker: usize, _clock: f64) {
        self.inner.borrow_mut().faults.push((round, worker));
    }

    fn on_durability(&mut self, event: &DurabilityEvent) {
        self.inner.borrow_mut().durability.push(event.clone());
    }

    fn on_complete(&mut self, _report: &TrainReport) {
        self.inner.borrow_mut().completions += 1;
    }
}
