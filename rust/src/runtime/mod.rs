//! PJRT runtime: load AOT artifacts (HLO text, produced once by
//! `make artifacts` → `python/compile/aot.py`) and execute them on the CPU
//! PJRT client from the rust hot path. Python never runs at training time.
//!
//! Pattern adapted from `/opt/xla-example/load_hlo`: HLO *text* is the
//! interchange format (jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects in proto form; the text parser reassigns
//! ids). Executables are compiled once per process and cached.
//!
//! The manifest reader below is always available (it needs only the
//! in-tree JSON codec); the PJRT client itself — everything touching the
//! external `xla` crate — is compiled only under the off-by-default `pjrt`
//! feature, so the default build carries zero external native
//! dependencies. Enable with `--features pjrt` after adding the `xla`
//! crate from the rust_pallas toolchain as a path dependency (see
//! `rust/README.md`).

use std::fmt;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Error type of the runtime layer: a human-actionable message chain
/// (replaces `anyhow`, which is unavailable in the offline build image).
#[derive(Debug, Clone)]
pub struct RuntimeError(String);

impl RuntimeError {
    pub fn new(msg: impl Into<String>) -> RuntimeError {
        RuntimeError(msg.into())
    }
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

macro_rules! rt_err {
    ($($arg:tt)*) => { RuntimeError::new(format!($($arg)*)) };
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub local_solve_file: String,
    /// Compiled row count (m).
    pub m: usize,
    /// Compiled partition width (nk) — partitions are padded up to this.
    pub nk: usize,
    /// Compiled index-buffer length (max H per kernel invocation).
    pub h_max: usize,
    pub objective_file: Option<String>,
    pub vmem_bytes_estimate: Option<u64>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            rt_err!("reading {} (run `make artifacts`): {}", path.display(), e)
        })?;
        let j = Json::parse(&text).map_err(|e| rt_err!("parsing manifest: {}", e))?;
        if j.at(&["format"]).and_then(|f| f.as_str()) != Some("hlo-text") {
            return Err(rt_err!("manifest format is not hlo-text"));
        }
        let ls = j
            .get("local_solve")
            .ok_or_else(|| rt_err!("manifest missing local_solve"))?;
        let field = |k: &str| -> Result<usize> {
            ls.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| rt_err!("manifest local_solve.{} missing", k))
        };
        Ok(Manifest {
            dir: dir.to_path_buf(),
            local_solve_file: ls
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| rt_err!("manifest local_solve.file missing"))?
                .to_string(),
            m: field("m")?,
            nk: field("nk")?,
            h_max: field("h_max")?,
            objective_file: j
                .at(&["objective", "file"])
                .and_then(|f| f.as_str())
                .map(String::from),
            vmem_bytes_estimate: ls
                .get("vmem_bytes_estimate")
                .and_then(|v| v.as_f64())
                .map(|v| v as u64),
        })
    }

    /// Default artifacts directory: `$SPARKBENCH_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("SPARKBENCH_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

/// Inputs to one kernel invocation, already padded to the compiled shape.
pub struct LocalSolveArgs<'a> {
    /// Row-major `[m, nk]` f32.
    pub a: &'a [f32],
    pub col_sq: &'a [f32],
    pub alpha: &'a [f32],
    pub v: &'a [f32],
    pub b: &'a [f32],
    /// Length `h_max`, entries < nk.
    pub idx: &'a [i32],
    pub h: i32,
    pub lam_n: f32,
    pub eta: f32,
    pub sigma: f32,
}

#[cfg(feature = "pjrt")]
mod pjrt_exec {
    use super::{LocalSolveArgs, Manifest, Result, RuntimeError};
    use std::path::Path;

    /// A compiled PJRT executable for the L2 `local_solve` graph.
    pub struct LocalSolveExec {
        exe: xla::PjRtLoadedExecutable,
        pub manifest: Manifest,
    }

    /// The PJRT runtime: CPU client + compiled executables.
    pub struct PjrtRuntime {
        pub client: xla::PjRtClient,
    }

    impl PjrtRuntime {
        /// Create the CPU PJRT client.
        pub fn cpu() -> Result<PjrtRuntime> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| rt_err!("pjrt cpu client: {:?}", e))?;
            Ok(PjrtRuntime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text file.
        fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| rt_err!("non-utf8 path"))?,
            )
            .map_err(|e| rt_err!("parse {}: {:?}", path.display(), e))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            self.client
                .compile(&comp)
                .map_err(|e| rt_err!("compile {}: {:?}", path.display(), e))
        }

        /// Compile the `local_solve` artifact described by the manifest.
        pub fn load_local_solve(&self, manifest: &Manifest) -> Result<LocalSolveExec> {
            let path = manifest.dir.join(&manifest.local_solve_file);
            let exe = self.compile_file(&path)?;
            Ok(LocalSolveExec {
                exe,
                manifest: manifest.clone(),
            })
        }
    }

    impl LocalSolveExec {
        /// Execute one CoCoA round on the PJRT device.
        /// Returns `(delta_alpha [nk], delta_v [m])`.
        pub fn run(&self, args: &LocalSolveArgs) -> Result<(Vec<f32>, Vec<f32>)> {
            let man = &self.manifest;
            let (m, nk, h_max) = (man.m as i64, man.nk as i64, man.h_max as i64);
            if args.a.len() != (m * nk) as usize {
                return Err(rt_err!(
                    "a has {} elems, artifact wants {}",
                    args.a.len(),
                    m * nk
                ));
            }
            if args.idx.len() != h_max as usize {
                return Err(rt_err!(
                    "idx has {} elems, artifact wants {}",
                    args.idx.len(),
                    h_max
                ));
            }
            if args.h < 0 || args.h as i64 > h_max {
                return Err(rt_err!("h {} outside [0, {}]", args.h, h_max));
            }

            let lit_a = xla::Literal::vec1(args.a)
                .reshape(&[m, nk])
                .map_err(|e| rt_err!("reshape a: {:?}", e))?;
            let lit_colsq = xla::Literal::vec1(args.col_sq);
            let lit_alpha = xla::Literal::vec1(args.alpha);
            let lit_v = xla::Literal::vec1(args.v);
            let lit_b = xla::Literal::vec1(args.b);
            let lit_idx = xla::Literal::vec1(args.idx);
            let lit_h = xla::Literal::scalar(args.h);
            let lit_lam = xla::Literal::scalar(args.lam_n);
            let lit_eta = xla::Literal::scalar(args.eta);
            let lit_sigma = xla::Literal::scalar(args.sigma);

            let outs = self
                .exe
                .execute::<xla::Literal>(&[
                    lit_a, lit_colsq, lit_alpha, lit_v, lit_b, lit_idx, lit_h, lit_lam, lit_eta,
                    lit_sigma,
                ])
                .map_err(|e| rt_err!("execute: {:?}", e))?;
            let lit = outs[0][0]
                .to_literal_sync()
                .map_err(|e| rt_err!("to_literal: {:?}", e))?;
            // aot.py lowers with return_tuple=True → a 2-tuple.
            let (da, dv) = lit.to_tuple2().map_err(|e| rt_err!("tuple2: {:?}", e))?;
            let delta_alpha = da.to_vec::<f32>().map_err(|e| rt_err!("dalpha: {:?}", e))?;
            let delta_v = dv.to_vec::<f32>().map_err(|e| rt_err!("dv: {:?}", e))?;
            Ok((delta_alpha, delta_v))
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_exec::{LocalSolveExec, PjrtRuntime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_generated_schema() {
        let dir = std::env::temp_dir().join("sparkbench_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format": "hlo-text",
                "local_solve": {"file": "ls.hlo.txt", "m": 512, "nk": 512,
                                 "h_max": 4096, "vmem_bytes_estimate": 1100000},
                "objective": {"file": "obj.hlo.txt", "m": 512, "n": 1024}}"#,
        )
        .unwrap();
        let man = Manifest::load(&dir).unwrap();
        assert_eq!(man.m, 512);
        assert_eq!(man.nk, 512);
        assert_eq!(man.h_max, 4096);
        assert_eq!(man.local_solve_file, "ls.hlo.txt");
        assert_eq!(man.objective_file.as_deref(), Some("obj.hlo.txt"));
        assert_eq!(man.vmem_bytes_estimate, Some(1_100_000));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_missing_is_actionable_error() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(format!("{:#}", err).contains("make artifacts"));
    }

    #[test]
    fn manifest_rejects_wrong_format() {
        let dir = std::env::temp_dir().join("sparkbench_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"format": "proto", "local_solve": {"file": "x", "m": 1, "nk": 1, "h_max": 1}}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
