//! Admission control for the serving front end: bounded queueing,
//! load-shedding, graceful deadline degradation, and model hot-swap —
//! the overload half of the durability story (DESIGN.md §15).
//!
//! The batching front end (`serve::batch`) trades latency for
//! throughput below its cutover rate λ* = `max_batch / max_delay`; above
//! λ* an unbounded queue grows without limit and every latency target is
//! eventually lost. The [`AdmissionController`] closes that hole with
//! three mechanisms, all closed-form and virtual-clocked (no wall reads
//! — this module is *outside* the clock-rule allowlist on purpose):
//!
//! 1. **Bounded queue with load-shedding.** Arrivals beyond `queue_cap`
//!    pending requests are rejected with a typed [`Admission::Overload`]
//!    outcome — the queue never grows past its high-water mark, so the
//!    latency of every *admitted* request stays bounded.
//! 2. **Graceful degradation.** The effective batching deadline shrinks
//!    linearly from `max_delay` at the low-water mark to zero at the
//!    high-water mark ([`AdmissionController::degraded_delay`]):
//!    `d(q) = max_delay · (cap − q)/(cap − low)` for `low < q < cap`.
//!    Under pressure the server stops waiting for fuller batches and
//!    burns queue depth instead; when pressure drops, the deadline
//!    recovers automatically (it is a pure function of depth).
//! 3. **Hot-swap at a batch boundary.** A new model (e.g. decoded from a
//!    fresher [`CheckpointStore`](crate::coordinator::checkpoint::CheckpointStore)
//!    envelope) replaces the serving model between batches — a pointer
//!    flip, no queue drain; the in-flight batch finishes on the old
//!    model, every later batch scores with the new one.
//!
//! [`overload_replay`] is the deterministic fault harness around those
//! pieces: seeded burst/storm arrival patterns, malformed request rows
//! (validated and refused *before* they can poison the batch arena), and
//! mid-stream swaps, all on a virtual clock with a closed-form service
//! model — so every overload experiment replays bit-exactly from its
//! seed, the same property training chaos has (DESIGN.md §12).

use crate::data::CsrMatrix;
use crate::linalg::Xorshift128;
use crate::serve::batch::BatchPolicy;
use crate::serve::model::PrimalModel;

/// Typed outcome of offering one request to the admission controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Enqueued; the request will be batched and served.
    Accepted,
    /// Load-shed: the queue is at its high-water mark. The caller gets
    /// an immediate typed rejection instead of unbounded queueing.
    Overload,
    /// Refused before the queue: the request row failed validation
    /// (length mismatch or out-of-range column index).
    Malformed,
}

/// Validate a sparse request row against the model dimension before it
/// touches a batch arena. `CsrMatrix::push_row` hard-asserts these
/// invariants — a malformed row must be refused *here*, as a typed
/// serving outcome, never as a server panic.
pub fn validate_request(dim: usize, idx: &[u32], vals: &[f64]) -> Result<(), String> {
    if idx.len() != vals.len() {
        return Err(format!(
            "request has {} indices but {} values",
            idx.len(),
            vals.len()
        ));
    }
    for &c in idx {
        if c as usize >= dim {
            return Err(format!("column {} out of range (dim {})", c, dim));
        }
    }
    Ok(())
}

/// Bounded-queue admission policy + counters. The controller decides —
/// the caller owns the actual queue; depth is passed in at each offer so
/// the decision logic stays a pure function of observable state.
#[derive(Debug, Clone)]
pub struct AdmissionController {
    /// High-water mark: offers at depth ≥ cap are shed.
    queue_cap: usize,
    /// Low-water mark: below this depth the full `max_delay` applies.
    low_water: usize,
    /// The undegraded batching deadline (the policy's `max_delay`).
    base_delay: f64,
    /// Requests admitted.
    pub admitted: usize,
    /// Requests shed with [`Admission::Overload`].
    pub shed: usize,
    /// Requests refused with [`Admission::Malformed`].
    pub malformed: usize,
}

impl AdmissionController {
    /// Build a controller for a batching policy and queue bound.
    /// `queue_cap` must admit at least one full batch, or the server
    /// could never reach a size flush.
    pub fn new(policy: &BatchPolicy, queue_cap: usize) -> AdmissionController {
        assert!(
            queue_cap >= policy.max_batch,
            "queue_cap {} must be >= max_batch {}",
            queue_cap,
            policy.max_batch
        );
        AdmissionController {
            queue_cap,
            low_water: queue_cap / 4,
            base_delay: policy.max_delay,
            admitted: 0,
            shed: 0,
            malformed: 0,
        }
    }

    pub fn queue_cap(&self) -> usize {
        self.queue_cap
    }

    pub fn low_water(&self) -> usize {
        self.low_water
    }

    /// The degraded batching deadline at queue depth `q` — closed form,
    /// monotone non-increasing in depth, and self-recovering (a pure
    /// function of depth: when pressure drops, the full delay returns):
    ///
    /// ```text
    /// d(q) = max_delay                          q ≤ low
    /// d(q) = max_delay · (cap − q)/(cap − low)  low < q < cap
    /// d(q) = 0                                  q ≥ cap
    /// ```
    ///
    /// Read alongside λ* = `max_batch / max_delay`: shrinking the
    /// deadline raises the flush rate toward one-batch-per-service-slot,
    /// spending latency headroom to drain depth.
    pub fn degraded_delay(&self, q: usize) -> f64 {
        if q <= self.low_water {
            self.base_delay
        } else if q >= self.queue_cap {
            0.0
        } else {
            self.base_delay * ((self.queue_cap - q) as f64)
                / ((self.queue_cap - self.low_water) as f64)
        }
    }

    /// Offer one (already validated) request at current queue depth `q`.
    pub fn offer(&mut self, q: usize) -> Admission {
        if q >= self.queue_cap {
            self.shed += 1;
            Admission::Overload
        } else {
            self.admitted += 1;
            Admission::Accepted
        }
    }

    /// Record a validation refusal (kept here so shed-rate accounting
    /// lives in one place).
    pub fn refuse_malformed(&mut self) -> Admission {
        self.malformed += 1;
        Admission::Malformed
    }
}

/// Deterministic arrival-time generator for the overload harness. All
/// patterns produce a non-decreasing virtual-time sequence from a seed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalPattern {
    /// Constant spacing `1/rate` — the baseline open-loop load.
    Uniform { rate: f64 },
    /// `burst` back-to-back arrivals spaced `within` seconds, then a
    /// `gap`-second pause: the classic thundering-herd shape.
    Burst { burst: usize, within: f64, gap: f64 },
    /// Seeded storm: mean spacing `1/rate`, per-arrival multiplier drawn
    /// uniformly from `[0.1, 1.9]` — bursty but bit-replayable.
    Storm { rate: f64 },
}

impl ArrivalPattern {
    fn next_gap(&self, i: usize, rng: &mut Xorshift128) -> f64 {
        match *self {
            ArrivalPattern::Uniform { rate } => 1.0 / rate,
            ArrivalPattern::Burst { burst, within, gap } => {
                let b = burst.max(1);
                if i % b == 0 && i > 0 {
                    gap
                } else {
                    within
                }
            }
            ArrivalPattern::Storm { rate } => (0.1 + 1.8 * rng.next_f64()) / rate,
        }
    }
}

/// Closed-form virtual service model: a batch of `b` rows occupies the
/// server `overhead_s + per_row_s · b` seconds. The sustainable service
/// rate is `μ(b) = b / (overhead_s + per_row_s · b)`, maximized at
/// `b = max_batch` — arrivals beyond `μ(max_batch)` are overload by
/// construction, which is exactly what the harness provokes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServiceModel {
    pub overhead_s: f64,
    pub per_row_s: f64,
}

impl ServiceModel {
    pub fn batch_cost(&self, b: usize) -> f64 {
        self.overhead_s + self.per_row_s * b as f64
    }

    /// The maximum arrival rate the server can sustain (full batches).
    pub fn sustainable_rate(&self, max_batch: usize) -> f64 {
        max_batch as f64 / self.batch_cost(max_batch)
    }
}

/// Harness knobs for [`overload_replay`].
#[derive(Debug, Clone)]
pub struct OverloadConfig {
    /// Bounded-queue capacity (high-water mark).
    pub queue_cap: usize,
    /// Virtual service-time model.
    pub service: ServiceModel,
    /// Present every `n`-th arrival malformed (one column pushed out of
    /// range). 0 = no malformed traffic.
    pub malformed_every: usize,
    /// Hot-swap to the standby model once this many batches completed
    /// (pointer flip at the batch boundary). `None` = never swap.
    pub swap_at_batch: Option<usize>,
    /// Seed for the arrival pattern's stochastic draws.
    pub seed: u64,
}

/// What the overload harness measured. Latencies are virtual seconds
/// (completion − arrival) over admitted-and-served requests.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct OverloadStats {
    /// Requests presented (admitted + shed + malformed).
    pub offered: usize,
    pub admitted: usize,
    pub shed: usize,
    pub malformed: usize,
    /// `shed / offered` — the load-shedding rate under this pattern.
    pub shed_rate: f64,
    /// Batches served.
    pub batches: usize,
    /// Batches formed while the deadline was degraded below `max_delay`.
    pub degraded_batches: usize,
    /// `degraded_batches / batches` — degraded-delay occupancy.
    pub degraded_occupancy: f64,
    /// Largest queue depth observed at any admission decision.
    pub max_depth: usize,
    /// Virtual latency percentiles over served requests.
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    /// Batches served by the standby model after the hot-swap boundary.
    pub swapped_batches: usize,
}

/// Nearest-rank percentile over unsorted samples (p in [0, 100]).
fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut s = samples.to_vec();
    s.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * s.len() as f64).ceil() as usize;
    s[rank.saturating_sub(1).min(s.len() - 1)]
}

/// Drive `rows` through admission control, degraded batching, and a
/// virtual-clock service loop — the serve-side fault harness.
///
/// Requests arrive at pattern-generated virtual times; each is validated
/// ([`validate_request`]) and offered to the controller. Admitted
/// requests queue; the server forms a batch whenever `max_batch` are
/// pending (size flush) or the oldest waiter's *degraded* deadline
/// passes, then scores it with the active model at the closed-form
/// service cost. A configured hot-swap flips to `standby` at a batch
/// boundary without draining the queue. `preds_out` receives
/// `(row index, prediction)` in service order — bit-comparable across
/// runs and against a drained-then-swapped baseline.
pub fn overload_replay(
    primary: &PrimalModel,
    standby: Option<&PrimalModel>,
    rows: &CsrMatrix,
    policy: &BatchPolicy,
    pattern: &ArrivalPattern,
    cfg: &OverloadConfig,
    preds_out: &mut Vec<(usize, f64)>,
) -> OverloadStats {
    assert_eq!(rows.n, primary.dim(), "request dim != model dim");
    if let Some(sb) = standby {
        assert_eq!(sb.dim(), primary.dim(), "standby model dim mismatch");
    }
    let mut ctrl = AdmissionController::new(policy, cfg.queue_cap);
    let mut rng = Xorshift128::new(cfg.seed ^ 0x0AD_317);
    let mut st = OverloadStats::default();
    let mut latencies: Vec<f64> = Vec::new();
    // FIFO of admitted requests: (row id, arrival time); head advances as
    // batches form (no reallocation churn, stable iteration order).
    let mut queue: Vec<(usize, f64)> = Vec::new();
    let mut head = 0usize;
    let mut server_free = 0.0f64;
    let mut active_standby = false;

    // One corrupted-index scratch per malformed presentation.
    let mut bad_idx: Vec<u32> = Vec::new();

    let serve_until = |t_limit: f64,
                       queue: &[(usize, f64)],
                       head: &mut usize,
                       server_free: &mut f64,
                       st: &mut OverloadStats,
                       latencies: &mut Vec<f64>,
                       preds_out: &mut Vec<(usize, f64)>,
                       ctrl: &AdmissionController,
                       active_standby: &mut bool| {
        loop {
            let pending = queue.len() - *head;
            if pending == 0 {
                break;
            }
            let t_first = queue[*head].1;
            let t_ready = if *server_free > t_first {
                *server_free
            } else {
                t_first
            };
            let delay = ctrl.degraded_delay(pending);
            let t_form = if pending >= policy.max_batch {
                t_ready
            } else {
                let t_deadline = t_first + delay;
                if t_deadline > t_ready {
                    t_deadline
                } else {
                    t_ready
                }
            };
            // The next arrival lands before this batch would form: let it
            // join the queue first (it may complete a size flush earlier).
            if t_form > t_limit {
                break;
            }
            // Pointer flip at the batch boundary: in-flight batches (all
            // earlier ones) finished on the old model; this one and every
            // later one score with the standby.
            if let Some(sw) = cfg.swap_at_batch {
                if st.batches >= sw {
                    *active_standby = standby.is_some();
                }
            }
            let k = pending.min(policy.max_batch);
            let t_done = t_form + cfg.service.batch_cost(k);
            let model = if *active_standby {
                standby.expect("active_standby without a standby model")
            } else {
                primary
            };
            for &(rid, t_arr) in &queue[*head..*head + k] {
                let (idx, vals) = rows.row(rid);
                preds_out.push((rid, model.predict_one(idx, vals)));
                latencies.push(t_done - t_arr);
            }
            *head += k;
            *server_free = t_done;
            st.batches += 1;
            if delay < ctrl.base_delay {
                st.degraded_batches += 1;
            }
            if *active_standby {
                st.swapped_batches += 1;
            }
        }
    };

    let mut t_arr = 0.0f64;
    for i in 0..rows.m {
        t_arr += pattern.next_gap(i, &mut rng);
        // Serve every batch that forms strictly before this arrival.
        serve_until(
            t_arr,
            &queue,
            &mut head,
            &mut server_free,
            &mut st,
            &mut latencies,
            preds_out,
            &ctrl,
            &mut active_standby,
        );
        st.offered += 1;
        let (idx, vals) = rows.row(i);
        // Malformed presentation: one column index pushed past the model
        // dimension — must be refused before any arena push.
        let malformed = cfg.malformed_every > 0 && (i + 1) % cfg.malformed_every == 0;
        let verdict = if malformed && !idx.is_empty() {
            bad_idx.clear();
            bad_idx.extend_from_slice(idx);
            bad_idx[0] = rows.n as u32 + 7;
            validate_request(rows.n, &bad_idx, vals)
        } else {
            validate_request(rows.n, idx, vals)
        };
        if verdict.is_err() {
            ctrl.refuse_malformed();
            continue;
        }
        let depth = queue.len() - head;
        if depth > st.max_depth {
            st.max_depth = depth;
        }
        match ctrl.offer(depth) {
            Admission::Accepted => queue.push((i, t_arr)),
            Admission::Overload => {}
            Admission::Malformed => unreachable!("offer never reports malformed"),
        }
    }
    // Drain: no more arrivals, serve everything still queued.
    serve_until(
        f64::INFINITY,
        &queue,
        &mut head,
        &mut server_free,
        &mut st,
        &mut latencies,
        preds_out,
        &ctrl,
        &mut active_standby,
    );

    st.admitted = ctrl.admitted;
    st.shed = ctrl.shed;
    st.malformed = ctrl.malformed;
    st.shed_rate = if st.offered > 0 {
        st.shed as f64 / st.offered as f64
    } else {
        0.0
    };
    st.degraded_occupancy = if st.batches > 0 {
        st.degraded_batches as f64 / st.batches as f64
    } else {
        0.0
    };
    st.p50_latency_s = percentile(&latencies, 50.0);
    st.p99_latency_s = percentile(&latencies, 99.0);
    st
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;
    use crate::problem::Problem;

    fn policy() -> BatchPolicy {
        BatchPolicy::new(8, 0.010)
    }

    fn model(n: usize, phase: f64) -> PrimalModel {
        let alpha: Vec<f64> = (0..n).map(|j| (j as f64 * 0.37 + phase).sin()).collect();
        PrimalModel::from_parts(Problem::ridge(1.0), &alpha, &[], Precision::F64, 1)
    }

    fn rows(m: usize, n: usize) -> CsrMatrix {
        let mut a = CsrMatrix::arena(n, m, 3 * m);
        for i in 0..m {
            let c0 = (i % n) as u32;
            let c1 = ((i + 3) % n) as u32;
            let (idx, vals) = if c0 < c1 {
                ([c0, c1], [1.0 + i as f64 * 0.01, -0.5])
            } else {
                ([c1, c0], [-0.5, 1.0 + i as f64 * 0.01])
            };
            a.push_row(&idx, &vals);
        }
        a
    }

    #[test]
    fn degraded_delay_is_monotone_and_recovers() {
        let ctrl = AdmissionController::new(&policy(), 64);
        assert_eq!(ctrl.low_water(), 16);
        // Full delay at and below the low-water mark.
        assert_eq!(ctrl.degraded_delay(0).to_bits(), 0.010f64.to_bits());
        assert_eq!(ctrl.degraded_delay(16).to_bits(), 0.010f64.to_bits());
        // Monotone non-increasing across the whole depth range.
        for q in 0..80 {
            assert!(
                ctrl.degraded_delay(q + 1) <= ctrl.degraded_delay(q),
                "delay increased between depth {} and {}",
                q,
                q + 1
            );
        }
        // Zero at and past the high-water mark; closed-form midpoint pin.
        assert_eq!(ctrl.degraded_delay(64), 0.0);
        assert_eq!(ctrl.degraded_delay(100), 0.0);
        let mid = ctrl.degraded_delay(40); // (64-40)/(64-16) = 1/2
        assert_eq!(mid.to_bits(), (0.010f64 * 0.5).to_bits());
        // Recovery is structural: the delay is a pure function of depth,
        // so after any excursion to depth 63 the shallow answer is back.
        let _ = ctrl.degraded_delay(63);
        assert_eq!(ctrl.degraded_delay(2).to_bits(), 0.010f64.to_bits());
    }

    #[test]
    fn offer_sheds_only_at_the_high_water_mark() {
        let mut ctrl = AdmissionController::new(&policy(), 16);
        assert_eq!(ctrl.offer(0), Admission::Accepted);
        assert_eq!(ctrl.offer(15), Admission::Accepted);
        assert_eq!(ctrl.offer(16), Admission::Overload);
        assert_eq!(ctrl.offer(40), Admission::Overload);
        assert_eq!(ctrl.refuse_malformed(), Admission::Malformed);
        assert_eq!(ctrl.admitted, 2);
        assert_eq!(ctrl.shed, 2);
        assert_eq!(ctrl.malformed, 1);
    }

    #[test]
    #[should_panic(expected = "queue_cap")]
    fn queue_cap_must_admit_a_full_batch() {
        let _ = AdmissionController::new(&policy(), 7);
    }

    #[test]
    fn validate_request_refuses_malformed_shapes() {
        assert!(validate_request(8, &[0, 3], &[1.0, 2.0]).is_ok());
        assert!(validate_request(8, &[], &[]).is_ok());
        let err = validate_request(8, &[0, 3], &[1.0]).unwrap_err();
        assert!(err.contains("indices"), "{}", err);
        let err = validate_request(8, &[0, 8], &[1.0, 2.0]).unwrap_err();
        assert!(err.contains("out of range"), "{}", err);
    }

    #[test]
    fn service_model_closed_forms_pin_the_overload_threshold() {
        // Dyadic constants so the closed forms are exact in binary fp.
        let svc = ServiceModel { overhead_s: 0.25, per_row_s: 0.03125 };
        assert_eq!(svc.batch_cost(8).to_bits(), 0.5f64.to_bits());
        assert_eq!(svc.sustainable_rate(8).to_bits(), 16.0f64.to_bits());
        // Larger batches amortize the overhead: μ(b) grows with b.
        assert!(svc.sustainable_rate(16) > svc.sustainable_rate(8));
    }

    #[test]
    fn percentile_is_nearest_rank() {
        let s: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(percentile(&s, 50.0), 5.0);
        assert_eq!(percentile(&s, 99.0), 10.0);
        assert_eq!(percentile(&s, 10.0), 1.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        // Unsorted input sorts internally.
        assert_eq!(percentile(&[3.0, 1.0, 2.0], 100.0), 3.0);
    }

    #[test]
    fn arrival_patterns_replay_bit_exactly_from_their_seed() {
        let storm = ArrivalPattern::Storm { rate: 100.0 };
        let mut a = Xorshift128::new(9);
        let mut b = Xorshift128::new(9);
        for i in 0..200 {
            let ga = storm.next_gap(i, &mut a);
            let gb = storm.next_gap(i, &mut b);
            assert_eq!(ga.to_bits(), gb.to_bits(), "storm gap {} diverged", i);
            assert!(ga > 0.0);
        }
        // Burst: `burst` tight arrivals, then a gap, repeating.
        let burst = ArrivalPattern::Burst { burst: 4, within: 0.001, gap: 0.1 };
        let mut rng = Xorshift128::new(1);
        let gaps: Vec<f64> = (0..9).map(|i| burst.next_gap(i, &mut rng)).collect();
        assert_eq!(gaps[3], 0.001);
        assert_eq!(gaps[4], 0.1);
        assert_eq!(gaps[8], 0.1);
        let uni = ArrivalPattern::Uniform { rate: 50.0 };
        assert_eq!(uni.next_gap(7, &mut rng).to_bits(), (1.0 / 50.0).to_bits());
    }

    #[test]
    fn uncontended_replay_serves_everything_with_no_shedding() {
        // Arrivals far below μ(max_batch): nothing sheds, nothing
        // degrades, and every row is served exactly once.
        let n = 8;
        let m = 64;
        let primary = model(n, 0.0);
        let a = rows(m, n);
        let svc = ServiceModel { overhead_s: 0.0001, per_row_s: 0.00001 };
        let cfg = OverloadConfig {
            queue_cap: 32,
            service: svc,
            malformed_every: 0,
            swap_at_batch: None,
            seed: 42,
        };
        let pattern = ArrivalPattern::Uniform { rate: svc.sustainable_rate(8) * 0.2 };
        let mut preds = Vec::new();
        let st = overload_replay(&primary, None, &a, &policy(), &pattern, &cfg, &mut preds);
        assert_eq!(st.offered, m);
        assert_eq!(st.admitted, m);
        assert_eq!(st.shed, 0);
        assert_eq!(st.malformed, 0);
        assert_eq!(st.degraded_batches, 0);
        assert_eq!(preds.len(), m);
        for (rid, p) in &preds {
            let (idx, vals) = a.row(*rid);
            assert_eq!(p.to_bits(), primary.predict_one(idx, vals).to_bits());
        }
    }
}
