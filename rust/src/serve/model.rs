//! The servable artifact: a primal weight vector extracted from training
//! state, with the per-family output transform.
//!
//! ## Why one dense vector covers all four families
//!
//! Training maintains `(α, v = Aα)`. The serving-side weight vector
//! depends on the layout the family trains (DESIGN.md §9, §13):
//!
//! * **Squared loss** (ridge / lasso / elastic): `A` is datapoints ×
//!   features and α *is* the feature-space model — a request row `x`
//!   predicts `ŷ = x·α`. Predicting the training rows themselves computes
//!   `Aα`, i.e. exactly the training-side `v` (same sum, row-major
//!   order, so equal to floating-point tolerance rather than bitwise).
//! * **Dual losses** (SVM hinge, logistic): `A` is features × datapoints
//!   with label-scaled columns `q_j = y_j·x_j`, and the trained primal
//!   weight vector is `w = v = Aα` itself. A datapoint's decision score
//!   is `x·v`; scoring the training columns computes `Aᵀv` through the
//!   very same per-column `dot_indexed` calls training's `matvec_t`
//!   issues — **bit-identical** to the training-side quantity
//!   (`tests/integration_serve.rs` pins this).
//!
//! Checkpoint envelopes hex-pack both vectors bit-exactly, so a model
//! extracted from disk is indistinguishable — to the bit — from one
//! extracted from the live `Session` that wrote the checkpoint.

use crate::config::Precision;
use crate::coordinator::checkpoint::Checkpoint;
use crate::problem::{LossKind, Problem};

/// What a finalized prediction means, per problem family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Output {
    /// Regression value `x·α` (ridge / lasso / elastic).
    Value,
    /// SVM decision score `x·v` (sign = class, magnitude = margin).
    Score,
    /// Logistic probability `σ(x·v)` of the positive class.
    Probability,
}

impl Output {
    pub fn name(&self) -> &'static str {
        match self {
            Output::Value => "value",
            Output::Score => "score",
            Output::Probability => "probability",
        }
    }
}

/// A trained model in serving form: dense weights + output transform.
#[derive(Debug, Clone, PartialEq)]
pub struct PrimalModel {
    problem: Problem,
    precision: Precision,
    rounds: usize,
    weights: Vec<f64>,
}

impl PrimalModel {
    /// Extract the serving weights from raw training state. For squared
    /// loss the weights are α (feature space, length n); for the dual
    /// losses they are `v = Aα` (feature space of the dual layout,
    /// length m). The copied weights preserve every bit.
    pub fn from_parts(
        problem: Problem,
        alpha: &[f64],
        v: &[f64],
        precision: Precision,
        rounds: usize,
    ) -> PrimalModel {
        let weights = match problem.loss {
            LossKind::Squared => alpha.to_vec(),
            LossKind::Hinge | LossKind::Logistic => v.to_vec(),
        };
        PrimalModel {
            problem,
            precision,
            rounds,
            weights,
        }
    }

    /// Extract from a decoded checkpoint (any envelope version — see
    /// [`Envelope::peek`](crate::coordinator::checkpoint::Envelope::peek)
    /// for the engine-free disk path). Errors if the checkpoint carries
    /// no servable weights.
    pub fn from_checkpoint(c: &Checkpoint) -> Result<PrimalModel, String> {
        let model = PrimalModel::from_parts(c.problem, &c.alpha, &c.v, c.precision, c.round);
        if model.weights.is_empty() {
            return Err("checkpoint has an empty weight vector — nothing to serve".into());
        }
        Ok(model)
    }

    /// Request-row dimension: the length a request's dense index space
    /// must match.
    pub fn dim(&self) -> usize {
        self.weights.len()
    }

    pub fn weights(&self) -> &[f64] {
        &self.weights
    }

    pub fn problem(&self) -> &Problem {
        &self.problem
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Training rounds behind these weights (provenance for logs).
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// The output transform this family's predictions go through.
    pub fn output(&self) -> Output {
        match self.problem.loss {
            LossKind::Squared => Output::Value,
            LossKind::Hinge => Output::Score,
            LossKind::Logistic => Output::Probability,
        }
    }

    /// Raw linear score of one sparse request row — ONE dispatched
    /// `dot_indexed` kernel call, the entire per-request hot path.
    #[inline]
    pub fn raw_score(&self, idx: &[u32], vals: &[f64]) -> f64 {
        crate::linalg::dot_indexed(idx, vals, &self.weights)
    }

    /// Apply the family's output transform to a raw score. `Value` and
    /// `Score` are the identity — for those families every "finalized"
    /// prediction is bit-identical to its raw score.
    #[inline]
    pub fn finalize(&self, raw: f64) -> f64 {
        match self.output() {
            Output::Value | Output::Score => raw,
            Output::Probability => sigmoid(raw),
        }
    }

    /// Finalized prediction for one sparse request row.
    #[inline]
    pub fn predict_one(&self, idx: &[u32], vals: &[f64]) -> f64 {
        self.finalize(self.raw_score(idx, vals))
    }
}

/// Numerically stable logistic sigmoid: never exponentiates a positive
/// argument, so no overflow for any finite score.
#[inline]
pub fn sigmoid(s: f64) -> f64 {
    if s >= 0.0 {
        1.0 / (1.0 + (-s).exp())
    } else {
        let e = s.exp();
        e / (1.0 + e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_selects_weights_and_output() {
        let alpha = vec![1.0, -2.0, 3.0];
        let v = vec![0.5, 0.25];
        let m = PrimalModel::from_parts(Problem::ridge(1.0), &alpha, &v, Precision::F64, 7);
        assert_eq!(m.weights(), &alpha[..]);
        assert_eq!(m.dim(), 3);
        assert_eq!(m.output(), Output::Value);
        assert_eq!(m.rounds(), 7);
        let m = PrimalModel::from_parts(Problem::lasso(1.0), &alpha, &v, Precision::F64, 0);
        assert_eq!(m.output(), Output::Value);
        let m = PrimalModel::from_parts(Problem::svm(1.0), &alpha, &v, Precision::F64, 0);
        assert_eq!(m.weights(), &v[..]);
        assert_eq!(m.output(), Output::Score);
        let m = PrimalModel::from_parts(Problem::logistic(1.0), &alpha, &v, Precision::F64, 0);
        assert_eq!(m.weights(), &v[..]);
        assert_eq!(m.output(), Output::Probability);
        assert_eq!(m.output().name(), "probability");
    }

    #[test]
    fn predict_one_is_a_sparse_dot() {
        let m = PrimalModel::from_parts(
            Problem::ridge(1.0),
            &[1.0, 10.0, 100.0, 1000.0],
            &[],
            Precision::F64,
            1,
        );
        // row = {0: 2, 3: 0.5} → 2·1 + 0.5·1000 = 502
        assert_eq!(m.predict_one(&[0, 3], &[2.0, 0.5]), 502.0);
        assert_eq!(m.predict_one(&[], &[]), 0.0);
    }

    #[test]
    fn sigmoid_is_stable_and_symmetric() {
        assert_eq!(sigmoid(0.0), 0.5);
        assert!(sigmoid(40.0) > 0.999999);
        assert!(sigmoid(-40.0) < 1e-6);
        // No overflow at extreme scores.
        assert_eq!(sigmoid(1e308), 1.0);
        assert_eq!(sigmoid(-1e308), 0.0);
        for s in [0.1, 1.5, 7.0] {
            assert!((sigmoid(s) + sigmoid(-s) - 1.0).abs() < 1e-15);
        }
        // Logistic model finalizes through it.
        let m = PrimalModel::from_parts(Problem::logistic(1.0), &[], &[2.0], Precision::F64, 1);
        let p = m.predict_one(&[0], &[1.0]);
        assert!((p - sigmoid(2.0)).abs() < 1e-15);
    }

    #[test]
    fn checkpoint_extraction_matches_parts_bitwise() {
        let c = Checkpoint {
            round: 12,
            time: 1.0,
            alpha: vec![1.0, f64::MIN_POSITIVE, -0.0, 1e300],
            v: vec![3.25, -2.5],
            problem: Problem::svm(2.0),
            workers: 4,
            threads_per_worker: 1,
            precision: Precision::F64,
            fault_cursor: 0,
        };
        let from_ckpt = PrimalModel::from_checkpoint(&c).unwrap();
        let from_parts =
            PrimalModel::from_parts(c.problem, &c.alpha, &c.v, c.precision, c.round);
        assert_eq!(from_ckpt, from_parts);
        for (a, b) in from_ckpt.weights().iter().zip(c.v.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Empty weights refuse.
        let mut empty = c.clone();
        empty.v.clear();
        assert!(PrimalModel::from_checkpoint(&empty).is_err());
    }
}
