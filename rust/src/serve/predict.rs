//! The serving hot path: batch predict over a CSR request block.
//!
//! One dispatched `dot_indexed` per row (scalar or AVX2 — whatever
//! `linalg::kernels` selected at startup), writing into a caller-owned
//! buffer: **zero steady-state allocations** once the buffer has warmed
//! up, asserted by the counting allocator in tests and the hotpath bench.
//!
//! The sharded variant fans the SAME per-row kernel calls across OS
//! threads over disjoint, contiguous row ranges (`split_at_mut`, like the
//! physical tree-reduce). Each prediction depends only on its own row and
//! the shared read-only weights, so the sharded output is **bit-identical**
//! to the sequential sweep — parallelism changes wall-clock, never a bit
//! (`tests/integration_serve.rs` pins all four families).

use crate::data::csr::CsrMatrix;

use super::model::PrimalModel;

/// A model wrapped for batch serving.
#[derive(Debug, Clone)]
pub struct Predictor {
    model: PrimalModel,
}

impl Predictor {
    pub fn new(model: PrimalModel) -> Predictor {
        Predictor { model }
    }

    pub fn model(&self) -> &PrimalModel {
        &self.model
    }

    /// Finalized predictions for every row of `rows`, into a caller-owned
    /// buffer (cleared, then filled in row order). Allocation-free once
    /// `out` has capacity for `rows.m` — THE steady-state serving path.
    // lint: alloc-free (THE steady-state serving path once `out` is warm)
    pub fn predict_into(&self, rows: &CsrMatrix, out: &mut Vec<f64>) {
        assert_eq!(
            rows.n,
            self.model.dim(),
            "request dimension {} != model dimension {}",
            rows.n,
            self.model.dim()
        );
        out.clear();
        out.reserve(rows.m);
        for i in 0..rows.m {
            let (ci, vs) = rows.row(i);
            out.push(self.model.predict_one(ci, vs));
        }
    }

    /// Allocating convenience wrapper over
    /// [`predict_into`](Predictor::predict_into).
    pub fn predict(&self, rows: &CsrMatrix) -> Vec<f64> {
        let mut out = Vec::new();
        self.predict_into(rows, &mut out);
        out
    }

    /// Multi-core batch predict: split the rows into `shards` contiguous
    /// ranges and sweep them on OS threads, each writing its own disjoint
    /// slice of `out`. Per-row work is the identical `predict_one` call
    /// the sequential path makes, so the result is bit-identical to
    /// [`predict_into`](Predictor::predict_into) for any shard count.
    /// Thread spawns allocate — this path trades the zero-alloc guarantee
    /// for wall-clock on large batches; `shards <= 1` falls back to the
    /// sequential sweep.
    // lint: alloc-free (thread spawns aside, per-row work must stay alloc-free)
    pub fn predict_sharded_into(&self, rows: &CsrMatrix, shards: usize, out: &mut Vec<f64>) {
        if shards <= 1 || rows.m <= 1 {
            self.predict_into(rows, out);
            return;
        }
        assert_eq!(
            rows.n,
            self.model.dim(),
            "request dimension {} != model dimension {}",
            rows.n,
            self.model.dim()
        );
        let shards = shards.min(rows.m);
        out.clear();
        out.resize(rows.m, 0.0);
        // Balanced contiguous ranges: the first `rem` shards get one extra
        // row. Range boundaries cannot affect bits — rows are independent.
        let base = rows.m / shards;
        let rem = rows.m % shards;
        std::thread::scope(|scope| {
            let mut rest = &mut out[..];
            let mut lo = 0usize;
            for s in 0..shards {
                let len = base + usize::from(s < rem);
                let (chunk, tail) = rest.split_at_mut(len);
                rest = tail;
                let start = lo;
                lo += len;
                scope.spawn(move || {
                    for (k, slot) in chunk.iter_mut().enumerate() {
                        let (ci, vs) = rows.row(start + k);
                        *slot = self.model.predict_one(ci, vs);
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;
    use crate::data::synthetic::{webspam_like, SyntheticSpec};
    use crate::problem::Problem;

    fn ridge_predictor(n: usize) -> Predictor {
        let alpha: Vec<f64> = (0..n).map(|j| (j as f64 * 0.37).sin()).collect();
        Predictor::new(PrimalModel::from_parts(
            Problem::ridge(1.0),
            &alpha,
            &[],
            Precision::F64,
            1,
        ))
    }

    #[test]
    fn batched_predict_matches_per_row_calls() {
        let ds = webspam_like(&SyntheticSpec::small());
        let rows = CsrMatrix::from_csc(&ds.a);
        let p = ridge_predictor(ds.n());
        let got = p.predict(&rows);
        assert_eq!(got.len(), rows.m);
        for i in 0..rows.m {
            let (ci, vs) = rows.row(i);
            assert_eq!(got[i].to_bits(), p.model().predict_one(ci, vs).to_bits());
        }
    }

    #[test]
    fn warmed_batch_predict_never_allocates() {
        let ds = webspam_like(&SyntheticSpec::small());
        let rows = CsrMatrix::from_csc(&ds.a);
        let p = ridge_predictor(ds.n());
        let mut out = Vec::new();
        p.predict_into(&rows, &mut out); // warm the buffer
        let before = crate::testkit::alloc::current_thread_allocations();
        for _ in 0..20 {
            p.predict_into(&rows, &mut out);
        }
        let after = crate::testkit::alloc::current_thread_allocations();
        assert_eq!(after - before, 0, "steady-state batched predict allocated");
    }

    #[test]
    fn sharded_is_bit_identical_for_any_shard_count() {
        let ds = webspam_like(&SyntheticSpec::small());
        let rows = CsrMatrix::from_csc(&ds.a);
        let p = ridge_predictor(ds.n());
        let seq = p.predict(&rows);
        let mut out = Vec::new();
        for shards in [1, 2, 3, 7, rows.m, rows.m + 5] {
            p.predict_sharded_into(&rows, shards, &mut out);
            assert_eq!(out.len(), seq.len());
            for (i, (a, b)) in out.iter().zip(seq.iter()).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "row {} differs at {} shards", i, shards);
            }
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let p = ridge_predictor(16);
        let arena = CsrMatrix::arena(16, 4, 8);
        let mut out = vec![1.0; 3];
        p.predict_into(&arena, &mut out);
        assert!(out.is_empty());
        p.predict_sharded_into(&arena, 4, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic(expected = "request dimension")]
    fn dimension_mismatch_panics() {
        let p = ridge_predictor(8);
        let rows = CsrMatrix::zeros(2, 9);
        p.predict_into(&rows, &mut Vec::new());
    }
}
