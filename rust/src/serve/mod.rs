//! Zero-alloc batched inference: the train→serve half of the system
//! (DESIGN.md §13).
//!
//! The paper's north star is a production system serving heavy traffic
//! from the models it trains; seven PRs built the training side of that
//! story and this module closes the loop. Four pieces:
//!
//! * [`PrimalModel`] ([`model`]) — the servable artifact, extracted from a
//!   finished [`Session`](crate::session::Session) (`run_extract`) or an
//!   on-disk checkpoint envelope
//!   ([`Envelope::peek`](crate::coordinator::checkpoint::Envelope::peek) —
//!   engine-free, any v1–v5 envelope). All four `Problem` families map to
//!   one representation: a dense weight vector dotted against sparse
//!   request rows, plus a per-family output transform (regression value,
//!   SVM decision score, logistic probability).
//! * [`Predictor`] ([`predict`]) — the hot path: one
//!   `linalg::dot_indexed` per request row over a
//!   [`CsrMatrix`](crate::data::CsrMatrix) batch (the same dispatched
//!   scalar/SIMD kernel training uses), zero steady-state allocations,
//!   and a sharded multi-core variant that is **bit-identical** to the
//!   sequential sweep (disjoint row ranges, identical per-row kernel
//!   calls — order of independent writes cannot change any bit).
//! * [`Batcher`] + [`BatchPolicy`] ([`batch`]) — the request-batching
//!   front end: flush when the batch fills (`max_batch`) or when the
//!   oldest request's wait hits the deadline (`max_delay`). The cutover
//!   arrival rate λ* = max_batch/max_delay separates the two regimes the
//!   same way PR 2's byte-cost cutover separates sparse from dense
//!   frames: a measurable knee, not a hard-coded choice.
//! * [`OnlineEval`] + [`replay`] ([`stream`]) — held-out stream replay:
//!   online RMSE/accuracy, queue-wait and end-to-end latency percentiles
//!   (p50/p99), and predictions/sec — the numbers
//!   `BENCH_hotpath.json`'s `serving` section records.
//! * [`AdmissionController`] + [`overload_replay`] ([`admission`]) — the
//!   overload layer (DESIGN.md §15): a bounded queue that sheds with a
//!   typed [`Admission::Overload`] outcome past the high-water mark,
//!   closed-form deadline degradation alongside the λ* cutover, model
//!   hot-swap at batch boundaries without draining, and a seeded
//!   burst/storm fault harness whose replays are bit-exact — the
//!   `serving.overload` numbers in `BENCH_hotpath.json`.

pub mod admission;
pub mod batch;
pub mod model;
pub mod predict;
pub mod stream;

pub use admission::{
    overload_replay, Admission, AdmissionController, ArrivalPattern, OverloadConfig,
    OverloadStats, ServiceModel,
};
pub use batch::{BatchPolicy, Batcher, FlushReason};
pub use model::{Output, PrimalModel};
pub use predict::Predictor;
pub use stream::{replay, OnlineEval, ServeStats};
