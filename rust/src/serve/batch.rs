//! The request-batching front end: a size/deadline cutover rule.
//!
//! Requests queue in a reusable [`CsrMatrix`] arena and flush as one
//! batch when either trigger fires:
//!
//! * **size** — the batch reached `max_batch` rows (throughput regime:
//!   amortize per-batch overhead, keep the SIMD sweep long);
//! * **deadline** — the *oldest* queued request has waited `max_delay`
//!   seconds (latency regime: an idle trickle must not strand requests).
//!
//! The two regimes meet at the cutover arrival rate
//! `λ* = max_batch / max_delay`: above λ* batches fill before the timer
//! fires (every flush is a size flush, mean batch ≈ `max_batch`); below
//! λ* the timer always wins (every flush is a deadline flush, mean batch
//! ≈ λ·max_delay, and no request waits longer than `max_delay` plus one
//! batch's compute). Same flavor as the sparse-frame byte-cost cutover of
//! DESIGN.md §7: a closed-form knee that the stream replay measures
//! instead of hard-coding a batch size.

use crate::data::csr::CsrMatrix;

/// Why a batch left the queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushReason {
    /// The batch filled to `max_batch` rows.
    Size,
    /// The oldest request's wait reached `max_delay`.
    Deadline,
    /// End of stream: whatever remained was flushed.
    Drain,
}

/// The batching knobs. Immutable over a serve session.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchPolicy {
    /// Flush as soon as this many requests are queued.
    pub max_batch: usize,
    /// Flush once the oldest queued request has waited this long (seconds).
    pub max_delay: f64,
}

impl BatchPolicy {
    pub fn new(max_batch: usize, max_delay: f64) -> BatchPolicy {
        assert!(max_batch >= 1, "max_batch must be >= 1");
        assert!(max_delay > 0.0, "max_delay must be > 0");
        BatchPolicy {
            max_batch,
            max_delay,
        }
    }

    /// The arrival rate (requests/sec) separating the deadline-bound
    /// regime (below) from the size-bound regime (above).
    pub fn cutover_rate(&self) -> f64 {
        self.max_batch as f64 / self.max_delay
    }
}

/// Accumulates requests into a zero-alloc arena until a flush trigger
/// fires. The caller owns the clock (times are plain `f64` seconds), so
/// the policy is exactly testable with a virtual clock and reusable
/// against a wall clock in the CLI.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    pending: CsrMatrix,
    arrivals: Vec<f64>,
}

impl Batcher {
    /// A batcher over `dim`-dimensional requests. The arena preallocates
    /// for `max_batch` rows so the steady state never allocates.
    pub fn new(policy: BatchPolicy, dim: usize) -> Batcher {
        Batcher {
            pending: CsrMatrix::arena(dim, policy.max_batch, policy.max_batch * 8),
            arrivals: Vec::with_capacity(policy.max_batch),
            policy,
        }
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    pub fn len(&self) -> usize {
        self.pending.m
    }

    pub fn is_empty(&self) -> bool {
        self.pending.m == 0
    }

    /// Queue one request arriving at time `now`. Returns `true` when the
    /// push filled the batch to `max_batch` — the caller must flush
    /// before pushing again.
    pub fn push(&mut self, now: f64, idx: &[u32], vals: &[f64]) -> bool {
        debug_assert!(
            self.pending.m < self.policy.max_batch,
            "pushed into a full batch — flush first"
        );
        self.pending.push_row(idx, vals);
        self.arrivals.push(now);
        self.pending.m >= self.policy.max_batch
    }

    /// The instant the deadline trigger fires: oldest arrival +
    /// `max_delay`. `None` while the queue is empty.
    pub fn deadline(&self) -> Option<f64> {
        self.arrivals.first().map(|&t| t + self.policy.max_delay)
    }

    /// Which trigger (if any) has fired by time `now`.
    pub fn due(&self, now: f64) -> Option<FlushReason> {
        if self.pending.m >= self.policy.max_batch {
            Some(FlushReason::Size)
        } else {
            match self.deadline() {
                Some(d) if now >= d => Some(FlushReason::Deadline),
                _ => None,
            }
        }
    }

    /// The queued batch: request rows plus their arrival times, in
    /// arrival order.
    pub fn batch(&self) -> (&CsrMatrix, &[f64]) {
        (&self.pending, &self.arrivals)
    }

    /// Recycle after processing a flush — capacity retained, so a warmed
    /// batcher's push/clear cycle is allocation-free.
    pub fn clear(&mut self) {
        self.pending.clear_rows();
        self.arrivals.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cutover_rate_is_closed_form() {
        let p = BatchPolicy::new(64, 0.002);
        assert_eq!(p.cutover_rate(), 32_000.0);
    }

    #[test]
    #[should_panic(expected = "max_batch")]
    fn zero_batch_rejected() {
        BatchPolicy::new(0, 1.0);
    }

    #[test]
    fn size_trigger_fires_at_max_batch() {
        let mut b = Batcher::new(BatchPolicy::new(3, 1.0), 4);
        assert!(b.is_empty());
        assert!(!b.push(0.0, &[0], &[1.0]));
        assert!(!b.push(0.1, &[1], &[1.0]));
        assert_eq!(b.due(0.1), None);
        assert!(b.push(0.2, &[2], &[1.0]));
        assert_eq!(b.due(0.2), Some(FlushReason::Size));
        let (rows, arrivals) = b.batch();
        assert_eq!(rows.m, 3);
        assert_eq!(arrivals, &[0.0, 0.1, 0.2]);
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.deadline(), None);
    }

    #[test]
    fn deadline_trigger_tracks_the_oldest_request() {
        let mut b = Batcher::new(BatchPolicy::new(100, 0.5), 4);
        b.push(1.0, &[0], &[1.0]);
        b.push(1.4, &[1], &[1.0]);
        assert_eq!(b.deadline(), Some(1.5)); // oldest + max_delay
        assert_eq!(b.due(1.49), None);
        assert_eq!(b.due(1.5), Some(FlushReason::Deadline));
        b.clear();
        // After a flush the next request restarts the timer.
        b.push(9.0, &[0], &[1.0]);
        assert_eq!(b.deadline(), Some(9.5));
    }

    #[test]
    fn warmed_batcher_cycle_never_allocates() {
        let mut b = Batcher::new(BatchPolicy::new(4, 1.0), 8);
        let idx = [0u32, 5];
        let vals = [1.0, -1.0];
        // Warm one full cycle, then the steady state must be silent.
        for _ in 0..4 {
            b.push(0.0, &idx, &vals);
        }
        b.clear();
        let before = crate::testkit::alloc::current_thread_allocations();
        for cycle in 0..10 {
            for k in 0..4 {
                b.push(cycle as f64 + 0.1 * k as f64, &idx, &vals);
            }
            b.clear();
        }
        let after = crate::testkit::alloc::current_thread_allocations();
        assert_eq!(after - before, 0, "warmed batcher allocated");
    }
}
