//! Held-out stream replay: online quality metrics plus latency and
//! throughput measurement for the batching front end.
//!
//! [`replay`] drives a request stream (rows of a
//! [`CsrMatrix`](crate::data::CsrMatrix), in arrival order) through a
//! [`Batcher`] at a given arrival rate on a **virtual clock** — arrival
//! `i` lands at `i/rate` seconds — so queueing behavior (flush reasons,
//! batch sizes, per-request waits) is exactly reproducible. Only batch
//! *compute* is measured on the wall clock; end-to-end latency is
//! virtual wait + measured compute. The predictions that come out are
//! bit-identical to one sequential sweep over the whole stream
//! (batching slices the row sweep, it never reorders or re-associates a
//! single dot product).

use std::time::Instant;

use crate::data::csr::CsrMatrix;
use crate::util::pool::F64Pool;

use super::batch::{BatchPolicy, Batcher, FlushReason};
use super::model::Output;
use super::predict::Predictor;

/// Running quality metrics over a served stream, per output family:
/// RMSE for regression values, accuracy for classification. Accumulates
/// in stream order, so the final RMSE is bit-identical to
/// `data::eval::rmse` over the concatenated stream.
#[derive(Debug, Clone)]
pub struct OnlineEval {
    output: Output,
    count: usize,
    sq_err: f64,
    correct: usize,
}

impl OnlineEval {
    pub fn new(output: Output) -> OnlineEval {
        OnlineEval {
            output,
            count: 0,
            sq_err: 0.0,
            correct: 0,
        }
    }

    /// Fold one batch of finalized predictions against its labels.
    /// Regression labels are target values; classification labels are ±1
    /// **in the same space as the predictions** — for dual-layout rows
    /// (label-scaled `q_j = y_j·x_j`), a score `q_j·v > 0` means correct,
    /// so pass `+1` labels there.
    pub fn update(&mut self, preds: &[f64], labels: &[f64]) {
        assert_eq!(preds.len(), labels.len());
        self.count += preds.len();
        match self.output {
            Output::Value => {
                for (p, y) in preds.iter().zip(labels.iter()) {
                    self.sq_err += (p - y) * (p - y);
                }
            }
            Output::Score => {
                self.correct += preds
                    .iter()
                    .zip(labels.iter())
                    .filter(|(&p, &y)| p * y > 0.0)
                    .count();
            }
            Output::Probability => {
                // p > ½ predicts the positive class.
                self.correct += preds
                    .iter()
                    .zip(labels.iter())
                    .filter(|(&p, &y)| (p - 0.5) * y > 0.0)
                    .count();
            }
        }
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Running RMSE (regression streams; `None` for classifiers).
    pub fn rmse(&self) -> Option<f64> {
        match self.output {
            Output::Value if self.count > 0 => Some((self.sq_err / self.count as f64).sqrt()),
            Output::Value => Some(0.0),
            _ => None,
        }
    }

    /// Running accuracy (classification streams; `None` for regression).
    pub fn accuracy(&self) -> Option<f64> {
        match self.output {
            Output::Score | Output::Probability if self.count > 0 => {
                Some(self.correct as f64 / self.count as f64)
            }
            Output::Score | Output::Probability => Some(0.0),
            _ => None,
        }
    }

    /// One-line metric for logs: `rmse=…` or `accuracy=…`.
    pub fn summary(&self) -> String {
        match (self.rmse(), self.accuracy()) {
            (Some(r), _) => format!("rmse={:.6}", r),
            (_, Some(a)) => format!("accuracy={:.4}", a),
            _ => "n/a".into(),
        }
    }
}

/// What a stream replay measured.
#[derive(Debug, Clone)]
pub struct ServeStats {
    pub requests: usize,
    pub batches: usize,
    pub size_flushes: usize,
    pub deadline_flushes: usize,
    pub drain_flushes: usize,
    /// Mean rows per batch.
    pub mean_batch: f64,
    pub max_batch_seen: usize,
    /// Virtual queue wait percentiles (seconds) — deterministic.
    pub wait_p50_s: f64,
    pub wait_p99_s: f64,
    /// End-to-end latency percentiles: virtual wait + measured compute.
    pub latency_p50_s: f64,
    pub latency_p99_s: f64,
    /// Total wall-clock compute across all batches (seconds).
    pub compute_s: f64,
    /// Requests / compute_s — the raw serving throughput.
    pub preds_per_sec: f64,
    /// Online quality over the stream.
    pub eval: OnlineEval,
}

impl ServeStats {
    /// Multi-line human summary for the CLI.
    pub fn render(&self) -> String {
        format!(
            "requests={} batches={} (size {} / deadline {} / drain {}) mean_batch={:.1} max={}\n\
             wait    p50={:.1}µs p99={:.1}µs (virtual queueing)\n\
             latency p50={:.1}µs p99={:.1}µs (wait + measured compute)\n\
             throughput {:.0} preds/s over {:.4}s compute; {}",
            self.requests,
            self.batches,
            self.size_flushes,
            self.deadline_flushes,
            self.drain_flushes,
            self.mean_batch,
            self.max_batch_seen,
            self.wait_p50_s * 1e6,
            self.wait_p99_s * 1e6,
            self.latency_p50_s * 1e6,
            self.latency_p99_s * 1e6,
            self.preds_per_sec,
            self.compute_s,
            self.eval.summary()
        )
    }
}

/// Nearest-rank percentile of an unsorted sample (sorts a copy — cold
/// path, runs once per replay).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((s.len() as f64 - 1.0) * p).round() as usize;
    s[idx.min(s.len() - 1)]
}

struct ReplayState<'a> {
    predictor: &'a Predictor,
    batcher: Batcher,
    shards: usize,
    pool: F64Pool,
    labels: Option<&'a [f64]>,
    served: usize,
    preds_out: &'a mut Vec<f64>,
    eval: OnlineEval,
    waits: Vec<f64>,
    lats: Vec<f64>,
    compute_s: f64,
    batches: usize,
    size_flushes: usize,
    deadline_flushes: usize,
    drain_flushes: usize,
    max_batch_seen: usize,
}

impl ReplayState<'_> {
    // lint: alloc-free (batcher flush reuses pooled score buffers)
    fn flush(&mut self, t_flush: f64, reason: FlushReason) {
        let (rows, arrivals) = self.batcher.batch();
        let b = rows.m;
        debug_assert!(b > 0, "flushed an empty batch");
        let mut scores = self.pool.take_cleared();
        // real wall time is the measurement (serve allowlist)
        #[allow(clippy::disallowed_methods)]
        let t0 = Instant::now();
        if self.shards > 1 {
            self.predictor.predict_sharded_into(rows, self.shards, &mut scores);
        } else {
            self.predictor.predict_into(rows, &mut scores);
        }
        let batch_compute = t0.elapsed().as_secs_f64();
        self.compute_s += batch_compute;
        for &arr in arrivals {
            let wait = t_flush - arr;
            self.waits.push(wait);
            self.lats.push(wait + batch_compute);
        }
        if let Some(labels) = self.labels {
            self.eval
                .update(&scores, &labels[self.served..self.served + b]);
        }
        self.preds_out.extend_from_slice(&scores);
        self.pool.put(scores);
        self.served += b;
        self.batches += 1;
        self.max_batch_seen = self.max_batch_seen.max(b);
        match reason {
            FlushReason::Size => self.size_flushes += 1,
            FlushReason::Deadline => self.deadline_flushes += 1,
            FlushReason::Drain => self.drain_flushes += 1,
        }
        self.batcher.clear();
    }
}

/// Replay `rows` as a request stream arriving at `rate` requests/sec
/// through the batching front end, predicting each flushed batch
/// (sharded across `shards` threads when > 1). Predictions land in
/// `preds_out` in request order, bit-identical to one sequential
/// `predict_into` over the whole stream. `labels`, when given, must
/// align with `rows` (see [`OnlineEval::update`] for the classification
/// label convention).
pub fn replay(
    predictor: &Predictor,
    rows: &CsrMatrix,
    labels: Option<&[f64]>,
    policy: BatchPolicy,
    rate: f64,
    shards: usize,
    preds_out: &mut Vec<f64>,
) -> ServeStats {
    assert!(rate > 0.0, "arrival rate must be > 0");
    if let Some(l) = labels {
        assert_eq!(l.len(), rows.m, "labels must align with request rows");
    }
    preds_out.clear();
    preds_out.reserve(rows.m);
    let mut st = ReplayState {
        predictor,
        batcher: Batcher::new(policy, rows.n),
        shards,
        pool: F64Pool::with_buffers(1, policy.max_batch),
        labels,
        served: 0,
        preds_out,
        eval: OnlineEval::new(predictor.model().output()),
        waits: Vec::with_capacity(rows.m),
        lats: Vec::with_capacity(rows.m),
        compute_s: 0.0,
        batches: 0,
        size_flushes: 0,
        deadline_flushes: 0,
        drain_flushes: 0,
        max_batch_seen: 0,
    };
    for i in 0..rows.m {
        let t_arr = i as f64 / rate;
        // The deadline timer may fire before this arrival: flush at the
        // timer instant, not at the arrival that observed it.
        if let Some(d) = st.batcher.deadline() {
            if d <= t_arr {
                st.flush(d, FlushReason::Deadline);
            }
        }
        let (ci, vs) = rows.row(i);
        if st.batcher.push(t_arr, ci, vs) {
            st.flush(t_arr, FlushReason::Size);
        }
    }
    if !st.batcher.is_empty() {
        // End of stream: the pending tail flushes when its timer fires.
        let d = st.batcher.deadline().expect("non-empty batcher has a deadline");
        st.flush(d, FlushReason::Drain);
    }
    debug_assert_eq!(st.served, rows.m);
    let requests = rows.m;
    ServeStats {
        requests,
        batches: st.batches,
        size_flushes: st.size_flushes,
        deadline_flushes: st.deadline_flushes,
        drain_flushes: st.drain_flushes,
        mean_batch: if st.batches > 0 {
            requests as f64 / st.batches as f64
        } else {
            0.0
        },
        max_batch_seen: st.max_batch_seen,
        wait_p50_s: percentile(&st.waits, 0.50),
        wait_p99_s: percentile(&st.waits, 0.99),
        latency_p50_s: percentile(&st.lats, 0.50),
        latency_p99_s: percentile(&st.lats, 0.99),
        compute_s: st.compute_s,
        preds_per_sec: requests as f64 / st.compute_s.max(1e-12),
        eval: st.eval,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Precision;
    use crate::data::synthetic::{webspam_like, SyntheticSpec};
    use crate::problem::Problem;
    use crate::serve::model::PrimalModel;

    fn setup() -> (CsrMatrix, Vec<f64>, Predictor) {
        let ds = webspam_like(&SyntheticSpec::small());
        let rows = CsrMatrix::from_csc(&ds.a);
        let alpha: Vec<f64> = (0..ds.n()).map(|j| (j as f64 * 0.11).cos() * 0.1).collect();
        let p = Predictor::new(PrimalModel::from_parts(
            Problem::ridge(1.0),
            &alpha,
            &[],
            Precision::F64,
            1,
        ));
        (rows, ds.b.clone(), p)
    }

    #[test]
    fn online_rmse_matches_batch_rmse_bitwise() {
        let mut ev = OnlineEval::new(Output::Value);
        let preds = [1.0, 2.5, -0.5, 4.0, 0.0];
        let labels = [1.5, 2.0, 0.0, 3.0, 1.0];
        // Fold in two uneven batches — same left-to-right order.
        ev.update(&preds[..2], &labels[..2]);
        ev.update(&preds[2..], &labels[2..]);
        assert_eq!(ev.count(), 5);
        assert_eq!(
            ev.rmse().unwrap().to_bits(),
            crate::data::eval::rmse(&preds, &labels).to_bits()
        );
        assert!(ev.accuracy().is_none());
    }

    #[test]
    fn online_accuracy_handles_scores_and_probabilities() {
        let mut score = OnlineEval::new(Output::Score);
        score.update(&[2.0, -1.0, 0.5], &[1.0, 1.0, 1.0]);
        assert!((score.accuracy().unwrap() - 2.0 / 3.0).abs() < 1e-12);
        assert!(score.rmse().is_none());
        let mut prob = OnlineEval::new(Output::Probability);
        prob.update(&[0.9, 0.4, 0.6, 0.5], &[1.0, -1.0, -1.0, 1.0]);
        // 0.9→+ ✓, 0.4→− ✓, 0.6→+ ✗, 0.5 undecided ✗
        assert!((prob.accuracy().unwrap() - 0.5).abs() < 1e-12);
        assert!(prob.summary().starts_with("accuracy="));
    }

    #[test]
    fn replay_preds_are_bit_identical_to_one_sequential_sweep() {
        let (rows, labels, p) = setup();
        let seq = p.predict(&rows);
        for (rate, shards) in [(1e5, 1), (300.0, 1), (1e5, 4)] {
            let mut preds = Vec::new();
            let stats = replay(
                &p,
                &rows,
                Some(&labels),
                BatchPolicy::new(16, 0.01),
                rate,
                shards,
                &mut preds,
            );
            assert_eq!(stats.requests, rows.m);
            assert_eq!(preds.len(), seq.len());
            for (i, (a, b)) in preds.iter().zip(seq.iter()).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "row {} (rate {})", i, rate);
            }
            assert_eq!(stats.eval.count(), rows.m);
        }
    }

    #[test]
    fn fast_arrivals_land_in_the_size_regime() {
        let (rows, _, p) = setup();
        let policy = BatchPolicy::new(8, 0.01); // cutover at 800/s
        let mut preds = Vec::new();
        let stats = replay(&p, &rows, None, policy, 100_000.0, 1, &mut preds);
        // Far above cutover: every non-drain flush is a size flush of
        // exactly max_batch rows.
        assert_eq!(stats.deadline_flushes, 0);
        assert_eq!(stats.size_flushes, rows.m / 8);
        assert_eq!(stats.max_batch_seen, 8);
        assert!(stats.drain_flushes <= 1);
        // Queue waits are bounded by the fill time, way under the deadline.
        assert!(stats.wait_p99_s < 8.0 / 100_000.0 + 1e-12);
    }

    #[test]
    fn slow_arrivals_land_in_the_deadline_regime() {
        let (rows, _, p) = setup();
        let policy = BatchPolicy::new(8, 0.01); // cutover at 800/s
        let mut preds = Vec::new();
        // Inter-arrival (0.1s) ≫ max_delay so every timer fires long
        // before the next arrival — regime membership is fp-robust.
        let stats = replay(&p, &rows, None, policy, 10.0, 1, &mut preds);
        // Far below cutover: the timer always wins — no size flush, and
        // no request ever waits past the deadline.
        assert_eq!(stats.size_flushes, 0);
        assert!(stats.deadline_flushes > 0);
        assert!(stats.mean_batch < 2.0);
        assert!(stats.wait_p99_s <= policy.max_delay + 1e-12);
        // Deadline flushes wait exactly max_delay (virtual clock).
        assert!((stats.wait_p50_s - policy.max_delay).abs() < 1e-12);
    }

    #[test]
    fn replay_evaluates_the_stream() {
        let (rows, labels, p) = setup();
        let mut preds = Vec::new();
        let stats = replay(
            &p,
            &rows,
            Some(&labels),
            BatchPolicy::new(32, 0.001),
            1e6,
            1,
            &mut preds,
        );
        let want = crate::data::eval::rmse(&preds, &labels);
        assert_eq!(stats.eval.rmse().unwrap().to_bits(), want.to_bits());
        assert!(stats.preds_per_sec > 0.0);
        assert!(stats.render().contains("rmse="));
    }
}
