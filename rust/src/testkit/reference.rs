//! The pre-problem-layer elastic-net SCD solver, preserved VERBATIM as a
//! reference implementation.
//!
//! Before the `Problem` API (DESIGN.md §9) the crate hard-wired this exact
//! loop: (λn, η) threaded as bare floats, elastic-net update inlined. Two
//! consumers pin the redesigned hot path against it from the ONE copy
//! here, so the reference can never silently fork:
//!
//! * `tests/integration_problems.rs` — asserts the `SquaredLoss`-routed
//!   [`NativeScd`](crate::solver::scd::NativeScd) reproduces its Δα/Δv
//!   BIT for BIT across ridge/elastic/lasso hyper-parameters;
//! * `benches/hotpath.rs` — times it against the problem-dispatched round
//!   (the `problem_dispatch.dispatch_ratio` target), with the same
//!   `solve_into` shape (r₀ snapshot + Δ materialization) so the pair is
//!   symmetric and the ratio isolates the dispatch cost alone.
//!
//! Do NOT modernize this code — its whole value is staying frozen.

use crate::data::WorkerData;
use crate::linalg::{self, Xorshift128};
use crate::solver::SolveResult;

/// The pre-redesign hard-coded elastic-net SCD (see module docs). Scratch
/// buffers persist across solves exactly like the historical `NativeScd`,
/// so steady-state rounds are allocation-free.
#[derive(Debug, Default)]
pub struct PreRedesignElasticScd {
    r: Vec<f64>,
    r0: Vec<f64>,
    alpha_buf: Vec<f64>,
}

impl PreRedesignElasticScd {
    pub fn new() -> PreRedesignElasticScd {
        PreRedesignElasticScd::default()
    }

    /// One round, verbatim pre-problem `solve_into`.
    #[allow(clippy::too_many_arguments)]
    pub fn solve_into(
        &mut self,
        data: &WorkerData,
        alpha: &[f64],
        v: &[f64],
        b: &[f64],
        h: usize,
        lam_n: f64,
        eta: f64,
        sigma: f64,
        seed: u64,
        out: &mut SolveResult,
    ) {
        let nk = data.n_local();
        self.r.clear();
        self.r.extend(v.iter().zip(b.iter()).map(|(&v, &b)| v - b));
        self.r0.clear();
        self.r0.extend_from_slice(&self.r);
        self.alpha_buf.clear();
        self.alpha_buf.extend_from_slice(alpha);

        let mut rng = Xorshift128::new(seed);
        let lam_eta = lam_n * eta;
        let tau_num = lam_n * (1.0 - eta);
        let mut steps = 0usize;
        if nk > 0 {
            for _ in 0..h {
                let j = rng.next_usize(nk);
                let csq = data.col_sq[j];
                let denom = sigma * csq + lam_eta;
                if denom <= 0.0 {
                    continue;
                }
                let (ri, vs) = data.flat.col(j);
                let cj_r = linalg::dot_indexed(ri, vs, &self.r);
                let aj = self.alpha_buf[j];
                let atilde = (sigma * csq * aj - cj_r) / denom;
                let anew = linalg::soft_threshold(atilde, tau_num / denom);
                let delta = anew - aj;
                if delta != 0.0 {
                    linalg::axpy_indexed(sigma * delta, ri, vs, &mut self.r);
                    self.alpha_buf[j] = anew;
                }
                steps += 1;
            }
        }

        out.delta_alpha.clear();
        out.delta_alpha.extend(
            self.alpha_buf
                .iter()
                .zip(alpha.iter())
                .map(|(&a, &a0)| a - a0),
        );
        let inv_sigma = 1.0 / sigma;
        out.delta_v.clear();
        out.delta_v.extend(
            self.r
                .iter()
                .zip(self.r0.iter())
                .map(|(&rf, &r0)| (rf - r0) * inv_sigma),
        );
        out.steps = steps;
    }

    /// Allocating convenience wrapper (the test-side shape).
    #[allow(clippy::too_many_arguments)]
    pub fn solve(
        &mut self,
        data: &WorkerData,
        alpha: &[f64],
        v: &[f64],
        b: &[f64],
        h: usize,
        lam_n: f64,
        eta: f64,
        sigma: f64,
        seed: u64,
    ) -> SolveResult {
        let mut out = SolveResult::default();
        self.solve_into(data, alpha, v, b, h, lam_n, eta, sigma, seed, &mut out);
        out
    }
}
