//! Property-testing driver (proptest is unavailable offline — DESIGN.md
//! §Offline-toolchain substitution).
//!
//! [`check`] runs a property over `cases` randomized inputs drawn through
//! a [`Gen`], reporting the seed of the first failing case so failures
//! reproduce exactly (`Gen::new(reported_seed)`).

use crate::linalg::Xorshift128;

pub mod alloc;
pub mod reference;

/// Random input generator handed to properties.
pub struct Gen {
    rng: Xorshift128,
    pub case_seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen {
            rng: Xorshift128::new(seed),
            case_seed: seed,
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.rng.next_usize(hi - lo)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_f64() < 0.5
    }

    pub fn gaussian_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.rng.next_gaussian()).collect()
    }

    pub fn seed(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.next_usize(items.len())]
    }
}

/// Run `prop` over `cases` random cases. Panics with the failing seed on
/// the first case whose property returns `Err`.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    for case in 0..cases {
        let seed = 0x5eed_0000_0000 + case as u64;
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{}' failed on case {} (Gen seed {:#x}): {}",
                name, case, seed, msg
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_in_range() {
        let mut g = Gen::new(1);
        for _ in 0..100 {
            let u = g.usize_in(5, 10);
            assert!((5..10).contains(&u));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
        assert_eq!(g.gaussian_vec(7).len(), 7);
        let items = [1, 2, 3];
        assert!(items.contains(g.pick(&items)));
    }

    #[test]
    fn check_passes_good_property() {
        check("sum-commutes", 50, |g| {
            let a = g.f64_in(-10.0, 10.0);
            let b = g.f64_in(-10.0, 10.0);
            if (a + b - (b + a)).abs() < 1e-15 {
                Ok(())
            } else {
                Err(format!("{} + {} not commutative?!", a, b))
            }
        });
    }

    #[test]
    fn check_reports_failing_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always-fails", 3, |_| Err("nope".into()));
        });
        let msg = format!("{:?}", r.unwrap_err().downcast_ref::<String>());
        assert!(msg.contains("always-fails"));
        assert!(msg.contains("Gen seed"));
    }

    #[test]
    fn same_seed_same_stream() {
        let mut a = Gen::new(9);
        let mut b = Gen::new(9);
        for _ in 0..20 {
            assert_eq!(a.usize_in(0, 1000), b.usize_in(0, 1000));
        }
    }
}
