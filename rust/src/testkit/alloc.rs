//! Counting allocator: proves the pooled hot path performs **zero**
//! steady-state heap allocations.
//!
//! [`CountingAllocator`] wraps the system allocator and bumps a
//! *thread-local* counter on every `alloc` / `realloc` / `alloc_zeroed`.
//! Thread-locality is what makes the measurement deterministic: the test
//! harness runs tests on many threads concurrently, and a process-global
//! counter would pick up their allocations; an allocation is always counted
//! on the thread that performed it, so
//! `current_thread_allocations()` deltas around a code region measure
//! exactly that region.
//!
//! The allocator is installed as `#[global_allocator]` for this crate's
//! unit-test binary (see `lib.rs`) and for the `hotpath` bench binary.
//! When it is not installed the counter simply never moves.
//!
//! The counter cell is `const`-initialized and has no destructor, so
//! touching it inside the allocator cannot recurse or run TLS dtors.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    static THREAD_ALLOCATIONS: Cell<u64> = const { Cell::new(0) };
}

/// Allocations performed by the *current thread* since it started (only
/// meaningful when [`CountingAllocator`] is the global allocator).
pub fn current_thread_allocations() -> u64 {
    THREAD_ALLOCATIONS.with(|c| c.get())
}

/// System allocator wrapper that counts allocation events per thread.
pub struct CountingAllocator;

#[inline]
fn bump() {
    THREAD_ALLOCATIONS.with(|c| c.set(c.get() + 1));
}

// SAFETY: defers all allocation to `System`; only adds side-effect-free
// counter bumps on the calling thread.
unsafe impl GlobalAlloc for CountingAllocator {
    // SAFETY: forwards the caller's layout to `System.alloc` unchanged.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    // SAFETY: `ptr`/`layout` came from `alloc`/`realloc` above, which
    // delegate to `System` — freeing through `System` matches.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: forwards the caller's layout to `System.alloc_zeroed`.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    // SAFETY: `ptr` originates from this allocator's `System` delegation;
    // layout and size are the caller's obligations, passed through.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_this_threads_allocations() {
        let before = current_thread_allocations();
        let v: Vec<u64> = Vec::with_capacity(32);
        let after = current_thread_allocations();
        drop(v);
        // Installed in the unit-test binary → exactly the one Vec alloc
        // (dealloc is not counted).
        assert_eq!(after - before, 1);
    }

    #[test]
    fn non_allocating_region_counts_zero() {
        let mut acc = 0.0f64;
        let before = current_thread_allocations();
        for i in 0..1000 {
            acc += i as f64;
        }
        let after = current_thread_allocations();
        assert_eq!(after - before, 0);
        assert!(acc > 0.0);
    }
}
