//! Column-wise data partitioners (§4.1 of the paper).
//!
//! Spark's default placement corresponds to contiguous [`Partitioner::Range`]
//! blocks; the paper's MPI implementation (E) ships a *custom load-balancing
//! algorithm* that equalizes `Σ_{i∈P_k} nnz(c_i)` across workers — here
//! [`Partitioner::BalancedNnz`], a greedy longest-processing-time bin pack.
//! The paper found it "comparable to the Spark partitioning" on webspam;
//! `sparkbench partition-stats` lets you verify the imbalance numbers.

use super::sparse::CscMatrix;
use crate::linalg::Xorshift128;

/// Strategy for assigning columns to workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partitioner {
    /// Contiguous ranges of columns (Spark default for a range-partitioned RDD).
    Range,
    /// Column i → worker i mod K.
    RoundRobin,
    /// Greedy LPT on column nnz: sort columns by nnz desc, always assign to
    /// the currently lightest worker (the paper's MPI load balancer).
    BalancedNnz,
    /// Uniformly random assignment (ablation baseline).
    Random,
    /// Deliberately imbalanced contiguous split (chaos layer, DESIGN.md
    /// §12): worker 0 gets ~half the columns, worker 1 half the rest, and
    /// so on geometrically (each worker at least one column while any
    /// remain). The adversarial baseline the skew experiments measure
    /// `BalancedNnz` against.
    Skewed,
}

impl Partitioner {
    pub fn parse(s: &str) -> Option<Partitioner> {
        match s {
            "range" => Some(Partitioner::Range),
            "round-robin" | "roundrobin" => Some(Partitioner::RoundRobin),
            "balanced-nnz" | "balanced" => Some(Partitioner::BalancedNnz),
            "random" => Some(Partitioner::Random),
            "skewed" => Some(Partitioner::Skewed),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Partitioner::Range => "range",
            Partitioner::RoundRobin => "round-robin",
            Partitioner::BalancedNnz => "balanced-nnz",
            Partitioner::Random => "random",
            Partitioner::Skewed => "skewed",
        }
    }
}

/// The partition `{P_k}`: worker k owns global columns `parts[k]`.
#[derive(Debug, Clone, PartialEq)]
pub struct Partitioning {
    pub parts: Vec<Vec<u32>>,
}

impl Partitioning {
    /// Partition `n` columns of `a` across `k` workers.
    pub fn build(p: Partitioner, a: &CscMatrix, k: usize, seed: u64) -> Partitioning {
        assert!(k > 0, "need at least one worker");
        let n = a.n;
        let parts = match p {
            Partitioner::Range => {
                let base = n / k;
                let extra = n % k;
                let mut out = Vec::with_capacity(k);
                let mut start = 0u32;
                for w in 0..k {
                    let len = base + usize::from(w < extra);
                    out.push((start..start + len as u32).collect());
                    start += len as u32;
                }
                out
            }
            Partitioner::RoundRobin => {
                let mut out = vec![Vec::new(); k];
                for c in 0..n as u32 {
                    out[(c as usize) % k].push(c);
                }
                out
            }
            Partitioner::BalancedNnz => {
                let mut cols: Vec<u32> = (0..n as u32).collect();
                cols.sort_by_key(|&c| std::cmp::Reverse(a.col_nnz(c as usize)));
                let mut out = vec![Vec::new(); k];
                let mut load = vec![0usize; k];
                for c in cols {
                    // index of lightest worker
                    let w = (0..k).min_by_key(|&w| load[w]).unwrap();
                    load[w] += a.col_nnz(c as usize);
                    out[w].push(c);
                }
                // Keep deterministic intra-worker order for reproducibility.
                for p in out.iter_mut() {
                    p.sort();
                }
                out
            }
            Partitioner::Random => {
                let mut rng = Xorshift128::new(seed);
                let mut out = vec![Vec::new(); k];
                for c in 0..n as u32 {
                    out[rng.next_usize(k)].push(c);
                }
                out
            }
            Partitioner::Skewed => {
                // Geometric halving: worker w takes half of what is left
                // (at least one column while any remain); the last worker
                // sweeps the remainder. Max/min column-count ratio grows
                // like 2^(k-1) — the straggler regime by construction.
                let mut out = Vec::with_capacity(k);
                let mut start = 0usize;
                for w in 0..k {
                    let remaining = n - start;
                    let len = if w + 1 == k {
                        remaining
                    } else if remaining > 0 {
                        (remaining / 2).max(1)
                    } else {
                        0
                    };
                    out.push((start as u32..(start + len) as u32).collect());
                    start += len;
                }
                out
            }
        };
        Partitioning { parts }
    }

    /// Two-level (nested) layout for hierarchical parallelism: build the
    /// **flat** `k·t` partitioning and view worker rank `w` as owning the
    /// `t` consecutive sub-shards `[w·t, (w+1)·t)` (see
    /// [`rank_shards`](Partitioning::rank_shards)). Because the sub-shards
    /// ARE the flat parts, a nested run's coordinate sets, σ′ and per-shard
    /// seeds line up with a flat `k·t` ring exactly — that is what makes
    /// nested trajectories bit-identical to flat ones for every
    /// partitioner (DESIGN.md §10), and what makes resume re-sharding
    /// deterministic (same partitioner, `k·t`, seed ⇒ same shards).
    pub fn build_nested(p: Partitioner, a: &CscMatrix, k: usize, t: usize, seed: u64) -> Partitioning {
        assert!(t > 0, "need at least one sub-shard per worker");
        Partitioning::build(p, a, k * t, seed)
    }

    /// Rank `w`'s sub-shard column sets under a nested view with `t`
    /// sub-shards per rank (`parts.len()` must be a multiple of `t`).
    pub fn rank_shards(&self, w: usize, t: usize) -> &[Vec<u32>] {
        debug_assert_eq!(self.parts.len() % t, 0);
        &self.parts[w * t..(w + 1) * t]
    }

    pub fn num_workers(&self) -> usize {
        self.parts.len()
    }

    /// Per-worker nnz loads.
    pub fn loads(&self, a: &CscMatrix) -> Vec<usize> {
        self.parts
            .iter()
            .map(|p| p.iter().map(|&c| a.col_nnz(c as usize)).sum())
            .collect()
    }

    /// Load imbalance: max(load)/mean(load) − 1 (0 = perfectly balanced).
    pub fn imbalance(&self, a: &CscMatrix) -> f64 {
        let loads = self.loads(a);
        let max = *loads.iter().max().unwrap_or(&0) as f64;
        let mean = loads.iter().sum::<usize>() as f64 / loads.len().max(1) as f64;
        if mean == 0.0 {
            0.0
        } else {
            max / mean - 1.0
        }
    }

    /// Validation: every column appears exactly once.
    pub fn validate(&self, n: usize) -> Result<(), String> {
        let mut seen = vec![false; n];
        for (w, p) in self.parts.iter().enumerate() {
            for &c in p {
                let c = c as usize;
                if c >= n {
                    return Err(format!("worker {} has column {} >= n {}", w, c, n));
                }
                if seen[c] {
                    return Err(format!("column {} assigned twice", c));
                }
                seen[c] = true;
            }
        }
        if let Some(missing) = seen.iter().position(|&s| !s) {
            return Err(format!("column {} unassigned", missing));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{webspam_like, SyntheticSpec};

    fn sample() -> CscMatrix {
        webspam_like(&SyntheticSpec::small()).a
    }

    #[test]
    fn range_is_contiguous_and_complete() {
        let a = sample();
        let p = Partitioning::build(Partitioner::Range, &a, 4, 0);
        p.validate(a.n).unwrap();
        assert_eq!(p.num_workers(), 4);
        // contiguity
        for part in &p.parts {
            for w in part.windows(2) {
                assert_eq!(w[1], w[0] + 1);
            }
        }
        // size difference at most 1
        let sizes: Vec<usize> = p.parts.iter().map(|p| p.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn round_robin_complete() {
        let a = sample();
        let p = Partitioning::build(Partitioner::RoundRobin, &a, 7, 0);
        p.validate(a.n).unwrap();
    }

    #[test]
    fn random_complete_and_seeded() {
        let a = sample();
        let p1 = Partitioning::build(Partitioner::Random, &a, 5, 9);
        let p2 = Partitioning::build(Partitioner::Random, &a, 5, 9);
        p1.validate(a.n).unwrap();
        assert_eq!(p1, p2);
    }

    #[test]
    fn balanced_nnz_beats_range_on_skewed_data() {
        let a = sample();
        let range = Partitioning::build(Partitioner::Range, &a, 8, 0);
        let bal = Partitioning::build(Partitioner::BalancedNnz, &a, 8, 0);
        bal.validate(a.n).unwrap();
        assert!(
            bal.imbalance(&a) <= range.imbalance(&a) + 1e-12,
            "balanced {} vs range {}",
            bal.imbalance(&a),
            range.imbalance(&a)
        );
        // And it should be nearly perfect on this data.
        assert!(bal.imbalance(&a) < 0.05, "imbalance {}", bal.imbalance(&a));
    }

    #[test]
    fn single_worker_gets_everything() {
        let a = sample();
        for p in [
            Partitioner::Range,
            Partitioner::RoundRobin,
            Partitioner::BalancedNnz,
            Partitioner::Random,
        ] {
            let part = Partitioning::build(p, &a, 1, 0);
            assert_eq!(part.parts[0].len(), a.n);
            part.validate(a.n).unwrap();
        }
    }

    #[test]
    fn more_workers_than_columns() {
        let a = CscMatrix::from_triplets(4, 2, &[(0, 0, 1.0), (1, 1, 1.0)]);
        let p = Partitioning::build(Partitioner::Range, &a, 5, 0);
        p.validate(2).unwrap();
        assert_eq!(p.num_workers(), 5); // some workers simply idle
    }

    #[test]
    fn nested_layout_is_the_flat_kt_partitioning() {
        let a = sample();
        for p in [Partitioner::Range, Partitioner::BalancedNnz, Partitioner::Random] {
            let nested = Partitioning::build_nested(p, &a, 3, 2, 9);
            let flat = Partitioning::build(p, &a, 6, 9);
            assert_eq!(nested, flat, "{:?}", p);
            nested.validate(a.n).unwrap();
            // Rank views tile the flat parts contiguously and completely.
            let mut seen = 0;
            for w in 0..3 {
                let shards = nested.rank_shards(w, 2);
                assert_eq!(shards.len(), 2);
                assert_eq!(shards[0], nested.parts[w * 2]);
                assert_eq!(shards[1], nested.parts[w * 2 + 1]);
                seen += shards.iter().map(|s| s.len()).sum::<usize>();
            }
            assert_eq!(seen, a.n);
        }
        // t = 1 degenerates to the ordinary partitioning.
        assert_eq!(
            Partitioning::build_nested(Partitioner::Range, &a, 4, 1, 0),
            Partitioning::build(Partitioner::Range, &a, 4, 0)
        );
    }

    #[test]
    fn parse_names() {
        assert_eq!(Partitioner::parse("balanced-nnz"), Some(Partitioner::BalancedNnz));
        assert_eq!(Partitioner::parse("range").unwrap().name(), "range");
        assert_eq!(Partitioner::parse("skewed"), Some(Partitioner::Skewed));
        assert_eq!(Partitioner::Skewed.name(), "skewed");
        assert!(Partitioner::parse("bogus").is_none());
    }

    #[test]
    fn skewed_is_complete_and_geometric() {
        let a = sample();
        let p = Partitioning::build(Partitioner::Skewed, &a, 4, 0);
        p.validate(a.n).unwrap();
        let sizes: Vec<usize> = p.parts.iter().map(|p| p.len()).collect();
        // Geometric halving: strictly decreasing until the tail remainder.
        assert_eq!(sizes[0], a.n / 2);
        assert!(sizes[0] > 2 * sizes[2], "sizes {:?}", sizes);
        // Far more imbalanced than range by construction: worker 0 holds
        // ~half the columns, so max/mean ≈ 2 (imbalance ≈ 1) while range
        // stays near 0.
        let range = Partitioning::build(Partitioner::Range, &a, 4, 0);
        assert!(p.imbalance(&a) > 0.5, "skewed imbalance {}", p.imbalance(&a));
        assert!(p.imbalance(&a) > 2.0 * range.imbalance(&a));
    }

    #[test]
    fn skewed_degenerate_shapes() {
        let a = sample();
        let solo = Partitioning::build(Partitioner::Skewed, &a, 1, 0);
        assert_eq!(solo.parts[0].len(), a.n);
        solo.validate(a.n).unwrap();
        // More workers than columns: early workers get >= 1 column while
        // any remain; the rest idle.
        let tiny = CscMatrix::from_triplets(4, 2, &[(0, 0, 1.0), (1, 1, 1.0)]);
        let p = Partitioning::build(Partitioner::Skewed, &tiny, 5, 0);
        p.validate(2).unwrap();
        assert_eq!(p.num_workers(), 5);
    }
}
