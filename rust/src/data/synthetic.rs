//! Synthetic dataset generators.
//!
//! The paper evaluates on **webspam** (350k docs × 16.6M trigram features,
//! highly sparse with power-law feature popularity). That corpus is not
//! redistributable and far exceeds this testbed, so [`webspam_like`]
//! generates a structurally matched stand-in: power-law column occupancy,
//! positive skewed values, labels from a sparse ground-truth model plus
//! noise. The communication/computation trade-off the paper studies depends
//! on (bytes per round) vs (flops per round), both preserved under this
//! proportional down-scaling (DESIGN.md §2).

use super::sparse::CscMatrix;
use super::Dataset;
use crate::linalg::Xorshift128;

/// Parameters for the webspam-like generator.
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    /// Rows (datapoints).
    pub m: usize,
    /// Columns (features).
    pub n: usize,
    /// Average nonzeros per column.
    pub avg_col_nnz: usize,
    /// Power-law exponent for row popularity (webspam-ish skew ≈ 1.3).
    pub powerlaw_s: f64,
    /// Fraction of ground-truth model coordinates that are nonzero.
    pub model_density: f64,
    /// Label noise stddev.
    pub noise: f64,
    /// RNG seed.
    pub seed: u64,
}

impl SyntheticSpec {
    /// Default experiment scale: big enough that compute is measurable,
    /// small enough that a full H sweep over five frameworks runs in minutes.
    pub fn webspam_mini() -> SyntheticSpec {
        SyntheticSpec {
            m: 2048,
            n: 32768,
            avg_col_nnz: 96,
            powerlaw_s: 1.3,
            model_density: 0.25,
            noise: 0.05,
            seed: 42,
        }
    }

    /// Tiny scale for unit/integration tests.
    pub fn small() -> SyntheticSpec {
        SyntheticSpec {
            m: 128,
            n: 256,
            avg_col_nnz: 16,
            powerlaw_s: 1.2,
            model_density: 0.3,
            noise: 0.01,
            seed: 7,
        }
    }

    /// Matches the default AOT artifact shape (m=512) for PJRT examples.
    pub fn pjrt_default() -> SyntheticSpec {
        SyntheticSpec {
            m: 512,
            n: 2048,
            avg_col_nnz: 32,
            powerlaw_s: 1.2,
            model_density: 0.25,
            noise: 0.02,
            seed: 13,
        }
    }
}

/// Generate a webspam-like sparse regression dataset.
pub fn webspam_like(spec: &SyntheticSpec) -> Dataset {
    let mut rng = Xorshift128::new(spec.seed);
    let m = spec.m;
    let n = spec.n;

    // Sparse ground-truth model.
    let mut alpha_true = vec![0.0; n];
    for a in alpha_true.iter_mut() {
        if rng.next_f64() < spec.model_density {
            *a = rng.next_gaussian();
        }
    }

    // Columns: nnz ~ 1 + Poisson-ish around avg (geometric mixture keeps it
    // simple and deterministic), rows drawn from a power law so a few
    // datapoints are dense (webspam's head documents).
    let mut triplets: Vec<(usize, usize, f64)> = Vec::with_capacity(n * spec.avg_col_nnz);
    let mut seen = vec![u32::MAX; m];
    for c in 0..n {
        let target = 1 + (rng.next_f64() * 2.0 * spec.avg_col_nnz as f64) as usize;
        let target = target.min(m);
        let mut placed = 0usize;
        let mut attempts = 0usize;
        while placed < target && attempts < 8 * target {
            let r = rng.next_powerlaw(m, spec.powerlaw_s);
            attempts += 1;
            if seen[r] == c as u32 {
                continue; // already placed in this column
            }
            seen[r] = c as u32;
            // Positive skewed values (tf-idf-ish): |N(0,1)| + 0.1
            let v = rng.next_gaussian().abs() + 0.1;
            triplets.push((r, c, v));
            placed += 1;
        }
    }

    let a = CscMatrix::from_triplets(m, n, &triplets);

    // Labels b = A α* + ε.
    let mut b = a.matvec(&alpha_true);
    for bi in b.iter_mut() {
        *bi += spec.noise * rng.next_gaussian();
    }

    Dataset {
        a,
        b,
        name: format!("webspam-like(m={},n={},s={})", m, n, spec.powerlaw_s),
    }
}

/// Generate a dataset whose **column mass** is Zipfian (chaos layer,
/// DESIGN.md §12): column `j` targets `nnz ∝ 1/(j+1)^s` with
/// `s = spec.powerlaw_s`, normalized so the mean column nnz stays
/// `spec.avg_col_nnz`. Where [`webspam_like`] skews *row* popularity
/// (head documents) with near-uniform column mass, this generator front-
/// loads the columns themselves — so contiguous partitionings (range,
/// skewed) produce heavy head shards and a straggler regime, while
/// `balanced-nnz` flattens it back out. Rows are drawn uniformly; labels
/// come from a sparse ground-truth model plus noise, as in
/// [`webspam_like`].
pub fn zipf_columns(spec: &SyntheticSpec) -> Dataset {
    let mut rng = Xorshift128::new(spec.seed ^ 0x21BF);
    let m = spec.m;
    let n = spec.n;
    let s = spec.powerlaw_s;

    // Sparse ground-truth model.
    let mut alpha_true = vec![0.0; n];
    for a in alpha_true.iter_mut() {
        if rng.next_f64() < spec.model_density {
            *a = rng.next_gaussian();
        }
    }

    // Normalize the Zipf mass so Σ target_j = n · avg_col_nnz:
    // target_j = c0 / (j+1)^s with c0 = n·avg / H_{n,s}.
    let harmonic: f64 = (0..n).map(|j| 1.0 / ((j + 1) as f64).powf(s)).sum();
    let c0 = (n * spec.avg_col_nnz) as f64 / harmonic;

    let mut triplets: Vec<(usize, usize, f64)> = Vec::with_capacity(n * spec.avg_col_nnz);
    let mut seen = vec![u32::MAX; m];
    for c in 0..n {
        let target = (c0 / ((c + 1) as f64).powf(s)).round().max(1.0) as usize;
        let target = target.min(m);
        let mut placed = 0usize;
        let mut attempts = 0usize;
        while placed < target && attempts < 8 * target {
            let r = rng.next_usize(m);
            attempts += 1;
            if seen[r] == c as u32 {
                continue; // already placed in this column
            }
            seen[r] = c as u32;
            let v = rng.next_gaussian().abs() + 0.1;
            triplets.push((r, c, v));
            placed += 1;
        }
    }

    let a = CscMatrix::from_triplets(m, n, &triplets);

    // Labels b = A α* + ε.
    let mut b = a.matvec(&alpha_true);
    for bi in b.iter_mut() {
        *bi += spec.noise * rng.next_gaussian();
    }

    Dataset {
        a,
        b,
        name: format!("zipf-columns(m={},n={},s={})", m, n, s),
    }
}

/// Linearly separable ±1 classification corpus in the **dual layout** the
/// SVM/logistic problems train on (DESIGN.md §9): the matrix is d × n with
/// one COLUMN per datapoint, already label-scaled (`q_j = y_j·x_j`, so the
/// dual box constraint is label-free), and `b = 0` (the smooth part's
/// reference vector). Returns the dataset plus the ±1 labels for
/// downstream accuracy evaluation.
///
/// Points are Gaussian, labeled by a random unit hyperplane w*, then
/// pushed `margin` further from the plane — strictly separable for any
/// margin > 0, so a trained SVM should reach accuracy ≈ 1.
pub fn separable_classes(
    d: usize,
    n_points: usize,
    margin: f64,
    seed: u64,
) -> (Dataset, Vec<f64>) {
    let mut rng = Xorshift128::new(seed);
    let mut w: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
    let norm = crate::linalg::nrm2_sq(&w).sqrt().max(1e-12);
    for x in w.iter_mut() {
        *x /= norm;
    }
    let mut data = vec![0.0; d * n_points]; // column-major d × n
    let mut labels = Vec::with_capacity(n_points);
    for j in 0..n_points {
        let col = &mut data[j * d..(j + 1) * d];
        for x in col.iter_mut() {
            *x = rng.next_gaussian();
        }
        let proj: f64 = col.iter().zip(w.iter()).map(|(a, b)| a * b).sum();
        let y = if proj >= 0.0 { 1.0 } else { -1.0 };
        for (x, wi) in col.iter_mut().zip(w.iter()) {
            *x += y * margin * wi; // push margin-deep into the class halfspace
            *x *= y; // label-scale: q_j = y_j · x_j
        }
        labels.push(y);
    }
    let a = CscMatrix::from_dense_cols(d, n_points, &data);
    (
        Dataset {
            a,
            b: vec![0.0; d],
            name: format!("separable(d={},n={},margin={})", d, n_points, margin),
        },
        labels,
    )
}

/// Fully dense Gaussian dataset (tests and PJRT-path examples).
pub fn dense_gaussian(m: usize, n: usize, seed: u64) -> Dataset {
    let mut rng = Xorshift128::new(seed);
    let mut data = vec![0.0; m * n];
    for v in data.iter_mut() {
        *v = rng.next_gaussian();
    }
    let a = CscMatrix::from_dense_cols(m, n, &data);
    let alpha_true: Vec<f64> = (0..n).map(|_| rng.next_gaussian() * 0.5).collect();
    let mut b = a.matvec(&alpha_true);
    for bi in b.iter_mut() {
        *bi += 0.01 * rng.next_gaussian();
    }
    Dataset {
        a,
        b,
        name: format!("dense-gaussian({}x{})", m, n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic() {
        let s = SyntheticSpec::small();
        let d1 = webspam_like(&s);
        let d2 = webspam_like(&s);
        assert_eq!(d1.a, d2.a);
        assert_eq!(d1.b, d2.b);
    }

    #[test]
    fn shapes_and_validity() {
        let s = SyntheticSpec::small();
        let d = webspam_like(&s);
        assert_eq!(d.m(), s.m);
        assert_eq!(d.n(), s.n);
        d.a.validate().unwrap();
        assert!(d.nnz() > 0);
        // Sparse: average column nnz in a sane band around the target.
        let avg = d.nnz() as f64 / d.n() as f64;
        assert!(avg > 2.0 && avg < 3.0 * s.avg_col_nnz as f64, "avg {}", avg);
    }

    #[test]
    fn powerlaw_rows_are_skewed() {
        let d = webspam_like(&SyntheticSpec::small());
        // Count row occupancy; head rows should be much denser than tail.
        let mut occ = vec![0usize; d.m()];
        for &r in &d.a.row_idx {
            occ[r as usize] += 1;
        }
        let head: usize = occ[..d.m() / 10].iter().sum();
        let total: usize = occ.iter().sum();
        assert!(
            head as f64 > 0.3 * total as f64,
            "head occupancy {}/{}",
            head,
            total
        );
    }

    #[test]
    fn zipf_columns_mass_is_front_loaded_and_deterministic() {
        let s = SyntheticSpec::small();
        let d1 = zipf_columns(&s);
        let d2 = zipf_columns(&s);
        assert_eq!(d1.a, d2.a);
        assert_eq!(d1.b, d2.b);
        d1.a.validate().unwrap();
        assert_eq!(d1.m(), s.m);
        assert_eq!(d1.n(), s.n);
        // Head columns carry a disproportionate share of the nnz mass:
        // the first 10% of columns should own well over 10% of entries.
        let head_cols = s.n / 10;
        let head: usize = (0..head_cols).map(|c| d1.a.col_nnz(c)).sum();
        let total = d1.nnz();
        assert!(
            head as f64 > 0.3 * total as f64,
            "head column mass {}/{}",
            head,
            total
        );
        // Mean column nnz stays in a sane band around the target (the m
        // clamp and dedup trim the head, so allow a wide band).
        let avg = total as f64 / d1.n() as f64;
        assert!(avg > 2.0 && avg < 3.0 * s.avg_col_nnz as f64, "avg {}", avg);
        // Every column is nonempty (target is clamped at >= 1).
        assert!((0..d1.n()).all(|c| d1.a.col_nnz(c) >= 1));
    }

    #[test]
    fn labels_correlate_with_data() {
        // The regression problem must be solvable: residual of the true
        // model should be far below ||b||.
        let d = webspam_like(&SyntheticSpec::small());
        let norm_b = crate::linalg::nrm2_sq(&d.b).sqrt();
        assert!(norm_b > 1.0);
    }

    #[test]
    fn dense_generator() {
        let d = dense_gaussian(32, 16, 3);
        assert_eq!(d.m(), 32);
        assert_eq!(d.n(), 16);
        assert_eq!(d.nnz(), 32 * 16); // Gaussian draws are never exactly 0
        d.a.validate().unwrap();
    }

    #[test]
    fn no_duplicate_entries_per_column() {
        let d = webspam_like(&SyntheticSpec::small());
        d.a.validate().unwrap(); // strict row ordering implies no duplicates
    }

    #[test]
    fn separable_classes_layout_and_separability() {
        let (ds, labels) = separable_classes(16, 80, 0.5, 3);
        assert_eq!(ds.m(), 16); // rows = feature dim
        assert_eq!(ds.n(), 80); // columns = datapoints
        assert_eq!(labels.len(), 80);
        assert!(labels.iter().all(|&y| y == 1.0 || y == -1.0));
        assert!(ds.b.iter().all(|&x| x == 0.0));
        ds.a.validate().unwrap();
        // Both classes occur.
        assert!(labels.iter().any(|&y| y > 0.0) && labels.iter().any(|&y| y < 0.0));
        // Label-scaled columns: every q_j has positive margin against the
        // (unknown) ground-truth plane. We can't see w*, but separability
        // implies SOME w separates: check the columns' mean direction
        // classifies most points correctly (a weak but deterministic
        // proxy: the mean of q_j correlates positively with each q_j for a
        // margin-separated Gaussian cloud).
        let d = ds.m();
        let mut mean = vec![0.0; d];
        for j in 0..ds.n() {
            let (ri, vs) = ds.a.col(j);
            for (&i, &v) in ri.iter().zip(vs.iter()) {
                mean[i as usize] += v;
            }
        }
        let correct = (0..ds.n())
            .filter(|&j| {
                let (ri, vs) = ds.a.col(j);
                let s: f64 = ri
                    .iter()
                    .zip(vs.iter())
                    .map(|(&i, &v)| v * mean[i as usize])
                    .sum();
                s > 0.0
            })
            .count();
        assert!(correct * 10 >= ds.n() * 7, "mean-direction proxy: {}/{}", correct, ds.n());
    }

    #[test]
    fn separable_classes_is_deterministic() {
        let (d1, l1) = separable_classes(8, 24, 0.3, 9);
        let (d2, l2) = separable_classes(8, 24, 0.3, 9);
        assert_eq!(d1.a, d2.a);
        assert_eq!(l1, l2);
    }
}
